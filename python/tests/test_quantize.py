"""PTQ calibration tests: scale composition, range coverage, INT-8 frozen
stage staying close to FP32 (the property Table II rests on)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model, quantize
from compile.kernels import ref


@pytest.fixture(scope="module")
def setup():
    params = model.init_params(jax.random.PRNGKey(1))
    images = np.random.RandomState(0).rand(48, model.INPUT_HW, model.INPUT_HW, 3).astype("float32")
    quant = quantize.calibrate(params, images, batch=16)
    return params, images, quant


def test_calibration_structure(setup):
    _, _, q = setup
    assert q["a_bits"] == 8 and q["w_bits"] == 8
    assert len(q["a_max"]) == len(model.ARCH)
    assert all(a > 0 for a in q["a_max"])
    assert q["pooled_a_max"] > 0
    assert q["input_a_max"] == 1.0


def test_latent_a_max_indexing(setup):
    _, _, q = setup
    for l in model.SPLITS:
        am = quantize.latent_a_max(q, l)
        if l >= model.L_LINEAR:
            assert am == q["pooled_a_max"]
        else:
            assert am == q["a_max"][l - 1]


def test_int8_forward_close_to_fp32(setup):
    params, images, q = setup
    x = jnp.asarray(images[:8])
    for l in [13, model.L_LINEAR]:
        fp = np.asarray(model.frozen_forward(params, x, l, None, use_kernels=False))
        qt = np.asarray(model.frozen_forward(params, x, l, q, use_kernels=False))
        # INT-8 fake-quant error stays small relative to the feature spread
        # (absolute per-step bounds don't compose across 13+ layers)
        err = np.abs(fp - qt)
        spread = fp.std() + 1e-9
        if l < model.L_LINEAR:
            assert np.median(err) < 0.25 * spread, (l, np.median(err), spread)
        # correlation of the representations stays high everywhere (for the
        # pooled l=15 vector, averaging makes absolute-error bounds loose
        # with an *untrained* net, so correlation is the right criterion)
        c = np.corrcoef(fp.ravel(), qt.ravel())[0, 1]
        assert c > 0.97, (l, c)


def test_quantized_latents_on_grid(setup):
    params, images, q = setup
    l = 13
    x = jnp.asarray(images[:4])
    lat = np.asarray(model.frozen_forward(params, x, l, q, use_kernels=False))
    a_max = quantize.latent_a_max(q, l)
    scale = a_max / 255.0
    codes = lat / scale
    # every latent is an integer multiple of the scale (it went through fq)
    np.testing.assert_allclose(codes, np.round(codes), atol=2e-2)
    assert lat.min() >= 0.0
    assert lat.max() <= a_max * (1 + 1e-5)


def test_fp32_latent_ranges(setup):
    params, images, _ = setup
    r = quantize.fp32_latent_ranges(params, images[:16], model.SPLITS, batch=8)
    assert set(r) == set(model.SPLITS)
    assert all(v > 0 for v in r.values())
    # ranges must cover the actual latents
    x = jnp.asarray(images[:8])
    for l in model.SPLITS:
        lat = model.frozen_forward(params, x, l, None, use_kernels=False)
        assert float(jnp.max(lat)) <= r[l] * (1 + 1e-6)


def test_weight_folding_preserves_function():
    """_fq_weights at high bit-width ~ the affine-folded original layer."""
    params = model.init_params(jax.random.PRNGKey(2))
    x = jnp.asarray(np.random.RandomState(1).rand(2, 16, 16, 16), jnp.float32)
    i = 2  # a pw layer
    kind = model.ARCH[i][0]
    p = params[i]
    folded = model._fq_weights(p, kind, bits=8)
    y_orig = model._conv_layer(kind, p, x, model.ARCH[i][3], use_kernels=False)
    y_fold = model._conv_layer(kind, folded, x, model.ARCH[i][3], use_kernels=False)
    # 8-bit weight quantization: small relative error on the outputs
    # (bound leaves headroom over the ~5.4% this seed draws — per-channel
    # a_max folding amplifies a handful of small-denominator outputs)
    denom = np.abs(np.asarray(y_orig)).mean() + 1e-6
    rel = np.abs(np.asarray(y_orig) - np.asarray(y_fold)).mean() / denom
    assert rel < 0.065, rel


@pytest.mark.parametrize("bits", [8, 7, 6])
def test_weight_quant_level_count(bits):
    w = jnp.asarray(np.random.RandomState(3).randn(64, 64), jnp.float32)
    q, s = ref.quantize_weight(w, bits)
    assert len(np.unique(np.asarray(q))) <= 2**bits
    assert float(s) > 0


def test_weight_quant_matches_cross_language_fixture():
    """ONE weight-rounding rule across the build: the fixture pins
    round-to-nearest-half-up codes (q = floor(w/S + 1/2)) for both this
    jax implementation and the rust quantizer (rust/tests/quant_edge.rs
    reads the same file). The scale-1.0 tie cases make the rule itself
    observable — half-to-even or half-away-from-zero would fail them."""
    import json
    import os

    path = os.path.join(
        os.path.dirname(__file__), "..", "..", "tools", "fixtures", "weight_quant.json"
    )
    with open(path, encoding="utf-8") as f:
        fixture = json.load(f)
    assert fixture["cases"], "fixture must not be empty"
    for case in fixture["cases"]:
        w = jnp.asarray(np.array(case["weights"], np.float32))
        q, scale = ref.quantize_weight(w, case["bits"])
        np.testing.assert_array_equal(
            np.asarray(q).astype(np.int64),
            np.array(case["codes"], np.int64),
            err_msg=f"case {case['name']}: signed levels",
        )
        assert float(scale) == pytest.approx(case["scale"], rel=1e-6), case["name"]
        np.testing.assert_allclose(
            np.asarray(q * scale, np.float32),
            np.array(case["grid"], np.float32),
            rtol=1e-5,
            atol=1e-9,
            err_msg=f"case {case['name']}: dequantized grid",
        )
