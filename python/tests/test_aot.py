"""AOT pipeline tests: HLO text emission (full constants, parseable
structure), param flattening order, manifest schema — the build/runtime
contract. A tiny lowering runs in-process; the full `make artifacts` output
is additionally validated when present."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_smoke():
    fn = lambda x: (jnp.tanh(x) @ jnp.ones((4, 2), jnp.float32),)
    low = jax.jit(fn).lower(jax.ShapeDtypeStruct((3, 4), jnp.float32))
    txt = aot.to_hlo_text(low)
    assert "HloModule" in txt
    assert "parameter(0)" in txt
    assert "ROOT" in txt


def test_constants_not_elided():
    """the print_large_constants regression: baked weights must be printed
    in full, never as `constant({...})`."""
    w = jnp.asarray(np.random.RandomState(0).randn(64, 64), jnp.float32)
    fn = lambda x: (x @ w,)
    low = jax.jit(fn).lower(jax.ShapeDtypeStruct((2, 64), jnp.float32))
    txt = aot.to_hlo_text(low)
    assert "constant({...})" not in txt
    assert "..." not in txt.replace("...", "...", 0) or "{...}" not in txt


def test_flatten_adaptive_order():
    params = model.init_params(jax.random.PRNGKey(0))
    ap = params[13:]
    leaves, treedef, names = aot._flatten_adaptive(ap)
    assert len(leaves) == len(names)
    # conv layers expose b, g, w (sorted); head exposes b, w
    assert names[0].endswith(".b") and names[1].endswith(".g") and names[2].endswith(".w")
    assert names[-2].endswith(".b") and names[-1].endswith(".w")
    # order is exactly jax's flatten order
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    for a, b in zip(jax.tree_util.tree_leaves(rebuilt), jax.tree_util.tree_leaves(ap)):
        np.testing.assert_array_equal(a, b)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)

    def test_manifest_schema(self, manifest):
        assert manifest["version"] == 1
        assert manifest["model"]["splits"] == list(model.SPLITS)
        assert manifest["batch"]["train"] == aot.B_TRAIN
        for l in model.SPLITS:
            entry = manifest["splits"][str(l)]
            for key in ("adaptive_train", "adaptive_eval", "params_bin"):
                assert os.path.exists(os.path.join(ARTIFACTS, entry[key])), entry[key]
            lat = manifest["latent"][str(l)]
            assert tuple(lat["shape"]) == model.latent_shape(l)
            assert lat["a_max_int8"] > 0 and lat["a_max_fp32"] > 0

    def test_params_bin_sizes(self, manifest):
        for l in model.SPLITS:
            entry = manifest["splits"][str(l)]
            n = sum(int(np.prod(t["shape"])) for t in entry["param_tensors"])
            size = os.path.getsize(os.path.join(ARTIFACTS, entry["params_bin"]))
            assert size == 4 * n

    def test_hlo_files_have_full_constants(self, manifest):
        for l in model.SPLITS:
            entry = manifest["splits"][str(l)]
            path = os.path.join(ARTIFACTS, entry[f"frozen_int8_b{aot.B_NEW}"])
            with open(path) as f:
                txt = f.read()
            assert "constant({...})" not in txt, f"{path} has elided constants"
            assert "HloModule" in txt

    def test_data_bins_match_shapes(self, manifest):
        for key, meta in manifest["data"].items():
            path = os.path.join(ARTIFACTS, meta["path"])
            expect = int(np.prod(meta["shape"])) * {"u8": 1, "i32": 4, "f32": 4}[meta["dtype"]]
            assert os.path.getsize(path) == expect, key
