"""Make `from compile import ...` importable regardless of the pytest
invocation directory (repo root CI runs `python -m pytest python/tests`)."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
