"""Core50-mini generator tests: determinism, session structure (the non-IID
property the protocol depends on), class separability, split hygiene."""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from compile import dataset as D


def test_render_shapes_and_range():
    f = D.render_session(0, 0, n_frames=8)
    assert f.shape == (8, D.HW, D.HW, 3)
    assert f.dtype == np.float32
    assert f.min() >= 0.0 and f.max() <= 1.0


def test_determinism():
    a = D.render_session(3, 2, n_frames=10)
    b = D.render_session(3, 2, n_frames=10)
    np.testing.assert_array_equal(a, b)


@settings(max_examples=10, deadline=None)
@given(cls=st.integers(0, 9), sess=st.integers(0, 7))
def test_sessions_are_video_like(cls, sess):
    """adjacent frames are closer than distant frames (temporal coherence)"""
    f = D.render_session(cls, sess, n_frames=30)
    d_adj = np.abs(f[1:] - f[:-1]).mean()
    d_far = np.abs(f[:10] - f[20:30]).mean()
    assert d_adj < d_far


def test_classes_are_more_different_than_sessions():
    """On average, class identity separates more than session nuisance.

    (Individual pairs can violate this — pose/lighting drift is strong by
    design, that's what makes the CL problem non-trivial — so the test
    averages over classes and sessions.)
    """
    means = {c: [D.render_session(c, s, 10).mean(0) for s in range(3)] for c in range(5)}
    within = [
        np.abs(means[c][0] - means[c][s]).mean() for c in range(5) for s in (1, 2)
    ]
    between = [
        np.abs(means[a][0] - means[b][0]).mean()
        for a in range(5) for b in range(a + 1, 5)
    ]
    assert np.mean(between) > np.mean(within)


def test_pretrain_universe_is_disjoint():
    cl = D.class_spec(0)
    pre = D.class_spec(D.PRETRAIN_SEED_OFFSET + 0)
    assert not np.allclose(cl["centers"], pre["centers"])


def test_build_cl_dataset_structure():
    data = D.build_cl_dataset()
    n_train = D.N_CL_CLASSES * D.TRAIN_SESSIONS * D.FRAMES_PER_SESSION
    n_test = D.N_CL_CLASSES * D.TEST_SESSIONS * D.FRAMES_PER_SESSION
    assert data["train_images"].shape == (n_train, D.HW, D.HW, 3)
    assert data["test_images"].shape == (n_test, D.HW, D.HW, 3)
    assert len(data["train_labels"]) == n_train
    # labels balanced
    counts = np.bincount(data["train_labels"], minlength=D.N_CL_CLASSES)
    assert (counts == D.TRAIN_SESSIONS * D.FRAMES_PER_SESSION).all()
    # bookkeeping consistent
    assert (data["train_class"] == data["train_labels"]).all()
    assert data["train_session"].max() == D.TRAIN_SESSIONS - 1
    assert data["train_frame"].max() == D.FRAMES_PER_SESSION - 1


def test_test_sessions_held_out():
    """test frames come from sessions the train split never saw"""
    data = D.build_cl_dataset()
    # regenerate a test-session frame and check it appears in test_images
    f = D.render_session(0, D.TRAIN_SESSIONS, D.FRAMES_PER_SESSION)
    np.testing.assert_allclose(data["test_images"][:60], f, atol=1e-6)
    # and train images of class 0 come only from sessions < TRAIN_SESSIONS
    m = data["train_class"] == 0
    assert set(np.unique(data["train_session"][m])) == set(range(D.TRAIN_SESSIONS))


def test_pretrain_dataset_shuffled_and_balanced():
    im, lab = D.build_pretrain_dataset(frames=10, sessions=2)
    assert len(im) == D.N_PRETRAIN_CLASSES * 2 * 10
    counts = np.bincount(lab, minlength=D.N_PRETRAIN_CLASSES)
    assert (counts == 20).all()
    # shuffled: first 20 labels are not all the same class
    assert len(np.unique(lab[:20])) > 1
