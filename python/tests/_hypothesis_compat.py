"""Import `given`, `settings`, `st` from here instead of `hypothesis`.

With hypothesis installed this is a pass-through. Without it (the
offline build image), only the property-based tests skip — the plain
fixed-case tests in the same module keep running, instead of the whole
module disappearing behind a module-level skip.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _AnyStrategy:
        """Stand-in so module-level `st.integers(...)` etc. still evaluate."""

        def __getattr__(self, _name):
            def strategy(*_args, **_kwargs):
                return None

            return strategy

    st = _AnyStrategy()
