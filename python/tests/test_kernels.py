"""L1 correctness: every Pallas kernel vs its pure-jnp oracle in ref.py.

Hypothesis sweeps shapes/strides/bit-widths; fixed cases pin the exact
tile geometries the AOT models use (MicroNet-32 layer shapes).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st

from compile.kernels import depthwise as dw
from compile.kernels import layers as ly
from compile.kernels import matmul as mk
from compile.kernels import quant as qk
from compile.kernels import ref

RTOL, ATOL = 2e-4, 2e-4


def rnd(*shape, seed=0):
    return np.random.RandomState(seed).randn(*shape).astype("float32")


# ---------------------------------------------------------------- matmul

MICRONET_MATMUL_SHAPES = [
    # (M, N, K) as they appear in the model: [B*H*W, Cout, Cin]
    (64 * 16, 256, 256),  # deepest PW at batch 64
    (64 * 4, 256, 256),
    (64, 10, 256),        # classifier head
    (8 * 256, 32, 16),    # stem-adjacent PW at batch 8
    (50 * 4, 256, 256),   # eval batch
]


@pytest.mark.parametrize("m,n,k", MICRONET_MATMUL_SHAPES)
def test_matmul_model_shapes(m, n, k):
    x, w = rnd(m, k, seed=1), rnd(k, n, seed=2)
    np.testing.assert_allclose(
        mk.matmul(x, w), ref.matmul(jnp.array(x), jnp.array(w)),
        rtol=RTOL, atol=ATOL * k ** 0.5,
    )


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96), n=st.integers(1, 96), k=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis(m, n, k, seed):
    x, w = rnd(m, k, seed=seed), rnd(k, n, seed=seed + 1)
    np.testing.assert_allclose(
        mk.matmul(x, w), ref.matmul(jnp.array(x), jnp.array(w)),
        rtol=1e-3, atol=1e-3 * k ** 0.5,
    )


@settings(max_examples=10, deadline=None)
@given(m=st.integers(2, 48), n=st.integers(2, 48), k=st.integers(2, 48))
def test_matmul_backwards_match_oracle(m, n, k):
    x, w, g = rnd(m, k, seed=3), rnd(k, n, seed=4), rnd(m, n, seed=5)
    np.testing.assert_allclose(
        mk.matmul_bw_err(g, w), ref.matmul_bw_err(jnp.array(g), jnp.array(w)),
        rtol=1e-3, atol=1e-3 * n ** 0.5,
    )
    np.testing.assert_allclose(
        mk.matmul_bw_grad(x, g), ref.matmul_bw_grad(jnp.array(x), jnp.array(g)),
        rtol=1e-3, atol=1e-3 * m ** 0.5,
    )


def test_matmul_explicit_blocks():
    x, w = rnd(32, 48, seed=6), rnd(48, 16, seed=7)
    out = mk.matmul(jnp.array(x), jnp.array(w), bm=8, bn=8, bk=16)
    np.testing.assert_allclose(out, ref.matmul(jnp.array(x), jnp.array(w)),
                               rtol=RTOL, atol=ATOL * 7)


def test_pick_blocks_fits_budget_and_divides():
    # strict TPU budget (what schedule_report uses)
    for m, n, k in [(1, 1, 1), (7, 13, 29), (1024, 1024, 1024), (64, 10, 256)]:
        bm, bn, bk = mk.pick_blocks(m, n, k, budget=mk.VMEM_BUDGET_BYTES)
        assert m % bm == 0 and n % bn == 0 and k % bk == 0
        assert 2 * 4 * (bm * bk + bk * bn + bm * bn) <= mk.VMEM_BUDGET_BYTES or (
            bm == bn == bk == 1
        )
    # relaxed CPU-lowering budget: small operands lower as a single block
    assert mk.pick_blocks(256, 256, 256) == (256, 256, 256)


def test_schedule_report_fields():
    rep = mk.schedule_report(512, 256, 512)
    assert rep["vmem_budget_ok"]
    assert rep["arithmetic_intensity_macs_per_byte"] > 1.0


# ------------------------------------------------------------- depthwise

DW_CASES = [  # MicroNet DW layer geometries
    (8, 16, 16, 16, 1), (8, 16, 16, 32, 2), (4, 8, 8, 64, 1),
    (4, 8, 8, 64, 2), (2, 4, 4, 128, 1), (2, 4, 4, 128, 2), (2, 2, 2, 256, 1),
]


@pytest.mark.parametrize("b,h,w,c,s", DW_CASES)
def test_depthwise_forward(b, h, w, c, s):
    x, k = rnd(b, h, w, c, seed=8), rnd(3, 3, c, seed=9)
    np.testing.assert_allclose(
        dw.depthwise_conv(x, k, s), ref.depthwise_conv(jnp.array(x), jnp.array(k), s),
        rtol=RTOL, atol=ATOL,
    )


@pytest.mark.parametrize("b,h,w,c,s", DW_CASES)
def test_depthwise_backwards(b, h, w, c, s):
    x, k = rnd(b, h, w, c, seed=10), rnd(3, 3, c, seed=11)
    g = np.asarray(ref.depthwise_conv(jnp.array(x), jnp.array(k), s))
    np.testing.assert_allclose(
        dw.depthwise_bw_err(g, k, s, h, w),
        ref.depthwise_bw_err(jnp.array(g), jnp.array(k), s, (h, w)),
        rtol=RTOL, atol=2e-3,
    )
    np.testing.assert_allclose(
        dw.depthwise_bw_grad(x, g, s),
        ref.depthwise_bw_grad(jnp.array(x), jnp.array(g), s),
        rtol=RTOL, atol=2e-3,
    )


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 4), h=st.integers(3, 12), w=st.integers(3, 12),
    c=st.integers(1, 16), s=st.sampled_from([1, 2]), seed=st.integers(0, 2**31 - 1),
)
def test_depthwise_hypothesis(b, h, w, c, s, seed):
    x, k = rnd(b, h, w, c, seed=seed), rnd(3, 3, c, seed=seed + 1)
    np.testing.assert_allclose(
        dw.depthwise_conv(x, k, s), ref.depthwise_conv(jnp.array(x), jnp.array(k), s),
        rtol=1e-3, atol=1e-3,
    )


def test_depthwise_gradcheck_vs_autodiff():
    """dw bw kernels must equal jax autodiff of the dw fw *kernel* itself."""
    import jax

    x, k = rnd(2, 6, 6, 4, seed=12), rnd(3, 3, 4, seed=13)
    for s in (1, 2):
        y, vjp = jax.vjp(lambda a, b: ref.depthwise_conv(a, b, s), jnp.array(x), jnp.array(k))
        g = rnd(*y.shape, seed=14)
        dx, dk = vjp(jnp.array(g))
        np.testing.assert_allclose(dw.depthwise_bw_err(g, k, s, 6, 6), dx, rtol=RTOL, atol=2e-3)
        np.testing.assert_allclose(dw.depthwise_bw_grad(x, g, s), dk, rtol=RTOL, atol=2e-3)


# ------------------------------------------------------- im2col / conv3x3


@pytest.mark.parametrize("s", [1, 2])
def test_im2col_matches_ref(s):
    x = rnd(2, 8, 8, 6, seed=15)
    np.testing.assert_allclose(ly.im2col3x3(x, s), ref.im2col3x3(jnp.array(x), s),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("s", [1, 2])
def test_im2col_times_weights_equals_conv(s):
    x, w = rnd(2, 8, 8, 6, seed=16), rnd(3, 3, 6, 10, seed=17)
    cols = np.asarray(ref.im2col3x3(jnp.array(x), s))
    flat = cols @ w.reshape(9 * 6, 10)
    conv = np.asarray(ref.conv3x3(jnp.array(x), jnp.array(w), s)).reshape(flat.shape)
    np.testing.assert_allclose(flat, conv, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3), hw=st.integers(3, 10), cin=st.integers(1, 8),
    cout=st.integers(1, 12), s=st.sampled_from([1, 2]),
)
def test_conv3x3_hypothesis(b, hw, cin, cout, s):
    x, w = rnd(b, hw, hw, cin, seed=18), rnd(3, 3, cin, cout, seed=19)
    np.testing.assert_allclose(
        ly.conv3x3(x, w, s), ref.conv3x3(jnp.array(x), jnp.array(w), s),
        rtol=1e-3, atol=1e-3,
    )


def test_pointwise_conv_matches_ref():
    x, w = rnd(4, 8, 8, 16, seed=20), rnd(16, 32, seed=21)
    np.testing.assert_allclose(
        ly.pointwise_conv(x, w), ref.pointwise_conv(jnp.array(x), jnp.array(w)),
        rtol=RTOL, atol=ATOL * 4,
    )


def test_dense_matches_ref():
    x, w, b = rnd(8, 64, seed=22), rnd(64, 10, seed=23), rnd(10, seed=24)
    np.testing.assert_allclose(
        ly.dense(x, w, b), ref.dense(jnp.array(x), jnp.array(w), jnp.array(b)),
        rtol=RTOL, atol=ATOL * 8,
    )


# ----------------------------------------------------------------- quant


@pytest.mark.parametrize("bits", [8, 7, 6])
def test_quantize_matches_ref(bits):
    a = np.abs(rnd(4, 5, 5, 8, seed=25)) * 2.0
    np.testing.assert_allclose(qk.quantize_act(a, 3.0, bits),
                               ref.quantize_act(jnp.array(a), 3.0, bits))
    np.testing.assert_allclose(qk.dequantize_act(a, 3.0, bits),
                               ref.dequantize_act(jnp.array(a), 3.0, bits))
    np.testing.assert_allclose(qk.fake_quant_act(a, 3.0, bits),
                               ref.fake_quant_act(jnp.array(a), 3.0, bits))


@settings(max_examples=30, deadline=None)
@given(
    bits=st.sampled_from([6, 7, 8]), a_max=st.floats(0.5, 16.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_properties(bits, a_max, seed):
    """Round-trip error bounded by one step; values land on the grid."""
    a = np.abs(rnd(3, 4, 4, 4, seed=seed))
    q = np.asarray(qk.quantize_act(a, a_max, bits))
    levels = 2**bits - 1
    assert q.min() >= 0 and q.max() <= levels
    assert np.array_equal(q, np.round(q))  # integer grid
    deq = np.asarray(qk.dequantize_act(q, a_max, bits))
    scale = a_max / levels
    inside = a <= a_max  # clipped values may err more
    assert np.all(np.abs(deq - a)[inside] <= scale * (1 + 1e-5))


@pytest.mark.parametrize("bits", [8, 7, 6])
def test_quant_monotone_and_idempotent(bits):
    a = np.linspace(0, 4, 101, dtype="float32").reshape(1, 101)
    q = np.asarray(qk.quantize_act(a, 3.0, bits))
    assert np.all(np.diff(q) >= 0)
    # floor-quantization is idempotent only up to one grid step (fp rounding
    # can push q*S/S just below the integer), matching the paper's eq. (2)
    fq = np.asarray(qk.fake_quant_act(a, 3.0, bits))
    fq2 = np.asarray(qk.fake_quant_act(fq, 3.0, bits))
    scale = 3.0 / (2**bits - 1)
    assert np.abs(fq - fq2).max() <= scale * (1 + 1e-5)


def test_weight_quant_ref_properties():
    w = rnd(16, 32, seed=26)
    for bits in (8, 7, 6):
        q, s = ref.quantize_weight(jnp.array(w), bits)
        deq = np.asarray(q) * float(s)
        assert np.abs(deq - w).max() <= float(s) * (1 + 1e-5)
        assert len(np.unique(np.asarray(q))) <= 2**bits
