"""L2 model tests: shapes, split semantics, kernel-vs-ref forward parity,
training-step behaviour (loss decreases, grads flow only into the adaptive
stage)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0))


def rnd_images(b, seed=0):
    return jnp.asarray(
        np.random.RandomState(seed).rand(b, model.INPUT_HW, model.INPUT_HW, 3), jnp.float32
    )


def test_arch_invariants():
    assert model.ARCH[0][0] == "conv3x3"
    kinds = [k for k, *_ in model.ARCH]
    # alternating dw/pw after the stem
    assert kinds[1::2] == ["dw"] * 7
    assert kinds[2::2] == ["pw"] * 7
    assert model.L_LINEAR == 15
    # all splits are valid indices and the linear split is included
    assert all(0 < l <= model.L_LINEAR for l in model.SPLITS)
    assert model.L_LINEAR in model.SPLITS


def test_param_count(params):
    n = model.num_params(params)
    assert 130_000 < n < 150_000, n


@pytest.mark.parametrize("l", model.SPLITS)
def test_latent_shapes(l, params):
    x = rnd_images(2)
    lat = model.frozen_forward(params, x, l, use_kernels=False)
    assert lat.shape == (2,) + model.latent_shape(l)
    assert model.latent_size(l) == int(np.prod(model.latent_shape(l)))


def test_full_forward_shape(params):
    logits = model.full_forward(params, rnd_images(3))
    assert logits.shape == (3, model.NUM_CLASSES)


def test_frozen_plus_adaptive_equals_full(params):
    x = rnd_images(2, seed=1)
    full = model.full_forward(params, x, use_kernels=False)
    for l in model.SPLITS:
        lat = model.frozen_forward(params, x, l, use_kernels=False)
        ap = params[l:] if l < model.L_LINEAR else params[model.L_LINEAR:]
        logits = model.adaptive_forward(ap, lat, l, use_kernels=False)
        np.testing.assert_allclose(logits, full, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("l", [13, 15])
def test_kernel_path_matches_ref_path(l, params):
    x = rnd_images(2, seed=2)
    lat_k = model.frozen_forward(params, x, l, use_kernels=True)
    lat_r = model.frozen_forward(params, x, l, use_kernels=False)
    np.testing.assert_allclose(lat_k, lat_r, rtol=5e-4, atol=5e-4)
    ap = params[l:] if l < model.L_LINEAR else params[model.L_LINEAR:]
    lg_k = model.adaptive_forward(ap, lat_r, l, use_kernels=True)
    lg_r = model.adaptive_forward(ap, lat_r, l, use_kernels=False)
    np.testing.assert_allclose(lg_k, lg_r, rtol=5e-4, atol=5e-4)


def test_train_step_decreases_loss(params):
    l = 13
    lat_shape = model.latent_shape(l)
    B = 16
    rng = np.random.RandomState(3)
    lat = jnp.asarray(np.abs(rng.randn(B, *lat_shape)), jnp.float32)
    labels = jnp.asarray(rng.randint(0, 10, B), jnp.int32)
    ap = params[l:]
    losses = []
    for _ in range(5):
        ap, loss, _cor = model.train_step(ap, lat, labels, 0.1, l, use_kernels=False)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_train_step_kernels_match_ref(params):
    l = 13
    B = 8
    rng = np.random.RandomState(4)
    lat = jnp.asarray(np.abs(rng.randn(B, *model.latent_shape(l))), jnp.float32)
    labels = jnp.asarray(rng.randint(0, 10, B), jnp.int32)
    ap = params[l:]
    new_k, loss_k, cor_k = model.train_step(ap, lat, labels, 0.05, l, True)
    new_r, loss_r, cor_r = model.train_step(ap, lat, labels, 0.05, l, False)
    assert int(cor_k) == int(cor_r)
    np.testing.assert_allclose(float(loss_k), float(loss_r), rtol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(new_k), jax.tree_util.tree_leaves(new_r)):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=1e-4)


def test_cross_entropy_known_value():
    logits = jnp.asarray([[10.0, 0.0], [0.0, 10.0]])
    labels = jnp.asarray([0, 1], jnp.int32)
    assert float(model.cross_entropy(logits, labels)) < 1e-3
    wrong = jnp.asarray([1, 0], jnp.int32)
    assert float(model.cross_entropy(logits, wrong)) > 5.0


def test_spatial_at():
    assert model.spatial_at(0) == 32
    assert model.spatial_at(1) == 16
    assert model.spatial_at(9) == 4
    assert model.spatial_at(13) == 2
