"""Build-time pretraining + initial fine-tune (paper §V-A setup, compressed).

The paper starts from a MobileNet-V1 pre-trained on ImageNet-1k, fine-tunes
it on the 3000 initially-available Core50 images (10 classes), then freezes
the frozen stage. We mirror that at build time:

 1. pretrain MicroNet-32 on the disjoint ImageNet-proxy classes (Adam),
 2. swap the head for NUM_CLASSES and fine-tune on the *initial* CL classes'
    early sessions (SGD, low LR),
 3. hand the trained parameters to PTQ calibration + AOT lowering.

This module is strictly compile-path Python (invoked by ``make artifacts``);
nothing here ships to the rust runtime except the resulting tensors.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import model

INITIAL_CLASSES = (0, 1, 2, 3)          # available before deployment
INITIAL_SESSIONS = (0, 1)               # sessions used for the initial fine-tune


def _adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


@functools.partial(jax.jit, static_argnames=("lr", "wd"))
def _adam_step(params, opt, images, labels, lr: float = 1e-3, wd: float = 1e-4):
    def loss_fn(p):
        logits = model.full_forward(p, images, use_kernels=False)
        return model.cross_entropy(logits, labels)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    t = opt["t"] + 1
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    tf = t.astype(jnp.float32)
    corr = jnp.sqrt(1 - b2**tf) / (1 - b1**tf)

    def upd(p, m_, v_):
        return p * (1 - lr * wd) - lr * corr * m_ / (jnp.sqrt(v_) + eps)

    params = jax.tree_util.tree_map(upd, params, m, v)
    return params, {"m": m, "v": v, "t": t}, loss


@functools.partial(jax.jit, static_argnames=("lr",))
def _sgd_step(params, images, labels, lr: float):
    def loss_fn(p):
        logits = model.full_forward(p, images, use_kernels=False)
        return model.cross_entropy(logits, labels)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return params, loss


@jax.jit
def _logits(params, images):
    return model.full_forward(params, images, use_kernels=False)


def evaluate(params, images: np.ndarray, labels: np.ndarray, batch: int = 200) -> float:
    correct = 0
    for s in range(0, len(images), batch):
        lg = _logits(params, jnp.asarray(images[s:s + batch]))
        correct += int(jnp.sum(jnp.argmax(lg, axis=1) == jnp.asarray(labels[s:s + batch])))
    return correct / len(images)


def _epochs(rng: np.random.RandomState, n: int, batch: int, epochs: int):
    for _ in range(epochs):
        perm = rng.permutation(n)
        for s in range(0, n - batch + 1, batch):
            yield perm[s:s + batch]


def pretrain_backbone(images, labels, n_classes: int, seed: int = 0,
                      epochs: int = 12, batch: int = 64, verbose=print):
    """Stage 1: train the whole net on the proxy classes."""
    params = init = model.init_params(jax.random.PRNGKey(seed), num_classes=n_classes)
    opt = _adam_init(params)
    rng = np.random.RandomState(seed + 1)
    step = 0
    for idx in _epochs(rng, len(images), batch, epochs):
        params, opt, loss = _adam_step(
            params, opt, jnp.asarray(images[idx]), jnp.asarray(labels[idx])
        )
        step += 1
        if step % 100 == 0:
            verbose(f"  pretrain step {step}: loss {float(loss):.4f}")
    return params


def swap_head(params, rng_key, num_classes: int = model.NUM_CLASSES):
    """Replace the classifier head for the CL problem (fresh init)."""
    params = list(params)
    w = jax.random.normal(rng_key, (model.FEAT_DIM, num_classes)) / model.FEAT_DIM**0.5
    params[-1] = {"w": w.astype(jnp.float32), "b": jnp.zeros((num_classes,), jnp.float32)}
    return params


def finetune_initial(params, data: dict, seed: int = 0, epochs: int = 10,
                     batch: int = 32, lr: float = 0.02, verbose=print):
    """Stage 2: fine-tune on the initial classes' initial sessions only."""
    mask = np.isin(data["train_class"], INITIAL_CLASSES) & np.isin(
        data["train_session"], INITIAL_SESSIONS
    )
    images, labels = data["train_images"][mask], data["train_labels"][mask]
    rng = np.random.RandomState(seed + 2)
    step = 0
    for idx in _epochs(rng, len(images), batch, epochs):
        params, loss = _sgd_step(params, jnp.asarray(images[idx]), jnp.asarray(labels[idx]), lr)
        step += 1
        if step % 50 == 0:
            verbose(f"  finetune step {step}: loss {float(loss):.4f}")
    return params, images, labels
