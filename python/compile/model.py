"""L2: MicroNet-32 — the trainable MobileNet-V1-style model (JAX, calls L1 kernels).

The paper uses MobileNet-V1 (width 1.0, 128x128) on Core50. MicroNet-32 is
its CPU-tractable sibling for the *learned* experiments (DESIGN.md §1):
same layer vocabulary (3x3 stem conv, DW/PW blocks, avg-pool, linear), same
frozen/adaptive split structure, ~139k params at 32x32x3.

Layer indexing mirrors the paper's: the latent-replay layer ``l`` is the
*first layer of the adaptive stage*; its input feature map is the latent
that gets quantized and stored. ``l = L_LINEAR`` (= 15) means "retrain only
the classifier", with the latent taken after global average pooling —
exactly the paper's l=27 row of Table III.

The adaptive-stage forward/backward runs through ``jax.custom_vjp`` wrappers
whose forward *and* backward bodies are the L1 Pallas kernels — i.e. the
AOT-lowered training step literally contains the paper's FW / BW-ERR /
BW-GRAD tiled kernels.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import depthwise as dwk
from .kernels import layers as lyk
from .kernels import matmul as mmk
from .kernels import quant as qk
from .kernels import ref

# ---------------------------------------------------------------- topology

# (kind, cin, cout, stride); layer index = position in this list.
ARCH: list[tuple[str, int, int, int]] = [
    ("conv3x3", 3, 16, 2),    # 0   -> 16x16x16
    ("dw", 16, 16, 1),        # 1
    ("pw", 16, 32, 1),        # 2   -> 16x16x32
    ("dw", 32, 32, 2),        # 3
    ("pw", 32, 64, 1),        # 4   -> 8x8x64
    ("dw", 64, 64, 1),        # 5
    ("pw", 64, 64, 1),        # 6   -> 8x8x64
    ("dw", 64, 64, 2),        # 7
    ("pw", 64, 128, 1),       # 8   -> 4x4x128
    ("dw", 128, 128, 1),      # 9
    ("pw", 128, 128, 1),      # 10  -> 4x4x128
    ("dw", 128, 128, 2),      # 11
    ("pw", 128, 256, 1),      # 12  -> 2x2x256
    ("dw", 256, 256, 1),      # 13
    ("pw", 256, 256, 1),      # 14  -> 2x2x256
]
L_LINEAR = len(ARCH)          # 15: avg-pool + linear head
NUM_CLASSES = 10
INPUT_HW = 32
FEAT_DIM = ARCH[-1][2]

# Latent-replay split points used throughout the repo (DESIGN.md §3 S2).
SPLITS = (9, 11, 13, 15)


def spatial_at(layer: int) -> int:
    """Input spatial resolution (H = W) of ``layer``."""
    hw = INPUT_HW
    for kind, _, _, stride in ARCH[:layer]:
        hw = -(-hw // stride)
    return hw


def latent_shape(l: int) -> tuple[int, ...]:
    """Shape (per sample) of the latent stored at split ``l``."""
    if l >= L_LINEAR:
        return (FEAT_DIM,)
    hw = spatial_at(l)
    return (hw, hw, ARCH[l][1])


def latent_size(l: int) -> int:
    n = 1
    for d in latent_shape(l):
        n *= d
    return n


# ------------------------------------------------------------------ params


def init_params(rng: jax.Array, num_classes: int = NUM_CLASSES) -> list[dict[str, Any]]:
    """He-initialized parameter list; every conv carries a trainable affine
    (folded BatchNorm: the paper freezes BN statistics after fine-tuning,
    leaving scale/shift as the trainable normalization parameters)."""
    params = []
    keys = jax.random.split(rng, len(ARCH) + 1)
    for i, (kind, cin, cout, _s) in enumerate(ARCH):
        k = keys[i]
        if kind == "conv3x3":
            fan_in = 9 * cin
            w = jax.random.normal(k, (3, 3, cin, cout)) * (2.0 / fan_in) ** 0.5
        elif kind == "dw":
            w = jax.random.normal(k, (3, 3, cin)) * (2.0 / 9.0) ** 0.5
        else:  # pw
            w = jax.random.normal(k, (cin, cout)) * (2.0 / cin) ** 0.5
        params.append({
            "w": w.astype(jnp.float32),
            "g": jnp.ones((cout,), jnp.float32),
            "b": jnp.zeros((cout,), jnp.float32),
        })
    wl = jax.random.normal(keys[-1], (FEAT_DIM, num_classes)) * (1.0 / FEAT_DIM) ** 0.5
    params.append({"w": wl.astype(jnp.float32), "b": jnp.zeros((num_classes,), jnp.float32)})
    return params


def num_params(params) -> int:
    return sum(int(v.size) for p in params for v in p.values())


# ------------------------------------------- custom-vjp kernel layer wrappers
#
# Forward = L1 FW kernel; backward = L1 BW-ERR + BW-GRAD kernels.


@jax.custom_vjp
def pw_op(x, w):
    return lyk.pointwise_conv(x, w)


def _pw_fwd(x, w):
    return pw_op(x, w), (x, w)


def _pw_bwd(res, g):
    x, w = res
    b, h, wd, cin = x.shape
    gm = g.reshape(-1, g.shape[-1])
    dx = mmk.matmul_bw_err(gm, w).reshape(x.shape)
    dw_ = mmk.matmul_bw_grad(x.reshape(-1, cin), gm)
    return dx, dw_


pw_op.defvjp(_pw_fwd, _pw_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def dw_op(x, k, stride):
    return dwk.depthwise_conv(x, k, stride)


def _dw_fwd(x, k, stride):
    return dw_op(x, k, stride), (x, k)


def _dw_bwd(stride, res, g):
    x, k = res
    _b, h, w, _c = x.shape
    dx = dwk.depthwise_bw_err(g, k, stride, h, w)
    dk = dwk.depthwise_bw_grad(x, g, stride)
    return dx, dk


dw_op.defvjp(_dw_fwd, _dw_bwd)


@jax.custom_vjp
def dense_op(x, w):
    return mmk.matmul(x, w)


def _dense_fwd(x, w):
    return dense_op(x, w), (x, w)


def _dense_bwd(res, g):
    x, w = res
    return mmk.matmul_bw_err(g, w), mmk.matmul_bw_grad(x, g)


dense_op.defvjp(_dense_fwd, _dense_bwd)


# ----------------------------------------------------------------- forward


def _conv_layer(kind: str, p: dict, x: jax.Array, stride: int, use_kernels: bool) -> jax.Array:
    if kind == "conv3x3":
        y = lyk.conv3x3(x, p["w"], stride) if use_kernels else ref.conv3x3(x, p["w"], stride)
    elif kind == "dw":
        y = dw_op(x, p["w"], stride) if use_kernels else ref.depthwise_conv(x, p["w"], stride)
    else:
        y = pw_op(x, p["w"]) if use_kernels else ref.pointwise_conv(x, p["w"])
    y = y * p["g"] + p["b"]
    return jax.nn.relu(y)


def _fq_weights(p: dict, kind: str, bits: int) -> dict:
    """Fold the affine scale into the conv weights and fake-quantize (PTQ)."""
    if kind == "dw":
        w_fold = p["w"] * p["g"]  # [3,3,C] * [C]
    elif kind == "pw":
        w_fold = p["w"] * p["g"][None, :]
    else:  # conv3x3
        w_fold = p["w"] * p["g"][None, None, None, :]
    return {"w": ref.fake_quant_weight(w_fold, bits), "g": jnp.ones_like(p["g"]), "b": p["b"]}


def frozen_forward(
    params,
    x: jax.Array,
    l: int,
    quant: dict | None = None,
    use_kernels: bool = True,
) -> jax.Array:
    """Run layers ``[0, l)`` and return the latent at split ``l``.

    ``quant``: None for the FP32 frozen stage, else a dict from
    :func:`compile.quantize.calibrate` — INT-Q weights (folded affine) and
    UINT-Q activations after every ReLU, with the latent quantized at
    ``S_a,l`` (paper §III-C). The returned latent is on the dequantized grid
    (``q * S``); the rust side re-derives the integer codes exactly.
    """
    fq = qk.fake_quant_act if use_kernels else ref.fake_quant_act
    if quant is not None:
        x = fq(x, float(quant["input_a_max"]), quant["a_bits"])
    for i, (kind, cin, cout, stride) in enumerate(ARCH[:min(l, L_LINEAR)]):
        p = params[i]
        if quant is not None:
            p = _fq_weights(p, kind, quant["w_bits"])
            y = _conv_layer(kind, p, x, stride, use_kernels)
            x = fq(y, float(quant["a_max"][i]), quant["a_bits"])
        else:
            x = _conv_layer(kind, params[i], x, stride, use_kernels)
    if l >= L_LINEAR:
        x = jnp.mean(x, axis=(1, 2))  # latent = pooled features (paper l=27)
    return x


def adaptive_forward(adaptive_params, latent: jax.Array, l: int, use_kernels: bool = True) -> jax.Array:
    """Run layers ``[l, L)`` + head over a latent batch -> logits.

    ``adaptive_params``: ``params[l:]`` (conv layers from l, then the head).
    """
    x = latent
    for off, (kind, cin, cout, stride) in enumerate(ARCH[l:] if l < L_LINEAR else []):
        p = adaptive_params[off]
        if kind == "dw":
            y = dw_op(x, p["w"], stride) if use_kernels else ref.depthwise_conv(x, p["w"], stride)
        elif kind == "pw":
            y = pw_op(x, p["w"]) if use_kernels else ref.pointwise_conv(x, p["w"])
        else:  # pragma: no cover — the stem is never adaptive in our splits
            y = lyk.conv3x3(x, p["w"], stride) if use_kernels else ref.conv3x3(x, p["w"], stride)
        x = jax.nn.relu(y * p["g"] + p["b"])
    if l < L_LINEAR:
        x = jnp.mean(x, axis=(1, 2))
    head = adaptive_params[-1]
    if use_kernels:
        return dense_op(x, head["w"]) + head["b"]
    return ref.dense(x, head["w"], head["b"])


def full_forward(params, x, quant=None, use_kernels: bool = False) -> jax.Array:
    """Whole-network logits (used at build time for pretraining/eval)."""
    latent = frozen_forward(params, x, L_LINEAR, quant, use_kernels)
    return adaptive_forward(params[L_LINEAR:], latent, L_LINEAR, use_kernels)


# ------------------------------------------------------------ loss / train


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def adaptive_loss(adaptive_params, latents, labels, l, use_kernels=True):
    logits = adaptive_forward(adaptive_params, latents, l, use_kernels)
    loss = cross_entropy(logits, labels)
    correct = jnp.sum((jnp.argmax(logits, axis=1) == labels).astype(jnp.int32))
    return loss, correct


def train_step(adaptive_params, latents, labels, lr, l: int, use_kernels: bool = True):
    """One SGD step over the adaptive stage (the paper's on-device learner).

    Returns ``(new_params, loss, n_correct)``. This is the function that
    gets AOT-lowered to ``adaptive_train_l{l}.hlo.txt`` — forward + BW-ERR +
    BW-GRAD through the L1 kernels, then the SGD update, in one HLO module.
    """
    (loss, correct), grads = jax.value_and_grad(
        lambda p: adaptive_loss(p, latents, labels, l, use_kernels), has_aux=True
    )(adaptive_params)
    new_params = jax.tree_util.tree_map(lambda p, g: p - lr * g, adaptive_params, grads)
    return new_params, loss, correct
