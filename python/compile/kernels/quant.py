"""L1 Pallas kernels: affine quantize / dequantize (paper eq. 1-2).

These are the QLR-CL-specific kernels: the frozen stage's UINT-Q activation
quantizer (applied after every ReLU in the INT-8 graph and at the latent
replay boundary) and the dequantizer that feeds stored replays back into the
FP32 adaptive stage. Elementwise, blocked over leading rows so arbitrarily
large activation tensors stream through a bounded VMEM footprint.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import matmul as mk


def _rows_block(rows: int, cols: int) -> int:
    """Largest divisor of ``rows`` with a (in+out) block <= half the budget
    (lowering budget — see matmul.LOWERING_BUDGET_BYTES, §Perf L1/L2)."""
    rb = rows
    while rb > 1 and 2 * 4 * rb * cols * 2 > mk.LOWERING_BUDGET_BYTES:
        nxt = rb - 1
        while rows % nxt != 0:
            nxt -= 1
        rb = nxt
    return rb


def _quant_kernel(x_ref, o_ref, *, scale: float, levels: float):
    q = jnp.floor(x_ref[...] * (1.0 / scale))
    o_ref[...] = jnp.clip(q, 0.0, levels)


def _dequant_kernel(q_ref, o_ref, *, scale: float):
    o_ref[...] = q_ref[...] * scale


def _elementwise(kernel, x: jax.Array) -> jax.Array:
    flat = x.reshape(-1, x.shape[-1])
    rows, cols = flat.shape
    rb = _rows_block(rows, cols)
    out = pl.pallas_call(
        kernel,
        grid=(rows // rb,),
        in_specs=[pl.BlockSpec((rb, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(flat)
    return out.reshape(x.shape)


@functools.partial(jax.jit, static_argnames=("a_max", "bits"))
def quantize_act(x: jax.Array, a_max: float, bits: int) -> jax.Array:
    """UINT-Q quantization: ``clip(floor(x / S_a), 0, 2^Q-1)``, S_a = a_max/(2^Q-1).

    Returns integer grid values as f32 (the rust side packs them to Q bits).
    """
    levels = float(2**bits - 1)
    scale = float(a_max) / levels
    return _elementwise(
        functools.partial(_quant_kernel, scale=scale, levels=levels), x
    )


@functools.partial(jax.jit, static_argnames=("a_max", "bits"))
def dequantize_act(q: jax.Array, a_max: float, bits: int) -> jax.Array:
    """``q * S_a`` — feeds stored replays into the FP32 adaptive stage."""
    scale = float(a_max) / float(2**bits - 1)
    return _elementwise(functools.partial(_dequant_kernel, scale=scale), q)


@functools.partial(jax.jit, static_argnames=("a_max", "bits"))
def fake_quant_act(x: jax.Array, a_max: float, bits: int) -> jax.Array:
    """quantize -> dequantize round trip used inside the INT-Q frozen graph."""
    return dequantize_act(quantize_act(x, a_max, bits), a_max, bits)
