"""L1: Pallas kernels for the paper's compute hot-spots (all interpret=True).

- :mod:`.matmul`    — tiled FP32 matmul + BW-ERR / BW-GRAD variants
- :mod:`.depthwise` — 3x3 depthwise conv fwd / bw-err / bw-grad
- :mod:`.layers`    — im2col, pointwise conv, dense, 3x3 conv
- :mod:`.quant`     — UINT-Q affine quantize / dequantize (QLR-CL eq. 1-2)
- :mod:`.ref`       — pure-jnp oracles for all of the above
"""

from . import depthwise, layers, matmul, quant, ref  # noqa: F401
