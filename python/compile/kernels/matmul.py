"""L1 Pallas kernel: tiled FP32 matmul — the workhorse of every CL primitive.

The paper reshapes pointwise conv, depthwise conv (after im2col) and linear
layers — forward, backward-error and backward-gradient — into matrix
multiplications executed from tiles resident in the 128 kB L1 TCDM
(Fig. 3 / Fig. 4). The TPU-style counterpart implemented here tiles the
operands into VMEM blocks via ``BlockSpec`` and accumulates over the K grid
dimension, which Pallas double-buffers across grid steps exactly like the
paper's L2->L1 DMA double-buffering scheme.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that inlines into the
AOT module (see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget mirroring the paper's L1 rule: one (x, w, acc) block set per
# grid step; Pallas keeps two in flight (double buffering), so we size
# blocks such that 2 * bytes(blocks) <= VMEM_BUDGET (128 kB L1-equivalent).
VMEM_BUDGET_BYTES = 128 * 1024

# Lowering budget (§Perf L1/L2): on a real TPU the 128 kB-equivalent budget
# above is the constraint; under interpret=True every grid step lowers to
# an XLA while-loop iteration with dynamic-slice traffic, which dominated
# the AOT modules' CPU runtime (measured 10x+ overhead — EXPERIMENTS.md
# §Perf). For the CPU artifacts we therefore lower with a relaxed budget
# (fewer, larger blocks — usually grid=1); `schedule_report` keeps using
# the strict TPU budget, so the structural analysis is unchanged.
LOWERING_BUDGET_BYTES = 8 * 1024 * 1024

# Default block shape, MXU-aligned (128x128 systolic array); shrunk to the
# actual dims for the small operands of the adaptive stage.
DEF_BM, DEF_BN, DEF_BK = 128, 128, 128


def _block(dim: int, pref: int) -> int:
    """Largest divisor of ``dim`` that is <= pref (block must tile exactly)."""
    b = min(dim, pref)
    while dim % b != 0:
        b -= 1
    return b


def pick_blocks(
    m: int, n: int, k: int, budget: int = LOWERING_BUDGET_BYTES
) -> tuple[int, int, int]:
    """Choose (bm, bn, bk) fitting the double-buffered VMEM budget."""
    if 2 * 4 * (m * k + k * n + m * n) <= budget:
        return m, n, k  # single block, grid = (1,1,1)
    bm, bn, bk = _block(m, DEF_BM), _block(n, DEF_BN), _block(k, DEF_BK)
    while 2 * 4 * (bm * bk + bk * bn + bm * bn) > budget:
        # Shrink the largest dimension first (keeps blocks square-ish, which
        # maximizes arithmetic intensity — MACs per byte moved).
        if bk >= bm and bk >= bn and bk > 1:
            bk = _block(k, bk - 1)
        elif bm >= bn and bm > 1:
            bm = _block(m, bm - 1)
        elif bn > 1:
            bn = _block(n, bn - 1)
        else:
            break
    return bm, bn, bk


def _matmul_kernel(x_ref, w_ref, o_ref):
    """Grid = (M/bm, N/bn, K/bk); the output block is revisited along the K
    axis (its index map ignores ``kk``), so it stays VMEM-resident and acts
    as the accumulator — the Pallas analogue of the paper's L1-resident
    output tile accumulated across K-slices."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x: jax.Array, w: jax.Array, bm: int = 0, bn: int = 0, bk: int = 0) -> jax.Array:
    """Tiled Pallas matmul ``[M,K] @ [K,N] -> [M,N]`` (FP32).

    Block sizes default to :func:`pick_blocks`; pass explicit ``bm/bn/bk``
    (must divide the dims) to pin a schedule, e.g. from the report tool.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"matmul inner dims mismatch: {k} vs {k2}"
    if not (bm and bn and bk):
        bm, bn, bk = pick_blocks(m, n, k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def matmul_bw_err(g: jax.Array, w: jax.Array) -> jax.Array:
    """BW-ERR step as a tiled kernel: ``dL/dx = g @ w^T``.

    The transpose is materialized outside the kernel (the paper's DMA can do
    the 2D-strided read; XLA fuses the transpose into the operand load).
    """
    return matmul(g, w.T)


def matmul_bw_grad(x: jax.Array, g: jax.Array) -> jax.Array:
    """BW-GRAD step as a tiled kernel: ``dL/dw = x^T @ g``."""
    return matmul(x.T, g)


def schedule_report(m: int, n: int, k: int) -> dict:
    """Structural perf estimate for a matmul schedule (no wall-clock).

    Reported per DESIGN.md §9: VMEM bytes per double-buffered block set,
    arithmetic intensity, and MXU-shape alignment of the chosen blocks.
    Always uses the strict TPU budget (VMEM_BUDGET_BYTES), independent of
    the relaxed CPU lowering budget.
    """
    bm, bn, bk = pick_blocks(m, n, k, budget=VMEM_BUDGET_BYTES)
    vmem = 2 * 4 * (bm * bk + bk * bn + bm * bn)
    macs = m * n * k
    bytes_moved = 4 * ((m * k) * (n // bn) + (k * n) * (m // bm) + m * n)
    return {
        "blocks": (bm, bn, bk),
        "grid": (m // bm, n // bn, k // bk),
        "vmem_bytes_double_buffered": vmem,
        "vmem_budget_ok": vmem <= VMEM_BUDGET_BYTES,
        "arithmetic_intensity_macs_per_byte": macs / bytes_moved,
        "mxu_aligned": (bm % 8 == 0 and bn % 128 == 0) or (bm >= 128 and bn >= 128),
    }
