"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness ground truth).

Every kernel in this package has a twin here implemented with plain
``jax.numpy`` / ``jax.lax`` ops only. ``python/tests/test_kernels.py`` sweeps
shapes (hypothesis) and asserts ``allclose`` between kernel and oracle.
Layout convention everywhere: NHWC activations, HWC depthwise filters,
``(Cin, Cout)`` pointwise / dense weights.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain FP32 matmul: ``[M, K] @ [K, N] -> [M, N]``."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def dense(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Fully-connected layer: ``x @ w + b``."""
    return matmul(x, w) + b


def pointwise_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """1x1 convolution. ``x: [B, H, W, Cin]``, ``w: [Cin, Cout]``."""
    b, h, wd, cin = x.shape
    y = matmul(x.reshape(b * h * wd, cin), w)
    return y.reshape(b, h, wd, -1)


def depthwise_conv(x: jax.Array, k: jax.Array, stride: int = 1) -> jax.Array:
    """3x3 depthwise convolution, pad=1 (PyTorch-style, as the paper). ``x: [B,H,W,C]``, ``k: [3,3,C]``."""
    dn = jax.lax.conv_dimension_numbers(x.shape, (3, 3, 1, k.shape[-1]), ("NHWC", "HWIO", "NHWC"))
    kern = k[:, :, None, :]  # HWC -> HW1C (feature_group_count = C)
    return jax.lax.conv_general_dilated(
        x, kern, window_strides=(stride, stride), padding=((1, 1), (1, 1)),
        dimension_numbers=dn, feature_group_count=k.shape[-1],
    )


def conv3x3(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """Regular 3x3 convolution, pad=1 (PyTorch-style). ``w: [3, 3, Cin, Cout]``."""
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape, ("NHWC", "HWIO", "NHWC"))
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=((1, 1), (1, 1)), dimension_numbers=dn
    )


def im2col3x3(x: jax.Array, stride: int = 1) -> jax.Array:
    """im2col for a 3x3 pad=1 conv: ``[B,H,W,C] -> [B*Ho*Wo, 9*C]``.

    Column order is (ky, kx, c), matching ``w.reshape(9*Cin, Cout)`` of an
    HWIO filter — i.e. ``im2col3x3(x) @ w.reshape(9*cin, cout)`` equals
    ``conv3x3(x, w)`` flattened.
    """
    b, h, wd, c = x.shape
    ho, wo = -(-h // stride), -(-wd // stride)
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = []
    for ky in range(3):
        for kx in range(3):
            patch = jax.lax.slice(
                xp, (0, ky, kx, 0), (b, ky + h, kx + wd, c), (1, stride, stride, 1)
            )
            cols.append(patch)
    out = jnp.concatenate([p[..., None, :] for p in cols], axis=-2)  # [B,Ho,Wo,9,C]
    return out.reshape(b * ho * wo, 9 * c)


def quantize_act(x: jax.Array, a_max: jax.Array, bits: int) -> jax.Array:
    """Paper eq. (2): UINT-Q affine quantization of a (post-ReLU) activation.

    Returns the *integer grid values* as f32 in ``[0, 2^Q - 1]``.
    """
    levels = float(2**bits - 1)
    scale = a_max / levels
    q = jnp.floor(x / scale)
    return jnp.clip(q, 0.0, levels)


def dequantize_act(q: jax.Array, a_max: jax.Array, bits: int) -> jax.Array:
    """Inverse of :func:`quantize_act`: ``q * S_a``."""
    return q * (a_max / float(2**bits - 1))


def fake_quant_act(x: jax.Array, a_max: jax.Array, bits: int) -> jax.Array:
    """quantize -> dequantize round trip (the value the INT-Q pipeline sees)."""
    return dequantize_act(quantize_act(x, a_max, bits), a_max, bits)


def quantize_weight(w: jax.Array, bits: int = 8) -> tuple[jax.Array, jax.Array]:
    """Paper eq. (1): INT-Q affine weight quantization over the full range.

    Returns ``(q, scale)`` with **round-to-nearest** codes
    ``q = floor(w / S_w + 1/2)`` (integer grid, f32) — deliberately
    ``floor(x + 0.5)`` rather than ``round`` so ties break identically to
    the rust quantizer (``quant::requant::quantize_weights_i8``; numpy's
    ``round`` is half-to-even, rust's is half-away-from-zero — half-UP is
    the one rule both sides express exactly). Pinned cross-language by
    ``tools/fixtures/weight_quant.json``.
    """
    w_min = jnp.minimum(jnp.min(w), 0.0)
    w_max = jnp.maximum(jnp.max(w), 0.0)
    scale = (w_max - w_min) / float(2**bits - 1)
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.floor(w / scale + 0.5)
    lo = jnp.floor(w_min / scale)
    return jnp.clip(q, lo, lo + float(2**bits - 1)), scale


def fake_quant_weight(w: jax.Array, bits: int = 8) -> jax.Array:
    q, s = quantize_weight(w, bits)
    return q * s


# --- backward-pass oracles (the paper's BW-ERR / BW-GRAD dataflows) -------


def matmul_bw_err(g: jax.Array, w: jax.Array) -> jax.Array:
    """Backward-error of a matmul: ``dL/dx = g @ w^T``."""
    return jnp.dot(g, w.T, preferred_element_type=jnp.float32)


def matmul_bw_grad(x: jax.Array, g: jax.Array) -> jax.Array:
    """Backward-gradient of a matmul: ``dL/dw = x^T @ g``."""
    return jnp.dot(x.T, g, preferred_element_type=jnp.float32)


def depthwise_bw_err(g: jax.Array, k: jax.Array, stride: int, in_hw: tuple[int, int]) -> jax.Array:
    """dL/dx of :func:`depthwise_conv` via VJP (shape-faithful oracle)."""
    c = k.shape[-1]
    x0 = jnp.zeros((g.shape[0], in_hw[0], in_hw[1], c), jnp.float32)
    _, vjp = jax.vjp(lambda x: depthwise_conv(x, k, stride), x0)
    return vjp(g)[0]


def depthwise_bw_grad(x: jax.Array, g: jax.Array, stride: int) -> jax.Array:
    """dL/dk of :func:`depthwise_conv` via VJP."""
    k0 = jnp.zeros((3, 3, x.shape[-1]), jnp.float32)
    _, vjp = jax.vjp(lambda k: depthwise_conv(x, k, stride), k0)
    return vjp(g)[0]
