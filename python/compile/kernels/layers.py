"""L1 Pallas kernels: im2col + the layer-level primitives built on matmul.

Mirrors the paper's Fig. 3: every convolutional variant is reshaped into a
matrix multiplication. Pointwise (1x1) conv needs no marshaling — it *is* a
matmul over [B*H*W, Cin]. The 3x3 full conv of the stem goes through an
im2col kernel (here a Pallas kernel per batch image, the analogue of the
paper's DMA-side im2col) followed by the tiled matmul kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import matmul as mk


def _im2col_kernel(x_ref, o_ref, *, stride: int, h: int, w: int, c: int):
    """x_ref: [Bb, H+2, W+2, C] padded; o_ref: [Bb, Ho*Wo, 9*C] (ky,kx,c order)."""
    ho, wo = -(-h // stride), -(-w // stride)
    x = x_ref[...]
    bb = x.shape[0]
    cols = []
    for ky in range(3):
        for kx in range(3):
            tap = jax.lax.slice(
                x, (0, ky, kx, 0), (bb, ky + h, kx + w, c), (1, stride, stride, 1)
            )
            cols.append(tap.reshape(bb, ho * wo, c))
    o_ref[...] = jnp.concatenate(cols, axis=-1)


@functools.partial(jax.jit, static_argnames=("stride",))
def im2col3x3(x: jax.Array, stride: int = 1) -> jax.Array:
    """``[B,H,W,C] -> [B*Ho*Wo, 9*C]`` patch matrix for a SAME 3x3 conv.

    NOTE: column order here is (ky, kx, c) *interleaved per tap*, matching
    ``ref.im2col3x3`` and ``w.reshape(9*Cin, Cout)`` for HWIO filters.
    """
    b, h, w, c = x.shape
    ho, wo = -(-h // stride), -(-w // stride)
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    # batch-block the grid only when the full batch blows the lowering
    # budget (§Perf L1/L2: each grid step is an XLA while iteration on CPU)
    bb = b
    while bb > 1 and 4 * bb * ((h + 2) * (w + 2) * c + ho * wo * 9 * c) > mk.LOWERING_BUDGET_BYTES:
        nxt = bb - 1
        while b % nxt != 0:
            nxt -= 1
        bb = nxt
    out = pl.pallas_call(
        functools.partial(_im2col_kernel, stride=stride, h=h, w=w, c=c),
        grid=(b // bb,),
        in_specs=[pl.BlockSpec((bb, h + 2, w + 2, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((bb, ho * wo, 9 * c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, ho * wo, 9 * c), jnp.float32),
        interpret=True,
    )(xp)
    return out.reshape(b * ho * wo, 9 * c)


def pointwise_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """1x1 conv as the tiled matmul kernel. ``x: [B,H,W,Cin]``, ``w: [Cin,Cout]``."""
    b, h, wd, cin = x.shape
    y = mk.matmul(x.reshape(b * h * wd, cin), w)
    return y.reshape(b, h, wd, -1)


def dense(x: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """Fully-connected layer on the tiled matmul kernel."""
    return mk.matmul(x, w) + bias


def conv3x3(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """SAME 3x3 conv = im2col kernel + tiled matmul kernel. ``w: [3,3,Cin,Cout]``."""
    b, h, wd, cin = x.shape
    ho, wo = -(-h // stride), -(-wd // stride)
    cols = im2col3x3(x, stride)
    y = mk.matmul(cols, w.reshape(9 * cin, -1))
    return y.reshape(b, ho, wo, -1)
