"""L1 Pallas kernels: 3x3 depthwise convolution (forward + both backward steps).

The paper implements depthwise layers as im2col + short-K matmul (K = 9),
noting the software im2col costs up to 70% of the forward latency unless the
DMA performs it during the L2->L1 transfer. On the TPU mapping there is no
DMA marshaling: the kernel reads a padded input block from VMEM and reduces
the nine taps as shifted strided slices — filter reuse only, exactly the
data-reuse structure the paper describes for DW layers.

Grid: channels blocked to the VMEM budget, full batch per step (§Perf
L1/L2: batch-per-step grids lowered to costly XLA while-loops under
interpret=True; one step per channel block keeps the lowered module flat).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import matmul as mk


def _out_hw(h: int, w: int, stride: int) -> tuple[int, int]:
    return -(-h // stride), -(-w // stride)


def _dw_fw_kernel(x_ref, k_ref, o_ref, *, stride: int, h: int, w: int):
    """x_ref: [B, H+2, W+2, Cb] (pre-padded), k_ref: [3, 3, Cb], o_ref: [B, Ho, Wo, Cb]."""
    x = x_ref[...]
    b = x.shape[0]
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for ky in range(3):
        for kx in range(3):
            tap = jax.lax.slice(
                x, (0, ky, kx, 0), (b, ky + h, kx + w, x.shape[3]), (1, stride, stride, 1)
            )
            acc += tap * k_ref[ky, kx, :]
    o_ref[...] = acc


def _pick_cb(b: int, c: int, plane: int) -> int:
    """Channel block: largest divisor of C keeping the batched input block
    within a quarter of the lowering budget."""
    cb = c
    while cb > 1 and 4 * b * plane * cb > mk.LOWERING_BUDGET_BYTES // 4:
        nxt = cb - 1
        while c % nxt != 0:
            nxt -= 1
        cb = nxt
    return cb


@functools.partial(jax.jit, static_argnames=("stride",))
def depthwise_conv(x: jax.Array, k: jax.Array, stride: int = 1) -> jax.Array:
    """3x3 depthwise conv, pad=1 (PyTorch-style). ``x: [B,H,W,C]``, ``k: [3,3,C]``."""
    b, h, w, c = x.shape
    ho, wo = _out_hw(h, w, stride)
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cb = _pick_cb(b, c, (h + 2) * (w + 2))
    grid = (c // cb,)
    return pl.pallas_call(
        functools.partial(_dw_fw_kernel, stride=stride, h=h, w=w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, h + 2, w + 2, cb), lambda j: (0, 0, 0, j)),
            pl.BlockSpec((3, 3, cb), lambda j: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((b, ho, wo, cb), lambda j: (0, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, ho, wo, c), jnp.float32),
        interpret=True,
    )(xp, k)


def _dilate(g: jax.Array, stride: int, h: int, w: int) -> jax.Array:
    """Insert ``stride-1`` zeros between gradient rows/cols and crop to HxW."""
    if stride == 1:
        return g
    b, ho, wo, c = g.shape
    gd = jnp.zeros((b, ho * stride, wo * stride, c), g.dtype)
    gd = gd.at[:, ::stride, ::stride, :].set(g)
    return gd[:, :h, :w, :]


@functools.partial(jax.jit, static_argnames=("stride", "h", "w"))
def depthwise_bw_err(g: jax.Array, k: jax.Array, stride: int, h: int, w: int) -> jax.Array:
    """BW-ERR of depthwise conv: full-correlation of the (dilated) output
    gradient with the 180°-rotated filter — itself a stride-1 depthwise
    conv, so it reuses the forward kernel (the paper's Fig. 3 dataflow)."""
    gd = _dilate(g, stride, h, w)
    k_rot = k[::-1, ::-1, :]
    return depthwise_conv(gd, k_rot, stride=1)


def _dw_grad_kernel(x_ref, g_ref, o_ref, *, stride: int, h: int, w: int):
    """Per-channel-block filter gradient, reduced over batch and space in
    one grid step: o_ref [3, 3, Cb]."""
    x = x_ref[...]
    g = g_ref[...]
    b = x.shape[0]
    for ky in range(3):
        for kx in range(3):
            tap = jax.lax.slice(
                x, (0, ky, kx, 0), (b, ky + h, kx + w, x.shape[3]), (1, stride, stride, 1)
            )
            o_ref[ky, kx, :] = jnp.sum(tap * g, axis=(0, 1, 2))


@functools.partial(jax.jit, static_argnames=("stride",))
def depthwise_bw_grad(x: jax.Array, g: jax.Array, stride: int = 1) -> jax.Array:
    """BW-GRAD of depthwise conv: ``dL/dk[ky,kx,c] = sum_bhw x_tap * g``."""
    b, h, w, c = x.shape
    ho, wo = _out_hw(h, w, stride)
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cb = _pick_cb(b, c, (h + 2) * (w + 2))
    grid = (c // cb,)
    return pl.pallas_call(
        functools.partial(_dw_grad_kernel, stride=stride, h=h, w=w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, h + 2, w + 2, cb), lambda j: (0, 0, 0, j)),
            pl.BlockSpec((b, ho, wo, cb), lambda j: (0, 0, 0, j)),
        ],
        out_specs=pl.BlockSpec((3, 3, cb), lambda j: (0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((3, 3, c), jnp.float32),
        interpret=True,
    )(xp, g)
