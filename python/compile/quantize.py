"""Post-Training Quantization of the frozen stage (paper §III-C, eq. 1-2).

Standard uniform-affine PTQ, the NEMO recipe the paper uses:
 1. fold BatchNorm (our per-channel affine) into the conv weights,
 2. quantize folded weights to INT-Q over their full dynamic range,
 3. calibrate activation dynamic ranges ``a_max`` on a training subset
    (activations are post-ReLU, hence UINT-Q),
 4. re-quantize every activation after each layer.

The result is a ``quant`` config dict consumed by ``model.frozen_forward``
and serialized into ``artifacts/manifest.json`` for the rust runtime (which
needs ``S_a,l`` to pack latent replays).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import model
from .kernels import ref


def calibrate(
    params,
    calib_images: np.ndarray,
    a_bits: int = 8,
    w_bits: int = 8,
    batch: int = 64,
) -> dict:
    """Measure per-layer activation ranges of the *fake-quantized* network.

    Ranges are collected progressively: layer ``i``'s input is the quantized
    output of layer ``i-1`` (as it will be at inference), so scales compose
    the way the deployed integer pipeline does.
    """
    a_max = [0.0] * len(model.ARCH)
    pooled_max = 0.0
    input_a_max = 1.0  # images are normalized to [0, 1]

    for s in range(0, len(calib_images), batch):
        x = jnp.asarray(calib_images[s:s + batch], jnp.float32)
        x = ref.fake_quant_act(x, input_a_max, a_bits)
        for i, (kind, _cin, _cout, stride) in enumerate(model.ARCH):
            p = model._fq_weights(params[i], kind, w_bits)
            y = model._conv_layer(kind, p, x, stride, use_kernels=False)
            a_max[i] = max(a_max[i], float(jnp.max(y)))
            # quantize with the running estimate — final pass below re-checks
            x = ref.fake_quant_act(y, max(a_max[i], 1e-6), a_bits)
        pooled_max = max(pooled_max, float(jnp.max(jnp.mean(x, axis=(1, 2)))))

    return {
        "a_bits": a_bits,
        "w_bits": w_bits,
        "input_a_max": input_a_max,
        "a_max": a_max,
        "pooled_a_max": pooled_max,
    }


def latent_a_max(quant: dict, l: int) -> float:
    """Dynamic range of the latent at split ``l`` (for LR packing scales)."""
    if l >= model.L_LINEAR:
        return float(quant["pooled_a_max"])
    return float(quant["a_max"][l - 1])


def fp32_latent_ranges(params, calib_images: np.ndarray, splits, batch: int = 64) -> dict:
    """Latent ``a_max`` per split for the *FP32* frozen stage.

    Needed by the FP32+UINT-Q ablation arm (Table II): replays of fp32
    latents still get quantized to Q_LR bits for storage, with a scale
    calibrated here.
    """
    out = {int(l): 0.0 for l in splits}
    for s in range(0, len(calib_images), batch):
        x = jnp.asarray(calib_images[s:s + batch], jnp.float32)
        for l in sorted(out):
            lat = model.frozen_forward(params, x, l, quant=None, use_kernels=False)
            out[l] = max(out[l], float(jnp.max(lat)))
    return out
