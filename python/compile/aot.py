"""AOT pipeline: build every runtime artifact for the rust coordinator.

Run as ``python -m compile.aot --out-dir ../artifacts`` (what ``make
artifacts`` does). Python executes ONCE here and never again: the emitted
artifacts make the rust binary self-contained.

Emits, per DESIGN.md §2:
  - ``data/*.bin``               Core50-mini tensors (u8 images, i32 labels)
  - ``frozen_{fp32,int8}_l{l}_b{B}.hlo.txt``   frozen stage, weights baked
    as HLO constants (the MRAM/Flash analogue)
  - ``adaptive_train_l{l}.hlo.txt``  fwd + BW-ERR/BW-GRAD + SGD, one module
  - ``adaptive_eval_l{l}.hlo.txt``   adaptive-stage logits for test eval
  - ``params_l{l}.bin``          initial adaptive parameters (f32 LE)
  - ``manifest.json``            shapes, scales, file index, protocol config

Interchange is HLO *text*: jax >= 0.5 emits protos with 64-bit instruction
ids that xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import dataset as D
from . import model, pretrain, quantize

B_NEW = 8      # new images per frozen-stage forward (paper: 21)
B_TRAIN = 64   # adaptive-stage mini-batch (paper: 128 = 21 new + 107 replay)
B_EVAL = 50    # test-eval batch

DTYPE_BYTES = {"u8": 1, "i32": 4, "f32": 4}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the frozen-stage weights are baked as HLO
    # constants; the default printer elides them as `constant({...})`,
    # which would silently destroy the model on the text round-trip.
    return comp.as_hlo_text(print_large_constants=True)


def _save_bin(path: str, arr: np.ndarray, dtype: str) -> dict:
    np_dtype = {"u8": np.uint8, "i32": np.int32, "f32": np.float32}[dtype]
    arr.astype(np_dtype).tofile(path)
    return {"path": os.path.basename(os.path.dirname(path)) + "/" + os.path.basename(path)
            if os.path.basename(os.path.dirname(path)) == "data" else os.path.basename(path),
            "dtype": dtype, "shape": list(arr.shape)}


def _flatten_adaptive(ap):
    """Deterministic flattening of the adaptive params pytree.

    jax flattens a list-of-dicts with dict keys in sorted order; we record
    the resulting (name, shape) list so the rust side can index tensors.
    """
    leaves, treedef = jax.tree_util.tree_flatten(ap)
    names = []
    for li, layer in enumerate(ap):
        for key in sorted(layer.keys()):
            names.append(f"layer{li}.{key}")
    assert len(names) == len(leaves)
    return leaves, treedef, names


def export_split(params, quant_cfg, l: int, out_dir: str, log) -> dict:
    """Lower all modules for one latent-replay split ``l``."""
    entry: dict = {}
    lat_shape = model.latent_shape(l)

    # -- frozen stage (constants baked) at both quant settings and batches
    for tag, q in (("fp32", None), ("int8", quant_cfg)):
        for b in (B_NEW, B_EVAL):
            t0 = time.time()
            fn = lambda x: (model.frozen_forward(params, x, l, q, use_kernels=True),)
            low = jax.jit(fn).lower(
                jax.ShapeDtypeStruct((b, D.HW, D.HW, 3), jnp.float32)
            )
            name = f"frozen_{tag}_l{l}_b{b}.hlo.txt"
            with open(os.path.join(out_dir, name), "w") as f:
                f.write(to_hlo_text(low))
            entry[f"frozen_{tag}_b{b}"] = name
            log(f"  {name} ({time.time() - t0:.1f}s)")

    # -- adaptive stage: initial params + train + eval modules
    ap = params[l:] if l < model.L_LINEAR else params[model.L_LINEAR:]
    leaves, treedef, names = _flatten_adaptive(ap)

    pbin = f"params_l{l}.bin"
    with open(os.path.join(out_dir, pbin), "wb") as f:
        for leaf in leaves:
            f.write(np.asarray(leaf, np.float32).tobytes())
    entry["params_bin"] = pbin
    entry["param_tensors"] = [
        {"name": n, "shape": list(np.asarray(x).shape)} for n, x in zip(names, leaves)
    ]

    def train_fn(flat, latents, labels, lr):
        ap_tree = jax.tree_util.tree_unflatten(treedef, flat)
        new_ap, loss, correct = model.train_step(ap_tree, latents, labels, lr, l, True)
        return tuple(jax.tree_util.tree_leaves(new_ap)) + (loss, correct)

    t0 = time.time()
    low = jax.jit(train_fn).lower(
        [jax.ShapeDtypeStruct(np.asarray(x).shape, jnp.float32) for x in leaves],
        jax.ShapeDtypeStruct((B_TRAIN,) + lat_shape, jnp.float32),
        jax.ShapeDtypeStruct((B_TRAIN,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    name = f"adaptive_train_l{l}.hlo.txt"
    with open(os.path.join(out_dir, name), "w") as f:
        f.write(to_hlo_text(low))
    entry["adaptive_train"] = name
    log(f"  {name} ({time.time() - t0:.1f}s)")

    def eval_fn(flat, latents):
        ap_tree = jax.tree_util.tree_unflatten(treedef, flat)
        return (model.adaptive_forward(ap_tree, latents, l, use_kernels=True),)

    t0 = time.time()
    low = jax.jit(eval_fn).lower(
        [jax.ShapeDtypeStruct(np.asarray(x).shape, jnp.float32) for x in leaves],
        jax.ShapeDtypeStruct((B_EVAL,) + lat_shape, jnp.float32),
    )
    name = f"adaptive_eval_l{l}.hlo.txt"
    with open(os.path.join(out_dir, name), "w") as f:
        f.write(to_hlo_text(low))
    entry["adaptive_eval"] = name
    log(f"  {name} ({time.time() - t0:.1f}s)")
    return entry


def build(out_dir: str, seed: int = 0, fast: bool = False, log=print) -> None:
    os.makedirs(out_dir, exist_ok=True)
    data_dir = os.path.join(out_dir, "data")
    os.makedirs(data_dir, exist_ok=True)

    # ---- 1. datasets --------------------------------------------------
    log("[aot] generating Core50-mini ...")
    data = D.build_cl_dataset()
    pt_frames, pt_sessions = (20, 2) if fast else (50, 4)
    pim, plab = D.build_pretrain_dataset(frames=pt_frames, sessions=pt_sessions)

    # ---- 2. pretrain + initial fine-tune ------------------------------
    log(f"[aot] pretraining backbone on ImageNet-proxy ({len(pim)} images) ...")
    t0 = time.time()
    params = pretrain.pretrain_backbone(
        pim, plab, D.N_PRETRAIN_CLASSES, seed=seed,
        epochs=3 if fast else 12, verbose=log,
    )
    acc_pt = pretrain.evaluate(params, pim, plab)
    log(f"[aot] pretrain done in {time.time() - t0:.0f}s, proxy-train acc {acc_pt:.3f}")

    params = pretrain.swap_head(params, jax.random.PRNGKey(seed + 7))
    params, init_images, init_labels = pretrain.finetune_initial(
        params, data, seed=seed, epochs=4 if fast else 12, verbose=log
    )
    acc_init = pretrain.evaluate(
        params,
        data["test_images"][np.isin(data["test_labels"], pretrain.INITIAL_CLASSES)],
        data["test_labels"][np.isin(data["test_labels"], pretrain.INITIAL_CLASSES)],
    )
    log(f"[aot] initial fine-tune done; initial-classes test acc {acc_init:.3f}")

    # ---- 3. PTQ calibration -------------------------------------------
    log("[aot] PTQ calibration (INT-8 frozen stage) ...")
    quant_cfg = quantize.calibrate(params, init_images)
    fp32_ranges = quantize.fp32_latent_ranges(params, init_images, model.SPLITS)

    # ---- 4. data bins ---------------------------------------------------
    manifest_data = {}
    img_u8 = np.clip(np.round(data["train_images"] * 255.0), 0, 255)
    manifest_data["train_images"] = _save_bin(os.path.join(data_dir, "train_images.bin"), img_u8, "u8")
    for key in ("train_labels", "train_class", "train_session", "train_frame", "test_labels"):
        manifest_data[key] = _save_bin(os.path.join(data_dir, f"{key}.bin"), data[key], "i32")
    test_u8 = np.clip(np.round(data["test_images"] * 255.0), 0, 255)
    manifest_data["test_images"] = _save_bin(os.path.join(data_dir, "test_images.bin"), test_u8, "u8")
    initial_mask = (
        np.isin(data["train_class"], pretrain.INITIAL_CLASSES)
        & np.isin(data["train_session"], pretrain.INITIAL_SESSIONS)
    ).astype(np.uint8)
    manifest_data["initial_mask"] = _save_bin(os.path.join(data_dir, "initial_mask.bin"), initial_mask, "u8")

    # ---- 5. HLO modules per split ---------------------------------------
    splits_entry = {}
    latent_entry = {}
    for l in model.SPLITS:
        log(f"[aot] lowering split l={l} ...")
        splits_entry[str(l)] = export_split(params, quant_cfg, l, out_dir, log)
        latent_entry[str(l)] = {
            "shape": list(model.latent_shape(l)),
            "a_max_int8": quantize.latent_a_max(quant_cfg, l),
            "a_max_fp32": float(fp32_ranges[l]),
        }

    # ---- 6. manifest -----------------------------------------------------
    manifest = {
        "version": 1,
        "seed": seed,
        "model": {
            "arch": [list(t) for t in model.ARCH],
            "num_classes": model.NUM_CLASSES,
            "input_hw": model.INPUT_HW,
            "feat_dim": model.FEAT_DIM,
            "splits": list(model.SPLITS),
            "num_params": model.num_params(params),
        },
        "batch": {"new": B_NEW, "train": B_TRAIN, "eval": B_EVAL},
        "quant": {
            "a_bits": quant_cfg["a_bits"],
            "w_bits": quant_cfg["w_bits"],
            "input_a_max": quant_cfg["input_a_max"],
            "a_max": [float(v) for v in quant_cfg["a_max"]],
            "pooled_a_max": float(quant_cfg["pooled_a_max"]),
        },
        "latent": latent_entry,
        "splits": splits_entry,
        "data": manifest_data,
        "protocol": {
            "initial_classes": list(pretrain.INITIAL_CLASSES),
            "initial_sessions": list(pretrain.INITIAL_SESSIONS),
            "n_classes": D.N_CL_CLASSES,
            "train_sessions": D.TRAIN_SESSIONS,
            "test_sessions": D.TEST_SESSIONS,
            "frames_per_session": D.FRAMES_PER_SESSION,
        },
        "build": {
            "pretrain_proxy_acc": float(acc_pt),
            "initial_test_acc": float(acc_init),
            "fast": fast,
        },
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"[aot] wrote {out_dir}/manifest.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", dest="out_dir_compat", default=None,
                    help="compat alias: path to any file inside the out dir")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fast", action="store_true", help="small pretrain (CI)")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out_dir_compat:
        out_dir = os.path.dirname(args.out_dir_compat) or "."
    build(out_dir, seed=args.seed, fast=args.fast)


if __name__ == "__main__":
    main()
