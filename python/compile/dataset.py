"""Core50-mini: a procedural, session-structured image dataset (DESIGN.md §1).

Core50 is 50 household objects filmed in 11 sessions; frames within a
session are temporally correlated (pose/background drift), which is what
makes NICv2 learning events non-IID. We reproduce that structure
synthetically at 32x32:

 - a *class* is a fixed constellation of oriented Gabor-like blobs with a
   class color palette and texture frequency — the "object identity";
 - a *session* is a smooth random trajectory of nuisance parameters
   (rotation, translation, scale, background color, lighting) — the "video";
 - a *frame* is one point on that trajectory plus pixel noise.

Two disjoint universes share the generator:
 - ``pretrain`` classes (seed offset 10_000): the "ImageNet proxy" used only
   for build-time pretraining of the backbone;
 - ``cl`` classes 0..9: the continual-learning benchmark itself.

Everything is deterministic in (seed, class, session, frame).
"""

from __future__ import annotations

import numpy as np

HW = 32
N_CL_CLASSES = 10
N_PRETRAIN_CLASSES = 20
TRAIN_SESSIONS = 6
TEST_SESSIONS = 2          # held-out sessions per class (never trained on)
FRAMES_PER_SESSION = 60
N_BLOBS = 4
PRETRAIN_SEED_OFFSET = 10_000


def _class_rng(seed: int, cls: int) -> np.random.RandomState:
    return np.random.RandomState((seed * 1_000_003 + cls) % (2**31 - 1))


def _session_rng(seed: int, cls: int, session: int) -> np.random.RandomState:
    return np.random.RandomState((seed * 1_000_003 + cls * 9_176 + session * 131 + 7) % (2**31 - 1))


def class_spec(cls: int, seed: int = 1234) -> dict:
    """The immutable identity of a class: blob constellation + palette."""
    r = _class_rng(seed, cls)
    return {
        "centers": r.uniform(-0.55, 0.55, size=(N_BLOBS, 2)),
        "sigmas": r.uniform(0.10, 0.28, size=N_BLOBS),
        "freqs": r.uniform(4.0, 11.0, size=N_BLOBS),
        "thetas": r.uniform(0, np.pi, size=N_BLOBS),
        "colors": r.uniform(0.25, 1.0, size=(N_BLOBS, 3)),
        "bg_base": r.uniform(0.0, 0.45, size=3),
    }


def session_trajectory(cls: int, session: int, n_frames: int, seed: int = 1234) -> dict:
    """Smooth nuisance trajectories: a random walk low-pass filtered so that
    consecutive frames are strongly correlated (video-like)."""
    r = _session_rng(seed, cls, session)

    def walk(lo, hi, scale):
        steps = r.randn(n_frames) * scale
        path = np.cumsum(steps)
        path = path - path.mean()
        start = r.uniform(lo, hi)
        return np.clip(start + path, lo, hi)

    return {
        "rot": walk(-0.6, 0.6, 0.03),
        "tx": walk(-0.25, 0.25, 0.015),
        "ty": walk(-0.25, 0.25, 0.015),
        "scale": walk(0.8, 1.25, 0.01),
        "light": walk(0.75, 1.2, 0.01),
        "bg_shift": np.stack([walk(-0.12, 0.12, 0.01) for _ in range(3)], axis=1),
    }


_YY, _XX = np.meshgrid(
    np.linspace(-1, 1, HW), np.linspace(-1, 1, HW), indexing="ij"
)


def render_frame(spec: dict, rot: float, tx: float, ty: float, scale: float,
                 light: float, bg_shift: np.ndarray, noise_rng=None) -> np.ndarray:
    """Render one 32x32x3 frame in [0, 1]."""
    c, s = np.cos(rot), np.sin(rot)
    # inverse pose transform of the pixel grid
    xg = (c * _XX + s * _YY) / scale - tx
    yg = (-s * _XX + c * _YY) / scale - ty
    img = np.empty((HW, HW, 3), np.float32)
    bg = np.clip(spec["bg_base"] + bg_shift, 0, 1)
    img[...] = bg[None, None, :]
    for i in range(N_BLOBS):
        cx, cy = spec["centers"][i]
        dx, dy = xg - cx, yg - cy
        g = np.exp(-(dx * dx + dy * dy) / (2 * spec["sigmas"][i] ** 2))
        th = spec["thetas"][i]
        tex = 0.5 + 0.5 * np.sin(
            spec["freqs"][i] * (np.cos(th) * dx + np.sin(th) * dy) * np.pi
        )
        blob = (g * tex).astype(np.float32)
        img += blob[..., None] * spec["colors"][i][None, None, :]
    img *= light
    if noise_rng is not None:
        img += noise_rng.randn(HW, HW, 3).astype(np.float32) * 0.02
    return np.clip(img, 0.0, 1.0)


def render_session(cls: int, session: int, n_frames: int = FRAMES_PER_SESSION,
                   seed: int = 1234) -> np.ndarray:
    """All frames of one (class, session): ``[n_frames, 32, 32, 3]`` f32."""
    spec = class_spec(cls, seed)
    traj = session_trajectory(cls, session, n_frames, seed)
    noise = np.random.RandomState(
        (seed * 17 + cls * 911 + session * 37 + 3) % (2**31 - 1)
    )
    return np.stack([
        render_frame(spec, traj["rot"][f], traj["tx"][f], traj["ty"][f],
                     traj["scale"][f], traj["light"][f], traj["bg_shift"][f], noise)
        for f in range(n_frames)
    ])


def build_cl_dataset(seed: int = 1234) -> dict:
    """The full Core50-mini tensor set.

    Returns dict with:
      train_images [N,32,32,3] f32, train_labels [N] i32,
      train_class/session/frame [N] i32 (event bookkeeping),
      test_images/test_labels (held-out sessions of every class).
    """
    tr_im, tr_lab, tr_cls, tr_sess, tr_frame = [], [], [], [], []
    te_im, te_lab = [], []
    n_sessions = TRAIN_SESSIONS + TEST_SESSIONS
    for cls in range(N_CL_CLASSES):
        for sess in range(n_sessions):
            frames = render_session(cls, sess, FRAMES_PER_SESSION, seed)
            if sess < TRAIN_SESSIONS:
                tr_im.append(frames)
                tr_lab += [cls] * len(frames)
                tr_cls += [cls] * len(frames)
                tr_sess += [sess] * len(frames)
                tr_frame += list(range(len(frames)))
            else:
                te_im.append(frames)
                te_lab += [cls] * len(frames)
    return {
        "train_images": np.concatenate(tr_im).astype(np.float32),
        "train_labels": np.asarray(tr_lab, np.int32),
        "train_class": np.asarray(tr_cls, np.int32),
        "train_session": np.asarray(tr_sess, np.int32),
        "train_frame": np.asarray(tr_frame, np.int32),
        "test_images": np.concatenate(te_im).astype(np.float32),
        "test_labels": np.asarray(te_lab, np.int32),
    }


def build_pretrain_dataset(seed: int = 1234, frames: int = 50,
                           sessions: int = 4) -> tuple[np.ndarray, np.ndarray]:
    """The ImageNet-proxy split: disjoint class universe, IID-shuffled."""
    ims, labs = [], []
    for cls in range(N_PRETRAIN_CLASSES):
        for sess in range(sessions):
            f = render_session(PRETRAIN_SEED_OFFSET + cls, sess, frames, seed)
            ims.append(f)
            labs += [cls] * len(f)
    images = np.concatenate(ims).astype(np.float32)
    labels = np.asarray(labs, np.int32)
    perm = np.random.RandomState(seed).permutation(len(labels))
    return images[perm], labels[perm]
