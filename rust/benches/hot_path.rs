//! Hot-path bench (§Perf L3): the per-step costs the rust coordinator adds
//! around the PJRT `execute` call — replay sampling + dequantization,
//! batch composition, bit-packed insertion, literal creation — plus, when
//! artifacts are present, the end-to-end train step and its breakdown.
//!
//! Every fused case has a `_twopass` twin that re-enacts the pre-rework
//! read path (unpack codes into a scratch `Vec`, then dequantize element
//! by element) so the before/after ratio is measured, not remembered.
//! Before/after numbers from this bench drive EXPERIMENTS.md §Perf and
//! BENCH_kernels.json.

use tinycl::coordinator::batcher::Batcher;
use tinycl::coordinator::replay::ReplayBuffer;
use tinycl::coordinator::{CLConfig, Session};
use tinycl::quant::{pack_bits, packed_len, unpack_range, ActQuantizer};
use tinycl::runtime::synthetic::{self, SyntheticSpec};
use tinycl::runtime::{
    literal_from_f32_slice, Backend, Dataset, FrozenPath, Manifest, NativeBackend, Runtime,
    TensorF32,
};
use tinycl::util::bench::{black_box, Bench};
use tinycl::util::rng::Rng;

fn main() {
    let mut b = Bench::new("hot_path");
    let elems = 1024; // latent size at split 13
    let n_lr = 256;
    let batch = 64;
    let batch_new = 8;

    // ---- replay buffer primitives --------------------------------------
    let mut rng = Rng::new(1);
    let latents: Vec<f32> = (0..n_lr * elems).map(|i| (i % 255) as f32 / 255.0).collect();
    let labels: Vec<i32> = (0..n_lr as i32).map(|i| i % 10).collect();

    for bits in [8u8, 7, 6] {
        let mut buf = ReplayBuffer::new_packed(n_lr, elems, bits, 1.0);
        buf.init_fill(&latents, &labels, &mut rng);
        let mut out = vec![0f32; 56 * elems];
        let mut labs = vec![0i32; 56];
        b.case(&format!("replay_sample56_u{bits}"), || {
            buf.sample_into(56, &mut rng, &mut out, &mut labs);
            black_box(&out);
        });

        // the pre-rework two-pass read path, re-enacted on the same data:
        // unpack_range into a code scratch Vec, then LUT-dequantize it
        let quant = ActQuantizer::new(bits, 1.0);
        let arena = {
            let mut codes = Vec::new();
            quant.quantize(&latents, &mut codes);
            let mut packed = Vec::new();
            pack_bits(&codes, bits, &mut packed);
            assert_eq!(packed.len(), packed_len(n_lr * elems, bits));
            packed
        };
        let mut scratch_codes: Vec<u8> = Vec::new();
        b.case(&format!("replay_sample56_u{bits}_twopass"), || {
            for i in 0..56 {
                let slot = rng.below(n_lr);
                unpack_range(&arena, bits, slot * elems, elems, &mut scratch_codes);
                quant.dequantize(&scratch_codes, &mut out[i * elems..(i + 1) * elems]);
            }
            black_box(&out);
        });

        b.case(&format!("replay_insert_u{bits}"), || {
            buf.write_slot(3, &latents[..elems], 5);
        });
    }
    let mut buf_f32 = ReplayBuffer::new_f32(n_lr, elems);
    buf_f32.init_fill(&latents, &labels, &mut rng);
    let mut out = vec![0f32; 56 * elems];
    let mut labs = vec![0i32; 56];
    b.case("replay_sample56_f32", || {
        buf_f32.sample_into(56, &mut rng, &mut out, &mut labs);
        black_box(&out);
    });

    // ---- batch composition ---------------------------------------------
    let mut buf = ReplayBuffer::new_packed(n_lr, elems, 8, 1.0);
    buf.init_fill(&latents, &labels, &mut rng);
    let mut batcher = Batcher::new(batch, batch_new, elems);
    let new_lat: Vec<f32> = (0..60 * elems).map(|i| (i % 128) as f32 / 128.0).collect();
    let new_lab: Vec<i32> = vec![5; 60];
    let pick: Vec<usize> = (0..batch_new).collect();
    b.case("batch_compose_8new_56replay", || {
        let (l, _lab) = batcher.compose(&new_lat, &new_lab, &pick, &buf, &mut rng);
        black_box(l.len());
    });

    // ---- the frozen stage: fake-quant f32 (before) vs true-INT8 (after)
    // — the hottest path of every workload: protocol events, coalesced
    // fleet traffic, batched inference all run frozen_forward per batch
    {
        let (m, ds) = synthetic::generate(&SyntheticSpec::tiny()).expect("synthetic env");
        let be_sim = NativeBackend::with_frozen_path(m.clone(), FrozenPath::FakeQuantF32)
            .expect("fake-quant backend");
        let be_int = NativeBackend::with_frozen_path(m, FrozenPath::Int8).expect("int8 backend");
        let img = ds.image_elems();
        let fb = 8;
        let mut images = vec![0f32; fb * img];
        for i in 0..fb {
            ds.train_image_into(i, &mut images[i * img..(i + 1) * img]);
        }
        for l in [13usize, 15] {
            let lelems = be_int.latent_elems(l).unwrap();
            let mut lat = vec![0f32; fb * lelems];
            b.case(&format!("frozen_fwd_l{l}_b8_fakequant_f32"), || {
                be_sim.frozen_forward(l, true, false, &images, &mut lat).unwrap();
                black_box(&lat);
            });
            b.case(&format!("frozen_fwd_l{l}_b8_int8"), || {
                be_int.frozen_forward(l, true, false, &images, &mut lat).unwrap();
                black_box(&lat);
            });
        }
    }

    // ---- literal creation (host -> XLA marshaling) ----------------------
    let t = TensorF32::new(vec![batch, 2, 2, 256], vec![0.5; batch * elems]);
    b.case("literal_create_64x2x2x256", || {
        black_box(t.to_literal().unwrap());
    });
    let shape = [batch, 2, 2, 256];
    let flat = vec![0.5f32; batch * elems];
    b.case("literal_from_slice_64x2x2x256", || {
        black_box(literal_from_f32_slice(&shape, &flat).unwrap());
    });

    // ---- end-to-end train step (needs artifacts) ------------------------
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        let rt = Runtime::open(&dir).expect("runtime");
        let ds = Dataset::load(rt.manifest()).expect("dataset");
        let cfg = CLConfig { l: 13, n_lr: 256, epochs: 1, ..Default::default() };
        let mut session = Session::new(&rt, &ds, cfg).expect("session");
        let mut quick = tinycl::util::bench::Bench::quick("hot_path_e2e");
        quick.case("run_event_60imgs_l13", || {
            black_box(session.run_event(&ds, 5, 0).unwrap());
        });
        quick.case("evaluate_1200imgs_cached", || {
            black_box(session.evaluate(&ds).unwrap());
        });
        quick.finish();
    } else {
        eprintln!("(skipping e2e cases: no artifacts — run `make artifacts`)");
    }

    b.finish();
}
