//! Bench target for Fig. 8: (a) the native kernel engine vs the naive
//! triple-loop baseline on the paper's own layer geometries (the §Perf
//! before/after numbers recorded in BENCH_kernels.json), (b) the
//! simulator's single-tile model evaluation itself (so design-space
//! sweeps stay interactive), and (c) prints the Fig. 8 MAC/cyc grid as a
//! side effect — the "regenerate the paper table" entry point for
//! `cargo bench`.

use tinycl::harness::systems;
use tinycl::kernels::{
    self, conv3x3_fw, default_engine, im2col3x3, matmul_fw_naive, Engine,
};
use tinycl::models::LayerKind;
use tinycl::simulator::kernels::{tile_macs_per_cyc, Pass};
use tinycl::simulator::targets::vega;
use tinycl::util::bench::{black_box, Bench};
use tinycl::util::rng::Rng;

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn main() {
    let v = vega();
    let mut b = Bench::new("fig8_kernels");

    // ---- native engine vs naive baseline --------------------------------
    // The largest matmul FW case in the Fig. 8 grid: PW layer #22
    // (8x8x512 -> 512) at batch 8 => [512, 512] x [512, 512].
    let mut rng = Rng::new(2);
    let (m, k, n) = (512usize, 512, 512);
    let x = randv(&mut rng, m * k);
    let w = randv(&mut rng, k * n);
    let mut out = vec![0f32; m * n];

    b.case("matmul_fw_pw22_512cubed_naive", || {
        black_box(matmul_fw_naive(&x, &w, m, k, n));
    });
    let single = Engine::with_threads(1);
    b.case("matmul_fw_pw22_512cubed_blocked_1thread", || {
        single.matmul_fw_into(&x, &w, m, k, n, &mut out);
        black_box(&out);
    });
    let auto = default_engine();
    b.case(
        &format!("matmul_fw_pw22_512cubed_blocked_{}threads", auto.threads),
        || {
            auto.matmul_fw_into(&x, &w, m, k, n, &mut out);
            black_box(&out);
        },
    );

    // the same geometry through the true-INT8 core: u8 codes x i8 codes
    // with i32 accumulation, pair-interleaved i16 panels (two MACs per
    // i32 lane) — the frozen stage's GEMM since the INT8 pipeline
    let xq: Vec<u8> = (0..m * k).map(|i| (i % 251) as u8).collect();
    let wq: Vec<i8> = (0..k * n).map(|i| (i % 253) as i8).collect();
    let mut oi = vec![0i32; m * n];
    b.case("matmul_fw_i8_pw22_512cubed_1thread", || {
        single.matmul_fw_i8_into(&xq, &wq, -3, m, k, n, &mut oi);
        black_box(&oi);
    });
    b.case(
        &format!("matmul_fw_i8_pw22_512cubed_{}threads", auto.threads),
        || {
            auto.matmul_fw_i8_into(&xq, &wq, -3, m, k, n, &mut oi);
            black_box(&oi);
        },
    );

    // backward passes through the same packed core (transposed views)
    let g = randv(&mut rng, m * n);
    let mut dx = vec![0f32; m * k];
    b.case("matmul_bw_err_pw22_naive", || {
        black_box(kernels::matmul_bw_err_naive(&g, &w, m, k, n));
    });
    b.case("matmul_bw_err_pw22_blocked", || {
        auto.matmul_bw_err_into(&g, &w, m, k, n, &mut dx);
        black_box(&dx);
    });
    let mut dw = vec![0f32; k * n];
    b.case("matmul_bw_grad_pw22_naive", || {
        black_box(kernels::matmul_bw_grad_naive(&x, &g, m, k, n));
    });
    b.case("matmul_bw_grad_pw22_blocked", || {
        auto.matmul_bw_grad_into(&x, &g, m, k, n, &mut dw);
        black_box(&dw);
    });

    // the stem conv: materialized im2col + naive matmul vs the fused
    // im2col-into-packed-panel path
    let (cb, ch, cw, cc, cout, stride) = (2usize, 32, 32, 16, 32, 1);
    let cx = randv(&mut rng, cb * ch * cw * cc);
    let cwm = randv(&mut rng, 9 * cc * cout);
    b.case("conv3x3_im2col_then_naive", || {
        let cols = im2col3x3(&cx, cb, ch, cw, cc, stride);
        black_box(matmul_fw_naive(&cols, &cwm, cols.len() / (9 * cc), 9 * cc, cout));
    });
    b.case("conv3x3_fused_blocked", || {
        black_box(conv3x3_fw(&cx, &cwm, cb, ch, cw, cc, stride, cout));
    });
    // the same conv and a depthwise layer on the integer path (u8 codes,
    // i8 levels) — the frozen stage's non-GEMM kernels
    let cxq: Vec<u8> = (0..cb * ch * cw * cc).map(|i| (i % 251) as u8).collect();
    let cwq: Vec<i8> = (0..9 * cc * cout).map(|i| (i % 253) as i8).collect();
    b.case("conv3x3_fused_i8", || {
        black_box(kernels::conv3x3_fw_i8(&cxq, &cwq, -5, cb, ch, cw, cc, stride, cout));
    });
    let (db, dh, dc) = (8usize, 8, 128);
    let dxq: Vec<u8> = (0..db * dh * dh * dc).map(|i| (i % 249) as u8).collect();
    let dkq: Vec<i8> = (0..9 * dc).map(|i| (i % 247) as i8).collect();
    b.case("depthwise_8x8x128_i8", || {
        black_box(kernels::depthwise_fw_i8(&dxq, &dkq, -7, db, dh, dh, dc, 1));
    });

    // ---- single-tile cycle model ----------------------------------------
    b.case("tile_model_pw_fw", || {
        black_box(tile_macs_per_cyc(&v, 8, LayerKind::PointWise, Pass::Fw, 512, false));
    });
    b.case("tile_model_dw_all_passes", || {
        for pass in Pass::all() {
            black_box(tile_macs_per_cyc(&v, 8, LayerKind::DepthWise, pass, 9, true));
        }
    });
    b.case("fig8_full_grid", || {
        black_box(systems::fig8());
    });
    b.finish();

    // regenerate the paper artifact
    let _ = systems::run("fig8");
}
