//! Bench target for Fig. 8: measures (a) the simulator's single-tile
//! model evaluation itself (so design-space sweeps stay interactive) and
//! (b) prints the Fig. 8 MAC/cyc grid as a side effect — this is the
//! "regenerate the paper table" entry point for `cargo bench`.

use tinycl::harness::systems;
use tinycl::models::LayerKind;
use tinycl::simulator::kernels::{tile_macs_per_cyc, Pass};
use tinycl::simulator::targets::vega;
use tinycl::util::bench::{black_box, Bench};

fn main() {
    let v = vega();
    let mut b = Bench::new("fig8_kernels");

    b.case("tile_model_pw_fw", || {
        black_box(tile_macs_per_cyc(&v, 8, LayerKind::PointWise, Pass::Fw, 512, false));
    });
    b.case("tile_model_dw_all_passes", || {
        for pass in Pass::all() {
            black_box(tile_macs_per_cyc(&v, 8, LayerKind::DepthWise, pass, 9, true));
        }
    });
    b.case("fig8_full_grid", || {
        black_box(systems::fig8());
    });
    b.finish();

    // regenerate the paper artifact
    systems::run("fig8");
}
