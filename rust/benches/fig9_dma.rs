//! Bench target for Fig. 9: times the tiled-training roll-up (tile solver
//! + DMA overlap model over the whole adaptive stage) and regenerates the
//! Fig. 9 bandwidth-sweep table.

use tinycl::harness::systems;
use tinycl::models::mobilenet_v1_128;
use tinycl::simulator::executor::adaptive_macs_per_cyc;
use tinycl::simulator::targets::{vega, HwConfig};
use tinycl::util::bench::{black_box, Bench};

fn main() {
    let v = vega();
    let net = mobilenet_v1_128();
    let mut b = Bench::new("fig9_dma");

    b.case("adaptive_rollup_l20_128k", || {
        black_box(adaptive_macs_per_cyc(&v, &v.default_hw, &net, 20, 128));
    });
    b.case("adaptive_rollup_low_bw", || {
        let hw = HwConfig {
            dma_read_bits_per_cyc: 8.0,
            dma_write_bits_per_cyc: 8.0,
            full_duplex: false,
            ..v.default_hw
        };
        black_box(adaptive_macs_per_cyc(&v, &hw, &net, 20, 128));
    });
    b.case("fig9_full_grid", || {
        black_box(systems::fig9());
    });
    b.finish();

    let _ = systems::run("fig9");
}
