//! Bench target for Table IV: times the event-latency roll-up for every
//! LR layer on both targets and regenerates the Table IV comparison.

use tinycl::harness::systems;
use tinycl::models::mobilenet_v1_128;
use tinycl::simulator::executor::{event_seconds, EventSpec};
use tinycl::simulator::targets::{stm32l4, vega};
use tinycl::util::bench::{black_box, Bench};

fn main() {
    let v = vega();
    let s = stm32l4();
    let net = mobilenet_v1_128();
    let ev = EventSpec::paper();
    let mut b = Bench::new("tab4_latency");

    b.case("event_rollup_vega_all_layers", || {
        for l in 20..=27 {
            black_box(event_seconds(&v, &v.default_hw, &net, l, &ev));
        }
    });
    b.case("event_rollup_stm32_all_layers", || {
        for l in 20..=27 {
            black_box(event_seconds(&s, &s.default_hw, &net, l, &ev));
        }
    });
    b.case("tab4_full_table", || {
        black_box(systems::tab4());
    });
    b.finish();

    let _ = systems::run("tab4");
    let _ = systems::run("fig10");
}
