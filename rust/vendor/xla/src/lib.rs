//! Host-side stand-in for the `xla` PJRT bindings.
//!
//! The build environment has no `xla_extension` C library, so this crate
//! keeps the workspace compiling and the host-side data plumbing fully
//! functional while making the device plane an explicit, well-reported
//! runtime error:
//!
//! - [`Literal`] is a real host tensor container (typed shape + bytes +
//!   tuples) — creation, round-tripping, `to_vec`, `scalar`/`vec1` all
//!   behave exactly like the real bindings;
//! - [`PjRtClient::compile`] / [`PjRtLoadedExecutable::execute`] return a
//!   descriptive error: executing AOT HLO modules requires the real PJRT
//!   runtime, which this build intentionally omits.
//!
//! Call sites that need actual module execution (the trainer hot loop,
//! the accuracy harness) already self-skip when `artifacts/` is missing,
//! so the full test suite runs green on top of this stub.

use std::fmt;

/// Error type of the stubbed bindings (the real crate's `Error` is also a
/// `std::error::Error`, which is what `?`-conversion into `anyhow` needs).
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(XlaError(msg.into()))
}

const NO_PJRT: &str = "PJRT is unavailable in this build (in-tree `xla` stub): \
     host literals work, but compiling/executing HLO modules requires the \
     real xla_extension runtime";

/// Element dtypes the workspace traffics in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U8,
    F64,
}

impl ElementType {
    pub fn byte_size(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
            ElementType::U8 => 1,
            ElementType::F64 => 8,
        }
    }
}

/// Rust scalar types storable in a [`Literal`].
pub trait NativeType: Copy + 'static {
    const TY: ElementType;
    fn append_bytes(xs: &[Self], out: &mut Vec<u8>);
    fn read_bytes(bytes: &[u8]) -> Vec<Self>;
}

macro_rules! native {
    ($t:ty, $ty:expr, $w:expr) => {
        impl NativeType for $t {
            const TY: ElementType = $ty;
            fn append_bytes(xs: &[Self], out: &mut Vec<u8>) {
                for x in xs {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            fn read_bytes(bytes: &[u8]) -> Vec<Self> {
                bytes
                    .chunks_exact($w)
                    .map(|c| {
                        let mut b = [0u8; $w];
                        b.copy_from_slice(c);
                        <$t>::from_le_bytes(b)
                    })
                    .collect()
            }
        }
    };
}

native!(f32, ElementType::F32, 4);
native!(i32, ElementType::S32, 4);
native!(f64, ElementType::F64, 8);
native!(u8, ElementType::U8, 1);

/// Array shape view returned by [`Literal::array_shape`].
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }
}

/// A host tensor (or tuple of tensors): the unit of data exchanged with
/// the runtime.
#[derive(Clone, Debug, PartialEq)]
pub enum Literal {
    Array { ty: ElementType, dims: Vec<usize>, data: Vec<u8> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Build an array literal from raw little-endian bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product();
        if n * ty.byte_size() != data.len() {
            return err(format!(
                "shape {:?} of {:?} needs {} bytes, got {}",
                dims,
                ty,
                n * ty.byte_size(),
                data.len()
            ));
        }
        Ok(Literal::Array { ty, dims: dims.to_vec(), data: data.to_vec() })
    }

    /// Rank-1 literal from a scalar slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        let mut data = Vec::with_capacity(v.len() * T::TY.byte_size());
        T::append_bytes(v, &mut data);
        Literal::Array { ty: T::TY, dims: vec![v.len()], data }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        let mut data = Vec::with_capacity(T::TY.byte_size());
        T::append_bytes(&[v], &mut data);
        Literal::Array { ty: T::TY, dims: Vec::new(), data }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { ty, dims, .. } => Ok(ArrayShape {
                dims: dims.iter().map(|&d| d as i64).collect(),
                ty: *ty,
            }),
            Literal::Tuple(_) => err("array_shape on a tuple literal"),
        }
    }

    pub fn element_count(&self) -> usize {
        match self {
            Literal::Array { dims, .. } => dims.iter().product(),
            Literal::Tuple(parts) => parts.iter().map(|p| p.element_count()).sum(),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { ty, data, .. } => {
                if *ty != T::TY {
                    return err(format!("to_vec dtype mismatch: literal {ty:?} vs {:?}", T::TY));
                }
                Ok(T::read_bytes(data))
            }
            Literal::Tuple(_) => err("to_vec on a tuple literal"),
        }
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        match self {
            Literal::Array { ty, data, .. } => {
                if *ty != T::TY {
                    return err(format!(
                        "get_first_element dtype mismatch: literal {ty:?} vs {:?}",
                        T::TY
                    ));
                }
                let w = T::TY.byte_size();
                if data.len() < w {
                    return err("get_first_element on an empty literal");
                }
                Ok(T::read_bytes(&data[..w])[0])
            }
            Literal::Tuple(_) => err("get_first_element on a tuple literal"),
        }
    }

    /// Decompose a tuple literal; a plain array decomposes to itself.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts),
            arr => Ok(vec![arr]),
        }
    }
}

/// Parsed HLO-text module (the stub only retains the source text).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        match std::fs::read_to_string(path) {
            Ok(text) => Ok(HloModuleProto { text }),
            Err(e) => err(format!("reading HLO text {path}: {e}")),
        }
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer (stub: a host literal in disguise).
#[derive(Clone)]
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

/// Marker for the input flavors `execute` accepts.
pub trait ExecuteInput {}
impl ExecuteInput for Literal {}
impl<'a> ExecuteInput for &'a Literal {}
impl<'a> ExecuteInput for &'a PjRtBuffer {}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: ExecuteInput>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        err(NO_PJRT)
    }

    pub fn execute_b<T: ExecuteInput>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        err(NO_PJRT)
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "host (xla stub, no PJRT)".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        err(NO_PJRT)
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { lit: lit.clone() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let xs = [1.0f32, -2.5, 3.25];
        let mut bytes = Vec::new();
        f32::append_bytes(&xs, &mut bytes);
        let lit = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes)
            .unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), xs.to_vec());
        assert_eq!(lit.element_count(), 3);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[3i64]);
    }

    #[test]
    fn vec1_and_scalar() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
        assert!(l.to_vec::<f32>().is_err(), "dtype mismatch must error");
        let s = Literal::scalar(0.5f32);
        assert_eq!(s.element_count(), 1);
        assert_eq!(s.get_first_element::<f32>().unwrap(), 0.5);
    }

    #[test]
    fn tuple_decomposition() {
        let t = Literal::Tuple(vec![Literal::scalar(1.0f32), Literal::vec1(&[1i32])]);
        assert_eq!(t.element_count(), 2);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        let arr = Literal::scalar(2.0f32);
        assert_eq!(arr.clone().to_tuple().unwrap(), vec![arr]);
    }

    #[test]
    fn shape_size_checked() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &[0; 15])
            .is_err());
    }

    #[test]
    fn execute_reports_missing_pjrt() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let comp = XlaComputation::from_proto(&HloModuleProto { text: String::new() });
        let e = client.compile(&comp).unwrap_err();
        assert!(e.to_string().contains("PJRT"));
    }
}
