//! Minimal, API-compatible stand-in for the `anyhow` crate.
//!
//! The build environment is fully offline, so the usual ecosystem crates
//! are replaced by small in-tree equivalents (see `tinycl::util`). This
//! shim covers exactly the surface the workspace uses: [`Error`],
//! [`Result`], the [`Context`] extension trait and the `anyhow!` /
//! `bail!` / `ensure!` macros. Error values carry a context chain and the
//! original source error; `{:?}` renders the anyhow-style
//! "Caused by:" report, which is what `fn main() -> Result<()>` prints.

use std::error::Error as StdError;
use std::fmt;

/// An error with a chain of human-readable context frames.
///
/// Like the real `anyhow::Error`, this type deliberately does NOT
/// implement `std::error::Error` — that keeps the blanket
/// `From<E: std::error::Error>` conversion (which powers `?`) coherent.
pub struct Error {
    /// innermost message (the root cause rendered at capture time)
    root: String,
    /// context frames, innermost first
    ctx: Vec<String>,
    /// original source, kept for downcasting-style inspection in Debug
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a displayable message (what `anyhow!` builds).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { root: message.to_string(), ctx: Vec::new(), source: None }
    }

    /// Wrap with an outer context frame (most recent shown first).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.ctx.push(context.to_string());
        self
    }

    /// The outermost message — what `Display` shows.
    pub fn top_message(&self) -> &str {
        self.ctx.last().map(|s| s.as_str()).unwrap_or(&self.root)
    }

    /// Root cause message (innermost frame).
    pub fn root_cause(&self) -> &str {
        &self.root
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.top_message())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.top_message())?;
        let inner: Vec<&str> = self
            .ctx
            .iter()
            .rev()
            .skip(1)
            .map(|s| s.as_str())
            .chain(std::iter::once(self.root.as_str()))
            .collect();
        // when there is no context, `root` IS the top message — no chain
        if !self.ctx.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for frame in inner {
                write!(f, "\n    {frame}")?;
            }
        }
        let _ = &self.source; // retained for parity; not separately printed
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { root: e.to_string(), ctx: Vec::new(), source: Some(Box::new(e)) }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let v = "nope".parse::<u32>()?;
            Ok(v)
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("invalid digit"), "{e}");
    }

    #[test]
    fn context_chains_and_debug_report() {
        let e: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = e
            .context("reading manifest")
            .context("opening runtime")
            .unwrap_err();
        assert_eq!(e.to_string(), "opening runtime");
        let report = format!("{e:?}");
        assert!(report.contains("opening runtime"));
        assert!(report.contains("Caused by:"));
        assert!(report.contains("reading manifest"));
        assert!(report.contains("file missing"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing key '{}'", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing key 'x'");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Err(anyhow!("fallthrough {}", x))
        }
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(f(1).unwrap_err().to_string(), "fallthrough 1");
    }

    #[test]
    fn ensure_without_message() {
        fn f(x: usize) -> Result<()> {
            ensure!(x % 2 == 0);
            Ok(())
        }
        assert!(f(2).is_ok());
        assert!(f(3).unwrap_err().to_string().contains("condition failed"));
    }
}
