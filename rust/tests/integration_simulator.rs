//! Integration: the simulator substrate against the paper's published
//! numbers (DESIGN.md §7 anchors) and cross-cutting invariants. These are
//! artifact-free (pure model) and always run.

use tinycl::models::{memory, mobilenet_v1_128};
use tinycl::simulator::executor::{
    adaptive_event_cycles, adaptive_macs_per_cyc, event_seconds, EventSpec,
};
use tinycl::simulator::kernels::{tile_macs_per_cyc, Pass};
use tinycl::simulator::targets::{stm32l4, vega, HwConfig};
use tinycl::simulator::{energy, tiling};
use tinycl::util::prop;

#[test]
fn table4_vega_adaptive_latencies_match_paper_magnitudes() {
    // paper Table IV (VEGA adaptive seconds): l=20: 2.49e3, l=23: 877,
    // l=25: 401, l=27: 2.07. Require same order of magnitude (0.4x..2.5x).
    let v = vega();
    let net = mobilenet_v1_128();
    let ev = EventSpec::paper();
    let expect = [(20usize, 2490.0), (23, 877.0), (25, 401.0), (27, 2.07)];
    for (l, paper) in expect {
        let ours = v.seconds(adaptive_event_cycles(&v, &v.default_hw, &net, l, &ev));
        let ratio = ours / paper;
        assert!(
            (0.3..3.0).contains(&ratio),
            "l={l}: ours {ours:.1}s vs paper {paper}s (ratio {ratio:.2})"
        );
    }
}

#[test]
fn table4_stm32_total_matches_paper_magnitudes() {
    // paper: l=23 on STM32L4 ~ 5.86e4 s, l=27 ~ 139 s
    let s = stm32l4();
    let net = mobilenet_v1_128();
    let ev = EventSpec::paper();
    for (l, paper) in [(23usize, 5.86e4), (27, 139.0)] {
        let ours = event_seconds(&s, &s.default_hw, &net, l, &ev);
        let ratio = ours / paper;
        assert!(
            (0.3..3.0).contains(&ratio),
            "l={l}: ours {ours:.0}s vs paper {paper}s"
        );
    }
}

#[test]
fn average_speedup_near_65x() {
    let v = vega();
    let s = stm32l4();
    let net = mobilenet_v1_128();
    let ev = EventSpec::paper();
    let mut ratios = Vec::new();
    for l in 20..=26 {
        let tv = event_seconds(&v, &v.default_hw, &net, l, &ev);
        let ts = event_seconds(&s, &s.default_hw, &net, l, &ev);
        ratios.push(ts / tv);
    }
    let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
    assert!(
        (50.0..90.0).contains(&avg),
        "average speed-up {avg:.1} (paper: 65x), per-l {ratios:?}"
    );
}

#[test]
fn energy_efficiency_near_37x() {
    let v = vega();
    let s = stm32l4();
    let net = mobilenet_v1_128();
    let ev = EventSpec::paper();
    let mut ratios = Vec::new();
    for l in 20..=26 {
        let ev_j = v.energy_j(event_seconds(&v, &v.default_hw, &net, l, &ev));
        let es_j = s.energy_j(event_seconds(&s, &s.default_hw, &net, l, &ev));
        ratios.push(es_j / ev_j);
    }
    let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
    assert!(
        (22.0..55.0).contains(&avg),
        "average energy gain {avg:.1} (paper: 37x)"
    );
}

#[test]
fn fig8_peak_and_orderings() {
    let v = vega();
    // peak PW FW @8 cores/512kB-tile ~ 1.91 MAC/cyc
    let peak =
        tile_macs_per_cyc(&v, 8, tinycl::models::LayerKind::PointWise, Pass::Fw, 2048, false);
    assert!((peak - 1.91).abs() < 0.2, "peak {peak}");
    // orderings: FW > BW-ERR > BW-GRAD for every kind and L1
    for kind in [tinycl::models::LayerKind::PointWise, tinycl::models::LayerKind::DepthWise] {
        for k in [512usize, 1024, 2048] {
            let fw = tile_macs_per_cyc(&v, 8, kind, Pass::Fw, k, false);
            let be = tile_macs_per_cyc(&v, 8, kind, Pass::BwErr, k, false);
            let bg = tile_macs_per_cyc(&v, 8, kind, Pass::BwGrad, k, false);
            assert!(fw > be && be > bg, "{kind:?} k={k}: {fw} {be} {bg}");
        }
    }
}

#[test]
fn fig9_sweet_spot_structure() {
    // paper: sweet spots at 16/32/64 bit/cyc for 2/4/8 cores @128 kB L1
    let v = vega();
    let net = mobilenet_v1_128();
    let rate = |cores: usize, bw: f64| {
        let hw = HwConfig {
            cores,
            l1_bytes: 128 * 1024,
            dma_read_bits_per_cyc: bw,
            dma_write_bits_per_cyc: bw,
            full_duplex: false,
        };
        adaptive_macs_per_cyc(&v, &hw, &net, 20, 128)
    };
    for (cores, sweet_bw) in [(2usize, 16.0), (4, 32.0), (8, 64.0)] {
        let at_sweet = rate(cores, sweet_bw);
        let at_plateau = rate(cores, 256.0);
        assert!(
            at_sweet > 0.85 * at_plateau,
            "{cores} cores: {sweet_bw} bit/cyc should be near the plateau \
             ({at_sweet:.3} vs {at_plateau:.3})"
        );
        let below = rate(cores, sweet_bw / 2.0);
        assert!(
            below < 0.97 * at_sweet,
            "{cores} cores: halving bw below the sweet spot should hurt \
             ({below:.3} vs {at_sweet:.3})"
        );
    }
}

#[test]
fn fig10_lifetime_anchors() {
    // paper: retraining only the last layer at max rate -> ~175 h on VEGA
    // vs ~10 h on STM32L4; 20x at equal rates
    let v = vega();
    let s = stm32l4();
    let net = mobilenet_v1_128();
    let ev = EventSpec::paper();
    let max_rate_v = energy::max_rate_per_hour(&v, &v.default_hw, &net, 27, &ev);
    let life_v = energy::lifetime_hours(&v, &v.default_hw, &net, 27, &ev, max_rate_v).unwrap();
    // at max duty cycle, lifetime = capacity / power
    let expect = energy::battery_capacity_j() / v.power_w / 3600.0;
    assert!((life_v - expect).abs() / expect < 0.01);
    assert!(
        (100.0..400.0).contains(&life_v),
        "VEGA max-duty lifetime {life_v:.0} h (paper ~175-200 h)"
    );
    let life_s = energy::lifetime_hours(&s, &s.default_hw, &net, 27, &ev, 1.0).unwrap();
    let life_v1 = energy::lifetime_hours(&v, &v.default_hw, &net, 27, &ev, 1.0).unwrap();
    assert!(life_v1 / life_s > 10.0, "equal-rate ratio {}", life_v1 / life_s);
}

#[test]
fn memory_model_paper_headline() {
    // abstract: "continual learning can be achieved in practice using less
    // than 64MB" — the high-accuracy cluster-B point
    let net = mobilenet_v1_128();
    let q = memory::QuantSetting { frozen_bits: 8, lr_bits: 8 };
    let b = memory::breakdown(&net, 23, 1500, q, 128);
    assert!(b.total_mb() < 64.0, "{} MB", b.total_mb());
    // and the FP32 baseline for the same point does NOT fit
    let fp = memory::breakdown(
        &net,
        23,
        1500,
        memory::QuantSetting { frozen_bits: 32, lr_bits: 32 },
        128,
    );
    assert!(fp.total_mb() > b.total_mb() * 1.5);
    // the LR memory itself compresses exactly 4x (the headline claim)
    assert_eq!(fp.lr_bytes, 4 * b.lr_bytes);
}

#[test]
fn fig7_cluster_a_fits_mram() {
    // §V-B: all cluster-A points (l=27) fit the 4 MB on-chip MRAM
    let net = mobilenet_v1_128();
    for (n_lr, bits) in [(1500usize, 7u8), (1500, 8), (3000, 8)] {
        let q = memory::QuantSetting { frozen_bits: 8, lr_bits: bits };
        let b = memory::breakdown(&net, 27, n_lr, q, 128);
        assert!(
            b.lr_mb() < 4.0,
            "cluster A ({n_lr} LR, {bits}b) LR mem {} MB exceeds MRAM",
            b.lr_mb()
        );
    }
}

#[test]
fn kernel_engine_is_the_simulators_executable_reference() {
    // schedule-vs-kernels tile-grid consistency, plus per-pass blocked
    // numerics == naive numerics, for the paper's LR layers
    let net = mobilenet_v1_128();
    for l in [19usize, 20, 22, 23, 26, 27] {
        for pass in Pass::all() {
            tinycl::simulator::executor::reference_check_layer(
                net.layer(l),
                pass,
                21,
                128 * 1024,
                1e-3,
            )
            .unwrap_or_else(|e| panic!("{e}"));
        }
    }
}

#[test]
fn tiling_schedules_are_feasible_everywhere() {
    prop::check("tiling feasible", 128, |rng| {
        let net = mobilenet_v1_128();
        let l = prop::int_in(rng, 0, net.layers.len() - 1);
        let batch = [1usize, 8, 21, 50, 128][rng.below(5)];
        let l1 = [32usize, 64, 128, 256, 512][rng.below(5)] * 1024;
        let pass = Pass::all()[rng.below(3)];
        let s = tiling::schedule_layer(net.layer(l), pass, batch, l1);
        assert!(s.tile_set_bytes() <= l1 / 2 || s.dims.tm == 1);
        assert_eq!(s.total_macs(), batch as u64 * net.layer(l).macs());
        assert!(s.k_inner >= 1);
    });
}

#[test]
fn simulated_latency_monotone_in_frequency_and_cores() {
    let net = mobilenet_v1_128();
    let ev = EventSpec::paper();
    let mut v_slow = vega();
    v_slow.freq_hz /= 2.0;
    let t_fast = event_seconds(&vega(), &vega().default_hw, &net, 23, &ev);
    let t_slow = event_seconds(&v_slow, &v_slow.default_hw, &net, 23, &ev);
    assert!((t_slow / t_fast - 2.0).abs() < 1e-6);

    let v = vega();
    let hw1 = HwConfig { cores: 1, ..v.default_hw };
    let t1 = event_seconds(&v, &hw1, &net, 23, &ev);
    let t8 = event_seconds(&v, &v.default_hw, &net, 23, &ev);
    assert!(t1 / t8 > 4.0, "8-core speedup {}", t1 / t8);
}
