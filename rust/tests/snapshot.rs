//! Property tests for the cold-tier tenant snapshot format: bit-exact
//! round-trips for real trained tenant state at Q ∈ {7, 8} (and the
//! FP32 baseline arm), clean rejection of corrupted / truncated /
//! wrong-version files at every byte offset, and spill→restore→train
//! equivalence through the real fleet server.

use tinycl::fleet::snapshot::{decode, encode, read_file, write_file, SNAPSHOT_MAGIC};
use tinycl::fleet::{traffic, FleetConfig, FleetServer, TenantConfig};
use tinycl::runtime::synthetic::SyntheticSpec;
use tinycl::runtime::{open_shared_synthetic, Dataset, SharedBackend};

const SPLIT: usize = 15;

fn world() -> (SharedBackend, Dataset) {
    open_shared_synthetic(&SyntheticSpec::tiny()).expect("synthetic world")
}

/// A tenant snapshot with real trained state: admitted from the
/// pre-deployment pool, driven through `events` NICv2 events, evicted.
fn trained_snapshot(
    be: &SharedBackend,
    ds: &Dataset,
    lr_bits: u8,
    seed: u64,
    events: usize,
) -> tinycl::fleet::TenantSnapshot {
    let server = FleetServer::new(be.clone(), FleetConfig::new(SPLIT)).expect("server");
    let (init_images, init_labels) = traffic::init_pool(ds);
    let id = server
        .admit(
            TenantConfig { n_lr: 96, lr_bits, seed, ..TenantConfig::default() },
            &init_images,
            &init_labels,
        )
        .expect("admit");
    if events > 0 {
        let evs =
            traffic::interleaved_nicv2(&be.manifest().protocol, ds, &[(id, seed)], events);
        server.run(evs, 2).expect("serve");
    }
    server.evict(id).expect("evict")
}

#[test]
fn trained_state_round_trips_bit_exactly_at_every_width() {
    let (be, ds) = world();
    for (lr_bits, seed, events) in [(7u8, 11u64, 2usize), (8, 12, 2), (32, 13, 1), (8, 14, 0)] {
        let snap = trained_snapshot(&be, &ds, lr_bits, seed, events);
        let bytes = encode(&snap);
        let back = decode(&bytes).unwrap_or_else(|e| panic!("Q={lr_bits}: {e:?}"));
        // byte-level fixpoint: encode(decode(encode(x))) == encode(x)
        assert_eq!(encode(&back), bytes, "Q={lr_bits} round trip drifted");
        assert_eq!(back.next_seq, snap.next_seq);
        assert_eq!(back.rng.state(), snap.rng.state());
        assert_eq!(back.replay.len(), snap.replay.len());
        assert_eq!(back.replay.bits(), snap.replay.bits());
        // params bit-exact
        for (a, b) in snap.params.tensors().iter().zip(back.params.tensors()) {
            assert_eq!(a.shape, b.shape);
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "Q={lr_bits} param drift");
            }
        }
    }
}

#[test]
fn every_single_byte_flip_is_rejected() {
    // exhaustively corrupt ONE byte at a time across the whole file:
    // decode must fail (header checks or checksum) for payload flips and
    // never panic anywhere — a snapshot is hostile input by definition
    let (be, ds) = world();
    let snap = trained_snapshot(&be, &ds, 7, 21, 1);
    let bytes = encode(&snap);
    // sample offsets across the file (exhaustive would be slow: params
    // dominate); always include the full header and the tail
    let mut offsets: Vec<usize> = (0..64.min(bytes.len())).collect();
    offsets.extend((64..bytes.len()).step_by(199));
    offsets.extend(bytes.len().saturating_sub(8)..bytes.len());
    for &i in &offsets {
        let mut bad = bytes.clone();
        bad[i] ^= 0x01;
        match decode(&bad) {
            Err(_) => {}
            Ok(back) => {
                // a flip in the length/checksum header CANNOT decode; a
                // payload flip that decodes would be a checksum break
                panic!(
                    "byte {i} flip decoded successfully (next_seq {})",
                    back.next_seq
                );
            }
        }
    }
}

#[test]
fn every_truncation_is_rejected() {
    let (be, ds) = world();
    let snap = trained_snapshot(&be, &ds, 8, 22, 1);
    let bytes = encode(&snap);
    let mut cuts: Vec<usize> = (0..32.min(bytes.len())).collect();
    cuts.extend((32..bytes.len()).step_by(157));
    cuts.push(bytes.len() - 1);
    for &keep in &cuts {
        assert!(
            decode(&bytes[..keep]).is_err(),
            "truncation to {keep}/{} bytes must fail",
            bytes.len()
        );
    }
}

#[test]
fn wrong_magic_and_future_version_rejected_with_clear_errors() {
    let (be, ds) = world();
    let snap = trained_snapshot(&be, &ds, 8, 23, 0);
    let bytes = encode(&snap);
    assert_eq!(&bytes[..4], &SNAPSHOT_MAGIC);
    let mut alien = bytes.clone();
    alien[..4].copy_from_slice(b"ELF\x7f");
    assert!(decode(&alien).unwrap_err().to_string().contains("bad magic"));
    let mut future = bytes.clone();
    future[4..8].copy_from_slice(&7u32.to_le_bytes());
    let err = decode(&future).unwrap_err().to_string();
    assert!(err.contains("unsupported snapshot version 7"), "{err}");
}

#[test]
fn spill_file_on_disk_restores_an_identical_tenant() {
    // full fleet-level disk cycle: snapshot -> write_file -> read_file
    // -> restore into a server -> continue training; compare against a
    // tenant that never left RAM, event for event
    let (be, ds) = world();
    let dir = std::env::temp_dir().join(format!("tinycl_snapshot_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("mkdir");
    let m = be.manifest();
    let run = |through_disk: bool| -> (f64, u64) {
        let server = FleetServer::new(be.clone(), FleetConfig::new(SPLIT)).expect("server");
        let (init_images, init_labels) = traffic::init_pool(&ds);
        let id = server
            .admit(
                TenantConfig { n_lr: 96, lr_bits: 8, seed: 31, ..TenantConfig::default() },
                &init_images,
                &init_labels,
            )
            .expect("admit");
        let tenants = [(id, 31u64)];
        server
            .run(traffic::nicv2_window(&m.protocol, &ds, &tenants, 0, 2), 2)
            .expect("leg 1");
        let id = if through_disk {
            let snap = server.evict(id).expect("evict");
            let path = dir.join("roundtrip.tcsn");
            let n = write_file(&path, &snap).expect("write");
            assert!(n > 0);
            let back = read_file(&path).expect("read");
            server.restore(back).expect("restore")
        } else {
            id
        };
        server
            .run(traffic::nicv2_window(&m.protocol, &ds, &tenants, 2, 2), 2)
            .expect("leg 2");
        let metrics = server.tenant_metrics(id).expect("metrics");
        (server.evaluate_tenant(&ds, id).expect("eval"), metrics.events)
    };
    let (acc_ram, ev_ram) = run(false);
    let (acc_disk, ev_disk) = run(true);
    assert_eq!(ev_ram, ev_disk, "event counts diverged across the disk cycle");
    assert_eq!(
        acc_ram, acc_disk,
        "a disk round trip mid-protocol changed the training trajectory"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn interrupted_rewrite_never_tears_the_published_snapshot() {
    // the write-tmp + fsync + atomic-rename contract: a writer killed
    // before the rename leaves only a stale `.tmp` sibling — the
    // published snapshot stays intact, and the next successful write
    // claims the sibling and atomically replaces the file
    let (be, ds) = world();
    let dir = std::env::temp_dir().join(format!("tinycl_snapshot_tmp_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("mkdir");
    let snap_a = trained_snapshot(&be, &ds, 7, 41, 1);
    let snap_b = trained_snapshot(&be, &ds, 8, 42, 2);
    let path = dir.join("tenant_3.tcsn");
    let tmp = path.with_extension("tmp");
    write_file(&path, &snap_a).expect("publish A");
    let published = std::fs::read(&path).expect("read back");
    // a writer died mid-write: half-written garbage in the tmp sibling
    std::fs::write(&tmp, &published[..published.len() / 2]).expect("plant stale tmp");
    // the published snapshot is untouched by the corpse...
    let back = read_file(&path).expect("read");
    assert_eq!(encode(&back), published, "stale tmp must not affect the published file");
    // ...and the next write claims the tmp slot and replaces the file
    write_file(&path, &snap_b).expect("publish B over a stale tmp");
    assert!(!tmp.exists(), "a successful publish consumes the tmp sibling");
    let replaced = read_file(&path).expect("read replacement");
    assert_eq!(encode(&replaced), encode(&snap_b), "second publish must win whole");
    assert_ne!(encode(&replaced), published);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn golden_fixture_decodes_and_reencodes_identically() {
    // `tools/fixtures/snapshot_v1.bin` was written by an INDEPENDENT
    // Python mirror of the format (tools/make_snapshot_fixture.py).
    // Decoding it, checking every field, and re-encoding to the same
    // bytes pins the on-disk/on-wire layout: a layout change breaks this
    // test and must bump SNAPSHOT_VERSION + regenerate the fixture.
    let bytes = std::fs::read(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../tools/fixtures/snapshot_v1.bin"
    ))
    .expect("golden fixture present (tools/make_snapshot_fixture.py)");
    let snap = decode(&bytes).expect("golden fixture decodes");

    assert_eq!(snap.cfg.l, 15);
    assert_eq!(snap.cfg.n_lr, 4);
    assert_eq!(snap.cfg.lr_bits, 8);
    assert!(snap.cfg.int8_frozen);
    assert_eq!(snap.cfg.lr.to_bits(), 0.1f32.to_bits());
    assert_eq!(snap.cfg.epochs, 2);
    assert_eq!(snap.cfg.seed, 42);
    assert_eq!(snap.next_seq, 3);
    assert_eq!(snap.metrics.events, 3);
    assert_eq!(snap.metrics.steps, 6);
    assert_eq!(snap.metrics.train_seen, 96);
    assert_eq!(snap.metrics.train_correct, 60);
    assert_eq!(snap.metrics.last_loss.to_bits(), 0.5f64.to_bits());
    assert_eq!(snap.metrics.demotions, 0);
    assert_eq!(snap.metrics.shrinks, 0);
    assert_eq!(snap.metrics.promotions, 1);
    assert_eq!(snap.metrics.spills, 2);
    assert_eq!(snap.rng.state(), [1, 2, 3, 4]);

    assert_eq!(snap.params.names(), &["head.b".to_string(), "head.w".to_string()]);
    let ts = snap.params.tensors();
    assert_eq!(ts[0].shape, vec![3]);
    assert_eq!(ts[0].data, vec![0.5, -1.25, 3.75]);
    assert_eq!(ts[1].shape, vec![2, 3]);
    assert_eq!(ts[1].data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);

    assert_eq!(snap.replay.capacity(), 4);
    assert_eq!(snap.replay.latent_elems(), 8);
    let (arena, bits, a_max) = snap.replay.packed_parts().expect("packed replay");
    assert_eq!(bits, 8);
    assert_eq!(a_max.to_bits(), 1.25f32.to_bits());
    assert_eq!(arena, (0u8..32).collect::<Vec<_>>().as_slice());
    assert_eq!(snap.replay.labels_raw(), &[0, 1, 2, -1]);
    assert_eq!(snap.replay.filled_slots_raw(), &[0, 1, 2]);

    assert_eq!(snap.parked.len(), 2);
    assert_eq!(snap.parked[0].0, 3);
    assert_eq!(snap.parked[0].2, vec![7]);
    assert_eq!(snap.parked[0].1, vec![0.25f32; 8]);
    assert_eq!(snap.parked[1].0, 5);
    assert_eq!(snap.parked[1].2, vec![8, 9]);
    assert_eq!(snap.parked[1].1, vec![0.5f32; 16]);

    // byte-for-byte fixpoint against the independently generated file
    assert_eq!(encode(&snap), bytes, "fixture re-encode drifted");
}
