//! Chaos suite: the fleet under seeded, replayable fault injection.
//!
//! Four contracts, each pinned at several fixed seeds (add one with
//! `TINYCL_CHAOS_SEED=<n>`; built-in seeds also carry fault-activity
//! assertions that an arbitrary seed cannot guarantee):
//!
//! 1. **survival** — under the full chaotic mix (torn/corrupt/failing
//!    spill I/O, stalls, budget shocks) no admitted tenant is ever lost,
//!    the byte budget is never exceeded, and the governor's incremental
//!    accounting still balances against a from-scratch recompute;
//! 2. **transparency** — under a transient-only plan (every fail streak
//!    shorter than the retry budget) the fleet's per-tenant outcomes are
//!    bit-identical to a faults-disabled run, at any worker count;
//! 3. **overload** — with shed-mode admission a stalled fleet rejects
//!    with `Rejected::Overloaded` + retry-after instead of blocking, and
//!    the degradation ladder (full -> sampled -> deferred eval) walks
//!    down under pressure and back up after `clear_pressure`;
//! 4. **shocks** — a mid-run budget shrink spills losslessly: the
//!    envelope resizes, nobody is lost, and accuracies stay bit-equal.

use std::time::Duration;

use tinycl::fleet::{
    traffic, Admission, EvalOutcome, FaultPlan, FaultSpec, FleetConfig, FleetEvent, FleetServer,
    ServiceLevel, Shock, TenantConfig,
};
use tinycl::runtime::synthetic::SyntheticSpec;
use tinycl::runtime::{open_shared_synthetic, Dataset, SharedBackend};

const SPLIT: usize = 15;
const BUILTIN_SEEDS: [u64; 3] = [7, 19, 101];

fn world() -> (SharedBackend, Dataset) {
    open_shared_synthetic(&SyntheticSpec::tiny()).expect("synthetic world")
}

/// Unique per-test spill directory (std-only; no tempfile crate).
fn spill_dir(tag: &str) -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("tinycl_chaos_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Budget that fits exactly `fit` tenants of this shape (plus change),
/// probed from the server's own accounting constants.
fn budget_for(be: &SharedBackend, n_lr: usize, lr_bits: u8, fit: usize) -> usize {
    let probe = FleetServer::new(be.clone(), FleetConfig::new(SPLIT)).expect("probe");
    let per = probe.per_tenant_bytes(n_lr, lr_bits);
    probe.shared_backbone_bytes() + per * fit + per / 2
}

/// The built-in seed set, plus an optional extra from the environment
/// (the CI chaos-smoke job drives two different values through here).
fn chaos_seeds() -> Vec<u64> {
    let mut seeds = BUILTIN_SEEDS.to_vec();
    if let Ok(raw) = std::env::var("TINYCL_CHAOS_SEED") {
        if let Ok(extra) = raw.trim().parse::<u64>() {
            if !seeds.contains(&extra) {
                seeds.push(extra);
            }
        }
    }
    seeds
}

fn admit_fleet(
    server: &FleetServer,
    ds: &Dataset,
    n: usize,
    n_lr: usize,
    lr_bits: u8,
) -> Vec<usize> {
    let (init_images, init_labels) = traffic::init_pool(ds);
    let init_latents = server.embed_images(&init_images).expect("embed");
    let mut ids = Vec::new();
    for t in 0..n {
        let tcfg =
            TenantConfig { n_lr, lr_bits, seed: 100 + t as u64, ..TenantConfig::default() };
        match server.admit_prepared(tcfg, &init_latents, &init_labels) {
            Ok(id) => ids.push(id),
            // a permanently failing admission-time spill is a legal
            // chaos outcome: the newcomer was refused CLEANLY, nobody
            // already admitted was harmed
            Err(e) => eprintln!("[chaos] admission refused: {e:#}"),
        }
    }
    ids
}

#[test]
fn chaotic_fault_plans_never_lose_a_tenant_or_break_accounting() {
    let (be, ds) = world();
    let n = 4;
    let n_lr = 128;
    for seed in chaos_seeds() {
        let dir = spill_dir(&format!("survive_{seed}"));
        let mut cfg = FleetConfig::new(SPLIT);
        cfg.governor.budget_bytes = budget_for(&be, n_lr, 7, 2);
        cfg.spill_dir = Some(dir.clone());
        cfg.faults = FaultPlan::seeded(seed);
        let server = FleetServer::new(be.clone(), cfg).expect("server");
        let ids = admit_fleet(&server, &ds, n, n_lr, 7);
        assert!(ids.len() >= 2, "seed {seed}: chaos must not refuse every admission");

        let seeded: Vec<(usize, u64)> = ids.iter().map(|&id| (id, 100 + id as u64)).collect();
        let mut events: Vec<FleetEvent> =
            traffic::interleaved_nicv2(&be.manifest().protocol, &ds, &seeded, 2);
        let submitted = events.len() as u64;
        // submit in plan-scheduled ingress bursts: each wave is its own
        // serving run, so the fleet also survives repeated spin-up/drain
        let (mut done, mut dropped, mut retries, mut degrades) = (0u64, 0u64, 0u64, 0u64);
        while !events.is_empty() {
            let k = server.config().faults.burst().unwrap_or(events.len()).min(events.len());
            let wave: Vec<FleetEvent> = events.drain(..k).collect();
            let report = server
                .run(wave, 2)
                .unwrap_or_else(|e| panic!("seed {seed}: the fleet died mid-chaos: {e:#}"));
            done += report.events;
            dropped += report.dropped;
            retries += report.robustness.io_retries;
            degrades += report.robustness.degrades;
        }
        // an event is applied, dropped (with a log line), or parked
        // behind a drop — but never double-counted or invented
        assert!(done + dropped <= submitted, "seed {seed}: {done}+{dropped} > {submitted}");
        assert!(done >= 1, "seed {seed}: chaos must not starve the whole run");

        // NO TENANT LOST: everyone admitted is resident or spilled
        let resident = server.resident_ids();
        let spilled = server.spilled_ids();
        for &id in &ids {
            assert!(
                resident.contains(&id) || spilled.contains(&id),
                "seed {seed}: tenant {id} vanished (resident {resident:?}, cold {spilled:?})"
            );
        }
        // budget holds and incremental accounting balances, even across
        // degrades, quarantines and shocks
        assert!(
            server.bytes_in_use() <= server.budget_bytes(),
            "seed {seed}: budget violated: {} > {}",
            server.bytes_in_use(),
            server.budget_bytes()
        );
        assert_eq!(server.bytes_in_use(), server.recompute_bytes(), "seed {seed}");
        assert_eq!(server.governor_tally().degrades as u64, degrades, "seed {seed}");

        // every tenant still answers (a degraded one from its rebuilt,
        // empty-replay state); a failed eval must leave it accounted
        for &id in &ids {
            match server.evaluate_tenant(&ds, id) {
                Ok(acc) => assert!((0.0..=1.0).contains(&acc), "seed {seed} tenant {id}"),
                Err(e) => {
                    eprintln!("[chaos] seed {seed}: eval of tenant {id} failed: {e:#}");
                    assert!(
                        server.resident_ids().contains(&id)
                            || server.spilled_ids().contains(&id),
                        "seed {seed}: failed eval lost tenant {id}"
                    );
                }
            }
        }
        assert_eq!(server.bytes_in_use(), server.recompute_bytes(), "seed {seed} post-eval");
        if BUILTIN_SEEDS.contains(&seed) {
            // these seeds provably inject early-op faults (see the fault
            // schedule tables in fleet::faults) — the machinery must
            // actually have been exercised, not just survived vacuously
            assert!(
                retries + degrades + dropped >= 1,
                "seed {seed}: expected observable chaos (retries/degrades/drops)"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn recovering_fault_plan_is_bit_transparent_at_any_worker_count() {
    let (be, ds) = world();
    let n = 3;
    let n_lr = 256;
    let run = |tag: &str, plan: FaultPlan, workers: usize| -> (Vec<f64>, u64, u64) {
        let dir = spill_dir(tag);
        let mut cfg = FleetConfig::new(SPLIT);
        // room for 2 of 3 tenants: real spill/restore traffic on every
        // run, so the fault plan has actual I/O to chew on
        cfg.governor.budget_bytes = budget_for(&be, n_lr, 7, 2);
        cfg.spill_dir = Some(dir.clone());
        cfg.faults = plan;
        let server = FleetServer::new(be.clone(), cfg).expect("server");
        let ids = admit_fleet(&server, &ds, n, n_lr, 7);
        assert_eq!(ids.len(), n, "transient-only faults must never refuse an admission");
        let seeded: Vec<(usize, u64)> = ids.iter().map(|&id| (id, 100 + id as u64)).collect();
        let events = traffic::interleaved_nicv2(&be.manifest().protocol, &ds, &seeded, 2);
        let report = server.run(events, workers).expect("run");
        assert_eq!(report.dropped, 0, "a recovering plan never drops an event");
        assert_eq!(report.robustness.degrades, 0, "a recovering plan never degrades");
        let accs: Vec<f64> =
            ids.iter().map(|&id| server.evaluate_tenant(&ds, id).expect("eval")).collect();
        std::fs::remove_dir_all(&dir).ok();
        (accs, report.robustness.io_retries, report.robustness.shed)
    };
    let (baseline, base_retries, base_shed) = run("base", FaultPlan::none(), 2);
    assert_eq!((base_retries, base_shed), (0, 0), "faults off => zero robustness activity");
    for seed in chaos_seeds() {
        let (solo, retries, _) =
            run(&format!("rec1_{seed}"), FaultPlan::recovering(seed), 1);
        let (wide, _, _) = run(&format!("rec3_{seed}"), FaultPlan::recovering(seed), 3);
        assert_eq!(
            solo, baseline,
            "seed {seed}: retried-but-recovered I/O must be bit-transparent (1 worker)"
        );
        assert_eq!(
            wide, baseline,
            "seed {seed}: retried-but-recovered I/O must be bit-transparent (3 workers)"
        );
        if BUILTIN_SEEDS.contains(&seed) {
            // each built-in seed faults one of the first few spill ops,
            // which the single-worker run reaches deterministically
            assert!(retries >= 1, "seed {seed}: the retry path was never exercised");
        }
    }
}

#[test]
fn overload_sheds_with_retry_after_and_the_ladder_walks_down_and_back() {
    let (be, ds) = world();
    let mut cfg = FleetConfig::new(SPLIT);
    cfg.queue_depth = 2;
    cfg.coalesce = 2;
    cfg.admission = Admission::Shed { max_wait_ms: 0 };
    // pure-stall plan: the only injected fault is a slow worker, so
    // every robustness event below is attributable to overload alone
    cfg.faults = FaultPlan::from_spec(FaultSpec {
        seed: 1,
        write_fault_p: 0.0,
        write_streak_max: 1,
        corrupt_writes: false,
        torn_writes: false,
        read_fault_p: 0.0,
        read_streak_max: 1,
        stall_p: 1.0,
        stall: Duration::from_millis(25),
        shocks: vec![],
        burst_max: 1,
        ..FaultSpec::default()
    });
    let server = FleetServer::new(be.clone(), cfg).expect("server");
    let ids = admit_fleet(&server, &ds, 2, 96, 8);
    assert_eq!(ids.len(), 2);
    let seeded: Vec<(usize, u64)> = ids.iter().map(|&id| (id, 100 + id as u64)).collect();
    let events = traffic::interleaved_nicv2(&be.manifest().protocol, &ds, &seeded, 4);
    let submitted = events.len() as u64;
    let report = server.run(events, 1).expect("run");

    // the stalled worker backs the queue up; zero-wait admission sheds
    assert!(report.robustness.shed >= 1, "expected sheds: {report:?}");
    assert_eq!(
        report.events + report.robustness.shed,
        submitted,
        "every event is either applied or explicitly shed — never silently lost"
    );
    assert_eq!(report.dropped, 0);
    let rejected = server.take_rejections();
    assert_eq!(rejected.len() as u64, report.robustness.shed);
    assert!(rejected.iter().all(|r| r.retry_after_ms() >= 1), "{rejected:?}");
    assert!(rejected.iter().all(|r| ids.contains(&r.tenant())), "{rejected:?}");
    assert!(server.take_rejections().is_empty(), "take_rejections drains");

    // 1..=6 sheds put the ladder on the middle rung: sampled eval
    assert_eq!(server.service_level(), ServiceLevel::Sampled);
    let sampled = match server.evaluate_tenant_adaptive(&ds, ids[0]).expect("adaptive") {
        EvalOutcome::Sampled(acc) => acc,
        other => panic!("expected a sampled eval under pressure, got {other:?}"),
    };
    assert!((0.0..=1.0).contains(&sampled));

    // heavy pressure: eval AND maintenance defer outright
    for _ in 0..8 {
        server.note_pressure();
    }
    assert_eq!(server.service_level(), ServiceLevel::Deferred);
    assert!(matches!(
        server.evaluate_tenant_adaptive(&ds, ids[0]).expect("adaptive"),
        EvalOutcome::Deferred
    ));
    let out = server.rebalance().expect("rebalance");
    assert!(out.deferred, "maintenance must yield to serving under heavy pressure");
    assert_eq!((out.unspilled, out.promoted), (0, 0));

    // the episode ends: full fidelity resumes, bit-equal to direct eval
    server.clear_pressure();
    assert_eq!(server.service_level(), ServiceLevel::Full);
    let full = server.evaluate_tenant(&ds, ids[0]).expect("eval");
    match server.evaluate_tenant_adaptive(&ds, ids[0]).expect("adaptive") {
        EvalOutcome::Full(acc) => assert_eq!(acc, full),
        other => panic!("expected a full eval after clear_pressure, got {other:?}"),
    }
}

#[test]
fn budget_shock_spills_losslessly_and_resizes_the_envelope() {
    let (be, ds) = world();
    let n = 3;
    let n_lr = 256;
    let run = |tag: &str, shocked: bool| -> (Vec<f64>, usize, usize) {
        let dir = spill_dir(tag);
        let mut cfg = FleetConfig::new(SPLIT);
        // roomy before the shock: all three tenants resident, no relief
        cfg.governor.budget_bytes = budget_for(&be, n_lr, 7, 4);
        cfg.spill_dir = Some(dir.clone());
        if shocked {
            // shock-only plan: spill I/O itself is clean, so every
            // relief action is attributable to the budget shrink
            cfg.faults = FaultPlan::from_spec(FaultSpec {
                seed: 3,
                write_fault_p: 0.0,
                write_streak_max: 1,
                corrupt_writes: false,
                torn_writes: false,
                read_fault_p: 0.0,
                read_streak_max: 1,
                stall_p: 0.0,
                stall: Duration::ZERO,
                shocks: vec![Shock { after_events: 2, budget_factor: 0.55 }],
                burst_max: 1,
                ..FaultSpec::default()
            });
        }
        let server = FleetServer::new(be.clone(), cfg).expect("server");
        let ids = admit_fleet(&server, &ds, n, n_lr, 7);
        assert_eq!(ids.len(), n);
        if shocked {
            assert_eq!(server.governor_tally().spills, 0, "no pressure before the shock");
        }
        let seeded: Vec<(usize, u64)> = ids.iter().map(|&id| (id, 100 + id as u64)).collect();
        let events = traffic::interleaved_nicv2(&be.manifest().protocol, &ds, &seeded, 2);
        let report = server.run(events, 2).expect("run");
        assert_eq!(report.dropped, 0, "a clean-I/O shock never drops events");
        let accs: Vec<f64> =
            ids.iter().map(|&id| server.evaluate_tenant(&ds, id).expect("eval")).collect();
        assert!(server.bytes_in_use() <= server.budget_bytes());
        assert_eq!(server.bytes_in_use(), server.recompute_bytes());
        std::fs::remove_dir_all(&dir).ok();
        (accs, server.budget_bytes(), server.governor_tally().spills)
    };
    let (baseline, base_budget, base_spills) = run("shock_base", false);
    assert_eq!(base_spills, 0, "the roomy envelope must not spill on its own");
    let (shocked, new_budget, spills) = run("shock_hit", true);
    assert!(new_budget < base_budget, "the shock must have resized the envelope");
    assert!(spills >= 1, "a 0.55x shrink must force lossless spills");
    assert_eq!(
        shocked, baseline,
        "a budget shock sheds RAM via the lossless cold tier — accuracies must be bit-equal"
    );
}
