//! Quantization edge cases and the cross-language weight-rounding
//! contract: degenerate ranges (`a_max = 0`, all-zero weights), sub-byte
//! widths (Q ∈ {6, 7}), saturating inputs, requant multiplier/shift
//! round-trip properties — proptest-style, like `rust/tests/snapshot.rs`
//! — plus the `tools/fixtures/weight_quant.json` fixture that pins the
//! round-to-nearest-half-up weight codes against
//! `python/compile/kernels/ref.py::quantize_weight`.

use tinycl::quant::{
    act_scale, dequantize_acts_into, fake_quant_weight, quantize_acts_into, quantize_weights_i8,
    requantize_relu_into, ActQuantizer, Requant,
};
use tinycl::util::json;
use tinycl::util::prop;
use tinycl::util::rng::Rng;

// ---- the cross-language fixture --------------------------------------------

fn fixture() -> json::Json {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../tools/fixtures/weight_quant.json");
    let text = std::fs::read_to_string(path).expect("weight_quant.json fixture");
    json::parse(&text).expect("fixture parses")
}

#[test]
fn weight_codes_match_the_cross_language_fixture() {
    // ONE rounding rule across the build: the fixture's codes were
    // produced by the numpy float32 replica of
    // `q = clip(floor(w/S + 1/2), lo, lo + 2^Q - 1)`; the python test
    // (`python/tests/test_quantize.py`) asserts the same file against
    // the jax implementation. The tie cases (scale exactly 1.0) make
    // the *rule* observable: half-up differs from half-to-even AND from
    // half-away-from-zero on them.
    let fx = fixture();
    for case in fx.at(&["cases"]).as_arr() {
        let name = case.at(&["name"]).as_str();
        let bits = case.at(&["bits"]).as_usize() as u8;
        let weights: Vec<f32> =
            case.at(&["weights"]).as_arr().iter().map(|v| v.as_f64() as f32).collect();
        let expect_codes: Vec<i32> =
            case.at(&["codes"]).as_arr().iter().map(|v| v.as_f64() as i32).collect();
        let expect_scale = case.at(&["scale"]).as_f64();
        let expect_lo = case.at(&["lo"]).as_f64() as i32;
        let expect_grid: Vec<f32> =
            case.at(&["grid"]).as_arr().iter().map(|v| v.as_f64() as f32).collect();

        let q = quantize_weights_i8(&weights, bits);
        let levels: Vec<i32> = q.codes.iter().map(|&c| c as i32 + q.off).collect();
        assert_eq!(levels, expect_codes, "case {name}: signed levels");
        assert_eq!(q.off - 128, expect_lo, "case {name}: lo");
        let scale_rel = ((q.scale as f64 - expect_scale) / expect_scale.max(1e-300)).abs();
        assert!(scale_rel < 1e-6, "case {name}: scale {} vs {expect_scale}", q.scale);
        for (i, (&g, &e)) in q.dequantize().iter().zip(&expect_grid).enumerate() {
            assert!(
                (g - e).abs() <= e.abs() * 1e-5 + 1e-9,
                "case {name} grid[{i}]: {g} vs {e}"
            );
        }
        // and the FP32 simulation grid is the same quantization
        assert_eq!(fake_quant_weight(&weights, bits), q.dequantize(), "case {name}");
    }
}

// ---- degenerate ranges -----------------------------------------------------

#[test]
fn a_max_zero_degenerates_cleanly() {
    // a_max = 0 must not divide by zero anywhere: the scale floors at
    // 1e-12, positive inputs saturate to the top code, zero/negative to
    // 0, and dequantization returns (finite) near-zero grid values
    for bits in [6u8, 7, 8] {
        let levels = (1u32 << bits) - 1;
        let xs = [0.0f32, 1.0, -1.0, 1e-6];
        let mut q = vec![0u8; xs.len()];
        quantize_acts_into(&xs, 0.0, bits, &mut q);
        assert_eq!(q, [0, levels as u8, 0, levels as u8], "bits={bits}");
        let mut back = vec![f32::NAN; q.len()];
        dequantize_acts_into(&q, 0.0, bits, &mut back);
        assert!(back.iter().all(|v| v.is_finite() && v.abs() < 1e-6), "bits={bits}: {back:?}");
        assert_eq!(act_scale(0.0, bits), 1e-12);
    }
    // the requant of a zero-range layer maps every accumulator to 0
    let rq = Requant::from_scale(0.0);
    let mut out = vec![1u8; 4];
    requantize_relu_into(&[i32::MAX, 1, 0, -5], rq, 8, &mut out);
    assert_eq!(out, [0, 0, 0, 0]);
    // and an all-zero weight tensor lands every code on level 0
    let q = quantize_weights_i8(&[0.0; 32], 8);
    assert!(q.dequantize().iter().all(|&v| v == 0.0));
}

#[test]
fn replay_codec_rejects_zero_range() {
    // the replay-buffer codec keeps its hard precondition: a_max must be
    // positive (a zero-range buffer would silently store garbage)
    let err = std::panic::catch_unwind(|| ActQuantizer::new(8, 0.0));
    assert!(err.is_err(), "ActQuantizer must reject a_max = 0");
}

// ---- sub-byte widths + saturation ------------------------------------------

#[test]
fn sub_byte_act_codes_agree_with_the_replay_codec() {
    // the frozen path's standalone quantizer and the replay buffer's
    // ActQuantizer implement the same eq. 2 — identical codes at every
    // width, including saturating and negative inputs. Compare against
    // the codec's BATCH path, which uses the same `x * (1/S)` reciprocal
    // form (quantize_one divides instead — a 1-ULP-different expression
    // that can land on the other side of a code boundary, so pinning it
    // bit-equal would assert an identity f32 does not guarantee).
    prop::check("act codecs agree", 96, |rng: &mut Rng| {
        let bits = prop::int_in(rng, 6, 8) as u8;
        let a_max = 0.05 + rng.f32() * 5.0;
        let codec = ActQuantizer::new(bits, a_max);
        let n = prop::int_in(rng, 1, 64);
        let xs: Vec<f32> = (0..n).map(|_| rng.f32() * a_max * 3.0 - a_max).collect();
        let mut q = vec![0u8; n];
        quantize_acts_into(&xs, a_max, bits, &mut q);
        let mut codec_q = Vec::new();
        codec.quantize(&xs, &mut codec_q);
        assert_eq!(q, codec_q, "bits={bits} a_max={a_max}");
    });
}

#[test]
fn saturating_inputs_clip_to_the_top_code_at_every_width() {
    for bits in [6u8, 7, 8] {
        let levels = ((1u32 << bits) - 1) as u8;
        let a_max = 1.25f32;
        let xs = [a_max, a_max * 1.0001, a_max * 100.0, f32::MAX];
        let mut q = vec![0u8; xs.len()];
        quantize_acts_into(&xs, a_max, bits, &mut q);
        assert!(q.iter().all(|&c| c == levels), "bits={bits}: {q:?}");
        // weight side: the +1/2 overshoot at the range top stays clipped
        let q = quantize_weights_i8(&[-1.0, 1.0], bits);
        let hi = q.codes.iter().map(|&c| c as i32 + q.off).max().unwrap();
        assert!(hi <= (q.off - 128) + (1i32 << bits) - 1, "bits={bits}");
    }
}

// ---- requant multiplier/shift round-trip -----------------------------------

#[test]
fn requant_round_trips_real_scales_within_one_code() {
    // floor(acc * s) via the 31-bit fixed-point form: never off by more
    // than one code anywhere in the code-range of products, monotone,
    // and exact on power-of-two scales
    prop::check("requant round trip", 192, |rng: &mut Rng| {
        let s = 10f64.powf(rng.f32() as f64 * 10.0 - 8.0); // 1e-8..=1e2
        let rq = Requant::from_scale(s);
        let cap = ((1e6 / s) as u64).clamp(1, 1 << 30) as usize;
        let a = rng.below(cap) as i32;
        let b = rng.below(cap) as i32;
        let real_a = (a as f64 * s).floor() as i64;
        assert!((real_a - rq.apply(a)).abs() <= 1, "s={s} acc={a}");
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        assert!(rq.apply(lo) <= rq.apply(hi), "monotone: s={s} {lo} {hi}");
    });
    for exp in -24i32..=2 {
        let s = 2f64.powi(exp);
        let rq = Requant::from_scale(s);
        for acc in [1i32, 7, 255, 65535, (1 << 30) - 1] {
            assert_eq!(rq.apply(acc), (acc as f64 * s).floor() as i64, "s=2^{exp} acc={acc}");
        }
    }
}

#[test]
fn requant_chain_reproduces_the_frozen_scale_algebra() {
    // the scales native.rs derives (S_in * S_w / S_out over act_scale)
    // requantize a known accumulator chain the way the real-number
    // algebra says: quantizing y = acc * S_in * S_w at S_out
    prop::check("requant chain", 96, |rng: &mut Rng| {
        let bits = 8u8;
        let in_a = 0.1 + rng.f32() * 4.0;
        let out_a = 0.1 + rng.f32() * 4.0;
        let w_scale = 10f32.powf(rng.f32() * 4.0 - 4.0);
        let s_in = act_scale(in_a, bits) as f64;
        let s_out = act_scale(out_a, bits) as f64;
        let rq = Requant::from_scale(s_in * w_scale as f64 / s_out);
        let acc = rng.below(1 << 20) as i32 - (1 << 10);
        let y = acc.max(0) as f64 * s_in * w_scale as f64;
        let want = (y / s_out).floor().clamp(0.0, 255.0) as i64;
        let got = rq.quantize(acc, 255) as i64;
        assert!(
            (want - got).abs() <= 1,
            "in={in_a} out={out_a} sw={w_scale} acc={acc}: {want} vs {got}"
        );
    });
}
