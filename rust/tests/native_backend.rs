//! The native backend's own test suite: finite-difference verification of
//! the fused train step, engine-vs-naive forward parity, the
//! Backend-trait conformance suite (run on native always, and on PJRT
//! when artifacts exist), and quantized-replay round-trips through full
//! learning events at Q ∈ {6, 7, 8}.

use tinycl::coordinator::{CLConfig, Session};
use tinycl::kernels::matmul_fw_naive;
use tinycl::runtime::{
    synthetic, Backend, Dataset, Manifest, NativeBackend, ParamState, Runtime,
};
use tinycl::util::rng::Rng;

fn native_env() -> (NativeBackend, Dataset) {
    let (m, ds) = synthetic::generate(&synthetic::SyntheticSpec::tiny()).expect("synthetic env");
    (NativeBackend::new(m).expect("native backend"), ds)
}

/// `&Runtime` coerces to `&dyn Backend`: the PJRT path implements the
/// same trait the coordinator consumes (compile-time conformance).
#[allow(dead_code)]
fn assert_runtime_is_a_backend(rt: &Runtime) -> &dyn Backend {
    rt
}

// ---- finite-difference gradient check of the fused train step -------------

/// Extract the gradient the SGD step applied: `(p_before - p_after) / lr`.
fn applied_grads(before: &ParamState, after: &ParamState, lr: f32) -> Vec<Vec<f32>> {
    before
        .tensors()
        .iter()
        .zip(after.tensors())
        .map(|(b, a)| {
            b.data
                .iter()
                .zip(&a.data)
                .map(|(&x, &y)| (x - y) / lr)
                .collect()
        })
        .collect()
}

fn fd_check_split(be: &NativeBackend, l: usize) {
    let m = be.manifest();
    let lelems = m.latent_info(l).unwrap().elems();
    let batch = 8;
    let mut rng = Rng::new(0xF0 + l as u64);
    let latents: Vec<f32> = (0..batch * lelems).map(|_| rng.f32() * 2.0).collect();
    let labels: Vec<i32> = (0..batch).map(|_| rng.below(m.num_classes) as i32).collect();

    let p0 = be.load_params(l).unwrap();
    let mut p1 = p0.clone();
    let lr = 1.0;
    let (loss, correct) = be.train_step(l, &mut p1, &latents, &labels, lr).unwrap();
    assert!(loss.is_finite() && loss > 0.0, "l={l}: loss {loss}");
    assert!(correct <= batch as u64);
    let grads = applied_grads(&p0, &p1, lr);

    // a handful of entries per tensor; mixed abs+rel tolerance because the
    // FD probe runs through an f32 forward with ReLU kinks
    let eps = 1e-2f32;
    for ti in 0..p0.len() {
        let n = p0.tensor(ti).elems();
        for probe in 0..4usize.min(n) {
            let i = if n <= 4 { probe } else { rng.below(n) };
            let mut pp = p0.clone();
            pp.data_mut(ti)[i] += eps;
            let mut pm = p0.clone();
            pm.data_mut(ti)[i] -= eps;
            let (lp, _) = be.loss_and_correct(l, &pp, &latents, &labels).unwrap();
            let (lm, _) = be.loss_and_correct(l, &pm, &latents, &labels).unwrap();
            let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
            let an = grads[ti][i];
            // mixed tolerance: the FD probe runs through an f32 forward
            // with ReLU kinks, so tiny components carry ~1e-3 probe noise
            // (measured in tools/native_mirror.py) while large ones are
            // accurate to a few percent
            let tol = 3e-3 + 0.08 * fd.abs().max(an.abs());
            assert!(
                (fd - an).abs() < tol,
                "l={l} tensor {} ({}) elem {i}: fd {fd} vs analytic {an}",
                ti,
                p0.names()[ti]
            );
        }
    }
}

#[test]
fn fused_train_step_gradients_match_finite_differences() {
    let (be, _ds) = native_env();
    // l=13 exercises depthwise + pointwise + affine + pool + head;
    // l=15 the head-only path
    fd_check_split(&be, 13);
    fd_check_split(&be, 15);
}

// ---- loss decreases on a separable task -----------------------------------

#[test]
fn train_steps_reduce_loss_on_separable_batch() {
    let (be, ds) = native_env();
    let m = be.manifest();
    let l = 13;
    let lelems = m.latent_info(l).unwrap().elems();
    // one real batch: images of two distinct classes through the frozen
    // stage — separable by construction of the synthetic world
    let idx: Vec<usize> = ds
        .event_indices(5, 0)
        .into_iter()
        .take(4)
        .chain(ds.event_indices(9, 0).into_iter().take(4))
        .collect();
    let img = ds.image_elems();
    let mut images = vec![0f32; idx.len() * img];
    let mut labels = vec![0i32; idx.len()];
    for (i, &src) in idx.iter().enumerate() {
        ds.train_image_into(src, &mut images[i * img..(i + 1) * img]);
        labels[i] = ds.train_labels[src];
    }
    let mut latents = vec![0f32; idx.len() * lelems];
    be.frozen_forward(l, true, false, &images, &mut latents).unwrap();

    let mut params = be.load_params(l).unwrap();
    let mut losses = Vec::new();
    for _ in 0..10 {
        let (loss, _) = be.train_step(l, &mut params, &latents, &labels, 0.1).unwrap();
        losses.push(loss);
    }
    assert!(
        losses[9] < losses[0] * 0.9,
        "loss should fall on a separable batch: {losses:?}"
    );
    let (_, correct) = be.loss_and_correct(l, &params, &latents, &labels).unwrap();
    assert_eq!(correct, labels.len() as u64, "batch should be memorized: {losses:?}");
}

// ---- engine-vs-naive forward parity ---------------------------------------

#[test]
fn head_eval_matches_naive_matmul() {
    // at l = 15 the adaptive stage is exactly pooled-latents @ W + b, so
    // the backend's engine path must match the naive triple loop
    let (be, ds) = native_env();
    let m = be.manifest();
    let l = 15;
    let feat = m.feat_dim;
    let ncls = m.num_classes;
    let params = be.load_params(l).unwrap();
    let batch = 6;
    let img = ds.image_elems();
    let mut images = vec![0f32; batch * img];
    for i in 0..batch {
        ds.test_image_into(i, &mut images[i * img..(i + 1) * img]);
    }
    let mut latents = vec![0f32; batch * feat];
    be.frozen_forward(l, true, false, &images, &mut latents).unwrap();

    let mut logits = vec![0f32; batch * ncls];
    be.adaptive_eval(l, &params, &latents, &mut logits).unwrap();

    let head_w = &params.tensor(1).data; // layer0.b, layer0.w at l=15
    let head_b = &params.tensor(0).data;
    let naive = matmul_fw_naive(&latents, head_w, batch, feat, ncls);
    for (i, (&a, &n)) in logits.iter().zip(&naive).enumerate() {
        let expect = n + head_b[i % ncls];
        assert!(
            (a - expect).abs() < 1e-3,
            "logit {i}: engine {a} vs naive {expect}"
        );
    }
}

// ---- Backend trait conformance suite --------------------------------------

fn conformance(be: &dyn Backend, ds: &Dataset) {
    let m = be.manifest();
    assert!(!m.splits.is_empty());
    let img = ds.image_elems();
    for &l in &m.splits {
        let split = m.split(l).unwrap();
        let lelems = m.latent_info(l).unwrap().elems();

        // params match the manifest's tensor metadata
        let params = be.load_params(l).unwrap();
        assert_eq!(params.len(), split.param_tensors.len(), "l={l}");
        for (t, meta) in params.tensors().iter().zip(&split.param_tensors) {
            assert_eq!(t.shape, meta.shape, "l={l} tensor {}", meta.name);
        }

        // frozen forward: right-sized, finite latents in both modes
        let b = m.batch_new;
        let mut images = vec![0f32; b * img];
        for i in 0..b {
            ds.train_image_into(i % ds.n_train(), &mut images[i * img..(i + 1) * img]);
        }
        for int8 in [true, false] {
            let mut lat = vec![f32::NAN; b * lelems];
            be.frozen_forward(l, int8, false, &images, &mut lat).unwrap();
            assert!(lat.iter().all(|v| v.is_finite()), "l={l} int8={int8}");
            assert!(
                lat.iter().any(|&v| v != 0.0),
                "l={l} int8={int8}: all-zero latents"
            );
        }

        // train step: finite loss, bounded correct count, params change
        let bt = m.batch_train;
        let mut rng = Rng::new(l as u64);
        let latents: Vec<f32> = (0..bt * lelems).map(|_| rng.f32()).collect();
        let labels: Vec<i32> = (0..bt).map(|_| rng.below(m.num_classes) as i32).collect();
        let mut p1 = params.clone();
        let (loss, correct) = be.train_step(l, &mut p1, &latents, &labels, 0.05).unwrap();
        assert!(loss.is_finite() && loss > 0.0, "l={l}");
        assert!(correct <= bt as u64, "l={l}");
        assert!(
            params.tensors().iter().zip(p1.tensors()).any(|(a, b)| a != b),
            "l={l}: train step must update parameters"
        );

        // determinism: the same step from the same state repeats exactly
        let mut p2 = params.clone();
        let (loss2, correct2) = be.train_step(l, &mut p2, &latents, &labels, 0.05).unwrap();
        assert_eq!(loss, loss2, "l={l}: train step must be deterministic");
        assert_eq!(correct, correct2);
        for (a, b) in p1.tensors().iter().zip(p2.tensors()) {
            assert_eq!(a, b, "l={l}: updated params must be bit-identical");
        }

        // eval: right-sized finite logits
        let be_b = m.batch_eval;
        let lat_eval: Vec<f32> = (0..be_b * lelems).map(|_| rng.f32()).collect();
        let mut logits = vec![f32::NAN; be_b * m.num_classes];
        be.adaptive_eval(l, &p1, &lat_eval, &mut logits).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()), "l={l}");
    }
}

#[test]
fn backend_conformance_suite() {
    let (be, ds) = native_env();
    eprintln!("[conformance] native: {}", be.platform());
    conformance(&be, &ds);

    // the same suite runs against PJRT when artifacts are present (the
    // native arm above always runs, so this test never self-skips)
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        let rt = Runtime::open(&dir).expect("open runtime");
        let pjrt_ds = Dataset::load(Runtime::manifest(&rt)).expect("load dataset");
        eprintln!("[conformance] pjrt: {}", Backend::platform(&rt));
        conformance(&rt, &pjrt_ds);
    }
}

// ---- quantized replay round-trip through full learning events -------------

#[test]
fn replay_roundtrip_through_learning_event_q678() {
    let (be, ds) = native_env();
    let m = be.manifest();
    for bits in [6u8, 7, 8] {
        let cfg = CLConfig {
            l: 13,
            n_lr: 64,
            lr_bits: bits,
            int8_frozen: true,
            seed: bits as u64,
            ..Default::default()
        };
        let mut s = Session::new(&be, &ds, cfg).unwrap();
        let stats = s.run_event(&ds, 6, 2).unwrap();
        assert!(stats.steps > 0 && stats.mean_loss.is_finite(), "Q={bits}");

        // every stored latent must sit exactly on the UINT-Q grid of the
        // buffer's scale, and survive sampling with valid labels
        let a_max = m.latent_info(13).unwrap().a_max(true);
        let scale = a_max / ((1u32 << bits) - 1) as f32;
        let elems = s.latent_elems();
        let k = 32;
        let mut out = vec![0f32; k * elems];
        let mut labs = vec![-1i32; k];
        s.replay.sample_into(k, &mut s.rng, &mut out, &mut labs);
        assert!(
            labs.iter().all(|&l| (0..m.num_classes as i32).contains(&l)),
            "Q={bits}: sampled labels {labs:?}"
        );
        for (i, &v) in out.iter().enumerate() {
            assert!(v >= 0.0 && v <= a_max + scale, "Q={bits} elem {i}: {v}");
            let code = v / scale;
            assert!(
                (code - code.round()).abs() < 1e-3,
                "Q={bits} elem {i}: {v} is off the quantization grid (code {code})"
            );
        }
    }
}
