//! Integration: the replay/batching hot path performs ZERO heap
//! allocations at steady state (§Perf L3). A counting global allocator
//! wraps the system one; after warm-up, thousands of sample/compose/
//! insert operations must not allocate once.
//!
//! This file holds a single test on purpose: the allocation counter is
//! per-binary, and a lone test keeps the measurement window free of
//! concurrent harness traffic.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use tinycl::coordinator::batcher::Batcher;
use tinycl::coordinator::replay::ReplayBuffer;
use tinycl::util::rng::Rng;

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_replay_and_compose_do_not_allocate() {
    let elems = 1024; // latent size at split 13
    let n_lr = 128;
    let (batch, batch_new) = (64, 8);

    for bits in [8u8, 7, 6] {
        let mut rng = Rng::new(7);
        let latents: Vec<f32> =
            (0..n_lr * elems).map(|i| (i % 255) as f32 / 255.0).collect();
        let labels: Vec<i32> = (0..n_lr as i32).map(|i| i % 10).collect();
        let mut buf = ReplayBuffer::new_packed(n_lr, elems, bits, 1.0);
        buf.init_fill(&latents, &labels, &mut rng);

        let mut batcher = Batcher::new(batch, batch_new, elems);
        let new_lat: Vec<f32> = (0..32 * elems).map(|i| (i % 128) as f32 / 128.0).collect();
        let new_lab: Vec<i32> = vec![5; 32];
        let pick: Vec<usize> = (0..batch_new).collect();
        let mut out = vec![0f32; 56 * elems];
        let mut labs = vec![0i32; 56];

        // warm up every code path once (scratch buffers reach capacity)
        buf.sample_into(56, &mut rng, &mut out, &mut labs);
        buf.write_slot(3, &latents[..elems], 5);
        batcher.compose(&new_lat, &new_lab, &pick, &buf, &mut rng);
        batcher.compose_replay_only(&buf, &mut rng);

        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        for step in 0..500 {
            buf.sample_into(56, &mut rng, &mut out, &mut labs);
            buf.write_slot(step % n_lr, &latents[..elems], 5);
            batcher.compose(&new_lat, &new_lab, &pick, &buf, &mut rng);
            batcher.compose_replay_only(&buf, &mut rng);
        }
        let after = ALLOC_CALLS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "bits={bits}: steady-state hot path allocated {} times",
            after - before
        );
    }
}
