//! Sharded-fleet integration tests: the redesigned client API
//! ([`FleetApi`] local + remote), real TCP loopback serving, live
//! snapshot migration, and the shed/backoff contract.
//!
//! The determinism spine: a tenant that is drained off one shard and
//! restored onto another must train on from that point bit-identically
//! to a tenant that never moved — and a 1-shard `LocalClient` must
//! reproduce the single-session `run_protocol` bit-for-bit. Both are
//! pinned here.

use std::sync::Arc;
use std::time::Duration;

use tinycl::coordinator::{run_protocol, CLConfig, RunOptions};
use tinycl::fleet::{
    submit_with_backoff, traffic, FleetApi, FleetClient, FleetConfig, FleetError, FleetEvent,
    FleetServer, LocalClient, RetryPolicy, TenantConfig, TenantId,
};
use tinycl::net::ShardServer;
use tinycl::runtime::synthetic::SyntheticSpec;
use tinycl::runtime::{open_shared_synthetic, Dataset, SharedBackend};

const SPLIT: usize = 15;

fn world() -> (SharedBackend, Dataset) {
    open_shared_synthetic(&SyntheticSpec::tiny()).expect("synthetic world")
}

/// The `[skip, skip + take)` window of one tenant's canonical NICv2
/// schedule, addressed to slot `id`.
fn leg(
    be: &SharedBackend,
    ds: &Dataset,
    id: TenantId,
    seed: u64,
    skip: usize,
    take: usize,
) -> Vec<FleetEvent> {
    traffic::nicv2_window(&be.manifest().protocol, ds, &[(id, seed)], skip, take)
}

// ---------------------------------------------------------------------------
// Local client: N=1 parity with the single-session path
// ---------------------------------------------------------------------------

#[test]
fn local_client_n1_reproduces_run_protocol_bit_for_bit() {
    let (be, ds) = world();
    let events = 3;
    let cl = CLConfig {
        l: SPLIT,
        n_lr: 128,
        lr_bits: 8,
        int8_frozen: true,
        lr: 0.1,
        epochs: 2,
        seed: 100,
    };
    let solo = run_protocol(
        &*be,
        &ds,
        cl,
        RunOptions { eval_every: 0, max_events: events, verbose: false },
    )
    .expect("run_protocol");

    // the whole new surface: builder -> server -> LocalClient verbs
    let cfg = FleetConfig::builder(SPLIT).max_tenants(4).build().expect("config");
    let server = Arc::new(FleetServer::new(be.clone(), cfg).expect("server"));
    let ds = Arc::new(ds);
    let mut client = LocalClient::new(server, ds.clone());
    client.serve(2).expect("serve");
    client
        .admit(7, TenantConfig { n_lr: 128, seed: 100, ..TenantConfig::default() })
        .expect("admit");
    let slot = client.local_id(7).expect("slot");
    for ev in leg(&be, &ds, slot, 100, 0, events) {
        client.submit(7, &ev.images, &ev.labels).expect("submit");
    }
    let acc = client.evaluate(7).expect("eval");
    assert_eq!(
        acc, solo.final_acc,
        "LocalClient N=1 must be bit-identical to the single-session path"
    );
    let report = client.finish().expect("finish");
    assert_eq!(report.events, events as u64);
    assert_eq!(report.dropped, 0);
}

#[test]
fn local_client_rejects_unknown_and_duplicate_tenants() {
    let (be, ds) = world();
    let cfg = FleetConfig::builder(SPLIT).max_tenants(4).build().expect("config");
    let server = Arc::new(FleetServer::new(be, cfg).expect("server"));
    let mut client = LocalClient::new(server, Arc::new(ds));
    client.serve(1).expect("serve");
    match client.submit(99, &[], &[]) {
        Err(FleetError::UnknownTenant { tenant: 99 }) => {}
        other => panic!("expected UnknownTenant, got {other:?}"),
    }
    client
        .admit(1, TenantConfig { n_lr: 32, seed: 100, ..TenantConfig::default() })
        .expect("admit");
    match client.admit(1, TenantConfig { n_lr: 32, seed: 100, ..TenantConfig::default() }) {
        Err(FleetError::Admission(_)) => {}
        other => panic!("expected Admission error, got {other:?}"),
    }
    client.finish().expect("finish");
}

// ---------------------------------------------------------------------------
// Live migration: in-process drain -> bytes -> restore bit-parity
// ---------------------------------------------------------------------------

#[test]
fn migrated_tenant_matches_never_moving_control_bit_for_bit() {
    let (be, ds) = world();
    let (seed, n_lr, total) = (100u64, 96, 4);
    let split_at = 2;

    // control: one server, never moves, full schedule
    let mk = |be: &SharedBackend| {
        let cfg = FleetConfig::builder(SPLIT).max_tenants(4).build().expect("config");
        FleetServer::new(be.clone(), cfg).expect("server")
    };
    let (init_images, init_labels) = traffic::init_pool(&ds);
    let control = mk(&be);
    let cid = control
        .admit(
            TenantConfig { n_lr, seed, ..TenantConfig::default() },
            &init_images,
            &init_labels,
        )
        .expect("admit control");
    control.run(leg(&be, &ds, cid, seed, 0, total), 2).expect("control run");
    let control_acc = control.evaluate_tenant(&ds, cid).expect("eval control");

    // migrant: leg 1 on server A, drain to bytes, restore on server B,
    // leg 2 there — exactly what the two shard processes do over TCP
    let a = mk(&be);
    let aid = a
        .admit(
            TenantConfig { n_lr, seed, ..TenantConfig::default() },
            &init_images,
            &init_labels,
        )
        .expect("admit A");
    a.run(leg(&be, &ds, aid, seed, 0, split_at), 2).expect("leg 1");
    let bytes = tinycl::fleet::snapshot::encode(&a.evict(aid).expect("drain"));

    let b = mk(&be);
    let snap = tinycl::fleet::snapshot::decode(&bytes).expect("decode transfer bytes");
    let bid = b.restore(snap).expect("restore");
    b.run(leg(&be, &ds, bid, seed, split_at, total - split_at), 2).expect("leg 2");
    let migrated_acc = b.evaluate_tenant(&ds, bid).expect("eval migrated");

    assert_eq!(
        migrated_acc.to_bits(),
        control_acc.to_bits(),
        "migration must be invisible to the tenant's trajectory"
    );
}

// ---------------------------------------------------------------------------
// Two real shard processes (in-process threads, real TCP loopback)
// ---------------------------------------------------------------------------

#[test]
fn two_shard_loopback_serves_migrates_and_loses_no_tenant() {
    let n_tenants = 4u64;
    let (leg1, leg2) = (2usize, 2usize);
    let n_lr = 64;
    let seed0 = 100u64;

    // each shard opens its own identical synthetic world (as separate
    // processes would); the client opens one more for traffic only
    let mut addrs = Vec::new();
    let mut servers = Vec::new();
    for shard in 0..2u32 {
        let (be, ds) = world();
        let cfg = FleetConfig::builder(SPLIT).max_tenants(16).build().expect("config");
        let srv =
            ShardServer::bind(be, Arc::new(ds), cfg, shard, 2, "127.0.0.1:0").expect("bind");
        addrs.push(srv.local_addr().to_string());
        servers.push(srv);
    }
    let handles: Vec<_> =
        servers.into_iter().map(|s| std::thread::spawn(move || s.serve())).collect();

    let (be, ds) = world();
    let retry = RetryPolicy { attempts: 20, base: Duration::from_millis(5) };
    let mut client = FleetClient::connect(&addrs, &retry).expect("connect");
    assert_eq!(client.shard_count(), 2);

    for g in 0..n_tenants {
        client
            .admit(g, TenantConfig { n_lr, seed: seed0 + g, ..TenantConfig::default() })
            .expect("admit");
    }

    // control for tenant 0: a never-sharded local fleet over the full
    // schedule — the loopback run must land on the same bits
    let (init_images, init_labels) = traffic::init_pool(&ds);
    let control = FleetServer::new(
        be.clone(),
        FleetConfig::builder(SPLIT).max_tenants(4).build().expect("config"),
    )
    .expect("control server");
    let cid = control
        .admit(
            TenantConfig { n_lr, seed: seed0, ..TenantConfig::default() },
            &init_images,
            &init_labels,
        )
        .expect("admit control");
    control.run(leg(&be, &ds, cid, seed0, 0, leg1 + leg2), 2).expect("control run");
    let control_acc = control.evaluate_tenant(&ds, cid).expect("control eval");

    // leg 1 over the wire
    for g in 0..n_tenants {
        for ev in leg(&be, &ds, g as TenantId, seed0 + g, 0, leg1) {
            submit_with_backoff(&mut client, g, &ev.images, &ev.labels, 64).expect("submit");
        }
    }

    // live-migrate tenant 0 to the other shard mid-stream
    let from = client.router().route(0);
    let to = 1 - from;
    client.migrate(0, to).expect("migrate");
    assert_eq!(client.router().route(0), to);
    assert_eq!(client.migrations(), &[(0, from, to)]);

    // leg 2: the migrated tenant continues on its new shard
    for g in 0..n_tenants {
        for ev in leg(&be, &ds, g as TenantId, seed0 + g, leg1, leg2) {
            submit_with_backoff(&mut client, g, &ev.images, &ev.labels, 64).expect("submit");
        }
    }

    // nobody lost: every tenant evaluates, and the migrated tenant's
    // accuracy is bit-identical to the never-moved control
    let mut lost = 0;
    for g in 0..n_tenants {
        match client.evaluate(g) {
            Ok(acc) => {
                assert!(acc.is_finite());
                if g == 0 {
                    assert_eq!(
                        acc.to_bits(),
                        control_acc.to_bits(),
                        "migrated tenant drifted from the never-moving control"
                    );
                }
            }
            Err(e) => {
                eprintln!("tenant {g} lost: {e}");
                lost += 1;
            }
        }
    }
    assert_eq!(lost, 0, "tenants_lost must be 0");

    // the rebalancer's world view agrees with the routing table
    let stats = client.stats().expect("stats");
    let visible: u64 = stats.iter().map(|s| s.tenants.len() as u64).sum();
    assert_eq!(visible, n_tenants);
    let frames: u64 = stats.iter().map(|s| s.events_done).sum();
    assert_eq!(frames, n_tenants * (leg1 + leg2) as u64, "every event applied");

    client.shutdown_all().expect("shutdown");
    let mut total_events = 0;
    for h in handles {
        let report = h.join().expect("serve thread").expect("report");
        assert_eq!(report.dropped, 0);
        total_events += report.events;
    }
    assert_eq!(total_events, n_tenants * (leg1 + leg2) as u64);
}

// ---------------------------------------------------------------------------
// Shed/backoff contract: the client sleeps exactly the quoted ladder
// ---------------------------------------------------------------------------

#[test]
fn shed_client_converges_and_quotes_follow_the_ladder() {
    let (be, ds) = world();
    // a deliberately tiny pipe: depth-1 queue, 1 ms shed deadline, one
    // worker grinding long events — overload is the steady state
    let cfg = FleetConfig::builder(SPLIT)
        .max_tenants(4)
        .queue_depth(1)
        .coalesce(1)
        .shed_after_ms(1)
        .build()
        .expect("config");
    let server = Arc::new(FleetServer::new(be.clone(), cfg).expect("server"));
    let ds = Arc::new(ds);
    let mut client = LocalClient::new(server, ds.clone());
    client.serve(1).expect("serve");
    client
        .admit(0, TenantConfig { n_lr: 64, seed: 100, epochs: 50, ..TenantConfig::default() })
        .expect("admit");
    let slot = client.local_id(0).expect("slot");

    let events: Vec<FleetEvent> = leg(&be, &ds, slot, 100, 0, 4);
    let mut streaks: Vec<Vec<u64>> = Vec::new();
    for ev in &events {
        let mut quotes = Vec::new();
        loop {
            match client.submit(0, &ev.images, &ev.labels) {
                Ok(()) => break,
                Err(FleetError::Overloaded { retry_after_ms }) => {
                    quotes.push(retry_after_ms);
                    // the whole contract: sleep exactly what was quoted
                    std::thread::sleep(Duration::from_millis(retry_after_ms));
                }
                Err(e) => panic!("only Overloaded is expected under pressure, got {e:?}"),
            }
        }
        if !quotes.is_empty() {
            streaks.push(quotes);
        }
    }
    // every consecutive-shed streak is exactly the doubling ladder
    // 1, 2, 4, ... capped at 64 — per-tenant, reset on each success
    for quotes in &streaks {
        for (k, &q) in quotes.iter().enumerate() {
            assert_eq!(q, 1u64 << k.min(6), "streak {quotes:?} deviates at step {k}");
        }
    }
    let report = client.finish().expect("finish");
    assert_eq!(report.events, events.len() as u64, "every event converged");
    assert_eq!(report.dropped, 0);
    let shed_total: usize = streaks.iter().map(|s| s.len()).sum();
    assert_eq!(report.robustness.shed, shed_total as u64, "server and client agree on sheds");
}
