//! Integration: the telemetry RECORD path performs ZERO heap
//! allocations — spans, externally-timed events, histogram samples,
//! counters, gauges, and the per-layer table all write into memory the
//! handle allocated up front, so instrumented hot paths (kernel rows,
//! dispatch, spill I/O) stay allocation-free whether recording is on or
//! off. Export (`report`, `chrome_trace`) may allocate; it runs after
//! the instrumented region has quiesced.
//!
//! Single test on purpose: the allocation counter is per-binary, and a
//! lone test keeps the measurement window free of harness traffic (the
//! same discipline as `alloc_hot_path.rs`).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use tinycl::telemetry::{
    Counter, EventKind, Gauge, Path, Telemetry, LANE_HIGH, LANE_NONE,
};

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Exercise every record-path entry point once.
fn record_round(tm: &Telemetry, i: u64) {
    {
        let mut sp = tm.span(EventKind::KernelConv3x3).key(i).lane(LANE_HIGH);
        sp.set_payload(i, 64);
        // guard drop records the span
    }
    {
        // the owned (global-style) guard: one Arc refcount bump, no alloc
        let _sp = tm
            .clone()
            .owned_span(EventKind::TrainStep)
            .tenant((i % 7) as u32)
            .payload(i, 0)
            .hist(Path::Serve)
            .counter(Counter::TrainSteps);
    }
    tm.event_ns(EventKind::Dispatch, i, (i % 5) as u32, LANE_NONE, 1_000 + i, 1, i);
    tm.hist_ns(Path::Dispatch, 10_000 + i * 97);
    tm.counter_add(Counter::Dispatches, 1);
    tm.gauge_set(Gauge::GovRamBytes, i * 4096);
    tm.gauge_max(Gauge::QueueDepthPeak, i % 33);
    tm.gauge_inc_peak(Gauge::PoolBusyHigh, Gauge::PoolBusyHighPeak);
    tm.gauge_dec(Gauge::PoolBusyHigh);
    tm.record_layer((i % 27) as usize, 0, 64, 5_000);
}

#[test]
fn record_path_never_allocates() {
    // ring geometry small enough that the loop WRAPS both rings — the
    // wrap/overwrite path must also be allocation-free
    let tm = Telemetry::with_capacity(2, 256);
    let disabled = Telemetry::none();

    // warm-up: claim this thread's ring, touch every path once
    record_round(&tm, 0);
    record_round(&disabled, 0);

    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for i in 1..=2_000u64 {
        record_round(&tm, i);
        record_round(&disabled, i);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "telemetry record path allocated {} times in 2000 rounds",
        after - before
    );

    // the rounds really landed: spans + events recorded, wrap counted
    let report = tm.report().expect("enabled handle reports");
    assert!(report.events_recorded > 0);
    assert!(
        report.events_recorded + report.events_dropped >= 3 * 2_000,
        "expected ~3 ring events per round (two spans + one event)"
    );
    assert!(report.events_dropped > 0, "the tiny rings must have wrapped");
    assert!(disabled.report().is_none());
}
