//! Pool-determinism property suite for the unified execution pool
//! (ISSUE 7): engine GEMM/conv outputs bit-identical across pool widths
//! {1, 2, 8} and under oversubscription, plus the zero-spawn assertion —
//! a steady-state frozen forward performs NO `thread::spawn` calls.
//!
//! The determinism argument has two independent axes:
//!
//! - **logical thread count** (`Engine::threads`) decides the row split;
//!   the engine's own suite sweeps it and this file re-pins it at an
//!   oversubscribed count (threads >> cores);
//! - **physical pool width** (`ExecPool` worker count) decides only WHO
//!   executes the pre-computed chunks; this file sweeps explicit pools
//!   and asserts bit-equality against the inline (single-part) result.

use tinycl::exec::{ExecConfig, ExecPool, Lane};
use tinycl::kernels::engine::Engine;

/// Deterministic pseudo-random f32s in [-1, 1) (splitmix-style).
fn synth(n: usize, seed: u64) -> Vec<f32> {
    let mut s = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    (0..n)
        .map(|_| {
            s ^= s >> 30;
            s = s.wrapping_mul(0xBF58_476D_1CE4_E5B9);
            s ^= s >> 27;
            ((s >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
        })
        .collect()
}

fn synth_u8(n: usize, seed: u64) -> Vec<u8> {
    synth(n, seed).iter().map(|v| ((v + 1.0) * 127.0) as u8).collect()
}

fn synth_i8(n: usize, seed: u64) -> Vec<i8> {
    synth(n, seed).iter().map(|v| (v * 126.0) as i8).collect()
}

/// A float row kernel whose result depends on accumulation ORDER (sums
/// of non-associative f32 terms): if a pool width ever changed the
/// split or ran a chunk against the wrong rows, bits would differ.
fn row_reduce(src: &[f32], cols: usize, r0: usize, rows: usize, chunk: &mut [f32]) {
    for r in 0..rows {
        let row = &src[(r0 + r) * cols..(r0 + r + 1) * cols];
        let mut acc = 0.0f32;
        for (j, v) in row.iter().enumerate() {
            acc += v * (1.0 + (j % 7) as f32 * 0.125);
        }
        chunk[r] = acc;
    }
}

#[test]
fn parallel_rows_bit_identical_across_pool_widths_and_oversubscription() {
    let cols = 257;
    let rows = 143;
    let src = synth(rows * cols, 11);
    // reference: the inline path (single part) on a width-1 pool
    let mut expect = vec![0f32; rows];
    ExecPool::new(1).parallel_rows_mut(&mut expect, 1, rows, rows, |r0, n, chunk| {
        row_reduce(&src, cols, r0, n, chunk)
    });
    // width 32 on a typical CI host is heavy oversubscription — the
    // split below (chunks of 5 rows -> 29 parts) must not care
    for width in [1usize, 2, 8, 32] {
        let pool = ExecPool::new(width);
        for rows_per in [1usize, 5, 64, 200] {
            let mut out = vec![0f32; rows];
            pool.parallel_rows_mut(&mut out, 1, rows, rows_per, |r0, n, chunk| {
                row_reduce(&src, cols, r0, n, chunk)
            });
            assert_eq!(
                out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                expect.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "width={width} rows_per={rows_per}"
            );
        }
    }
}

#[test]
fn engine_outputs_bit_identical_for_oversubscribed_logical_threads() {
    // the engine suite sweeps threads {1, 2, 8}; here: threads far above
    // any host's core count, through the SHARED global pool, against the
    // single-threaded reference — f32 GEMM, conv, depthwise, i8 GEMM
    let (m, k, n) = (61, 37, 29);
    let x = synth(m * k, 3);
    let w = synth(k * n, 4);
    let single = Engine::with_threads(1);
    let wide = Engine { threads: 97, l2_bytes: 4096 };

    let mut out1 = vec![0f32; m * n];
    let mut out2 = vec![0f32; m * n];
    single.matmul_fw_into(&x, &w, m, k, n, &mut out1);
    wide.matmul_fw_into(&x, &w, m, k, n, &mut out2);
    assert_eq!(
        out1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        out2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "f32 GEMM must be bit-identical under oversubscription"
    );

    let (b, h, wd, c, cout) = (2, 9, 9, 4, 6);
    let img = synth(b * h * wd * c, 5);
    let wmat = synth(9 * c * cout, 6);
    let rows = b * h * wd;
    let mut c1 = vec![0f32; rows * cout];
    let mut c2 = vec![0f32; rows * cout];
    single.conv3x3_fw_into(&img, &wmat, b, h, wd, c, 1, cout, &mut c1);
    wide.conv3x3_fw_into(&img, &wmat, b, h, wd, c, 1, cout, &mut c2);
    assert_eq!(
        c1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        c2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "conv3x3 must be bit-identical under oversubscription"
    );

    let kern = synth(9 * c, 7);
    let mut d1 = vec![0f32; b * h * wd * c];
    let mut d2 = vec![0f32; b * h * wd * c];
    single.depthwise_fw_into(&img, &kern, b, h, wd, c, 1, &mut d1);
    wide.depthwise_fw_into(&img, &kern, b, h, wd, c, 1, &mut d2);
    assert_eq!(
        d1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        d2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "depthwise must be bit-identical under oversubscription"
    );

    let xq = synth_u8(m * k, 8);
    let wq = synth_i8(k * n, 9);
    let mut i1 = vec![0i32; m * n];
    let mut i2 = vec![0i32; m * n];
    single.matmul_fw_i8_into(&xq, &wq, -3, m, k, n, &mut i1);
    wide.matmul_fw_i8_into(&xq, &wq, -3, m, k, n, &mut i2);
    assert_eq!(i1, i2, "i8 GEMM must be bit-identical under oversubscription");
}

#[test]
fn steady_state_frozen_forward_spawns_zero_threads() {
    // warm up: first contact initializes the global pool (the only
    // spawns this process's compute path ever performs) and the frozen
    // stage's weights/calibration
    let (be, ds) =
        tinycl::runtime::open_shared_synthetic(&tinycl::runtime::synthetic::SyntheticSpec::tiny())
            .expect("native backend");
    let m = be.manifest();
    let l = *m.splits.last().expect("manifest has splits");
    let img = m.input_hw * m.input_hw * 3;
    let b = m.batch_eval;
    let le = m.latent[&l].elems();
    let mut images = vec![0f32; b * img];
    for (i, slot) in images.iter_mut().enumerate() {
        *slot = (i % 255) as f32 / 255.0;
    }
    ds.test_image_into(0, &mut images[..img]);
    let mut latents = vec![0f32; b * le];
    be.frozen_forward(l, true, true, &images, &mut latents)
        .expect("warmup frozen forward");

    let pool = tinycl::exec::global();
    let spawns0 = pool.spawn_count();
    for _ in 0..5 {
        be.frozen_forward(l, true, true, &images, &mut latents)
            .expect("steady-state frozen forward");
    }
    assert_eq!(
        pool.spawn_count(),
        spawns0,
        "steady-state frozen forwards must perform zero thread spawns"
    );
    assert_eq!(pool.spawn_count(), pool.width() as u64, "only the initial worker spawns");
}

#[test]
fn task_groups_preserve_submission_order_on_every_lane() {
    for lane in [Lane::High, Lane::Low] {
        let pool = ExecPool::new(4);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..32)
            .map(|i| Box::new(move || i * i) as Box<dyn FnOnce() -> usize + Send>)
            .collect();
        let got = pool.submit_group(lane, jobs).wait();
        assert_eq!(got, (0..32).map(|i| i * i).collect::<Vec<_>>(), "{lane:?}");
    }
}

#[test]
fn group_jobs_may_borrow_the_callers_stack() {
    // the 'env lifetime contract: jobs read a stack-owned buffer; the
    // handle's wait keeps the borrow alive until every job is done
    let data: Vec<u64> = (0..1000).collect();
    let pool = ExecPool::new(2);
    let jobs: Vec<Box<dyn FnOnce() -> u64 + Send + '_>> = (0..4)
        .map(|part| {
            let data = &data;
            Box::new(move || data[part * 250..(part + 1) * 250].iter().sum::<u64>())
                as Box<dyn FnOnce() -> u64 + Send + '_>
        })
        .collect();
    let got = pool.submit_group(Lane::High, jobs).wait();
    assert_eq!(got.iter().sum::<u64>(), data.iter().sum::<u64>());
}

#[test]
fn exec_config_resolves_at_least_one_thread() {
    let cfg = ExecConfig::from_env();
    assert!(cfg.threads >= 1);
    // the engine's default threads come from the SAME resolution
    assert_eq!(tinycl::kernels::engine::default_threads(), cfg.threads);
}
