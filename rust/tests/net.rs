//! Frame-decoder corruption suite: hostile bytes into the wire layer.
//!
//! The contract under test (satellite of the partition-tolerance PR):
//! no input byte stream — truncated, bit-flipped, oversized, or
//! mis-framed — may panic the decoder or leave partial state behind.
//! Every failure is classified: a clean close *between* frames is
//! `Ok(false)` / `FrameError::Closed`, anything that dies *inside* a
//! frame is `FrameError::Torn` (the stream is desynchronized and must
//! be abandoned), and payload-level corruption is a decode `Err` —
//! never a half-built `Request`/`Reply`.

use std::io::Cursor;

use tinycl::fleet::TenantConfig;
use tinycl::net::frame::{
    client_handshake, decode_reply, decode_request, encode_reply, encode_request, read_frame,
    read_frame_into, server_handshake, write_frame, FrameError, Reply, Request, Stamp,
    MAX_FRAME_BYTES, PROTOCOL_MAGIC, PROTOCOL_VERSION,
};

fn sample_admit() -> Request {
    Request::Admit {
        tenant: 42,
        stamp: Stamp { client_id: 7, seq: 3 },
        cfg: TenantConfig { n_lr: 128, lr_bits: 8, lr: 0.01, epochs: 2, seed: 11 },
    }
}

/// One good frame on the wire: `[len u32 LE][payload]`.
fn framed(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    write_frame(&mut out, payload).unwrap();
    out
}

// ---- stream framing --------------------------------------------------------

#[test]
fn clean_eof_before_any_byte_is_not_an_error() {
    let mut buf = vec![0xAA; 8];
    let got = read_frame_into(&mut Cursor::new(Vec::<u8>::new()), &mut buf).unwrap();
    assert!(!got, "empty stream must report no-frame, not a frame");
    // the scratch buffer is untouched on the no-frame path
    assert_eq!(buf, vec![0xAA; 8]);
    assert!(read_frame(&mut Cursor::new(Vec::<u8>::new())).unwrap().is_none());
}

#[test]
fn truncated_length_prefix_is_torn() {
    // every strictly-partial prefix (1..=3 bytes then EOF) is mid-frame
    for keep in 1..4 {
        let wire = framed(b"payload")[..keep].to_vec();
        let mut buf = Vec::new();
        match read_frame_into(&mut Cursor::new(wire), &mut buf) {
            Err(FrameError::Torn(m)) => {
                assert!(m.contains("mid-frame"), "torn message should say mid-frame: {m}")
            }
            other => panic!("{keep}-byte prefix must be Torn, got {other:?}"),
        }
    }
}

#[test]
fn truncated_payload_is_torn() {
    // the prefix promises 7 bytes; deliver every shorter count
    let wire = framed(b"payload");
    for keep in 4..wire.len() {
        let mut buf = Vec::new();
        match read_frame_into(&mut Cursor::new(wire[..keep].to_vec()), &mut buf) {
            Err(FrameError::Torn(m)) => {
                assert!(m.contains("mid-payload"), "torn message should say mid-payload: {m}")
            }
            other => panic!("truncation at {keep} must be Torn, got {other:?}"),
        }
    }
}

#[test]
fn oversized_length_prefix_is_rejected_before_allocation() {
    // a length prefix of u32::MAX (and of MAX+1) must be refused by
    // arithmetic, not attempted as an allocation
    for len in [u32::MAX, (MAX_FRAME_BYTES as u32) + 1] {
        let wire = len.to_le_bytes().to_vec();
        let mut buf = Vec::new();
        match read_frame_into(&mut Cursor::new(wire), &mut buf) {
            Err(FrameError::Torn(m)) => {
                assert!(m.contains("MAX_FRAME_BYTES"), "should cite the bound: {m}")
            }
            other => panic!("oversized len {len} must be Torn, got {other:?}"),
        }
        assert!(
            buf.capacity() < 1 << 20,
            "rejection must happen before the payload buffer grows (cap {})",
            buf.capacity()
        );
    }
    // exactly at the bound the length itself is legal — the stream then
    // dies mid-payload, which is still Torn, still no panic
    let wire = (MAX_FRAME_BYTES as u32).to_le_bytes().to_vec();
    assert!(matches!(
        read_frame_into(&mut Cursor::new(wire), &mut Vec::new()),
        Err(FrameError::Torn(_))
    ));
}

#[test]
fn scratch_buffer_survives_a_torn_read() {
    // a failed read must not poison the reused buffer for the next
    // (fresh) connection
    let mut buf = Vec::new();
    let torn = framed(b"abcdef")[..6].to_vec();
    assert!(read_frame_into(&mut Cursor::new(torn), &mut buf).is_err());
    let good = framed(b"hello again");
    assert!(read_frame_into(&mut Cursor::new(good), &mut buf).unwrap());
    assert_eq!(&buf, b"hello again");
}

// ---- payload decoding ------------------------------------------------------

#[test]
fn unknown_request_op_is_an_error_not_a_panic() {
    let mut bytes = encode_request(&sample_admit());
    bytes[0] = 0xEE; // no such op
    let err = decode_request(&bytes).unwrap_err();
    assert!(format!("{err}").contains("unknown request op"), "{err}");
}

#[test]
fn bit_flipped_request_never_panics() {
    // flip every bit of an Admit frame one at a time: each mutant must
    // decode to Ok(some request) or Err — never panic, never hang
    let bytes = encode_request(&sample_admit());
    for i in 0..bytes.len() {
        for bit in 0..8 {
            let mut mutant = bytes.clone();
            mutant[i] ^= 1 << bit;
            let _ = decode_request(&mutant);
        }
    }
}

#[test]
fn bit_flipped_reply_never_panics() {
    let replies = [
        encode_reply(&Reply::Ok),
        encode_reply(&Reply::Admitted { tenant: 9 }),
        encode_reply(&Reply::Snapshot { bytes: vec![1, 2, 3, 4] }),
        encode_reply(&Reply::Logits { rows: 2, classes: 3, data: vec![0.5; 6] }),
        encode_reply(&Reply::Duplicate),
    ];
    for bytes in &replies {
        for i in 0..bytes.len() {
            for bit in 0..8 {
                let mut mutant = bytes.clone();
                mutant[i] ^= 1 << bit;
                let _ = decode_reply(&mutant);
            }
        }
    }
}

#[test]
fn truncated_request_payload_is_an_error() {
    // every strict prefix of a valid frame must fail decode — a partial
    // Request must never escape
    let bytes = encode_request(&sample_admit());
    for keep in 0..bytes.len() {
        assert!(
            decode_request(&bytes[..keep]).is_err(),
            "prefix of {keep}/{} bytes decoded to a full request",
            bytes.len()
        );
    }
}

#[test]
fn trailing_bytes_are_an_error() {
    let mut req = encode_request(&Request::Ping);
    req.push(0);
    let err = decode_request(&req).unwrap_err();
    assert!(format!("{err:#}").contains("trailing"), "{err:#}");

    let mut rep = encode_reply(&Reply::Ok);
    rep.push(0);
    let err = decode_reply(&rep).unwrap_err();
    assert!(format!("{err:#}").contains("trailing"), "{err:#}");
}

#[test]
fn hostile_submit_counts_are_bounded_by_the_frame() {
    // a Submit whose label count claims 1 billion rows must be refused
    // by the count-vs-frame-size check, not answered with a giant
    // Vec::with_capacity
    let mut bytes = encode_request(&Request::Submit {
        tenant: 1,
        stamp: Stamp::default(),
        images: vec![0.0; 4],
        labels: vec![0],
    });
    // label count lives right after op(1) + tenant(8) + stamp(16)
    bytes[25..29].copy_from_slice(&1_000_000_000u32.to_le_bytes());
    let err = decode_request(&bytes).unwrap_err();
    assert!(format!("{err}").contains("exceeds the frame"), "{err}");
}

#[test]
fn unknown_reply_code_is_version_skew() {
    let err = decode_reply(&[0xEE]).unwrap_err();
    assert!(format!("{err}").contains("unknown reply code"), "{err}");
}

// ---- handshake -------------------------------------------------------------

/// An in-memory full-duplex stub: reads from `input`, collects writes.
struct HalfDuplex {
    input: Cursor<Vec<u8>>,
    written: Vec<u8>,
}

impl HalfDuplex {
    fn new(input: Vec<u8>) -> Self {
        HalfDuplex { input: Cursor::new(input), written: Vec::new() }
    }
}

impl std::io::Read for HalfDuplex {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        self.input.read(buf)
    }
}

impl std::io::Write for HalfDuplex {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.written.extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

#[test]
fn server_handshake_rejects_wrong_magic() {
    let mut hello = [0u8; 8];
    hello[..4].copy_from_slice(b"HTTP");
    hello[4..].copy_from_slice(&PROTOCOL_VERSION.to_le_bytes());
    let mut stream = HalfDuplex::new(hello.to_vec());
    let err = server_handshake(&mut stream).unwrap_err();
    assert!(format!("{err}").contains("bad magic"), "{err}");
    assert!(stream.written.is_empty(), "a rejected client must not be echoed");
}

#[test]
fn server_handshake_rejects_version_skew() {
    let mut hello = [0u8; 8];
    hello[..4].copy_from_slice(&PROTOCOL_MAGIC);
    hello[4..].copy_from_slice(&(PROTOCOL_VERSION + 1).to_le_bytes());
    let mut stream = HalfDuplex::new(hello.to_vec());
    let err = server_handshake(&mut stream).unwrap_err();
    assert!(format!("{err}").contains("unsupported protocol version"), "{err}");
}

#[test]
fn client_handshake_rejects_a_wrong_echo() {
    // server answers with a different version: the client must refuse
    let mut echo = [0u8; 8];
    echo[..4].copy_from_slice(&PROTOCOL_MAGIC);
    echo[4..].copy_from_slice(&(PROTOCOL_VERSION + 9).to_le_bytes());
    let mut stream = HalfDuplex::new(echo.to_vec());
    let err = client_handshake(&mut stream).unwrap_err();
    assert!(format!("{err}").contains("different protocol"), "{err}");
}

#[test]
fn client_handshake_classifies_a_silent_server() {
    // server accepts the connection but never echoes: read_exact EOF
    let mut stream = HalfDuplex::new(Vec::new());
    assert!(client_handshake(&mut stream).is_err());
    // the hello itself did go out
    assert_eq!(&stream.written[..4], &PROTOCOL_MAGIC);
}
