//! The INT8 parity suite: the true-integer frozen path against the
//! fake-quant FP32 oracle — bit-exact where the arithmetic is exactly
//! representable, ≤ 1 LSB at real layer boundaries (the strict per-layer
//! pin lives in `runtime/native.rs` unit tests, which can feed both
//! implementations identical per-layer inputs), coalescer parity on the
//! fleet path, and protocol-level accuracy unchanged end-to-end.

use tinycl::coordinator::batcher::FrozenCoalescer;
use tinycl::coordinator::{run_protocol, CLConfig, RunOptions};
use tinycl::kernels::{matmul_fw_i8, matmul_fw_naive};
use tinycl::quant::{act_scale, Requant};
use tinycl::runtime::synthetic::{self, SyntheticSpec};
use tinycl::runtime::{Backend, Dataset, FrozenPath, NativeBackend};
use tinycl::util::rng::Rng;

fn world(path: FrozenPath) -> (NativeBackend, Dataset) {
    let (m, ds) = synthetic::generate(&SyntheticSpec::tiny()).expect("synthetic env");
    (NativeBackend::with_frozen_path(m, path).expect("backend"), ds)
}

#[test]
fn integer_layer_is_bit_exact_on_representable_grids() {
    // power-of-two scales with small reductions: every fake-quant f32
    // product and partial sum is exactly representable, so the oracle
    // has NO rounding noise and the integer path must match bit-for-bit
    let mut rng = Rng::new(0x1E8);
    let (m, k, n) = (16usize, 24, 12);
    let s_in = 2f32.powi(-8);
    let s_w = 2f32.powi(-7);
    let s_out = 2f32.powi(-6);
    for trial in 0..20 {
        let x_codes: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
        // signed weight levels in [-128, 127], stored as code+off with off=0
        let w_codes: Vec<i8> = (0..k * n).map(|_| rng.below(256) as i8).collect();
        // integer path: exact accumulation + fixed-point requant
        let acc = matmul_fw_i8(&x_codes, &w_codes, 0, m, k, n);
        let rq = Requant::from_scale(s_in as f64 * s_w as f64 / s_out as f64);
        let q_int: Vec<u8> = acc.iter().map(|&a| rq.quantize(a, 255)).collect();
        // oracle: f32 grid values through the f32 kernel, then quantize.
        // products q_x*q_w*2^-15 and their sums stay below 2^24 ulps of
        // the shared exponent, so f32 accumulation is exact here
        let x_g: Vec<f32> = x_codes.iter().map(|&c| c as f32 * s_in).collect();
        let w_g: Vec<f32> = w_codes.iter().map(|&c| c as f32 * s_w).collect();
        let y = matmul_fw_naive(&x_g, &w_g, m, k, n);
        let inv = 1.0 / s_out;
        let q_f32: Vec<u8> =
            y.iter().map(|&v| (v * inv).floor().clamp(0.0, 255.0) as u8).collect();
        assert_eq!(q_int, q_f32, "trial {trial}: representable grid must be bit-exact");
    }
}

#[test]
fn int8_default_backend_runs_the_integer_path() {
    let (be, _) = world(FrozenPath::from_env().expect("env"));
    assert_eq!(be.frozen_path(), FrozenPath::Int8, "true-INT8 must be the default");
    assert!(be.platform().contains("true-int8"), "{}", be.platform());
}

#[test]
fn coalesced_frozen_forward_is_bit_identical_to_solo_on_the_integer_path() {
    // the fleet coalescer's contract, integer edition: latents of an
    // event inside a cross-tenant batch equal a solo frozen_forward —
    // exact integer accumulation makes this bit-exact by construction,
    // pinned here against the real backend
    let (be, ds) = world(FrozenPath::Int8);
    let m = be.manifest();
    let img = m.input_hw * m.input_hw * 3;
    let l = 13;
    let lelems = be.latent_elems(l).unwrap();
    let mut images = vec![0f32; 5 * img];
    for i in 0..5 {
        ds.train_image_into(i, &mut images[i * img..(i + 1) * img]);
    }
    let mut coal = FrozenCoalescer::new(img, lelems);
    let e0 = coal.push(&images[..2 * img]); // 2 rows
    let e1 = coal.push(&images[2 * img..]); // 3 rows
    coal.run(&be, l, true).unwrap();
    for (idx, range) in [(e0, 0..2usize), (e1, 2..5)] {
        let rows = range.len();
        let mut solo = vec![0f32; rows * lelems];
        be.frozen_forward(l, true, false, &images[range.start * img..range.end * img], &mut solo)
            .unwrap();
        assert_eq!(coal.latents(idx), &solo[..], "event {idx}");
    }
}

#[test]
fn protocol_accuracy_is_unchanged_on_the_integer_path() {
    // the tentpole's end guarantee: swapping the frozen stage's
    // implementation (fake-quant f32 -> true integer) leaves the
    // CL protocol's learning outcome intact. Latent codes drift <= 1 LSB
    // per layer, compounding to a few percent of codes at the deepest
    // prefixes under rustc's strict-IEEE f32 (C-mirror measured at -O2),
    // so the accuracies track closely; both arms must LEARN
    let events = 6;
    let cl = CLConfig { l: 13, n_lr: 128, lr_bits: 8, int8_frozen: true, ..Default::default() };
    let opts = RunOptions { eval_every: 0, max_events: events, verbose: false };
    let (be_int, ds) = world(FrozenPath::Int8);
    let r_int = run_protocol(&be_int, &ds, cl, opts).expect("int8 protocol");
    let (be_sim, ds2) = world(FrozenPath::FakeQuantF32);
    let r_sim = run_protocol(&be_sim, &ds2, cl, opts).expect("sim protocol");
    assert!(
        r_int.final_acc > r_int.initial_acc + 0.05,
        "integer path must learn: {:.3} -> {:.3}",
        r_int.initial_acc,
        r_int.final_acc
    );
    assert!(
        (r_int.final_acc - r_sim.final_acc).abs() <= 0.1,
        "protocol accuracy must be unchanged across frozen paths: int8 {:.3} vs sim {:.3}",
        r_int.final_acc,
        r_sim.final_acc
    );
    // determinism within a path: the integer protocol reproduces itself
    let (be_int2, ds3) = world(FrozenPath::Int8);
    let r_int2 = run_protocol(&be_int2, &ds3, cl, opts).expect("int8 protocol, run 2");
    assert_eq!(r_int.final_acc, r_int2.final_acc, "integer path must be deterministic");
}

#[test]
fn requant_scale_chain_stays_sane_across_the_real_manifest() {
    // every frozen layer's combined scale must produce a non-degenerate
    // requantization on the real calibrated manifest (no layer maps
    // everything to zero or saturates everything)
    let (be, ds) = world(FrozenPath::Int8);
    let m = be.manifest();
    let img = m.input_hw * m.input_hw * 3;
    let mut images = vec![0f32; 4 * img];
    for i in 0..4 {
        ds.train_image_into(i, &mut images[i * img..(i + 1) * img]);
    }
    for &l in &m.splits {
        let lelems = be.latent_elems(l).unwrap();
        let mut lat = vec![0f32; 4 * lelems];
        be.frozen_forward(l, true, false, &images, &mut lat).unwrap();
        let n_conv = m.arch.len();
        let a_max = (if l >= n_conv { m.pooled_a_max } else { m.a_max[l - 1] }) as f32;
        let top = act_scale(a_max, m.a_bits) * 255.0;
        let nonzero = lat.iter().filter(|&&v| v > 0.0).count();
        let saturated = lat.iter().filter(|&&v| v >= top * 0.999).count();
        assert!(nonzero * 4 >= lat.len(), "l={l}: {} of {} nonzero", nonzero, lat.len());
        assert!(saturated * 2 <= lat.len(), "l={l}: over-saturated ({saturated})");
    }
}
