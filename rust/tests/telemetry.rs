//! Telemetry integration contract: recording is an observer, never a
//! participant. A fleet run with telemetry enabled produces the exact
//! same outcomes — event counts, governor log, per-tenant accuracies —
//! as the same run with telemetry off, at any worker count; and the
//! digest an enabled run exports is coherent with the outcomes it
//! observed (one dispatch-histogram sample per applied event, one
//! governor event per committed action, balanced spans in the trace).
//!
//! Enabled runs install the process-global telemetry slot for their
//! duration; `SERIAL` keeps two enabled runs from interleaving their
//! kernel-level spans into each other's sinks (outcomes would still be
//! identical — the content assertions below are what need the lock).

use std::sync::Mutex;

use tinycl::fleet::{traffic, FleetConfig, FleetReport, FleetServer, TenantConfig};
use tinycl::runtime::synthetic::SyntheticSpec;
use tinycl::runtime::{open_shared_synthetic, Dataset, SharedBackend};
use tinycl::telemetry::Telemetry;

const SPLIT: usize = 15;
const N_LR: usize = 1024;
const TENANTS: usize = 6;
const EVENTS_PER_TENANT: usize = 2;

static SERIAL: Mutex<()> = Mutex::new(());

fn world() -> (SharedBackend, Dataset) {
    open_shared_synthetic(&SyntheticSpec::tiny()).expect("synthetic world")
}

/// Budget sized so ~4 of the 6 tenants fit raw: the tail admissions
/// force demote/shrink relief, so the off/on comparison also covers the
/// governor commit path (every commit now routes through telemetry).
fn pressured_budget(be: &SharedBackend) -> usize {
    let probe = FleetServer::new(be.clone(), FleetConfig::new(SPLIT)).expect("probe");
    let per = probe.per_tenant_bytes(N_LR, 8);
    probe.shared_backbone_bytes() + per * 4 + per / 2
}

/// One complete governed run: admit TENANTS under the pressured budget,
/// serve the canonical interleaved stream, evaluate everyone. Returns
/// the report plus every outcome the off/on diff compares.
fn governed_run(
    be: &SharedBackend,
    ds: &Dataset,
    workers: usize,
    telemetry: Telemetry,
) -> (FleetReport, Vec<f64>, String, usize) {
    let mut cfg = FleetConfig::new(SPLIT);
    cfg.governor.budget_bytes = pressured_budget(be);
    cfg.governor.min_slots = 16;
    cfg.telemetry = telemetry;
    let server = FleetServer::new(be.clone(), cfg).expect("server");
    let (init_images, init_labels) = traffic::init_pool(ds);
    let init_latents = server.embed_images(&init_images).expect("embed");
    let mut ids = Vec::new();
    for t in 0..TENANTS {
        let tcfg = TenantConfig { n_lr: N_LR, seed: 100 + t as u64, ..TenantConfig::default() };
        ids.push(server.admit_prepared(tcfg, &init_latents, &init_labels).expect("admit"));
    }
    let seeded: Vec<(usize, u64)> = ids.iter().map(|&id| (id, 100 + id as u64)).collect();
    let events =
        traffic::interleaved_nicv2(&be.manifest().protocol, ds, &seeded, EVENTS_PER_TENANT);
    let report = server.run(events, workers).expect("run");
    let accs: Vec<f64> =
        ids.iter().map(|&id| server.evaluate_tenant(ds, id).expect("eval")).collect();
    // the full ordered action log, debug-formatted: any divergence in
    // governor behavior (kind, tenant, byte counts, order) shows here
    let gov = format!("{:?}", server.governor_log());
    (report, accs, gov, server.bytes_in_use())
}

#[test]
fn recording_never_changes_fleet_outcomes() {
    let _serial = SERIAL.lock().unwrap();
    let (be, ds) = world();
    for workers in [1usize, 4] {
        let (r_off, acc_off, gov_off, bytes_off) =
            governed_run(&be, &ds, workers, Telemetry::none());
        let (r_on, acc_on, gov_on, bytes_on) =
            governed_run(&be, &ds, workers, Telemetry::enabled());
        assert!(r_off.telemetry.is_none(), "disabled run must not carry a digest");
        assert!(r_on.telemetry.is_some(), "enabled run must carry a digest");
        assert_eq!(r_off.events, r_on.events, "workers={workers}: event count diverged");
        assert_eq!(r_off.dropped, r_on.dropped);
        assert_eq!(r_off.lazy_restores, r_on.lazy_restores);
        assert_eq!(r_off.robustness, r_on.robustness, "workers={workers}");
        assert_eq!(r_off.frozen_rows, r_on.frozen_rows, "workers={workers}");
        assert_eq!(gov_off, gov_on, "workers={workers}: governor log diverged");
        assert_eq!(bytes_off, bytes_on, "workers={workers}: residency diverged");
        // bit-exact f64 equality — the engine is deterministic per row
        // and telemetry must not perturb a single arithmetic step
        assert_eq!(acc_off, acc_on, "workers={workers}: accuracies diverged");
    }
}

#[test]
fn enabled_digest_is_coherent_with_the_run_it_observed() {
    let _serial = SERIAL.lock().unwrap();
    let (be, ds) = world();
    let (report, _accs, gov, _bytes) = governed_run(&be, &ds, 2, Telemetry::enabled());
    let td = report.telemetry.expect("enabled run exports a digest");
    assert!(td.events_recorded > 0, "spans were recorded");
    assert_eq!(td.events_dropped, 0, "ring capacity covers this tiny run");
    assert!(td.threads_traced >= 1);

    // one dispatch-histogram sample per applied event
    let dispatch = td.hist("dispatch").expect("dispatch path recorded");
    assert_eq!(dispatch.n, report.events, "dispatch hist n == applied events");
    assert!(dispatch.p50_ms <= dispatch.p99_ms && dispatch.p99_ms <= dispatch.max_ms);
    // one serve sample per applied event too (the tenant-apply span)
    let serve = td.hist("serve").expect("serve path recorded");
    assert_eq!(serve.n, report.events);

    let counter = |name: &str| {
        td.counters.iter().find(|(n, _)| *n == name).map(|(_, v)| *v).unwrap_or(0)
    };
    // one Dispatch counter tick per dispatch() call; a call can apply
    // several events at once when it drains parked successors
    let dispatches = counter("dispatches");
    assert!(dispatches >= 1 && dispatches <= report.events, "dispatches={dispatches}");
    assert!(counter("kernel_calls") > 0, "kernel spans reached the installed global sink");
    assert!(counter("frozen_forwards") > 0);
    assert_eq!(counter("frozen_rows"), report.frozen_rows);
    // every governor commit mirrored into the stream: the count matches
    // the server's own ordered action log exactly
    let gov_actions = counter("governor_actions") as usize;
    assert!(gov_actions >= 1, "the pressured budget must force governor actions");
    let log_len = gov.matches('{').count(); // one braced variant per action
    assert_eq!(gov_actions, log_len, "one telemetry event per committed action");

    // per-layer frozen-forward table covers the frozen stage (Fig. 8)
    assert!(!td.frozen_layers.is_empty(), "per-layer stats recorded");
    assert!(td.frozen_layers.iter().all(|l| l.calls > 0 && l.rows > 0));
}

#[test]
fn trace_export_is_balanced_and_loadable() {
    let _serial = SERIAL.lock().unwrap();
    let (be, ds) = world();
    let mut cfg = FleetConfig::new(SPLIT);
    cfg.telemetry = Telemetry::enabled();
    let tm = cfg.telemetry.clone();
    let server = FleetServer::new(be.clone(), cfg).expect("server");
    let (init_images, init_labels) = traffic::init_pool(&ds);
    let id = server
        .admit(TenantConfig { n_lr: 128, seed: 100, ..TenantConfig::default() }, &init_images, &init_labels)
        .expect("admit");
    let evs = traffic::interleaved_nicv2(&be.manifest().protocol, &ds, &[(id, 100)], 2);
    server.run(evs, 2).expect("run");

    let json = tm.chrome_trace().expect("enabled handle exports a trace").to_string();
    // self-describing top level Chrome/Perfetto accepts as-is
    assert!(json.contains("\"traceEvents\""));
    assert!(json.contains("\"displayTimeUnit\""));
    // complete events only (plus thread-name metadata): "X" phases are
    // begin/end balanced by construction — assert both phases appear
    // and nothing else leaked in
    assert!(json.contains("\"ph\":\"X\""));
    assert!(json.contains("\"ph\":\"M\""));
    assert!(!json.contains("\"ph\":\"B\"") && !json.contains("\"ph\":\"E\""));
    // the span vocabulary made it out
    for name in ["fleet.dispatch", "tenant.apply", "frozen.layer"] {
        assert!(json.contains(name), "trace is missing {name} spans");
    }
}
