//! Network-chaos integration tests: the partition-tolerance contract.
//!
//! Three layers of the same promise, from cheapest to most real:
//!
//! 1. **Bit-transparency** — a stamped client run under
//!    [`FaultPlan::net_recovering`] (torn frames, dropped connections,
//!    seeded stalls, every streak shorter than the retry budget) must
//!    produce accuracy bits IDENTICAL to a [`FaultPlan::none`] run, at
//!    1 worker and at 3;
//! 2. **Exactly-once** — re-delivering a stamped Submit must be
//!    acknowledged `Duplicate` and applied exactly once (state bits
//!    equal to a single delivery);
//! 3. **Two-phase migration** — a migration whose restore fails rolls
//!    back via the source tombstone with the tenant's trajectory
//!    untouched; a tombstone orphaned by a "crash" (server torn down
//!    between Drain and Commit) is adopted by the next server on the
//!    same spill dir and resurrectable by MigrateAbort.
//!
//! The `#[ignore]`d drill at the bottom spawns REAL shard processes
//! under [`ShardSupervisor`], scripts a crash on the migration
//! destination mid-restore, and checks the full story: supervisor
//! restart + client failover + rollback/retry, `tenants_lost == 0`.
//! CI's chaos-net-smoke job runs it with `--ignored`.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use tinycl::fleet::{
    submit_with_backoff, traffic, FaultPlan, FleetApi, FleetClient, FleetConfig, FleetError,
    FleetEvent, RetryPolicy, ShardSupervisor, SupervisorConfig, TenantConfig, TenantId,
};
use tinycl::net::frame::Stamp;
use tinycl::net::{DirectNet, RemoteClient, ShardServer};
use tinycl::runtime::synthetic::SyntheticSpec;
use tinycl::runtime::{open_shared_synthetic, Dataset, SharedBackend};

const SPLIT: usize = 15;

fn world() -> (SharedBackend, Dataset) {
    open_shared_synthetic(&SyntheticSpec::tiny()).expect("synthetic world")
}

fn leg(
    be: &SharedBackend,
    ds: &Dataset,
    id: TenantId,
    seed: u64,
    skip: usize,
    take: usize,
) -> Vec<FleetEvent> {
    traffic::nicv2_window(&be.manifest().protocol, ds, &[(id, seed)], skip, take)
}

fn temp_root(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tinycl_chaos_net_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("temp root");
    dir
}

/// Spin up `n` loopback shards (in-thread, real TCP) and return their
/// addresses plus the serve-thread handles.
fn spawn_shards(
    n: u32,
    workers: usize,
    mk_cfg: impl Fn(u32) -> FleetConfig,
) -> (Vec<String>, Vec<std::thread::JoinHandle<anyhow::Result<tinycl::fleet::FleetReport>>>) {
    let mut addrs = Vec::new();
    let mut servers = Vec::new();
    for shard in 0..n {
        let (be, ds) = world();
        let srv = ShardServer::bind(be, Arc::new(ds), mk_cfg(shard), shard, workers, "127.0.0.1:0")
            .expect("bind");
        addrs.push(srv.local_addr().to_string());
        servers.push(srv);
    }
    let handles =
        servers.into_iter().map(|s| std::thread::spawn(move || s.serve())).collect();
    (addrs, handles)
}

// ---------------------------------------------------------------------------
// 1. Bit-transparency: net_recovering == none, to the bit
// ---------------------------------------------------------------------------

/// One full sharded serve — admits, two submit legs with a live
/// migration between them, evals — under the given plan. Returns the
/// per-tenant accuracy bits plus the client's recovery counters.
fn chaos_run(plan: &FaultPlan, workers: usize) -> (Vec<u64>, u64, u64) {
    let n_tenants = 3u64;
    let (leg1, leg2) = (2usize, 2usize);
    let seed0 = 300u64;

    let (addrs, handles) = spawn_shards(2, workers, |_| {
        FleetConfig::builder(SPLIT).max_tenants(16).build().expect("config")
    });

    let (be, ds) = world();
    let retry = RetryPolicy { attempts: 4, base: Duration::from_millis(1) };
    let mut client = FleetClient::connect_with(&addrs, &retry, plan, 42).expect("connect");

    for g in 0..n_tenants {
        client
            .admit(g, TenantConfig { n_lr: 64, seed: seed0 + g, ..TenantConfig::default() })
            .expect("admit");
    }
    for g in 0..n_tenants {
        for ev in leg(&be, &ds, g as TenantId, seed0 + g, 0, leg1) {
            submit_with_backoff(&mut client, g, &ev.images, &ev.labels, 64).expect("submit");
        }
    }
    // live migration mid-stream, two-phase under whatever the plan throws
    let from = client.router().route(0);
    client.migrate(0, 1 - from).expect("migrate");
    for g in 0..n_tenants {
        for ev in leg(&be, &ds, g as TenantId, seed0 + g, leg1, leg2) {
            submit_with_backoff(&mut client, g, &ev.images, &ev.labels, 64).expect("submit");
        }
    }
    // flush any commit/abort that fell to a retried connection
    client.resolve_pending();
    assert!(client.pending().is_empty(), "migration outcomes must all resolve");

    let accs: Vec<u64> =
        (0..n_tenants).map(|g| client.evaluate(g).expect("eval").to_bits()).collect();
    let (retries, dups) = (client.net_retries(), client.duplicates());
    client.shutdown_all().expect("shutdown");
    for h in handles {
        let report = h.join().expect("serve thread").expect("report");
        assert_eq!(report.dropped, 0);
    }
    (accs, retries, dups)
}

#[test]
fn net_recovering_chaos_is_bit_transparent_across_worker_counts() {
    for workers in [1usize, 3] {
        let (clean, clean_retries, _) = chaos_run(&FaultPlan::none(), workers);
        assert_eq!(clean_retries, 0, "the no-op plan must never trigger a retry");
        let (chaos, chaos_retries, _) = chaos_run(&FaultPlan::net_recovering(11), workers);
        assert_eq!(
            chaos, clean,
            "workers={workers}: accuracy bits drifted under transient network chaos"
        );
        assert!(
            chaos_retries >= 1,
            "workers={workers}: the plan injected nothing — the test proved nothing"
        );
    }
}

// ---------------------------------------------------------------------------
// 2. Exactly-once: duplicate delivery is acked, applied once
// ---------------------------------------------------------------------------

/// Drive one tenant through a fixed schedule over a RemoteClient with
/// explicit stamps; when `redeliver` is set, every Submit is sent TWICE
/// with the same stamp. Returns (accuracy bits, duplicate acks).
fn stamped_run(redeliver: bool) -> (u64, u64) {
    let (addrs, handles) = spawn_shards(1, 2, |_| {
        FleetConfig::builder(SPLIT).max_tenants(4).build().expect("config")
    });
    let (be, ds) = world();
    let retry = RetryPolicy { attempts: 4, base: Duration::from_millis(1) };
    let mut client =
        RemoteClient::connect_with(&addrs[0], &retry, Box::new(DirectNet), 9).expect("connect");

    client
        .admit(5, TenantConfig { n_lr: 64, seed: 500, ..TenantConfig::default() })
        .expect("admit");
    for (i, ev) in leg(&be, &ds, 5, 500, 0, 3).iter().enumerate() {
        // explicit seqs, clear of the ones FleetApi minted for admit
        let stamp = Stamp::new(9, 100 + i as u64);
        let first = client.submit_stamped(5, stamp, &ev.images, &ev.labels).expect("submit");
        assert!(
            matches!(first, tinycl::net::Reply::Queued),
            "first delivery must be Queued, got {first:?}"
        );
        if redeliver {
            let again =
                client.submit_stamped(5, stamp, &ev.images, &ev.labels).expect("redeliver");
            assert!(
                matches!(again, tinycl::net::Reply::Duplicate),
                "re-sent stamp must be acked Duplicate, got {again:?}"
            );
        }
    }
    let acc = client.evaluate(5).expect("eval").to_bits();
    let dups = client.duplicates();
    client.shutdown().expect("shutdown");
    for h in handles {
        h.join().expect("serve thread").expect("report");
    }
    (acc, dups)
}

#[test]
fn duplicate_delivery_is_acked_and_applied_exactly_once() {
    let (once, dups_once) = stamped_run(false);
    let (twice, dups_twice) = stamped_run(true);
    assert_eq!(dups_once, 0);
    assert_eq!(dups_twice, 3, "every redelivery must be acknowledged as a duplicate");
    assert_eq!(
        twice, once,
        "double delivery changed the tenant's trajectory — dedup failed"
    );
}

// ---------------------------------------------------------------------------
// 3a. Two-phase migration: failed restore rolls back, loses nothing
// ---------------------------------------------------------------------------

#[test]
fn failed_migration_rolls_back_via_the_source_tombstone() {
    // shard 1 has exactly one slot; filling it makes any restore there
    // fail AFTER the source has already drained — the abort path
    let caps = [16usize, 1];
    let (addrs, handles) = spawn_shards(2, 2, |shard| {
        FleetConfig::builder(SPLIT).max_tenants(caps[shard as usize]).build().expect("config")
    });
    let (be, ds) = world();
    let retry = RetryPolicy { attempts: 4, base: Duration::from_millis(1) };
    let plan = FaultPlan::none();
    let mut client = FleetClient::connect_with(&addrs, &retry, &plan, 21).expect("connect");

    // tenant 0 homes on shard 1 (fills its single slot), tenant 2 on 0
    assert_eq!(client.router().route(0), 1);
    assert_eq!(client.router().route(2), 0);
    for g in [0u64, 2] {
        client
            .admit(g, TenantConfig { n_lr: 64, seed: 700 + g, ..TenantConfig::default() })
            .expect("admit");
        for ev in leg(&be, &ds, g as TenantId, 700 + g, 0, 2) {
            client.submit(g, &ev.images, &ev.labels).expect("submit");
        }
    }
    let before = client.evaluate(2).expect("eval before").to_bits();

    match client.migrate(2, 1) {
        Err(FleetError::Internal(_) | FleetError::Admission(_)) => {}
        other => panic!("migration into a full shard must fail, got {other:?}"),
    }
    // rollback left no trace: route restored, nothing pending, nothing
    // recorded as a migration, and the tenant trains on bit-identically
    assert_eq!(client.router().route(2), 0, "failed migration must restore the pin");
    assert!(client.pending().is_empty());
    assert!(client.migrations().is_empty());
    assert_eq!(client.evaluate(2).expect("eval after").to_bits(), before);
    for ev in leg(&be, &ds, 2, 702, 2, 2) {
        client.submit(2, &ev.images, &ev.labels).expect("submit after rollback");
    }
    assert!(client.evaluate(2).expect("final eval").is_finite());
    assert!(client.evaluate(0).expect("bystander eval").is_finite());

    client.shutdown_all().expect("shutdown");
    for h in handles {
        h.join().expect("serve thread").expect("report");
    }
}

// ---------------------------------------------------------------------------
// 3b. Crash between Drain and Commit: the tombstone survives on disk
// ---------------------------------------------------------------------------

#[test]
fn orphaned_tombstone_is_adopted_and_resurrectable_after_restart() {
    let root = temp_root("tomb");
    let mk = || {
        let (be, ds) = world();
        let cfg = FleetConfig::builder(SPLIT)
            .max_tenants(4)
            .spill_dir(&root)
            .build()
            .expect("config");
        ShardServer::bind(be, Arc::new(ds), cfg, 0, 2, "127.0.0.1:0").expect("bind")
    };
    let retry = RetryPolicy { attempts: 4, base: Duration::from_millis(1) };
    let (be, ds) = world();

    // first incarnation: train a tenant, drain it (tombstone hits disk),
    // then tear the server down WITHOUT commit — the crash window
    let srv = mk();
    let addr = srv.local_addr().to_string();
    let h = std::thread::spawn(move || srv.serve());
    let mut client =
        RemoteClient::connect_with(&addr, &retry, Box::new(DirectNet), 31).expect("connect");
    client
        .admit(6, TenantConfig { n_lr: 64, seed: 600, ..TenantConfig::default() })
        .expect("admit");
    for ev in leg(&be, &ds, 6, 600, 0, 2) {
        client.submit(6, &ev.images, &ev.labels).expect("submit");
    }
    let before = client.evaluate(6).expect("eval").to_bits();
    let bytes = client.drain(6).expect("drain");
    assert!(!bytes.is_empty());
    client.shutdown().expect("shutdown");
    h.join().expect("serve thread").expect("report");
    assert!(
        root.join("tenant_g6.tomb").is_file(),
        "the uncommitted drain must leave its tombstone on disk"
    );

    // second incarnation, same spill dir: the orphan is adopted at bind
    // and MigrateAbort resurrects the tenant bit-for-bit
    let srv = mk();
    assert_eq!(srv.tombstoned(), vec![6], "restart must adopt the orphaned tombstone");
    let addr = srv.local_addr().to_string();
    let h = std::thread::spawn(move || srv.serve());
    let mut client =
        RemoteClient::connect_with(&addr, &retry, Box::new(DirectNet), 32).expect("connect");
    client.migrate_abort(6).expect("abort resurrects");
    assert_eq!(
        client.evaluate(6).expect("eval resurrected").to_bits(),
        before,
        "resurrection from the adopted tombstone must be bit-exact"
    );
    assert!(!root.join("tenant_g6.tomb").is_file(), "abort must clear the tombstone");
    client.shutdown().expect("shutdown");
    h.join().expect("serve thread").expect("report");
    let _ = std::fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------------------
// The full drill: real processes, scripted crash, supervisor failover
// ---------------------------------------------------------------------------

fn read_addrs(path: &Path) -> Option<Vec<String>> {
    let body = std::fs::read_to_string(path).ok()?;
    let addrs: Vec<String> =
        body.lines().map(str::trim).filter(|l| !l.is_empty()).map(str::to_string).collect();
    (!addrs.is_empty()).then_some(addrs)
}

fn recoverable(e: &FleetError) -> bool {
    matches!(e, FleetError::Io(_) | FleetError::Protocol(_) | FleetError::ShardDown { .. })
}

/// Retry `op` through shard death: mark the suspect down, re-read the
/// supervisor's addrs file, reconnect, go again.
fn with_failover<T>(
    client: &mut FleetClient,
    addrs_file: &Path,
    addrs: &mut Vec<String>,
    suspect: usize,
    mut op: impl FnMut(&mut FleetClient) -> Result<T, FleetError>,
) -> Result<T, FleetError> {
    let mut last = None;
    for _ in 0..150 {
        match op(client) {
            Ok(v) => return Ok(v),
            Err(e) if recoverable(&e) => {
                client.mark_down(suspect);
                std::thread::sleep(Duration::from_millis(100));
                if let Some(fresh) = read_addrs(addrs_file) {
                    if fresh.len() == addrs.len() {
                        *addrs = fresh;
                    }
                }
                let _ = client.re_resolve(addrs);
                last = Some(e);
            }
            Err(e) => return Err(e),
        }
    }
    Err(last.expect("at least one failing round"))
}

#[test]
#[ignore = "spawns real shard processes; run by CI's chaos-net-smoke job"]
fn supervised_fleet_survives_a_crash_mid_migration() {
    // children inherit this env and open the same world as tiny()
    std::env::set_var("TINYCL_SYNTH_FRAMES", "12");
    let root = temp_root("drill");
    let addrs_file = root.join("addrs");
    let mut cfg = SupervisorConfig::new(
        PathBuf::from(env!("CARGO_BIN_EXE_tinycl")),
        2,
        root.join("spill"),
        addrs_file.clone(),
    );
    // shard 1 dies on its FIRST served frame — which, by construction
    // of the traffic below, is the migration's Restore: the worst
    // moment (applied on the wire, never acknowledged)
    cfg.crash = Some((1, 1));
    let sup = ShardSupervisor::start(cfg).expect("supervisor start");
    let mut addrs = sup.addresses();
    let sup_thread = std::thread::spawn(move || sup.run());

    let (be, ds) = world();
    let retry = RetryPolicy { attempts: 6, base: Duration::from_millis(10) };
    let plan = FaultPlan::none();
    let mut client = FleetClient::connect_with(&addrs, &retry, &plan, 77).expect("connect");

    // every tenant homes on shard 0, so shard 1 serves NO frame until
    // the migration targets it — and nobody else dies with it
    let tenants = [2u64, 4, 5, 6];
    for &g in &tenants {
        assert_eq!(client.router().route(g), 0, "drill precondition: tenant {g} homes on 0");
        client
            .admit(g, TenantConfig { n_lr: 64, seed: 900 + g, ..TenantConfig::default() })
            .expect("admit");
        for ev in leg(&be, &ds, g as TenantId, 900 + g, 0, 2) {
            client.submit(g, &ev.images, &ev.labels).expect("submit leg 1");
        }
    }

    // migrate tenant 2 into the booby-trapped shard: the first restore
    // is applied and then the process exits(9) before replying; the
    // drill is the recovery — rollback to shard 0, supervisor restart,
    // retried migration onto the replacement
    with_failover(&mut client, &addrs_file, &mut addrs, 1, |c| c.migrate(2, 1))
        .expect("migration must eventually land on the replacement shard");
    assert_eq!(client.router().route(2), 1);
    assert!(client.pending().is_empty());

    // leg 2 everywhere (tenant 2 now served by the replacement)
    for &g in &tenants {
        for ev in leg(&be, &ds, g as TenantId, 900 + g, 2, 2) {
            let suspect = client.router().route(g);
            with_failover(&mut client, &addrs_file, &mut addrs, suspect, |c| {
                c.submit(g, &ev.images, &ev.labels)
            })
            .expect("submit leg 2");
        }
    }

    let mut lost = 0;
    for &g in &tenants {
        let suspect = client.router().route(g);
        match with_failover(&mut client, &addrs_file, &mut addrs, suspect, |c| c.evaluate(g)) {
            Ok(acc) => assert!(acc.is_finite()),
            Err(e) => {
                eprintln!("tenant {g} lost: {e}");
                lost += 1;
            }
        }
    }
    assert_eq!(lost, 0, "tenants_lost must be 0 under a single scripted crash");
    assert!(client.failovers() >= 1, "the client must have recovered the dead shard");

    client.shutdown_all().expect("shutdown");
    let report = sup_thread.join().expect("supervisor thread").expect("supervisor report");
    assert!(report.restarts >= 1, "the supervisor must have restarted the crashed shard");
    assert_eq!(report.mttr_ms.len(), report.restarts as usize, "every restart measures MTTR");
    let _ = std::fs::remove_dir_all(&root);
}
