//! Fleet-server integration tests over the tiny synthetic world:
//! determinism across worker counts, bit-for-bit N=1 parity with the
//! single-session path, governor behavior under pressure, and a
//! concurrency stress run hammering admit/serve/evict.

use tinycl::coordinator::{run_protocol, CLConfig, RunOptions};
use tinycl::fleet::{
    traffic, FleetConfig, FleetEvent, FleetServer, GovernorAction, InferRequest, TenantConfig,
};
use tinycl::runtime::synthetic::SyntheticSpec;
use tinycl::runtime::{open_shared_synthetic, Dataset, SharedBackend};

const SPLIT: usize = 15;

fn world() -> (SharedBackend, Dataset) {
    open_shared_synthetic(&SyntheticSpec::tiny()).expect("synthetic world")
}

/// Round-robin-interleaved per-tenant NICv2 schedules (the canonical
/// fleet traffic shape, shared with the example/bench/CLI via
/// `fleet::traffic`; tenant seeds follow the 100+id convention).
fn interleaved_events(
    be: &SharedBackend,
    ds: &Dataset,
    ids: &[usize],
    events_per_tenant: usize,
) -> Vec<FleetEvent> {
    let seeded: Vec<(usize, u64)> = ids.iter().map(|&id| (id, 100 + id as u64)).collect();
    traffic::interleaved_nicv2(&be.manifest().protocol, ds, &seeded, events_per_tenant)
}

/// Build a fleet of `n` tenants, serve `events_per_tenant` events each
/// with `workers`, and return every tenant's final accuracy.
fn run_fleet(
    be: &SharedBackend,
    ds: &Dataset,
    n: usize,
    events_per_tenant: usize,
    workers: usize,
    n_lr: usize,
    budget: usize,
) -> (FleetServer, Vec<usize>, Vec<f64>) {
    let mut cfg = FleetConfig::new(SPLIT);
    cfg.governor.budget_bytes = budget;
    cfg.governor.min_slots = 16;
    let server = FleetServer::new(be.clone(), cfg).expect("server");
    let (init_images, init_labels) = traffic::init_pool(ds);
    let init_latents = server.embed_images(&init_images).expect("embed");
    let mut ids = Vec::new();
    for t in 0..n {
        let tcfg = TenantConfig { n_lr, seed: 100 + t as u64, ..TenantConfig::default() };
        ids.push(server.admit_prepared(tcfg, &init_latents, &init_labels).expect("admit"));
    }
    let events = interleaved_events(be, ds, &ids, events_per_tenant);
    let n_events = events.len();
    let report = server.run(events, workers).expect("run");
    assert_eq!(report.events as usize, n_events, "all submitted events applied");
    assert_eq!(report.dropped, 0);
    let accs: Vec<f64> = ids
        .iter()
        .map(|&id| server.evaluate_tenant(ds, id).expect("eval"))
        .collect();
    (server, ids, accs)
}

#[test]
fn fleet_of_one_reproduces_run_protocol_bit_for_bit() {
    let (be, ds) = world();
    let events = 3;
    let cl = CLConfig {
        l: SPLIT,
        n_lr: 128,
        lr_bits: 8,
        int8_frozen: true,
        lr: 0.1,
        epochs: 2,
        seed: 100,
    };
    let solo = run_protocol(
        &*be,
        &ds,
        cl,
        RunOptions { eval_every: 0, max_events: events, verbose: false },
    )
    .expect("run_protocol");

    let server = FleetServer::new(be.clone(), FleetConfig::new(SPLIT)).expect("server");
    let (init_images, init_labels) = traffic::init_pool(&ds);
    let id = server
        .admit(
            TenantConfig { n_lr: 128, seed: 100, ..TenantConfig::default() },
            &init_images,
            &init_labels,
        )
        .expect("admit");
    // the exact schedule run_protocol derives from this seed
    // (traffic::schedule_seed pins the derivation; a drift fails this test)
    let evs = traffic::interleaved_nicv2(&be.manifest().protocol, &ds, &[(id, cl.seed)], events);
    server.run(evs, 2).expect("serve");
    let fleet_acc = server.evaluate_tenant(&ds, id).expect("eval");
    assert_eq!(
        fleet_acc, solo.final_acc,
        "fleet N=1 must be bit-identical to the single-session path"
    );
    // and the tenant actually learned over the protocol
    let m = server.tenant_metrics(id).expect("metrics");
    assert_eq!(m.events, events as u64);
}

#[test]
fn per_tenant_accuracy_identical_for_any_worker_count() {
    let (be, ds) = world();
    let budget = 64 * 1024 * 1024;
    let (_, _, acc1) = run_fleet(&be, &ds, 5, 2, 1, 96, budget);
    let (_, _, acc2) = run_fleet(&be, &ds, 5, 2, 2, 96, budget);
    let (_, _, acc4) = run_fleet(&be, &ds, 5, 2, 4, 96, budget);
    assert_eq!(acc1, acc2, "1 vs 2 workers");
    assert_eq!(acc1, acc4, "1 vs 4 workers");
    // different seeds genuinely differentiate tenants (not all equal by
    // construction)
    assert!(
        acc1.windows(2).any(|w| w[0] != w[1]),
        "tenants with different seeds should not all coincide: {acc1:?}"
    );
}

#[test]
fn governor_demotes_under_pressure_and_accounting_balances() {
    let (be, ds) = world();
    // budget sized so ~6 of 9 tenants fit raw: admissions 7..9 force
    // 8->7-bit demotions (and possibly shrinks) of the coldest tenants
    let probe = FleetServer::new(be.clone(), FleetConfig::new(SPLIT)).expect("probe");
    let per_tenant = probe.tenant_overhead_bytes()
        + tinycl::coordinator::replay::ReplayBuffer::bytes_for(1024, 256, 8);
    let budget = probe.shared_backbone_bytes() + per_tenant * 6 + per_tenant / 2;
    drop(probe);

    let (server, ids, accs) = run_fleet(&be, &ds, 9, 1, 2, 1024, budget);
    assert_eq!(ids.len(), 9, "every tenant admitted");
    let (admits, demotes, _shrinks, _evicts, rejects) = server.governor_tally();
    assert_eq!(admits, 9);
    assert_eq!(rejects, 0);
    assert!(demotes >= 1, "expected 8->7-bit demotions under this budget");
    assert!(
        server.bytes_in_use() <= budget,
        "budget violated: {} > {budget}",
        server.bytes_in_use()
    );
    // incremental accounting must match a from-scratch recompute
    assert_eq!(server.bytes_in_use(), server.recompute_bytes());
    // demoted tenants still function (finite accuracy, sane range)
    assert!(accs.iter().all(|a| (0.0..=1.0).contains(a)));
    // the log records real demotions with real byte deltas
    let demoted_bytes: usize = server
        .governor_log()
        .iter()
        .filter_map(|a| match a {
            GovernorAction::Demote { freed, from_bits: 8, to_bits: 7, .. } => Some(*freed),
            _ => None,
        })
        .sum();
    assert!(demoted_bytes > 0);
}

#[test]
fn evict_restore_preserves_learned_state_and_bytes() {
    let (be, ds) = world();
    let (server, ids, accs) = run_fleet(&be, &ds, 3, 2, 2, 96, 64 * 1024 * 1024);
    let victim = ids[1];
    let before_bytes = server.bytes_in_use();
    let snap = server.evict(victim).expect("evict");
    assert!(server.bytes_in_use() < before_bytes, "eviction must release bytes");
    assert_eq!(server.tenant_count(), 2);
    let back = server.restore(snap).expect("restore");
    assert_eq!(server.bytes_in_use(), before_bytes, "restore recharges the same bytes");
    let acc = server.evaluate_tenant(&ds, back).expect("eval");
    assert_eq!(acc, accs[1], "restored tenant must score exactly as before");
    assert_eq!(server.bytes_in_use(), server.recompute_bytes());
}

#[test]
fn batched_inference_matches_per_tenant_eval() {
    let (be, ds) = world();
    let (server, ids, _) = run_fleet(&be, &ds, 4, 1, 2, 96, 64 * 1024 * 1024);
    let img = ds.image_elems();
    let rows = 3;
    let mut probe = vec![0f32; rows * img];
    for r in 0..rows {
        ds.test_image_into(r, &mut probe[r * img..(r + 1) * img]);
    }
    // interleave requests so sorting/scatter is actually exercised
    let order = [ids[2], ids[0], ids[3], ids[1], ids[2]];
    let reqs: Vec<InferRequest> =
        order.iter().map(|&id| InferRequest { tenant: id, images: &probe }).collect();
    let batched = server.infer_batch(&reqs).expect("infer");
    assert_eq!(batched.len(), order.len());
    // reference: one request at a time (per-tenant solo path)
    for (i, &id) in order.iter().enumerate() {
        let solo = server
            .infer_batch(&[InferRequest { tenant: id, images: &probe }])
            .expect("solo infer");
        assert_eq!(
            batched[i], solo[0],
            "batched inference must be bit-identical to solo (req {i}, tenant {id})"
        );
    }
}

#[test]
fn concurrent_admit_serve_evict_stress() {
    let (be, ds) = world();
    let mut cfg = FleetConfig::new(SPLIT);
    cfg.governor.budget_bytes = 64 * 1024 * 1024;
    let server = FleetServer::new(be.clone(), cfg).expect("server");
    let (init_images, init_labels) = traffic::init_pool(&ds);
    let init_latents = server.embed_images(&init_images).expect("embed");
    // resident tenants that receive traffic (never evicted)
    let mut ids = Vec::new();
    for t in 0..4 {
        let tcfg = TenantConfig { n_lr: 96, seed: 100 + t as u64, ..TenantConfig::default() };
        ids.push(server.admit_prepared(tcfg, &init_latents, &init_labels).expect("admit"));
    }
    let events = interleaved_events(&be, &ds, &ids, 2);
    let n_events = events.len();
    std::thread::scope(|s| {
        // churn thread: admit + evict transient tenants while serving
        let churn = s.spawn(|| {
            let mut cycles = 0;
            for k in 0..10 {
                let tcfg =
                    TenantConfig { n_lr: 64, seed: 500 + k, ..TenantConfig::default() };
                match server.admit_prepared(tcfg, &init_latents, &init_labels) {
                    Ok(id) => {
                        let snap = server.evict(id).expect("evict transient");
                        let id2 = server.restore(snap).expect("restore transient");
                        server.evict(id2).expect("evict again");
                        cycles += 1;
                    }
                    Err(_) => {} // budget rejection is a legal outcome
                }
            }
            cycles
        });
        // inference thread: read-mostly traffic against live tenants
        let infer = s.spawn(|| {
            let img = ds.image_elems();
            let mut probe = vec![0f32; img];
            ds.test_image_into(0, &mut probe);
            let mut ok = 0;
            for _ in 0..10 {
                let reqs: Vec<InferRequest> =
                    ids.iter().map(|&id| InferRequest { tenant: id, images: &probe }).collect();
                if server.infer_batch(&reqs).is_ok() {
                    ok += 1;
                }
            }
            ok
        });
        let report = server.run(events, 3).expect("run under churn");
        assert_eq!(report.events as usize, n_events);
        assert_eq!(report.dropped, 0, "resident tenants were never evicted");
        assert!(churn.join().unwrap() >= 1, "churn thread made no progress");
        assert_eq!(infer.join().unwrap(), 10, "all inference batches succeeded");
    });
    // after the dust settles: invariants hold
    assert_eq!(server.tenant_count(), 4);
    assert!(server.bytes_in_use() <= 64 * 1024 * 1024);
    assert_eq!(server.bytes_in_use(), server.recompute_bytes());
    for &id in &ids {
        let acc = server.evaluate_tenant(&ds, id).expect("eval");
        assert!((0.0..=1.0).contains(&acc));
    }
}
