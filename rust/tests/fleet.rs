//! Fleet-server integration tests over the tiny synthetic world:
//! determinism across worker counts, bit-for-bit N=1 parity with the
//! single-session path, governor behavior under pressure, and a
//! concurrency stress run hammering admit/serve/evict.

use tinycl::coordinator::{run_protocol, CLConfig, RunOptions};
use tinycl::fleet::{
    traffic, FleetConfig, FleetEvent, FleetServer, GovernorAction, InferRequest, TenantConfig,
};
use tinycl::runtime::synthetic::SyntheticSpec;
use tinycl::runtime::{open_shared_synthetic, Dataset, SharedBackend};

const SPLIT: usize = 15;

fn world() -> (SharedBackend, Dataset) {
    open_shared_synthetic(&SyntheticSpec::tiny()).expect("synthetic world")
}

/// Round-robin-interleaved per-tenant NICv2 schedules (the canonical
/// fleet traffic shape, shared with the example/bench/CLI via
/// `fleet::traffic`; tenant seeds follow the 100+id convention).
fn interleaved_events(
    be: &SharedBackend,
    ds: &Dataset,
    ids: &[usize],
    events_per_tenant: usize,
) -> Vec<FleetEvent> {
    let seeded: Vec<(usize, u64)> = ids.iter().map(|&id| (id, 100 + id as u64)).collect();
    traffic::interleaved_nicv2(&be.manifest().protocol, ds, &seeded, events_per_tenant)
}

/// Build a fleet of `n` tenants, serve `events_per_tenant` events each
/// with `workers`, and return every tenant's final accuracy.
fn run_fleet(
    be: &SharedBackend,
    ds: &Dataset,
    n: usize,
    events_per_tenant: usize,
    workers: usize,
    n_lr: usize,
    budget: usize,
) -> (FleetServer, Vec<usize>, Vec<f64>) {
    let mut cfg = FleetConfig::new(SPLIT);
    cfg.governor.budget_bytes = budget;
    cfg.governor.min_slots = 16;
    let server = FleetServer::new(be.clone(), cfg).expect("server");
    let (init_images, init_labels) = traffic::init_pool(ds);
    let init_latents = server.embed_images(&init_images).expect("embed");
    let mut ids = Vec::new();
    for t in 0..n {
        let tcfg = TenantConfig { n_lr, seed: 100 + t as u64, ..TenantConfig::default() };
        ids.push(server.admit_prepared(tcfg, &init_latents, &init_labels).expect("admit"));
    }
    let events = interleaved_events(be, ds, &ids, events_per_tenant);
    let n_events = events.len();
    let report = server.run(events, workers).expect("run");
    assert_eq!(report.events as usize, n_events, "all submitted events applied");
    assert_eq!(report.dropped, 0);
    let accs: Vec<f64> = ids
        .iter()
        .map(|&id| server.evaluate_tenant(ds, id).expect("eval"))
        .collect();
    (server, ids, accs)
}

#[test]
fn fleet_of_one_reproduces_run_protocol_bit_for_bit() {
    let (be, ds) = world();
    let events = 3;
    let cl = CLConfig {
        l: SPLIT,
        n_lr: 128,
        lr_bits: 8,
        int8_frozen: true,
        lr: 0.1,
        epochs: 2,
        seed: 100,
    };
    let solo = run_protocol(
        &*be,
        &ds,
        cl,
        RunOptions { eval_every: 0, max_events: events, verbose: false },
    )
    .expect("run_protocol");

    let server = FleetServer::new(be.clone(), FleetConfig::new(SPLIT)).expect("server");
    let (init_images, init_labels) = traffic::init_pool(&ds);
    let id = server
        .admit(
            TenantConfig { n_lr: 128, seed: 100, ..TenantConfig::default() },
            &init_images,
            &init_labels,
        )
        .expect("admit");
    // the exact schedule run_protocol derives from this seed
    // (traffic::schedule_seed pins the derivation; a drift fails this test)
    let evs = traffic::interleaved_nicv2(&be.manifest().protocol, &ds, &[(id, cl.seed)], events);
    server.run(evs, 2).expect("serve");
    let fleet_acc = server.evaluate_tenant(&ds, id).expect("eval");
    assert_eq!(
        fleet_acc, solo.final_acc,
        "fleet N=1 must be bit-identical to the single-session path"
    );
    // and the tenant actually learned over the protocol
    let m = server.tenant_metrics(id).expect("metrics");
    assert_eq!(m.events, events as u64);
}

#[test]
fn per_tenant_accuracy_identical_for_any_worker_count() {
    let (be, ds) = world();
    let budget = 64 * 1024 * 1024;
    let (srv1, ids1, acc1) = run_fleet(&be, &ds, 5, 2, 1, 96, budget);
    let (_, _, acc2) = run_fleet(&be, &ds, 5, 2, 2, 96, budget);
    let (_, _, acc4) = run_fleet(&be, &ds, 5, 2, 4, 96, budget);
    assert_eq!(acc1, acc2, "1 vs 2 workers");
    assert_eq!(acc1, acc4, "1 vs 4 workers");
    // different seeds genuinely differentiate tenants (not all equal by
    // construction). Probe a CONTINUOUS per-tenant quantity — the final
    // training loss — rather than test accuracy: with only 2 tiny-world
    // events, several heads can coast at the same coarse accuracy while
    // their actual states (and schedules: each tenant trains different
    // classes) are thoroughly distinct.
    let losses: Vec<f64> = ids1
        .iter()
        .map(|&id| srv1.tenant_metrics(id).expect("metrics").last_loss)
        .collect();
    assert!(
        losses.windows(2).any(|w| w[0] != w[1]),
        "tenants with different seeds/schedules should not all coincide: {losses:?}"
    );
}

#[test]
fn governor_demotes_under_pressure_and_accounting_balances() {
    let (be, ds) = world();
    // budget sized so ~6 of 9 tenants fit raw: admissions 7..9 force
    // 8->7-bit demotions (and possibly shrinks) of the coldest tenants
    let probe = FleetServer::new(be.clone(), FleetConfig::new(SPLIT)).expect("probe");
    let per_tenant = probe.tenant_overhead_bytes()
        + tinycl::coordinator::replay::ReplayBuffer::bytes_for(1024, 256, 8);
    let budget = probe.shared_backbone_bytes() + per_tenant * 6 + per_tenant / 2;
    drop(probe);

    let (server, ids, accs) = run_fleet(&be, &ds, 9, 1, 2, 1024, budget);
    assert_eq!(ids.len(), 9, "every tenant admitted");
    let tally = server.governor_tally();
    assert_eq!(tally.admits, 9);
    assert_eq!(tally.rejects, 0);
    assert!(tally.demotes >= 1, "expected 8->7-bit demotions under this budget");
    assert!(
        server.bytes_in_use() <= budget,
        "budget violated: {} > {budget}",
        server.bytes_in_use()
    );
    // incremental accounting must match a from-scratch recompute
    assert_eq!(server.bytes_in_use(), server.recompute_bytes());
    // demoted tenants still function (finite accuracy, sane range)
    assert!(accs.iter().all(|a| (0.0..=1.0).contains(a)));
    // the log records real demotions with real byte deltas
    let demoted_bytes: usize = server
        .governor_log()
        .iter()
        .filter_map(|a| match a {
            GovernorAction::Demote { freed, from_bits: 8, to_bits: 7, .. } => Some(*freed),
            _ => None,
        })
        .sum();
    assert!(demoted_bytes > 0);
}

#[test]
fn evict_restore_preserves_learned_state_and_bytes() {
    let (be, ds) = world();
    let (server, ids, accs) = run_fleet(&be, &ds, 3, 2, 2, 96, 64 * 1024 * 1024);
    let victim = ids[1];
    let before_bytes = server.bytes_in_use();
    let snap = server.evict(victim).expect("evict");
    assert!(server.bytes_in_use() < before_bytes, "eviction must release bytes");
    assert_eq!(server.tenant_count(), 2);
    let back = server.restore(snap).expect("restore");
    assert_eq!(server.bytes_in_use(), before_bytes, "restore recharges the same bytes");
    let acc = server.evaluate_tenant(&ds, back).expect("eval");
    assert_eq!(acc, accs[1], "restored tenant must score exactly as before");
    assert_eq!(server.bytes_in_use(), server.recompute_bytes());
}

#[test]
fn batched_inference_matches_per_tenant_eval() {
    let (be, ds) = world();
    let (server, ids, _) = run_fleet(&be, &ds, 4, 1, 2, 96, 64 * 1024 * 1024);
    let img = ds.image_elems();
    let rows = 3;
    let mut probe = vec![0f32; rows * img];
    for r in 0..rows {
        ds.test_image_into(r, &mut probe[r * img..(r + 1) * img]);
    }
    // interleave requests so sorting/scatter is actually exercised
    let order = [ids[2], ids[0], ids[3], ids[1], ids[2]];
    let reqs: Vec<InferRequest> =
        order.iter().map(|&id| InferRequest { tenant: id, images: &probe }).collect();
    let batched = server.infer_batch(&reqs).expect("infer");
    assert_eq!(batched.len(), order.len());
    // reference: one request at a time (per-tenant solo path)
    for (i, &id) in order.iter().enumerate() {
        let solo = server
            .infer_batch(&[InferRequest { tenant: id, images: &probe }])
            .expect("solo infer");
        assert_eq!(
            batched[i], solo[0],
            "batched inference must be bit-identical to solo (req {i}, tenant {id})"
        );
    }
}

/// Unique per-test spill directory (std-only; no tempfile crate).
fn spill_dir(tag: &str) -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("tinycl_fleet_test_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Budget that fits exactly `fit` tenants of this shape (plus change),
/// probed from the server's own accounting constants.
fn budget_for(be: &SharedBackend, n_lr: usize, lr_bits: u8, fit: usize) -> usize {
    let probe = FleetServer::new(be.clone(), FleetConfig::new(SPLIT)).expect("probe");
    let per = probe.per_tenant_bytes(n_lr, lr_bits);
    probe.shared_backbone_bytes() + per * fit + per / 2
}

#[test]
fn spill_lazy_restore_matches_unspilled_fleet_bit_for_bit() {
    // THE tentpole invariant: a fleet that spills cold tenants to disk
    // and lazily restores them on traffic must produce bit-identical
    // per-tenant outcomes to a fleet that never felt pressure. Tenants
    // run at Q7 so the demote pass is inert and every relief action on
    // the spill arm is a lossless whole-tenant spill.
    let (be, ds) = world();
    let n = 3;
    let n_lr = 256;
    let dir = spill_dir("parity");
    let run = |spill: bool| -> (Vec<f64>, u64) {
        let mut cfg = FleetConfig::new(SPLIT);
        if spill {
            // room for ~2 of 3 tenants: the third admission spills the
            // coldest, and its first event lazily restores it
            cfg.governor.budget_bytes = budget_for(&be, n_lr, 7, 2);
            cfg.spill_dir = Some(dir.clone());
        }
        let server = FleetServer::new(be.clone(), cfg).expect("server");
        let (init_images, init_labels) = traffic::init_pool(&ds);
        let init_latents = server.embed_images(&init_images).expect("embed");
        let mut ids = Vec::new();
        for t in 0..n {
            let tcfg = TenantConfig {
                n_lr,
                lr_bits: 7,
                seed: 100 + t as u64,
                ..TenantConfig::default()
            };
            ids.push(server.admit_prepared(tcfg, &init_latents, &init_labels).expect("admit"));
        }
        if spill {
            let tally = server.governor_tally();
            assert!(tally.spills >= 1, "expected an admission-time spill: {tally:?}");
            assert_eq!(tally.demotes, 0, "Q7 tenants must not demote");
            assert_eq!(tally.shrinks, 0, "the cold tier must absorb all pressure");
        }
        let events = interleaved_events(&be, &ds, &ids, 2);
        let report = server.run(events, 2).expect("run");
        assert_eq!(report.dropped, 0);
        let accs: Vec<f64> =
            ids.iter().map(|&id| server.evaluate_tenant(&ds, id).expect("eval")).collect();
        (accs, report.lazy_restores)
    };
    let (reference, lazy_ref) = run(false);
    let (spilled, lazy_spill) = run(true);
    assert_eq!(lazy_ref, 0);
    assert!(lazy_spill >= 1, "the spilled tenant's event must trigger a lazy restore");
    assert_eq!(
        reference, spilled,
        "spill -> lazy restore -> train must be bit-identical to never-spilled"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn spill_preserves_sequence_parking_across_restore() {
    // a spilled tenant's slot keeps its submit counter and the snapshot
    // keeps next_seq: a second serving leg (continuing the tenant's
    // NICv2 schedule mid-stream) must line up exactly — and match the
    // same two-leg run on a never-spilled fleet
    let (be, ds) = world();
    let n_lr = 128;
    let dir = spill_dir("seq");
    let two_leg = |spill: bool| -> f64 {
        let mut cfg = FleetConfig::new(SPLIT);
        if spill {
            cfg.spill_dir = Some(dir.clone());
        }
        let server = FleetServer::new(be.clone(), cfg).expect("server");
        let (init_images, init_labels) = traffic::init_pool(&ds);
        let id = server
            .admit(
                TenantConfig { n_lr, lr_bits: 7, seed: 100, ..TenantConfig::default() },
                &init_images,
                &init_labels,
            )
            .expect("admit");
        let tenants = [(id, 100u64)];
        let m = be.manifest();
        // leg 1: events 0..2
        let leg1 = traffic::nicv2_window(&m.protocol, &ds, &tenants, 0, 2);
        server.run(leg1, 2).expect("leg 1");
        if spill {
            // cycle the tenant through the snapshot codec between the
            // legs (evict -> encode -> decode -> restore); the true
            // on-disk spill path is pinned by the parity test above
            let snap = server.evict(id).expect("evict");
            let bytes = tinycl::fleet::snapshot::encode(&snap);
            let back = tinycl::fleet::snapshot::decode(&bytes).expect("decode");
            let id2 = server.restore(back).expect("restore");
            assert_eq!(id2, id, "sole tenant returns to the sole free slot");
        }
        // leg 2: events 2..4 of the SAME schedule, continuing mid-stream
        let leg2 = traffic::nicv2_window(&m.protocol, &ds, &tenants, 2, 2);
        server.run(leg2, 2).expect("leg 2");
        server.evaluate_tenant(&ds, id).expect("eval")
    };
    let plain = two_leg(false);
    let cycled = two_leg(true);
    assert_eq!(plain, cycled, "snapshot codec round trip changed the trajectory");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn rebalance_promotes_and_readmits_under_watermarks() {
    let (be, ds) = world();
    let n_lr = 512;
    let dir = spill_dir("rebalance");
    let mut cfg = FleetConfig::new(SPLIT);
    // fits ~3 Q8 tenants; 5 admissions demote everyone and spill the
    // coldest past that
    cfg.governor.budget_bytes = budget_for(&be, n_lr, 8, 3);
    cfg.spill_dir = Some(dir.clone());
    let server = FleetServer::new(be.clone(), cfg).expect("server");
    let (init_images, init_labels) = traffic::init_pool(&ds);
    let init_latents = server.embed_images(&init_images).expect("embed");
    let mut ids = Vec::new();
    for t in 0..5 {
        let tcfg = TenantConfig { n_lr, seed: 100 + t as u64, ..TenantConfig::default() };
        ids.push(server.admit_prepared(tcfg, &init_latents, &init_labels).expect("admit"));
    }
    let tally = server.governor_tally();
    assert!(tally.demotes >= 1, "pressure must demote: {tally:?}");
    assert!(tally.spills >= 1, "pressure must spill: {tally:?}");
    assert_eq!(server.bytes_in_use(), server.recompute_bytes());
    // under pressure the watermark gate keeps rebalance a no-op
    let noop = server.rebalance().expect("rebalance under pressure");
    assert_eq!((noop.unspilled, noop.promoted), (0, 0), "must not boost above the low mark");
    // clear the pressure: evict residents until below the low watermark,
    // keeping one demoted (7-bit) tenant to showcase the promotion
    let low = (server.config().governor.low_watermark
        * server.config().governor.budget_bytes as f64) as usize;
    let keep = server
        .resident_ids()
        .into_iter()
        .find(|&id| server.tenant_metrics(id).unwrap().demotions > 0)
        .expect("a demoted resident exists");
    for id in server.resident_ids() {
        if id != keep && server.bytes_in_use() >= low {
            server.evict(id).expect("evict");
        }
    }
    assert!(server.bytes_in_use() < low);
    let boost = server.rebalance().expect("rebalance");
    assert!(boost.promoted >= 1, "expected a 7->8-bit promotion: {boost:?}");
    assert!(boost.unspilled >= 1, "expected a cold-tier readmission: {boost:?}");
    let m = server.tenant_metrics(keep).expect("metrics");
    assert!(m.promotions >= 1, "kept tenant must be promoted: {m:?}");
    // boosts stop at the high watermark and accounting still balances
    let high = (server.config().governor.high_watermark
        * server.config().governor.budget_bytes as f64) as usize;
    assert!(server.bytes_in_use() <= high, "rebalance overshot the high watermark");
    assert_eq!(server.bytes_in_use(), server.recompute_bytes());
    // promoted tenant still serves and scores sanely
    let acc = server.evaluate_tenant(&ds, keep).expect("eval");
    assert!((0.0..=1.0).contains(&acc));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupted_spill_file_quarantines_and_degrades_instead_of_failing() {
    // a lying disk (or bit rot) is discovered at restore time by the
    // snapshot checksum; the fleet survives it: quarantine the damaged
    // file, rebuild the tenant RESIDENT with an empty replay buffer
    // (`GovernorAction::Degrade` logs the loss explicitly) and keep
    // serving everyone — a tenant is never lost to a bad snapshot
    let (be, ds) = world();
    let n_lr = 256;
    let dir = spill_dir("corrupt");
    let mut cfg = FleetConfig::new(SPLIT);
    let budget = budget_for(&be, n_lr, 7, 2);
    cfg.governor.budget_bytes = budget;
    cfg.spill_dir = Some(dir.clone());
    let server = FleetServer::new(be.clone(), cfg).expect("server");
    let (init_images, init_labels) = traffic::init_pool(&ds);
    let init_latents = server.embed_images(&init_images).expect("embed");
    for t in 0..3 {
        let tcfg =
            TenantConfig { n_lr, lr_bits: 7, seed: 100 + t as u64, ..TenantConfig::default() };
        server.admit_prepared(tcfg, &init_latents, &init_labels).expect("admit");
    }
    let cold = server.spilled_ids();
    assert!(!cold.is_empty(), "expected an admission-time spill");
    let victim = cold[0];
    // flip one payload byte in the snapshot file
    let path = dir.join(format!("tenant_{victim}.tcsn"));
    let mut bytes = std::fs::read(&path).expect("spill file exists");
    let k = bytes.len() - 7;
    bytes[k] ^= 0x20;
    std::fs::write(&path, &bytes).expect("rewrite");
    // the lazy restore discovers the damage, quarantines and degrades —
    // the tenant still answers, from a rebuilt empty-replay state
    let acc = server.evaluate_tenant(&ds, victim).expect("degraded tenant still serves");
    assert!((0.0..=1.0).contains(&acc));
    assert!(
        dir.join(format!("tenant_{victim}.tcsn.quarantine")).is_file(),
        "damaged snapshot must be preserved for forensics, not deleted"
    );
    assert!(!path.exists(), "the damaged file must not stay on the restore path");
    assert!(server.resident_ids().contains(&victim), "degraded tenant is rebuilt resident");
    assert!(!server.spilled_ids().contains(&victim));
    let m = server.tenant_metrics(victim).expect("metrics survive the degrade");
    assert!(m.spills >= 1, "pre-degrade metrics kept: {m:?}");
    assert!(server.governor_tally().degrades >= 1);
    // the books balance and the rest of the fleet keeps serving
    assert!(server.bytes_in_use() <= budget);
    assert_eq!(server.bytes_in_use(), server.recompute_bytes());
    for id in server.resident_ids() {
        let acc = server.evaluate_tenant(&ds, id).expect("healthy tenant eval");
        assert!((0.0..=1.0).contains(&acc));
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn spill_restore_preserves_parked_events_bit_for_bit() {
    // property: a tenant snapshotted MID-REORDER (a parked early arrival
    // whose predecessor never landed) survives a real disk spill +
    // lazy-restore cycle bit-for-bit — parked payloads included
    let (be, ds) = world();
    let n_lr = 128;
    let dir = spill_dir("parked");
    let seed_server = FleetServer::new(be.clone(), FleetConfig::new(SPLIT)).expect("server");
    let (init_images, init_labels) = traffic::init_pool(&ds);
    let init_latents = seed_server.embed_images(&init_images).expect("embed");
    let id = seed_server
        .admit_prepared(
            TenantConfig { n_lr, lr_bits: 7, seed: 100, ..TenantConfig::default() },
            &init_latents,
            &init_labels,
        )
        .expect("admit");
    let m = be.manifest();
    let leg = traffic::nicv2_window(&m.protocol, &ds, &[(id, 100)], 0, 2);
    seed_server.run(leg, 2).expect("run");
    let mut snap = seed_server.evict(id).expect("evict");
    // an early arrival at next_seq + 1: its predecessor is missing, so it
    // stays parked across every cycle below
    let elems = snap.replay.latent_elems();
    let rows = 2;
    let latents: Vec<f32> = (0..rows * elems).map(|i| (i % 13) as f32 * 0.125).collect();
    snap.parked.push((snap.next_seq + 1, latents, vec![1, 3]));
    let bytes = tinycl::fleet::snapshot::encode(&snap);

    let cycle = |through_disk: bool| -> Vec<u8> {
        let mut cfg = FleetConfig::new(SPLIT);
        if through_disk {
            cfg.governor.budget_bytes = budget_for(&be, n_lr, 7, 1);
            cfg.spill_dir = Some(dir.clone());
        }
        let server = FleetServer::new(be.clone(), cfg).expect("server");
        let snap = tinycl::fleet::snapshot::decode(&bytes).expect("decode");
        let id = server.restore(snap).expect("restore");
        if through_disk {
            // a second admission squeezes the tenant out to disk...
            let other = server
                .admit_prepared(
                    TenantConfig { n_lr, lr_bits: 7, seed: 101, ..TenantConfig::default() },
                    &init_latents,
                    &init_labels,
                )
                .expect("admit");
            assert!(server.spilled_ids().contains(&id), "restored tenant is the coldest");
            // ...and an eval lazily restores it through the real file
            server.evaluate_tenant(&ds, id).expect("eval");
            assert!(server.spilled_ids().contains(&other), "the other tenant rotated out");
        }
        let mut out = server.evict(id).expect("evict");
        // the spill counter legitimately diverges between the two paths;
        // everything else must be bit-identical
        out.metrics.spills = 0;
        tinycl::fleet::snapshot::encode(&out)
    };
    let direct = cycle(false);
    let disked = cycle(true);
    assert_eq!(direct, disked, "disk cycle changed the snapshot (parked events?)");
    let back = tinycl::fleet::snapshot::decode(&disked).expect("decode");
    assert_eq!(back.parked.len(), 1, "the parked early arrival must survive");
    assert_eq!(back.parked[0].0, back.next_seq + 1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn crash_restart_recovers_spilled_tenants_from_disk() {
    // kill-and-restart: the spill registry is in-memory, so a new server
    // over the same spill directory must rebuild it by scanning the
    // snapshot files — and a recovered tenant must continue its NICv2
    // schedule mid-stream with the exact trajectory of a never-crashed
    // fleet (spills are lossless; per-tenant outcomes are independent of
    // other tenants' events)
    let (be, ds) = world();
    let n = 3;
    let n_lr = 256;
    let dir = spill_dir("recover");
    let m = be.manifest();
    let make = |dir: &std::path::PathBuf| -> FleetServer {
        let mut cfg = FleetConfig::new(SPLIT);
        cfg.governor.budget_bytes = budget_for(&be, n_lr, 7, 2);
        cfg.spill_dir = Some(dir.clone());
        FleetServer::new(be.clone(), cfg).expect("server")
    };
    // `survivor` is chosen by the crash run (only cold-tier tenants
    // survive a crash); the continuous run then replays the same
    // tenant's schedule — per-tenant outcomes are independent of other
    // tenants' traffic, so the accuracies must match bit-for-bit
    let run = |crash: bool, survivor_choice: Option<usize>| -> (usize, f64) {
        std::fs::remove_dir_all(&dir).ok();
        let server = make(&dir);
        let (init_images, init_labels) = traffic::init_pool(&ds);
        let init_latents = server.embed_images(&init_images).expect("embed");
        let mut ids = Vec::new();
        for t in 0..n {
            let tcfg = TenantConfig {
                n_lr,
                lr_bits: 7,
                seed: 100 + t as u64,
                ..TenantConfig::default()
            };
            ids.push(server.admit_prepared(tcfg, &init_latents, &init_labels).expect("admit"));
        }
        // leg 1: one event per tenant (lazy restores rotate the cold set)
        let leg1: Vec<FleetEvent> = {
            let seeded: Vec<(usize, u64)> = ids.iter().map(|&id| (id, 100 + id as u64)).collect();
            traffic::nicv2_window(&m.protocol, &ds, &seeded, 0, 1)
        };
        server.run(leg1, 2).expect("leg 1");
        let cold = server.spilled_ids();
        assert!(!cold.is_empty(), "someone must be in the cold tier after leg 1");
        let (server, survivor) = if crash {
            drop(server); // the crash: resident tenants die with the process
            let restarted = make(&dir);
            let tally = restarted.governor_tally();
            assert!(
                tally.recovers >= 1,
                "restart must re-register cold-tier snapshots: {tally:?}"
            );
            assert_eq!(
                restarted.spilled_ids(),
                cold,
                "recovery must rebuild exactly the pre-crash cold set"
            );
            assert_eq!(restarted.tenant_count(), 0, "resident tenants died with the process");
            assert!(restarted.spilled_disk_bytes() > 0, "disk charge recovered");
            (restarted, cold[0])
        } else {
            (server, survivor_choice.expect("continuous run replays the crash run's survivor"))
        };
        // leg 2: the survivor continues its schedule mid-stream
        let leg2 = traffic::nicv2_window(
            &m.protocol,
            &ds,
            &[(survivor, 100 + survivor as u64)],
            1,
            1,
        );
        let report = server.run(leg2, 2).expect("leg 2");
        assert_eq!(report.dropped, 0);
        let acc = server.evaluate_tenant(&ds, survivor).expect("eval survivor");
        let metrics = server.tenant_metrics(survivor).expect("metrics");
        assert_eq!(metrics.events, 2, "survivor applied both legs");
        (survivor, acc)
    };
    let (survivor, acc_crash) = run(true, None);
    let (_, acc_cont) = run(false, Some(survivor));
    assert_eq!(
        acc_cont, acc_crash,
        "a recovered tenant's trajectory must be bit-identical to the never-crashed run"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn crash_recovery_quarantines_corrupt_spill_files() {
    let (be, ds) = world();
    let n_lr = 256;
    let dir = spill_dir("quarantine");
    let mut cfg = FleetConfig::new(SPLIT);
    cfg.governor.budget_bytes = budget_for(&be, n_lr, 7, 1);
    cfg.spill_dir = Some(dir.clone());
    let server = FleetServer::new(be.clone(), cfg.clone()).expect("server");
    let (init_images, init_labels) = traffic::init_pool(&ds);
    let init_latents = server.embed_images(&init_images).expect("embed");
    for t in 0..3 {
        let tcfg = TenantConfig { n_lr, lr_bits: 7, seed: 100 + t, ..TenantConfig::default() };
        server.admit_prepared(tcfg, &init_latents, &init_labels).expect("admit");
    }
    let cold = server.spilled_ids();
    assert!(cold.len() >= 2, "need at least two cold tenants: {cold:?}");
    drop(server); // crash
    // corrupt one snapshot, drop junk + a stale partial write alongside
    let victim = cold[0];
    let victim_path = dir.join(format!("tenant_{victim}.tcsn"));
    let mut bytes = std::fs::read(&victim_path).expect("spill file");
    let k = bytes.len() - 9;
    bytes[k] ^= 0x10;
    std::fs::write(&victim_path, &bytes).expect("rewrite");
    std::fs::write(dir.join("tenant_9999.tcsn"), b"not a snapshot").unwrap();
    std::fs::write(dir.join("tenant_1.tcsn.tmp"), b"partial").unwrap();
    let restarted = FleetServer::new(be.clone(), cfg).expect("restart");
    // the corrupt file is quarantined with its bytes preserved...
    assert!(!restarted.spilled_ids().contains(&victim), "corrupt snapshot must not register");
    assert!(
        dir.join(format!("tenant_{victim}.tcsn.quarantine")).is_file(),
        "corrupt snapshot must be moved aside, not deleted"
    );
    assert!(
        dir.join("tenant_9999.tcsn.quarantine").is_file(),
        "out-of-range tenant id must be quarantined"
    );
    assert!(!dir.join("tenant_1.tcsn.tmp").exists(), "partial writes are swept");
    // ...and every healthy snapshot recovered and still serves
    let healthy: Vec<usize> = cold[1..].to_vec();
    assert_eq!(restarted.spilled_ids(), healthy);
    for id in healthy {
        let acc = restarted.evaluate_tenant(&ds, id).expect("recovered tenant serves");
        assert!((0.0..=1.0).contains(&acc));
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn concurrent_admit_serve_evict_stress() {
    let (be, ds) = world();
    let mut cfg = FleetConfig::new(SPLIT);
    cfg.governor.budget_bytes = 64 * 1024 * 1024;
    let server = FleetServer::new(be.clone(), cfg).expect("server");
    let (init_images, init_labels) = traffic::init_pool(&ds);
    let init_latents = server.embed_images(&init_images).expect("embed");
    // resident tenants that receive traffic (never evicted)
    let mut ids = Vec::new();
    for t in 0..4 {
        let tcfg = TenantConfig { n_lr: 96, seed: 100 + t as u64, ..TenantConfig::default() };
        ids.push(server.admit_prepared(tcfg, &init_latents, &init_labels).expect("admit"));
    }
    let events = interleaved_events(&be, &ds, &ids, 2);
    let n_events = events.len();
    std::thread::scope(|s| {
        // churn thread: admit + evict transient tenants while serving
        let churn = s.spawn(|| {
            let mut cycles = 0;
            for k in 0..10 {
                let tcfg =
                    TenantConfig { n_lr: 64, seed: 500 + k, ..TenantConfig::default() };
                match server.admit_prepared(tcfg, &init_latents, &init_labels) {
                    Ok(id) => {
                        let snap = server.evict(id).expect("evict transient");
                        let id2 = server.restore(snap).expect("restore transient");
                        server.evict(id2).expect("evict again");
                        cycles += 1;
                    }
                    Err(_) => {} // budget rejection is a legal outcome
                }
            }
            cycles
        });
        // inference thread: read-mostly traffic against live tenants
        let infer = s.spawn(|| {
            let img = ds.image_elems();
            let mut probe = vec![0f32; img];
            ds.test_image_into(0, &mut probe);
            let mut ok = 0;
            for _ in 0..10 {
                let reqs: Vec<InferRequest> =
                    ids.iter().map(|&id| InferRequest { tenant: id, images: &probe }).collect();
                if server.infer_batch(&reqs).is_ok() {
                    ok += 1;
                }
            }
            ok
        });
        let report = server.run(events, 3).expect("run under churn");
        assert_eq!(report.events as usize, n_events);
        assert_eq!(report.dropped, 0, "resident tenants were never evicted");
        assert!(churn.join().unwrap() >= 1, "churn thread made no progress");
        assert_eq!(infer.join().unwrap(), 10, "all inference batches succeeded");
    });
    // after the dust settles: invariants hold
    assert_eq!(server.tenant_count(), 4);
    assert!(server.bytes_in_use() <= 64 * 1024 * 1024);
    assert_eq!(server.bytes_in_use(), server.recompute_bytes());
    for &id in &ids {
        let acc = server.evaluate_tenant(&ds, id).expect("eval");
        assert!((0.0..=1.0).contains(&acc));
    }
}

#[test]
fn async_eval_matches_sync_eval_bit_for_bit_on_a_quiesced_server() {
    let (be, ds) = world();
    let (server, ids, sync_accs) = run_fleet(&be, &ds, 4, 2, 2, 96, 64 * 1024 * 1024);
    // the background sweep scores the SAME quiesced tenants over the
    // same shared test embedding -> identical bits, submission order
    let async_accs = server.evaluate_tenants_async(&ds, &ids).expect("submit").wait().expect("eval");
    assert_eq!(
        async_accs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        sync_accs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "pooled eval must be bit-identical to sequential evaluate_tenant calls"
    );
}

#[test]
fn eval_sweep_does_not_block_dispatch() {
    // the latency pin for ISSUE 7's async-eval contract: launch a
    // full-fleet eval sweep, then drive a serving run WHILE it is in
    // flight. The low-lane cap leaves at least one pool worker for
    // high-lane serving tasks, so the run must complete every event
    // (structurally: no deadlock, no starvation) with submit latency
    // bounded well under the eval sweep's own wall time.
    let (be, ds) = world();
    let mut cfg = FleetConfig::new(SPLIT);
    cfg.governor.budget_bytes = 64 * 1024 * 1024;
    cfg.governor.min_slots = 16;
    let server = FleetServer::new(be.clone(), cfg).expect("server");
    let (init_images, init_labels) = traffic::init_pool(&ds);
    let init_latents = server.embed_images(&init_images).expect("embed");
    let mut ids = Vec::new();
    for t in 0..6 {
        let tcfg = TenantConfig { n_lr: 96, seed: 100 + t as u64, ..TenantConfig::default() };
        ids.push(server.admit_prepared(tcfg, &init_latents, &init_labels).expect("admit"));
    }
    let events = interleaved_events(&be, &ds, &ids, 2);
    let n_events = events.len();

    let pool = tinycl::exec::global();
    let spawns0 = pool.spawn_count();
    // a sweep per tenant, launched BEFORE the run so the low lane is
    // saturated when serving starts
    let sweep = server.evaluate_tenants_async(&ds, &ids).expect("submit sweep");
    let t0 = std::time::Instant::now();
    let report = server.run(events, 2).expect("run during eval sweep");
    let serve_wall = t0.elapsed();
    assert_eq!(report.events as usize, n_events, "every event dispatched during the sweep");
    assert_eq!(report.dropped, 0);
    // generous structural bound: if the sweep had parked the serving
    // lane (the pre-pool failure mode was a full eval running inline on
    // a worker), the tiny-world run would stall for the whole sweep and
    // the suite's timeout would trip; 60 s only guards regressions into
    // outright blocking
    assert!(
        serve_wall < std::time::Duration::from_secs(60),
        "serving stalled behind the eval sweep: {serve_wall:?}"
    );
    let accs = sweep.wait().expect("sweep finishes");
    assert_eq!(accs.len(), ids.len());
    for acc in accs {
        assert!((0.0..=1.0).contains(&acc));
    }
    assert_eq!(
        pool.spawn_count(),
        spawns0,
        "a serving run plus a concurrent eval sweep must spawn zero threads"
    );
}
