//! Integration: full QLR-CL protocol behaviour — the paper's learning
//! dynamics at mini scale. Runs unconditionally on the default test
//! environment (PJRT over artifacts when present, native synthetic
//! otherwise); thresholds were calibrated with tools/native_mirror.py.

use tinycl::coordinator::{run_protocol_cached, CLConfig, EvalLatentCache, RunOptions};
use tinycl::runtime::{synthetic, Backend, Dataset, Manifest, NativeBackend, Runtime};

fn env() -> (Box<dyn Backend>, Dataset) {
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        let rt = Runtime::open(&dir).expect("open runtime");
        let ds = Dataset::load(Runtime::manifest(&rt)).expect("load dataset");
        return (Box::new(rt), ds);
    }
    let (m, ds) = synthetic::generate(&synthetic::SyntheticSpec::tiny()).expect("synthetic env");
    (Box::new(NativeBackend::new(m).expect("native backend")), ds)
}

fn opts(events: usize) -> RunOptions {
    RunOptions { eval_every: 0, max_events: events, verbose: false }
}

fn accuracy_improves_over_events(be: &dyn Backend, ds: &Dataset, cache: &EvalLatentCache) {
    let cfg =
        CLConfig { l: 13, n_lr: 256, lr_bits: 8, int8_frozen: true, seed: 1, ..Default::default() };
    let r = run_protocol_cached(be, ds, cfg, opts(12), Some(cache)).unwrap();
    assert!(
        r.final_acc > r.initial_acc + 0.05,
        "CL should lift accuracy: {:.3} -> {:.3}",
        r.initial_acc, r.final_acc
    );
    assert_eq!(r.events.len(), 12);
    assert!(r.events.iter().all(|e| e.steps > 0));
}

fn replay_prevents_catastrophic_forgetting(
    be: &dyn Backend,
    ds: &Dataset,
    cache: &EvalLatentCache,
) {
    // with replays disabled-by-starvation (tiny buffer) the model should
    // not do better than with a healthy buffer, other things equal
    let mk = |n_lr| {
        CLConfig { l: 13, n_lr, lr_bits: 8, int8_frozen: true, seed: 2, ..Default::default() }
    };
    let big = run_protocol_cached(be, ds, mk(256), opts(12), Some(cache)).unwrap();
    let tiny = run_protocol_cached(be, ds, mk(8), opts(12), Some(cache)).unwrap();
    assert!(
        big.final_acc >= tiny.final_acc - 0.05,
        "more replay memory should not hurt: {} (256) vs {} (8)",
        big.final_acc, tiny.final_acc
    );
}

fn six_bit_replays_do_not_win(be: &dyn Backend, ds: &Dataset, cache: &EvalLatentCache) {
    // paper: below UINT-7 accuracy degrades rapidly; at mini scale we
    // only require that coarser replays never come out ahead
    let mk = |bits| CLConfig {
        l: 13,
        n_lr: 256,
        lr_bits: bits,
        int8_frozen: true,
        seed: 4,
        ..Default::default()
    };
    let u8_ = run_protocol_cached(be, ds, mk(8), opts(12), Some(cache)).unwrap();
    let u6 = run_protocol_cached(be, ds, mk(6), opts(12), Some(cache)).unwrap();
    assert!(
        u8_.final_acc >= u6.final_acc - 0.1,
        "UINT-8 should not lose to UINT-6: {} vs {}",
        u8_.final_acc, u6.final_acc
    );
}

fn runs_are_deterministic_per_seed(be: &dyn Backend, ds: &Dataset, cache: &EvalLatentCache) {
    let cfg =
        CLConfig { l: 15, n_lr: 64, lr_bits: 8, int8_frozen: true, seed: 7, ..Default::default() };
    let a = run_protocol_cached(be, ds, cfg, opts(6), Some(cache)).unwrap();
    let b = run_protocol_cached(be, ds, cfg, opts(6), Some(cache)).unwrap();
    assert_eq!(a.final_acc, b.final_acc);
    let la: Vec<f64> = a.events.iter().map(|e| e.mean_loss).collect();
    let lb: Vec<f64> = b.events.iter().map(|e| e.mean_loss).collect();
    assert_eq!(la, lb, "per-event losses must be bit-identical per seed");
    // different seed -> different schedule -> different trajectory
    let c = run_protocol_cached(
        be, ds, CLConfig { seed: 8, ..cfg }, opts(6), Some(cache)
    ).unwrap();
    let lc: Vec<f64> = c.events.iter().map(|e| e.mean_loss).collect();
    assert_ne!(la, lc);
}

fn lr_storage_matches_config(be: &dyn Backend, ds: &Dataset, cache: &EvalLatentCache) {
    let latent = be.manifest().latent_info(13).unwrap().elems();
    for (bits, expect) in [(8u8, 256 * latent), (7, 256 * latent * 7 / 8), (32, 256 * latent * 4)] {
        let cfg = CLConfig {
            l: 13,
            n_lr: 256,
            lr_bits: bits,
            int8_frozen: bits != 32,
            seed: 1,
            ..Default::default()
        };
        let r = run_protocol_cached(be, ds, cfg, opts(2), Some(cache)).unwrap();
        assert_eq!(r.lr_storage_bytes, expect, "bits={bits}");
    }
}

fn new_classes_enter_replay_buffer(be: &dyn Backend, ds: &Dataset) {
    use tinycl::coordinator::Session;
    let cfg =
        CLConfig { l: 13, n_lr: 128, lr_bits: 8, int8_frozen: true, seed: 5, ..Default::default() };
    let mut s = Session::new(be, ds, cfg).unwrap();
    s.run_event(ds, 7, 0).unwrap();
    s.run_event(ds, 8, 1).unwrap();
    let hist = s.replay.class_histogram(be.manifest().num_classes);
    assert!(hist[7] > 0, "class 7 latents should be in the buffer: {hist:?}");
    assert!(hist[8] > 0, "class 8 latents should be in the buffer: {hist:?}");
    // and initial classes were not wiped out
    assert!(hist[..4].iter().sum::<usize>() > 0, "initial classes evicted: {hist:?}");
}

/// One suite, sequential (see integration_runtime.rs); the shared
/// [`EvalLatentCache`] keeps the frozen eval pass to one per (l, mode).
#[test]
fn protocol_suite() {
    let (be, ds) = env();
    let cache = EvalLatentCache::new();
    eprintln!("[protocol_suite] backend: {}", be.platform());
    eprintln!("-- accuracy_improves_over_events");
    accuracy_improves_over_events(&*be, &ds, &cache);
    eprintln!("-- replay_prevents_catastrophic_forgetting");
    replay_prevents_catastrophic_forgetting(&*be, &ds, &cache);
    eprintln!("-- six_bit_replays_do_not_win");
    six_bit_replays_do_not_win(&*be, &ds, &cache);
    eprintln!("-- runs_are_deterministic_per_seed");
    runs_are_deterministic_per_seed(&*be, &ds, &cache);
    eprintln!("-- lr_storage_matches_config");
    lr_storage_matches_config(&*be, &ds, &cache);
    eprintln!("-- new_classes_enter_replay_buffer");
    new_classes_enter_replay_buffer(&*be, &ds);
}
