//! Integration: full QLR-CL protocol behaviour — the paper's learning
//! dynamics at mini scale. Self-skips without artifacts.

use tinycl::coordinator::{run_protocol_cached, CLConfig, EvalLatentCache, RunOptions};
use tinycl::runtime::{Dataset, Manifest, Runtime};

/// One process-wide Runtime + Dataset (see integration_runtime.rs note).
fn env() -> Option<(&'static Runtime, &'static Dataset)> {
    unsafe {
        static mut ENV: Option<(&'static Runtime, &'static Dataset)> = None;
        if ENV.is_none() {
            let dir = Manifest::default_dir();
            if !dir.join("manifest.json").exists() {
                eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
                return None;
            }
            let rt: &'static Runtime = Box::leak(Box::new(Runtime::open(&dir).expect("open runtime")));
            let ds: &'static Dataset = Box::leak(Box::new(Dataset::load(rt.manifest()).expect("load dataset")));
            ENV = Some((rt, ds));
        }
        ENV
    }
}

fn opts(events: usize) -> RunOptions {
    RunOptions { eval_every: 0, max_events: events, verbose: false }
}

fn accuracy_improves_over_events() {
    let Some((rt, ds)) = env() else { return };
    let cache = EvalLatentCache::new();
    let cfg = CLConfig { l: 13, n_lr: 256, lr_bits: 8, int8_frozen: true, seed: 1, ..Default::default() };
    let r = run_protocol_cached(rt, ds, cfg, opts(12), Some(&cache)).unwrap();
    assert!(
        r.final_acc > r.initial_acc + 0.03,
        "CL should lift accuracy: {:.3} -> {:.3}",
        r.initial_acc, r.final_acc
    );
    assert_eq!(r.events.len(), 12);
    assert!(r.events.iter().all(|e| e.steps > 0));
}

fn replay_prevents_catastrophic_forgetting() {
    // with replays disabled-by-starvation (tiny buffer) the model should
    // do visibly worse than with a healthy buffer, other things equal
    let Some((rt, ds)) = env() else { return };
    let cache = EvalLatentCache::new();
    let mk = |n_lr| CLConfig { l: 13, n_lr, lr_bits: 8, int8_frozen: true, seed: 2, ..Default::default() };
    let big = run_protocol_cached(rt, ds, mk(256), opts(12), Some(&cache)).unwrap();
    let tiny = run_protocol_cached(rt, ds, mk(8), opts(12), Some(&cache)).unwrap();
    assert!(
        big.final_acc >= tiny.final_acc - 0.02,
        "more replay memory should not hurt: {} (256) vs {} (8)",
        big.final_acc, tiny.final_acc
    );
}

fn six_bit_replays_degrade() {
    // paper: below UINT-7 accuracy degrades rapidly (UINT-6 often fails
    // to converge); at mini scale we only require a visible ordering
    let Some((rt, ds)) = env() else { return };
    let cache = EvalLatentCache::new();
    let mk = |bits| CLConfig { l: 13, n_lr: 256, lr_bits: bits, int8_frozen: true, seed: 4, ..Default::default() };
    let u8_ = run_protocol_cached(rt, ds, mk(8), opts(12), Some(&cache)).unwrap();
    let u6 = run_protocol_cached(rt, ds, mk(6), opts(12), Some(&cache)).unwrap();
    assert!(
        u8_.final_acc >= u6.final_acc - 0.02,
        "UINT-8 should beat UINT-6: {} vs {}",
        u8_.final_acc, u6.final_acc
    );
}

fn runs_are_deterministic_per_seed() {
    let Some((rt, ds)) = env() else { return };
    let cache = EvalLatentCache::new();
    let cfg = CLConfig { l: 15, n_lr: 64, lr_bits: 8, int8_frozen: true, seed: 7, ..Default::default() };
    let a = run_protocol_cached(rt, ds, cfg, opts(6), Some(&cache)).unwrap();
    let b = run_protocol_cached(rt, ds, cfg, opts(6), Some(&cache)).unwrap();
    assert_eq!(a.final_acc, b.final_acc);
    let la: Vec<f64> = a.events.iter().map(|e| e.mean_loss).collect();
    let lb: Vec<f64> = b.events.iter().map(|e| e.mean_loss).collect();
    assert_eq!(la, lb, "per-event losses must be bit-identical per seed");
    // different seed -> different schedule -> different trajectory
    let c = run_protocol_cached(
        rt, ds, CLConfig { seed: 8, ..cfg }, opts(6), Some(&cache)
    ).unwrap();
    let lc: Vec<f64> = c.events.iter().map(|e| e.mean_loss).collect();
    assert_ne!(la, lc);
}

fn lr_storage_matches_config() {
    let Some((rt, ds)) = env() else { return };
    let cache = EvalLatentCache::new();
    let latent = rt.manifest().latent_info(13).unwrap().elems();
    for (bits, expect) in [(8u8, 256 * latent), (7, 256 * latent * 7 / 8), (32, 256 * latent * 4)] {
        let cfg = CLConfig { l: 13, n_lr: 256, lr_bits: bits, int8_frozen: bits != 32, seed: 1, ..Default::default() };
        let r = run_protocol_cached(rt, ds, cfg, opts(2), Some(&cache)).unwrap();
        assert_eq!(r.lr_storage_bytes, expect, "bits={bits}");
    }
}

fn new_classes_enter_replay_buffer() {
    let Some((rt, ds)) = env() else { return };
    use tinycl::coordinator::Session;
    let cfg = CLConfig { l: 13, n_lr: 128, lr_bits: 8, int8_frozen: true, seed: 5, ..Default::default() };
    let mut s = Session::new(rt, ds, cfg).unwrap();
    s.run_event(ds, 7, 0).unwrap();
    s.run_event(ds, 8, 1).unwrap();
    let hist = s.replay.class_histogram(rt.manifest().num_classes);
    assert!(hist[7] > 0, "class 7 latents should be in the buffer: {hist:?}");
    assert!(hist[8] > 0, "class 8 latents should be in the buffer: {hist:?}");
    // and initial classes were not wiped out
    assert!(hist[..4].iter().sum::<usize>() > 0, "initial classes evicted: {hist:?}");
}

/// PJRT CPU in this xla_extension build tolerates neither multiple
/// clients per process nor cross-thread buffer traffic, so the scenarios
/// above run sequentially on one thread under a single client.
#[test]
fn protocol_suite() {
    eprintln!("-- accuracy_improves_over_events");
    accuracy_improves_over_events();
    eprintln!("-- replay_prevents_catastrophic_forgetting");
    replay_prevents_catastrophic_forgetting();
    eprintln!("-- six_bit_replays_degrade");
    six_bit_replays_degrade();
    eprintln!("-- runs_are_deterministic_per_seed");
    runs_are_deterministic_per_seed();
    eprintln!("-- lr_storage_matches_config");
    lr_storage_matches_config();
    eprintln!("-- new_classes_enter_replay_buffer");
    new_classes_enter_replay_buffer();
}
