//! Integration: the faults-off network frame path performs ZERO heap
//! allocations at steady state. A counting global allocator wraps the
//! system one; after one warm-up round trip, repeated
//! encode → frame-write → frame-read → decode cycles over a reused
//! scratch buffer must not allocate once.
//!
//! This is the wire-layer sibling of `alloc_hot_path.rs` and the
//! acceptance gate for the chaos shim: `DirectNet` adds no plan checks
//! and the framing helpers (`encode_request_into`, `read_frame_into`)
//! reuse caller-owned buffers, so a fault-free client at steady state
//! costs the same whether the chaos layer exists or not.
//!
//! One test per binary on purpose: the allocation counter is global.

use std::alloc::{GlobalAlloc, Layout, System};
use std::io::Cursor;
use std::sync::atomic::{AtomicU64, Ordering};

use tinycl::net::frame::{
    decode_reply, encode_reply, encode_request_into, read_frame_into, write_frame, Reply, Request,
    Stamp,
};

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_frame_path_does_not_allocate() {
    // a realistic Submit: 32 rows of a 64-float latent each, stamped
    let images: Vec<f32> = (0..32 * 64).map(|i| (i % 251) as f32 / 251.0).collect();
    let labels: Vec<i32> = (0..32).map(|i| i % 10).collect();
    let req = Request::Submit { tenant: 5, stamp: Stamp::new(7, 1), images, labels };
    // the scalar replies a steady-state client sees (no payload vecs)
    let queued_wire = {
        let mut w = Vec::new();
        write_frame(&mut w, &encode_reply(&Reply::Queued)).unwrap();
        w
    };

    let mut send_buf = Vec::new();
    let mut frame_buf = Vec::new();
    let mut recv_buf = Vec::new();

    // warm up: every reused buffer reaches its steady-state capacity
    encode_request_into(&req, &mut send_buf);
    frame_buf.clear();
    write_frame(&mut frame_buf, &send_buf).unwrap();
    assert!(read_frame_into(&mut Cursor::new(queued_wire.as_slice()), &mut recv_buf).unwrap());
    assert_eq!(decode_reply(&recv_buf).unwrap(), Reply::Queued);

    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..1000 {
        // client send path: payload into the reused scratch, then the
        // length-prefixed frame into a reused sink
        encode_request_into(&req, &mut send_buf);
        frame_buf.clear();
        write_frame(&mut frame_buf, &send_buf).unwrap();
        // client receive path: frame into the reused buffer, scalar decode
        let got =
            read_frame_into(&mut Cursor::new(queued_wire.as_slice()), &mut recv_buf).unwrap();
        assert!(got);
        match decode_reply(&recv_buf).unwrap() {
            Reply::Queued => {}
            other => panic!("unexpected reply {other:?}"),
        }
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state frame path allocated {} times in 1000 round trips",
        after - before
    );
}
