//! Integration: artifacts -> PJRT -> numerics. Requires `make artifacts`;
//! every test self-skips (with a loud note) when artifacts are missing so
//! `cargo test` stays runnable on a fresh clone.

use tinycl::coordinator::{CLConfig, Session};
use tinycl::runtime::{Dataset, Manifest, Runtime};

/// One process-wide Runtime: creating several PjRtClients in one process
/// destabilizes this xla_extension build. Only called under TEST_LOCK.
fn runtime() -> Option<&'static Runtime> {
    unsafe {
        static mut RT: Option<&'static Runtime> = None;
        if RT.is_none() {
            let dir = Manifest::default_dir();
            if !dir.join("manifest.json").exists() {
                eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
                return None;
            }
            RT = Some(Box::leak(Box::new(Runtime::open(&dir).expect("open runtime"))));
        }
        RT
    }
}

fn manifest_is_consistent() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest();
    assert_eq!(m.arch.len(), 15, "micronet conv layers");
    assert!(m.splits.len() >= 3);
    for &l in &m.splits {
        let split = m.split(l).unwrap();
        let lat = m.latent_info(l).unwrap();
        assert!(lat.elems() > 0);
        assert!(lat.a_max_int8 > 0.0 && lat.a_max_fp32 > 0.0);
        assert!(!split.param_tensors.is_empty());
        assert!(split.n_param_elems() > 0);
    }
    // a_max calibration: one per conv layer
    assert_eq!(m.a_max.len(), 15);
    assert!(m.a_max.iter().all(|&a| a > 0.0));
}

fn dataset_loads_and_validates() {
    let Some(rt) = runtime() else { return };
    let ds = Dataset::load(rt.manifest()).unwrap();
    assert_eq!(ds.n_train(), 3600);
    assert_eq!(ds.n_test(), 1200);
    // every (class, session) event has exactly frames_per_session images
    let p = &rt.manifest().protocol;
    for class in 0..p.n_classes {
        for session in 0..p.train_sessions {
            assert_eq!(
                ds.event_indices(class, session).len(),
                p.frames_per_session,
                "event ({class},{session})"
            );
        }
    }
    // initial set: 4 classes x 2 sessions x 60 frames
    assert_eq!(ds.initial_indices().len(), 4 * 2 * 60);
}

fn frozen_modules_execute_and_seed_buffer() {
    let Some(rt) = runtime() else { return };
    let ds = Dataset::load(rt.manifest()).unwrap();
    let m = rt.manifest();
    let l = *m.splits.last().unwrap();
    let cfg = CLConfig { l, n_lr: 64, lr_bits: 8, int8_frozen: true, ..Default::default() };
    let session = Session::new(rt, &ds, cfg).expect("session");
    // the replay buffer was seeded through the frozen INT-8 stage
    assert_eq!(session.replay.len(), 64);
    let hist = session.replay.class_histogram(m.num_classes);
    // only initial classes are present before any event
    for c in 4..m.num_classes {
        assert_eq!(hist[c], 0, "class {c} must not be in the initial buffer");
    }
    assert!(hist[..4].iter().all(|&c| c > 0), "all initial classes present: {hist:?}");
}

fn int8_and_fp32_frozen_agree_roughly() {
    // the INT-8 frozen stage is a quantization of the FP32 one: accuracy
    // under the same adaptive params should be close.
    let Some(rt) = runtime() else { return };
    let ds = Dataset::load(rt.manifest()).unwrap();
    let l = *rt.manifest().splits.last().unwrap();
    let mk = |int8| CLConfig { l, n_lr: 64, lr_bits: 8, int8_frozen: int8, seed: 3, ..Default::default() };
    let mut s_fp = Session::new(rt, &ds, mk(false)).unwrap();
    let mut s_q = Session::new(rt, &ds, mk(true)).unwrap();
    let a_fp = s_fp.evaluate(&ds).unwrap();
    let a_q = s_q.evaluate(&ds).unwrap();
    assert!(
        (a_fp - a_q).abs() < 0.08,
        "int8 vs fp32 frozen accuracy gap too large: {a_fp} vs {a_q}"
    );
}

fn train_step_reduces_loss_on_repeated_event() {
    let Some(rt) = runtime() else { return };
    let ds = Dataset::load(rt.manifest()).unwrap();
    let l = rt.manifest().splits[rt.manifest().splits.len() - 2];
    let cfg = CLConfig { l, n_lr: 128, epochs: 1, ..Default::default() };
    let mut session = Session::new(rt, &ds, cfg).unwrap();
    let first = session.run_event(&ds, 5, 0).unwrap();
    let second = session.run_event(&ds, 5, 0).unwrap();
    let third = session.run_event(&ds, 5, 0).unwrap();
    assert!(
        third.mean_loss < first.mean_loss,
        "loss should fall when relearning the same event: {} -> {} -> {}",
        first.mean_loss, second.mean_loss, third.mean_loss
    );
    assert!(first.steps > 0 && first.train_acc >= 0.0);
}

fn executable_cache_reuses_compilations() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest();
    let l = m.splits[0];
    let split = m.split(l).unwrap();
    let a = rt.executable(&split.adaptive_eval).unwrap();
    let before = rt.compiled_count();
    let b = rt.executable(&split.adaptive_eval).unwrap();
    assert_eq!(before, rt.compiled_count(), "second fetch must hit the cache");
    assert!(std::rc::Rc::ptr_eq(&a, &b));
}

fn param_state_roundtrip() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest();
    let l = *m.splits.first().unwrap();
    let split = m.split(l).unwrap();
    let params = tinycl::runtime::ParamState::load(rt, split).unwrap();
    assert_eq!(params.len(), split.param_tensors.len());
    let snap = params.to_tensors().unwrap();
    assert_eq!(snap.len(), params.len());
    let mut p2 = tinycl::runtime::ParamState::load(rt, split).unwrap();
    p2.restore(rt, &snap).unwrap();
    let snap2 = p2.to_tensors().unwrap();
    for (a, b) in snap.iter().zip(&snap2) {
        assert_eq!(a, b);
    }
}

/// PJRT CPU in this xla_extension build tolerates neither multiple
/// clients per process nor cross-thread buffer traffic, so the scenarios
/// above run sequentially on one thread under a single client.
#[test]
fn runtime_suite() {
    eprintln!("-- param_state_roundtrip");
    param_state_roundtrip();
    eprintln!("-- manifest_is_consistent");
    manifest_is_consistent();
    eprintln!("-- dataset_loads_and_validates");
    dataset_loads_and_validates();
    eprintln!("-- frozen_modules_execute_and_seed_buffer");
    frozen_modules_execute_and_seed_buffer();
    eprintln!("-- int8_and_fp32_frozen_agree_roughly");
    int8_and_fp32_frozen_agree_roughly();
    eprintln!("-- train_step_reduces_loss_on_repeated_event");
    train_step_reduces_loss_on_repeated_event();
    eprintln!("-- executable_cache_reuses_compilations");
    executable_cache_reuses_compilations();
}
