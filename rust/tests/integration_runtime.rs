//! Integration: backend -> session -> numerics. Runs unconditionally: on
//! PJRT over `artifacts/` when they exist, otherwise on the native kernel
//! engine over the deterministic synthetic Core50-mini — there is no
//! self-skipping build configuration anymore.

use tinycl::coordinator::{CLConfig, Session};
use tinycl::runtime::{
    synthetic, Backend, Dataset, Manifest, NativeBackend, Runtime,
};

/// The test environment: PJRT when artifacts are on disk, native
/// synthetic (tiny spec, so the whole suite stays fast) otherwise.
fn env() -> (Box<dyn Backend>, Dataset) {
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        let rt = Runtime::open(&dir).expect("open runtime");
        let ds = Dataset::load(Runtime::manifest(&rt)).expect("load dataset");
        return (Box::new(rt), ds);
    }
    let (m, ds) = synthetic::generate(&synthetic::SyntheticSpec::tiny()).expect("synthetic env");
    (Box::new(NativeBackend::new(m).expect("native backend")), ds)
}

fn manifest_is_consistent(be: &dyn Backend) {
    let m = be.manifest();
    assert_eq!(m.arch.len(), 15, "micronet conv layers");
    assert!(m.splits.len() >= 3);
    for &l in &m.splits {
        let split = m.split(l).unwrap();
        let lat = m.latent_info(l).unwrap();
        assert!(lat.elems() > 0);
        assert!(lat.a_max_int8 > 0.0 && lat.a_max_fp32 > 0.0);
        assert!(!split.param_tensors.is_empty());
        assert!(split.n_param_elems() > 0);
    }
    // a_max calibration: one per conv layer
    assert_eq!(m.a_max.len(), 15);
    assert!(m.a_max.iter().all(|&a| a > 0.0));
    assert!(m.input_a_max > 0.0);
}

fn dataset_matches_protocol(be: &dyn Backend, ds: &Dataset) {
    let p = &be.manifest().protocol;
    assert_eq!(ds.n_train(), p.n_classes * p.train_sessions * p.frames_per_session);
    assert_eq!(ds.n_test(), p.n_classes * p.test_sessions * p.frames_per_session);
    // every (class, session) event has exactly frames_per_session images
    for class in 0..p.n_classes {
        for session in 0..p.train_sessions {
            assert_eq!(
                ds.event_indices(class, session).len(),
                p.frames_per_session,
                "event ({class},{session})"
            );
        }
    }
    assert_eq!(
        ds.initial_indices().len(),
        p.initial_classes.len() * p.initial_sessions.len() * p.frames_per_session
    );
}

fn frozen_stage_seeds_buffer(be: &dyn Backend, ds: &Dataset) {
    let m = be.manifest();
    let l = *m.splits.last().unwrap();
    let cfg = CLConfig { l, n_lr: 64, lr_bits: 8, int8_frozen: true, ..Default::default() };
    let session = Session::new(be, ds, cfg).expect("session");
    // the replay buffer was seeded through the frozen INT-8 stage
    assert_eq!(session.replay.len(), 64);
    let hist = session.replay.class_histogram(m.num_classes);
    // only initial classes are present before any event
    let p = &m.protocol;
    for c in 0..m.num_classes {
        if p.initial_classes.contains(&c) {
            assert!(hist[c] > 0, "initial class {c} missing: {hist:?}");
        } else {
            assert_eq!(hist[c], 0, "class {c} must not be in the initial buffer");
        }
    }
}

fn int8_and_fp32_frozen_agree_roughly(be: &dyn Backend, ds: &Dataset) {
    // the INT-8 frozen stage is a quantization of the FP32 one: accuracy
    // under the same adaptive params should be close
    let l = *be.manifest().splits.last().unwrap();
    let mk = |int8| {
        CLConfig { l, n_lr: 64, lr_bits: 8, int8_frozen: int8, seed: 3, ..Default::default() }
    };
    let mut s_fp = Session::new(be, ds, mk(false)).unwrap();
    let mut s_q = Session::new(be, ds, mk(true)).unwrap();
    let a_fp = s_fp.evaluate(ds).unwrap();
    let a_q = s_q.evaluate(ds).unwrap();
    assert!(
        (a_fp - a_q).abs() < 0.10,
        "int8 vs fp32 frozen accuracy gap too large: {a_fp} vs {a_q}"
    );
}

fn train_step_reduces_loss_on_repeated_event(be: &dyn Backend, ds: &Dataset) {
    let splits = &be.manifest().splits;
    let l = splits[splits.len() - 2];
    let cfg = CLConfig { l, n_lr: 128, epochs: 1, ..Default::default() };
    let mut session = Session::new(be, ds, cfg).unwrap();
    let first = session.run_event(ds, 5, 0).unwrap();
    let second = session.run_event(ds, 5, 0).unwrap();
    let third = session.run_event(ds, 5, 0).unwrap();
    assert!(
        third.mean_loss < first.mean_loss,
        "loss should fall when relearning the same event: {} -> {} -> {}",
        first.mean_loss, second.mean_loss, third.mean_loss
    );
    assert!(first.steps > 0 && first.train_acc >= 0.0);
}

fn param_state_roundtrip(be: &dyn Backend) {
    let m = be.manifest();
    let l = *m.splits.first().unwrap();
    let split = m.split(l).unwrap();
    let params = be.load_params(l).unwrap();
    assert_eq!(params.len(), split.param_tensors.len());
    for (t, meta) in params.tensors().iter().zip(&split.param_tensors) {
        assert_eq!(t.shape, meta.shape, "tensor {}", meta.name);
    }
    let snap = params.to_tensors();
    let mut p2 = be.load_params(l).unwrap();
    p2.restore(&snap).unwrap();
    for (a, b) in snap.iter().zip(p2.tensors()) {
        assert_eq!(a, b);
    }
    // loading twice is deterministic (seeded init / same bin file)
    let p3 = be.load_params(l).unwrap();
    for (a, b) in params.tensors().iter().zip(p3.tensors()) {
        assert_eq!(a, b);
    }
}

/// One suite, sequential: the PJRT arm tolerates neither multiple clients
/// per process nor cross-thread traffic, and the native arm reuses one
/// generated environment.
#[test]
fn runtime_suite() {
    let (be, ds) = env();
    eprintln!("[runtime_suite] backend: {}", be.platform());
    eprintln!("-- manifest_is_consistent");
    manifest_is_consistent(&*be);
    eprintln!("-- dataset_matches_protocol");
    dataset_matches_protocol(&*be, &ds);
    eprintln!("-- param_state_roundtrip");
    param_state_roundtrip(&*be);
    eprintln!("-- frozen_stage_seeds_buffer");
    frozen_stage_seeds_buffer(&*be, &ds);
    eprintln!("-- int8_and_fp32_frozen_agree_roughly");
    int8_and_fp32_frozen_agree_roughly(&*be, &ds);
    eprintln!("-- train_step_reduces_loss_on_repeated_event");
    train_step_reduces_loss_on_repeated_event(&*be, &ds);
}
