//! The persistent execution pool: ONE threading layer shared by the
//! kernel engine (fork-join row panels), the fleet serving loop
//! (pool-resident worker tasks) and background evaluation (low-priority
//! task groups).
//!
//! The paper's VEGA platform keeps its 10-core PULP cluster *resident*
//! and fork-joins it per layer — it never pays a thread spawn on the
//! steady-state path. This module gives the host runtime the same
//! shape: [`ExecPool`] spawns its workers once (counted — tests assert
//! the steady state performs ZERO further spawns) and every layer of
//! the stack dispatches onto them.
//!
//! ## Determinism contract
//!
//! [`ExecPool::parallel_rows_mut`] splits `total_rows` into chunks of
//! `rows_per` rows — a pure function of `(total_rows, rows_per)`, both
//! supplied by the caller from its LOGICAL width (`Engine::threads`).
//! The pool's PHYSICAL width only decides how many workers help execute
//! the pre-computed parts; each part owns a disjoint output slice and
//! reduces in a fixed order, so results are bit-identical at any pool
//! width, under oversubscription, and for any claim interleaving
//! (`rust/tests/exec.rs` pins this).
//!
//! ## Scheduling
//!
//! Two lanes. The HIGH lane carries fork-join parts (pushed to the
//! front — a forked kernel finishes before a new task starts) and
//! serving tasks. The LOW lane carries eval sweeps; workers take low
//! jobs only while at least one worker is left for high work
//! (`low_active < width - 1`), so a full eval can never occupy the
//! whole pool and stall event dispatch. Forking callers always
//! participate in their own join, and [`GroupHandle::wait`] drives any
//! still-queued jobs of its own group, so progress never depends on a
//! pool worker being free — there is no configuration that deadlocks.
//!
//! ## Thread-count configuration
//!
//! [`ExecConfig::from_env`] is the single resolution point:
//! `TINYCL_THREADS` (>= 1) overrides the host parallelism. The engine's
//! `default_threads`, the fleet's `FleetConfig::exec` and the benches
//! all consume it, and [`global`] logs the resolved width once at
//! startup for reproducibility.

use crate::telemetry;
use std::cell::Cell;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;
use std::time::{Duration, Instant};

/// The unified thread-count configuration (satellite of the pool
/// refactor: one env var, one resolution, consumed everywhere).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecConfig {
    /// worker-pool width / default logical split width
    pub threads: usize,
    /// true when `TINYCL_THREADS` decided the width (logged at startup)
    pub from_env: bool,
}

impl ExecConfig {
    /// Resolve the process thread count: `TINYCL_THREADS` (parsed,
    /// >= 1) wins; otherwise the host's available parallelism.
    pub fn from_env() -> ExecConfig {
        if let Ok(v) = std::env::var("TINYCL_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                if n >= 1 {
                    return ExecConfig { threads: n, from_env: true };
                }
            }
        }
        let threads = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ExecConfig { threads, from_env: false }
    }
}

impl Default for ExecConfig {
    fn default() -> ExecConfig {
        ExecConfig::from_env()
    }
}

/// Which queue a task group lands on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lane {
    /// serving tasks + fork-join parts: drained first
    High,
    /// background eval sweeps: capped at `width - 1` concurrent jobs so
    /// one worker always remains for high-lane work
    Low,
}

enum Job {
    /// one helper share of a fork-join (claims parts until none remain)
    Part(Arc<ForkCtx>),
    /// one claim of a task group (serving worker loop, eval sweep)
    Task(Box<dyn FnOnce() + Send + 'static>),
}

struct PoolState {
    high: VecDeque<Job>,
    low: VecDeque<Job>,
    /// low-lane jobs currently RUNNING on pool workers
    low_active: usize,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
    width: usize,
    /// threads ever spawned by this pool — the steady-state zero-spawn
    /// assertion reads the delta of this counter
    spawns: AtomicU64,
}

thread_local! {
    /// set inside pool workers: lets [`ExecPool::yield_backoff`] turn a
    /// blocking sleep into productive part-stealing
    static IS_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// A persistent, deterministically-partitioned worker pool.
pub struct ExecPool {
    shared: Arc<PoolShared>,
    handles: Vec<thread::JoinHandle<()>>,
}

/// The process-wide pool, sized by [`ExecConfig::from_env`] on first
/// use and logged once. Never torn down.
pub fn global() -> &'static ExecPool {
    static POOL: OnceLock<ExecPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let cfg = ExecConfig::from_env();
        eprintln!(
            "[exec] persistent worker pool: {} threads ({})",
            cfg.threads,
            if cfg.from_env { "TINYCL_THREADS" } else { "auto: host parallelism" }
        );
        ExecPool::new(cfg.threads)
    })
}

/// Sleep `d` without idling a shared worker: on a pool worker thread the
/// wait is spent draining queued fork-join PARTS (pure kernel compute —
/// safe under held server locks, never a long-running task); elsewhere
/// it is a plain sleep. Used by the fleet's spill-retry backoff so one
/// tenant's flaky I/O can't freeze a serving worker for the whole
/// backoff ladder.
pub fn yield_backoff(d: Duration) {
    global().yield_backoff(d);
}

impl ExecPool {
    /// Spawn a pool of `width` persistent workers (tests build explicit
    /// widths {1, 2, 8}; production uses [`global`]).
    pub fn new(width: usize) -> ExecPool {
        let width = width.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                high: VecDeque::new(),
                low: VecDeque::new(),
                low_active: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            width,
            spawns: AtomicU64::new(0),
        });
        let handles = (0..width)
            .map(|i| {
                let sh = shared.clone();
                sh.spawns.fetch_add(1, Ordering::Relaxed);
                thread::Builder::new()
                    .name(format!("tinycl-exec-{i}"))
                    .spawn(move || worker_main(sh))
                    .expect("spawn exec worker")
            })
            .collect();
        ExecPool { shared, handles }
    }

    /// Physical worker count.
    pub fn width(&self) -> usize {
        self.shared.width
    }

    /// Threads ever spawned by this pool. Steady state: constant — the
    /// zero-spawn tests assert `spawn_count()` does not move across
    /// frozen forwards and whole serving runs.
    pub fn spawn_count(&self) -> u64 {
        self.shared.spawns.load(Ordering::Relaxed)
    }

    /// Fork-join over `out`, split into chunks of `rows_per` logical
    /// rows of `row_elems` elements each — the SAME split the engine's
    /// old per-call `thread::scope` produced, now a pure function of
    /// the caller's logical width with zero thread spawns. `f` runs as
    /// `f(row0, rows, chunk)` on disjoint chunks; the caller
    /// participates, queued pool workers help. Bit-deterministic at any
    /// pool width. Panics in `f` re-panic here after the join.
    pub fn parallel_rows_mut<T, F>(
        &self,
        out: &mut [T],
        row_elems: usize,
        total_rows: usize,
        rows_per: usize,
        f: F,
    ) where
        T: Send,
        F: Fn(usize, usize, &mut [T]) + Sync,
    {
        if total_rows == 0 {
            return;
        }
        assert_eq!(out.len(), total_rows * row_elems, "parallel_rows out size mismatch");
        let rows_per = rows_per.max(1);
        let n_parts = total_rows.div_ceil(rows_per);
        if n_parts <= 1 {
            f(0, total_rows, out);
            return;
        }
        // the pure partition: chunk boundaries depend only on
        // (total_rows, rows_per) — never on the pool
        let base = out.as_mut_ptr();
        let mut parts = Vec::with_capacity(n_parts);
        let mut row0 = 0;
        while row0 < total_rows {
            let rows = rows_per.min(total_rows - row0);
            parts.push(Part {
                r0: row0,
                rows,
                // SAFETY: consecutive, non-overlapping subranges of `out`
                ptr: unsafe { base.add(row0 * row_elems) },
                len: rows * row_elems,
            });
            row0 += rows;
        }
        let set = PartSet { f: &f as *const F, parts, _t: PhantomData::<T> };
        let ctx = Arc::new(ForkCtx {
            claim: AtomicUsize::new(0),
            total: set.parts.len(),
            done: Mutex::new(0),
            done_cv: Condvar::new(),
            panicked: AtomicBool::new(false),
            set: &set as *const PartSet<T, F> as *const (),
            run_part: run_part_impl::<T, F>,
        });
        // helpers for every part the caller's own claim loop may not
        // reach first; pushed to the FRONT so forked kernels finish
        // before queued tasks start. Stale helpers (all parts already
        // claimed) exit without touching `set`.
        let helpers = self.shared.width.min(ctx.total - 1);
        if helpers > 0 {
            let mut st = self.shared.state.lock().unwrap();
            for _ in 0..helpers {
                st.high.push_front(Job::Part(ctx.clone()));
            }
            drop(st);
            self.shared.work_cv.notify_all();
        }
        drive_parts(&ctx);
        // the join: `set` (and the borrow of `out`/`f`) stays alive
        // until every claimed part has finished
        let mut done = ctx.done.lock().unwrap();
        while *done < ctx.total {
            done = ctx.done_cv.wait(done).unwrap();
        }
        drop(done);
        if ctx.panicked.load(Ordering::Relaxed) {
            panic!("exec: a parallel_rows part panicked");
        }
    }

    /// Submit `jobs` as one task group on `lane` and return its handle.
    /// Jobs may borrow the caller's environment (`'env`): the handle
    /// cannot outlive it, and both [`GroupHandle::wait`] and the
    /// handle's `Drop` block until every job has finished (do NOT
    /// `mem::forget` a handle). Results come back in submission order.
    pub fn submit_group<'env, R: Send + 'static>(
        &self,
        lane: Lane,
        jobs: Vec<Box<dyn FnOnce() -> R + Send + 'env>>,
    ) -> GroupHandle<'env, R> {
        let total = jobs.len();
        let jobs: Vec<Mutex<Option<BoxedJob<R>>>> = jobs
            .into_iter()
            .map(|j| {
                // SAFETY: the 'env borrow is protected by the handle —
                // wait()/Drop block until every job completes, and the
                // handle's PhantomData pins it inside 'env
                let j: BoxedJob<R> = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() -> R + Send + 'env>, BoxedJob<R>>(j)
                };
                Mutex::new(Some(j))
            })
            .collect();
        let ctx = Arc::new(GroupCtx {
            claim: AtomicUsize::new(0),
            total,
            results: (0..total).map(|_| Mutex::new(None)).collect(),
            jobs,
            done: Mutex::new(0),
            done_cv: Condvar::new(),
        });
        if total > 0 {
            let mut st = self.shared.state.lock().unwrap();
            for _ in 0..total {
                let c = ctx.clone();
                let job = Job::Task(Box::new(move || drive_group_one(&c)));
                match lane {
                    Lane::High => st.high.push_back(job),
                    Lane::Low => st.low.push_back(job),
                }
            }
            drop(st);
            self.shared.work_cv.notify_all();
        }
        GroupHandle { ctx, joined: total == 0, _env: PhantomData }
    }

    /// See the free function [`yield_backoff`].
    pub fn yield_backoff(&self, d: Duration) {
        if d.is_zero() {
            return;
        }
        if !IS_POOL_WORKER.with(|w| w.get()) {
            thread::sleep(d);
            return;
        }
        let deadline = Instant::now() + d;
        loop {
            // steal ONLY fork-join parts: pure kernel compute, safe to
            // run while the backing-off task holds server locks (a
            // queued TASK could be a serving loop — running one
            // reentrantly here could self-deadlock)
            let stolen = {
                let mut st = self.shared.state.lock().unwrap();
                st.high
                    .iter()
                    .position(|j| matches!(j, Job::Part(_)))
                    .and_then(|i| st.high.remove(i))
            };
            match stolen {
                Some(Job::Part(ctx)) => drive_parts(&ctx),
                _ => {
                    let now = Instant::now();
                    if now >= deadline {
                        return;
                    }
                    thread::sleep((deadline - now).min(Duration::from_millis(1)));
                }
            }
            if Instant::now() >= deadline {
                return;
            }
        }
    }
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_main(shared: Arc<PoolShared>) {
    IS_POOL_WORKER.with(|w| w.set(true));
    loop {
        let picked = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(job) = st.high.pop_front() {
                    break Some((job, false));
                }
                // leave one worker for high-lane work at all times;
                // width 1 never runs low jobs here (GroupHandle::wait
                // drives them on the waiting thread instead)
                if st.low_active < shared.width.saturating_sub(1) {
                    if let Some(job) = st.low.pop_front() {
                        st.low_active += 1;
                        break Some((job, true));
                    }
                }
                if st.shutdown {
                    break None;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        let Some((job, was_low)) = picked else { return };
        // occupancy gauges: observation only (one pointer load when
        // telemetry is disabled), never part of scheduling decisions
        let tm = crate::telemetry::global();
        let (busy, peak) = if was_low {
            (telemetry::Gauge::PoolBusyLow, telemetry::Gauge::PoolBusyLowPeak)
        } else {
            (telemetry::Gauge::PoolBusyHigh, telemetry::Gauge::PoolBusyHighPeak)
        };
        tm.gauge_inc_peak(busy, peak);
        match job {
            Job::Part(ctx) => drive_parts(&ctx),
            // group jobs record their own panic in the group context;
            // nothing can escape into the worker loop
            Job::Task(f) => f(),
        }
        tm.gauge_dec(busy);
        if was_low {
            let mut st = shared.state.lock().unwrap();
            st.low_active -= 1;
            drop(st);
            shared.work_cv.notify_one();
        }
    }
}

// ---- fork-join internals ---------------------------------------------------

/// One disjoint output chunk of a fork-join. The raw pointer covers a
/// subrange of the caller's `&mut [T]` that no other part touches.
struct Part<T> {
    r0: usize,
    rows: usize,
    ptr: *mut T,
    len: usize,
}

/// The caller-stack part table: closure + chunk table. Referenced from
/// worker threads only through [`ForkCtx::set`] while the forking call
/// is blocked in its join, which keeps the borrows alive.
struct PartSet<T, F> {
    f: *const F,
    parts: Vec<Part<T>>,
    _t: PhantomData<T>,
}

/// The shared fork-join state (owned by `Arc`, outlives stale helper
/// jobs; `set` is only dereferenced for claims `< total`).
struct ForkCtx {
    claim: AtomicUsize,
    total: usize,
    done: Mutex<usize>,
    done_cv: Condvar,
    panicked: AtomicBool,
    set: *const (),
    run_part: unsafe fn(*const (), usize),
}

// SAFETY: `set` is dereferenced only by claim winners (idx < total),
// and the forking caller blocks until `done == total` — the pointee and
// the chunks it points into are alive for every such access. Chunks are
// disjoint by construction and `T: Send` is enforced at the API.
unsafe impl Send for ForkCtx {}
unsafe impl Sync for ForkCtx {}

/// Monomorphized trampoline: run part `idx` of the erased [`PartSet`].
unsafe fn run_part_impl<T: Send, F: Fn(usize, usize, &mut [T]) + Sync>(
    set: *const (),
    idx: usize,
) {
    let set = &*(set as *const PartSet<T, F>);
    let p = &set.parts[idx];
    let chunk = std::slice::from_raw_parts_mut(p.ptr, p.len);
    (*set.f)(p.r0, p.rows, chunk);
}

/// Claim-and-run parts until none remain. Runs on the forking caller
/// AND any helper that picked the job up; the done count is advanced
/// (and the join condvar notified) under the lock, so the last notify
/// can never race the caller tearing the context down.
fn drive_parts(ctx: &ForkCtx) {
    loop {
        let idx = ctx.claim.fetch_add(1, Ordering::Relaxed);
        if idx >= ctx.total {
            return;
        }
        let r = catch_unwind(AssertUnwindSafe(|| unsafe { (ctx.run_part)(ctx.set, idx) }));
        if r.is_err() {
            ctx.panicked.store(true, Ordering::Relaxed);
        }
        let mut done = ctx.done.lock().unwrap();
        *done += 1;
        if *done == ctx.total {
            ctx.done_cv.notify_all();
        }
    }
}

// ---- task groups -----------------------------------------------------------

type BoxedJob<R> = Box<dyn FnOnce() -> R + Send + 'static>;

struct GroupCtx<R> {
    claim: AtomicUsize,
    total: usize,
    jobs: Vec<Mutex<Option<BoxedJob<R>>>>,
    results: Vec<Mutex<Option<thread::Result<R>>>>,
    done: Mutex<usize>,
    done_cv: Condvar,
}

/// Claim and run ONE group job (each queued pool entry performs one
/// claim, so the low-lane running-job cap counts real concurrency).
fn drive_group_one<R: Send>(ctx: &GroupCtx<R>) {
    let idx = ctx.claim.fetch_add(1, Ordering::Relaxed);
    if idx >= ctx.total {
        return;
    }
    let job = ctx.jobs[idx].lock().unwrap().take().expect("each group job claimed once");
    let res = catch_unwind(AssertUnwindSafe(job));
    *ctx.results[idx].lock().unwrap() = Some(res);
    let mut done = ctx.done.lock().unwrap();
    *done += 1;
    if *done == ctx.total {
        ctx.done_cv.notify_all();
    }
}

/// Completion handle of a submitted task group. `wait` (and `Drop`)
/// drive still-queued jobs of THIS group on the current thread before
/// blocking, so completion never depends on pool availability.
pub struct GroupHandle<'env, R: Send + 'static> {
    ctx: Arc<GroupCtx<R>>,
    joined: bool,
    _env: PhantomData<&'env ()>,
}

impl<R: Send + 'static> GroupHandle<'_, R> {
    fn join(&mut self) {
        if self.joined {
            return;
        }
        self.joined = true;
        loop {
            // help-first: claim whatever the pool has not started yet
            let before = self.ctx.claim.load(Ordering::Relaxed);
            if before >= self.ctx.total {
                break;
            }
            drive_group_one(&self.ctx);
        }
        let mut done = self.ctx.done.lock().unwrap();
        while *done < self.ctx.total {
            done = self.ctx.done_cv.wait(done).unwrap();
        }
    }

    /// Block until every job has finished; return results in submission
    /// order. Re-raises the first job panic.
    pub fn wait(mut self) -> Vec<R> {
        self.join();
        let mut out = Vec::with_capacity(self.ctx.total);
        for slot in &self.ctx.results {
            match slot.lock().unwrap().take().expect("group joined") {
                Ok(r) => out.push(r),
                Err(p) => resume_unwind(p),
            }
        }
        out
    }
}

impl<R: Send + 'static> Drop for GroupHandle<'_, R> {
    fn drop(&mut self) {
        // an un-waited handle still guarantees the 'env borrows are
        // dead before it goes out of scope (panics stay recorded in
        // the context and are dropped with it)
        self.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_resolution_is_sane() {
        let cfg = ExecConfig::from_env();
        assert!(cfg.threads >= 1);
    }

    #[test]
    fn inline_path_runs_without_pool_contact() {
        let pool = ExecPool::new(2);
        let mut out = vec![0u32; 12];
        pool.parallel_rows_mut(&mut out, 3, 4, 4, |r0, rows, chunk| {
            assert_eq!((r0, rows, chunk.len()), (0, 4, 12));
            chunk.fill(7);
        });
        assert!(out.iter().all(|&v| v == 7));
    }

    #[test]
    fn partition_covers_exactly_once_for_ragged_splits() {
        for &(total, per) in &[(1usize, 1usize), (7, 2), (8, 3), (37, 8), (64, 64), (5, 100)] {
            let pool = ExecPool::new(3);
            let mut out = vec![0u8; total * 2];
            pool.parallel_rows_mut(&mut out, 2, total, per, |r0, rows, chunk| {
                assert_eq!(chunk.len(), rows * 2);
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v += ((r0 * 2 + i) % 251) as u8 + 1;
                }
            });
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v, (i % 251) as u8 + 1, "total={total} per={per} i={i}");
            }
        }
    }

    #[test]
    fn group_results_come_back_in_submission_order() {
        let pool = ExecPool::new(4);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..16).map(|i| Box::new(move || i * 3) as Box<dyn FnOnce() -> usize + Send>).collect();
        let got = pool.submit_group(Lane::High, jobs).wait();
        assert_eq!(got, (0..16).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn low_lane_group_completes_even_on_a_width_one_pool() {
        // width 1 => the worker never takes low jobs (cap 0); the
        // handle's help-first wait must finish the group anyway
        let pool = ExecPool::new(1);
        let jobs: Vec<Box<dyn FnOnce() -> u32 + Send>> =
            (0..4).map(|i| Box::new(move || i + 1) as Box<dyn FnOnce() -> u32 + Send>).collect();
        assert_eq!(pool.submit_group(Lane::Low, jobs).wait(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn group_panic_resurfaces_at_wait() {
        let pool = ExecPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() -> u32 + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("boom in a group job")),
        ];
        let handle = pool.submit_group(Lane::High, jobs);
        let err = catch_unwind(AssertUnwindSafe(move || handle.wait()));
        assert!(err.is_err(), "the job panic must re-raise at wait()");
    }

    #[test]
    fn parallel_rows_panic_resurfaces_at_the_join() {
        let pool = ExecPool::new(2);
        let mut out = vec![0f32; 8];
        let err = catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_rows_mut(&mut out, 1, 8, 2, |r0, _rows, _chunk| {
                if r0 >= 4 {
                    panic!("boom in a part");
                }
            });
        }));
        assert!(err.is_err());
    }

    #[test]
    fn spawn_count_is_width_and_stays_flat() {
        let pool = ExecPool::new(3);
        assert_eq!(pool.spawn_count(), 3);
        for _ in 0..10 {
            let mut out = vec![0f64; 64];
            pool.parallel_rows_mut(&mut out, 1, 64, 8, |r0, rows, chunk| {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = (r0 + i) as f64 * 0.5;
                }
                assert!(rows <= 8);
            });
        }
        assert_eq!(pool.spawn_count(), 3, "steady state must spawn nothing");
    }

    #[test]
    fn yield_backoff_returns_promptly_off_pool() {
        let t0 = Instant::now();
        yield_backoff(Duration::from_millis(2));
        assert!(t0.elapsed() >= Duration::from_millis(2));
    }
}
