//! Generators for the systems tables/figures (no PJRT needed): Table I,
//! Table III, Table IV, Fig. 7, Fig. 8, Fig. 9, Fig. 10 — all on the
//! paper's exact MobileNet-V1-128 workload via the simulator substrate.

use crate::models::{memory, mobilenet_v1_128, LayerKind};
use crate::simulator::energy;
use crate::simulator::executor::{
    adaptive_event_cycles, adaptive_macs_per_cyc, event_seconds, frozen_event_cycles, EventSpec,
};
use crate::simulator::kernels::{tile_macs_per_cyc, Pass};
use crate::simulator::targets::{snapdragon845, stm32l4, vega, HwConfig};
use crate::simulator::tiling::{matmul_geom, solve_tile};
use crate::util::table::{fmt, fmt_eng, Table};

const RESULTS_DIR: &str = "results";

/// Table I — the qualitative related-work landscape (reprinted).
pub fn tab1() -> Table {
    let mut t = Table::new(
        "Table I — on-device learning methods on tiny embedded systems (paper, reprinted)",
        &["Method", "Learning approach", "Device", "Tiny", "On-device", "Compute", "Memory", "CL"],
    );
    let rows: &[[&str; 8]] = &[
        [
            "Transfer Learning [21]",
            "retrain last layer",
            "Coral Edge TPU",
            "",
            "yes",
            "LOW",
            "LOW",
            "",
        ],
        ["TinyTL [22]", "retrain biases", "EPYC AMD 7302", "", "yes", "MEDIUM", "LOW/MED", ""],
        ["TinyOL [23]", "added online layer", "Arduino Nano 33", "yes", "yes", "LOW", "LOW", ""],
        ["TinyML Minicar [8]", "CNN backprop (server)", "GAP8", "yes", "", "-", "-", "yes"],
        ["TML [24]", "kNN classifier", "STM32F7", "yes", "yes", "LOW", "HIGH(unbounded)", "yes"],
        ["PULP-HD [25]", "hyperdimensional", "Mr. Wolf", "yes", "yes", "MEDIUM", "LOW", "yes"],
        [
            "LR-CL [1]",
            "CNN backprop w/ LRs",
            "Snapdragon 845",
            "",
            "yes",
            "HIGH",
            "HIGH/MED",
            "yes",
        ],
        [
            "QLR-CL (this work)",
            "CNN backprop w/ QLRs",
            "VEGA",
            "yes",
            "yes",
            "HIGH",
            "MEDIUM",
            "yes",
        ],
    ];
    for r in rows {
        t.row(r.iter().map(|s| s.to_string()).collect());
    }
    t
}

/// Table III — LR dimension and size per MobileNet-V1 layer.
pub fn tab3() -> Table {
    let net = mobilenet_v1_128();
    let mut t = Table::new(
        "Table III — size of latent replays per MobileNet-V1-128 layer",
        &["LR layer l", "Layer type", "LR dim (HxWxC)", "LR size (elems)"],
    );
    for (l, kind, h, w, c) in crate::models::table3_rows() {
        t.row(vec![
            l.to_string(),
            match kind {
                LayerKind::DepthWise => "DW".into(),
                LayerKind::PointWise => "PW".into(),
                LayerKind::Linear => "Linear".into(),
                LayerKind::Conv3x3 => "C3".into(),
            },
            format!("{h}x{w}x{c}"),
            format!("{}k", net.lr_elems(l) / 1024),
        ]);
    }
    t
}

/// Fig. 7 — memory breakdown of the Pareto points (paper workload).
pub fn fig7() -> Table {
    let net = mobilenet_v1_128();
    let mut t = Table::new(
        "Fig. 7 — memory breakdown [MB] (MobileNet-V1-128, batch 128)",
        &[
            "point",
            "LR layer",
            "N_LR",
            "quant",
            "LR mem",
            "frozen",
            "adaptive+grad",
            "activations",
            "total",
            "fits 64MB",
            "fits 4MB MRAM",
        ],
    );
    // the paper's clusters: A = {l=27, 1500/3000 LRs, U7/U8};
    // B = {l=23, 1500/3000, U8}; C1 = {l=19, 1500, U8}
    let points: &[(&str, usize, usize, u8)] = &[
        ("A1", 27, 1500, 7),
        ("A2", 27, 1500, 8),
        ("A3", 27, 3000, 8),
        ("B1", 23, 1500, 8),
        ("B2", 23, 3000, 8),
        ("C1", 19, 1500, 8),
        ("FP32 base", 19, 1500, 32),
    ];
    for &(name, l, n_lr, bits) in points {
        let q = memory::QuantSetting {
            frozen_bits: if bits == 32 { 32 } else { 8 },
            lr_bits: bits,
        };
        let b = memory::breakdown(&net, l, n_lr, q, 128);
        let mb = |x: usize| fmt(x as f64 / (1024.0 * 1024.0), 2);
        t.row(vec![
            name.into(),
            l.to_string(),
            n_lr.to_string(),
            q.label(),
            mb(b.lr_bytes),
            mb(b.frozen_param_bytes),
            mb(b.adaptive_param_bytes + b.gradient_bytes),
            mb(b.activation_bytes),
            mb(b.total()),
            (b.total_mb() < 64.0).to_string(),
            (b.lr_mb() < 4.0).to_string(),
        ]);
    }
    t
}

/// Fig. 8 — single-tile MAC/cyc of every CL primitive on VEGA.
pub fn fig8() -> Table {
    let v = vega();
    let net = mobilenet_v1_128();
    let mut t = Table::new(
        "Fig. 8 — CL primitive efficiency [MAC/cyc] on VEGA (single tile in L1)",
        &["kernel", "pass", "L1 kB", "tile (tm,tn,tk)", "1 core", "2 cores", "4 cores", "8 cores"],
    );
    // representative layers, as the paper's tile tables: PW 8x8x512->512,
    // DW 8x8x512, Linear 1024->50
    let cases: &[(&str, usize)] = &[("PW", 22), ("DW", 21), ("Lin", 27)];
    for &(label, idx) in cases {
        let layer = net.layer(idx);
        for pass in Pass::all() {
            for l1 in [128usize, 256, 512] {
                let geom = matmul_geom(layer, Pass::Fw, 8);
                let dims = solve_tile(&geom, l1 * 1024);
                // the paper's RISC-V kernels run the inner loop along the
                // L1-resident strip (512/1024/2048 iterations for 128/256/
                // 512 kB — §V-C), so the amortization length scales with L1
                let k_inner = match layer.kind {
                    LayerKind::DepthWise => 9,
                    _ => dims.tk * (l1 / 128).max(1),
                };
                let rate = |cores| {
                    // Fig. 8 benchmarks the raw kernels: software im2col
                    // for DW (the DMA-assisted path is discussed in §V-C)
                    tile_macs_per_cyc(&v, cores, layer.kind, pass, k_inner, false)
                };
                t.row(vec![
                    label.into(),
                    pass.label().into(),
                    l1.to_string(),
                    format!("({},{},{})", dims.tm, dims.tn, dims.tk),
                    fmt(rate(1), 3),
                    fmt(rate(2), 3),
                    fmt(rate(4), 3),
                    fmt(rate(8), 3),
                ]);
            }
        }
    }
    t
}

/// Fig. 9 — average training MAC/cyc vs L2-L1 DMA bandwidth.
pub fn fig9() -> Table {
    let v = vega();
    let net = mobilenet_v1_128();
    let mut t = Table::new(
        "Fig. 9 — adaptive-stage training MAC/cyc vs DMA bandwidth (LR layer 19, batch 128, \
         half duplex)",
        &["cores", "L1 kB", "bw 8", "bw 16", "bw 32", "bw 64", "bw 128", "sweet spot (bit/cyc)"],
    );
    for cores in [1usize, 2, 4, 8] {
        for l1 in [128usize, 256, 512] {
            let rate = |bw: f64| {
                let hw = HwConfig {
                    cores,
                    l1_bytes: l1 * 1024,
                    dma_read_bits_per_cyc: bw,
                    dma_write_bits_per_cyc: bw,
                    full_duplex: false,
                };
                // paper plots the adaptive stage from LR layer 19 => first
                // retrained layer 20
                adaptive_macs_per_cyc(&v, &hw, &net, 20, 128)
            };
            let series: Vec<f64> =
                [8.0, 16.0, 32.0, 64.0, 128.0].iter().map(|&b| rate(b)).collect();
            // sweet spot: smallest bw within 5% of the bw=128 plateau
            let plateau = series[4];
            let sweet = [8.0, 16.0, 32.0, 64.0, 128.0]
                .iter()
                .zip(&series)
                .find(|(_, &r)| r >= 0.95 * plateau)
                .map(|(b, _)| *b)
                .unwrap_or(128.0);
            t.row(vec![
                cores.to_string(),
                l1.to_string(),
                fmt(series[0], 3),
                fmt(series[1], 3),
                fmt(series[2], 3),
                fmt(series[3], 3),
                fmt(series[4], 3),
                format!("{sweet}"),
            ]);
        }
    }
    t
}

/// Table IV — cumulative latency + energy per learning event.
pub fn tab4() -> Table {
    let v = vega();
    let s = stm32l4();
    let sd = snapdragon845();
    let net = mobilenet_v1_128();
    let ev = EventSpec::paper();
    let mut t = Table::new(
        "Table IV — per-event latency/energy (VEGA vs STM32L4 vs Snapdragon 845)",
        &["LR layer l", "VEGA adaptive [s]", "VEGA frozen [s]", "VEGA energy [J]",
          "STM32L4 total [s]", "STM32L4 energy [J]", "SD845 total [s]", "VEGA speed-up"],
    );
    for l in 20..=27 {
        let va = v.seconds(adaptive_event_cycles(&v, &v.default_hw, &net, l, &ev));
        let vf = v.seconds(frozen_event_cycles(&v, &v.default_hw, &net, l, &ev));
        let vj = v.energy_j(va + vf);
        let st = event_seconds(&s, &s.default_hw, &net, l, &ev);
        let sj = s.energy_j(st);
        let sd_s = if l == 27 {
            // published anchor for the last-layer scenario
            format!("{:.2} (publ.)", crate::simulator::targets::SNAPDRAGON_EVENT_SECONDS)
        } else {
            let t_ = event_seconds(&sd, &sd.default_hw, &net, l, &ev);
            format!("{:.2} (model)", t_)
        };
        t.row(vec![
            l.to_string(),
            fmt_eng(va),
            fmt(vf, 2),
            fmt(vj, 2),
            fmt_eng(st),
            fmt(sj, 1),
            sd_s,
            format!("{:.0}x", st / (va + vf)),
        ]);
    }
    t
}

/// Fig. 10 — battery lifetime vs learning events per hour.
pub fn fig10() -> Table {
    let v = vega();
    let s = stm32l4();
    let net = mobilenet_v1_128();
    let ev = EventSpec::paper();
    let mut t = Table::new(
        "Fig. 10 — battery lifetime [h] vs learning events/hour (3300 mAh)",
        &["target", "LR layer", "1/h", "6/h", "60/h", "360/h", "1080/h", "max rate/h"],
    );
    for (target, ls) in [(&v, vec![27usize, 25, 23, 21, 20]), (&s, vec![27])] {
        for l in ls {
            let cell = |rate: f64| {
                match energy::lifetime_hours(target, &target.default_hw, &net, l, &ev, rate) {
                    Some(h) => fmt_eng(h),
                    None => "infeasible".into(),
                }
            };
            t.row(vec![
                target.name.into(),
                l.to_string(),
                cell(1.0),
                cell(6.0),
                cell(60.0),
                cell(360.0),
                cell(1080.0),
                fmt(energy::max_rate_per_hour(target, &target.default_hw, &net, l, &ev), 0),
            ]);
        }
    }
    t
}

/// Run one systems generator by id, print + persist.
pub fn run(id: &str) -> Option<Table> {
    let t = match id {
        "tab1" => tab1(),
        "tab3" => tab3(),
        "fig7" => fig7(),
        "fig8" => fig8(),
        "fig9" => fig9(),
        "tab4" => tab4(),
        "fig10" => fig10(),
        _ => return None,
    };
    t.print();
    let _ = t.save_tsv(RESULTS_DIR, id);
    Some(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_systems_tables_generate() {
        for id in ["tab1", "tab3", "fig7", "fig8", "fig9", "tab4", "fig10"] {
            let t = match id {
                "tab1" => tab1(),
                "tab3" => tab3(),
                "fig7" => fig7(),
                "fig8" => fig8(),
                "fig9" => fig9(),
                "tab4" => tab4(),
                "fig10" => fig10(),
                _ => unreachable!(),
            };
            assert!(!t.rows.is_empty(), "{id} produced no rows");
        }
    }

    #[test]
    fn tab4_latency_orders_match_paper() {
        let t = tab4();
        // VEGA adaptive latency decreases monotonically from l=20 to l=27
        let col: Vec<f64> = t
            .rows
            .iter()
            .map(|r| r[1].parse::<f64>().unwrap_or_else(|_| r[1].parse().unwrap()))
            .collect();
        for w in col.windows(2) {
            assert!(w[1] < w[0], "adaptive latency not decreasing: {col:?}");
        }
    }

    #[test]
    fn fig9_sweet_spots_shift_with_cores() {
        let t = fig9();
        // at 128 kB L1: sweet spot bw for 2 cores <= 4 cores <= 8 cores
        let find = |cores: &str| -> f64 {
            t.rows
                .iter()
                .find(|r| r[0] == cores && r[1] == "128")
                .map(|r| r[7].parse().unwrap())
                .unwrap()
        };
        assert!(find("2") <= find("4"));
        assert!(find("4") <= find("8"));
    }
}
