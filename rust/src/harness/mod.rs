//! Figure/table harness: one generator per artifact of the paper's
//! evaluation section (DESIGN.md §4), behind `tinycl fig --id <id>`.
//!
//! - accuracy generators (real QLR-CL runs on the default backend —
//!   PJRT with artifacts, native-synthetic without): fig5, tab2, fig6
//! - systems generators (simulator/memory model):     tab1, tab3, fig7,
//!   fig8, fig9, tab4, fig10
//! - fleet capacity (memory model, §Fleet):           fleet

pub mod accuracy;
pub mod fleet;
pub mod systems;

use anyhow::Result;

pub use accuracy::Profile;

pub const ALL_IDS: &[&str] = &[
    "tab1", "tab3", "fig7", "fig8", "fig9", "tab4", "fig10", // systems
    "fleet", // fleet capacity (memory model)
    "fig5", "tab2", "fig6", // accuracy (PJRT or native backend)
];

/// Run one generator; `Ok(false)` if the id is unknown.
pub fn run_one(id: &str, profile: Profile) -> Result<bool> {
    if id == "fleet" {
        let t = fleet::capacity_table();
        t.print();
        let _ = t.save_tsv("results", "fleet_capacity");
        return Ok(true);
    }
    if systems::run(id).is_some() {
        return Ok(true);
    }
    Ok(accuracy::run(id, profile)?.is_some())
}

/// Run every generator (systems first — they're instant).
pub fn run_all(profile: Profile) -> Result<()> {
    for id in ALL_IDS {
        eprintln!("\n=== generating {id} ===");
        run_one(id, profile)?;
    }
    Ok(())
}
