//! §Fleet capacity table: how many concurrent CL tenants fit a host
//! budget, from the same §III-B memory model + `ReplayBuffer` accounting
//! the live governor uses (one source of truth — see
//! `models::memory::tenant_bytes`).
//!
//! Instant (pure model, no runs): `tinycl fig --id fleet` writes
//! `results/fleet_capacity.tsv`, the companion to the *measured*
//! throughput numbers `examples/fleet_serving.rs` records in
//! `BENCH_fleet.json`.

use crate::models::memory::{
    shared_backbone_bytes, tenant_bytes, tenants_within_budget, tenants_within_budget_tiered,
    QuantSetting,
};
use crate::models::micronet32;
use crate::util::table::Table;

const BUDGET: usize = 64 * 1024 * 1024;

/// Tenants-per-64MB at Q=8 vs Q=7 — and with the cold (disk-spill) tier
/// at half / quarter hot fractions — over the MicroNet splits / N_LR
/// grid.
pub fn capacity_table() -> Table {
    let net = micronet32();
    let mut t = Table::new(
        "Fleet — tenants per 64 MB host budget (MicroNet-32, batch 64)",
        &[
            "LR layer",
            "N_LR",
            "tenant kB (Q8)",
            "tenant kB (Q7)",
            "tenants @64MB Q8",
            "tenants @64MB Q7",
            "Q7 gain",
            "spill 1/2 hot",
            "spill 1/4 hot",
        ],
    );
    let q8 = QuantSetting { frozen_bits: 8, lr_bits: 8 };
    let q7 = QuantSetting { frozen_bits: 8, lr_bits: 7 };
    for &l in &[13usize, 15] {
        for &n_lr in &[128usize, 256, 512, 1024] {
            let b8 = tenant_bytes(&net, l, n_lr, q8, 64);
            let b7 = tenant_bytes(&net, l, n_lr, q7, 64);
            let t8 = tenants_within_budget(&net, l, n_lr, q8, 64, BUDGET);
            let t7 = tenants_within_budget(&net, l, n_lr, q7, 64, BUDGET);
            let s2 = tenants_within_budget_tiered(&net, l, n_lr, q8, 64, BUDGET, 1, 2);
            let s4 = tenants_within_budget_tiered(&net, l, n_lr, q8, 64, BUDGET, 1, 4);
            t.row(vec![
                l.to_string(),
                n_lr.to_string(),
                format!("{:.1}", b8 as f64 / 1024.0),
                format!("{:.1}", b7 as f64 / 1024.0),
                t8.to_string(),
                t7.to_string(),
                format!("+{}", t7.saturating_sub(t8)),
                s2.to_string(),
                s4.to_string(),
            ]);
        }
    }
    t.row(vec![
        "shared".into(),
        "-".into(),
        format!("{:.1}", shared_backbone_bytes(&net, 13, 8) as f64 / 1024.0),
        format!("{:.1}", shared_backbone_bytes(&net, 15, 8) as f64 / 1024.0),
        "-".into(),
        "-".into(),
        "(frozen backbone, once per host)".into(),
        "-".into(),
        "-".into(),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_table_has_expected_shape_and_orderings() {
        let t = capacity_table();
        let tsv = t.to_tsv();
        let lines: Vec<&str> = tsv.lines().collect();
        // header + 8 grid rows + shared row
        assert_eq!(lines.len(), 1 + 8 + 1, "{tsv}");
        for row in &lines[1..9] {
            let cells: Vec<&str> = row.split('\t').collect();
            assert_eq!(cells.len(), 9, "{row}");
            let t8: usize = cells[4].parse().unwrap();
            let t7: usize = cells[5].parse().unwrap();
            let s2: usize = cells[7].parse().unwrap();
            let s4: usize = cells[8].parse().unwrap();
            assert!(t8 >= 1, "every config must admit at least one tenant");
            assert!(t7 >= t8, "Q7 must never admit fewer tenants than Q8");
            assert!(s2 >= 2 * t8, "half-hot spill tier must at least double capacity");
            assert!(s4 >= 2 * s2, "quarter-hot must at least double half-hot");
        }
    }
}
