//! Generators for the learned experiments: Fig. 5 (accuracy vs LR layer x
//! N_LR x quantization), Table II (frozen-quant vs LR-quant ablation) and
//! Fig. 6 (accuracy-vs-LR-memory Pareto frontier).
//!
//! These run real QLR-CL protocols on Core50-mini through whichever
//! execution backend is available — PJRT over AOT artifacts, or the
//! native kernel engine on the synthetic dataset when no artifacts exist
//! (the fully offline path; see DESIGN.md §1 on why absolute numbers
//! differ from the paper while the orderings are expected to hold). One
//! [`EvalLatentCache`] is shared across a whole sweep — every run of the
//! same (split, frozen-mode) reuses the same frozen-stage test latents.

use anyhow::Result;

use crate::coordinator::{run_protocol_cached, CLConfig, EvalLatentCache, RunOptions};
use crate::quant::lr_bytes;
use crate::runtime::{open_default_backend, Backend, Dataset};
use crate::util::stats;
use crate::util::table::{fmt, Table};

const RESULTS_DIR: &str = "results";

/// Sweep sizing per profile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Profile {
    /// reduced grid, truncated schedule — minutes, CI-friendly
    Fast,
    /// the full mini-benchmark grid — tens of minutes
    Paper,
}

impl Profile {
    pub fn parse(s: &str) -> Self {
        match s {
            "paper" | "full" => Profile::Paper,
            _ => Profile::Fast,
        }
    }

    fn max_events(&self) -> usize {
        match self {
            Profile::Fast => 16,
            Profile::Paper => 0, // full schedule
        }
    }

    fn n_lr_grid(&self) -> &'static [usize] {
        match self {
            Profile::Fast => &[128, 256],
            Profile::Paper => &[64, 128, 256, 512],
        }
    }

    fn splits(&self, all: &[usize]) -> Vec<usize> {
        match self {
            Profile::Fast => all.iter().copied().skip(all.len().saturating_sub(2)).collect(),
            Profile::Paper => all.to_vec(),
        }
    }

    fn seeds(&self) -> &'static [u64] {
        match self {
            Profile::Fast => &[1],
            Profile::Paper => &[1, 2, 3],
        }
    }
}

fn opts(profile: Profile) -> RunOptions {
    RunOptions {
        eval_every: 0, // final eval only (the sweep's signal)
        max_events: profile.max_events(),
        verbose: false,
    }
}

/// Fig. 5 — final accuracy per (LR layer, N_LR, quantization arm).
pub fn fig5(be: &dyn Backend, ds: &Dataset, profile: Profile) -> Result<Table> {
    let cache = EvalLatentCache::new();
    let mut t = Table::new(
        "Fig. 5 — Core50-mini accuracy after the NICv2-mini protocol",
        &["N_LR", "LR layer", "FP32", "UINT-8", "UINT-7", "UINT-6", "LR mem bytes (U8)"],
    );
    let splits = profile.splits(&be.manifest().splits);
    for &n_lr in profile.n_lr_grid() {
        for &l in &splits {
            let mut cells = Vec::new();
            let latent = be.manifest().latent_info(l)?.elems();
            for (int8, bits) in [(false, 32u8), (true, 8), (true, 7), (true, 6)] {
                let mut accs = Vec::new();
                for &seed in profile.seeds() {
                    let cfg = CLConfig {
                        l,
                        n_lr,
                        lr_bits: bits,
                        int8_frozen: int8,
                        seed,
                        ..Default::default()
                    };
                    let r = run_protocol_cached(be, ds, cfg, opts(profile), Some(&cache))?;
                    accs.push(r.final_acc);
                }
                cells.push(fmt(stats::mean(&accs), 3));
            }
            eprintln!("[fig5] N_LR={n_lr} l={l} done");
            t.row(vec![
                n_lr.to_string(),
                l.to_string(),
                cells[0].clone(),
                cells[1].clone(),
                cells[2].clone(),
                cells[3].clone(),
                (n_lr * lr_bytes(latent, 8)).to_string(),
            ]);
        }
    }
    Ok(t)
}

/// Table II — ablation: quantize the frozen stage vs the LR memory.
pub fn tab2(be: &dyn Backend, ds: &Dataset, profile: Profile) -> Result<Table> {
    let cache = EvalLatentCache::new();
    let n_lr = 256; // the mini analogue of the paper's 1500
    let arms: &[(&str, bool, u8)] = &[
        ("FP32 baseline", false, 32),
        ("FP32+UINT-8", false, 8),
        ("UINT-8+UINT-8", true, 8),
        ("FP32+UINT-7", false, 7),
        ("UINT-8+UINT-7", true, 7),
    ];
    let mut t = Table::new(
        "Table II — accuracy (mean±std) with frozen-stage vs LR quantization, N_LR=256",
        &[
            "LR layer",
            "FP32 baseline",
            "FP32+UINT-8",
            "UINT-8+UINT-8",
            "FP32+UINT-7",
            "UINT-8+UINT-7",
        ],
    );
    for &l in &profile.splits(&be.manifest().splits) {
        let mut cells = vec![l.to_string()];
        for &(_, int8, bits) in arms {
            let mut accs = Vec::new();
            for &seed in profile.seeds() {
                let cfg = CLConfig {
                    l,
                    n_lr,
                    lr_bits: bits,
                    int8_frozen: int8,
                    seed,
                    ..Default::default()
                };
                let r = run_protocol_cached(be, ds, cfg, opts(profile), Some(&cache))?;
                accs.push(r.final_acc * 100.0);
            }
            cells.push(format!("{:.1} ± {:.2}", stats::mean(&accs), stats::std(&accs)));
        }
        eprintln!("[tab2] l={l} done");
        t.row(cells);
    }
    Ok(t)
}

/// Fig. 6 — accuracy vs LR-memory Pareto frontier (reuses the fig5 grid).
pub fn fig6(be: &dyn Backend, ds: &Dataset, profile: Profile) -> Result<Table> {
    let cache = EvalLatentCache::new();
    let mut points: Vec<(String, usize, f64)> = Vec::new(); // (label, bytes, acc)
    let splits = profile.splits(&be.manifest().splits);
    for &n_lr in profile.n_lr_grid() {
        for &l in &splits {
            let latent = be.manifest().latent_info(l)?.elems();
            for bits in [8u8, 7] {
                let cfg = CLConfig {
                    l,
                    n_lr,
                    lr_bits: bits,
                    int8_frozen: true,
                    seed: 1,
                    ..Default::default()
                };
                let r = run_protocol_cached(be, ds, cfg, opts(profile), Some(&cache))?;
                points.push((
                    format!("l={l} N={n_lr} U{bits}"),
                    n_lr * lr_bytes(latent, bits),
                    r.final_acc,
                ));
            }
            eprintln!("[fig6] N_LR={n_lr} l={l} done");
        }
    }
    // Pareto frontier: not dominated = no point with <= memory and > acc
    let mut t = Table::new(
        "Fig. 6 — accuracy vs LR memory (Pareto frontier marked)",
        &["config", "LR memory [kB]", "accuracy", "pareto"],
    );
    points.sort_by_key(|p| p.1);
    for (label, bytes, acc) in &points {
        let dominated = points
            .iter()
            .any(|(l2, b2, a2)| {
                (b2 < bytes && a2 >= acc) || (b2 <= bytes && a2 > acc) && l2 != label
            });
        t.row(vec![
            label.clone(),
            fmt(*bytes as f64 / 1024.0, 1),
            fmt(*acc, 3),
            (!dominated).to_string(),
        ]);
    }
    Ok(t)
}

/// Run one accuracy generator by id (opens the default backend: PJRT
/// when artifacts exist, native-synthetic otherwise).
pub fn run(id: &str, profile: Profile) -> Result<Option<Table>> {
    if !matches!(id, "fig5" | "tab2" | "fig6") {
        return Ok(None);
    }
    let (be, ds) = open_default_backend()?;
    eprintln!("[{id}] backend: {}", be.platform());
    let t = match id {
        "fig5" => fig5(&*be, &ds, profile)?,
        "tab2" => tab2(&*be, &ds, profile)?,
        "fig6" => fig6(&*be, &ds, profile)?,
        _ => unreachable!(),
    };
    t.print();
    let _ = t.save_tsv(RESULTS_DIR, id);
    Ok(Some(t))
}
