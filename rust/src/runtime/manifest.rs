//! Typed view of `artifacts/manifest.json` (produced by `python -m
//! compile.aot`). The manifest is the single contract between the build
//! path (Python) and the runtime (this crate): file index, tensor shapes,
//! quantization scales, batch sizes and protocol constants.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

#[derive(Clone, Debug)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorMeta {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct SplitArtifacts {
    pub l: usize,
    pub frozen_fp32_b_new: String,
    pub frozen_fp32_b_eval: String,
    pub frozen_int8_b_new: String,
    pub frozen_int8_b_eval: String,
    pub adaptive_train: String,
    pub adaptive_eval: String,
    pub params_bin: String,
    pub param_tensors: Vec<TensorMeta>,
}

impl SplitArtifacts {
    pub fn n_param_elems(&self) -> usize {
        self.param_tensors.iter().map(|t| t.elems()).sum()
    }

    pub fn frozen(&self, int8: bool, eval_batch: bool) -> &str {
        match (int8, eval_batch) {
            (true, false) => &self.frozen_int8_b_new,
            (true, true) => &self.frozen_int8_b_eval,
            (false, false) => &self.frozen_fp32_b_new,
            (false, true) => &self.frozen_fp32_b_eval,
        }
    }
}

#[derive(Clone, Debug)]
pub struct LatentInfo {
    pub shape: Vec<usize>,
    pub a_max_int8: f64,
    pub a_max_fp32: f64,
}

impl LatentInfo {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn a_max(&self, int8_frozen: bool) -> f32 {
        if int8_frozen {
            self.a_max_int8 as f32
        } else {
            self.a_max_fp32 as f32
        }
    }
}

#[derive(Clone, Debug)]
pub struct BinMeta {
    pub path: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl BinMeta {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ProtocolCfg {
    pub initial_classes: Vec<usize>,
    pub initial_sessions: Vec<usize>,
    pub n_classes: usize,
    pub train_sessions: usize,
    pub test_sessions: usize,
    pub frames_per_session: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub seed: u64,
    pub arch: Vec<(String, usize, usize, usize)>,
    pub num_classes: usize,
    pub input_hw: usize,
    pub feat_dim: usize,
    pub num_params: usize,
    pub splits: Vec<usize>,
    pub batch_new: usize,
    pub batch_train: usize,
    pub batch_eval: usize,
    pub a_bits: u8,
    pub w_bits: u8,
    /// dynamic range of the (normalized) input images — the first
    /// fake-quant of the INT-8 frozen pipeline
    pub input_a_max: f64,
    pub a_max: Vec<f64>,
    pub pooled_a_max: f64,
    pub latent: BTreeMap<usize, LatentInfo>,
    pub split_artifacts: BTreeMap<usize, SplitArtifacts>,
    pub data: BTreeMap<String, BinMeta>,
    pub protocol: ProtocolCfg,
}

fn tuple4(v: &Json) -> (String, usize, usize, usize) {
    let a = v.as_arr();
    (
        a[0].as_str().to_string(),
        a[1].as_usize(),
        a[2].as_usize(),
        a[3].as_usize(),
    )
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;
        if j.at(&["version"]).as_usize() != 1 {
            bail!("unsupported manifest version");
        }

        let model = j.at(&["model"]);
        let splits = model.at(&["splits"]).usize_vec();

        let mut latent = BTreeMap::new();
        for (k, v) in j.at(&["latent"]).as_obj() {
            latent.insert(
                k.parse::<usize>().context("latent key")?,
                LatentInfo {
                    shape: v.at(&["shape"]).usize_vec(),
                    a_max_int8: v.at(&["a_max_int8"]).as_f64(),
                    a_max_fp32: v.at(&["a_max_fp32"]).as_f64(),
                },
            );
        }

        let batch = j.at(&["batch"]);
        let b_new = batch.at(&["new"]).as_usize();
        let b_eval = batch.at(&["eval"]).as_usize();

        let mut split_artifacts = BTreeMap::new();
        for (k, v) in j.at(&["splits"]).as_obj() {
            let l = k.parse::<usize>().context("split key")?;
            split_artifacts.insert(
                l,
                SplitArtifacts {
                    l,
                    frozen_fp32_b_new: v.at(&[&format!("frozen_fp32_b{b_new}")]).as_str().into(),
                    frozen_fp32_b_eval: v.at(&[&format!("frozen_fp32_b{b_eval}")]).as_str().into(),
                    frozen_int8_b_new: v.at(&[&format!("frozen_int8_b{b_new}")]).as_str().into(),
                    frozen_int8_b_eval: v.at(&[&format!("frozen_int8_b{b_eval}")]).as_str().into(),
                    adaptive_train: v.at(&["adaptive_train"]).as_str().into(),
                    adaptive_eval: v.at(&["adaptive_eval"]).as_str().into(),
                    params_bin: v.at(&["params_bin"]).as_str().into(),
                    param_tensors: v
                        .at(&["param_tensors"])
                        .as_arr()
                        .iter()
                        .map(|t| TensorMeta {
                            name: t.at(&["name"]).as_str().into(),
                            shape: t.at(&["shape"]).usize_vec(),
                        })
                        .collect(),
                },
            );
        }

        let mut data = BTreeMap::new();
        for (k, v) in j.at(&["data"]).as_obj() {
            data.insert(
                k.clone(),
                BinMeta {
                    path: v.at(&["path"]).as_str().into(),
                    dtype: v.at(&["dtype"]).as_str().into(),
                    shape: v.at(&["shape"]).usize_vec(),
                },
            );
        }

        let proto = j.at(&["protocol"]);
        let quant = j.at(&["quant"]);

        Ok(Manifest {
            dir: dir.to_path_buf(),
            seed: j.at(&["seed"]).as_f64() as u64,
            arch: model.at(&["arch"]).as_arr().iter().map(tuple4).collect(),
            num_classes: model.at(&["num_classes"]).as_usize(),
            input_hw: model.at(&["input_hw"]).as_usize(),
            feat_dim: model.at(&["feat_dim"]).as_usize(),
            num_params: model.at(&["num_params"]).as_usize(),
            splits,
            batch_new: b_new,
            batch_train: batch.at(&["train"]).as_usize(),
            batch_eval: b_eval,
            a_bits: quant.at(&["a_bits"]).as_usize() as u8,
            w_bits: quant.at(&["w_bits"]).as_usize() as u8,
            input_a_max: quant.get("input_a_max").map(|v| v.as_f64()).unwrap_or(1.0),
            a_max: quant.at(&["a_max"]).f64_vec(),
            pooled_a_max: quant.at(&["pooled_a_max"]).as_f64(),
            latent,
            split_artifacts,
            data,
            protocol: ProtocolCfg {
                initial_classes: proto.at(&["initial_classes"]).usize_vec(),
                initial_sessions: proto.at(&["initial_sessions"]).usize_vec(),
                n_classes: proto.at(&["n_classes"]).as_usize(),
                train_sessions: proto.at(&["train_sessions"]).as_usize(),
                test_sessions: proto.at(&["test_sessions"]).as_usize(),
                frames_per_session: proto.at(&["frames_per_session"]).as_usize(),
            },
        })
    }

    pub fn split(&self, l: usize) -> Result<&SplitArtifacts> {
        self.split_artifacts
            .get(&l)
            .with_context(|| format!("no artifacts for split l={l}; available: {:?}", self.splits))
    }

    pub fn latent_info(&self, l: usize) -> Result<&LatentInfo> {
        self.latent
            .get(&l)
            .with_context(|| format!("no latent info for split l={l}"))
    }

    /// Default artifacts directory: `$TINYCL_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("TINYCL_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}
