//! The runtime layer: manifest + dataset loading, the pluggable
//! [`Backend`] execution abstraction, and its two implementations —
//! the PJRT path over AOT HLO artifacts ([`Runtime`]) and the native
//! kernel-engine path ([`NativeBackend`], no artifacts/XLA needed, paired
//! with the [`synthetic`] Core50-mini generator for fully offline runs).
//!
//! PJRT pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Compiled executables are cached per artifact file; the adaptive-stage
//! parameters live as a host-tensor `ParamState` threaded through the
//! train step call after call.

pub mod backend;
pub mod data;
pub mod manifest;
pub mod native;
pub mod params;
pub mod synthetic;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};

pub use backend::{
    open_backend, open_default_backend, open_shared_native, open_shared_synthetic, Backend,
    BackendChoice, SharedBackend,
};
pub use data::Dataset;
pub use manifest::Manifest;
pub use native::{FrozenPath, NativeBackend};
pub use params::ParamState;

/// A host-side f32 tensor (what flows between coordinator and PJRT).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorF32 {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "TensorF32 shape/data mismatch"
        );
        TensorF32 { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        TensorF32 { shape, data: vec![0.0; n] }
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        literal_from_f32_slice(&self.shape, &self.data)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        Ok(TensorF32::new(dims, lit.to_vec::<f32>()?))
    }
}

/// The runtime: PJRT CPU client + artifact directory + compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open the artifacts directory (compiles nothing yet — executables are
    /// compiled lazily on first use and cached).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn open_default() -> Result<Runtime> {
        Self::open(&Manifest::default_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) one artifact HLO module.
    pub fn executable(&self, file: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(file) {
            return Ok(exe.clone());
        }
        let path = self.manifest.dir.join(file);
        let path_str = path
            .to_str()
            .with_context(|| format!("non-utf8 path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {file}"))?,
        );
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute a module lowered with `return_tuple=True`: returns the
    /// decomposed output tuple as literals.
    pub fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Like [`Runtime::execute`] but borrowing the inputs — the hot-path
    /// variant (no input clones).
    ///
    /// KNOWN UPSTREAM ISSUE: the C shim behind literal-input `execute`
    /// leaks ~0.5 MB/call (EXPERIMENTS.md §Perf #5). The buffer-input
    /// alternative ([`Runtime::execute_buffers`]) is leak-free but
    /// unstable on this xla_extension build; partition very large sweeps
    /// across processes instead.
    pub fn execute_refs(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe.execute::<&xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Copy a host literal to a device buffer (done once per tensor; the
    /// buffer is then reused across executions).
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }

    /// Execute with device-resident inputs (`execute_b`): leak-free, but
    /// see EXPERIMENTS.md §Perf #5 — this xla_extension build's async H2D
    /// transfers make the buffer lifecycle fragile (the source literal
    /// must outlive the transfer; never drop an unexecuted buffer; one
    /// client per process). Exposed for experimentation; the coordinator
    /// uses the literal path.
    pub fn execute_buffers(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe.execute_b::<&xla::PjRtBuffer>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Number of executables compiled so far (used by tests/benches).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// Build an f32 literal straight from a borrowed slice — the trainer
/// hot-path marshaling primitive (§Perf L3: one host copy into the
/// literal's buffer, no intermediate `Vec`; the old vec1+reshape path
/// copied twice and cost ~1.6 ms per training batch, and the
/// `TensorF32`-owning variant still copied the batch once more).
pub fn literal_from_f32_slice(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    assert_eq!(
        shape.iter().product::<usize>(),
        data.len(),
        "literal shape/data mismatch"
    );
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        bytes,
    )?)
}

/// Convenience: i32 label batch literal of shape `[n]`.
pub fn labels_literal(labels: &[i32]) -> xla::Literal {
    xla::Literal::vec1(labels)
}

/// Convenience: f32 scalar literal (e.g. the learning rate input).
pub fn scalar_literal(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrip_via_literal() {
        let t = TensorF32::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = t.to_literal().unwrap();
        let back = TensorF32::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn slice_literal_matches_owned_path() {
        let shape = [4usize, 2];
        let data: Vec<f32> = (0..8).map(|i| i as f32 * 0.5).collect();
        let a = literal_from_f32_slice(&shape, &data).unwrap();
        let b = TensorF32::new(shape.to_vec(), data.clone()).to_literal().unwrap();
        assert_eq!(a.to_vec::<f32>().unwrap(), b.to_vec::<f32>().unwrap());
        assert_eq!(a.element_count(), 8);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn slice_literal_checks_shape() {
        let _ = literal_from_f32_slice(&[3, 3], &[0.0; 8]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn tensor_shape_checked() {
        TensorF32::new(vec![2, 2], vec![0.0; 5]);
    }

    #[test]
    fn zeros_has_right_size() {
        let t = TensorF32::zeros(vec![4, 4, 2]);
        assert_eq!(t.elems(), 32);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn label_literal_dtype() {
        let l = labels_literal(&[1, 2, 3]);
        assert_eq!(l.element_count(), 3);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
    }
}
