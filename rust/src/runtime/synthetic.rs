//! Deterministic synthetic Core50-mini: a seeded procedural stand-in for
//! the AOT pipeline's dataset + manifest, matching the schema the runtime
//! consumes (`manifest.json` fields, image/label/session bookkeeping,
//! latent shapes, calibrated quantization ranges).
//!
//! Paired with [`super::NativeBackend`], this makes the full QLR-CL
//! protocol — `Session`, the Fig 5/6 sweeps, the e2e example — runnable
//! offline with zero artifacts and zero XLA: `(spec.seed)` fully
//! determines the images, the network weights, and therefore every run.
//!
//! Image model: each class owns a random coarse 4x4x3 color grid
//! (upsampled to 32x32); each session tints it with a brightness shift;
//! each frame adds per-pixel noise. Classes are therefore well separated
//! in input space while sessions/frames provide the non-IID variation the
//! NICv2 protocol feeds the learner.

use anyhow::{ensure, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::util::rng::Rng;

use super::manifest::{BinMeta, LatentInfo, Manifest, ProtocolCfg, SplitArtifacts, TensorMeta};
use super::native::NativeBackend;
use super::{Backend, Dataset};

/// Default seed of the synthetic environment (`$TINYCL_SYNTH_SEED`).
pub const DEFAULT_SEED: u64 = 7;

/// The MicroNet-32 topology, identical to `python/compile/model.py::ARCH`.
const ARCH: &[(&str, usize, usize, usize)] = &[
    ("conv3x3", 3, 16, 2),
    ("dw", 16, 16, 1),
    ("pw", 16, 32, 1),
    ("dw", 32, 32, 2),
    ("pw", 32, 64, 1),
    ("dw", 64, 64, 1),
    ("pw", 64, 64, 1),
    ("dw", 64, 64, 2),
    ("pw", 64, 128, 1),
    ("dw", 128, 128, 1),
    ("pw", 128, 128, 1),
    ("dw", 128, 128, 2),
    ("pw", 128, 256, 1),
    ("dw", 256, 256, 1),
    ("pw", 256, 256, 1),
];
const INPUT_HW: usize = 32;
const NUM_CLASSES: usize = 10;
const FEAT_DIM: usize = 256;
const SPLITS: &[usize] = &[9, 11, 13, 15];
const B_NEW: usize = 8;
const B_TRAIN: usize = 64;
const B_EVAL: usize = 50;
const A_BITS: u8 = 8;
const W_BITS: u8 = 8;

/// Sizing + seeding of one synthetic environment.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub seed: u64,
    /// images per (class, session) learning event — Core50-mini uses 60
    pub frames_per_session: usize,
    pub train_sessions: usize,
    pub test_sessions: usize,
    pub initial_classes: Vec<usize>,
    pub initial_sessions: Vec<usize>,
}

impl Default for SyntheticSpec {
    fn default() -> Self {
        SyntheticSpec {
            seed: DEFAULT_SEED,
            frames_per_session: 30,
            train_sessions: 6,
            test_sessions: 2,
            initial_classes: vec![0, 1, 2, 3],
            initial_sessions: vec![0, 1],
        }
    }
}

impl SyntheticSpec {
    /// Test-sized environment: same protocol structure, fewer frames.
    pub fn tiny() -> Self {
        SyntheticSpec { frames_per_session: 12, ..Default::default() }
    }

    /// Default spec with `$TINYCL_SYNTH_SEED` / `$TINYCL_SYNTH_FRAMES`
    /// overrides.
    pub fn from_env() -> Self {
        let mut spec = SyntheticSpec::default();
        if let Ok(s) = std::env::var("TINYCL_SYNTH_SEED") {
            if let Ok(v) = s.parse() {
                spec.seed = v;
            }
        }
        if let Ok(s) = std::env::var("TINYCL_SYNTH_FRAMES") {
            if let Ok(v) = s.parse::<usize>() {
                if v >= 1 {
                    spec.frames_per_session = v;
                }
            }
        }
        spec
    }

    pub fn n_train(&self) -> usize {
        NUM_CLASSES * self.train_sessions * self.frames_per_session
    }

    pub fn n_test(&self) -> usize {
        NUM_CLASSES * self.test_sessions * self.frames_per_session
    }
}

fn spatial_at(layer: usize) -> usize {
    let mut hw = INPUT_HW;
    for &(_, _, _, stride) in &ARCH[..layer] {
        hw = hw.div_ceil(stride);
    }
    hw
}

fn latent_shape(l: usize) -> Vec<usize> {
    if l >= ARCH.len() {
        return vec![FEAT_DIM];
    }
    let hw = spatial_at(l);
    vec![hw, hw, ARCH[l].1]
}

/// Per-split artifact entry: dummy HLO file names (the native backend
/// never reads them) + the real parameter-tensor metadata in the AOT
/// flattening order (per layer sorted keys `b`, `g`, `w`; head `b`, `w`).
fn split_entry(l: usize) -> SplitArtifacts {
    let mut param_tensors = Vec::new();
    let n_conv = ARCH.len() - l;
    for li in 0..n_conv {
        let (kind, cin, cout, _) = ARCH[l + li];
        param_tensors.push(TensorMeta { name: format!("layer{li}.b"), shape: vec![cout] });
        param_tensors.push(TensorMeta { name: format!("layer{li}.g"), shape: vec![cout] });
        let wshape = match kind {
            "dw" => vec![3, 3, cin],
            "pw" => vec![cin, cout],
            _ => vec![3, 3, cin, cout],
        };
        param_tensors.push(TensorMeta { name: format!("layer{li}.w"), shape: wshape });
    }
    param_tensors.push(TensorMeta { name: format!("layer{n_conv}.b"), shape: vec![NUM_CLASSES] });
    param_tensors.push(TensorMeta {
        name: format!("layer{n_conv}.w"),
        shape: vec![FEAT_DIM, NUM_CLASSES],
    });
    SplitArtifacts {
        l,
        frozen_fp32_b_new: format!("frozen_fp32_l{l}_b{B_NEW}.hlo.txt"),
        frozen_fp32_b_eval: format!("frozen_fp32_l{l}_b{B_EVAL}.hlo.txt"),
        frozen_int8_b_new: format!("frozen_int8_l{l}_b{B_NEW}.hlo.txt"),
        frozen_int8_b_eval: format!("frozen_int8_l{l}_b{B_EVAL}.hlo.txt"),
        adaptive_train: format!("adaptive_train_l{l}.hlo.txt"),
        adaptive_eval: format!("adaptive_eval_l{l}.hlo.txt"),
        params_bin: format!("params_l{l}.bin"),
        param_tensors,
    }
}

fn num_params() -> usize {
    let mut n = 0;
    for &(kind, cin, cout, _) in ARCH {
        n += match kind {
            "conv3x3" => 9 * cin * cout,
            "dw" => 9 * cin,
            _ => cin * cout,
        };
        n += 2 * cout; // affine g + b
    }
    n + FEAT_DIM * NUM_CLASSES + NUM_CLASSES
}

fn bin(dtype: &str, shape: Vec<usize>) -> BinMeta {
    BinMeta { path: "<synthetic>".to_string(), dtype: dtype.to_string(), shape }
}

/// Build the manifest skeleton; `a_max`/latent ranges are placeholders
/// until calibration fills them in.
fn manifest_skeleton(spec: &SyntheticSpec) -> Manifest {
    let mut latent = BTreeMap::new();
    for &l in SPLITS {
        latent.insert(
            l,
            LatentInfo { shape: latent_shape(l), a_max_int8: 1.0, a_max_fp32: 1.0 },
        );
    }
    let mut split_artifacts = BTreeMap::new();
    for &l in SPLITS {
        split_artifacts.insert(l, split_entry(l));
    }
    let img = INPUT_HW * INPUT_HW * 3;
    let n_train = spec.n_train();
    let n_test = spec.n_test();
    let mut data = BTreeMap::new();
    data.insert("train_images".into(), bin("u8", vec![n_train, INPUT_HW, INPUT_HW, 3]));
    data.insert("train_labels".into(), bin("i32", vec![n_train]));
    data.insert("train_class".into(), bin("i32", vec![n_train]));
    data.insert("train_session".into(), bin("i32", vec![n_train]));
    data.insert("train_frame".into(), bin("i32", vec![n_train]));
    data.insert("initial_mask".into(), bin("u8", vec![n_train]));
    data.insert("test_images".into(), bin("u8", vec![n_test, INPUT_HW, INPUT_HW, 3]));
    data.insert("test_labels".into(), bin("i32", vec![n_test]));
    debug_assert_eq!(img, 3072);

    Manifest {
        dir: PathBuf::from("<synthetic>"),
        seed: spec.seed,
        arch: ARCH
            .iter()
            .map(|&(k, cin, cout, s)| (k.to_string(), cin, cout, s))
            .collect(),
        num_classes: NUM_CLASSES,
        input_hw: INPUT_HW,
        feat_dim: FEAT_DIM,
        num_params: num_params(),
        splits: SPLITS.to_vec(),
        batch_new: B_NEW,
        batch_train: B_TRAIN,
        batch_eval: B_EVAL,
        a_bits: A_BITS,
        w_bits: W_BITS,
        input_a_max: 1.0,
        a_max: vec![1.0; ARCH.len()],
        pooled_a_max: 1.0,
        latent,
        split_artifacts,
        data,
        protocol: ProtocolCfg {
            initial_classes: spec.initial_classes.clone(),
            initial_sessions: spec.initial_sessions.clone(),
            n_classes: NUM_CLASSES,
            train_sessions: spec.train_sessions,
            test_sessions: spec.test_sessions,
            frames_per_session: spec.frames_per_session,
        },
    }
}

/// One 32x32x3 frame: the class's coarse grid + session tint + noise.
fn gen_image(grid: &[u8], shift: i32, rng: &mut Rng, out: &mut [u8]) {
    debug_assert_eq!(grid.len(), 4 * 4 * 3);
    debug_assert_eq!(out.len(), INPUT_HW * INPUT_HW * 3);
    for y in 0..INPUT_HW {
        for x in 0..INPUT_HW {
            for ch in 0..3 {
                let base = grid[((y / 8) * 4 + x / 8) * 3 + ch] as i32;
                let noise = rng.below(37) as i32 - 18;
                out[(y * INPUT_HW + x) * 3 + ch] = (base + shift + noise).clamp(0, 255) as u8;
            }
        }
    }
}

fn class_grid(seed: u64, class: usize) -> Vec<u8> {
    let mut r = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15) ^ (class as u64 + 1) * 0x1000_0001);
    (0..48).map(|_| (30 + r.below(196)) as u8).collect()
}

fn session_shift(seed: u64, session: usize) -> i32 {
    let mut r =
        Rng::new(seed.wrapping_mul(0xBF58476D1CE4E5B9) ^ (session as u64 + 1) * 0x2000_0003);
    r.below(51) as i32 - 25
}

/// Generate the full synthetic environment: calibrated manifest + dataset.
pub fn generate(spec: &SyntheticSpec) -> Result<(Manifest, Dataset)> {
    ensure!(spec.frames_per_session >= 1, "frames_per_session must be >= 1");
    ensure!(spec.train_sessions >= 1 && spec.test_sessions >= 1, "need sessions");
    ensure!(
        spec.initial_classes.iter().all(|&c| c < NUM_CLASSES)
            && spec.initial_sessions.iter().all(|&s| s < spec.train_sessions),
        "initial classes/sessions out of range"
    );
    let mut m = manifest_skeleton(spec);
    let img = INPUT_HW * INPUT_HW * 3;

    // ---- images + bookkeeping ------------------------------------------
    let n_train = spec.n_train();
    let n_test = spec.n_test();
    let mut train_images = vec![0u8; n_train * img];
    let mut train_labels = vec![0i32; n_train];
    let mut train_class = vec![0i32; n_train];
    let mut train_session = vec![0i32; n_train];
    let mut train_frame = vec![0i32; n_train];
    let mut initial_mask = vec![0u8; n_train];
    let mut idx = 0;
    for class in 0..NUM_CLASSES {
        let grid = class_grid(spec.seed, class);
        for session in 0..spec.train_sessions {
            let shift = session_shift(spec.seed, session);
            let mut fr = Rng::new(
                spec.seed
                    ^ (class as u64 * 131 + session as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15),
            );
            let initial = spec.initial_classes.contains(&class)
                && spec.initial_sessions.contains(&session);
            for frame in 0..spec.frames_per_session {
                gen_image(&grid, shift, &mut fr, &mut train_images[idx * img..(idx + 1) * img]);
                train_labels[idx] = class as i32;
                train_class[idx] = class as i32;
                train_session[idx] = session as i32;
                train_frame[idx] = frame as i32;
                initial_mask[idx] = initial as u8;
                idx += 1;
            }
        }
    }
    let mut test_images = vec![0u8; n_test * img];
    let mut test_labels = vec![0i32; n_test];
    let mut idx = 0;
    for class in 0..NUM_CLASSES {
        let grid = class_grid(spec.seed, class);
        for ts in 0..spec.test_sessions {
            let session = spec.train_sessions + ts; // held-out sessions
            let shift = session_shift(spec.seed, session);
            let mut fr = Rng::new(
                spec.seed
                    ^ (class as u64 * 131 + session as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15),
            );
            for _frame in 0..spec.frames_per_session {
                gen_image(&grid, shift, &mut fr, &mut test_images[idx * img..(idx + 1) * img]);
                test_labels[idx] = class as i32;
                idx += 1;
            }
        }
    }

    // ---- PTQ calibration on the initial (pre-deployment) images ---------
    // mirrors the AOT pipeline: ranges come from the same images the paper
    // calibrates on, through the same INT-8 pipeline the runtime executes
    let be0 = NativeBackend::new(m.clone())?;
    let n_probe = initial_mask
        .iter()
        .enumerate()
        .filter(|(_, &f)| f != 0)
        .map(|(i, _)| i)
        .take(96)
        .collect::<Vec<_>>();
    ensure!(!n_probe.is_empty(), "no initial images to calibrate on");
    let mut probes = vec![0f32; n_probe.len() * img];
    for (pi, &src) in n_probe.iter().enumerate() {
        for (o, &b) in probes[pi * img..(pi + 1) * img]
            .iter_mut()
            .zip(&train_images[src * img..(src + 1) * img])
        {
            *o = b as f32 * (1.0 / 255.0);
        }
    }
    let (a_max, pooled_max) = be0.calibrate_act_ranges(&probes, 32)?;
    m.a_max = a_max.iter().map(|&v| v.max(1e-3) as f64).collect();
    m.pooled_a_max = (pooled_max.max(1e-3)) as f64;

    // FP32 latent ranges per split (the FP32+UINT-Q ablation arm needs a
    // storage scale even when the frozen stage is not quantized)
    let be = NativeBackend::new(m.clone())?;
    for &l in SPLITS {
        let lelems = be.latent_elems(l)?;
        let mut fp32_max = 0f32;
        let chunk = 32;
        let mut lat = vec![0f32; chunk * lelems];
        let mut start = 0;
        while start < n_probe.len() {
            let count = (n_probe.len() - start).min(chunk);
            be.frozen_forward(
                l,
                false,
                false,
                &probes[start * img..(start + count) * img],
                &mut lat[..count * lelems],
            )?;
            for &v in &lat[..count * lelems] {
                fp32_max = fp32_max.max(v);
            }
            start += count;
        }
        let info = m.latent.get_mut(&l).expect("split latent entry");
        info.a_max_fp32 = (fp32_max.max(1e-3)) as f64;
        info.a_max_int8 = if l >= ARCH.len() {
            m.pooled_a_max
        } else {
            m.a_max[l - 1]
        };
    }

    let ds = Dataset::from_parts(
        &m,
        train_images,
        train_labels,
        train_class,
        train_session,
        train_frame,
        initial_mask,
        test_images,
        test_labels,
    )?;
    Ok((m, ds))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic_and_consistent() {
        let spec = SyntheticSpec::tiny();
        let (m1, d1) = generate(&spec).unwrap();
        let (m2, d2) = generate(&spec).unwrap();
        assert_eq!(d1.train_images, d2.train_images);
        assert_eq!(d1.test_labels, d2.test_labels);
        assert_eq!(m1.a_max, m2.a_max);
        assert_eq!(d1.n_train(), spec.n_train());
        assert_eq!(d1.n_test(), spec.n_test());
        // every event is fully populated
        for class in 0..m1.protocol.n_classes {
            for session in 0..m1.protocol.train_sessions {
                assert_eq!(
                    d1.event_indices(class, session).len(),
                    spec.frames_per_session,
                    "event ({class},{session})"
                );
            }
        }
        assert_eq!(
            d1.initial_indices().len(),
            spec.initial_classes.len() * spec.initial_sessions.len() * spec.frames_per_session
        );
    }

    #[test]
    fn seeds_change_the_world() {
        let (m1, d1) = generate(&SyntheticSpec { seed: 1, ..SyntheticSpec::tiny() }).unwrap();
        let (m2, d2) = generate(&SyntheticSpec { seed: 2, ..SyntheticSpec::tiny() }).unwrap();
        assert_ne!(d1.train_images, d2.train_images);
        assert_eq!(m1.splits, m2.splits);
    }

    #[test]
    fn calibrated_ranges_are_positive_and_latents_match() {
        let (m, _) = generate(&SyntheticSpec::tiny()).unwrap();
        assert!(m.a_max.iter().all(|&a| a > 0.0));
        assert!(m.pooled_a_max > 0.0);
        for (&l, info) in &m.latent {
            assert!(info.a_max_int8 > 0.0 && info.a_max_fp32 > 0.0);
            assert_eq!(info.shape, latent_shape(l));
            // byte-aligned replay slots at every supported Q
            for bits in [6usize, 7, 8] {
                assert_eq!((info.elems() * bits) % 8, 0, "l={l} Q={bits}");
            }
        }
        // schema invariants the runtime relies on
        assert_eq!(m.arch.len(), 15);
        assert_eq!(m.split(13).unwrap().param_tensors.len(), 3 * 2 + 2);
        assert_eq!(m.split(15).unwrap().param_tensors.len(), 2);
    }

    #[test]
    fn classes_are_visibly_distinct() {
        let (_, ds) = generate(&SyntheticSpec::tiny()).unwrap();
        // mean absolute pixel distance between class 0 and class 5 images
        // must dwarf the within-class frame noise
        let img = ds.image_elems();
        let a = &ds.train_images[..img];
        let idx5 = ds.event_indices(5, 0)[0];
        let b = &ds.train_images[idx5 * img..(idx5 + 1) * img];
        let cross: f64 = a
            .iter()
            .zip(b)
            .map(|(&x, &y)| (x as f64 - y as f64).abs())
            .sum::<f64>()
            / img as f64;
        let a2 = &ds.train_images[img..2 * img]; // same class+session, next frame
        let within: f64 = a
            .iter()
            .zip(a2)
            .map(|(&x, &y)| (x as f64 - y as f64).abs())
            .sum::<f64>()
            / img as f64;
        assert!(
            cross > within * 2.0,
            "classes not separable: cross {cross:.1} vs within {within:.1}"
        );
    }
}
