//! Loading the Core50-mini tensor bins exported by the AOT pipeline.
//!
//! Images are stored u8 (the sensor-side representation) and normalized to
//! f32 `[0,1]` on demand; labels and event bookkeeping are i32.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;

/// The full dataset, resident in memory (a few tens of MB at mini scale —
/// the paper's analogue is the camera stream + test set on the host).
pub struct Dataset {
    pub input_hw: usize,
    pub train_images: Vec<u8>,
    pub train_labels: Vec<i32>,
    pub train_class: Vec<i32>,
    pub train_session: Vec<i32>,
    pub train_frame: Vec<i32>,
    pub initial_mask: Vec<u8>,
    pub test_images: Vec<u8>,
    pub test_labels: Vec<i32>,
}

fn read_u8(path: &Path, expect: usize) -> Result<Vec<u8>> {
    let v = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if v.len() != expect {
        bail!("{path:?}: expected {expect} bytes, found {}", v.len());
    }
    Ok(v)
}

fn read_i32(path: &Path, expect: usize) -> Result<Vec<i32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if bytes.len() != expect * 4 {
        bail!("{path:?}: expected {} bytes, found {}", expect * 4, bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read a raw f32 (little-endian) binary file of exactly `expect` elements.
pub fn read_f32(path: &Path, expect: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if bytes.len() != expect * 4 {
        bail!("{path:?}: expected {} bytes, found {}", expect * 4, bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

impl Dataset {
    /// Build a dataset directly from in-memory tensors (the synthetic
    /// generator's path — no files involved). Runs the same consistency
    /// validation as [`Dataset::load`].
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        m: &Manifest,
        train_images: Vec<u8>,
        train_labels: Vec<i32>,
        train_class: Vec<i32>,
        train_session: Vec<i32>,
        train_frame: Vec<i32>,
        initial_mask: Vec<u8>,
        test_images: Vec<u8>,
        test_labels: Vec<i32>,
    ) -> Result<Dataset> {
        let ds = Dataset {
            input_hw: m.input_hw,
            train_images,
            train_labels,
            train_class,
            train_session,
            train_frame,
            initial_mask,
            test_images,
            test_labels,
        };
        ds.validate(m)?;
        Ok(ds)
    }

    pub fn load(m: &Manifest) -> Result<Dataset> {
        let bin = |key: &str| -> Result<&crate::runtime::manifest::BinMeta> {
            m.data
                .get(key)
                .with_context(|| format!("manifest missing data entry '{key}'"))
        };
        let p = |key: &str| -> Result<std::path::PathBuf> { Ok(m.dir.join(&bin(key)?.path)) };

        let ds = Dataset {
            input_hw: m.input_hw,
            train_images: read_u8(&p("train_images")?, bin("train_images")?.elems())?,
            train_labels: read_i32(&p("train_labels")?, bin("train_labels")?.elems())?,
            train_class: read_i32(&p("train_class")?, bin("train_class")?.elems())?,
            train_session: read_i32(&p("train_session")?, bin("train_session")?.elems())?,
            train_frame: read_i32(&p("train_frame")?, bin("train_frame")?.elems())?,
            initial_mask: read_u8(&p("initial_mask")?, bin("initial_mask")?.elems())?,
            test_images: read_u8(&p("test_images")?, bin("test_images")?.elems())?,
            test_labels: read_i32(&p("test_labels")?, bin("test_labels")?.elems())?,
        };
        ds.validate(m)?;
        Ok(ds)
    }

    fn validate(&self, m: &Manifest) -> Result<()> {
        let img = self.image_elems();
        if self.train_images.len() != self.train_labels.len() * img {
            bail!("train images/labels inconsistent");
        }
        if self.test_images.len() != self.test_labels.len() * img {
            bail!("test images/labels inconsistent");
        }
        let n = self.train_labels.len();
        if self.train_class.len() != n || self.train_session.len() != n
            || self.train_frame.len() != n || self.initial_mask.len() != n
        {
            bail!("train bookkeeping arrays inconsistent");
        }
        for &l in &self.train_labels {
            if l < 0 || l as usize >= m.num_classes {
                bail!("label {l} out of range");
            }
        }
        Ok(())
    }

    pub fn image_elems(&self) -> usize {
        self.input_hw * self.input_hw * 3
    }

    pub fn n_train(&self) -> usize {
        self.train_labels.len()
    }

    pub fn n_test(&self) -> usize {
        self.test_labels.len()
    }

    /// Normalize image `idx` of the train split into `out` (f32 in [0,1]).
    pub fn train_image_into(&self, idx: usize, out: &mut [f32]) {
        let n = self.image_elems();
        let src = &self.train_images[idx * n..(idx + 1) * n];
        for (o, &b) in out.iter_mut().zip(src) {
            *o = b as f32 * (1.0 / 255.0);
        }
    }

    pub fn test_image_into(&self, idx: usize, out: &mut [f32]) {
        let n = self.image_elems();
        let src = &self.test_images[idx * n..(idx + 1) * n];
        for (o, &b) in out.iter_mut().zip(src) {
            *o = b as f32 * (1.0 / 255.0);
        }
    }

    /// Indices of train samples for one (class, session) learning event.
    pub fn event_indices(&self, class: usize, session: usize) -> Vec<usize> {
        (0..self.n_train())
            .filter(|&i| {
                self.train_class[i] as usize == class
                    && self.train_session[i] as usize == session
            })
            .collect()
    }

    /// Indices flagged as available before deployment (initial fine-tune set).
    pub fn initial_indices(&self) -> Vec<usize> {
        (0..self.n_train())
            .filter(|&i| self.initial_mask[i] != 0)
            .collect()
    }
}
