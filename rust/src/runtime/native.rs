//! The native execution backend: runs the manifest's layer graph directly
//! on the in-tree kernel engine — no Python, no artifacts, no XLA.
//!
//! Semantics mirror `python/compile/model.py` exactly:
//!
//! - **frozen stage** (`layers [0, l)`): conv → ReLU per layer; in INT-8
//!   mode the input and every post-ReLU activation are fake-quantized at
//!   the manifest's calibrated `a_max` and the weights are fake-quantized
//!   over their full range (paper eq. 1/2); split `l = L` pools the final
//!   feature map (the paper's l=27 row of Table III);
//! - **adaptive stage** (`layers [l, L)` + head): conv → per-channel
//!   affine (`y*g + b`, the folded-BN trainable normalization) → ReLU,
//!   then global average pool and the linear head. The train step fuses
//!   forward + BW-ERR + BW-GRAD + SGD in one call: pointwise/linear
//!   passes run on the blocked parallel engine
//!   ([`Engine::matmul_fw_into`] / `bw_err` / `bw_grad`), depthwise
//!   passes on the dedicated kernels
//!   ([`crate::kernels::depthwise_bw_err`]/[`crate::kernels::depthwise_bw_grad`]).
//!
//! Weights are seeded deterministically from `manifest.seed` (He init +
//! layer-wise standardization), so a native run is a pure function of
//! `(manifest, dataset, config, seed)`. The AOT-trained model lives only
//! in the HLO artifacts (frozen weights are baked constants), so when the
//! native backend is pointed at an on-disk artifacts manifest it
//! re-derives everything from the seed and recalibrates the activation
//! ranges — self-consistent, but deliberately not comparable to PJRT.

use anyhow::{bail, ensure, Result};

use crate::kernels::{depthwise_bw_err, depthwise_bw_grad, Engine};
use crate::models::{LayerDesc, LayerKind, NetDesc};
use crate::util::rng::Rng;

use super::backend::Backend;
use super::manifest::Manifest;
use super::params::ParamState;
use super::TensorF32;

pub struct NativeBackend {
    m: Manifest,
    engine: Engine,
    net: NetDesc,
    /// per-conv-layer weights, engine layout:
    /// Conv3x3 `[9*cin, cout]` ((ky,kx,c) rows), DepthWise `[9*c]`
    /// ((ky*3+kx)*c + ch), PointWise `[cin, cout]`
    weights: Vec<Vec<f32>>,
    /// fake-quantized (paper eq. 1, full-range affine) weights for the
    /// INT-8 frozen pipeline
    weights_int8: Vec<Vec<f32>>,
    /// linear head `[feat_dim, num_classes]`
    head_w: Vec<f32>,
}

/// Number of f32s a conv layer's weight tensor holds (engine layout).
fn weight_len(layer: &LayerDesc) -> usize {
    match layer.kind {
        LayerKind::Conv3x3 => 9 * layer.cin * layer.cout,
        LayerKind::DepthWise => 9 * layer.cin,
        LayerKind::PointWise | LayerKind::Linear => layer.cin * layer.cout,
    }
}

/// Parse the manifest's `model.arch` tuples into a [`NetDesc`] (conv
/// layers + the pool/linear head appended), mirroring the python `ARCH`.
pub fn net_from_manifest(m: &Manifest) -> Result<NetDesc> {
    let mut layers = Vec::with_capacity(m.arch.len() + 1);
    let mut hw = m.input_hw;
    for (i, (kind, cin, cout, stride)) in m.arch.iter().enumerate() {
        let k = match kind.as_str() {
            "conv3x3" => LayerKind::Conv3x3,
            "dw" => LayerKind::DepthWise,
            "pw" => LayerKind::PointWise,
            other => bail!("manifest arch: unknown layer kind '{other}'"),
        };
        ensure!(*stride >= 1, "layer {i}: stride must be >= 1");
        layers.push(LayerDesc { idx: i, kind: k, cin: *cin, cout: *cout, stride: *stride, hw_in: hw });
        hw = hw.div_ceil(*stride);
    }
    let feat = m.arch.last().map(|t| t.2).unwrap_or(0);
    ensure!(feat == m.feat_dim, "manifest feat_dim {} != last conv cout {feat}", m.feat_dim);
    layers.push(LayerDesc {
        idx: layers.len(),
        kind: LayerKind::Linear,
        cin: m.feat_dim,
        cout: m.num_classes,
        stride: 1,
        hw_in: hw,
    });
    Ok(NetDesc { name: "manifest", input_hw: m.input_hw, num_classes: m.num_classes, layers })
}

/// One conv layer forward on the engine (free function: also used during
/// construction, before `self` exists).
fn conv_fw(engine: Engine, layer: &LayerDesc, w: &[f32], x: &[f32], b: usize) -> Vec<f32> {
    let h = layer.hw_in;
    let mut out = vec![0f32; b * layer.out_elems()];
    match layer.kind {
        LayerKind::Conv3x3 => {
            engine.conv3x3_fw_into(x, w, b, h, h, layer.cin, layer.stride, layer.cout, &mut out);
        }
        LayerKind::DepthWise => {
            engine.depthwise_fw_into(x, w, b, h, h, layer.cin, layer.stride, &mut out);
        }
        LayerKind::PointWise => {
            debug_assert_eq!(layer.stride, 1, "pointwise stride is always 1");
            let rows = b * h * h;
            engine.matmul_fw_into(x, w, rows, layer.cin, layer.cout, &mut out);
        }
        LayerKind::Linear => unreachable!("linear handled by the head path"),
    }
    out
}

/// Layer-wise weight standardization on seeded noise probes: rescale each
/// layer so its post-ReLU std over the probe batch is 1. This is the
/// random-net analogue of the folded-BN scales the real pipeline gets
/// from pretraining — without it, activation variance decays ~100x over
/// the 15-layer stack and the adaptive stage's SGD is hopelessly
/// ill-conditioned (flushed out by the first end-to-end native runs).
fn normalize_weights(engine: Engine, net: &NetDesc, weights: &mut [Vec<f32>], seed: u64) {
    let mut rng = Rng::new(seed.wrapping_mul(0x6C62_272E_07BB_0142) ^ 0x57A4_DA12);
    let probes = 16usize;
    let hw = net.input_hw;
    let mut x: Vec<f32> = (0..probes * hw * hw * 3).map(|_| rng.f32()).collect();
    for (i, layer) in net.layers[..weights.len()].iter().enumerate() {
        let mut y = conv_fw(engine, layer, &weights[i], &x, probes);
        for v in y.iter_mut() {
            *v = v.max(0.0);
        }
        let n = y.len() as f64;
        let mean: f64 = y.iter().map(|&v| v as f64).sum::<f64>() / n;
        let var: f64 = y.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
        let sd = (var.sqrt() as f32).max(1e-6);
        let inv = 1.0 / sd;
        for w in weights[i].iter_mut() {
            *w *= inv;
        }
        for v in y.iter_mut() {
            *v *= inv;
        }
        x = y;
    }
}

/// Fake-quantize a weight tensor over its full range (paper eq. 1):
/// `S_w = (max - min)/(2^Q - 1)` with zero included in the range,
/// `q = clip(floor(w/S_w))`, returned on the dequantized grid `q * S_w`.
fn fake_quant_weight(w: &[f32], bits: u8) -> Vec<f32> {
    let mut w_min = 0f32;
    let mut w_max = 0f32;
    for &v in w {
        w_min = w_min.min(v);
        w_max = w_max.max(v);
    }
    let levels = ((1u32 << bits) - 1) as f32;
    let scale = ((w_max - w_min) / levels).max(1e-12);
    let lo = (w_min / scale).floor();
    w.iter()
        .map(|&v| (v / scale).floor().clamp(lo, lo + levels) * scale)
        .collect()
}

/// Numerically-stable softmax cross-entropy over a logits batch: returns
/// `(mean_loss, argmax_correct)` and, when `dlogits` is given (the train
/// step), fills it with `d(mean_loss)/d(logits)`. One implementation for
/// both the fused step and the [`NativeBackend::loss_and_correct`] oracle
/// the FD tests compare it against.
fn softmax_ce(
    logits: &[f32],
    labels: &[i32],
    ncls: usize,
    mut dlogits: Option<&mut [f32]>,
) -> Result<(f64, u64)> {
    let b = labels.len();
    ensure!(b > 0 && logits.len() == b * ncls, "softmax_ce: logits/labels size");
    if let Some(d) = dlogits.as_ref() {
        ensure!(d.len() == b * ncls, "softmax_ce: dlogits size");
    }
    let inv_b = 1.0 / b as f32;
    let mut loss_sum = 0f64;
    let mut correct = 0u64;
    for bi in 0..b {
        let row = &logits[bi * ncls..(bi + 1) * ncls];
        let label = labels[bi];
        ensure!(
            (0..ncls as i32).contains(&label),
            "softmax_ce: label {label} out of range"
        );
        let mut max = f32::NEG_INFINITY;
        let mut argmax = 0;
        for (c, &v) in row.iter().enumerate() {
            if v > max {
                max = v;
                argmax = c;
            }
        }
        let mut sum = 0f32;
        for &v in row {
            sum += (v - max).exp();
        }
        let lse = max + sum.ln();
        loss_sum += (lse - row[label as usize]) as f64;
        if argmax == label as usize {
            correct += 1;
        }
        if let Some(d) = dlogits.as_mut() {
            let drow = &mut d[bi * ncls..(bi + 1) * ncls];
            for (c, dv) in drow.iter_mut().enumerate() {
                let p = (row[c] - lse).exp();
                *dv = (p - if c == label as usize { 1.0 } else { 0.0 }) * inv_b;
            }
        }
    }
    Ok((loss_sum / b as f64, correct))
}

/// In-place activation fake-quant (paper eq. 2): UINT-Q affine on the
/// post-ReLU (non-negative) grid.
fn fake_quant_act(x: &mut [f32], a_max: f32, bits: u8) {
    let levels = ((1u32 << bits) - 1) as f32;
    let scale = (a_max / levels).max(1e-12);
    let inv = 1.0 / scale;
    for v in x.iter_mut() {
        *v = (*v * inv).floor().clamp(0.0, levels) * scale;
    }
}

impl NativeBackend {
    pub fn new(m: Manifest) -> Result<NativeBackend> {
        let net = net_from_manifest(&m)?;
        let n_conv = net.layers.len() - 1;
        ensure!(
            m.a_max.len() == n_conv,
            "manifest a_max has {} entries for {n_conv} conv layers",
            m.a_max.len()
        );
        // seeded He init, one forked stream per layer (deterministic in
        // manifest.seed alone)
        let mut master = Rng::new(m.seed.wrapping_mul(0x9E3779B97F4A7C15) ^ 0x5EED_BACC);
        let mut weights = Vec::with_capacity(n_conv);
        for layer in &net.layers[..n_conv] {
            let mut r = master.fork(layer.idx as u64 + 1);
            let std = match layer.kind {
                LayerKind::Conv3x3 => (2.0 / (9.0 * layer.cin as f64)).sqrt(),
                LayerKind::DepthWise => (2.0 / 9.0f64).sqrt(),
                LayerKind::PointWise => (2.0 / layer.cin as f64).sqrt(),
                LayerKind::Linear => unreachable!(),
            };
            weights.push(
                (0..weight_len(layer))
                    .map(|_| (r.normal() * std) as f32)
                    .collect::<Vec<f32>>(),
            );
        }
        let mut hr = master.fork(0x4EAD);
        let head_std = (1.0 / m.feat_dim as f64).sqrt();
        let head_w: Vec<f32> = (0..m.feat_dim * m.num_classes)
            .map(|_| (hr.normal() * head_std) as f32)
            .collect();
        let engine = crate::kernels::default_engine();
        normalize_weights(engine, &net, &mut weights, m.seed);
        let weights_int8 = weights
            .iter()
            .map(|w| fake_quant_weight(w, m.w_bits))
            .collect();
        // when the manifest carries latent shapes, they must agree with
        // the graph we will execute
        for (&l, info) in &m.latent {
            let expect = Self::latent_elems_of(&net, l)?;
            ensure!(
                info.elems() == expect,
                "manifest latent l={l}: {} elems, layer graph says {expect}",
                info.elems()
            );
        }
        let mut be = NativeBackend { m, engine, net, weights, weights_int8, head_w };
        // A manifest that exists on disk came from the AOT pipeline: its
        // a_max ranges were calibrated on the *trained* model, not on this
        // backend's seeded weights — fake-quantizing with them would clip
        // activations at arbitrary points and silently wreck accuracy.
        // Recalibrate every range against the weights we actually execute
        // (the synthetic generator's manifests are already consistent by
        // construction and never hit this path).
        if be.m.dir.join("manifest.json").is_file() {
            eprintln!(
                "[native] note: executing an on-disk artifacts manifest — frozen weights \
                 and adaptive params are re-derived from seed {} (the AOT-trained model \
                 lives only in the HLO artifacts) and activation ranges are recalibrated; \
                 runs are self-consistent but not comparable to the PJRT backend",
                be.m.seed
            );
            be.recalibrate_manifest_ranges()?;
        }
        Ok(be)
    }

    /// Re-derive `a_max` / `pooled_a_max` / per-split latent ranges from
    /// seeded noise probes through this backend's own weights, replacing
    /// whatever the manifest carried.
    fn recalibrate_manifest_ranges(&mut self) -> Result<()> {
        let hw = self.m.input_hw;
        let mut rng = Rng::new(self.m.seed.wrapping_mul(0x2545_F491_4F6C_DD1D) ^ 0xCA11_B8A7);
        let probes: Vec<f32> = (0..32 * hw * hw * 3).map(|_| rng.f32()).collect();
        let (a_max, pooled) = self.calibrate_act_ranges(&probes, 16)?;
        let n_conv = self.n_conv_layers();
        let splits: Vec<usize> = self.m.latent.keys().copied().collect();
        let mut fp32_ranges = Vec::with_capacity(splits.len());
        for &l in &splits {
            let lelems = self.latent_elems(l)?;
            let b = probes.len() / (hw * hw * 3);
            let mut lat = vec![0f32; b * lelems];
            self.frozen_forward(l, false, false, &probes, &mut lat)?;
            let max = lat.iter().fold(0f32, |a, &v| a.max(v));
            fp32_ranges.push(max.max(1e-3));
        }
        self.m.a_max = a_max.iter().map(|&v| v.max(1e-3) as f64).collect();
        self.m.pooled_a_max = pooled.max(1e-3) as f64;
        for (&l, fp32) in splits.iter().zip(&fp32_ranges) {
            let int8 = if l >= n_conv { self.m.pooled_a_max } else { self.m.a_max[l - 1] };
            if let Some(info) = self.m.latent.get_mut(&l) {
                info.a_max_int8 = int8;
                info.a_max_fp32 = *fp32 as f64;
            }
        }
        Ok(())
    }

    /// The network this backend executes (parsed from the manifest).
    pub fn net(&self) -> &NetDesc {
        &self.net
    }

    fn n_conv_layers(&self) -> usize {
        self.net.layers.len() - 1
    }

    fn latent_elems_of(net: &NetDesc, l: usize) -> Result<usize> {
        let n_conv = net.layers.len() - 1;
        ensure!(l <= n_conv, "split l={l} beyond the layer graph ({n_conv} conv layers)");
        if l == n_conv {
            Ok(net.layers[n_conv].cin) // pooled feature vector
        } else {
            Ok(net.layers[l].in_elems())
        }
    }

    /// Latent vector size at split `l` (elements).
    pub fn latent_elems(&self, l: usize) -> Result<usize> {
        Self::latent_elems_of(&self.net, l)
    }

    /// One conv layer forward on the engine. `x` is `[b, hw_in², cin]`
    /// NHWC-flattened; returns `[b, hw_out², cout]`.
    fn conv_fw(&self, layer: &LayerDesc, w: &[f32], x: &[f32], b: usize) -> Vec<f32> {
        conv_fw(self.engine, layer, w, x, b)
    }

    /// Global average pool `[b, hw², c] -> [b, c]`.
    fn pool(x: &[f32], b: usize, hw2: usize, c: usize) -> Vec<f32> {
        let mut out = vec![0f32; b * c];
        let inv = 1.0 / hw2 as f32;
        for bi in 0..b {
            let dst = &mut out[bi * c..(bi + 1) * c];
            for p in 0..hw2 {
                let src = &x[(bi * hw2 + p) * c..(bi * hw2 + p + 1) * c];
                for (d, &s) in dst.iter_mut().zip(src) {
                    *d += s;
                }
            }
            for d in dst.iter_mut() {
                *d *= inv;
            }
        }
        out
    }

    /// PTQ calibration (mirrors `python/compile/quantize.py::calibrate`):
    /// run `images` through the INT-8 pipeline with progressively-updated
    /// per-layer ranges; returns `(a_max per conv layer, pooled_a_max)`.
    pub fn calibrate_act_ranges(&self, images: &[f32], batch: usize) -> Result<(Vec<f32>, f32)> {
        let hw = self.m.input_hw;
        let img = hw * hw * 3;
        ensure!(!images.is_empty() && images.len() % img == 0, "calibration images size");
        let n = images.len() / img;
        let n_conv = self.n_conv_layers();
        let mut a_max = vec![0f32; n_conv];
        let mut pooled_max = 0f32;
        let a_bits = self.m.a_bits;
        let mut start = 0;
        while start < n {
            let count = (n - start).min(batch.max(1));
            let mut x = images[start * img..(start + count) * img].to_vec();
            fake_quant_act(&mut x, self.m.input_a_max as f32, a_bits);
            for (i, layer) in self.net.layers[..n_conv].iter().enumerate() {
                let mut y = self.conv_fw(layer, &self.weights_int8[i], &x, count);
                for v in y.iter_mut() {
                    *v = v.max(0.0);
                }
                for &v in &y {
                    a_max[i] = a_max[i].max(v);
                }
                fake_quant_act(&mut y, a_max[i].max(1e-6), a_bits);
                x = y;
            }
            let last = &self.net.layers[n_conv - 1];
            let hw2 = last.hw_out() * last.hw_out();
            let pooled = Self::pool(&x, count, hw2, last.cout);
            for &v in &pooled {
                pooled_max = pooled_max.max(v);
            }
            start += count;
        }
        Ok((a_max, pooled_max))
    }
}

impl Backend for NativeBackend {
    fn manifest(&self) -> &Manifest {
        &self.m
    }

    fn platform(&self) -> String {
        format!(
            "native (tinycl kernel engine, {} threads, {} kB L2 blocks)",
            self.engine.threads,
            self.engine.l2_bytes / 1024
        )
    }

    fn load_params(&self, l: usize) -> Result<ParamState> {
        let n_conv_total = self.n_conv_layers();
        ensure!(l <= n_conv_total, "split l={l} beyond the layer graph");
        // Always the deterministic seeded init — never `params_l{l}.bin`:
        // those weights were fine-tuned against the AOT model's frozen
        // stage, whose trained weights are baked into the HLO artifacts
        // and unrecoverable here. Loading them over this backend's seeded
        // frozen stage would silently produce a meaningless model (the
        // latent distributions differ entirely); the seeded init keeps
        // every native run a pure function of `(manifest.seed, config)`.
        //
        // Init: adaptive conv weights from the full-net seeded weights,
        // identity affine, He head — tensor order matches the AOT
        // flattening (per layer sorted keys b, g, w; head b, w)
        let mut names = Vec::new();
        let mut tensors = Vec::new();
        let n_conv = n_conv_total - l.min(n_conv_total);
        for li in 0..n_conv {
            let layer = &self.net.layers[l + li];
            names.push(format!("layer{li}.b"));
            tensors.push(TensorF32::zeros(vec![layer.cout]));
            names.push(format!("layer{li}.g"));
            tensors.push(TensorF32::new(vec![layer.cout], vec![1.0; layer.cout]));
            names.push(format!("layer{li}.w"));
            let shape = match layer.kind {
                LayerKind::DepthWise => vec![3, 3, layer.cin],
                LayerKind::Conv3x3 => vec![3, 3, layer.cin, layer.cout],
                LayerKind::PointWise => vec![layer.cin, layer.cout],
                LayerKind::Linear => unreachable!(),
            };
            tensors.push(TensorF32::new(shape, self.weights[l + li].clone()));
        }
        names.push(format!("layer{n_conv}.b"));
        tensors.push(TensorF32::zeros(vec![self.m.num_classes]));
        names.push(format!("layer{n_conv}.w"));
        tensors.push(TensorF32::new(
            vec![self.m.feat_dim, self.m.num_classes],
            self.head_w.clone(),
        ));
        Ok(ParamState::from_tensors(names, tensors))
    }

    fn frozen_forward(
        &self,
        l: usize,
        int8: bool,
        _eval_batch: bool,
        images: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        let hw = self.m.input_hw;
        let img = hw * hw * 3;
        ensure!(!images.is_empty() && images.len() % img == 0, "frozen_forward: image batch size");
        let b = images.len() / img;
        let n_conv = self.n_conv_layers();
        let lelems = self.latent_elems(l)?;
        ensure!(out.len() == b * lelems, "frozen_forward: latent buffer size");
        let a_bits = self.m.a_bits;

        let mut x = images.to_vec();
        if int8 {
            fake_quant_act(&mut x, self.m.input_a_max as f32, a_bits);
        }
        let stop = l.min(n_conv);
        for i in 0..stop {
            let layer = &self.net.layers[i];
            let w = if int8 { &self.weights_int8[i] } else { &self.weights[i] };
            let mut y = self.conv_fw(layer, w, &x, b);
            for v in y.iter_mut() {
                *v = v.max(0.0);
            }
            if int8 {
                fake_quant_act(&mut y, self.m.a_max[i] as f32, a_bits);
            }
            x = y;
        }
        if l >= n_conv {
            let last = &self.net.layers[n_conv - 1];
            let hw2 = last.hw_out() * last.hw_out();
            x = Self::pool(&x, b, hw2, last.cout);
        }
        ensure!(x.len() == out.len(), "frozen_forward: internal size mismatch");
        out.copy_from_slice(&x);
        Ok(())
    }

    fn train_step(
        &self,
        l: usize,
        params: &mut ParamState,
        latents: &[f32],
        labels: &[i32],
        lr: f32,
    ) -> Result<(f64, u64)> {
        let n_conv_total = self.n_conv_layers();
        ensure!(l <= n_conv_total, "split l={l} beyond the layer graph");
        let lelems = self.latent_elems(l)?;
        let b = labels.len();
        ensure!(b > 0 && latents.len() == b * lelems, "train_step: latent batch size");
        let n_conv = n_conv_total - l;
        ensure!(
            params.len() == 3 * n_conv + 2,
            "train_step: ParamState has {} tensors, expected {}",
            params.len(),
            3 * n_conv + 2
        );
        for li in 0..n_conv {
            ensure!(
                self.net.layers[l + li].kind != LayerKind::Conv3x3,
                "the stem conv is never adaptive in the supported splits"
            );
        }
        let ncls = self.m.num_classes;
        let feat = self.m.feat_dim;

        // ---- forward, stashing what backward needs ----------------------
        // acts[li] = input of adaptive conv layer li (post-ReLU upstream);
        // zs[li] = its raw conv output (pre-affine, for dg)
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(n_conv + 1);
        let mut zs: Vec<Vec<f32>> = Vec::with_capacity(n_conv);
        acts.push(latents.to_vec());
        for li in 0..n_conv {
            let layer = &self.net.layers[l + li];
            let w = params.tensor(3 * li + 2);
            ensure!(w.elems() == weight_len(layer), "train_step: layer {li} weight size");
            let z = self.conv_fw(layer, &w.data, &acts[li], b);
            let g = &params.tensor(3 * li + 1).data;
            let bb = &params.tensor(3 * li).data;
            let cout = layer.cout;
            let mut a = vec![0f32; z.len()];
            for (idx, (&zv, av)) in z.iter().zip(a.iter_mut()).enumerate() {
                let ch = idx % cout;
                *av = (zv * g[ch] + bb[ch]).max(0.0);
            }
            zs.push(z);
            acts.push(a);
        }
        let feats: Vec<f32> = if n_conv > 0 {
            let last = &self.net.layers[l + n_conv - 1];
            let hw2 = last.hw_out() * last.hw_out();
            Self::pool(acts.last().unwrap(), b, hw2, last.cout)
        } else {
            latents.to_vec()
        };
        let head_w = &params.tensor(3 * n_conv + 1).data;
        let head_b = &params.tensor(3 * n_conv).data;
        ensure!(head_w.len() == feat * ncls && head_b.len() == ncls, "train_step: head size");
        let mut logits = vec![0f32; b * ncls];
        self.engine.matmul_fw_into(&feats, head_w, b, feat, ncls, &mut logits);
        for (idx, v) in logits.iter_mut().enumerate() {
            *v += head_b[idx % ncls];
        }

        // ---- softmax cross-entropy loss + dlogits -----------------------
        let mut dlogits = vec![0f32; b * ncls];
        let (mean_loss, correct) = softmax_ce(&logits, labels, ncls, Some(&mut dlogits))?;

        // ---- backward: head -> pool -> conv stack -----------------------
        let mut d_head_w = vec![0f32; feat * ncls];
        self.engine.matmul_bw_grad_into(&feats, &dlogits, b, feat, ncls, &mut d_head_w);
        let mut d_head_b = vec![0f32; ncls];
        for (idx, &d) in dlogits.iter().enumerate() {
            d_head_b[idx % ncls] += d;
        }
        let mut dfeat = vec![0f32; b * feat];
        self.engine.matmul_bw_err_into(&dlogits, head_w, b, feat, ncls, &mut dfeat);

        // grads of the conv stack, applied after the walk (SGD is a pure
        // p -= lr*g over the pre-step forward, like the AOT module)
        let mut conv_grads: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = Vec::with_capacity(n_conv);
        if n_conv > 0 {
            let last = &self.net.layers[l + n_conv - 1];
            let hw2 = last.hw_out() * last.hw_out();
            let inv = 1.0 / hw2 as f32;
            let mut da = vec![0f32; b * hw2 * last.cout];
            for (idx, v) in da.iter_mut().enumerate() {
                let bi = idx / (hw2 * last.cout);
                let ch = idx % last.cout;
                *v = dfeat[bi * feat + ch] * inv;
            }
            for li in (0..n_conv).rev() {
                let layer = &self.net.layers[l + li];
                let cout = layer.cout;
                let g = &params.tensor(3 * li + 1).data;
                let a = &acts[li + 1];
                let z = &zs[li];
                let x = &acts[li];
                let mut dz = vec![0f32; z.len()];
                let mut db = vec![0f32; cout];
                let mut dg = vec![0f32; cout];
                for idx in 0..z.len() {
                    if a[idx] > 0.0 {
                        let ch = idx % cout;
                        let dy = da[idx];
                        db[ch] += dy;
                        dg[ch] += dy * z[idx];
                        dz[idx] = dy * g[ch];
                    }
                }
                let w = &params.tensor(3 * li + 2).data;
                let h = layer.hw_in;
                let (dx, dw) = match layer.kind {
                    LayerKind::PointWise => {
                        let rows = b * h * h;
                        let mut dx = vec![0f32; rows * layer.cin];
                        self.engine.matmul_bw_err_into(&dz, w, rows, layer.cin, cout, &mut dx);
                        let mut dw = vec![0f32; layer.cin * cout];
                        self.engine.matmul_bw_grad_into(x, &dz, rows, layer.cin, cout, &mut dw);
                        (dx, dw)
                    }
                    LayerKind::DepthWise => {
                        let dx = depthwise_bw_err(&dz, w, b, h, h, layer.cin, layer.stride);
                        let dw = depthwise_bw_grad(x, &dz, b, h, h, layer.cin, layer.stride);
                        (dx, dw)
                    }
                    LayerKind::Conv3x3 | LayerKind::Linear => unreachable!(),
                };
                conv_grads.push((db, dg, dw));
                da = dx;
            }
            conv_grads.reverse();
        }

        // ---- SGD update (p -= lr * grad) --------------------------------
        for (li, (db, dg, dw)) in conv_grads.iter().enumerate() {
            for (p, &gr) in params.data_mut(3 * li).iter_mut().zip(db) {
                *p -= lr * gr;
            }
            for (p, &gr) in params.data_mut(3 * li + 1).iter_mut().zip(dg) {
                *p -= lr * gr;
            }
            for (p, &gr) in params.data_mut(3 * li + 2).iter_mut().zip(dw) {
                *p -= lr * gr;
            }
        }
        for (p, &gr) in params.data_mut(3 * n_conv).iter_mut().zip(&d_head_b) {
            *p -= lr * gr;
        }
        for (p, &gr) in params.data_mut(3 * n_conv + 1).iter_mut().zip(&d_head_w) {
            *p -= lr * gr;
        }

        Ok((mean_loss, correct))
    }

    fn adaptive_eval(
        &self,
        l: usize,
        params: &ParamState,
        latents: &[f32],
        out_logits: &mut [f32],
    ) -> Result<()> {
        let n_conv_total = self.n_conv_layers();
        ensure!(l <= n_conv_total, "split l={l} beyond the layer graph");
        let lelems = self.latent_elems(l)?;
        ensure!(!latents.is_empty() && latents.len() % lelems == 0, "adaptive_eval: latent batch");
        let b = latents.len() / lelems;
        let ncls = self.m.num_classes;
        let feat = self.m.feat_dim;
        ensure!(out_logits.len() == b * ncls, "adaptive_eval: logits buffer size");
        let n_conv = n_conv_total - l;
        ensure!(
            params.len() == 3 * n_conv + 2,
            "adaptive_eval: ParamState has {} tensors, expected {}",
            params.len(),
            3 * n_conv + 2
        );

        let mut x = latents.to_vec();
        for li in 0..n_conv {
            let layer = &self.net.layers[l + li];
            let w = params.tensor(3 * li + 2);
            ensure!(w.elems() == weight_len(layer), "adaptive_eval: layer {li} weight size");
            let z = self.conv_fw(layer, &w.data, &x, b);
            let g = &params.tensor(3 * li + 1).data;
            let bb = &params.tensor(3 * li).data;
            let cout = layer.cout;
            let mut a = vec![0f32; z.len()];
            for (idx, (&zv, av)) in z.iter().zip(a.iter_mut()).enumerate() {
                let ch = idx % cout;
                *av = (zv * g[ch] + bb[ch]).max(0.0);
            }
            x = a;
        }
        let feats = if n_conv > 0 {
            let last = &self.net.layers[l + n_conv - 1];
            let hw2 = last.hw_out() * last.hw_out();
            Self::pool(&x, b, hw2, last.cout)
        } else {
            x
        };
        let head_w = &params.tensor(3 * n_conv + 1).data;
        let head_b = &params.tensor(3 * n_conv).data;
        ensure!(head_w.len() == feat * ncls && head_b.len() == ncls, "adaptive_eval: head size");
        self.engine.matmul_fw_into(&feats, head_w, b, feat, ncls, out_logits);
        for (idx, v) in out_logits.iter_mut().enumerate() {
            *v += head_b[idx % ncls];
        }
        Ok(())
    }
}

impl NativeBackend {
    /// Mean cross-entropy loss + correct count of the adaptive stage on a
    /// latent batch — forward only, params untouched. Tests use this to
    /// finite-difference-check the fused train step's gradients.
    pub fn loss_and_correct(
        &self,
        l: usize,
        params: &ParamState,
        latents: &[f32],
        labels: &[i32],
    ) -> Result<(f64, u64)> {
        let b = labels.len();
        let ncls = self.m.num_classes;
        let mut logits = vec![0f32; b * ncls];
        self.adaptive_eval(l, params, latents, &mut logits)?;
        softmax_ce(&logits, labels, ncls, None)
    }
}
