//! The native execution backend: runs the manifest's layer graph directly
//! on the in-tree kernel engine — no Python, no artifacts, no XLA.
//!
//! Semantics mirror `python/compile/model.py` exactly:
//!
//! - **frozen stage** (`layers [0, l)`): conv → ReLU per layer; in INT-8
//!   mode the stage executes as **true integer arithmetic** by default —
//!   weights live as `i8` codes (round-to-nearest full-range affine,
//!   paper eq. 1), activations cross into UINT-8 codes once at the input
//!   boundary (eq. 2), every conv is an i8×i8→i32 kernel
//!   ([`Engine::matmul_fw_i8_into`] and friends), and each layer
//!   boundary is one fixed-point multiplier+shift requantization
//!   ([`crate::quant::Requant`]). Codes leave the pipeline exactly once,
//!   dequantized onto the very grid the fake-quant FP32 oracle produces
//!   (≤ 1 LSB parity per layer, pinned by the parity suite). The legacy
//!   fake-quant FP32 simulation survives behind
//!   `TINYCL_FROZEN_PATH=f32` ([`FrozenPath`]) as the oracle/escape
//!   hatch; split `l = L` pools the final feature map (the paper's l=27
//!   row of Table III);
//! - **adaptive stage** (`layers [l, L)` + head): conv → per-channel
//!   affine (`y*g + b`, the folded-BN trainable normalization) → ReLU,
//!   then global average pool and the linear head. The train step fuses
//!   forward + BW-ERR + BW-GRAD + SGD in one call: pointwise/linear
//!   passes run on the blocked parallel engine
//!   ([`Engine::matmul_fw_into`] / `bw_err` / `bw_grad`), depthwise
//!   passes on the dedicated kernels
//!   ([`crate::kernels::depthwise_bw_err`]/[`crate::kernels::depthwise_bw_grad`]).
//!
//! Weights are seeded deterministically from `manifest.seed` (He init +
//! layer-wise standardization), so a native run is a pure function of
//! `(manifest, dataset, config, seed)`. The AOT-trained model lives only
//! in the HLO artifacts (frozen weights are baked constants), so when the
//! native backend is pointed at an on-disk artifacts manifest it
//! re-derives everything from the seed and recalibrates the activation
//! ranges — self-consistent, but deliberately not comparable to PJRT.

use anyhow::{bail, ensure, Result};

use crate::kernels::{depthwise_bw_err, depthwise_bw_grad, Engine};
use crate::models::{LayerDesc, LayerKind, NetDesc};
use crate::quant::requant::{
    act_scale, dequantize_acts_into, quantize_acts_into, quantize_weights_i8,
    requantize_relu_into, QuantizedWeights, Requant,
};
use crate::util::rng::Rng;

use super::backend::Backend;
use super::manifest::Manifest;
use super::params::ParamState;
use super::TensorF32;

/// Which implementation executes the INT-8 frozen stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrozenPath {
    /// true integer execution: `i8` weights, UINT-8 activation codes,
    /// i32 accumulation, fixed-point requantization (the default)
    Int8,
    /// the fake-quant FP32 simulation — grid values carried as f32, one
    /// blocked-f32 conv + quantize pass per layer. The integer path's
    /// oracle, and the escape hatch for A/B debugging.
    FakeQuantF32,
}

impl FrozenPath {
    /// Parse `$TINYCL_FROZEN_PATH` (`int8` | `f32`; empty = `int8`).
    /// Unknown values are an error, not a silent fallback.
    pub fn from_env() -> Result<FrozenPath> {
        match std::env::var("TINYCL_FROZEN_PATH").unwrap_or_default().as_str() {
            "" | "int8" => Ok(FrozenPath::Int8),
            "f32" => Ok(FrozenPath::FakeQuantF32),
            other => Err(anyhow::anyhow!(
                "TINYCL_FROZEN_PATH='{other}' is not recognized; valid values: int8, f32"
            )),
        }
    }
}

/// One frozen layer of the integer pipeline: true-`i8` weight codes and
/// the fixed-point requantization of its output boundary
/// (`S_in · S_w / S_out`).
struct FrozenInt8Layer {
    w: QuantizedWeights,
    requant: Requant,
}

pub struct NativeBackend {
    m: Manifest,
    engine: Engine,
    net: NetDesc,
    /// per-conv-layer weights, engine layout:
    /// Conv3x3 `[9*cin, cout]` ((ky,kx,c) rows), DepthWise `[9*c]`
    /// ((ky*3+kx)*c + ch), PointWise `[cin, cout]`
    weights: Vec<Vec<f32>>,
    /// the INT-8 frozen stage in true `i8` storage — 1 byte per weight,
    /// the 4x RAM drop vs the old dequantized-f32-grid copy that
    /// `models::memory`'s INT-8 column always charged for
    frozen_i8: Vec<FrozenInt8Layer>,
    /// which implementation `frozen_forward(int8 = true)` runs
    frozen_path: FrozenPath,
    /// fake-quant grid weights (`q · S_w` as f32), materialized ONLY on
    /// the simulation path — the integer path dequantizes transiently
    /// when an oracle needs them (calibration)
    frozen_sim: Option<Vec<Vec<f32>>>,
    /// linear head `[feat_dim, num_classes]`
    head_w: Vec<f32>,
}

/// Number of f32s a conv layer's weight tensor holds (engine layout).
fn weight_len(layer: &LayerDesc) -> usize {
    match layer.kind {
        LayerKind::Conv3x3 => 9 * layer.cin * layer.cout,
        LayerKind::DepthWise => 9 * layer.cin,
        LayerKind::PointWise | LayerKind::Linear => layer.cin * layer.cout,
    }
}

/// Parse the manifest's `model.arch` tuples into a [`NetDesc`] (conv
/// layers + the pool/linear head appended), mirroring the python `ARCH`.
pub fn net_from_manifest(m: &Manifest) -> Result<NetDesc> {
    let mut layers = Vec::with_capacity(m.arch.len() + 1);
    let mut hw = m.input_hw;
    for (i, (kind, cin, cout, stride)) in m.arch.iter().enumerate() {
        let k = match kind.as_str() {
            "conv3x3" => LayerKind::Conv3x3,
            "dw" => LayerKind::DepthWise,
            "pw" => LayerKind::PointWise,
            other => bail!("manifest arch: unknown layer kind '{other}'"),
        };
        ensure!(*stride >= 1, "layer {i}: stride must be >= 1");
        layers.push(LayerDesc {
            idx: i,
            kind: k,
            cin: *cin,
            cout: *cout,
            stride: *stride,
            hw_in: hw,
        });
        hw = hw.div_ceil(*stride);
    }
    let feat = m.arch.last().map(|t| t.2).unwrap_or(0);
    ensure!(feat == m.feat_dim, "manifest feat_dim {} != last conv cout {feat}", m.feat_dim);
    layers.push(LayerDesc {
        idx: layers.len(),
        kind: LayerKind::Linear,
        cin: m.feat_dim,
        cout: m.num_classes,
        stride: 1,
        hw_in: hw,
    });
    Ok(NetDesc { name: "manifest", input_hw: m.input_hw, num_classes: m.num_classes, layers })
}

/// One conv layer forward on the engine (free function: also used during
/// construction, before `self` exists).
fn conv_fw(engine: Engine, layer: &LayerDesc, w: &[f32], x: &[f32], b: usize) -> Vec<f32> {
    let h = layer.hw_in;
    let mut out = vec![0f32; b * layer.out_elems()];
    match layer.kind {
        LayerKind::Conv3x3 => {
            engine.conv3x3_fw_into(x, w, b, h, h, layer.cin, layer.stride, layer.cout, &mut out);
        }
        LayerKind::DepthWise => {
            engine.depthwise_fw_into(x, w, b, h, h, layer.cin, layer.stride, &mut out);
        }
        LayerKind::PointWise => {
            debug_assert_eq!(layer.stride, 1, "pointwise stride is always 1");
            let rows = b * h * h;
            engine.matmul_fw_into(x, w, rows, layer.cin, layer.cout, &mut out);
        }
        LayerKind::Linear => unreachable!("linear handled by the head path"),
    }
    out
}

/// Layer-wise weight standardization on seeded noise probes: rescale each
/// layer so its post-ReLU std over the probe batch is 1. This is the
/// random-net analogue of the folded-BN scales the real pipeline gets
/// from pretraining — without it, activation variance decays ~100x over
/// the 15-layer stack and the adaptive stage's SGD is hopelessly
/// ill-conditioned (flushed out by the first end-to-end native runs).
fn normalize_weights(engine: Engine, net: &NetDesc, weights: &mut [Vec<f32>], seed: u64) {
    let mut rng = Rng::new(seed.wrapping_mul(0x6C62_272E_07BB_0142) ^ 0x57A4_DA12);
    let probes = 16usize;
    let hw = net.input_hw;
    let mut x: Vec<f32> = (0..probes * hw * hw * 3).map(|_| rng.f32()).collect();
    for (i, layer) in net.layers[..weights.len()].iter().enumerate() {
        let mut y = conv_fw(engine, layer, &weights[i], &x, probes);
        for v in y.iter_mut() {
            *v = v.max(0.0);
        }
        let n = y.len() as f64;
        let mean: f64 = y.iter().map(|&v| v as f64).sum::<f64>() / n;
        let var: f64 = y.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
        let sd = (var.sqrt() as f32).max(1e-6);
        let inv = 1.0 / sd;
        for w in weights[i].iter_mut() {
            *w *= inv;
        }
        for v in y.iter_mut() {
            *v *= inv;
        }
        x = y;
    }
}

/// Numerically-stable softmax cross-entropy over a logits batch: returns
/// `(mean_loss, argmax_correct)` and, when `dlogits` is given (the train
/// step), fills it with `d(mean_loss)/d(logits)`. One implementation for
/// both the fused step and the [`NativeBackend::loss_and_correct`] oracle
/// the FD tests compare it against.
fn softmax_ce(
    logits: &[f32],
    labels: &[i32],
    ncls: usize,
    mut dlogits: Option<&mut [f32]>,
) -> Result<(f64, u64)> {
    let b = labels.len();
    ensure!(b > 0 && logits.len() == b * ncls, "softmax_ce: logits/labels size");
    if let Some(d) = dlogits.as_ref() {
        ensure!(d.len() == b * ncls, "softmax_ce: dlogits size");
    }
    let inv_b = 1.0 / b as f32;
    let mut loss_sum = 0f64;
    let mut correct = 0u64;
    for bi in 0..b {
        let row = &logits[bi * ncls..(bi + 1) * ncls];
        let label = labels[bi];
        ensure!(
            (0..ncls as i32).contains(&label),
            "softmax_ce: label {label} out of range"
        );
        let mut max = f32::NEG_INFINITY;
        let mut argmax = 0;
        for (c, &v) in row.iter().enumerate() {
            if v > max {
                max = v;
                argmax = c;
            }
        }
        let mut sum = 0f32;
        for &v in row {
            sum += (v - max).exp();
        }
        let lse = max + sum.ln();
        loss_sum += (lse - row[label as usize]) as f64;
        if argmax == label as usize {
            correct += 1;
        }
        if let Some(d) = dlogits.as_mut() {
            let drow = &mut d[bi * ncls..(bi + 1) * ncls];
            for (c, dv) in drow.iter_mut().enumerate() {
                let p = (row[c] - lse).exp();
                *dv = (p - if c == label as usize { 1.0 } else { 0.0 }) * inv_b;
            }
        }
    }
    Ok((loss_sum / b as f64, correct))
}

/// In-place activation fake-quant (paper eq. 2): UINT-Q affine on the
/// post-ReLU (non-negative) grid.
fn fake_quant_act(x: &mut [f32], a_max: f32, bits: u8) {
    let levels = ((1u32 << bits) - 1) as f32;
    let scale = (a_max / levels).max(1e-12);
    let inv = 1.0 / scale;
    for v in x.iter_mut() {
        *v = (*v * inv).floor().clamp(0.0, levels) * scale;
    }
}

/// Fixed-point requantization per frozen layer, rebuilt whenever the
/// activation ranges change (construction, recalibration): the combined
/// scale `S_in · S_w / S_out` of layer `i`, where `S_in` is the input
/// boundary's activation scale (`input_a_max` for the stem, `a_max[i-1]`
/// after) and `S_out` is `a_max[i]`'s.
fn build_requants(m: &Manifest, layers: &mut [FrozenInt8Layer]) {
    let a_bits = m.a_bits;
    let mut in_a_max = m.input_a_max as f32;
    for (i, fz) in layers.iter_mut().enumerate() {
        let s_in = act_scale(in_a_max, a_bits) as f64;
        let s_out = act_scale(m.a_max[i] as f32, a_bits) as f64;
        fz.requant = Requant::from_scale(s_in * fz.w.scale as f64 / s_out);
        in_a_max = m.a_max[i] as f32;
    }
}

impl NativeBackend {
    pub fn new(m: Manifest) -> Result<NativeBackend> {
        Self::with_frozen_path(m, FrozenPath::from_env()?)
    }

    /// [`NativeBackend::new`] with an explicit frozen-stage execution
    /// path (benches and the parity suite construct both arms
    /// side-by-side without touching the environment).
    pub fn with_frozen_path(m: Manifest, frozen_path: FrozenPath) -> Result<NativeBackend> {
        let net = net_from_manifest(&m)?;
        let n_conv = net.layers.len() - 1;
        ensure!(
            m.a_max.len() == n_conv,
            "manifest a_max has {} entries for {n_conv} conv layers",
            m.a_max.len()
        );
        // seeded He init, one forked stream per layer (deterministic in
        // manifest.seed alone)
        let mut master = Rng::new(m.seed.wrapping_mul(0x9E3779B97F4A7C15) ^ 0x5EED_BACC);
        let mut weights = Vec::with_capacity(n_conv);
        for layer in &net.layers[..n_conv] {
            let mut r = master.fork(layer.idx as u64 + 1);
            let std = match layer.kind {
                LayerKind::Conv3x3 => (2.0 / (9.0 * layer.cin as f64)).sqrt(),
                LayerKind::DepthWise => (2.0 / 9.0f64).sqrt(),
                LayerKind::PointWise => (2.0 / layer.cin as f64).sqrt(),
                LayerKind::Linear => unreachable!(),
            };
            weights.push(
                (0..weight_len(layer))
                    .map(|_| (r.normal() * std) as f32)
                    .collect::<Vec<f32>>(),
            );
        }
        let mut hr = master.fork(0x4EAD);
        let head_std = (1.0 / m.feat_dim as f64).sqrt();
        let head_w: Vec<f32> = (0..m.feat_dim * m.num_classes)
            .map(|_| (hr.normal() * head_std) as f32)
            .collect();
        let engine = crate::kernels::default_engine();
        normalize_weights(engine, &net, &mut weights, m.seed);
        // true-i8 frozen stage: codes + per-tensor scale/offset now,
        // requantization constants once a_max is final (below)
        let mut frozen_i8: Vec<FrozenInt8Layer> = weights
            .iter()
            .map(|w| FrozenInt8Layer {
                w: quantize_weights_i8(w, m.w_bits),
                requant: Requant::from_scale(0.0),
            })
            .collect();
        build_requants(&m, &mut frozen_i8);
        let frozen_sim = (frozen_path == FrozenPath::FakeQuantF32)
            .then(|| frozen_i8.iter().map(|fz| fz.w.dequantize()).collect());
        // when the manifest carries latent shapes, they must agree with
        // the graph we will execute
        for (&l, info) in &m.latent {
            let expect = Self::latent_elems_of(&net, l)?;
            ensure!(
                info.elems() == expect,
                "manifest latent l={l}: {} elems, layer graph says {expect}",
                info.elems()
            );
        }
        let mut be =
            NativeBackend { m, engine, net, weights, frozen_i8, frozen_path, frozen_sim, head_w };
        // A manifest that exists on disk came from the AOT pipeline: its
        // a_max ranges were calibrated on the *trained* model, not on this
        // backend's seeded weights — fake-quantizing with them would clip
        // activations at arbitrary points and silently wreck accuracy.
        // Recalibrate every range against the weights we actually execute
        // (the synthetic generator's manifests are already consistent by
        // construction and never hit this path).
        if be.m.dir.join("manifest.json").is_file() {
            eprintln!(
                "[native] note: executing an on-disk artifacts manifest — frozen weights \
                 and adaptive params are re-derived from seed {} (the AOT-trained model \
                 lives only in the HLO artifacts) and activation ranges are recalibrated; \
                 runs are self-consistent but not comparable to the PJRT backend",
                be.m.seed
            );
            be.recalibrate_manifest_ranges()?;
        }
        Ok(be)
    }

    /// Re-derive `a_max` / `pooled_a_max` / per-split latent ranges from
    /// seeded noise probes through this backend's own weights, replacing
    /// whatever the manifest carried.
    fn recalibrate_manifest_ranges(&mut self) -> Result<()> {
        let hw = self.m.input_hw;
        let mut rng = Rng::new(self.m.seed.wrapping_mul(0x2545_F491_4F6C_DD1D) ^ 0xCA11_B8A7);
        let probes: Vec<f32> = (0..32 * hw * hw * 3).map(|_| rng.f32()).collect();
        let (a_max, pooled) = self.calibrate_act_ranges(&probes, 16)?;
        let n_conv = self.n_conv_layers();
        let splits: Vec<usize> = self.m.latent.keys().copied().collect();
        let mut fp32_ranges = Vec::with_capacity(splits.len());
        for &l in &splits {
            let lelems = self.latent_elems(l)?;
            let b = probes.len() / (hw * hw * 3);
            let mut lat = vec![0f32; b * lelems];
            self.frozen_forward(l, false, false, &probes, &mut lat)?;
            let max = lat.iter().fold(0f32, |a, &v| a.max(v));
            fp32_ranges.push(max.max(1e-3));
        }
        self.m.a_max = a_max.iter().map(|&v| v.max(1e-3) as f64).collect();
        self.m.pooled_a_max = pooled.max(1e-3) as f64;
        for (&l, fp32) in splits.iter().zip(&fp32_ranges) {
            let int8 = if l >= n_conv { self.m.pooled_a_max } else { self.m.a_max[l - 1] };
            if let Some(info) = self.m.latent.get_mut(&l) {
                info.a_max_int8 = int8;
                info.a_max_fp32 = *fp32 as f64;
            }
        }
        // the requantization constants bake S_in/S_out in — rebuild them
        // against the ranges we just measured
        build_requants(&self.m, &mut self.frozen_i8);
        Ok(())
    }

    /// The network this backend executes (parsed from the manifest).
    pub fn net(&self) -> &NetDesc {
        &self.net
    }

    /// Which implementation `frozen_forward(int8 = true)` runs.
    pub fn frozen_path(&self) -> FrozenPath {
        self.frozen_path
    }

    /// Bytes of true-`i8` frozen-weight storage this backend holds — one
    /// byte per frozen weight, the figure `models::memory`'s INT-8
    /// frozen column charges (asserted equal in `models/memory.rs`
    /// tests).
    pub fn frozen_arena_bytes(&self) -> usize {
        self.frozen_i8.iter().map(|fz| fz.w.codes.len()).sum()
    }

    fn n_conv_layers(&self) -> usize {
        self.net.layers.len() - 1
    }

    fn latent_elems_of(net: &NetDesc, l: usize) -> Result<usize> {
        let n_conv = net.layers.len() - 1;
        ensure!(l <= n_conv, "split l={l} beyond the layer graph ({n_conv} conv layers)");
        if l == n_conv {
            Ok(net.layers[n_conv].cin) // pooled feature vector
        } else {
            Ok(net.layers[l].in_elems())
        }
    }

    /// Latent vector size at split `l` (elements).
    pub fn latent_elems(&self, l: usize) -> Result<usize> {
        Self::latent_elems_of(&self.net, l)
    }

    /// One conv layer forward on the engine. `x` is `[b, hw_in², cin]`
    /// NHWC-flattened; returns `[b, hw_out², cout]`.
    fn conv_fw(&self, layer: &LayerDesc, w: &[f32], x: &[f32], b: usize) -> Vec<f32> {
        conv_fw(self.engine, layer, w, x, b)
    }

    /// Global average pool `[b, hw², c] -> [b, c]`.
    fn pool(x: &[f32], b: usize, hw2: usize, c: usize) -> Vec<f32> {
        let mut out = vec![0f32; b * c];
        let inv = 1.0 / hw2 as f32;
        for bi in 0..b {
            let dst = &mut out[bi * c..(bi + 1) * c];
            for p in 0..hw2 {
                let src = &x[(bi * hw2 + p) * c..(bi * hw2 + p + 1) * c];
                for (d, &s) in dst.iter_mut().zip(src) {
                    *d += s;
                }
            }
            for d in dst.iter_mut() {
                *d *= inv;
            }
        }
        out
    }

    /// Fake-quant grid weights of frozen layer `i` (`q · S_w` as f32) —
    /// borrowed from the simulation path's materialized copy when it
    /// exists, dequantized transiently from the i8 codes otherwise.
    /// Bit-identical either way (one rounding rule, one grid).
    fn sim_weight(&self, i: usize) -> std::borrow::Cow<'_, [f32]> {
        match &self.frozen_sim {
            Some(ws) => std::borrow::Cow::Borrowed(ws[i].as_slice()),
            None => std::borrow::Cow::Owned(self.frozen_i8[i].w.dequantize()),
        }
    }

    /// PTQ calibration (mirrors `python/compile/quantize.py::calibrate`):
    /// run `images` through the INT-8 pipeline with progressively-updated
    /// per-layer ranges; returns `(a_max per conv layer, pooled_a_max)`.
    ///
    /// Calibration is inherently a fake-quant measurement (the ranges it
    /// measures are what the integer path's requantization constants are
    /// DERIVED from), so it always runs the FP32 simulation over the
    /// dequantized grid — a once-per-deployment cost.
    pub fn calibrate_act_ranges(&self, images: &[f32], batch: usize) -> Result<(Vec<f32>, f32)> {
        let hw = self.m.input_hw;
        let img = hw * hw * 3;
        ensure!(!images.is_empty() && images.len() % img == 0, "calibration images size");
        let n = images.len() / img;
        let n_conv = self.n_conv_layers();
        let sim: Vec<std::borrow::Cow<'_, [f32]>> =
            (0..n_conv).map(|i| self.sim_weight(i)).collect();
        let mut a_max = vec![0f32; n_conv];
        let mut pooled_max = 0f32;
        let a_bits = self.m.a_bits;
        let mut start = 0;
        while start < n {
            let count = (n - start).min(batch.max(1));
            let mut x = images[start * img..(start + count) * img].to_vec();
            fake_quant_act(&mut x, self.m.input_a_max as f32, a_bits);
            for (i, layer) in self.net.layers[..n_conv].iter().enumerate() {
                let mut y = self.conv_fw(layer, &sim[i], &x, count);
                for v in y.iter_mut() {
                    *v = v.max(0.0);
                }
                for &v in &y {
                    a_max[i] = a_max[i].max(v);
                }
                fake_quant_act(&mut y, a_max[i].max(1e-6), a_bits);
                x = y;
            }
            let last = &self.net.layers[n_conv - 1];
            let hw2 = last.hw_out() * last.hw_out();
            let pooled = Self::pool(&x, count, hw2, last.cout);
            for &v in &pooled {
                pooled_max = pooled_max.max(v);
            }
            start += count;
        }
        Ok((a_max, pooled_max))
    }

    /// The true-INT8 frozen forward: one float→integer crossing at the
    /// input, integer conv + fixed-point requantization per layer, one
    /// integer→float crossing at the split boundary. The emitted latents
    /// sit on exactly the grid the fake-quant oracle emits (same scale
    /// expression, same `code · S` multiply), so everything downstream —
    /// replay packing, pooling, the adaptive stage — is code-for-code
    /// identical given identical codes.
    fn frozen_forward_int8(&self, l: usize, images: &[f32], out: &mut [f32]) -> Result<()> {
        let hw = self.m.input_hw;
        let img = hw * hw * 3;
        ensure!(!images.is_empty() && images.len() % img == 0, "frozen_forward: image batch size");
        let b = images.len() / img;
        let n_conv = self.n_conv_layers();
        let lelems = self.latent_elems(l)?;
        ensure!(out.len() == b * lelems, "frozen_forward: latent buffer size");
        let a_bits = self.m.a_bits;

        let tm = crate::telemetry::global();
        let _fw = tm
            .clone()
            .owned_span(crate::telemetry::EventKind::FrozenForward)
            .payload(b as u64, l as u64)
            .counter(crate::telemetry::Counter::FrozenForwards);
        tm.counter_add(crate::telemetry::Counter::FrozenRows, b as u64);

        let mut q = vec![0u8; images.len()];
        quantize_acts_into(images, self.m.input_a_max as f32, a_bits, &mut q);
        let mut cur_a_max = self.m.input_a_max as f32;
        let stop = l.min(n_conv);
        let mut acc: Vec<i32> = Vec::new();
        for i in 0..stop {
            let layer = &self.net.layers[i];
            let fz = &self.frozen_i8[i];
            let sp = tm
                .span(crate::telemetry::EventKind::FrozenLayer)
                .key(i as u64)
                .payload(i as u64, b as u64);
            let h = layer.hw_in;
            acc.clear();
            acc.resize(b * layer.out_elems(), 0);
            match layer.kind {
                LayerKind::Conv3x3 => self.engine.conv3x3_fw_i8_into(
                    &q,
                    &fz.w.codes,
                    fz.w.off,
                    b,
                    h,
                    h,
                    layer.cin,
                    layer.stride,
                    layer.cout,
                    &mut acc,
                ),
                LayerKind::DepthWise => self.engine.depthwise_fw_i8_into(
                    &q,
                    &fz.w.codes,
                    fz.w.off,
                    b,
                    h,
                    h,
                    layer.cin,
                    layer.stride,
                    &mut acc,
                ),
                LayerKind::PointWise => {
                    debug_assert_eq!(layer.stride, 1, "pointwise stride is always 1");
                    let rows = b * h * h;
                    self.engine.matmul_fw_i8_into(
                        &q,
                        &fz.w.codes,
                        fz.w.off,
                        rows,
                        layer.cin,
                        layer.cout,
                        &mut acc,
                    );
                }
                LayerKind::Linear => unreachable!("linear handled by the head path"),
            }
            q.clear();
            q.resize(acc.len(), 0);
            requantize_relu_into(&acc, fz.requant, a_bits, &mut q);
            tm.record_layer(i, layer_tag(layer.kind), b as u64, sp.elapsed_ns());
            cur_a_max = self.m.a_max[i] as f32;
        }
        if l >= n_conv {
            let mut x = vec![0f32; q.len()];
            dequantize_acts_into(&q, cur_a_max, a_bits, &mut x);
            let last = &self.net.layers[n_conv - 1];
            let hw2 = last.hw_out() * last.hw_out();
            let pooled = Self::pool(&x, b, hw2, last.cout);
            ensure!(pooled.len() == out.len(), "frozen_forward: internal size mismatch");
            out.copy_from_slice(&pooled);
        } else {
            // non-pooled splits dequantize straight into the caller's
            // buffer — no temporary, no copy on the hot path
            ensure!(q.len() == out.len(), "frozen_forward: internal size mismatch");
            dequantize_acts_into(&q, cur_a_max, a_bits, out);
        }
        Ok(())
    }
}

impl Backend for NativeBackend {
    fn manifest(&self) -> &Manifest {
        &self.m
    }

    fn platform(&self) -> String {
        format!(
            "native (tinycl kernel engine, {} threads on the persistent exec pool, \
             {} kB L2 blocks, {} frozen stage)",
            self.engine.threads,
            self.engine.l2_bytes / 1024,
            match self.frozen_path {
                FrozenPath::Int8 => "true-int8",
                FrozenPath::FakeQuantF32 => "fake-quant-f32",
            }
        )
    }

    fn load_params(&self, l: usize) -> Result<ParamState> {
        let n_conv_total = self.n_conv_layers();
        ensure!(l <= n_conv_total, "split l={l} beyond the layer graph");
        // Always the deterministic seeded init — never `params_l{l}.bin`:
        // those weights were fine-tuned against the AOT model's frozen
        // stage, whose trained weights are baked into the HLO artifacts
        // and unrecoverable here. Loading them over this backend's seeded
        // frozen stage would silently produce a meaningless model (the
        // latent distributions differ entirely); the seeded init keeps
        // every native run a pure function of `(manifest.seed, config)`.
        //
        // Init: adaptive conv weights from the full-net seeded weights,
        // identity affine, He head — tensor order matches the AOT
        // flattening (per layer sorted keys b, g, w; head b, w)
        let mut names = Vec::new();
        let mut tensors = Vec::new();
        let n_conv = n_conv_total - l.min(n_conv_total);
        for li in 0..n_conv {
            let layer = &self.net.layers[l + li];
            names.push(format!("layer{li}.b"));
            tensors.push(TensorF32::zeros(vec![layer.cout]));
            names.push(format!("layer{li}.g"));
            tensors.push(TensorF32::new(vec![layer.cout], vec![1.0; layer.cout]));
            names.push(format!("layer{li}.w"));
            let shape = match layer.kind {
                LayerKind::DepthWise => vec![3, 3, layer.cin],
                LayerKind::Conv3x3 => vec![3, 3, layer.cin, layer.cout],
                LayerKind::PointWise => vec![layer.cin, layer.cout],
                LayerKind::Linear => unreachable!(),
            };
            tensors.push(TensorF32::new(shape, self.weights[l + li].clone()));
        }
        names.push(format!("layer{n_conv}.b"));
        tensors.push(TensorF32::zeros(vec![self.m.num_classes]));
        names.push(format!("layer{n_conv}.w"));
        tensors.push(TensorF32::new(
            vec![self.m.feat_dim, self.m.num_classes],
            self.head_w.clone(),
        ));
        Ok(ParamState::from_tensors(names, tensors))
    }

    fn frozen_forward(
        &self,
        l: usize,
        int8: bool,
        _eval_batch: bool,
        images: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        if int8 && self.frozen_path == FrozenPath::Int8 {
            return self.frozen_forward_int8(l, images, out);
        }
        let hw = self.m.input_hw;
        let img = hw * hw * 3;
        ensure!(!images.is_empty() && images.len() % img == 0, "frozen_forward: image batch size");
        let b = images.len() / img;
        let n_conv = self.n_conv_layers();
        let lelems = self.latent_elems(l)?;
        ensure!(out.len() == b * lelems, "frozen_forward: latent buffer size");
        let a_bits = self.m.a_bits;

        let tm = crate::telemetry::global();
        let _fw = tm
            .clone()
            .owned_span(crate::telemetry::EventKind::FrozenForward)
            .payload(b as u64, l as u64)
            .counter(crate::telemetry::Counter::FrozenForwards);
        tm.counter_add(crate::telemetry::Counter::FrozenRows, b as u64);

        let mut x = images.to_vec();
        if int8 {
            fake_quant_act(&mut x, self.m.input_a_max as f32, a_bits);
        }
        let stop = l.min(n_conv);
        for i in 0..stop {
            let layer = &self.net.layers[i];
            let sp = tm
                .span(crate::telemetry::EventKind::FrozenLayer)
                .key(i as u64)
                .payload(i as u64, b as u64);
            let y = if int8 {
                let mut y = self.conv_fw(layer, &self.sim_weight(i), &x, b);
                for v in y.iter_mut() {
                    *v = v.max(0.0);
                }
                fake_quant_act(&mut y, self.m.a_max[i] as f32, a_bits);
                y
            } else {
                let mut y = self.conv_fw(layer, &self.weights[i], &x, b);
                for v in y.iter_mut() {
                    *v = v.max(0.0);
                }
                y
            };
            tm.record_layer(i, layer_tag(layer.kind), b as u64, sp.elapsed_ns());
            x = y;
        }
        if l >= n_conv {
            let last = &self.net.layers[n_conv - 1];
            let hw2 = last.hw_out() * last.hw_out();
            x = Self::pool(&x, b, hw2, last.cout);
        }
        ensure!(x.len() == out.len(), "frozen_forward: internal size mismatch");
        out.copy_from_slice(&x);
        Ok(())
    }

    fn train_step(
        &self,
        l: usize,
        params: &mut ParamState,
        latents: &[f32],
        labels: &[i32],
        lr: f32,
    ) -> Result<(f64, u64)> {
        let n_conv_total = self.n_conv_layers();
        ensure!(l <= n_conv_total, "split l={l} beyond the layer graph");
        let lelems = self.latent_elems(l)?;
        let b = labels.len();
        ensure!(b > 0 && latents.len() == b * lelems, "train_step: latent batch size");
        let n_conv = n_conv_total - l;
        ensure!(
            params.len() == 3 * n_conv + 2,
            "train_step: ParamState has {} tensors, expected {}",
            params.len(),
            3 * n_conv + 2
        );
        for li in 0..n_conv {
            ensure!(
                self.net.layers[l + li].kind != LayerKind::Conv3x3,
                "the stem conv is never adaptive in the supported splits"
            );
        }
        let ncls = self.m.num_classes;
        let feat = self.m.feat_dim;
        let _sp = crate::telemetry::global_span(crate::telemetry::EventKind::TrainStep)
            .payload(b as u64, l as u64)
            .counter(crate::telemetry::Counter::TrainSteps);

        // ---- forward, stashing what backward needs ----------------------
        // acts[li] = input of adaptive conv layer li (post-ReLU upstream);
        // zs[li] = its raw conv output (pre-affine, for dg)
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(n_conv + 1);
        let mut zs: Vec<Vec<f32>> = Vec::with_capacity(n_conv);
        acts.push(latents.to_vec());
        for li in 0..n_conv {
            let layer = &self.net.layers[l + li];
            let w = params.tensor(3 * li + 2);
            ensure!(w.elems() == weight_len(layer), "train_step: layer {li} weight size");
            let z = self.conv_fw(layer, &w.data, &acts[li], b);
            let g = &params.tensor(3 * li + 1).data;
            let bb = &params.tensor(3 * li).data;
            let cout = layer.cout;
            let mut a = vec![0f32; z.len()];
            for (idx, (&zv, av)) in z.iter().zip(a.iter_mut()).enumerate() {
                let ch = idx % cout;
                *av = (zv * g[ch] + bb[ch]).max(0.0);
            }
            zs.push(z);
            acts.push(a);
        }
        let feats: Vec<f32> = if n_conv > 0 {
            let last = &self.net.layers[l + n_conv - 1];
            let hw2 = last.hw_out() * last.hw_out();
            Self::pool(acts.last().unwrap(), b, hw2, last.cout)
        } else {
            latents.to_vec()
        };
        let head_w = &params.tensor(3 * n_conv + 1).data;
        let head_b = &params.tensor(3 * n_conv).data;
        ensure!(head_w.len() == feat * ncls && head_b.len() == ncls, "train_step: head size");
        let mut logits = vec![0f32; b * ncls];
        self.engine.matmul_fw_into(&feats, head_w, b, feat, ncls, &mut logits);
        for (idx, v) in logits.iter_mut().enumerate() {
            *v += head_b[idx % ncls];
        }

        // ---- softmax cross-entropy loss + dlogits -----------------------
        let mut dlogits = vec![0f32; b * ncls];
        let (mean_loss, correct) = softmax_ce(&logits, labels, ncls, Some(&mut dlogits))?;

        // ---- backward: head -> pool -> conv stack -----------------------
        let mut d_head_w = vec![0f32; feat * ncls];
        self.engine.matmul_bw_grad_into(&feats, &dlogits, b, feat, ncls, &mut d_head_w);
        let mut d_head_b = vec![0f32; ncls];
        for (idx, &d) in dlogits.iter().enumerate() {
            d_head_b[idx % ncls] += d;
        }
        let mut dfeat = vec![0f32; b * feat];
        self.engine.matmul_bw_err_into(&dlogits, head_w, b, feat, ncls, &mut dfeat);

        // grads of the conv stack, applied after the walk (SGD is a pure
        // p -= lr*g over the pre-step forward, like the AOT module)
        let mut conv_grads: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = Vec::with_capacity(n_conv);
        if n_conv > 0 {
            let last = &self.net.layers[l + n_conv - 1];
            let hw2 = last.hw_out() * last.hw_out();
            let inv = 1.0 / hw2 as f32;
            let mut da = vec![0f32; b * hw2 * last.cout];
            for (idx, v) in da.iter_mut().enumerate() {
                let bi = idx / (hw2 * last.cout);
                let ch = idx % last.cout;
                *v = dfeat[bi * feat + ch] * inv;
            }
            for li in (0..n_conv).rev() {
                let layer = &self.net.layers[l + li];
                let cout = layer.cout;
                let g = &params.tensor(3 * li + 1).data;
                let a = &acts[li + 1];
                let z = &zs[li];
                let x = &acts[li];
                let mut dz = vec![0f32; z.len()];
                let mut db = vec![0f32; cout];
                let mut dg = vec![0f32; cout];
                for idx in 0..z.len() {
                    if a[idx] > 0.0 {
                        let ch = idx % cout;
                        let dy = da[idx];
                        db[ch] += dy;
                        dg[ch] += dy * z[idx];
                        dz[idx] = dy * g[ch];
                    }
                }
                let w = &params.tensor(3 * li + 2).data;
                let h = layer.hw_in;
                let (dx, dw) = match layer.kind {
                    LayerKind::PointWise => {
                        let rows = b * h * h;
                        let mut dx = vec![0f32; rows * layer.cin];
                        self.engine.matmul_bw_err_into(&dz, w, rows, layer.cin, cout, &mut dx);
                        let mut dw = vec![0f32; layer.cin * cout];
                        self.engine.matmul_bw_grad_into(x, &dz, rows, layer.cin, cout, &mut dw);
                        (dx, dw)
                    }
                    LayerKind::DepthWise => {
                        let dx = depthwise_bw_err(&dz, w, b, h, h, layer.cin, layer.stride);
                        let dw = depthwise_bw_grad(x, &dz, b, h, h, layer.cin, layer.stride);
                        (dx, dw)
                    }
                    LayerKind::Conv3x3 | LayerKind::Linear => unreachable!(),
                };
                conv_grads.push((db, dg, dw));
                da = dx;
            }
            conv_grads.reverse();
        }

        // ---- SGD update (p -= lr * grad) --------------------------------
        for (li, (db, dg, dw)) in conv_grads.iter().enumerate() {
            for (p, &gr) in params.data_mut(3 * li).iter_mut().zip(db) {
                *p -= lr * gr;
            }
            for (p, &gr) in params.data_mut(3 * li + 1).iter_mut().zip(dg) {
                *p -= lr * gr;
            }
            for (p, &gr) in params.data_mut(3 * li + 2).iter_mut().zip(dw) {
                *p -= lr * gr;
            }
        }
        for (p, &gr) in params.data_mut(3 * n_conv).iter_mut().zip(&d_head_b) {
            *p -= lr * gr;
        }
        for (p, &gr) in params.data_mut(3 * n_conv + 1).iter_mut().zip(&d_head_w) {
            *p -= lr * gr;
        }

        Ok((mean_loss, correct))
    }

    fn adaptive_eval(
        &self,
        l: usize,
        params: &ParamState,
        latents: &[f32],
        out_logits: &mut [f32],
    ) -> Result<()> {
        let n_conv_total = self.n_conv_layers();
        ensure!(l <= n_conv_total, "split l={l} beyond the layer graph");
        let lelems = self.latent_elems(l)?;
        ensure!(!latents.is_empty() && latents.len() % lelems == 0, "adaptive_eval: latent batch");
        let b = latents.len() / lelems;
        let ncls = self.m.num_classes;
        let feat = self.m.feat_dim;
        ensure!(out_logits.len() == b * ncls, "adaptive_eval: logits buffer size");
        let n_conv = n_conv_total - l;
        ensure!(
            params.len() == 3 * n_conv + 2,
            "adaptive_eval: ParamState has {} tensors, expected {}",
            params.len(),
            3 * n_conv + 2
        );

        // span only — the Eval latency histogram is fed by the fleet's
        // async-eval wrapper (one sample per tenant sweep, not per call)
        let _sp = crate::telemetry::global_span(crate::telemetry::EventKind::EvalSweep)
            .payload(b as u64, l as u64);

        let mut x = latents.to_vec();
        for li in 0..n_conv {
            let layer = &self.net.layers[l + li];
            let w = params.tensor(3 * li + 2);
            ensure!(w.elems() == weight_len(layer), "adaptive_eval: layer {li} weight size");
            let z = self.conv_fw(layer, &w.data, &x, b);
            let g = &params.tensor(3 * li + 1).data;
            let bb = &params.tensor(3 * li).data;
            let cout = layer.cout;
            let mut a = vec![0f32; z.len()];
            for (idx, (&zv, av)) in z.iter().zip(a.iter_mut()).enumerate() {
                let ch = idx % cout;
                *av = (zv * g[ch] + bb[ch]).max(0.0);
            }
            x = a;
        }
        let feats = if n_conv > 0 {
            let last = &self.net.layers[l + n_conv - 1];
            let hw2 = last.hw_out() * last.hw_out();
            Self::pool(&x, b, hw2, last.cout)
        } else {
            x
        };
        let head_w = &params.tensor(3 * n_conv + 1).data;
        let head_b = &params.tensor(3 * n_conv).data;
        ensure!(head_w.len() == feat * ncls && head_b.len() == ncls, "adaptive_eval: head size");
        self.engine.matmul_fw_into(&feats, head_w, b, feat, ncls, out_logits);
        for (idx, v) in out_logits.iter_mut().enumerate() {
            *v += head_b[idx % ncls];
        }
        Ok(())
    }
}

/// Telemetry tag of a frozen layer kind (0-based; the report renders
/// tag 0/1/2 as conv3x3/depthwise/pointwise).
fn layer_tag(kind: LayerKind) -> u64 {
    match kind {
        LayerKind::Conv3x3 => 0,
        LayerKind::DepthWise => 1,
        LayerKind::PointWise => 2,
        LayerKind::Linear => 3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::synthetic::{self, SyntheticSpec};
    use crate::runtime::Dataset;

    fn backend(path: FrozenPath) -> (NativeBackend, Dataset) {
        let (m, ds) = synthetic::generate(&SyntheticSpec::tiny()).expect("synthetic env");
        (NativeBackend::with_frozen_path(m, path).expect("backend"), ds)
    }

    fn image_batch(be: &NativeBackend, ds: &Dataset, b: usize) -> Vec<f32> {
        let img = be.m.input_hw * be.m.input_hw * 3;
        let mut images = vec![0f32; b * img];
        for i in 0..b {
            ds.train_image_into(i % ds.n_train(), &mut images[i * img..(i + 1) * img]);
        }
        images
    }

    #[test]
    fn frozen_path_defaults_to_int8() {
        // CI never sets TINYCL_FROZEN_PATH; the default must be the
        // integer path (the tentpole: true-INT8 is not opt-in)
        let (be, _) = backend(FrozenPath::from_env().unwrap());
        assert_eq!(be.frozen_path(), FrozenPath::Int8);
        assert!(be.frozen_sim.is_none(), "int8 path must not keep the f32 grid copy");
    }

    #[test]
    fn int8_weight_storage_is_one_byte_per_frozen_weight() {
        let (be, _) = backend(FrozenPath::Int8);
        let expect: usize = be.net.layers[..be.n_conv_layers()]
            .iter()
            .map(|l| match l.kind {
                LayerKind::Conv3x3 => 9 * l.cin * l.cout,
                LayerKind::DepthWise => 9 * l.cin,
                LayerKind::PointWise => l.cin * l.cout,
                LayerKind::Linear => unreachable!(),
            })
            .sum();
        assert_eq!(be.frozen_arena_bytes(), expect);
        // ~4x below the old dequantized-f32-grid copy
        assert_eq!(expect * 4, be.weights.iter().map(|w| w.len() * 4).sum::<usize>());
    }

    /// THE per-layer parity pin: every frozen layer, fed the SAME input
    /// codes, must requantize to within one code of the fake-quant FP32
    /// oracle — the oracle's f32 accumulation noise and the fixed-point
    /// multiplier's 2^-31 truncation are the only divergences, and both
    /// are orders of magnitude below one quantization step.
    #[test]
    fn int8_layers_match_the_fake_quant_oracle_within_one_lsb() {
        let (be, ds) = backend(FrozenPath::Int8);
        let a_bits = be.m.a_bits;
        let b = 4;
        let images = image_batch(&be, &ds, b);
        let mut q = vec![0u8; images.len()];
        quantize_acts_into(&images, be.m.input_a_max as f32, a_bits, &mut q);
        let mut in_a_max = be.m.input_a_max as f32;
        let levels = ((1u32 << a_bits) - 1) as f32;
        for i in 0..be.n_conv_layers() {
            let layer = &be.net.layers[i];
            let fz = &be.frozen_i8[i];
            let h = layer.hw_in;
            // integer layer over the shared input codes
            let mut acc = vec![0i32; b * layer.out_elems()];
            match layer.kind {
                LayerKind::Conv3x3 => be.engine.conv3x3_fw_i8_into(
                    &q, &fz.w.codes, fz.w.off, b, h, h, layer.cin, layer.stride, layer.cout,
                    &mut acc,
                ),
                LayerKind::DepthWise => be.engine.depthwise_fw_i8_into(
                    &q, &fz.w.codes, fz.w.off, b, h, h, layer.cin, layer.stride, &mut acc,
                ),
                LayerKind::PointWise => {
                    let rows = b * h * h;
                    be.engine.matmul_fw_i8_into(
                        &q, &fz.w.codes, fz.w.off, rows, layer.cin, layer.cout, &mut acc,
                    );
                }
                LayerKind::Linear => unreachable!(),
            }
            let mut q_int = vec![0u8; acc.len()];
            requantize_relu_into(&acc, fz.requant, a_bits, &mut q_int);
            // oracle layer over the SAME input, as grid values
            let mut x = vec![0f32; q.len()];
            dequantize_acts_into(&q, in_a_max, a_bits, &mut x);
            let mut y = conv_fw(be.engine, layer, &be.sim_weight(i), &x, b);
            for v in y.iter_mut() {
                *v = v.max(0.0);
            }
            let inv = 1.0 / act_scale(be.m.a_max[i] as f32, a_bits);
            let mut worst = 0i32;
            let mut n_diff = 0usize;
            for (&qi, &yv) in q_int.iter().zip(&y) {
                let qs = (yv * inv).floor().clamp(0.0, levels) as i32;
                let d = (qi as i32 - qs).abs();
                worst = worst.max(d);
                n_diff += (d > 0) as usize;
            }
            assert!(
                worst <= 1,
                "layer {i} ({:?}): max code diff {worst} ({n_diff}/{} differ)",
                layer.kind,
                q_int.len()
            );
            // both paths continue from the INTEGER codes, so every layer
            // is tested on identical inputs
            q = q_int;
            in_a_max = be.m.a_max[i] as f32;
        }
    }

    #[test]
    fn int8_and_sim_frozen_latents_agree_end_to_end() {
        // end-to-end the per-layer <= 1 LSB divergences may compound on
        // a handful of elements. How many depends on the ORACLE's f32
        // rounding, which is compiler-dependent (the integer path is
        // bit-stable): with FMA-contracted f32 (gcc -O3 -march=native)
        // the C mirror measures ~0.01% drift, worst 1 code; with strict
        // IEEE mul+add (gcc -O2, and rustc, which never contracts) up to
        // ~4% of codes drift at the deepest prefix, worst 4 codes —
        // still individually explained by the <= 1-LSB-per-layer pin.
        // Bounds sized for the strict-IEEE oracle with margin.
        let (be_i, ds) = backend(FrozenPath::Int8);
        let (be_s, _) = backend(FrozenPath::FakeQuantF32);
        let b = 6;
        let images = image_batch(&be_i, &ds, b);
        let a_bits = be_i.m.a_bits;
        for &l in &[9usize, 13, 15] {
            let lelems = be_i.latent_elems(l).unwrap();
            let mut lat_i = vec![0f32; b * lelems];
            let mut lat_s = vec![0f32; b * lelems];
            be_i.frozen_forward(l, true, false, &images, &mut lat_i).unwrap();
            be_s.frozen_forward(l, true, false, &images, &mut lat_s).unwrap();
            let n_conv = be_i.n_conv_layers();
            let a_max = if l >= n_conv {
                // pooled split: compare pre-pool codes via the last
                // layer's scale on the pooled values (means of grid
                // points — compare in units of the last grid step)
                be_i.m.a_max[n_conv - 1] as f32
            } else {
                be_i.m.a_max[l - 1] as f32
            };
            let step = act_scale(a_max, a_bits);
            let mut worst = 0f32;
            let mut n_diff = 0usize;
            for (&a, &s) in lat_i.iter().zip(&lat_s) {
                let d = (a - s).abs() / step;
                worst = worst.max(d);
                n_diff += (d > 1e-3) as usize;
            }
            assert!(worst <= 8.0, "l={l}: worst end-to-end drift {worst} steps");
            assert!(
                n_diff * 4 <= lat_i.len(),
                "l={l}: {}/{} latents drifted",
                n_diff,
                lat_i.len()
            );
        }
    }

    #[test]
    fn int8_latents_sit_on_the_oracle_grid() {
        // the integer path's output grid is the oracle's: code * S with
        // the same S expression — so stored replays, eval caches and the
        // adaptive stage cannot tell the paths apart given equal codes
        let (be, ds) = backend(FrozenPath::Int8);
        let b = 3;
        let images = image_batch(&be, &ds, b);
        let l = 13;
        let lelems = be.latent_elems(l).unwrap();
        let mut lat = vec![0f32; b * lelems];
        be.frozen_forward(l, true, false, &images, &mut lat).unwrap();
        let s = act_scale(be.m.a_max[l - 1] as f32, be.m.a_bits);
        let levels = ((1u32 << be.m.a_bits) - 1) as f32;
        for (i, &v) in lat.iter().enumerate() {
            let code = (v / s).round();
            assert!(code >= 0.0 && code <= levels, "latent {i} off range: {v}");
            assert_eq!(code * s, v, "latent {i} off the grid: {v}");
        }
    }
}

impl NativeBackend {
    /// Mean cross-entropy loss + correct count of the adaptive stage on a
    /// latent batch — forward only, params untouched. Tests use this to
    /// finite-difference-check the fused train step's gradients.
    pub fn loss_and_correct(
        &self,
        l: usize,
        params: &ParamState,
        latents: &[f32],
        labels: &[i32],
    ) -> Result<(f64, u64)> {
        let b = labels.len();
        let ncls = self.m.num_classes;
        let mut logits = vec![0f32; b * ncls];
        self.adaptive_eval(l, params, latents, &mut logits)?;
        softmax_ce(&logits, labels, ncls, None)
    }
}
