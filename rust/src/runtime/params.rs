//! Adaptive-stage parameter state: the mutable model coefficients the
//! coordinator threads through every `adaptive_train` execution.
//!
//! Loaded once from `params_l{l}.bin` (the build-time fine-tuned weights),
//! then replaced in-place by the leading outputs of each train step. The
//! tensors stay as XLA literals between steps.
//!
//! NOTE (§Perf #5, EXPERIMENTS.md): a device-buffer-resident variant
//! (`execute_b` + `buffer_from_host_literal`) was prototyped to avoid the
//! C-shim's per-call conversion leak, but this xla_extension 0.5.1 build
//! handles async H2D transfers unsafely (use-after-free when the source
//! literal or an unexecuted buffer is dropped), so the stable literal path
//! is used and long sweeps partition across processes instead.

use anyhow::{bail, Context, Result};

use super::data::read_f32;
use super::manifest::SplitArtifacts;
use super::{Runtime, TensorF32};

pub struct ParamState {
    /// one literal per adaptive tensor, in the manifest's flattened order
    literals: Vec<xla::Literal>,
    names: Vec<String>,
    shapes: Vec<Vec<usize>>,
}

impl ParamState {
    /// Load the initial adaptive parameters for split `l`.
    pub fn load(rt: &Runtime, split: &SplitArtifacts) -> Result<ParamState> {
        let dir = &rt.manifest().dir;
        let flat = read_f32(&dir.join(&split.params_bin), split.n_param_elems())
            .with_context(|| format!("loading {}", split.params_bin))?;
        let mut literals = Vec::with_capacity(split.param_tensors.len());
        let mut names = Vec::new();
        let mut shapes = Vec::new();
        let mut off = 0;
        for meta in &split.param_tensors {
            let n = meta.elems();
            let t = TensorF32::new(meta.shape.clone(), flat[off..off + n].to_vec());
            literals.push(t.to_literal()?);
            names.push(meta.name.clone());
            shapes.push(meta.shape.clone());
            off += n;
        }
        if off != flat.len() {
            bail!("params bin length mismatch");
        }
        Ok(ParamState { literals, names, shapes })
    }

    pub fn len(&self) -> usize {
        self.literals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.literals.is_empty()
    }

    pub fn literals(&self) -> &[xla::Literal] {
        &self.literals
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// Replace the state with the updated tensors from a train-step output
    /// (the first `len()` entries of the output tuple). Returns the
    /// remaining outputs (loss, counters, ...).
    pub fn update_from(
        &mut self,
        _rt: &Runtime,
        mut outputs: Vec<xla::Literal>,
    ) -> Result<Vec<xla::Literal>> {
        if outputs.len() < self.literals.len() {
            bail!(
                "train output tuple too short: {} < {}",
                outputs.len(),
                self.literals.len()
            );
        }
        let rest = outputs.split_off(self.literals.len());
        self.literals = outputs;
        Ok(rest)
    }

    /// Snapshot to host tensors (for checkpointing / tests).
    pub fn to_tensors(&self) -> Result<Vec<TensorF32>> {
        self.literals
            .iter()
            .zip(&self.shapes)
            .map(|(l, shape)| Ok(TensorF32::new(shape.clone(), l.to_vec::<f32>()?)))
            .collect()
    }

    /// Restore from a snapshot (e.g. per-seed reset in the fig5 sweep).
    pub fn restore(&mut self, _rt: &Runtime, tensors: &[TensorF32]) -> Result<()> {
        if tensors.len() != self.shapes.len() {
            bail!("restore: tensor count mismatch");
        }
        let mut lits = Vec::with_capacity(tensors.len());
        for (t, shape) in tensors.iter().zip(&self.shapes) {
            if &t.shape != shape {
                bail!("restore: shape mismatch {:?} vs {:?}", t.shape, shape);
            }
            lits.push(t.to_literal()?);
        }
        self.literals = lits;
        Ok(())
    }

    /// Total parameter count (elements).
    pub fn n_elems(&self) -> usize {
        self.shapes.iter().map(|s| s.iter().product::<usize>()).sum()
    }
}
