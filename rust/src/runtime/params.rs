//! Adaptive-stage parameter state: the mutable model coefficients a
//! [`crate::runtime::Backend`] threads through every train step.
//!
//! Since the backend split, `ParamState` is backend-agnostic: it holds
//! plain host tensors in the manifest's flattened order (per adaptive
//! layer, dict keys sorted — `layer{i}.b`, `layer{i}.g`, `layer{i}.w` —
//! then the head's `b`/`w`; see `python/compile/aot.py::_flatten_adaptive`).
//! The PJRT backend marshals these into XLA literals per call; the native
//! backend updates them in place with its fused SGD step.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::data::read_f32;
use super::manifest::SplitArtifacts;
use super::TensorF32;

#[derive(Clone, Debug)]
pub struct ParamState {
    /// one host tensor per adaptive parameter, in the manifest's order
    tensors: Vec<TensorF32>,
    names: Vec<String>,
}

impl ParamState {
    /// Build from an explicit (name, tensor) list — the native backend's
    /// seeded-initialization path, and the restore path of tests.
    pub fn from_tensors(names: Vec<String>, tensors: Vec<TensorF32>) -> Self {
        assert_eq!(names.len(), tensors.len(), "names/tensors length mismatch");
        ParamState { tensors, names }
    }

    /// Load the initial adaptive parameters for split `l` from the
    /// artifact directory's `params_l{l}.bin` (f32 LE, flattened in
    /// `param_tensors` order).
    pub fn load_bin(dir: &Path, split: &SplitArtifacts) -> Result<ParamState> {
        let flat = read_f32(&dir.join(&split.params_bin), split.n_param_elems())
            .with_context(|| format!("loading {}", split.params_bin))?;
        let mut tensors = Vec::with_capacity(split.param_tensors.len());
        let mut names = Vec::new();
        let mut off = 0;
        for meta in &split.param_tensors {
            let n = meta.elems();
            tensors.push(TensorF32::new(meta.shape.clone(), flat[off..off + n].to_vec()));
            names.push(meta.name.clone());
            off += n;
        }
        if off != flat.len() {
            bail!("params bin length mismatch");
        }
        Ok(ParamState { tensors, names })
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn tensors(&self) -> &[TensorF32] {
        &self.tensors
    }

    pub fn tensor(&self, i: usize) -> &TensorF32 {
        &self.tensors[i]
    }

    /// Mutable view of one tensor's data (shape is fixed) — the native
    /// backend's in-place SGD update path.
    pub fn data_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.tensors[i].data
    }

    /// Index of a tensor by manifest name.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.names.iter().position(|n| n == name)
    }

    /// Replace the whole state with updated tensors (the PJRT backend's
    /// post-step path: the leading entries of the train output tuple).
    /// Shapes must match the existing state.
    pub fn set_tensors(&mut self, tensors: Vec<TensorF32>) -> Result<()> {
        if tensors.len() != self.tensors.len() {
            bail!(
                "param update tensor count mismatch: {} vs {}",
                tensors.len(),
                self.tensors.len()
            );
        }
        for (new, old) in tensors.iter().zip(&self.tensors) {
            if new.shape != old.shape {
                bail!("param update shape mismatch {:?} vs {:?}", new.shape, old.shape);
            }
        }
        self.tensors = tensors;
        Ok(())
    }

    /// Snapshot to host tensors (for checkpointing / per-seed resets).
    pub fn to_tensors(&self) -> Vec<TensorF32> {
        self.tensors.clone()
    }

    /// Restore from a snapshot (e.g. per-seed reset in the fig5 sweep).
    pub fn restore(&mut self, tensors: &[TensorF32]) -> Result<()> {
        self.set_tensors(tensors.to_vec())
    }

    /// Total parameter count (elements).
    pub fn n_elems(&self) -> usize {
        self.tensors.iter().map(|t| t.elems()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> ParamState {
        ParamState::from_tensors(
            vec!["layer0.b".into(), "layer0.w".into()],
            vec![
                TensorF32::zeros(vec![4]),
                TensorF32::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]),
            ],
        )
    }

    #[test]
    fn indexing_and_sizes() {
        let p = state();
        assert_eq!(p.len(), 2);
        assert_eq!(p.n_elems(), 10);
        assert_eq!(p.index_of("layer0.w"), Some(1));
        assert_eq!(p.index_of("nope"), None);
        assert_eq!(p.tensor(1).shape, vec![2, 3]);
    }

    #[test]
    fn in_place_update_and_snapshot_roundtrip() {
        let mut p = state();
        let snap = p.to_tensors();
        p.data_mut(0)[2] = 9.0;
        assert_eq!(p.tensor(0).data[2], 9.0);
        p.restore(&snap).unwrap();
        assert_eq!(p.tensor(0).data[2], 0.0);
    }

    #[test]
    fn set_tensors_checks_shapes() {
        let mut p = state();
        let bad = vec![TensorF32::zeros(vec![4]), TensorF32::zeros(vec![3, 2])];
        assert!(p.set_tensors(bad).is_err());
        let short = vec![TensorF32::zeros(vec![4])];
        assert!(p.set_tensors(short).is_err());
    }
}
