//! The execution-backend abstraction: everything the coordinator needs
//! from a compute substrate, as one object-safe trait.
//!
//! Two implementations ship in-tree:
//!
//! - **PJRT** ([`Runtime`]): compiles the AOT HLO modules under
//!   `artifacts/` and executes them through the XLA PJRT client — the
//!   paper-faithful build-time path (requires `make artifacts` and a real
//!   `xla_extension`);
//! - **native** ([`super::NativeBackend`]): executes the manifest's layer
//!   graph directly on the in-tree kernel engine
//!   (`crate::kernels::engine`) — no Python, no artifacts, no XLA. Paired
//!   with the synthetic Core50-mini generator ([`super::synthetic`]) it
//!   makes the full QLR-CL protocol runnable offline.
//!
//! The trait surface is deliberately host-tensor shaped (`&[f32]` in,
//! `&mut [f32]` out): marshaling into device formats (XLA literals) is a
//! backend concern, and the coordinator's scratch-buffer reuse keeps the
//! hot loop allocation-free regardless of backend.

use anyhow::{ensure, Context, Result};

use super::manifest::Manifest;
use super::params::ParamState;
use super::{
    labels_literal, literal_from_f32_slice, scalar_literal, Dataset, Runtime, TensorF32,
};

/// One QLR-CL execution substrate. All methods are per-split (`l` is the
/// first adaptive layer, one of `manifest().splits`).
///
/// Batch-size contract: `frozen_forward` and `adaptive_eval` infer the
/// batch from the slice lengths. The PJRT backend's modules are compiled
/// at the manifest batch sizes (`batch_new`/`batch_eval` for the frozen
/// stage and eval, `batch_train` for the train step), so callers pad tail
/// batches (the coordinator already does); the native backend accepts any
/// batch.
pub trait Backend {
    /// The artifact/synthetic manifest this backend executes.
    fn manifest(&self) -> &Manifest;

    /// Human-readable substrate description (for `info` and logs).
    fn platform(&self) -> String;

    /// Initial adaptive-stage parameters for split `l` (the build-time
    /// fine-tuned weights, or the backend's deterministic init when no
    /// params artifact exists).
    fn load_params(&self, l: usize) -> Result<ParamState>;

    /// Frozen-stage forward: images `[b, hw, hw, 3]` (f32, `[0,1]`) to
    /// latents `[b, latent_elems(l)]`. `int8` selects the INT-8
    /// fake-quantized pipeline vs the FP32 baseline; `eval_batch` selects
    /// the eval-batch module flavor (PJRT compiles one per batch size).
    fn frozen_forward(
        &self,
        l: usize,
        int8: bool,
        eval_batch: bool,
        images: &[f32],
        out: &mut [f32],
    ) -> Result<()>;

    /// One fused adaptive-stage train step — forward + BW-ERR + BW-GRAD +
    /// SGD — over a composed batch of latents. Updates `params` in place
    /// and returns `(mean_loss, n_correct)`.
    fn train_step(
        &self,
        l: usize,
        params: &mut ParamState,
        latents: &[f32],
        labels: &[i32],
        lr: f32,
    ) -> Result<(f64, u64)>;

    /// Adaptive-stage logits for evaluation: latents
    /// `[b, latent_elems(l)]` to logits `[b, num_classes]`.
    fn adaptive_eval(
        &self,
        l: usize,
        params: &ParamState,
        latents: &[f32],
        out_logits: &mut [f32],
    ) -> Result<()>;
}

fn batch_shape(b: usize, latent_shape: &[usize]) -> Vec<usize> {
    let mut s = Vec::with_capacity(latent_shape.len() + 1);
    s.push(b);
    s.extend_from_slice(latent_shape);
    s
}

/// The PJRT path: marshal host tensors into XLA literals, execute the
/// compiled AOT modules, read results back. (The former literal-resident
/// `ParamState` saved one host round-trip per step; the backend split
/// trades that for a substrate-agnostic coordinator — a literal cache can
/// come back behind this impl without touching callers.)
impl Backend for Runtime {
    fn manifest(&self) -> &Manifest {
        Runtime::manifest(self)
    }

    fn platform(&self) -> String {
        Runtime::platform(self)
    }

    fn load_params(&self, l: usize) -> Result<ParamState> {
        let m = Runtime::manifest(self);
        ParamState::load_bin(&m.dir, m.split(l)?)
    }

    fn frozen_forward(
        &self,
        l: usize,
        int8: bool,
        eval_batch: bool,
        images: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        let m = Runtime::manifest(self);
        let split = m.split(l)?;
        let lat = m.latent_info(l)?;
        let b = if eval_batch { m.batch_eval } else { m.batch_new };
        let hw = m.input_hw;
        ensure!(
            images.len() == b * hw * hw * 3,
            "frozen_forward: expected a full batch of {b} images"
        );
        ensure!(out.len() == b * lat.elems(), "frozen_forward: latent buffer size");
        let exe = self.executable(split.frozen(int8, eval_batch))?;
        let input = literal_from_f32_slice(&[b, hw, hw, 3], images)?;
        let outs = self.execute_refs(&exe, &[&input])?;
        let lat_lit = outs
            .into_iter()
            .next()
            .context("frozen module returned empty tuple")?;
        let host = lat_lit.to_vec::<f32>()?;
        ensure!(host.len() == out.len(), "frozen module output size mismatch");
        out.copy_from_slice(&host);
        Ok(())
    }

    fn train_step(
        &self,
        l: usize,
        params: &mut ParamState,
        latents: &[f32],
        labels: &[i32],
        lr: f32,
    ) -> Result<(f64, u64)> {
        let m = Runtime::manifest(self);
        let split = m.split(l)?;
        let lat = m.latent_info(l)?;
        let b = labels.len();
        ensure!(latents.len() == b * lat.elems(), "train_step: latent batch size");
        let exe = self.executable(&split.adaptive_train)?;

        let mut param_lits = Vec::with_capacity(params.len());
        for t in params.tensors() {
            param_lits.push(t.to_literal()?);
        }
        let lat_lit = literal_from_f32_slice(&batch_shape(b, &lat.shape), latents)?;
        let lab_lit = labels_literal(labels);
        let lr_lit = scalar_literal(lr);

        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(params.len() + 3);
        inputs.extend(param_lits.iter());
        inputs.push(&lat_lit);
        inputs.push(&lab_lit);
        inputs.push(&lr_lit);

        let mut outputs = self.execute_refs(&exe, &inputs)?;
        ensure!(
            outputs.len() >= params.len() + 2,
            "train output tuple too short: {} < {}",
            outputs.len(),
            params.len() + 2
        );
        let rest = outputs.split_off(params.len());
        let mut new_tensors = Vec::with_capacity(outputs.len());
        for (lit, old) in outputs.iter().zip(params.tensors()) {
            new_tensors.push(TensorF32::new(old.shape.clone(), lit.to_vec::<f32>()?));
        }
        params.set_tensors(new_tensors)?;
        let loss = rest[0].get_first_element::<f32>()? as f64;
        let correct = rest[1].get_first_element::<i32>()?.max(0) as u64;
        Ok((loss, correct))
    }

    fn adaptive_eval(
        &self,
        l: usize,
        params: &ParamState,
        latents: &[f32],
        out_logits: &mut [f32],
    ) -> Result<()> {
        let m = Runtime::manifest(self);
        let split = m.split(l)?;
        let lat = m.latent_info(l)?;
        let b = latents.len() / lat.elems().max(1);
        ensure!(latents.len() == b * lat.elems(), "adaptive_eval: latent batch size");
        ensure!(
            out_logits.len() == b * m.num_classes,
            "adaptive_eval: logits buffer size"
        );
        let exe = self.executable(&split.adaptive_eval)?;
        let mut param_lits = Vec::with_capacity(params.len());
        for t in params.tensors() {
            param_lits.push(t.to_literal()?);
        }
        let lat_lit = literal_from_f32_slice(&batch_shape(b, &lat.shape), latents)?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(params.len() + 1);
        inputs.extend(param_lits.iter());
        inputs.push(&lat_lit);
        let outs = self.execute_refs(&exe, &inputs)?;
        let host = outs
            .first()
            .context("eval module returned empty tuple")?
            .to_vec::<f32>()?;
        ensure!(host.len() == out_logits.len(), "eval module logits size mismatch");
        out_logits.copy_from_slice(&host);
        Ok(())
    }
}

// ---- shared-backbone handles ------------------------------------------------

/// A shared, thread-safe backend handle — the fleet server's "one frozen
/// backbone per host": frozen weights, PTQ calibration and the layer
/// graph are loaded ONCE and shared via `Arc` across every tenant and
/// worker, never duplicated per tenant. The native backend qualifies
/// (immutable weights, stateless engine, `Send + Sync` by construction);
/// the PJRT runtime does not (single-threaded client + compile cache),
/// so fleet serving runs on the native path.
pub type SharedBackend = std::sync::Arc<dyn Backend + Send + Sync>;

/// Open the offline fleet environment: the native backend over the
/// deterministic synthetic Core50-mini (env-tunable like
/// [`open_default_backend`]'s synthetic arm) as a shared `Arc` handle,
/// plus the dataset.
pub fn open_shared_native() -> Result<(SharedBackend, Dataset)> {
    open_shared_synthetic(&super::synthetic::SyntheticSpec::from_env())
}

/// [`open_shared_native`] with an explicit synthetic spec (tests use the
/// tiny profile).
pub fn open_shared_synthetic(
    spec: &super::synthetic::SyntheticSpec,
) -> Result<(SharedBackend, Dataset)> {
    use super::NativeBackend;
    let (m, ds) = super::synthetic::generate(spec)?;
    Ok((std::sync::Arc::new(NativeBackend::new(m)?), ds))
}

// ---- backend selection -----------------------------------------------------

/// Which backend `open_default_backend` should produce.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// PJRT when artifacts exist, native-synthetic otherwise (default)
    Auto,
    /// force PJRT (error when artifacts are missing)
    Pjrt,
    /// force native; uses on-disk artifacts' manifest/dataset when
    /// present, synthetic otherwise
    Native,
    /// force native on synthetic data, even when artifacts exist
    Synthetic,
}

impl BackendChoice {
    /// Parse `$TINYCL_BACKEND` (`auto` | `pjrt` | `native` | `synthetic`).
    /// Unknown values are an error, not a silent fallback — a typo must
    /// not hand the run to a different backend than the one asked for.
    pub fn from_env() -> Result<BackendChoice> {
        match std::env::var("TINYCL_BACKEND").unwrap_or_default().as_str() {
            "" | "auto" => Ok(BackendChoice::Auto),
            "pjrt" => Ok(BackendChoice::Pjrt),
            "native" => Ok(BackendChoice::Native),
            "synthetic" => Ok(BackendChoice::Synthetic),
            other => Err(anyhow::anyhow!(
                "TINYCL_BACKEND='{other}' is not recognized; valid values: \
                 auto, pjrt, native, synthetic"
            )),
        }
    }
}

/// Open the default execution environment: `(backend, dataset)`.
///
/// - artifacts present (`manifest.json` under [`Manifest::default_dir`]):
///   PJRT over the AOT modules, unless `$TINYCL_BACKEND` forces native;
/// - otherwise: the native backend over a deterministic synthetic
///   Core50-mini (seed from `$TINYCL_SYNTH_SEED`, default
///   [`super::synthetic::DEFAULT_SEED`]) — the zero-artifact offline path.
pub fn open_default_backend() -> Result<(Box<dyn Backend>, Dataset)> {
    open_backend(BackendChoice::from_env()?)
}

/// [`open_default_backend`] with an explicit choice.
pub fn open_backend(choice: BackendChoice) -> Result<(Box<dyn Backend>, Dataset)> {
    use super::{synthetic, NativeBackend};
    let dir = Manifest::default_dir();
    let have_artifacts = dir.join("manifest.json").exists();
    match choice {
        BackendChoice::Pjrt => {
            ensure!(
                have_artifacts,
                "TINYCL_BACKEND=pjrt but no artifacts at {dir:?} — run `make artifacts`"
            );
            let rt = Runtime::open(&dir)?;
            let ds = Dataset::load(Runtime::manifest(&rt))?;
            Ok((Box::new(rt), ds))
        }
        BackendChoice::Auto | BackendChoice::Native if have_artifacts => {
            let m = Manifest::load(&dir)?;
            let ds = Dataset::load(&m)?;
            if choice == BackendChoice::Native {
                Ok((Box::new(NativeBackend::new(m)?), ds))
            } else {
                let rt = Runtime::open(&dir)?;
                Ok((Box::new(rt), ds))
            }
        }
        _ => {
            let spec = synthetic::SyntheticSpec::from_env();
            let (m, ds) = synthetic::generate(&spec)?;
            Ok((Box::new(NativeBackend::new(m)?), ds))
        }
    }
}
