//! Runtime quantization: affine UINT-Q codecs + dense bit-packing.
//!
//! This is the rust half of QLR-CL (paper §III-C): the frozen stage emits
//! latents on the INT-8 dequantized grid; the replay buffer re-quantizes
//! them to `Q_LR ∈ {8,7,6}` bits and stores them *packed* — 8-bit replays
//! as raw bytes, 7-/6-bit replays bit-packed — which is where the paper's
//! 4× / 4.5× LR-memory compression comes from.
//!
//! [`requant`] is the frozen-stage half: true-`i8` weight codes,
//! round-to-nearest weight quantization (the rule shared with the python
//! build pipeline), and the fixed-point multiplier+shift requantization
//! the integer i8×i8→i32 kernel path runs at every layer boundary.

pub mod bitpack;
pub mod requant;

pub use requant::{
    act_scale, dequantize_acts_into, fake_quant_weight, quantize_acts_into, quantize_weights_i8,
    requantize_relu_into, QuantizedWeights, Requant,
};

pub use bitpack::{
    narrow_code, pack_bits, pack_bits_into, packed_len, remap_code, repack_narrow_in_place,
    repack_widen_in_place, unpack_bits, unpack_bits_into, unpack_dequant_range, unpack_range,
    unpack_range_into,
};

/// Affine UINT-Q codec for (post-ReLU, hence non-negative) activations:
/// `q = clip(floor(x / S), 0, 2^Q - 1)`, `S = a_max / (2^Q - 1)` (eq. 2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ActQuantizer {
    pub bits: u8,
    pub a_max: f32,
}

impl ActQuantizer {
    pub fn new(bits: u8, a_max: f32) -> Self {
        assert!((1..=8).contains(&bits), "supported Q range is 1..=8 bits");
        assert!(a_max > 0.0, "a_max must be positive (post-ReLU range)");
        ActQuantizer { bits, a_max }
    }

    pub fn levels(&self) -> u32 {
        (1u32 << self.bits) - 1
    }

    pub fn scale(&self) -> f32 {
        self.a_max / self.levels() as f32
    }

    pub fn quantize_one(&self, x: f32) -> u8 {
        let q = (x / self.scale()).floor();
        q.clamp(0.0, self.levels() as f32) as u8
    }

    pub fn dequantize_one(&self, q: u8) -> f32 {
        q as f32 * self.scale()
    }

    pub fn quantize(&self, xs: &[f32], out: &mut Vec<u8>) {
        out.clear();
        out.reserve(xs.len());
        let inv = 1.0 / self.scale();
        let lv = self.levels() as f32;
        out.extend(xs.iter().map(|&x| (x * inv).floor().clamp(0.0, lv) as u8));
    }

    /// The 256-entry dequantization table: `lut[q] = q * S`. Exact for
    /// every representable code at any Q <= 8 (f32 holds `q * S` the same
    /// way `dequantize_one` computes it — same expression, same rounding).
    /// The replay buffer builds this once per buffer and feeds it to the
    /// fused [`unpack_dequant_range`] read path.
    pub fn lut(&self) -> [f32; 256] {
        let s = self.scale();
        let mut lut = [0f32; 256];
        for (code, slot) in lut.iter_mut().enumerate().take(self.levels() as usize + 1) {
            *slot = code as f32 * s;
        }
        lut
    }

    pub fn dequantize(&self, qs: &[u8], out: &mut [f32]) {
        assert_eq!(qs.len(), out.len());
        // LUT dequantization: one multiply per distinct code instead of per
        // element — the hot-path variant used by the batcher (§Perf L3).
        let lut = self.lut();
        for (o, &q) in out.iter_mut().zip(qs) {
            *o = lut[q as usize];
        }
    }

    /// Round-trip `x -> grid` (what the adaptive stage actually consumes).
    pub fn fake_quant(&self, x: f32) -> f32 {
        self.dequantize_one(self.quantize_one(x))
    }
}

/// Memory cost in bytes of `n` codes at `bits` precision, bit-packed.
pub fn lr_bytes(n: usize, bits: u8) -> usize {
    packed_len(n, bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn quantize_known_values() {
        let q = ActQuantizer::new(8, 2.55);
        assert_eq!(q.quantize_one(0.0), 0);
        assert_eq!(q.quantize_one(2.55), 255);
        assert_eq!(q.quantize_one(10.0), 255); // clipped
        assert_eq!(q.quantize_one(-1.0), 0); // clipped
        assert!((q.scale() - 0.01).abs() < 1e-7);
    }

    #[test]
    fn round_trip_error_bounded_by_one_step() {
        prop::check("quant round trip", 128, |rng: &mut Rng| {
            let bits = prop::int_in(rng, 2, 8) as u8;
            let a_max = 0.5 + rng.f32() * 8.0;
            let q = ActQuantizer::new(bits, a_max);
            let xs = prop::vec_f32(rng, 256, 0.0, a_max);
            let mut codes = Vec::new();
            q.quantize(&xs, &mut codes);
            let mut back = vec![0f32; xs.len()];
            q.dequantize(&codes, &mut back);
            for (&x, &b) in xs.iter().zip(&back) {
                assert!(
                    (x - b).abs() <= q.scale() * (1.0 + 1e-5),
                    "bits={bits} a_max={a_max} x={x} back={b} scale={}",
                    q.scale()
                );
            }
        });
    }

    #[test]
    fn quantize_monotone() {
        prop::check("quant monotone", 64, |rng| {
            let bits = prop::int_in(rng, 2, 8) as u8;
            let q = ActQuantizer::new(bits, 4.0);
            let a = rng.f32() * 4.0;
            let b = rng.f32() * 4.0;
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            assert!(q.quantize_one(lo) <= q.quantize_one(hi));
        });
    }

    #[test]
    fn lut_is_bit_exact_for_all_widths() {
        // the fused replay read path relies on lut[q] being the very same
        // f32 `dequantize_one` produces, for every Q in 1..=8
        prop::check("lut bit exact", 64, |rng| {
            let bits = prop::int_in(rng, 1, 8) as u8;
            let a_max = 0.1 + rng.f32() * 9.0;
            let q = ActQuantizer::new(bits, a_max);
            let lut = q.lut();
            for code in 0..=q.levels() {
                let viaq = q.dequantize_one(code as u8);
                assert_eq!(
                    lut[code as usize].to_bits(),
                    viaq.to_bits(),
                    "bits={bits} a_max={a_max} code={code}"
                );
            }
            // codes beyond the representable range are zero-filled
            for code in (q.levels() as usize + 1)..256 {
                assert_eq!(lut[code], 0.0);
            }
        });
    }

    #[test]
    fn dequantize_lut_matches_scalar() {
        prop::check("lut == scalar", 64, |rng| {
            let bits = prop::int_in(rng, 2, 8) as u8;
            let q = ActQuantizer::new(bits, 3.3);
            let codes: Vec<u8> = (0..100)
                .map(|_| rng.below(q.levels() as usize + 1) as u8)
                .collect();
            let mut out = vec![0f32; codes.len()];
            q.dequantize(&codes, &mut out);
            for (&c, &o) in codes.iter().zip(&out) {
                assert_eq!(o, q.dequantize_one(c));
            }
        });
    }

    #[test]
    fn grid_values_are_fixed_points() {
        // fake_quant(fake_quant(x)) == fake_quant(x) up to one scale step
        let q = ActQuantizer::new(7, 1.7);
        for i in 0..=q.levels() {
            let g = q.dequantize_one(i as u8);
            assert!((q.fake_quant(g) - g).abs() <= q.scale());
        }
    }

    #[test]
    fn lr_bytes_compression_factors() {
        // the paper's headline: 8-bit -> 4x vs FP32, 7-bit -> ~4.57x
        let n = 32_000;
        assert_eq!(lr_bytes(n, 8), n);
        assert_eq!(lr_bytes(n, 7), n * 7 / 8);
        assert_eq!(lr_bytes(n, 6), n * 6 / 8);
        let fp32 = n * 4;
        assert!((fp32 as f64 / lr_bytes(n, 8) as f64 - 4.0).abs() < 1e-9);
        assert!((fp32 as f64 / lr_bytes(n, 7) as f64 - 4.571).abs() < 1e-2);
    }
}
