//! The INT-8 frozen-stage quantization toolkit: round-to-nearest weight
//! quantization to true `i8` codes, the activation-scale rule shared with
//! the fake-quant oracle, and fixed-point (multiplier + shift)
//! requantization — everything the integer i8×i8→i32 kernel path needs
//! to run a conv → ReLU → quantize layer without touching a float.
//!
//! ## The arithmetic
//!
//! A frozen layer in the paper's INT-8 pipeline (eq. 1/2) is
//!
//! ```text
//! y = ReLU(conv(x, w)),   x = q_x · S_x,   w = q_w · S_w
//! q_y = clip(⌊y / S_y⌋, 0, 2^Q - 1)
//! ```
//!
//! With both operands on their integer grids the conv is an exact integer
//! accumulation `acc = Σ q_x · q_w` (i32), and the quantize step becomes
//!
//! ```text
//! q_y = clip(⌊acc · s⌋, 0, 2^Q - 1),   s = S_x · S_w / S_y
//! ```
//!
//! [`Requant`] carries `s` as a fixed-point `multiplier · 2^-shift`
//! (31 significant bits, the PULP-NN / gemmlowp normalization), so the
//! whole layer boundary is one integer multiply-shift per element — no
//! division, no float. The relative error of the fixed-point form is
//! ≤ 2⁻³¹, which keeps the integer path within ≤ 1 LSB of the fake-quant
//! FP32 oracle (the parity suite pins this; the oracle itself carries
//! f32 accumulation noise of the same order).
//!
//! ## Weight codes
//!
//! [`quantize_weights_i8`] stores the full-range affine grid (paper
//! eq. 1) as true `i8`: level `q ∈ [lo, lo + 255]` is kept as
//! `code = q - lo - 128 ∈ [-128, 127]`, and the integer kernels recover
//! `q = code + off` with `off = lo + 128` folded into the accumulation
//! via per-row activation sums (`Σ q_x (code + off) = Σ q_x·code +
//! off·Σ q_x`). Rounding is **round-to-nearest** (`⌊w/S + ½⌋`), the rule
//! shared with `python/compile/kernels/ref.py::quantize_weight` and
//! pinned by the cross-language fixture test
//! (`tools/fixtures/weight_quant.json`).

/// Round-to-nearest-half-up in f32: `⌊v + ½⌋`. One expression for both
/// languages of the build (python mirrors it as `floor(w/s + 0.5)`), so
/// ties break identically everywhere — unlike `f32::round` (half away
/// from zero) or numpy's default (half to even).
#[inline]
pub fn round_half_up(v: f32) -> f32 {
    (v + 0.5).floor()
}

/// Activation quantization scale — the exact expression of the
/// fake-quant oracle (`S = max(a_max / (2^Q - 1), 1e-12)`), so codes and
/// grid values produced here are bit-identical to the FP32 path's.
#[inline]
pub fn act_scale(a_max: f32, bits: u8) -> f32 {
    let levels = ((1u32 << bits) - 1) as f32;
    (a_max / levels).max(1e-12)
}

/// Quantize a non-negative activation tensor to UINT-Q codes (paper
/// eq. 2): `q = clip(⌊x / S⌋, 0, 2^Q - 1)` — the one float→integer
/// crossing of the INT-8 frozen pipeline (the input boundary).
pub fn quantize_acts_into(x: &[f32], a_max: f32, bits: u8, out: &mut [u8]) {
    assert_eq!(x.len(), out.len(), "quantize_acts_into: size mismatch");
    let inv = 1.0 / act_scale(a_max, bits);
    let levels = ((1u32 << bits) - 1) as f32;
    for (o, &v) in out.iter_mut().zip(x) {
        *o = (v * inv).floor().clamp(0.0, levels) as u8;
    }
}

/// Dequantize UINT-Q codes back to the grid: `q · S`, the very f32 value
/// the fake-quant oracle produces for the same code (same scale
/// expression, same multiply), so downstream consumers (replay packing,
/// pooling, the adaptive stage) see bit-identical inputs.
pub fn dequantize_acts_into(q: &[u8], a_max: f32, bits: u8, out: &mut [f32]) {
    assert_eq!(q.len(), out.len(), "dequantize_acts_into: size mismatch");
    let s = act_scale(a_max, bits);
    for (o, &c) in out.iter_mut().zip(q) {
        *o = c as f32 * s;
    }
}

/// Full-range affine weight quantization (paper eq. 1) to true `i8`
/// storage. Level of element `i` is `codes[i] as i32 + off`; the
/// dequantized grid value is `(codes[i] as i32 + off) as f32 * scale`.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedWeights {
    /// `q - lo - 128` per element — the byte the kernels load
    pub codes: Vec<i8>,
    /// `lo + 128`: add to a code to recover the signed level `q`
    pub off: i32,
    /// `S_w = max((w_max - w_min) / (2^Q - 1), 1e-12)`, zero in range
    pub scale: f32,
}

impl QuantizedWeights {
    /// Dequantize back to the fake-quant grid (`q · S_w`) — bit-identical
    /// to [`fake_quant_weight`] on the same tensor, by construction.
    pub fn dequantize(&self) -> Vec<f32> {
        self.codes
            .iter()
            .map(|&c| (c as i32 + self.off) as f32 * self.scale)
            .collect()
    }
}

/// Quantize a weight tensor to [`QuantizedWeights`]: full-range affine
/// scale with zero included, **round-to-nearest** codes
/// (`q = clip(⌊w/S + ½⌋, lo, lo + 2^Q - 1)`).
pub fn quantize_weights_i8(w: &[f32], bits: u8) -> QuantizedWeights {
    assert!((1..=8).contains(&bits), "weight Q range is 1..=8 bits");
    let mut w_min = 0f32;
    let mut w_max = 0f32;
    for &v in w {
        w_min = w_min.min(v);
        w_max = w_max.max(v);
    }
    let levels = ((1u32 << bits) - 1) as f32;
    let scale = ((w_max - w_min) / levels).max(1e-12);
    let lo = (w_min / scale).floor();
    let codes = w
        .iter()
        .map(|&v| (round_half_up(v / scale).clamp(lo, lo + levels) - lo - 128.0) as i8)
        .collect();
    QuantizedWeights { codes, off: lo as i32 + 128, scale }
}

/// Fake-quantize a weight tensor over its full range (paper eq. 1):
/// round-to-nearest onto the `q · S_w` grid — the FP32-simulation twin of
/// [`quantize_weights_i8`] (one rounding rule, asserted bit-identical).
pub fn fake_quant_weight(w: &[f32], bits: u8) -> Vec<f32> {
    quantize_weights_i8(w, bits).dequantize()
}

/// A positive real scale as fixed point: `s ≈ mult · 2^-shift` with
/// `mult` normalized to 31 significant bits. [`Requant::apply`] computes
/// `⌊acc · s⌋` for `acc ≥ 0` in one widening multiply + shift.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Requant {
    pub mult: i64,
    pub shift: i32,
}

impl Requant {
    /// Fixed-point form of `s`. Non-positive / non-finite scales yield
    /// the zero map (every accumulator requantizes to code 0) — the
    /// degenerate `a_max = 0` layers fall here instead of dividing by
    /// zero.
    pub fn from_scale(s: f64) -> Requant {
        if !(s.is_finite() && s > 0.0) {
            return Requant { mult: 0, shift: 0 };
        }
        // frexp: s = mant * 2^exp, mant in [0.5, 1)
        let mut mant = s;
        let mut exp = 0i32;
        while mant >= 1.0 {
            mant *= 0.5;
            exp += 1;
        }
        while mant < 0.5 {
            mant *= 2.0;
            exp -= 1;
        }
        let mut mult = (mant * (1u64 << 31) as f64).round() as i64;
        if mult == 1 << 31 {
            mult = 1 << 30;
            exp += 1;
        }
        Requant { mult, shift: 31 - exp }
    }

    /// `⌊acc · s⌋` for `acc ≥ 0` (relative fixed-point error ≤ 2⁻³¹).
    /// Negative accumulators are the ReLU-clipped region and map to 0.
    #[inline]
    pub fn apply(&self, acc: i32) -> i64 {
        if acc <= 0 {
            return 0;
        }
        let prod = acc as i64 * self.mult; // < 2^31 * 2^31 = 2^62: no overflow
        if self.shift >= 64 {
            // s < ~2^-33: every representable accumulator floors to 0
            return 0;
        }
        if self.shift >= 0 {
            prod >> self.shift
        } else {
            // s >= 2^31: enormous scales saturate (the clamp downstream
            // caps at the code ceiling anyway)
            prod.saturating_mul(1i64 << (-self.shift).min(62))
        }
    }

    /// Fused ReLU + quantize of one accumulator:
    /// `clip(⌊acc · s⌋, 0, levels)`.
    #[inline]
    pub fn quantize(&self, acc: i32, levels: u32) -> u8 {
        self.apply(acc).clamp(0, levels as i64) as u8
    }
}

/// One layer boundary of the integer pipeline: ReLU + requantize a whole
/// i32 accumulator tensor into UINT-Q codes.
pub fn requantize_relu_into(acc: &[i32], rq: Requant, bits: u8, out: &mut [u8]) {
    assert_eq!(acc.len(), out.len(), "requantize_relu_into: size mismatch");
    let levels = (1u32 << bits) - 1;
    for (o, &a) in out.iter_mut().zip(acc) {
        *o = rq.quantize(a, levels);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn requant_matches_real_floor() {
        // |apply(acc) - floor(acc * s)| <= 1 wherever the product lands
        // in code range (the use case: products are quantization codes,
        // <= 255 + clip overshoot): the fixed-point form may land on the
        // other side of a boundary the real product sits within
        // `product * 2^-31` of — < 1 whenever the product itself is far
        // below 2^31 — never further
        prop::check("requant floor", 256, |rng: &mut Rng| {
            let s = 10f64.powf(rng.f32() as f64 * 12.0 - 9.0); // 1e-9..=1e3
            let rq = Requant::from_scale(s);
            // cap the accumulator so acc * s stays in a generous code
            // range (<= ~1e6), where the <= 1 bound genuinely holds
            let cap = ((1e6 / s) as u64).clamp(1, 1 << 30) as usize;
            let acc = rng.below(cap) as i32;
            let real = (acc as f64 * s).floor() as i64;
            let fixed = rq.apply(acc);
            assert!(
                (real - fixed).abs() <= 1,
                "s={s} acc={acc}: real {real} vs fixed {fixed}"
            );
        });
    }

    #[test]
    fn requant_power_of_two_scales_are_exact() {
        for exp in -20i32..=4 {
            let s = 2f64.powi(exp);
            let rq = Requant::from_scale(s);
            for acc in [0i32, 1, 2, 3, 100, 12345, 1 << 20, (1 << 30) - 1] {
                assert_eq!(
                    rq.apply(acc),
                    (acc as f64 * s).floor() as i64,
                    "s=2^{exp} acc={acc}"
                );
            }
        }
    }

    #[test]
    fn requant_is_monotone_and_zero_at_zero() {
        prop::check("requant monotone", 64, |rng: &mut Rng| {
            let s = (rng.f32() as f64) * 0.01 + 1e-7;
            let rq = Requant::from_scale(s);
            let a = rng.below(1 << 24) as i32;
            let b = rng.below(1 << 24) as i32;
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            assert!(rq.apply(lo) <= rq.apply(hi));
        });
        let rq = Requant::from_scale(0.123);
        assert_eq!(rq.apply(0), 0);
        assert_eq!(rq.apply(-5), 0, "negative accumulators are the ReLU region");
    }

    #[test]
    fn requant_degenerate_scales_yield_zero() {
        for s in [0.0f64, -1.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let rq = Requant::from_scale(s);
            assert_eq!(rq.quantize(1 << 20, 255), 0, "s={s}");
        }
        // a scale so small every accumulator floors to zero
        let tiny = Requant::from_scale(1e-30);
        assert_eq!(tiny.quantize(i32::MAX, 255), 0);
    }

    #[test]
    fn requant_quantize_clamps_to_levels() {
        let rq = Requant::from_scale(1.0);
        assert_eq!(rq.quantize(300, 255), 255);
        assert_eq!(rq.quantize(300, 127), 127);
        assert_eq!(rq.quantize(64, 127), 64);
        // huge scale saturates into the clamp instead of overflowing
        let big = Requant::from_scale(1e18);
        assert_eq!(big.quantize(7, 255), 255);
    }

    #[test]
    fn weight_codes_round_to_nearest_and_cover_the_range() {
        prop::check("weight quant", 96, |rng: &mut Rng| {
            let bits = prop::int_in(rng, 2, 8) as u8;
            let n = prop::int_in(rng, 1, 200);
            let w: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.3).collect();
            let q = quantize_weights_i8(&w, bits);
            let back = q.dequantize();
            let half = q.scale * 0.5;
            for (&orig, &deq) in w.iter().zip(&back) {
                // round-to-nearest: within half a step unless clipped at
                // the range ends (which full-range affine never is, save
                // for the +1/2-rounding overshoot at the very extremes)
                assert!(
                    (orig - deq).abs() <= half * (1.0 + 1e-4) + q.scale * 1e-4,
                    "bits={bits}: {orig} -> {deq} (scale {})",
                    q.scale
                );
            }
            // levels q = code + off stay inside [lo, lo + levels]
            let levels = (1i32 << bits) - 1;
            let lo = q.off - 128;
            for &c in &q.codes {
                let lvl = c as i32 + q.off;
                assert!((lo..=lo + levels).contains(&lvl), "bits={bits} level {lvl}");
            }
        });
    }

    #[test]
    fn fake_quant_weight_is_the_dequantized_i8_grid() {
        // ONE rounding rule: the FP32 simulation grid and the i8 codes
        // must be the same quantization, element for element
        let mut rng = Rng::new(9);
        let w: Vec<f32> = (0..300).map(|_| rng.normal() as f32).collect();
        for bits in [6u8, 7, 8] {
            let grid = fake_quant_weight(&w, bits);
            let q = quantize_weights_i8(&w, bits);
            assert_eq!(grid, q.dequantize(), "bits={bits}");
        }
    }

    #[test]
    fn weight_quant_handles_degenerate_tensors() {
        // all-zero weights: scale floors at 1e-12, every code is level 0
        let q = quantize_weights_i8(&[0.0; 16], 8);
        assert!(q.dequantize().iter().all(|&v| v == 0.0));
        // all-positive tensor: zero is still on the grid (lo == 0)
        let q = quantize_weights_i8(&[0.5, 1.0, 2.0], 8);
        assert_eq!(q.off, 128, "lo must be 0 for a non-negative tensor");
        // all-negative tensor: the top of the range is zero
        let q = quantize_weights_i8(&[-1.0, -0.25], 8);
        assert_eq!(q.off - 128 + 255, 0, "hi must be 0 for a non-positive tensor");
    }

    #[test]
    fn act_codes_round_trip_and_saturate() {
        for bits in [6u8, 7, 8] {
            let levels = (1u32 << bits) - 1;
            let a_max = 1.7f32;
            let xs = [0.0f32, 0.3, 1.69, 1.7, 5.0, -2.0];
            let mut q = vec![0u8; xs.len()];
            quantize_acts_into(&xs, a_max, bits, &mut q);
            assert_eq!(q[3], levels as u8, "x == a_max is the top code");
            assert_eq!(q[4], levels as u8, "saturating input clips to the top code");
            assert_eq!(q[5], 0, "negative input clips to 0");
            let mut back = vec![0f32; xs.len()];
            dequantize_acts_into(&q, a_max, bits, &mut back);
            let s = act_scale(a_max, bits);
            for (&x, &b) in xs.iter().zip(&back).take(4) {
                assert!((x.clamp(0.0, a_max) - b).abs() <= s * (1.0 + 1e-5), "bits={bits}");
            }
        }
    }

    #[test]
    fn act_scale_matches_the_fake_quant_oracle_expression() {
        // same max(…, 1e-12) clamp, same division — including a_max = 0,
        // where both degenerate to the 1e-12 floor instead of dividing
        // by zero
        for bits in [6u8, 7, 8] {
            let levels = ((1u32 << bits) - 1) as f32;
            for a_max in [0.0f32, 1e-20, 0.5, 3.7] {
                let expect = (a_max / levels).max(1e-12);
                assert_eq!(act_scale(a_max, bits), expect, "bits={bits} a_max={a_max}");
            }
        }
    }
}
