//! Dense bit-packing of UINT-Q codes (Q <= 8) into a byte stream.
//!
//! The LR memory stores `N_LR x latent_size` codes; at Q=7 packing saves a
//! further 12.5% over byte storage — the difference between the paper's
//! 4x and 4.57x compression claims. Codes are packed LSB-first into a
//! little-endian bit stream, so any Q and any length round-trip exactly.

/// Bytes needed to pack `n` codes of `bits` width.
pub fn packed_len(n: usize, bits: u8) -> usize {
    (n * bits as usize + 7) / 8
}

/// Pack `codes` (each `< 2^bits`) into `out` (resized as needed).
pub fn pack_bits(codes: &[u8], bits: u8, out: &mut Vec<u8>) {
    assert!((1..=8).contains(&bits));
    out.clear();
    out.resize(packed_len(codes.len(), bits), 0);
    if bits == 8 {
        out.copy_from_slice(codes);
        return;
    }
    let mask = (1u16 << bits) - 1;
    let mut acc: u32 = 0; // bit accumulator, LSB-first
    let mut nbits: u32 = 0;
    let mut byte_i = 0;
    for &c in codes {
        debug_assert!(
            (c as u16) <= mask,
            "code {c} exceeds {bits}-bit range"
        );
        acc |= ((c as u16 & mask) as u32) << nbits;
        nbits += bits as u32;
        while nbits >= 8 {
            out[byte_i] = (acc & 0xFF) as u8;
            byte_i += 1;
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out[byte_i] = (acc & 0xFF) as u8;
    }
}

/// Unpack `n` codes of `bits` width from `packed` into `out`.
pub fn unpack_bits(packed: &[u8], bits: u8, n: usize, out: &mut Vec<u8>) {
    assert!((1..=8).contains(&bits));
    assert!(
        packed.len() >= packed_len(n, bits),
        "packed buffer too short: {} < {}",
        packed.len(),
        packed_len(n, bits)
    );
    out.clear();
    out.reserve(n);
    if bits == 8 {
        out.extend_from_slice(&packed[..n]);
        return;
    }
    let mask = (1u32 << bits) - 1;
    let mut acc: u32 = 0;
    let mut nbits: u32 = 0;
    let mut byte_i = 0;
    for _ in 0..n {
        while nbits < bits as u32 {
            acc |= (packed[byte_i] as u32) << nbits;
            byte_i += 1;
            nbits += 8;
        }
        out.push((acc & mask) as u8);
        acc >>= bits;
        nbits -= bits as u32;
    }
}

/// Unpack a *sub-range* `[start, start+len)` of codes without touching the
/// rest of the stream — the replay buffer reads one latent vector at a time
/// out of a large packed arena (hot path).
pub fn unpack_range(packed: &[u8], bits: u8, start: usize, len: usize, out: &mut Vec<u8>) {
    assert!((1..=8).contains(&bits));
    out.clear();
    out.reserve(len);
    if bits == 8 {
        out.extend_from_slice(&packed[start..start + len]);
        return;
    }
    let bits = bits as usize;
    let mask = (1u32 << bits) - 1;
    let mut bitpos = start * bits;
    for _ in 0..len {
        let byte_i = bitpos / 8;
        let off = bitpos % 8;
        // a code spans at most 2 bytes for bits <= 8
        let lo = packed[byte_i] as u32 >> off;
        let hi = if off + bits > 8 {
            (packed[byte_i + 1] as u32) << (8 - off)
        } else {
            0
        };
        out.push(((lo | hi) & mask) as u8);
        bitpos += bits;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn round_trip_all_widths() {
        prop::check("bitpack round trip", 256, |rng| {
            let bits = prop::int_in(rng, 1, 8) as u8;
            let n = prop::int_in(rng, 0, 600);
            let max = (1u16 << bits) as usize;
            let codes: Vec<u8> = (0..n).map(|_| rng.below(max) as u8).collect();
            let mut packed = Vec::new();
            pack_bits(&codes, bits, &mut packed);
            assert_eq!(packed.len(), packed_len(n, bits));
            let mut back = Vec::new();
            unpack_bits(&packed, bits, n, &mut back);
            assert_eq!(codes, back, "bits={bits} n={n}");
        });
    }

    #[test]
    fn unpack_range_matches_full_unpack() {
        prop::check("bitpack range", 256, |rng| {
            let bits = prop::int_in(rng, 1, 8) as u8;
            let n = prop::int_in(rng, 1, 500);
            let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
            let mut packed = Vec::new();
            pack_bits(&codes, bits, &mut packed);
            let start = rng.below(n);
            let len = rng.below(n - start + 1);
            let mut sub = Vec::new();
            unpack_range(&packed, bits, start, len, &mut sub);
            assert_eq!(&codes[start..start + len], &sub[..]);
        });
    }

    #[test]
    fn known_pattern_7bit() {
        // 7-bit codes 0..8 pack into exactly 7 bytes
        let codes: Vec<u8> = (0..8).collect();
        let mut packed = Vec::new();
        pack_bits(&codes, 7, &mut packed);
        assert_eq!(packed.len(), 7);
        let mut back = Vec::new();
        unpack_bits(&packed, 7, 8, &mut back);
        assert_eq!(back, codes);
    }

    #[test]
    fn eight_bit_is_identity() {
        let codes = vec![0u8, 255, 17, 128];
        let mut packed = Vec::new();
        pack_bits(&codes, 8, &mut packed);
        assert_eq!(packed, codes);
    }

    #[test]
    fn empty_input() {
        let mut packed = vec![9u8; 3];
        pack_bits(&[], 6, &mut packed);
        assert!(packed.is_empty());
        let mut out = Vec::new();
        unpack_bits(&[], 6, 0, &mut out);
        assert!(out.is_empty());
    }
}
