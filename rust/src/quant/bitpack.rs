//! Dense bit-packing of UINT-Q codes (Q <= 8) into a byte stream.
//!
//! The LR memory stores `N_LR x latent_size` codes; at Q=7 packing saves a
//! further 12.5% over byte storage — the difference between the paper's
//! 4x and 4.57x compression claims. Codes are packed LSB-first into a
//! little-endian bit stream, so any Q and any length round-trip exactly.
//!
//! The `*_into` functions are the hot-path primitives: they write into
//! caller-provided slices and perform no allocation. The `Vec` variants
//! are thin wrappers kept for tests and one-shot callers. The replay
//! buffer's fused read path is [`unpack_dequant_range`], which maps codes
//! through a 256-entry f32 lookup table *while* unpacking — one pass, no
//! intermediate code buffer.

/// Bytes needed to pack `n` codes of `bits` width.
pub fn packed_len(n: usize, bits: u8) -> usize {
    (n * bits as usize + 7) / 8
}

/// Pack `codes` (each `< 2^bits`) into the exactly-sized slice `out`
/// (`packed_len(codes.len(), bits)` bytes). Allocation-free.
pub fn pack_bits_into(codes: &[u8], bits: u8, out: &mut [u8]) {
    assert!((1..=8).contains(&bits));
    assert_eq!(
        out.len(),
        packed_len(codes.len(), bits),
        "pack_bits_into: wrong output length"
    );
    if bits == 8 {
        out.copy_from_slice(codes);
        return;
    }
    let mask = (1u16 << bits) - 1;
    let mut acc: u32 = 0; // bit accumulator, LSB-first
    let mut nbits: u32 = 0;
    let mut byte_i = 0;
    for &c in codes {
        debug_assert!(
            (c as u16) <= mask,
            "code {c} exceeds {bits}-bit range"
        );
        acc |= ((c as u16 & mask) as u32) << nbits;
        nbits += bits as u32;
        while nbits >= 8 {
            out[byte_i] = (acc & 0xFF) as u8;
            byte_i += 1;
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out[byte_i] = (acc & 0xFF) as u8;
    }
}

/// Pack `codes` into `out` (resized as needed) — `Vec` convenience over
/// [`pack_bits_into`].
pub fn pack_bits(codes: &[u8], bits: u8, out: &mut Vec<u8>) {
    out.clear();
    out.resize(packed_len(codes.len(), bits), 0);
    pack_bits_into(codes, bits, out);
}

/// Unpack `out.len()` codes of `bits` width from the start of `packed`
/// into `out`. Allocation-free.
pub fn unpack_bits_into(packed: &[u8], bits: u8, out: &mut [u8]) {
    unpack_range_into(packed, bits, 0, out);
}

/// Unpack `n` codes of `bits` width from `packed` into `out` — `Vec`
/// convenience over [`unpack_bits_into`].
pub fn unpack_bits(packed: &[u8], bits: u8, n: usize, out: &mut Vec<u8>) {
    out.clear();
    out.resize(n, 0);
    unpack_bits_into(packed, bits, out);
}

/// Unpack the code sub-range `[start, start + out.len())` from `packed`
/// into `out`, without touching the rest of the stream — the replay
/// buffer reads one latent vector at a time out of a large packed arena.
/// Allocation-free.
pub fn unpack_range_into(packed: &[u8], bits: u8, start: usize, out: &mut [u8]) {
    assert!((1..=8).contains(&bits));
    let len = out.len();
    assert!(
        packed.len() >= packed_len(start + len, bits),
        "packed buffer too short: {} < {}",
        packed.len(),
        packed_len(start + len, bits)
    );
    if bits == 8 {
        out.copy_from_slice(&packed[start..start + len]);
        return;
    }
    let bits = bits as usize;
    let mask = (1u32 << bits) - 1;
    let mut bitpos = start * bits;
    for slot in out.iter_mut() {
        let byte_i = bitpos / 8;
        let off = bitpos % 8;
        // a code spans at most 2 bytes for bits <= 8
        let lo = packed[byte_i] as u32 >> off;
        let hi = if off + bits > 8 {
            (packed[byte_i + 1] as u32) << (8 - off)
        } else {
            0
        };
        *slot = ((lo | hi) & mask) as u8;
        bitpos += bits;
    }
}

/// Unpack a sub-range `[start, start+len)` of codes into a `Vec` — thin
/// wrapper over [`unpack_range_into`], kept for tests/one-shot callers.
pub fn unpack_range(packed: &[u8], bits: u8, start: usize, len: usize, out: &mut Vec<u8>) {
    out.clear();
    out.resize(len, 0);
    unpack_range_into(packed, bits, start, out);
}

/// Fused unpack + dequantize: map the code sub-range
/// `[start, start + out.len())` through `lut` straight into the caller's
/// f32 slice. This is the replay hot path (`sample_into` /
/// `read_slot_into`): one pass over the packed arena, no intermediate
/// code buffer, no allocation.
///
/// CONTRACT: `lut` must be the *affine* 256-entry dequantization table
/// `lut[q] = q * lut[1]` over the representable code range — exactly
/// what [`crate::quant::ActQuantizer::lut`] builds (exact for all
/// Q <= 8, debug-asserted here). Affinity is what lets the hot paths
/// replace table lookups with the bit-identical `code as f32 * scale`:
///
/// - **Q = 8** runs as a straight-line convert-and-scale over arena
///   bytes (memcpy-free, and the loop auto-vectorizes: widen, convert,
///   one multiply — the scalar table-gather it replaces cannot);
/// - **Q < 8** on byte-aligned, multiple-of-8 ranges (every replay slot
///   by construction) decodes *eight codes per `u64` load* — 8 codes
///   span exactly `Q` bytes — instead of per-code byte arithmetic;
/// - everything else (unaligned starts, ragged tails) takes the scalar
///   two-byte extraction path, via the same table.
pub fn unpack_dequant_range(
    packed: &[u8],
    bits: u8,
    start: usize,
    lut: &[f32; 256],
    out: &mut [f32],
) {
    assert!((1..=8).contains(&bits));
    let len = out.len();
    assert!(
        packed.len() >= packed_len(start + len, bits),
        "packed buffer too short: {} < {}",
        packed.len(),
        packed_len(start + len, bits)
    );
    let scale = lut[1];
    debug_assert!(
        (0..1usize << bits).all(|q| lut[q].to_bits() == (q as f32 * scale).to_bits()),
        "unpack_dequant_range requires an affine lut (lut[q] = q * lut[1])"
    );
    if bits == 8 {
        // convert-and-scale per arena byte: bit-identical to lut[b]
        // (affine contract) and vectorizable, unlike a table gather
        for (o, &b) in out.iter_mut().zip(&packed[start..start + len]) {
            *o = b as f32 * scale;
        }
        return;
    }
    let bits = bits as usize;
    let mask = (1u32 << bits) - 1;
    let mut bitpos = start * bits;
    let mut idx = 0;
    if bitpos % 8 == 0 {
        // group fast path: 8 codes == `bits` bytes, decoded from one u64
        // (the load reads 8 bytes, so stop short of the buffer tail)
        let mut byte = bitpos / 8;
        while idx + 8 <= len && byte + 8 <= packed.len() {
            let v = u64::from_le_bytes(packed[byte..byte + 8].try_into().unwrap());
            for (j, slot) in out[idx..idx + 8].iter_mut().enumerate() {
                *slot = ((v >> (bits * j)) as u32 & mask) as f32 * scale;
            }
            idx += 8;
            byte += bits;
            bitpos += 8 * bits;
        }
    }
    for slot in out[idx..].iter_mut() {
        let byte_i = bitpos / 8;
        let off = bitpos % 8;
        // a code spans at most 2 bytes for bits <= 8
        let lo = packed[byte_i] as u32 >> off;
        let hi = if off + bits > 8 {
            (packed[byte_i + 1] as u32) << (8 - off)
        } else {
            0
        };
        *slot = lut[((lo | hi) & mask) as usize];
        bitpos += bits;
    }
}

/// Requantize + repack a packed code stream **in place**: the first `n`
/// codes of `packed` at `from_bits` become `n` codes at `to_bits`
/// (`to_bits <= from_bits`), and `packed` is truncated to the new length.
/// No full-precision round-trip: codes are remapped in integer arithmetic
/// on the fly — `q' = round(q * (2^to - 1) / (2^from - 1))` — which is
/// the rounding-to-nearest projection between the two affine grids that
/// share one `a_max`. The governor's 8→7-bit replay demotion runs through
/// here, so the extra error over the stored value is at most **half** a
/// step of the *new* grid (`S_to / 2`), strictly better than re-running
/// the floor-based [`crate::quant::ActQuantizer`] on dequantized floats
/// (up to one full step) — bounded by the `narrowing_error_bounded`
/// property test below.
///
/// Works chunked: 256 codes are decoded ahead into a stack buffer before
/// their (shorter) packed form is written back, so the write cursor can
/// never overrun un-read input even though both live in the same buffer
/// (for chunk `c` starting at code `i`, writes end at bit
/// `i*to + 256*to`, while the next read starts at bit `(i+256)*from`;
/// `to <= from` makes the gap non-negative once `i + 256 >= 8`, and the
/// first chunk is fully decoded before any write).
pub fn repack_narrow_in_place(packed: &mut Vec<u8>, from_bits: u8, to_bits: u8, n: usize) {
    assert!((1..=8).contains(&from_bits) && (1..=8).contains(&to_bits));
    assert!(
        to_bits <= from_bits,
        "repack_narrow_in_place: cannot widen {from_bits} -> {to_bits} bits in place"
    );
    assert!(
        packed.len() >= packed_len(n, from_bits),
        "packed buffer too short: {} < {}",
        packed.len(),
        packed_len(n, from_bits)
    );
    if to_bits == from_bits {
        packed.truncate(packed_len(n, from_bits));
        return;
    }
    let lf = ((1u32 << from_bits) - 1) as u32;
    let lt = ((1u32 << to_bits) - 1) as u32;
    // 256 codes per chunk: a multiple of 8, so every chunk's write offset
    // (done * to_bits / 8) is whole-byte aligned for any Q
    const CHUNK: usize = 256;
    let mut chunk = [0u8; CHUNK];
    let mut done = 0;
    while done < n {
        let c = (n - done).min(CHUNK);
        unpack_range_into(packed, from_bits, done, &mut chunk[..c]);
        for q in chunk[..c].iter_mut() {
            *q = ((*q as u32 * lt + lf / 2) / lf) as u8;
        }
        let woff = done * to_bits as usize / 8;
        let wlen = packed_len(c, to_bits);
        pack_bits_into(&chunk[..c], to_bits, &mut packed[woff..woff + wlen]);
        done += c;
    }
    packed.truncate(packed_len(n, to_bits));
}

/// Requantize + repack a packed code stream **in place**, widening: the
/// first `n` codes at `from_bits` become `n` codes at `to_bits`
/// (`to_bits >= from_bits`), growing `packed` to the wider length. This
/// is the governor's 7→8-bit replay *promotion* — the exact counterpart
/// of [`repack_narrow_in_place`], using the same round-to-nearest
/// projection `q' = round(q * (2^to - 1) / (2^from - 1))` between the
/// two affine grids sharing one `a_max`.
///
/// Round-trip guarantee (property-tested below): because widening lands
/// each code within half a *new* (finer) step of its old grid point,
/// `narrow(widen(q)) == q` exactly — so a demote→promote→demote cycle
/// is idempotent and promotion never compounds error. (The information
/// lost by an earlier 8→7-bit demotion is of course not recovered; the
/// promoted buffer re-widens the *grid*, restoring full 8-bit precision
/// for everything written after the promotion.)
///
/// Works chunked **from the tail**: 256 codes are decoded ahead into a
/// stack buffer before their (longer) packed form is written back, so
/// the write cursor can never overrun un-read input even though both
/// live in the same buffer (for a chunk starting at code `i`, writes
/// cover bits `[i*to, (i+c)*to)` while all still-unread input lives
/// below bit `i*from <= i*to`; chunk starts are multiples of 256, hence
/// of 8, so both offsets are whole-byte aligned for any Q).
pub fn repack_widen_in_place(packed: &mut Vec<u8>, from_bits: u8, to_bits: u8, n: usize) {
    assert!((1..=8).contains(&from_bits) && (1..=8).contains(&to_bits));
    assert!(
        to_bits >= from_bits,
        "repack_widen_in_place: cannot narrow {from_bits} -> {to_bits} bits; \
         use repack_narrow_in_place"
    );
    assert!(
        packed.len() >= packed_len(n, from_bits),
        "packed buffer too short: {} < {}",
        packed.len(),
        packed_len(n, from_bits)
    );
    if to_bits == from_bits {
        packed.truncate(packed_len(n, from_bits));
        return;
    }
    let lf = ((1u32 << from_bits) - 1) as u32;
    let lt = ((1u32 << to_bits) - 1) as u32;
    packed.resize(packed_len(n, to_bits), 0);
    const CHUNK: usize = 256;
    let mut chunk = [0u8; CHUNK];
    // walk chunks tail-first; the last chunk may be ragged
    let n_chunks = n.div_ceil(CHUNK);
    for ci in (0..n_chunks).rev() {
        let start = ci * CHUNK;
        let c = (n - start).min(CHUNK);
        unpack_range_into(packed, from_bits, start, &mut chunk[..c]);
        for q in chunk[..c].iter_mut() {
            *q = ((*q as u32 * lt + lf / 2) / lf) as u8;
        }
        let woff = start * to_bits as usize / 8;
        let wlen = packed_len(c, to_bits);
        pack_bits_into(&chunk[..c], to_bits, &mut packed[woff..woff + wlen]);
    }
}

/// The single-code remap both in-place repacks apply: round-to-nearest
/// projection of a `from_bits` code onto the `to_bits` grid over the
/// same `a_max` range (narrowing *or* widening). Exposed for tests and
/// for callers that need the exact reference mapping.
pub fn remap_code(q: u8, from_bits: u8, to_bits: u8) -> u8 {
    let lf = ((1u32 << from_bits) - 1) as u32;
    let lt = ((1u32 << to_bits) - 1) as u32;
    ((q as u32 * lt + lf / 2) / lf) as u8
}

/// [`remap_code`] under its historical narrowing-only name.
pub fn narrow_code(q: u8, from_bits: u8, to_bits: u8) -> u8 {
    remap_code(q, from_bits, to_bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn round_trip_all_widths() {
        prop::check("bitpack round trip", 256, |rng| {
            let bits = prop::int_in(rng, 1, 8) as u8;
            let n = prop::int_in(rng, 0, 600);
            let max = (1u16 << bits) as usize;
            let codes: Vec<u8> = (0..n).map(|_| rng.below(max) as u8).collect();
            let mut packed = Vec::new();
            pack_bits(&codes, bits, &mut packed);
            assert_eq!(packed.len(), packed_len(n, bits));
            let mut back = Vec::new();
            unpack_bits(&packed, bits, n, &mut back);
            assert_eq!(codes, back, "bits={bits} n={n}");
        });
    }

    #[test]
    fn unpack_range_matches_full_unpack() {
        prop::check("bitpack range", 256, |rng| {
            let bits = prop::int_in(rng, 1, 8) as u8;
            let n = prop::int_in(rng, 1, 500);
            let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
            let mut packed = Vec::new();
            pack_bits(&codes, bits, &mut packed);
            let start = rng.below(n);
            let len = rng.below(n - start + 1);
            let mut sub = Vec::new();
            unpack_range(&packed, bits, start, len, &mut sub);
            assert_eq!(&codes[start..start + len], &sub[..]);
        });
    }

    #[test]
    fn into_variants_match_vec_variants() {
        prop::check("bitpack into", 128, |rng| {
            let bits = prop::int_in(rng, 1, 8) as u8;
            let n = prop::int_in(rng, 1, 300);
            let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
            let mut packed_vec = Vec::new();
            pack_bits(&codes, bits, &mut packed_vec);
            let mut packed_slice = vec![0u8; packed_len(n, bits)];
            pack_bits_into(&codes, bits, &mut packed_slice);
            assert_eq!(packed_vec, packed_slice);
            let start = rng.below(n);
            let len = rng.below(n - start + 1);
            let mut sub = vec![0u8; len];
            unpack_range_into(&packed_slice, bits, start, &mut sub);
            assert_eq!(&codes[start..start + len], &sub[..]);
        });
    }

    #[test]
    fn fused_dequant_matches_unpack_then_lookup() {
        prop::check("bitpack fused dequant", 128, |rng| {
            let bits = prop::int_in(rng, 1, 8) as u8;
            let n = prop::int_in(rng, 1, 300);
            let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
            let mut packed = Vec::new();
            pack_bits(&codes, bits, &mut packed);
            let mut lut = [0f32; 256];
            for (i, slot) in lut.iter_mut().enumerate() {
                *slot = i as f32 * 0.125;
            }
            let start = rng.below(n);
            let len = rng.below(n - start + 1);
            let mut fused = vec![0f32; len];
            unpack_dequant_range(&packed, bits, start, &lut, &mut fused);
            for (f, &c) in fused.iter().zip(&codes[start..start + len]) {
                assert_eq!(*f, lut[c as usize], "bits={bits}");
            }
        });
    }

    #[test]
    fn known_pattern_7bit() {
        // 7-bit codes 0..8 pack into exactly 7 bytes
        let codes: Vec<u8> = (0..8).collect();
        let mut packed = Vec::new();
        pack_bits(&codes, 7, &mut packed);
        assert_eq!(packed.len(), 7);
        let mut back = Vec::new();
        unpack_bits(&packed, 7, 8, &mut back);
        assert_eq!(back, codes);
    }

    #[test]
    fn eight_bit_is_identity() {
        let codes = vec![0u8, 255, 17, 128];
        let mut packed = Vec::new();
        pack_bits(&codes, 8, &mut packed);
        assert_eq!(packed, codes);
    }

    #[test]
    fn repack_narrow_matches_per_code_remap() {
        // the in-place narrowing must agree with the scalar reference
        // remap for every (from, to) pair and any length, including
        // multi-chunk streams that exercise the overlap-safety logic
        prop::check("bitpack repack remap", 96, |rng| {
            let from = prop::int_in(rng, 1, 8) as u8;
            let to = prop::int_in(rng, 1, from as usize) as u8;
            let n = prop::int_in(rng, 0, 700); // > 2 chunks of 256
            let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << from) as u8).collect();
            let mut packed = Vec::new();
            pack_bits(&codes, from, &mut packed);
            repack_narrow_in_place(&mut packed, from, to, n);
            assert_eq!(packed.len(), packed_len(n, to), "from={from} to={to} n={n}");
            let mut back = Vec::new();
            unpack_bits(&packed, to, n, &mut back);
            for (i, (&q, &q2)) in codes.iter().zip(&back).enumerate() {
                assert_eq!(q2, narrow_code(q, from, to), "from={from} to={to} i={i} q={q}");
            }
        });
    }

    #[test]
    fn repack_widen_matches_per_code_remap() {
        // the in-place widening must agree with the scalar reference
        // remap for every (from, to) pair and any length, including
        // multi-chunk streams that exercise the tail-first overlap logic
        prop::check("bitpack widen remap", 96, |rng| {
            let from = prop::int_in(rng, 1, 8) as u8;
            let to = prop::int_in(rng, from as usize, 8) as u8;
            let n = prop::int_in(rng, 0, 700); // > 2 chunks of 256
            let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << from) as u8).collect();
            let mut packed = Vec::new();
            pack_bits(&codes, from, &mut packed);
            repack_widen_in_place(&mut packed, from, to, n);
            assert_eq!(packed.len(), packed_len(n, to), "from={from} to={to} n={n}");
            let mut back = Vec::new();
            unpack_bits(&packed, to, n, &mut back);
            for (i, (&q, &q2)) in codes.iter().zip(&back).enumerate() {
                assert_eq!(q2, remap_code(q, from, to), "from={from} to={to} i={i} q={q}");
            }
        });
    }

    #[test]
    fn widen_then_narrow_round_trips_exactly() {
        // SATELLITE PROPERTY: promotion must be reversible — widening to
        // a finer grid then narrowing back recovers every code exactly,
        // so demote→promote→demote cycles are idempotent (no compounding
        // drift across governor pressure cycles)
        prop::check("bitpack widen/narrow round trip", 96, |rng| {
            let from = prop::int_in(rng, 1, 8) as u8;
            let to = prop::int_in(rng, from as usize, 8) as u8;
            let n = prop::int_in(rng, 1, 600);
            let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << from) as u8).collect();
            let mut packed = Vec::new();
            pack_bits(&codes, from, &mut packed);
            repack_widen_in_place(&mut packed, from, to, n);
            repack_narrow_in_place(&mut packed, to, from, n);
            let mut back = Vec::new();
            unpack_bits(&packed, from, n, &mut back);
            assert_eq!(codes, back, "from={from} to={to} n={n}");
        });
    }

    #[test]
    fn widening_error_bounded() {
        // promoting Q_from -> Q_to over a shared a_max lands each value
        // within half a step of the NEW (finer) grid — same bound as
        // narrowing, which is what makes the round trip exact
        prop::check("bitpack widen error", 96, |rng| {
            let from = prop::int_in(rng, 1, 7) as u8;
            let to = prop::int_in(rng, from as usize + 1, 8) as u8;
            let a_max = 0.25 + rng.f32() * 8.0;
            let lf = ((1u32 << from) - 1) as f64;
            let lt = ((1u32 << to) - 1) as f64;
            let (s_from, s_to) = (a_max as f64 / lf, a_max as f64 / lt);
            for q in 0..=((1u32 << from) - 1) as u16 {
                let q2 = remap_code(q as u8, from, to);
                assert!((q2 as f64) <= lt, "projected code out of range");
                let before = q as f64 * s_from;
                let after = q2 as f64 * s_to;
                assert!(
                    (before - after).abs() <= 0.5 * s_to * (1.0 + 1e-9),
                    "from={from} to={to} q={q}: |{before} - {after}| > S_to/2"
                );
            }
        });
    }

    #[test]
    fn widen_same_width_is_identity() {
        let codes: Vec<u8> = (0..100).map(|i| (i % 64) as u8).collect();
        let mut packed = Vec::new();
        pack_bits(&codes, 6, &mut packed);
        let reference = packed.clone();
        repack_widen_in_place(&mut packed, 6, 6, 100);
        assert_eq!(packed, reference);
    }

    #[test]
    fn repack_same_width_is_identity() {
        let codes: Vec<u8> = (0..100).map(|i| (i % 64) as u8).collect();
        let mut packed = Vec::new();
        pack_bits(&codes, 6, &mut packed);
        let reference = packed.clone();
        repack_narrow_in_place(&mut packed, 6, 6, 100);
        assert_eq!(packed, reference);
    }

    #[test]
    fn narrowing_error_bounded() {
        // SATELLITE PROPERTY: demoting Q_from -> Q_to over a shared a_max
        // must add at most *half* a new-grid step over the stored value —
        // strictly tighter than the floor-based full-precision round-trip
        // (dequantize + ActQuantizer re-quantize), which can lose a full
        // step. `(q*lt + lf/2) / lf` with lf = 2^from - 1 odd has a
        // worst-case code error of (lf/2)/lf < 1/2 exactly.
        prop::check("bitpack repack error", 96, |rng| {
            let from = prop::int_in(rng, 2, 8) as u8;
            let to = prop::int_in(rng, 1, from as usize) as u8;
            let a_max = 0.25 + rng.f32() * 8.0;
            let lf = ((1u32 << from) - 1) as f64;
            let lt = ((1u32 << to) - 1) as f64;
            let (s_from, s_to) = (a_max as f64 / lf, a_max as f64 / lt);
            for q in 0..=((1u32 << from) - 1) as u16 {
                let q2 = narrow_code(q as u8, from, to);
                assert!((q2 as f64) <= lt, "projected code out of range");
                let before = q as f64 * s_from;
                let after = q2 as f64 * s_to;
                assert!(
                    (before - after).abs() <= 0.5 * s_to * (1.0 + 1e-9),
                    "from={from} to={to} q={q}: |{before} - {after}| > S_to/2"
                );
            }
        });
    }

    #[test]
    fn empty_input() {
        let mut packed = vec![9u8; 3];
        pack_bits(&[], 6, &mut packed);
        assert!(packed.is_empty());
        let mut out = Vec::new();
        unpack_bits(&[], 6, 0, &mut out);
        assert!(out.is_empty());
    }
}
