//! The network io shim: where wire faults are injected.
//!
//! [`NetIo`] mirrors the spill tier's [`SpillIo`] seam one layer up the
//! stack: one *attempt* per call, with the caller supplying a stable
//! logical operation id and the attempt index, so a [`FaultPlan`] can
//! schedule per-operation fail streaks that replay exactly — the same
//! pure-`(seed, domain, op, attempt)` discipline as disk faults, now
//! over TCP. [`DirectNet`] is the production path (no plan checks at
//! all — faults-off stays zero-overhead); [`FaultyNet`] consults the
//! plan before every connect, frame send and frame receive:
//!
//! - **dropped connections** — the attempt errors and the stream is
//!   shut down (the client must reconnect);
//! - **torn frames** — the length prefix promises the full payload but
//!   only a seeded fraction of the bytes go out, then the stream is
//!   shut down *and the send call reports success*: the failure
//!   surfaces at the peer (mid-frame EOF → [`FrameError::Torn`]) and
//!   at the reply read, exactly like a real half-delivered `write(2)`;
//! - **seeded stalls** — the frame is delayed, then proceeds.
//!
//! Receive failures are classified, never stringly matched: a clean
//! close before any reply byte maps to [`FleetError::Io`], a death
//! mid-frame to [`FleetError::Protocol`] — and no partially-decoded
//! reply ever escapes (the payload buffer is only handed to the codec
//! after a complete frame arrived).
//!
//! [`SpillIo`]: crate::fleet::faults::SpillIo

use std::io::Write;
use std::net::{Shutdown, TcpStream};

use crate::fleet::api::FleetError;
use crate::fleet::faults::{FaultPlan, NetFault};
use crate::net::frame::{client_handshake, write_frame, read_frame_into, FrameError};

/// Map a classified frame failure onto the client-visible error: a
/// clean close is connection loss (I/O), a torn frame means the stream
/// is desynchronized (protocol).
pub fn classify_recv(e: FrameError) -> FleetError {
    match e {
        FrameError::Closed(m) => FleetError::Io(m),
        FrameError::Torn(m) => FleetError::Protocol(m),
    }
}

/// One network attempt per call — connect (incl. protocol handshake),
/// frame send, frame receive. The caller owns the retry loop and the
/// `(op, attempt)` coordinates.
pub trait NetIo: Send + Sync {
    /// One connect attempt: TCP connect + protocol handshake.
    fn connect(&self, addr: &str, op: u64, attempt: u32) -> Result<TcpStream, FleetError>;

    /// One frame-send attempt (length prefix + payload + flush).
    fn send_frame(
        &self,
        stream: &mut TcpStream,
        payload: &[u8],
        op: u64,
        attempt: u32,
    ) -> Result<(), FleetError>;

    /// One frame-receive attempt into a reused buffer. EOF while a
    /// reply is owed is an error (classified), never a partial frame.
    fn recv_frame(
        &self,
        stream: &mut TcpStream,
        buf: &mut Vec<u8>,
        op: u64,
        attempt: u32,
    ) -> Result<(), FleetError>;
}

fn direct_connect(addr: &str) -> Result<TcpStream, FleetError> {
    let mut stream = TcpStream::connect(addr).map_err(|e| FleetError::Io(e.to_string()))?;
    stream.set_nodelay(true).ok();
    client_handshake(&mut stream).map_err(|e| FleetError::Protocol(format!("{e:#}")))?;
    Ok(stream)
}

fn direct_send(stream: &mut TcpStream, payload: &[u8]) -> Result<(), FleetError> {
    write_frame(stream, payload).map_err(|e| FleetError::Io(format!("{e:#}")))
}

fn direct_recv(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Result<(), FleetError> {
    match read_frame_into(stream, buf) {
        Ok(true) => Ok(()),
        Ok(false) => Err(FleetError::Io("connection closed while waiting for a reply".into())),
        Err(e) => Err(classify_recv(e)),
    }
}

/// Production network I/O: straight to the framing layer, ignoring the
/// schedule coordinates. No fault-plan checks on any path.
pub struct DirectNet;

impl NetIo for DirectNet {
    fn connect(&self, addr: &str, _op: u64, _attempt: u32) -> Result<TcpStream, FleetError> {
        direct_connect(addr)
    }

    fn send_frame(
        &self,
        stream: &mut TcpStream,
        payload: &[u8],
        _op: u64,
        _attempt: u32,
    ) -> Result<(), FleetError> {
        direct_send(stream, payload)
    }

    fn recv_frame(
        &self,
        stream: &mut TcpStream,
        buf: &mut Vec<u8>,
        _op: u64,
        _attempt: u32,
    ) -> Result<(), FleetError> {
        direct_recv(stream, buf)
    }
}

/// Fault-injecting network I/O: consults the plan before every attempt.
pub struct FaultyNet {
    plan: FaultPlan,
}

impl FaultyNet {
    pub fn new(plan: FaultPlan) -> FaultyNet {
        FaultyNet { plan }
    }
}

impl NetIo for FaultyNet {
    fn connect(&self, addr: &str, op: u64, attempt: u32) -> Result<TcpStream, FleetError> {
        match self.plan.connect_fault(op, attempt) {
            None => direct_connect(addr),
            Some(NetFault::Drop(msg)) => {
                Err(FleetError::Io(format!("{msg} ({addr}, op {op} attempt {attempt})")))
            }
            Some(NetFault::Stall(d)) => {
                std::thread::sleep(d);
                direct_connect(addr)
            }
            Some(NetFault::Torn(_)) => unreachable!("connects are never torn"),
        }
    }

    fn send_frame(
        &self,
        stream: &mut TcpStream,
        payload: &[u8],
        op: u64,
        attempt: u32,
    ) -> Result<(), FleetError> {
        if let Some(d) = self.plan.net_stall(op) {
            std::thread::sleep(d);
        }
        match self.plan.frame_write_fault(op, attempt) {
            None => direct_send(stream, payload),
            Some(NetFault::Drop(msg)) => {
                stream.shutdown(Shutdown::Both).ok();
                Err(FleetError::Io(format!("{msg} (op {op} attempt {attempt})")))
            }
            Some(NetFault::Torn(frac)) => {
                // a real half-delivered write: the length prefix
                // promises everything, a prefix of the payload follows,
                // the stream dies — and the send call REPORTS SUCCESS.
                // The peer sees mid-frame EOF; the caller discovers the
                // loss only at the reply read.
                let n = ((payload.len() as f64 * frac) as usize).min(payload.len());
                let _ = stream.write_all(&(payload.len() as u32).to_le_bytes());
                let _ = stream.write_all(&payload[..n]);
                let _ = stream.flush();
                stream.shutdown(Shutdown::Both).ok();
                Ok(())
            }
            Some(NetFault::Stall(d)) => {
                std::thread::sleep(d);
                direct_send(stream, payload)
            }
        }
    }

    fn recv_frame(
        &self,
        stream: &mut TcpStream,
        buf: &mut Vec<u8>,
        op: u64,
        attempt: u32,
    ) -> Result<(), FleetError> {
        match self.plan.frame_read_fault(op, attempt) {
            None => direct_recv(stream, buf),
            Some(NetFault::Drop(msg)) => {
                // the reply is lost in flight: the connection drops
                // before the frame lands — the canonical AMBIGUOUS
                // failure (the server may or may not have applied the
                // request), which is exactly what idempotency stamps
                // make safe to retry
                stream.shutdown(Shutdown::Both).ok();
                Err(FleetError::Io(format!("{msg} (op {op} attempt {attempt})")))
            }
            Some(NetFault::Stall(d)) => {
                std::thread::sleep(d);
                direct_recv(stream, buf)
            }
            Some(NetFault::Torn(_)) => unreachable!("receive faults are drops or stalls"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;
    use std::net::TcpListener;

    #[test]
    fn classification_maps_closed_to_io_and_torn_to_protocol() {
        assert!(matches!(
            classify_recv(FrameError::Closed("x".into())),
            FleetError::Io(_)
        ));
        assert!(matches!(
            classify_recv(FrameError::Torn("x".into())),
            FleetError::Protocol(_)
        ));
    }

    #[test]
    fn torn_send_reports_success_but_peer_sees_mid_frame_eof() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut conn, _) = listener.accept().unwrap();
            // read whatever arrives until EOF; must be SHORTER than the
            // promised frame
            let mut got = Vec::new();
            conn.read_to_end(&mut got).unwrap();
            got
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        // find a seeded torn decision and inject it
        let plan = FaultPlan::net_seeded(11);
        let io = FaultyNet::new(plan.clone());
        let torn_op = (0..10_000u64)
            .find(|&op| matches!(plan.frame_write_fault(op, 0), Some(NetFault::Torn(_))))
            .expect("a chaotic net plan torn-frame op");
        let payload = vec![0xAB; 64];
        io.send_frame(&mut stream, &payload, torn_op, 0).expect("torn send 'succeeds'");
        let got = server.join().unwrap();
        assert!(got.len() >= 4, "the length prefix always goes out");
        let promised = u32::from_le_bytes(got[..4].try_into().unwrap()) as usize;
        assert_eq!(promised, payload.len(), "the prefix promises the FULL payload");
        assert!(got.len() - 4 < payload.len(), "the payload itself is truncated");
        // a receive on the dead stream classifies as an error, never a
        // partial frame
        let mut buf = Vec::new();
        assert!(io.recv_frame(&mut stream, &mut buf, 0, 0).is_err());
    }

    #[test]
    fn dropped_connect_errors_without_touching_the_network() {
        let plan = FaultPlan::net_seeded(7);
        let io = FaultyNet::new(plan.clone());
        let op = (0..10_000u64)
            .find(|&op| plan.connect_fault(op, 0).is_some())
            .expect("a chaotic net plan connect fault");
        // an address that would hang/fail if actually dialed — the
        // injected refusal must fire first
        match io.connect("203.0.113.1:1", op, 0) {
            Err(FleetError::Io(m)) => assert!(m.contains("injected connect failure"), "{m}"),
            other => panic!("expected injected Io error, got {other:?}"),
        }
    }
}
