//! Network ingress for the sharded fleet: wire codec, framed protocol,
//! TCP shard server, and the client half of the connection.
//!
//! Layering, bottom up:
//!
//! - [`wire`] — little-endian scalar codec ([`wire::Writer`] /
//!   [`wire::Reader`]) shared with the on-disk snapshot format, plus
//!   [`wire::fnv1a64`];
//! - [`frame`] — the versioned, length-prefixed request/reply protocol
//!   ([`frame::Request`], [`frame::Reply`]) with a magic + version
//!   handshake and strict decode (unknown ops and trailing bytes are
//!   errors, not warnings);
//! - [`server`] — [`server::ShardServer`]: a TCP accept loop feeding a
//!   [`crate::fleet::ServingSession`], one handler thread per
//!   connection, compute staying on the shared exec pool;
//! - [`chaos`] — the [`chaos::NetIo`] shim every client socket op goes
//!   through: [`chaos::DirectNet`] in production (no fault-plan checks
//!   at all), [`chaos::FaultyNet`] under a seeded
//!   [`crate::fleet::FaultPlan`] (deterministic torn frames, dropped
//!   connections, stalls);
//! - [`client`] — [`client::RemoteClient`]: one connection to one
//!   shard, connect retry/backoff via [`crate::fleet::RetryPolicy`],
//!   idempotency-stamped mutations with exactly-once retry semantics,
//!   implementing the same [`crate::fleet::api::FleetApi`] trait as the
//!   in-process [`crate::fleet::api::LocalClient`].
//!
//! Tenant routing across many shards (hashing, pins, live migration,
//! pressure-driven rebalancing, failover) lives one level up in
//! [`crate::fleet::shard`]; shard process supervision in
//! [`crate::fleet::supervisor`].

pub mod chaos;
pub mod client;
pub mod frame;
pub mod server;
pub mod wire;

pub use chaos::{DirectNet, FaultyNet, NetIo};
pub use client::RemoteClient;
pub use frame::{FrameError, Reply, Request, ShardStats, Stamp, TenantHeat, PROTOCOL_VERSION};
pub use server::ShardServer;
