//! [`ShardServer`]: real network ingress for one fleet shard.
//!
//! One TCP accept loop feeds an open-ended
//! [`crate::fleet::ServingSession`] on an in-process
//! [`FleetServer`]. Each accepted connection gets a dedicated OS
//! handler thread — deliberately NOT a pool task, because a connection
//! handler blocks on socket reads for its whole lifetime and would
//! starve the bounded exec pool; all actual compute (training workers,
//! frozen sweeps, eval) stays on the shared pool exactly as in
//! offline serving.
//!
//! Worker-count determinism carries over unchanged: the session uses
//! the same worker loop, stamping and coalescing as
//! [`FleetServer::run`], so a 1-shard network serve over a tenant's
//! event order produces bit-identical tenant state to the offline
//! driver (pinned by `rust/tests/shard.rs`).
//!
//! Migration protocol, shard side: `Drain` quiesces the tenant (all
//! stamped events applied), evicts it through the same path the
//! governor's cold tier uses, and ships the versioned snapshot bytes
//! back in one frame; `Restore` decodes + revalidates and adopts the
//! tenant into a fresh slot. The router above
//! ([`crate::fleet::shard::FleetClient`]) sequences drain → restore so
//! a tenant is never live on two shards.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::fleet::api::{wait_quiesced, FleetError};
use crate::fleet::server::{FleetConfig, FleetReport, FleetServer, InferRequest, ServingSession, Submitted};
use crate::fleet::tenant::TenantId;
use crate::fleet::{snapshot, traffic};
use crate::runtime::{Dataset, SharedBackend};
use crate::telemetry::{Counter, EventKind, Gauge, LANE_NONE, TENANT_NONE};

use super::frame::{
    recv_request, send_reply, server_handshake, Reply, Request, ShardStats, TenantHeat,
};

/// Shared state every connection handler sees.
struct ShardState {
    fleet: Arc<FleetServer>,
    /// `None` once serving has finished (post-shutdown stragglers get a
    /// clean error instead of a panic).
    session: Mutex<Option<ServingSession>>,
    ds: Arc<Dataset>,
    init_images: Vec<f32>,
    init_labels: Vec<i32>,
    /// global tenant id -> shard-local slot
    gmap: Mutex<BTreeMap<u64, TenantId>>,
    shard_index: u32,
    addr: SocketAddr,
    stop: AtomicBool,
}

/// One shard process: a bound listener plus the serving fleet behind it.
pub struct ShardServer {
    listener: TcpListener,
    state: Arc<ShardState>,
}

impl ShardServer {
    /// Build the fleet, embed the shared init pool, start the serving
    /// session, and bind the listener (use port 0 for an ephemeral
    /// port; read it back with [`ShardServer::local_addr`]).
    pub fn bind(
        be: SharedBackend,
        ds: Arc<Dataset>,
        cfg: FleetConfig,
        shard_index: u32,
        workers: usize,
        addr: &str,
    ) -> Result<ShardServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding shard on {addr}"))?;
        let local = listener.local_addr().context("reading bound shard address")?;
        let fleet = Arc::new(FleetServer::new(be, cfg)?);
        let (init_images, init_labels) = traffic::init_pool(&ds);
        let session = fleet.start_session(workers);
        Ok(ShardServer {
            listener,
            state: Arc::new(ShardState {
                fleet,
                session: Mutex::new(Some(session)),
                ds,
                init_images,
                init_labels,
                gmap: Mutex::new(BTreeMap::new()),
                shard_index,
                addr: local,
                stop: AtomicBool::new(false),
            }),
        })
    }

    /// The address the listener actually bound.
    pub fn local_addr(&self) -> SocketAddr {
        self.state.addr
    }

    /// The fleet behind this shard (tests and embedders).
    pub fn fleet(&self) -> &Arc<FleetServer> {
        &self.state.fleet
    }

    /// Run the accept loop until a `Shutdown` frame, then drain the
    /// serving session and return its report. Holds the telemetry
    /// install guard for the whole serve so kernel- and pool-level
    /// spans land in this shard's sink.
    pub fn serve(self) -> Result<FleetReport> {
        let _tm_guard = self.state.fleet.install_telemetry();
        let mut handlers = Vec::new();
        for conn in self.listener.incoming() {
            if self.state.stop.load(Ordering::SeqCst) {
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("[shard {}] accept error: {e}", self.state.shard_index);
                    continue;
                }
            };
            let state = self.state.clone();
            handlers.push(std::thread::spawn(move || handle_connection(&state, stream)));
        }
        for h in handlers {
            let _ = h.join();
        }
        let session = self
            .state
            .session
            .lock()
            .unwrap()
            .take()
            .context("serving session already finished")?;
        session.finish()
    }
}

/// Per-connection loop: handshake, then request/reply until EOF.
fn handle_connection(state: &ShardState, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    if let Err(e) = server_handshake(&mut stream) {
        eprintln!("[shard {}] handshake failed: {e:#}", state.shard_index);
        return;
    }
    loop {
        let req = match recv_request(&mut stream) {
            Ok(Some(req)) => req,
            Ok(None) => return, // clean EOF: client hung up
            Err(e) => {
                eprintln!("[shard {}] bad frame: {e:#}", state.shard_index);
                return;
            }
        };
        let t0 = Instant::now();
        let op = req.op();
        let shutting_down = matches!(req, Request::Shutdown);
        let reply = match dispatch(state, req) {
            Ok(reply) => reply,
            Err(e) => Reply::Err(e),
        };
        let tm = &state.fleet.config().telemetry;
        tm.event_ns(
            EventKind::Frame,
            op as u64,
            TENANT_NONE,
            LANE_NONE,
            t0.elapsed().as_nanos() as u64,
            op as u64,
            0,
        );
        tm.counter_add(Counter::FramesServed, 1);
        tm.gauge_set(Gauge::ShardTenants, state.gmap.lock().unwrap().len() as u64);
        if send_reply(&mut stream, &reply).is_err() {
            return; // client went away mid-reply
        }
        if shutting_down {
            // wake the accept loop (it is parked in accept()) with a
            // throwaway self-connection, then let this handler exit
            state.stop.store(true, Ordering::SeqCst);
            let _ = TcpStream::connect(state.addr);
            return;
        }
    }
}

fn resolve(state: &ShardState, tenant: u64) -> Result<TenantId, FleetError> {
    state
        .gmap
        .lock()
        .unwrap()
        .get(&tenant)
        .copied()
        .ok_or(FleetError::UnknownTenant { tenant })
}

/// Execute one request against the shard's fleet. Every failure maps
/// onto a [`FleetError`] variant, which the wire carries losslessly.
fn dispatch(state: &ShardState, req: Request) -> Result<Reply, FleetError> {
    match req {
        Request::Admit { tenant, cfg } => {
            let mut gmap = state.gmap.lock().unwrap();
            if gmap.contains_key(&tenant) {
                return Err(FleetError::Admission(format!("tenant {tenant} already admitted")));
            }
            let id = state
                .fleet
                .admit(cfg, &state.init_images, &state.init_labels)
                .map_err(|e| FleetError::Admission(format!("{e:#}")))?;
            gmap.insert(tenant, id);
            Ok(Reply::Admitted { tenant })
        }
        Request::Submit { tenant, images, labels } => {
            let id = resolve(state, tenant)?;
            let session = state.session.lock().unwrap();
            let session = session
                .as_ref()
                .ok_or_else(|| FleetError::Internal("serving session already finished".into()))?;
            match session.submit_event(id, images, labels).map_err(FleetError::internal)? {
                Submitted::Enqueued => Ok(Reply::Queued),
                Submitted::Shed { retry_after_ms } => Ok(Reply::Rejected { retry_after_ms }),
            }
        }
        Request::Infer { tenant, rows, images } => {
            let id = resolve(state, tenant)?;
            let data = state
                .fleet
                .infer_batch(&[InferRequest { tenant: id, images: &images }])
                .map_err(FleetError::internal)?
                .pop()
                .unwrap_or_default();
            let classes = (data.len() / (rows.max(1) as usize)) as u32;
            Ok(Reply::Logits { rows, classes, data })
        }
        Request::Eval { tenant } => {
            let id = resolve(state, tenant)?;
            wait_quiesced(&state.fleet, id)?;
            let value = state
                .fleet
                .evaluate_tenant(&state.ds, id)
                .map_err(FleetError::internal)?;
            Ok(Reply::Accuracy { value })
        }
        Request::Drain { tenant } => {
            let id = resolve(state, tenant)?;
            wait_quiesced(&state.fleet, id)?;
            let snap = state.fleet.evict(id).map_err(FleetError::internal)?;
            state.gmap.lock().unwrap().remove(&tenant);
            state.fleet.config().telemetry.counter_add(Counter::Migrations, 1);
            Ok(Reply::Snapshot { bytes: snapshot::encode(&snap) })
        }
        Request::Restore { tenant, snapshot: bytes } => {
            let mut gmap = state.gmap.lock().unwrap();
            if gmap.contains_key(&tenant) {
                return Err(FleetError::Admission(format!("tenant {tenant} already resident")));
            }
            let snap =
                snapshot::decode(&bytes).map_err(|e| FleetError::Protocol(format!("{e:#}")))?;
            let id = state.fleet.restore(snap).map_err(FleetError::internal)?;
            gmap.insert(tenant, id);
            state.fleet.config().telemetry.counter_add(Counter::Migrations, 1);
            Ok(Reply::Ok)
        }
        Request::Stats => Ok(Reply::Stats(shard_stats(state))),
        Request::Shutdown => Ok(Reply::Ok),
    }
}

/// Assemble the rebalancer's world view of this shard.
fn shard_stats(state: &ShardState) -> ShardStats {
    let gmap = state.gmap.lock().unwrap();
    let rev: BTreeMap<TenantId, u64> = gmap.iter().map(|(&g, &l)| (l, g)).collect();
    let heat = state.fleet.tenant_heat();
    let mut tenants = Vec::with_capacity(heat.len());
    let (mut resident, mut spilled) = (0u64, 0u64);
    for (local, last_active, is_resident) in heat {
        if is_resident {
            resident += 1;
        } else {
            spilled += 1;
        }
        // slots not owned by a remote tenant (e.g. mid-drain) are
        // invisible to the rebalancer
        if let Some(&tenant) = rev.get(&local) {
            tenants.push(TenantHeat { tenant, last_active, resident: is_resident });
        }
    }
    ShardStats {
        shard: state.shard_index,
        resident,
        spilled,
        bytes_in_use: state.fleet.bytes_in_use() as u64,
        budget_bytes: state.fleet.budget_bytes() as u64,
        sheds: state.fleet.sheds(),
        events_done: state.fleet.events_applied(),
        tenants,
    }
}
