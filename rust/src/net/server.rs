//! [`ShardServer`]: real network ingress for one fleet shard.
//!
//! One TCP accept loop feeds an open-ended
//! [`crate::fleet::ServingSession`] on an in-process
//! [`FleetServer`]. Each accepted connection gets a dedicated OS
//! handler thread — deliberately NOT a pool task, because a connection
//! handler blocks on socket reads for its whole lifetime and would
//! starve the bounded exec pool; all actual compute (training workers,
//! frozen sweeps, eval) stays on the shared pool exactly as in
//! offline serving.
//!
//! Worker-count determinism carries over unchanged: the session uses
//! the same worker loop, stamping and coalescing as
//! [`FleetServer::run`], so a 1-shard network serve over a tenant's
//! event order produces bit-identical tenant state to the offline
//! driver (pinned by `rust/tests/shard.rs`).
//!
//! **Exactly-once ingress.** Stamped mutations (Admit/Submit/Restore
//! carrying a nonzero `(client_id, seq)`) pass through a bounded
//! per-`(client, tenant)` dedup window before they apply: a re-sent
//! stamp — the client's retry after an ambiguous timeout — is
//! acknowledged as [`Reply::Duplicate`] and applied exactly once.
//! Only *successful* applies are recorded; a shed or errored request
//! leaves no trace, so the client's retry genuinely re-attempts it.
//!
//! **Crash-safe migration, shard side.** `Drain` quiesces the tenant,
//! evicts it through the cold-tier path, and ships the snapshot bytes
//! back — but the shard keeps a *tombstoned* copy (in memory, and as a
//! `tenant_g<id>.tomb` file published with the snapshot module's
//! atomic tmp+fsync+rename when a spill dir is configured) until the
//! client confirms the destination committed with `MigrateCommit`.
//! A repeated `Drain` returns the tombstone again; `MigrateAbort`
//! resurrects the tenant from it. A shard that crashes mid-migration
//! re-adopts `.tomb` files on startup — tombstoned, not live — so the
//! client's resolution (commit or abort) still lands correctly and no
//! tenant is ever live on two shards or lost on none.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::fleet::api::{wait_quiesced, FleetError};
use crate::fleet::server::{FleetConfig, FleetReport, FleetServer, InferRequest, ServingSession, Submitted};
use crate::fleet::tenant::TenantId;
use crate::fleet::{snapshot, traffic};
use crate::runtime::{Dataset, SharedBackend};
use crate::telemetry::{Counter, EventKind, Gauge, LANE_NONE, TENANT_NONE};

use super::frame::{
    recv_request, send_reply, server_handshake, Reply, Request, ShardStats, Stamp, TenantHeat,
};

/// Out-of-order seqs tracked per `(client, tenant)` before the window
/// starts folding its floor forward (a bounded-memory guarantee, not a
/// correctness boundary — in-order retries never get near it).
const DEDUP_WINDOW_CAP: usize = 1024;

/// One client's dedup window for one tenant: everything `<= floor` was
/// applied; entries above the floor are individually tracked, `false`
/// while the apply is still in flight, `true` once it succeeded.
#[derive(Default)]
struct SeqWindow {
    floor: u64,
    seen: BTreeMap<u64, bool>,
}

impl SeqWindow {
    /// Record intent to apply `seq`. Returns true when the stamp was
    /// seen before (duplicate — do not apply).
    fn claim(&mut self, seq: u64) -> bool {
        if seq <= self.floor || self.seen.contains_key(&seq) {
            return true;
        }
        self.seen.insert(seq, false);
        false
    }

    /// The apply succeeded: make the claim permanent and compact
    /// settled runs into the floor.
    fn settle(&mut self, seq: u64) {
        if let Some(done) = self.seen.get_mut(&seq) {
            *done = true;
        }
        while self.seen.get(&(self.floor + 1)).copied() == Some(true) {
            self.seen.remove(&(self.floor + 1));
            self.floor += 1;
        }
        // bounded memory: beyond the cap, fold the oldest entries into
        // the floor (a false-duplicate is only possible for a seq this
        // far out of order, which a sequential client never produces)
        while self.seen.len() > DEDUP_WINDOW_CAP {
            let (&lo, _) = self.seen.iter().next().expect("non-empty over cap");
            self.seen.remove(&lo);
            self.floor = self.floor.max(lo);
        }
    }

    /// The apply failed or was shed: forget the claim entirely so a
    /// retry of the same stamp re-attempts the operation.
    fn unclaim(&mut self, seq: u64) {
        self.seen.remove(&seq);
    }
}

/// Shared state every connection handler sees.
struct ShardState {
    fleet: Arc<FleetServer>,
    /// `None` once serving has finished (post-shutdown stragglers get a
    /// clean error instead of a panic).
    session: Mutex<Option<ServingSession>>,
    ds: Arc<Dataset>,
    init_images: Vec<f32>,
    init_labels: Vec<i32>,
    /// global tenant id -> shard-local slot
    gmap: Mutex<BTreeMap<u64, TenantId>>,
    /// `(client_id, tenant)` -> dedup window for stamped mutations
    dedup: Mutex<BTreeMap<(u64, u64), SeqWindow>>,
    /// mid-migration tenants: drained, awaiting commit/abort
    tombs: Mutex<BTreeMap<u64, Vec<u8>>>,
    /// total frames served — the scripted-crash trigger's clock
    frames_served: AtomicU64,
    shard_index: u32,
    addr: SocketAddr,
    stop: AtomicBool,
}

impl ShardState {
    fn tomb_path(&self, tenant: u64) -> Option<PathBuf> {
        self.fleet
            .config()
            .spill_dir
            .as_ref()
            .map(|dir| dir.join(format!("tenant_g{tenant}.tomb")))
    }
}

/// One shard process: a bound listener plus the serving fleet behind it.
pub struct ShardServer {
    listener: TcpListener,
    state: Arc<ShardState>,
}

impl ShardServer {
    /// Build the fleet, embed the shared init pool, start the serving
    /// session, and bind the listener (use port 0 for an ephemeral
    /// port; read it back with [`ShardServer::local_addr`]). Any
    /// `tenant_g<id>.tomb` files in the spill dir — mid-migration state
    /// left by a crashed predecessor — are adopted as tombstones.
    pub fn bind(
        be: SharedBackend,
        ds: Arc<Dataset>,
        cfg: FleetConfig,
        shard_index: u32,
        workers: usize,
        addr: &str,
    ) -> Result<ShardServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding shard on {addr}"))?;
        let local = listener.local_addr().context("reading bound shard address")?;
        let tombs = adopt_tombstones(cfg.spill_dir.as_deref())?;
        let fleet = Arc::new(FleetServer::new(be, cfg)?);
        let (init_images, init_labels) = traffic::init_pool(&ds);
        let session = fleet.start_session(workers);
        Ok(ShardServer {
            listener,
            state: Arc::new(ShardState {
                fleet,
                session: Mutex::new(Some(session)),
                ds,
                init_images,
                init_labels,
                gmap: Mutex::new(BTreeMap::new()),
                dedup: Mutex::new(BTreeMap::new()),
                tombs: Mutex::new(tombs),
                frames_served: AtomicU64::new(0),
                shard_index,
                addr: local,
                stop: AtomicBool::new(false),
            }),
        })
    }

    /// The address the listener actually bound.
    pub fn local_addr(&self) -> SocketAddr {
        self.state.addr
    }

    /// The fleet behind this shard (tests and embedders).
    pub fn fleet(&self) -> &Arc<FleetServer> {
        &self.state.fleet
    }

    /// Tenants currently tombstoned on this shard (tests).
    pub fn tombstoned(&self) -> Vec<u64> {
        self.state.tombs.lock().unwrap().keys().copied().collect()
    }

    /// Run the accept loop until a `Shutdown` frame, then drain the
    /// serving session and return its report. Holds the telemetry
    /// install guard for the whole serve so kernel- and pool-level
    /// spans land in this shard's sink.
    pub fn serve(self) -> Result<FleetReport> {
        let _tm_guard = self.state.fleet.install_telemetry();
        let mut handlers = Vec::new();
        for conn in self.listener.incoming() {
            if self.state.stop.load(Ordering::SeqCst) {
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("[shard {}] accept error: {e}", self.state.shard_index);
                    continue;
                }
            };
            let state = self.state.clone();
            handlers.push(std::thread::spawn(move || handle_connection(&state, stream)));
        }
        for h in handlers {
            let _ = h.join();
        }
        let session = self
            .state
            .session
            .lock()
            .unwrap()
            .take()
            .context("serving session already finished")?;
        session.finish()
    }
}

/// Scan a spill dir for `tenant_g<id>.tomb` files left by a crashed
/// predecessor mid-migration. They come back TOMBSTONED — never live —
/// so the client's commit/abort resolution still applies cleanly.
fn adopt_tombstones(spill_dir: Option<&std::path::Path>) -> Result<BTreeMap<u64, Vec<u8>>> {
    let mut tombs = BTreeMap::new();
    let Some(dir) = spill_dir else { return Ok(tombs) };
    if !dir.exists() {
        return Ok(tombs);
    }
    for entry in std::fs::read_dir(dir).with_context(|| format!("scanning {}", dir.display()))? {
        let path = entry?.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        let Some(id) = name.strip_prefix("tenant_g").and_then(|s| s.strip_suffix(".tomb")) else {
            continue;
        };
        let Ok(tenant) = id.parse::<u64>() else { continue };
        let bytes =
            std::fs::read(&path).with_context(|| format!("adopting tombstone {}", path.display()))?;
        // revalidate before trusting: a torn tombstone (the crash hit
        // mid-publish — impossible with the atomic rename, but disks
        // lie) must not resurrect a corrupt tenant later
        snapshot::decode(&bytes)
            .with_context(|| format!("tombstone {} failed validation", path.display()))?;
        tombs.insert(tenant, bytes);
    }
    Ok(tombs)
}

/// Per-connection loop: handshake, then request/reply until EOF.
fn handle_connection(state: &ShardState, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    if let Err(e) = server_handshake(&mut stream) {
        eprintln!("[shard {}] handshake failed: {e:#}", state.shard_index);
        return;
    }
    loop {
        let req = match recv_request(&mut stream) {
            Ok(Some(req)) => req,
            Ok(None) => return, // clean EOF: client hung up
            Err(e) => {
                eprintln!("[shard {}] bad frame: {e:#}", state.shard_index);
                return;
            }
        };
        let t0 = Instant::now();
        let op = req.op();
        let shutting_down = matches!(req, Request::Shutdown);
        let reply = match dispatch(state, req) {
            Ok(reply) => reply,
            Err(e) => Reply::Err(e),
        };
        let tm = &state.fleet.config().telemetry;
        tm.event_ns(
            EventKind::Frame,
            op as u64,
            TENANT_NONE,
            LANE_NONE,
            t0.elapsed().as_nanos() as u64,
            op as u64,
            0,
        );
        tm.counter_add(Counter::FramesServed, 1);
        tm.gauge_set(Gauge::ShardTenants, state.gmap.lock().unwrap().len() as u64);
        // scripted shard death: AFTER the request applied, BEFORE the
        // reply leaves — the nastiest spot (the client sees an ambiguous
        // timeout; only stamps + tombstones make the retry safe). Fires
        // only in processes whose fault plan scripts a crash.
        let served = state.frames_served.fetch_add(1, Ordering::SeqCst) + 1;
        if state.fleet.config().faults.crash_due(served) {
            eprintln!(
                "[shard {}] injected crash after {served} frames",
                state.shard_index
            );
            std::process::exit(9);
        }
        if send_reply(&mut stream, &reply).is_err() {
            return; // client went away mid-reply
        }
        if shutting_down {
            // wake the accept loop (it is parked in accept()) with a
            // throwaway self-connection, then let this handler exit
            state.stop.store(true, Ordering::SeqCst);
            let _ = TcpStream::connect(state.addr);
            return;
        }
    }
}

fn resolve(state: &ShardState, tenant: u64) -> Result<TenantId, FleetError> {
    state
        .gmap
        .lock()
        .unwrap()
        .get(&tenant)
        .copied()
        .ok_or(FleetError::UnknownTenant { tenant })
}

/// Claim a stamp before applying. `true` = duplicate, do not apply.
fn dedup_claim(state: &ShardState, stamp: &Stamp, tenant: u64) -> bool {
    state
        .dedup
        .lock()
        .unwrap()
        .entry((stamp.client_id, tenant))
        .or_default()
        .claim(stamp.seq)
}

/// Resolve a stamped apply: settle the claim on success, drop it on
/// failure or shed (so the client's retry genuinely re-attempts).
fn dedup_resolve(state: &ShardState, stamp: &Stamp, tenant: u64, applied: bool) {
    let mut dedup = state.dedup.lock().unwrap();
    if let Some(win) = dedup.get_mut(&(stamp.client_id, tenant)) {
        if applied {
            win.settle(stamp.seq);
        } else {
            win.unclaim(stamp.seq);
        }
    }
}

/// Run one stamped mutation through the dedup window: duplicate stamps
/// short-circuit to [`Reply::Duplicate`]; otherwise the claim is
/// settled only when the apply genuinely succeeded (a shed or error is
/// forgotten — retries must re-attempt).
fn with_dedup(
    state: &ShardState,
    stamp: Stamp,
    tenant: u64,
    apply: impl FnOnce() -> Result<Reply, FleetError>,
) -> Result<Reply, FleetError> {
    if !stamp.is_stamped() {
        return apply();
    }
    if dedup_claim(state, &stamp, tenant) {
        state.fleet.config().telemetry.counter_add(Counter::Duplicates, 1);
        return Ok(Reply::Duplicate);
    }
    let result = apply();
    let applied = matches!(
        &result,
        Ok(Reply::Ok | Reply::Admitted { .. } | Reply::Queued | Reply::Snapshot { .. })
    );
    dedup_resolve(state, &stamp, tenant, applied);
    result
}

/// Publish a tombstone for a drained tenant: durable file first (when a
/// spill dir exists), then the in-memory registry.
fn publish_tombstone(state: &ShardState, tenant: u64, bytes: &[u8]) -> Result<(), FleetError> {
    if let Some(path) = state.tomb_path(tenant) {
        snapshot::write_bytes(&path, bytes)
            .map_err(|e| FleetError::Internal(format!("publishing tombstone: {e:#}")))?;
    }
    state.tombs.lock().unwrap().insert(tenant, bytes.to_vec());
    Ok(())
}

/// Drop a tombstone (commit, or abort after resurrection): registry
/// first, then the durable file. Absent entries are fine — idempotent.
fn clear_tombstone(state: &ShardState, tenant: u64) {
    state.tombs.lock().unwrap().remove(&tenant);
    if let Some(path) = state.tomb_path(tenant) {
        let _ = std::fs::remove_file(path);
    }
}

/// Execute one request against the shard's fleet. Every failure maps
/// onto a [`FleetError`] variant, which the wire carries losslessly.
fn dispatch(state: &ShardState, req: Request) -> Result<Reply, FleetError> {
    match req {
        Request::Admit { tenant, stamp, cfg } => with_dedup(state, stamp, tenant, || {
            let mut gmap = state.gmap.lock().unwrap();
            if gmap.contains_key(&tenant) {
                return Err(FleetError::Admission(format!("tenant {tenant} already admitted")));
            }
            let id = state
                .fleet
                .admit(cfg, &state.init_images, &state.init_labels)
                .map_err(|e| FleetError::Admission(format!("{e:#}")))?;
            gmap.insert(tenant, id);
            Ok(Reply::Admitted { tenant })
        }),
        Request::Submit { tenant, stamp, images, labels } => {
            with_dedup(state, stamp, tenant, move || {
                let id = resolve(state, tenant)?;
                let session = state.session.lock().unwrap();
                let session = session
                    .as_ref()
                    .ok_or_else(|| FleetError::Internal("serving session already finished".into()))?;
                match session.submit_event(id, images, labels).map_err(FleetError::internal)? {
                    Submitted::Enqueued => Ok(Reply::Queued),
                    Submitted::Shed { retry_after_ms } => Ok(Reply::Rejected { retry_after_ms }),
                }
            })
        }
        Request::Infer { tenant, rows, images } => {
            let id = resolve(state, tenant)?;
            let data = state
                .fleet
                .infer_batch(&[InferRequest { tenant: id, images: &images }])
                .map_err(FleetError::internal)?
                .pop()
                .unwrap_or_default();
            let classes = (data.len() / (rows.max(1) as usize)) as u32;
            Ok(Reply::Logits { rows, classes, data })
        }
        Request::Eval { tenant } => {
            let id = resolve(state, tenant)?;
            wait_quiesced(&state.fleet, id)?;
            let value = state
                .fleet
                .evaluate_tenant(&state.ds, id)
                .map_err(FleetError::internal)?;
            Ok(Reply::Accuracy { value })
        }
        Request::Drain { tenant } => {
            // idempotent re-drain: a tombstoned tenant's snapshot IS the
            // answer (the client's retry after an ambiguous timeout)
            if let Some(bytes) = state.tombs.lock().unwrap().get(&tenant).cloned() {
                return Ok(Reply::Snapshot { bytes });
            }
            let id = resolve(state, tenant)?;
            wait_quiesced(&state.fleet, id)?;
            let snap = state.fleet.evict(id).map_err(FleetError::internal)?;
            let bytes = snapshot::encode(&snap);
            // tombstone BEFORE the routing entry goes: between the two
            // the tenant exists in both registries, never in neither
            if let Err(e) = publish_tombstone(state, tenant, &bytes) {
                // the durable handoff failed — undo the evict so the
                // tenant stays live here rather than in limbo
                let snap = snapshot::decode(&bytes).map_err(FleetError::internal)?;
                let id = state.fleet.restore(snap).map_err(FleetError::internal)?;
                state.gmap.lock().unwrap().insert(tenant, id);
                return Err(e);
            }
            state.gmap.lock().unwrap().remove(&tenant);
            state.fleet.config().telemetry.counter_add(Counter::Migrations, 1);
            Ok(Reply::Snapshot { bytes })
        }
        Request::Restore { tenant, stamp, snapshot: bytes } => {
            with_dedup(state, stamp, tenant, move || {
                let mut gmap = state.gmap.lock().unwrap();
                if gmap.contains_key(&tenant) {
                    return Err(FleetError::Admission(format!("tenant {tenant} already resident")));
                }
                let snap =
                    snapshot::decode(&bytes).map_err(|e| FleetError::Protocol(format!("{e:#}")))?;
                let id = state.fleet.restore(snap).map_err(FleetError::internal)?;
                gmap.insert(tenant, id);
                state.fleet.config().telemetry.counter_add(Counter::Migrations, 1);
                Ok(Reply::Ok)
            })
        }
        Request::MigrateCommit { tenant } => {
            // the destination holds the tenant — this copy is history.
            // Idempotent: clearing an absent tombstone is still Ok.
            clear_tombstone(state, tenant);
            Ok(Reply::Ok)
        }
        Request::MigrateAbort { tenant } => {
            // idempotent: already live again means a previous abort won
            if state.gmap.lock().unwrap().contains_key(&tenant) {
                return Ok(Reply::Ok);
            }
            let bytes = state
                .tombs
                .lock()
                .unwrap()
                .get(&tenant)
                .cloned()
                .ok_or(FleetError::UnknownTenant { tenant })?;
            let snap =
                snapshot::decode(&bytes).map_err(|e| FleetError::Internal(format!("{e:#}")))?;
            let id = state.fleet.restore(snap).map_err(FleetError::internal)?;
            state.gmap.lock().unwrap().insert(tenant, id);
            clear_tombstone(state, tenant);
            Ok(Reply::Ok)
        }
        Request::Ping => Ok(Reply::Ok),
        Request::Stats => Ok(Reply::Stats(shard_stats(state))),
        Request::Shutdown => Ok(Reply::Ok),
    }
}

/// Assemble the rebalancer's world view of this shard.
fn shard_stats(state: &ShardState) -> ShardStats {
    let gmap = state.gmap.lock().unwrap();
    let rev: BTreeMap<TenantId, u64> = gmap.iter().map(|(&g, &l)| (l, g)).collect();
    let heat = state.fleet.tenant_heat();
    let mut tenants = Vec::with_capacity(heat.len());
    let (mut resident, mut spilled) = (0u64, 0u64);
    for (local, last_active, is_resident) in heat {
        if is_resident {
            resident += 1;
        } else {
            spilled += 1;
        }
        // slots not owned by a remote tenant (e.g. mid-drain) are
        // invisible to the rebalancer
        if let Some(&tenant) = rev.get(&local) {
            tenants.push(TenantHeat { tenant, last_active, resident: is_resident });
        }
    }
    ShardStats {
        shard: state.shard_index,
        resident,
        spilled,
        bytes_in_use: state.fleet.bytes_in_use() as u64,
        budget_bytes: state.fleet.budget_bytes() as u64,
        sheds: state.fleet.sheds(),
        events_done: state.fleet.events_applied(),
        tenants,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seq_window_dedups_settled_claims() {
        let mut w = SeqWindow::default();
        assert!(!w.claim(1));
        w.settle(1);
        assert_eq!(w.floor, 1);
        assert!(w.claim(1), "settled seq is a duplicate");
        assert!(!w.claim(2));
        assert!(w.claim(2), "pending claim already counts as seen");
    }

    #[test]
    fn seq_window_forgets_unclaimed_applies() {
        let mut w = SeqWindow::default();
        assert!(!w.claim(1));
        w.unclaim(1); // the apply failed / was shed
        assert!(!w.claim(1), "a forgotten claim can be re-attempted");
        w.settle(1);
        assert!(w.claim(1));
    }

    #[test]
    fn seq_window_floor_compacts_in_order_runs() {
        let mut w = SeqWindow::default();
        for seq in 1..=100u64 {
            assert!(!w.claim(seq));
            w.settle(seq);
        }
        assert_eq!(w.floor, 100);
        assert!(w.seen.is_empty(), "in-order traffic stores nothing");
        assert!(w.claim(50), "everything under the floor is a duplicate");
    }

    #[test]
    fn seq_window_out_of_order_gap_tracked_until_filled() {
        let mut w = SeqWindow::default();
        assert!(!w.claim(2));
        w.settle(2);
        assert_eq!(w.floor, 0, "the gap at 1 holds the floor");
        assert!(!w.claim(1));
        w.settle(1);
        assert_eq!(w.floor, 2, "filling the gap compacts both");
    }

    #[test]
    fn seq_window_cap_folds_floor_forward() {
        let mut w = SeqWindow::default();
        // all even seqs: every entry is a gap, nothing compacts
        for i in 0..(DEDUP_WINDOW_CAP as u64 + 10) {
            let seq = 2 * (i + 1);
            assert!(!w.claim(seq));
            w.settle(seq);
        }
        assert!(w.seen.len() <= DEDUP_WINDOW_CAP, "memory stays bounded");
        assert!(w.floor > 0, "the floor absorbed the overflow");
    }
}
