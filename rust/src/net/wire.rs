//! Little-endian scalar codec shared by the tenant-snapshot format and
//! the shard wire protocol.
//!
//! This is the byte-level substrate both `fleet::snapshot` and
//! `net::frame` are written against: fixed-width little-endian scalars,
//! length-prefixed strings, and a bounds-checked reader that reports
//! truncation *before* any allocation is attempted. Factoring it out of
//! the snapshot module (where it was born) means a snapshot travelling
//! inside a migration frame and a snapshot on the spill disk are encoded
//! by the very same code — there is exactly one place byte order can be
//! wrong.
//!
//! The codec is format-agnostic: framing, magic numbers, versioning and
//! checksums stay in the callers. Only [`fnv1a64`] lives here because
//! both the snapshot header and the protocol tests use it.

use anyhow::{ensure, Context, Result};

/// FNV-1a 64 — cheap, dependency-free corruption detection (bit flips,
/// short writes, concatenated garbage).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Append-only little-endian scalar writer.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    /// Wrap an existing buffer for reuse: contents cleared, capacity
    /// retained — the zero-alloc encode path.
    pub fn reuse(mut buf: Vec<u8>) -> Writer {
        buf.clear();
        Writer { buf }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// u32 length prefix + UTF-8 bytes.
    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Raw bytes, no length prefix — the caller owns the framing.
    pub fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }
}

/// Bounds-checked little-endian scalar reader over a borrowed buffer.
pub struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    pub fn new(b: &'a [u8]) -> Reader<'a> {
        Reader { b, i: 0 }
    }

    /// Bytes consumed so far.
    pub fn pos(&self) -> usize {
        self.i
    }

    /// Bytes left to consume.
    pub fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.i + n <= self.b.len(),
            "truncated buffer: wanted {} bytes at offset {}, have {}",
            n,
            self.i,
            self.b.len() - self.i
        );
        let out = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        ensure!(n <= 4096, "string length {n} implausible");
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).context("string is not utf-8")
    }

    /// Bounded length prefix: any count exceeding the bytes that remain
    /// is corruption, reported before a huge allocation is attempted.
    pub fn len_bounded(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u64()? as usize;
        ensure!(
            n.checked_mul(elem_bytes).is_some_and(|b| b <= self.b.len() - self.i),
            "truncated buffer: length prefix {n} exceeds remaining payload"
        );
        Ok(n)
    }

    /// Every byte must have been consumed — trailing garbage is a
    /// framing error, not padding.
    pub fn finish(&self) -> Result<()> {
        ensure!(
            self.i == self.b.len(),
            "{} trailing bytes after the last field",
            self.b.len() - self.i
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_round_trip_bit_exactly() {
        let mut w = Writer::new();
        w.u8(0xAB);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 7);
        w.i32(-123_456);
        w.f32(f32::from_bits(0x7FC0_0001)); // a specific NaN payload
        w.f64(-0.0);
        w.str("tenant/0");
        w.bytes(&[1, 2, 3]);
        let buf = w.into_vec();
        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 0xAB);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 7);
        assert_eq!(r.i32().unwrap(), -123_456);
        assert_eq!(r.f32().unwrap().to_bits(), 0x7FC0_0001);
        assert_eq!(r.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.str().unwrap(), "tenant/0");
        assert_eq!(r.take(3).unwrap(), &[1, 2, 3]);
        r.finish().unwrap();
    }

    #[test]
    fn truncation_is_reported_not_panicked() {
        let mut w = Writer::new();
        w.u64(42);
        let buf = w.into_vec();
        let mut r = Reader::new(&buf[..5]);
        assert!(r.u64().unwrap_err().to_string().contains("truncated"));
    }

    #[test]
    fn length_prefix_beyond_payload_is_rejected_before_allocation() {
        let mut w = Writer::new();
        w.u64(u64::MAX); // promises ~2^64 elements
        let buf = w.into_vec();
        let mut r = Reader::new(&buf);
        assert!(r.len_bounded(4).is_err());
    }

    #[test]
    fn trailing_bytes_fail_finish() {
        let mut w = Writer::new();
        w.u32(1);
        w.u8(0);
        let buf = w.into_vec();
        let mut r = Reader::new(&buf);
        r.u32().unwrap();
        assert!(r.finish().unwrap_err().to_string().contains("trailing"));
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // reference values for the 64-bit FNV-1a parameters
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
