//! The shard wire protocol: length-prefixed, versioned binary frames.
//!
//! A connection starts with an 8-byte handshake (`b"TCFL"` magic + u32
//! protocol version, echoed back by the server) and then carries frames
//! in both directions:
//!
//! ```text
//! [0..4)  payload length u32 (little-endian, <= MAX_FRAME_BYTES)
//! [4..)   payload: op/code byte + body ([`crate::net::wire`] scalars)
//! ```
//!
//! Requests (client → shard). The three *mutating* ops (Admit, Submit,
//! Restore) carry an idempotency [`Stamp`] — `(client_id, seq)` right
//! after the tenant id — so a retry after an ambiguous timeout is safe:
//! the shard's bounded dedup window acknowledges a re-delivered stamp
//! with [`Reply::Duplicate`] instead of applying it twice.
//!
//! | op | frame         | body                                         |
//! |----|---------------|----------------------------------------------|
//! | 1  | Admit         | tenant u64, client_id u64, seq u64, n_lr u64, lr_bits u8, lr f32, epochs u64, seed u64 |
//! | 2  | Submit        | tenant u64, client_id u64, seq u64, rows u32, labels i32×rows, images len u64 + f32s |
//! | 3  | Infer         | tenant u64, rows u32, images len u64 + f32s  |
//! | 4  | Eval          | tenant u64                                   |
//! | 5  | Drain         | tenant u64 (quiesce + evict → tombstoned snapshot bytes) |
//! | 6  | Restore       | tenant u64, client_id u64, seq u64, snapshot len u64 + bytes |
//! | 7  | Stats         | —                                            |
//! | 8  | Shutdown      | —                                            |
//! | 9  | Ping          | — (supervisor heartbeat; replies Ok)         |
//! | 10 | MigrateCommit | tenant u64 (restore committed on B → drop A's tombstone) |
//! | 11 | MigrateAbort  | tenant u64 (migration failed → resurrect from A's tombstone) |
//!
//! Replies (shard → client) carry a code byte that maps 1:1 onto
//! [`FleetError`] variants for the error half of the space:
//!
//! | code | reply     | body                                           |
//! |------|-----------|------------------------------------------------|
//! | 0    | Ok        | —                                              |
//! | 1    | Admitted  | tenant u64                                     |
//! | 2    | Queued    | —                                              |
//! | 3    | Rejected  | retry_after_ms u64 (the shedding-ladder quote) |
//! | 4    | Logits    | rows u32, classes u32, f32×(rows·classes)      |
//! | 5    | Accuracy  | f64                                            |
//! | 6    | Snapshot  | len u64 + snapshot bytes                       |
//! | 7    | Stats     | see [`ShardStats`]                             |
//! | 14   | Duplicate | — (stamp already applied; success, not error)  |
//! | 8..  | Err       | [`FleetError`] by wire code (see `FleetError::code`) |
//!
//! Tenant ids on the wire are **global** u64s; each shard maps them onto
//! local slot ids internally, so a migrated tenant keeps its identity
//! across hosts. Frames are strict: trailing bytes after the last field
//! are a protocol error, and any frame longer than [`MAX_FRAME_BYTES`]
//! is rejected before allocation. A receive failure is *classified*
//! ([`FrameError`]): EOF before any byte of a frame is an ordinary
//! connection close, EOF mid-frame means the stream is torn and must be
//! abandoned — no partially-decoded frame ever escapes.

use std::io::{Read, Write};

use anyhow::{bail, ensure, Context, Result};

use crate::fleet::api::FleetError;
use crate::fleet::TenantConfig;
use crate::net::wire::{Reader, Writer};

/// Connection preamble magic: "TinyCl FLeet".
pub const PROTOCOL_MAGIC: [u8; 4] = *b"TCFL";

/// Wire protocol version. Bump on any frame-layout change; a version
/// mismatch is detected at handshake, before any frame is parsed.
/// v2: idempotency stamps on Admit/Submit/Restore, Ping/MigrateCommit/
/// MigrateAbort ops, Duplicate reply.
pub const PROTOCOL_VERSION: u32 = 2;

/// Hard cap on a single frame's payload. Large enough for a full-profile
/// tenant snapshot inside a migration frame, small enough that a
/// corrupted length prefix cannot trigger a giant allocation.
pub const MAX_FRAME_BYTES: usize = 256 << 20;

const OP_ADMIT: u8 = 1;
const OP_SUBMIT: u8 = 2;
const OP_INFER: u8 = 3;
const OP_EVAL: u8 = 4;
const OP_DRAIN: u8 = 5;
const OP_RESTORE: u8 = 6;
const OP_STATS: u8 = 7;
const OP_SHUTDOWN: u8 = 8;
const OP_PING: u8 = 9;
const OP_MIGRATE_COMMIT: u8 = 10;
const OP_MIGRATE_ABORT: u8 = 11;

const CODE_OK: u8 = 0;
const CODE_ADMITTED: u8 = 1;
const CODE_QUEUED: u8 = 2;
const CODE_REJECTED: u8 = 3;
const CODE_LOGITS: u8 = 4;
const CODE_ACCURACY: u8 = 5;
const CODE_SNAPSHOT: u8 = 6;
const CODE_STATS: u8 = 7;
// 8..=13 are FleetError wire codes (see FleetError::code); 14 is back
// in the SUCCESS space: the stamp was seen before and the original
// application stands
const CODE_DUPLICATE: u8 = 14;

/// Idempotency stamp on the mutating ops: `(client_id, seq)` uniquely
/// names one *logical* mutation, so a network-level re-delivery (the
/// retry after an ambiguous timeout) is recognizable. `seq` is
/// per-`(client, tenant)` monotonic; `client_id` 0 with `seq` 0 is the
/// "unstamped" escape hatch (dedup bypassed — local clients).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Stamp {
    pub client_id: u64,
    pub seq: u64,
}

impl Stamp {
    pub fn new(client_id: u64, seq: u64) -> Stamp {
        Stamp { client_id, seq }
    }

    /// True when this stamp participates in deduplication.
    pub fn is_stamped(&self) -> bool {
        self.client_id != 0 || self.seq != 0
    }
}

/// A client request frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Provision a tenant on this shard (the shard embeds its own
    /// pre-deployment init pool — only the config travels). Stamped.
    Admit { tenant: u64, stamp: Stamp, cfg: TenantConfig },
    /// One training event: `rows` images with their labels. Stamped.
    Submit { tenant: u64, stamp: Stamp, images: Vec<f32>, labels: Vec<i32> },
    /// Forward `rows` images through frozen + adaptive stages.
    Infer { tenant: u64, rows: u32, images: Vec<f32> },
    /// Test-set accuracy after all queued events have applied.
    Eval { tenant: u64 },
    /// Quiesce + evict: the tenant leaves this shard as snapshot bytes
    /// (migration phase 1). The shard keeps a tombstoned copy until the
    /// client confirms with MigrateCommit — a repeated Drain of a
    /// tombstoned tenant returns the tombstone bytes again (idempotent).
    Drain { tenant: u64 },
    /// Install a drained tenant from snapshot bytes (migration phase
    /// 2). Stamped.
    Restore { tenant: u64, stamp: Stamp, snapshot: Vec<u8> },
    /// Shard-level pressure + per-tenant heat, for the rebalancer.
    Stats,
    /// Finish serving: the shard drains its session and exits.
    Shutdown,
    /// Supervisor heartbeat: liveness probe, replies Ok. Read-only.
    Ping,
    /// Migration resolved: Restore committed on the destination — the
    /// source drops its tombstone. Idempotent (absent tombstone → Ok).
    MigrateCommit { tenant: u64 },
    /// Migration failed partway: resurrect the tenant from the source's
    /// tombstone. Idempotent (already live → Ok).
    MigrateAbort { tenant: u64 },
}

impl Request {
    /// This request's wire op code (telemetry keys, logs).
    pub fn op(&self) -> u8 {
        match self {
            Request::Admit { .. } => OP_ADMIT,
            Request::Submit { .. } => OP_SUBMIT,
            Request::Infer { .. } => OP_INFER,
            Request::Eval { .. } => OP_EVAL,
            Request::Drain { .. } => OP_DRAIN,
            Request::Restore { .. } => OP_RESTORE,
            Request::Stats => OP_STATS,
            Request::Shutdown => OP_SHUTDOWN,
            Request::Ping => OP_PING,
            Request::MigrateCommit { .. } => OP_MIGRATE_COMMIT,
            Request::MigrateAbort { .. } => OP_MIGRATE_ABORT,
        }
    }
}

/// One tenant's heat record inside [`ShardStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TenantHeat {
    /// Global tenant id.
    pub tenant: u64,
    /// Logical-clock tick of the last event (larger = hotter).
    pub last_active: u64,
    /// false = spilled to the shard's cold tier.
    pub resident: bool,
}

/// Shard-level load report: the rebalancer's entire world view.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ShardStats {
    /// This shard's index in the fleet.
    pub shard: u32,
    /// RAM-resident tenants.
    pub resident: u64,
    /// Cold (disk-spilled) tenants.
    pub spilled: u64,
    /// Governor RAM charge in bytes.
    pub bytes_in_use: u64,
    /// Governor budget in bytes.
    pub budget_bytes: u64,
    /// Events shed since serving began.
    pub sheds: u64,
    /// Events applied since serving began.
    pub events_done: u64,
    /// Per-tenant heat, hottest data the rebalancer needs.
    pub tenants: Vec<TenantHeat>,
}

impl ShardStats {
    /// Governor pressure: RAM charge over budget.
    pub fn pressure(&self) -> f64 {
        if self.budget_bytes == 0 {
            return 0.0;
        }
        self.bytes_in_use as f64 / self.budget_bytes as f64
    }
}

/// A shard reply frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Reply {
    Ok,
    Admitted { tenant: u64 },
    Queued,
    /// Shed by admission control; retry after exactly this many ms (the
    /// server's shedding-ladder quote).
    Rejected { retry_after_ms: u64 },
    Logits { rows: u32, classes: u32, data: Vec<f32> },
    Accuracy { value: f64 },
    Snapshot { bytes: Vec<u8> },
    Stats(ShardStats),
    /// The request's stamp was applied before — acknowledged as a
    /// success (the original application stands), distinguished from
    /// Ok so clients and tests can see the dedup window working.
    Duplicate,
    Err(FleetError),
}

// ---- payload codec ---------------------------------------------------------

/// Encode a request payload (no length prefix — `write_frame` adds it).
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut buf = Vec::new();
    encode_request_into(req, &mut buf);
    buf
}

/// Encode a request payload into a reused buffer — the hot-path
/// variant: `buf` is cleared and refilled in place, so a client that
/// owns a scratch buffer allocates nothing at steady state.
pub fn encode_request_into(req: &Request, buf: &mut Vec<u8>) {
    let mut w = Writer::reuse(std::mem::take(buf));
    match req {
        Request::Admit { tenant, stamp, cfg } => {
            w.u8(OP_ADMIT);
            w.u64(*tenant);
            w.u64(stamp.client_id);
            w.u64(stamp.seq);
            w.u64(cfg.n_lr as u64);
            w.u8(cfg.lr_bits);
            w.f32(cfg.lr);
            w.u64(cfg.epochs as u64);
            w.u64(cfg.seed);
        }
        Request::Submit { tenant, stamp, images, labels } => {
            w.u8(OP_SUBMIT);
            w.u64(*tenant);
            w.u64(stamp.client_id);
            w.u64(stamp.seq);
            w.u32(labels.len() as u32);
            for &l in labels {
                w.i32(l);
            }
            w.u64(images.len() as u64);
            for &v in images {
                w.f32(v);
            }
        }
        Request::Infer { tenant, rows, images } => {
            w.u8(OP_INFER);
            w.u64(*tenant);
            w.u32(*rows);
            w.u64(images.len() as u64);
            for &v in images {
                w.f32(v);
            }
        }
        Request::Eval { tenant } => {
            w.u8(OP_EVAL);
            w.u64(*tenant);
        }
        Request::Drain { tenant } => {
            w.u8(OP_DRAIN);
            w.u64(*tenant);
        }
        Request::Restore { tenant, stamp, snapshot } => {
            w.u8(OP_RESTORE);
            w.u64(*tenant);
            w.u64(stamp.client_id);
            w.u64(stamp.seq);
            w.u64(snapshot.len() as u64);
            w.bytes(snapshot);
        }
        Request::Stats => w.u8(OP_STATS),
        Request::Shutdown => w.u8(OP_SHUTDOWN),
        Request::Ping => w.u8(OP_PING),
        Request::MigrateCommit { tenant } => {
            w.u8(OP_MIGRATE_COMMIT);
            w.u64(*tenant);
        }
        Request::MigrateAbort { tenant } => {
            w.u8(OP_MIGRATE_ABORT);
            w.u64(*tenant);
        }
    }
    *buf = w.into_vec();
}

/// Decode a request payload. Strict: trailing bytes are an error.
pub fn decode_request(payload: &[u8]) -> Result<Request> {
    let mut r = Reader::new(payload);
    let op = r.u8().context("empty request frame")?;
    let req = match op {
        OP_ADMIT => {
            let tenant = r.u64()?;
            let stamp = Stamp { client_id: r.u64()?, seq: r.u64()? };
            let cfg = TenantConfig {
                n_lr: r.u64()? as usize,
                lr_bits: r.u8()?,
                lr: r.f32()?,
                epochs: r.u64()? as usize,
                seed: r.u64()?,
            };
            Request::Admit { tenant, stamp, cfg }
        }
        OP_SUBMIT => {
            let tenant = r.u64()?;
            let stamp = Stamp { client_id: r.u64()?, seq: r.u64()? };
            let rows = r.u32()? as usize;
            ensure!(
                rows.checked_mul(4).is_some_and(|b| b <= payload.len()),
                "submit frame label count {rows} exceeds the frame"
            );
            let mut labels = Vec::with_capacity(rows);
            for _ in 0..rows {
                labels.push(r.i32()?);
            }
            let n = r.len_bounded(4)?;
            let mut images = Vec::with_capacity(n);
            for _ in 0..n {
                images.push(r.f32()?);
            }
            Request::Submit { tenant, stamp, images, labels }
        }
        OP_INFER => {
            let tenant = r.u64()?;
            let rows = r.u32()?;
            let n = r.len_bounded(4)?;
            let mut images = Vec::with_capacity(n);
            for _ in 0..n {
                images.push(r.f32()?);
            }
            Request::Infer { tenant, rows, images }
        }
        OP_EVAL => Request::Eval { tenant: r.u64()? },
        OP_DRAIN => Request::Drain { tenant: r.u64()? },
        OP_RESTORE => {
            let tenant = r.u64()?;
            let stamp = Stamp { client_id: r.u64()?, seq: r.u64()? };
            let n = r.len_bounded(1)?;
            let snapshot = r.take(n)?.to_vec();
            Request::Restore { tenant, stamp, snapshot }
        }
        OP_STATS => Request::Stats,
        OP_SHUTDOWN => Request::Shutdown,
        OP_PING => Request::Ping,
        OP_MIGRATE_COMMIT => Request::MigrateCommit { tenant: r.u64()? },
        OP_MIGRATE_ABORT => Request::MigrateAbort { tenant: r.u64()? },
        other => bail!("unknown request op {other} (protocol version skew?)"),
    };
    r.finish().context("request frame has trailing bytes")?;
    Ok(req)
}

/// Encode a reply payload (no length prefix — `write_frame` adds it).
pub fn encode_reply(reply: &Reply) -> Vec<u8> {
    let mut w = Writer::new();
    match reply {
        Reply::Ok => w.u8(CODE_OK),
        Reply::Admitted { tenant } => {
            w.u8(CODE_ADMITTED);
            w.u64(*tenant);
        }
        Reply::Queued => w.u8(CODE_QUEUED),
        Reply::Rejected { retry_after_ms } => {
            w.u8(CODE_REJECTED);
            w.u64(*retry_after_ms);
        }
        Reply::Logits { rows, classes, data } => {
            w.u8(CODE_LOGITS);
            w.u32(*rows);
            w.u32(*classes);
            for &v in data {
                w.f32(v);
            }
        }
        Reply::Accuracy { value } => {
            w.u8(CODE_ACCURACY);
            w.f64(*value);
        }
        Reply::Snapshot { bytes } => {
            w.u8(CODE_SNAPSHOT);
            w.u64(bytes.len() as u64);
            w.bytes(bytes);
        }
        Reply::Stats(s) => {
            w.u8(CODE_STATS);
            w.u32(s.shard);
            w.u64(s.resident);
            w.u64(s.spilled);
            w.u64(s.bytes_in_use);
            w.u64(s.budget_bytes);
            w.u64(s.sheds);
            w.u64(s.events_done);
            w.u32(s.tenants.len() as u32);
            for t in &s.tenants {
                w.u64(t.tenant);
                w.u64(t.last_active);
                w.u8(t.resident as u8);
            }
        }
        Reply::Duplicate => w.u8(CODE_DUPLICATE),
        Reply::Err(e) => {
            w.u8(e.code());
            match e {
                // Overloaded shares the Rejected wire shape: code 3 +
                // quote — one byte pattern, two Rust-side views
                FleetError::Overloaded { retry_after_ms } => w.u64(*retry_after_ms),
                FleetError::ShardDown { retry_after_ms } => w.u64(*retry_after_ms),
                FleetError::UnknownTenant { tenant } => w.u64(*tenant),
                FleetError::Admission(m)
                | FleetError::Protocol(m)
                | FleetError::Io(m)
                | FleetError::Internal(m)
                | FleetError::Config(m) => w.str(clip(m)),
            }
        }
    }
    w.into_vec()
}

/// Decode a reply payload. Strict: trailing bytes are an error.
pub fn decode_reply(payload: &[u8]) -> Result<Reply> {
    let mut r = Reader::new(payload);
    let code = r.u8().context("empty reply frame")?;
    let reply = match code {
        CODE_OK => Reply::Ok,
        CODE_ADMITTED => Reply::Admitted { tenant: r.u64()? },
        CODE_QUEUED => Reply::Queued,
        CODE_REJECTED => Reply::Rejected { retry_after_ms: r.u64()? },
        CODE_LOGITS => {
            let rows = r.u32()?;
            let classes = r.u32()?;
            let n = (rows as usize)
                .checked_mul(classes as usize)
                .filter(|&n| n.checked_mul(4).is_some_and(|b| b <= payload.len()))
                .ok_or_else(|| anyhow::anyhow!("logits frame geometry implausible"))?;
            let mut data = Vec::with_capacity(n);
            for _ in 0..n {
                data.push(r.f32()?);
            }
            Reply::Logits { rows, classes, data }
        }
        CODE_ACCURACY => Reply::Accuracy { value: r.f64()? },
        CODE_SNAPSHOT => {
            let n = r.len_bounded(1)?;
            Reply::Snapshot { bytes: r.take(n)?.to_vec() }
        }
        CODE_STATS => {
            let shard = r.u32()?;
            let resident = r.u64()?;
            let spilled = r.u64()?;
            let bytes_in_use = r.u64()?;
            let budget_bytes = r.u64()?;
            let sheds = r.u64()?;
            let events_done = r.u64()?;
            let n = r.u32()? as usize;
            ensure!(
                n.checked_mul(17).is_some_and(|b| b <= payload.len()),
                "stats frame tenant count {n} exceeds the frame"
            );
            let mut tenants = Vec::with_capacity(n);
            for _ in 0..n {
                tenants.push(TenantHeat {
                    tenant: r.u64()?,
                    last_active: r.u64()?,
                    resident: r.u8()? != 0,
                });
            }
            Reply::Stats(ShardStats {
                shard,
                resident,
                spilled,
                bytes_in_use,
                budget_bytes,
                sheds,
                events_done,
                tenants,
            })
        }
        CODE_DUPLICATE => Reply::Duplicate,
        code => {
            let err = match code {
                c if c == FleetError::CODE_UNKNOWN_TENANT => {
                    FleetError::UnknownTenant { tenant: r.u64()? }
                }
                c if c == FleetError::CODE_ADMISSION => FleetError::Admission(r.str()?),
                c if c == FleetError::CODE_PROTOCOL => FleetError::Protocol(r.str()?),
                c if c == FleetError::CODE_IO => FleetError::Io(r.str()?),
                c if c == FleetError::CODE_INTERNAL => FleetError::Internal(r.str()?),
                c if c == FleetError::CODE_CONFIG => FleetError::Config(r.str()?),
                c if c == FleetError::CODE_SHARD_DOWN => {
                    FleetError::ShardDown { retry_after_ms: r.u64()? }
                }
                other => bail!("unknown reply code {other} (protocol version skew?)"),
            };
            Reply::Err(err)
        }
    };
    r.finish().context("reply frame has trailing bytes")?;
    Ok(reply)
}

/// Clip an error message to the codec's 4096-byte string bound without
/// splitting a UTF-8 sequence.
fn clip(s: &str) -> &str {
    if s.len() <= 4096 {
        return s;
    }
    let mut end = 4096;
    while end > 0 && !s.is_char_boundary(end) {
        end -= 1;
    }
    &s[..end]
}

// ---- stream framing --------------------------------------------------------

/// Write one `[len u32][payload]` frame and flush.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    ensure!(
        payload.len() <= MAX_FRAME_BYTES,
        "frame of {} bytes exceeds MAX_FRAME_BYTES",
        payload.len()
    );
    w.write_all(&(payload.len() as u32).to_le_bytes()).context("writing frame length")?;
    w.write_all(payload).context("writing frame payload")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Why a frame receive failed — the classification the client needs to
/// map transport trouble onto the right [`FleetError`]: a peer that
/// died *mid-message* left the stream desynchronized (protocol-level:
/// the connection must be abandoned, no partial frame escapes), while
/// a clean close between frames is ordinary connection loss (I/O).
#[derive(Debug)]
pub enum FrameError {
    /// The connection closed or errored before any byte of this frame.
    Closed(String),
    /// The connection died after the frame started (partial length
    /// prefix, truncated payload, or an implausible length) — a torn
    /// frame; the stream must not be reused.
    Torn(String),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Closed(m) => write!(f, "connection closed: {m}"),
            FrameError::Torn(m) => write!(f, "torn frame: {m}"),
        }
    }
}

impl std::error::Error for FrameError {}

/// Read one frame into a reused buffer — the hot-path variant. `buf`
/// is resized in place (capacity retained across calls, so steady-state
/// receives allocate nothing). Returns `Ok(false)` on clean EOF before
/// a length prefix (no frame, `buf` untouched), `Ok(true)` with the
/// payload in `buf`; every failure is classified as [`FrameError`].
pub fn read_frame_into(
    r: &mut impl Read,
    buf: &mut Vec<u8>,
) -> std::result::Result<bool, FrameError> {
    let mut len_bytes = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        let n = match r.read(&mut len_bytes[got..]) {
            Ok(n) => n,
            Err(e) if got == 0 => return Err(FrameError::Closed(format!("{e}"))),
            Err(e) => {
                return Err(FrameError::Torn(format!(
                    "read error after {got}/4 length bytes: {e}"
                )))
            }
        };
        if n == 0 {
            if got == 0 {
                return Ok(false);
            }
            return Err(FrameError::Torn(format!(
                "connection closed mid-frame ({got}/4 length bytes)"
            )));
        }
        got += n;
    }
    let len = u32::from_le_bytes(len_bytes) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(FrameError::Torn(format!(
            "incoming frame of {len} bytes exceeds MAX_FRAME_BYTES"
        )));
    }
    buf.clear();
    buf.resize(len, 0);
    r.read_exact(buf)
        .map_err(|e| FrameError::Torn(format!("connection closed mid-payload: {e}")))?;
    Ok(true)
}

/// Read one frame. `Ok(None)` on clean EOF *before* a length prefix —
/// the peer closed between frames; EOF mid-frame is an error.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>> {
    let mut payload = Vec::new();
    match read_frame_into(r, &mut payload) {
        Ok(false) => Ok(None),
        Ok(true) => Ok(Some(payload)),
        Err(e) => Err(anyhow::anyhow!("{e}")),
    }
}

/// Send a request frame.
pub fn send_request(w: &mut impl Write, req: &Request) -> Result<()> {
    write_frame(w, &encode_request(req))
}

/// Receive a request frame; `Ok(None)` when the client hung up cleanly.
pub fn recv_request(r: &mut impl Read) -> Result<Option<Request>> {
    match read_frame(r)? {
        None => Ok(None),
        Some(payload) => Ok(Some(decode_request(&payload)?)),
    }
}

/// Send a reply frame.
pub fn send_reply(w: &mut impl Write, reply: &Reply) -> Result<()> {
    write_frame(w, &encode_reply(reply))
}

/// Receive a reply frame; EOF here is always an error (the server owed
/// us an answer).
pub fn recv_reply(r: &mut impl Read) -> Result<Reply> {
    match read_frame(r)? {
        None => bail!("connection closed while waiting for a reply"),
        Some(payload) => decode_reply(&payload),
    }
}

/// Client half of the preamble: send magic+version, expect the echo.
pub fn client_handshake(stream: &mut (impl Read + Write)) -> Result<()> {
    let mut hello = [0u8; 8];
    hello[..4].copy_from_slice(&PROTOCOL_MAGIC);
    hello[4..].copy_from_slice(&PROTOCOL_VERSION.to_le_bytes());
    stream.write_all(&hello).context("sending protocol hello")?;
    stream.flush().context("flushing protocol hello")?;
    let mut echo = [0u8; 8];
    stream.read_exact(&mut echo).context("reading protocol echo")?;
    ensure!(echo == hello, "server answered a different protocol/version: {echo:02x?}");
    Ok(())
}

/// Server half of the preamble: validate magic+version, echo it back.
pub fn server_handshake(stream: &mut (impl Read + Write)) -> Result<()> {
    let mut hello = [0u8; 8];
    stream.read_exact(&mut hello).context("reading protocol hello")?;
    ensure!(
        hello[..4] == PROTOCOL_MAGIC,
        "not a tinycl fleet client (bad magic {:02x?})",
        &hello[..4]
    );
    let version = u32::from_le_bytes(hello[4..8].try_into().unwrap());
    ensure!(
        version == PROTOCOL_VERSION,
        "unsupported protocol version {version} (this shard speaks {PROTOCOL_VERSION})"
    );
    stream.write_all(&hello).context("echoing protocol hello")?;
    stream.flush().context("flushing protocol echo")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip_request(req: Request) {
        let bytes = encode_request(&req);
        let back = decode_request(&bytes).unwrap();
        assert_eq!(back, req);
    }

    fn round_trip_reply(reply: Reply) {
        let bytes = encode_reply(&reply);
        let back = decode_reply(&bytes).unwrap();
        assert_eq!(back, reply);
    }

    #[test]
    fn every_request_round_trips() {
        round_trip_request(Request::Admit {
            tenant: 7,
            stamp: Stamp::new(11, 1),
            cfg: TenantConfig { n_lr: 96, lr_bits: 7, lr: 0.05, epochs: 2, seed: 41 },
        });
        round_trip_request(Request::Submit {
            tenant: u64::MAX,
            stamp: Stamp::new(u64::MAX, 42),
            images: vec![0.5, -1.5, 3.25],
            labels: vec![0, 4],
        });
        round_trip_request(Request::Infer { tenant: 3, rows: 2, images: vec![1.0; 8] });
        round_trip_request(Request::Eval { tenant: 0 });
        round_trip_request(Request::Drain { tenant: 12 });
        round_trip_request(Request::Restore {
            tenant: 12,
            stamp: Stamp::new(11, 7),
            snapshot: vec![1, 2, 3, 4, 5],
        });
        round_trip_request(Request::Stats);
        round_trip_request(Request::Shutdown);
        round_trip_request(Request::Ping);
        round_trip_request(Request::MigrateCommit { tenant: 9 });
        round_trip_request(Request::MigrateAbort { tenant: 9 });
        // the unstamped escape hatch survives the wire too
        round_trip_request(Request::Submit {
            tenant: 0,
            stamp: Stamp::default(),
            images: vec![],
            labels: vec![],
        });
        assert!(!Stamp::default().is_stamped());
        assert!(Stamp::new(1, 0).is_stamped());
    }

    #[test]
    fn every_reply_round_trips() {
        round_trip_reply(Reply::Ok);
        round_trip_reply(Reply::Admitted { tenant: 9 });
        round_trip_reply(Reply::Queued);
        round_trip_reply(Reply::Rejected { retry_after_ms: 64 });
        round_trip_reply(Reply::Logits { rows: 2, classes: 3, data: vec![0.0; 6] });
        round_trip_reply(Reply::Accuracy { value: 0.875 });
        round_trip_reply(Reply::Snapshot { bytes: vec![0xAA; 32] });
        round_trip_reply(Reply::Stats(ShardStats {
            shard: 1,
            resident: 3,
            spilled: 1,
            bytes_in_use: 1 << 20,
            budget_bytes: 4 << 20,
            sheds: 2,
            events_done: 40,
            tenants: vec![
                TenantHeat { tenant: 5, last_active: 17, resident: true },
                TenantHeat { tenant: 9, last_active: 3, resident: false },
            ],
        }));
        round_trip_reply(Reply::Duplicate);
        round_trip_reply(Reply::Err(FleetError::UnknownTenant { tenant: 5 }));
        round_trip_reply(Reply::Err(FleetError::Admission("full".into())));
        round_trip_reply(Reply::Err(FleetError::Protocol("bad op".into())));
        round_trip_reply(Reply::Err(FleetError::Io("disk".into())));
        round_trip_reply(Reply::Err(FleetError::Internal("bug".into())));
        round_trip_reply(Reply::Err(FleetError::Config("watermarks".into())));
        round_trip_reply(Reply::Err(FleetError::ShardDown { retry_after_ms: 50 }));
    }

    #[test]
    fn reused_encode_buffer_matches_the_allocating_path() {
        let reqs = [
            Request::Eval { tenant: 3 },
            Request::Submit {
                tenant: 1,
                stamp: Stamp::new(2, 9),
                images: vec![1.0, 2.0],
                labels: vec![4],
            },
            Request::Ping,
        ];
        let mut buf = Vec::new();
        for req in &reqs {
            encode_request_into(req, &mut buf);
            assert_eq!(buf, encode_request(req), "reused-buffer encode diverged");
        }
    }

    #[test]
    fn read_frame_into_classifies_clean_close_vs_torn() {
        // clean EOF before any frame → Ok(false)
        let mut empty = std::io::Cursor::new(Vec::new());
        let mut buf = Vec::new();
        assert!(!read_frame_into(&mut empty, &mut buf).unwrap());
        // partial length prefix → Torn
        let mut partial = std::io::Cursor::new(vec![5u8, 0]);
        match read_frame_into(&mut partial, &mut buf) {
            Err(FrameError::Torn(m)) => assert!(m.contains("2/4"), "{m}"),
            other => panic!("expected Torn, got {other:?}"),
        }
        // full prefix, truncated payload → Torn
        let mut torn = Vec::new();
        send_request(&mut torn, &Request::Eval { tenant: 3 }).unwrap();
        torn.truncate(torn.len() - 2);
        let mut cur = std::io::Cursor::new(torn);
        assert!(matches!(read_frame_into(&mut cur, &mut buf), Err(FrameError::Torn(_))));
        // implausible length prefix → Torn, before any allocation
        let mut huge = std::io::Cursor::new(u32::MAX.to_le_bytes().to_vec());
        match read_frame_into(&mut huge, &mut buf) {
            Err(FrameError::Torn(m)) => assert!(m.contains("MAX_FRAME_BYTES"), "{m}"),
            other => panic!("expected Torn, got {other:?}"),
        }
    }

    #[test]
    fn overloaded_error_shares_the_rejected_wire_shape() {
        let bytes = encode_reply(&Reply::Err(FleetError::Overloaded { retry_after_ms: 8 }));
        assert_eq!(decode_reply(&bytes).unwrap(), Reply::Rejected { retry_after_ms: 8 });
    }

    #[test]
    fn trailing_bytes_and_unknown_ops_are_rejected() {
        let mut bytes = encode_request(&Request::Eval { tenant: 1 });
        bytes.push(0);
        assert!(decode_request(&bytes).unwrap_err().to_string().contains("trailing"));
        assert!(decode_request(&[0xEE]).unwrap_err().to_string().contains("unknown request op"));
        assert!(decode_reply(&[0xEE]).unwrap_err().to_string().contains("unknown reply code"));
        assert!(decode_request(&[]).is_err());
    }

    #[test]
    fn stream_framing_round_trips_and_reports_clean_eof() {
        let mut buf = Vec::new();
        send_request(&mut buf, &Request::Stats).unwrap();
        send_reply(&mut buf, &Reply::Accuracy { value: 0.5 }).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        assert_eq!(recv_request(&mut cur).unwrap(), Some(Request::Stats));
        let payload = read_frame(&mut cur).unwrap().unwrap();
        assert_eq!(decode_reply(&payload).unwrap(), Reply::Accuracy { value: 0.5 });
        // clean EOF between frames → None, not an error
        assert_eq!(read_frame(&mut cur).unwrap(), None);
        // EOF inside a frame → error
        let mut torn = Vec::new();
        send_request(&mut torn, &Request::Eval { tenant: 3 }).unwrap();
        torn.truncate(torn.len() - 2);
        let mut cur = std::io::Cursor::new(torn);
        assert!(read_frame(&mut cur).is_err());
    }

    #[test]
    fn handshake_rejects_magic_and_version_skew() {
        // a well-formed hello echoes back
        let mut wire = std::io::Cursor::new(Vec::new());
        {
            let mut hello = [0u8; 8];
            hello[..4].copy_from_slice(&PROTOCOL_MAGIC);
            hello[4..].copy_from_slice(&PROTOCOL_VERSION.to_le_bytes());
            wire.get_mut().extend_from_slice(&hello);
        }
        server_handshake(&mut wire).unwrap();
        // bad magic
        let mut bad = std::io::Cursor::new(b"HTTP/1.1".to_vec());
        assert!(server_handshake(&mut bad).unwrap_err().to_string().contains("bad magic"));
        // future version
        let mut hello = [0u8; 8];
        hello[..4].copy_from_slice(&PROTOCOL_MAGIC);
        hello[4..].copy_from_slice(&9u32.to_le_bytes());
        let mut skew = std::io::Cursor::new(hello.to_vec());
        assert!(server_handshake(&mut skew)
            .unwrap_err()
            .to_string()
            .contains("unsupported protocol version 9"));
    }
}
