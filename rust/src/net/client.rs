//! [`RemoteClient`]: the client half of one shard connection.
//!
//! One TCP stream, one request in flight at a time (the protocol is
//! strictly request/reply), connect retry with the fleet's shared
//! [`RetryPolicy`] backoff curve. Implements [`FleetApi`], so code
//! written against the trait serves identically through an in-process
//! [`crate::fleet::api::LocalClient`] or across the wire.
//!
//! **Exactly-once mutations.** A client constructed with a nonzero
//! `client_id` stamps every Admit/Submit/Restore with a per-tenant
//! monotonic `(client_id, seq)` pair. That makes the ambiguous
//! failure — the connection died after the request left but before the
//! reply landed, so the server may or may not have applied it — safe
//! to resolve by retrying *with the same stamp*: the shard's dedup
//! window recognizes the re-delivery and acknowledges it as
//! [`Reply::Duplicate`] without applying twice. Read-only ops
//! (Infer/Eval/Stats/Ping) and the idempotent migration verbs
//! (Drain/MigrateCommit/MigrateAbort) are always safe to retry;
//! unstamped mutations are never retried (one attempt, old behavior).
//!
//! **Error discipline** (the classification contract): a connection
//! that dies *cleanly between frames* is connection loss —
//! [`FleetError::Io`]; a connection that dies *mid-frame* (short read
//! inside a length prefix or payload) means the stream is
//! desynchronized — [`FleetError::Protocol`]. No partially-decoded
//! reply is ever returned: frames are materialized in full before the
//! codec sees a byte. A decoded [`Reply::Err`] is returned verbatim —
//! the server's error IS the client's error, byte-coded through
//! [`FleetError::code`] — and is never retried (the server answered
//! authoritatively).
//!
//! All socket traffic goes through a [`NetIo`] shim (the network twin
//! of the spill tier's `SpillIo`), so a seeded [`FaultPlan`] can tear
//! frames, drop connections and stall sends deterministically; the
//! default [`DirectNet`] path has no plan checks at all.

use std::collections::BTreeMap;
use std::net::TcpStream;
use std::thread;
use std::time::Duration;

use crate::fleet::api::{FleetApi, FleetError};
use crate::fleet::faults::RetryPolicy;
use crate::fleet::tenant::TenantConfig;

use super::chaos::{DirectNet, NetIo};
use super::frame::{decode_reply, encode_request_into, Reply, Request, ShardStats, Stamp};

/// One connection to one shard process.
pub struct RemoteClient {
    io: Box<dyn NetIo>,
    stream: TcpStream,
    addr: String,
    retry: RetryPolicy,
    /// 0 = unstamped (dedup bypassed, mutations never retried).
    client_id: u64,
    /// Per-tenant next sequence number (monotonic from 1).
    seqs: BTreeMap<u64, u64>,
    /// Logical connect counter: the `op` coordinate for connect faults.
    connect_ops: u64,
    /// Logical request counter: the `op` coordinate for frame faults.
    frame_ops: u64,
    /// Attempts beyond the first, summed over all calls.
    net_retries: u64,
    /// Replies acknowledged as [`Reply::Duplicate`].
    duplicates: u64,
    /// Read/write timeout re-applied after every (re)connect.
    timeout: Option<Duration>,
    send_buf: Vec<u8>,
    recv_buf: Vec<u8>,
}

impl RemoteClient {
    /// Connect and handshake with the production io path and no
    /// stamping — the drop-in equivalent of the pre-dedup client.
    /// Retries refused connections on the policy's backoff curve
    /// (shard processes may still be binding when the client starts —
    /// the loopback race CI hits every run).
    pub fn connect(addr: &str, retry: &RetryPolicy) -> Result<RemoteClient, FleetError> {
        RemoteClient::connect_with(addr, retry, Box::new(DirectNet), 0)
    }

    /// Connect with an explicit io shim and client identity. A nonzero
    /// `client_id` turns on stamping: mutations become idempotent and
    /// ambiguous transport failures are retried with the same stamp.
    pub fn connect_with(
        addr: &str,
        retry: &RetryPolicy,
        io: Box<dyn NetIo>,
        client_id: u64,
    ) -> Result<RemoteClient, FleetError> {
        let stream = dial(io.as_ref(), addr, retry, 0)?;
        Ok(RemoteClient {
            io,
            stream,
            addr: addr.to_string(),
            retry: retry.clone(),
            client_id,
            seqs: BTreeMap::new(),
            connect_ops: 1,
            frame_ops: 0,
            net_retries: 0,
            duplicates: 0,
            timeout: None,
            send_buf: Vec::new(),
            recv_buf: Vec::new(),
        })
    }

    /// The address this client dialed.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// The stamping identity (0 = unstamped).
    pub fn client_id(&self) -> u64 {
        self.client_id
    }

    /// Transport retries performed so far (attempts beyond the first).
    pub fn net_retries(&self) -> u64 {
        self.net_retries
    }

    /// Replies the server acknowledged as duplicate re-deliveries.
    pub fn duplicates(&self) -> u64 {
        self.duplicates
    }

    /// Read/write timeout for every subsequent socket operation,
    /// surviving reconnects. The supervisor's heartbeat path — a hung
    /// shard must fail a ping, not block it forever.
    pub fn set_timeout(&mut self, d: Option<Duration>) -> Result<(), FleetError> {
        self.timeout = d;
        apply_timeout(&self.stream, d)
    }

    fn reconnect(&mut self) -> Result<(), FleetError> {
        let op = self.connect_ops;
        self.connect_ops += 1;
        let stream = dial(self.io.as_ref(), &self.addr, &self.retry, op)?;
        apply_timeout(&stream, self.timeout)?;
        self.stream = stream;
        Ok(())
    }

    /// Mint the next stamp for a mutating op on `tenant`.
    fn next_stamp(&mut self, tenant: u64) -> Stamp {
        if self.client_id == 0 {
            return Stamp::default();
        }
        let seq = self.seqs.entry(tenant).or_insert(0);
        *seq += 1;
        Stamp::new(self.client_id, *seq)
    }

    /// Can this request be re-sent after an ambiguous transport
    /// failure without risk of double application?
    fn retry_safe(req: &Request) -> bool {
        match req {
            // stamped mutations dedup server-side; unstamped must not retry
            Request::Admit { stamp, .. }
            | Request::Submit { stamp, .. }
            | Request::Restore { stamp, .. } => stamp.is_stamped(),
            // read-only
            Request::Infer { .. } | Request::Eval { .. } | Request::Stats | Request::Ping => true,
            // idempotent by construction: a tombstoned Drain returns the
            // tombstone again, Commit/Abort tolerate re-delivery
            Request::Drain { .. }
            | Request::MigrateCommit { .. }
            | Request::MigrateAbort { .. } => true,
            // one-way: the peer exits after replying
            Request::Shutdown => false,
        }
    }

    /// One send/recv/decode attempt over the current stream. The
    /// payload buffer is only decoded after a COMPLETE frame arrived.
    fn attempt(&mut self, op: u64, attempt: u32) -> Result<Reply, FleetError> {
        self.io.send_frame(&mut self.stream, &self.send_buf, op, attempt)?;
        self.io.recv_frame(&mut self.stream, &mut self.recv_buf, op, attempt)?;
        decode_reply(&self.recv_buf)
            .map_err(|e| FleetError::Protocol(format!("reply from {}: {e:#}", self.addr)))
    }

    /// One logical request: encode once, attempt up to `retry.attempts`
    /// times (retry-safe requests only), reconnecting after every
    /// transport failure. A decoded [`Reply::Err`] is authoritative and
    /// final — only transport/framing failures are retried.
    pub fn call(&mut self, req: &Request) -> Result<Reply, FleetError> {
        let op = self.frame_ops;
        self.frame_ops += 1;
        encode_request_into(req, &mut self.send_buf);
        let attempts = if Self::retry_safe(req) { self.retry.attempts.max(1) } else { 1 };
        let mut last: Option<FleetError> = None;
        for attempt in 0..attempts {
            if attempt > 0 {
                self.net_retries += 1;
                thread::sleep(self.retry.backoff(attempt));
                // the stream is dead or desynchronized after a failed
                // attempt — always start the retry on a fresh connection
                if let Err(e) = self.reconnect() {
                    return Err(last.unwrap_or(e));
                }
            }
            match self.attempt(op, attempt) {
                Ok(Reply::Err(e)) => return Err(e),
                Ok(Reply::Duplicate) => {
                    self.duplicates += 1;
                    return Ok(Reply::Duplicate);
                }
                Ok(other) => return Ok(other),
                Err(e) => last = Some(e),
            }
        }
        Err(last.expect("at least one attempt ran"))
    }

    fn unexpected(&self, verb: &str, got: &Reply) -> FleetError {
        FleetError::Protocol(format!("{verb} to {}: unexpected reply {got:?}", self.addr))
    }

    /// Load report for the rebalancer.
    pub fn stats(&mut self) -> Result<ShardStats, FleetError> {
        match self.call(&Request::Stats)? {
            Reply::Stats(s) => Ok(s),
            other => Err(self.unexpected("stats", &other)),
        }
    }

    /// Liveness probe: replies Ok and touches no tenant state.
    pub fn ping(&mut self) -> Result<(), FleetError> {
        match self.call(&Request::Ping)? {
            Reply::Ok => Ok(()),
            other => Err(self.unexpected("ping", &other)),
        }
    }

    /// Migration resolved on the destination — drop the source's
    /// tombstone. Idempotent.
    pub fn migrate_commit(&mut self, tenant: u64) -> Result<(), FleetError> {
        match self.call(&Request::MigrateCommit { tenant })? {
            Reply::Ok => Ok(()),
            other => Err(self.unexpected("migrate-commit", &other)),
        }
    }

    /// Migration failed partway — resurrect the tenant from the
    /// source's tombstone. Idempotent.
    pub fn migrate_abort(&mut self, tenant: u64) -> Result<(), FleetError> {
        match self.call(&Request::MigrateAbort { tenant })? {
            Reply::Ok => Ok(()),
            other => Err(self.unexpected("migrate-abort", &other)),
        }
    }

    /// Send a Submit with an EXPLICIT stamp and return the raw reply
    /// (`Queued` or `Duplicate`). The dedup window's test hook: re-send
    /// the same stamp, observe `Duplicate`, state applied exactly once.
    pub fn submit_stamped(
        &mut self,
        tenant: u64,
        stamp: Stamp,
        images: &[f32],
        labels: &[i32],
    ) -> Result<Reply, FleetError> {
        let req =
            Request::Submit { tenant, stamp, images: images.to_vec(), labels: labels.to_vec() };
        match self.call(&req)? {
            r @ (Reply::Queued | Reply::Duplicate) => Ok(r),
            Reply::Rejected { retry_after_ms } => Err(FleetError::Overloaded { retry_after_ms }),
            other => Err(self.unexpected("submit", &other)),
        }
    }

    /// Ask the shard process to finish its serving session and exit.
    pub fn shutdown(&mut self) -> Result<(), FleetError> {
        match self.call(&Request::Shutdown)? {
            Reply::Ok => Ok(()),
            other => Err(self.unexpected("shutdown", &other)),
        }
    }
}

fn apply_timeout(stream: &TcpStream, d: Option<Duration>) -> Result<(), FleetError> {
    stream
        .set_read_timeout(d)
        .and_then(|()| stream.set_write_timeout(d))
        .map_err(|e| FleetError::Io(format!("set_timeout: {e}")))
}

/// One logical connect: up to `retry.attempts` io-shim attempts on the
/// shared backoff curve.
fn dial(
    io: &dyn NetIo,
    addr: &str,
    retry: &RetryPolicy,
    op: u64,
) -> Result<TcpStream, FleetError> {
    let attempts = retry.attempts.max(1);
    let mut last: Option<FleetError> = None;
    for attempt in 0..attempts {
        if attempt > 0 {
            thread::sleep(retry.backoff(attempt));
        }
        match io.connect(addr, op, attempt) {
            Ok(stream) => return Ok(stream),
            Err(e) => last = Some(e),
        }
    }
    Err(FleetError::Io(format!(
        "connect to shard {addr} failed after {attempts} attempts: {}",
        last.map(|e| e.to_string()).unwrap_or_default()
    )))
}

impl FleetApi for RemoteClient {
    fn admit(&mut self, tenant: u64, cfg: TenantConfig) -> Result<(), FleetError> {
        let stamp = self.next_stamp(tenant);
        match self.call(&Request::Admit { tenant, stamp, cfg })? {
            Reply::Admitted { tenant: t } if t == tenant => Ok(()),
            Reply::Duplicate => Ok(()),
            other => Err(self.unexpected("admit", &other)),
        }
    }

    fn submit(&mut self, tenant: u64, images: &[f32], labels: &[i32]) -> Result<(), FleetError> {
        let stamp = self.next_stamp(tenant);
        let req =
            Request::Submit { tenant, stamp, images: images.to_vec(), labels: labels.to_vec() };
        match self.call(&req)? {
            Reply::Queued | Reply::Duplicate => Ok(()),
            Reply::Rejected { retry_after_ms } => Err(FleetError::Overloaded { retry_after_ms }),
            other => Err(self.unexpected("submit", &other)),
        }
    }

    fn infer(&mut self, tenant: u64, images: &[f32], rows: u32) -> Result<Vec<f32>, FleetError> {
        let req = Request::Infer { tenant, rows, images: images.to_vec() };
        match self.call(&req)? {
            Reply::Logits { rows: r, classes, data } => {
                if data.len() != r as usize * classes as usize {
                    return Err(FleetError::Protocol(format!(
                        "ragged logits from {}: {} values for {r}x{classes}",
                        self.addr,
                        data.len()
                    )));
                }
                Ok(data)
            }
            other => Err(self.unexpected("infer", &other)),
        }
    }

    fn evaluate(&mut self, tenant: u64) -> Result<f64, FleetError> {
        match self.call(&Request::Eval { tenant })? {
            Reply::Accuracy { value } => Ok(value),
            other => Err(self.unexpected("eval", &other)),
        }
    }

    fn drain(&mut self, tenant: u64) -> Result<Vec<u8>, FleetError> {
        match self.call(&Request::Drain { tenant })? {
            Reply::Snapshot { bytes } => Ok(bytes),
            other => Err(self.unexpected("drain", &other)),
        }
    }

    fn restore(&mut self, tenant: u64, snapshot: &[u8]) -> Result<(), FleetError> {
        let stamp = self.next_stamp(tenant);
        let req = Request::Restore { tenant, stamp, snapshot: snapshot.to_vec() };
        match self.call(&req)? {
            Reply::Ok | Reply::Duplicate => Ok(()),
            other => Err(self.unexpected("restore", &other)),
        }
    }
}
