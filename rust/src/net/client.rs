//! [`RemoteClient`]: the client half of one shard connection.
//!
//! One TCP stream, one request in flight at a time (the protocol is
//! strictly request/reply), connect retry with the fleet's shared
//! [`RetryPolicy`] backoff curve. Implements [`FleetApi`], so code
//! written against the trait serves identically through an in-process
//! [`crate::fleet::api::LocalClient`] or across the wire.
//!
//! Error discipline: transport failures surface as [`FleetError::Io`],
//! malformed or unexpected replies as [`FleetError::Protocol`], and a
//! decoded [`Reply::Err`] is returned verbatim — the server's error IS
//! the client's error, byte-coded through [`FleetError::code`].

use std::io::Write;
use std::net::TcpStream;
use std::thread;

use crate::fleet::api::{FleetApi, FleetError};
use crate::fleet::faults::RetryPolicy;
use crate::fleet::tenant::TenantConfig;

use super::frame::{client_handshake, recv_reply, send_request, Reply, Request, ShardStats};

/// One connection to one shard process.
pub struct RemoteClient {
    stream: TcpStream,
    addr: String,
}

impl RemoteClient {
    /// Connect and handshake, retrying refused connections on the
    /// policy's backoff curve (shard processes may still be binding
    /// when the client starts — the loopback race CI hits every run).
    pub fn connect(addr: &str, retry: &RetryPolicy) -> Result<RemoteClient, FleetError> {
        let attempts = retry.attempts.max(1);
        let mut last: Option<std::io::Error> = None;
        for attempt in 1..=attempts {
            match TcpStream::connect(addr) {
                Ok(mut stream) => {
                    stream
                        .set_nodelay(true)
                        .map_err(|e| FleetError::Io(format!("set_nodelay({addr}): {e}")))?;
                    client_handshake(&mut stream)
                        .map_err(|e| FleetError::Protocol(format!("handshake with {addr}: {e:#}")))?;
                    return Ok(RemoteClient { stream, addr: addr.to_string() });
                }
                Err(e) => {
                    last = Some(e);
                    if attempt < attempts {
                        thread::sleep(retry.backoff(attempt));
                    }
                }
            }
        }
        Err(FleetError::Io(format!(
            "connect to shard {addr} failed after {attempts} attempts: {}",
            last.map(|e| e.to_string()).unwrap_or_default()
        )))
    }

    /// The address this client dialed.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// One request/reply round trip. A decoded [`Reply::Err`] becomes
    /// this call's error; every other reply shape is returned for the
    /// caller to match.
    pub fn call(&mut self, req: &Request) -> Result<Reply, FleetError> {
        send_request(&mut self.stream, req)
            .map_err(|e| FleetError::Io(format!("send to {}: {e:#}", self.addr)))?;
        self.stream
            .flush()
            .map_err(|e| FleetError::Io(format!("flush to {}: {e}", self.addr)))?;
        let reply = recv_reply(&mut self.stream)
            .map_err(|e| FleetError::Io(format!("recv from {}: {e:#}", self.addr)))?;
        match reply {
            Reply::Err(e) => Err(e),
            other => Ok(other),
        }
    }

    fn unexpected(&self, verb: &str, got: &Reply) -> FleetError {
        FleetError::Protocol(format!("{verb} to {}: unexpected reply {got:?}", self.addr))
    }

    /// Load report for the rebalancer.
    pub fn stats(&mut self) -> Result<ShardStats, FleetError> {
        match self.call(&Request::Stats)? {
            Reply::Stats(s) => Ok(s),
            other => Err(self.unexpected("stats", &other)),
        }
    }

    /// Ask the shard process to finish its serving session and exit.
    pub fn shutdown(&mut self) -> Result<(), FleetError> {
        match self.call(&Request::Shutdown)? {
            Reply::Ok => Ok(()),
            other => Err(self.unexpected("shutdown", &other)),
        }
    }
}

impl FleetApi for RemoteClient {
    fn admit(&mut self, tenant: u64, cfg: TenantConfig) -> Result<(), FleetError> {
        match self.call(&Request::Admit { tenant, cfg })? {
            Reply::Admitted { tenant: t } if t == tenant => Ok(()),
            other => Err(self.unexpected("admit", &other)),
        }
    }

    fn submit(&mut self, tenant: u64, images: &[f32], labels: &[i32]) -> Result<(), FleetError> {
        let req = Request::Submit { tenant, images: images.to_vec(), labels: labels.to_vec() };
        match self.call(&req)? {
            Reply::Queued => Ok(()),
            Reply::Rejected { retry_after_ms } => Err(FleetError::Overloaded { retry_after_ms }),
            other => Err(self.unexpected("submit", &other)),
        }
    }

    fn infer(&mut self, tenant: u64, images: &[f32], rows: u32) -> Result<Vec<f32>, FleetError> {
        let req = Request::Infer { tenant, rows, images: images.to_vec() };
        match self.call(&req)? {
            Reply::Logits { rows: r, classes, data } => {
                if data.len() != r as usize * classes as usize {
                    return Err(FleetError::Protocol(format!(
                        "ragged logits from {}: {} values for {r}x{classes}",
                        self.addr,
                        data.len()
                    )));
                }
                Ok(data)
            }
            other => Err(self.unexpected("infer", &other)),
        }
    }

    fn evaluate(&mut self, tenant: u64) -> Result<f64, FleetError> {
        match self.call(&Request::Eval { tenant })? {
            Reply::Accuracy { value } => Ok(value),
            other => Err(self.unexpected("eval", &other)),
        }
    }

    fn drain(&mut self, tenant: u64) -> Result<Vec<u8>, FleetError> {
        match self.call(&Request::Drain { tenant })? {
            Reply::Snapshot { bytes } => Ok(bytes),
            other => Err(self.unexpected("drain", &other)),
        }
    }

    fn restore(&mut self, tenant: u64, snapshot: &[u8]) -> Result<(), FleetError> {
        let req = Request::Restore { tenant, snapshot: snapshot.to_vec() };
        match self.call(&req)? {
            Reply::Ok => Ok(()),
            other => Err(self.unexpected("restore", &other)),
        }
    }
}
