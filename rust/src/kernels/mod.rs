//! Native rust implementations of the paper's CL compute primitives
//! (§IV-B, Fig. 3): FW / BW-ERR / BW-GRAD for pointwise, depthwise and
//! linear layers, via im2col + matmul — the same dataflow the paper's
//! RISC-V kernels use.
//!
//! Three roles in this repo:
//!  1. an executable *reference* for the simulator's work accounting (the
//!     tiled driver iterates exactly the solver's tile schedule, so MAC
//!     counts and block structure are validated on real data);
//!  2. a PJRT-free compute substrate for quick experiments and tests;
//!  3. the paper's "future work" portability claim made concrete — the
//!     primitives run anywhere rust runs.
//!
//! Layouts match the Python L1 kernels: NHWC activations, `[K, N]`
//! weights, HWC depthwise filters, pad=1 convolutions.

use crate::simulator::tiling::{matmul_geom, solve_tile};
use crate::simulator::kernels::Pass;
use crate::models::LayerDesc;

/// `out[M,N] = x[M,K] @ w[K,N]` (naive triple loop, K innermost —
/// the paper's inner-loop-over-K structure).
pub fn matmul_fw(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += x[i * k + p] * w[p * n + j];
            }
            out[i * n + j] = acc;
        }
    }
    out
}

/// BW-ERR: `dx[M,K] = g[M,N] @ w[K,N]^T`.
pub fn matmul_bw_err(g: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; m * k];
    for i in 0..m {
        for p in 0..k {
            let mut acc = 0.0f32;
            for j in 0..n {
                acc += g[i * n + j] * w[p * n + j];
            }
            dx[i * k + p] = acc;
        }
    }
    dx
}

/// BW-GRAD: `dw[K,N] = x[M,K]^T @ g[M,N]`.
pub fn matmul_bw_grad(x: &[f32], g: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut dw = vec![0.0f32; k * n];
    for p in 0..k {
        for j in 0..n {
            let mut acc = 0.0f32;
            for i in 0..m {
                acc += x[i * k + p] * g[i * n + j];
            }
            dw[p * n + j] = acc;
        }
    }
    dw
}

/// Tile-scheduled matmul forward: iterates the L1 tile schedule produced
/// by the simulator's solver (M/N/K blocking with K-accumulation), i.e.
/// the execution order the cycle model charges for. Must equal
/// [`matmul_fw`] bit-for-bit in this summation order? No — floating
/// point reassociates across K-chunks; equality is to a tolerance.
pub fn matmul_fw_tiled(
    x: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    l1_bytes: usize,
) -> Vec<f32> {
    let geom = crate::simulator::tiling::MatmulGeom { m, n, k, scratch_per_row: 0 };
    let dims = solve_tile(&geom, l1_bytes);
    let mut out = vec![0.0f32; m * n];
    let div = |a: usize, b: usize| (a + b - 1) / b;
    for im in 0..div(m, dims.tm) {
        let m0 = im * dims.tm;
        let m1 = (m0 + dims.tm).min(m);
        for jn in 0..div(n, dims.tn) {
            let n0 = jn * dims.tn;
            let n1 = (n0 + dims.tn).min(n);
            for kk in 0..div(k, dims.tk) {
                let k0 = kk * dims.tk;
                let k1 = (k0 + dims.tk).min(k);
                for i in m0..m1 {
                    for j in n0..n1 {
                        let mut acc = 0.0f32;
                        for p in k0..k1 {
                            acc += x[i * k + p] * w[p * n + j];
                        }
                        out[i * n + j] += acc;
                    }
                }
            }
        }
    }
    out
}

/// im2col for a pad=1 3x3 conv: `[B,H,W,C] -> [B*Ho*Wo, 9*C]`, (ky,kx,c)
/// column order — identical to the Python L1 kernel.
pub fn im2col3x3(x: &[f32], b: usize, h: usize, w: usize, c: usize, stride: usize) -> Vec<f32> {
    assert_eq!(x.len(), b * h * w * c);
    let ho = h.div_ceil(stride);
    let wo = w.div_ceil(stride);
    let cols = 9 * c;
    let mut out = vec![0.0f32; b * ho * wo * cols];
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = ((bi * ho + oy) * wo + ox) * cols;
                for ky in 0..3 {
                    for kx in 0..3 {
                        let iy = (oy * stride + ky) as isize - 1;
                        let ix = (ox * stride + kx) as isize - 1;
                        if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                            continue; // zero padding
                        }
                        let src = ((bi * h + iy as usize) * w + ix as usize) * c;
                        let dst = row + (ky * 3 + kx) * c;
                        out[dst..dst + c].copy_from_slice(&x[src..src + c]);
                    }
                }
            }
        }
    }
    out
}

/// 3x3 depthwise conv forward (pad=1): `x [B,H,W,C]`, `kern [3,3,C]`.
pub fn depthwise_fw(
    x: &[f32],
    kern: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    stride: usize,
) -> Vec<f32> {
    let ho = h.div_ceil(stride);
    let wo = w.div_ceil(stride);
    let mut out = vec![0.0f32; b * ho * wo * c];
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                for ky in 0..3 {
                    let iy = (oy * stride + ky) as isize - 1;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..3 {
                        let ix = (ox * stride + kx) as isize - 1;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((bi * h + iy as usize) * w + ix as usize) * c;
                        let dst = ((bi * ho + oy) * wo + ox) * c;
                        let kf = (ky * 3 + kx) * c;
                        for ch in 0..c {
                            out[dst + ch] += x[src + ch] * kern[kf + ch];
                        }
                    }
                }
            }
        }
    }
    out
}

/// Pointwise (1x1) conv forward: matmul over `[B*H*W, Cin] x [Cin, Cout]`.
pub fn pointwise_fw(x: &[f32], w: &[f32], rows: usize, cin: usize, cout: usize) -> Vec<f32> {
    matmul_fw(x, w, rows, cin, cout)
}

/// Exact MAC count performed by [`matmul_fw_tiled`] under a given L1 —
/// cross-checked against the simulator's `TileSchedule::total_macs`.
pub fn tiled_macs(layer: &LayerDesc, pass: Pass, batch: usize, l1_bytes: usize) -> u64 {
    let geom = matmul_geom(layer, pass, batch);
    // every (m, n, k) element triple is touched exactly once
    geom.m as u64 * geom.n as u64 * geom.k as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mobilenet_v1_128;
    use crate::simulator::tiling::schedule_layer;
    use crate::util::{prop, rng::Rng};

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn matmul_known_values() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let out = matmul_fw(&[1., 2., 3., 4.], &[1., 1., 1., 1.], 2, 2, 2);
        assert_eq!(out, vec![3., 3., 7., 7.]);
    }

    #[test]
    fn tiled_matches_naive_for_many_l1_sizes() {
        prop::check("tiled matmul", 32, |rng| {
            let m = prop::int_in(rng, 1, 40);
            let k = prop::int_in(rng, 1, 40);
            let n = prop::int_in(rng, 1, 40);
            let x = randv(rng, m * k);
            let w = randv(rng, k * n);
            let naive = matmul_fw(&x, &w, m, k, n);
            for l1 in [256usize, 1024, 64 * 1024] {
                let tiled = matmul_fw_tiled(&x, &w, m, k, n, l1);
                for (a, b) in naive.iter().zip(&tiled) {
                    assert!((a - b).abs() < 1e-3 * k as f32, "l1={l1}");
                }
            }
        });
    }

    #[test]
    fn backward_error_is_gradient() {
        // finite differences: d(sum(out * g))/dx[i] == bw_err[i]
        let mut rng = Rng::new(3);
        let (m, k, n) = (3, 4, 5);
        let x = randv(&mut rng, m * k);
        let w = randv(&mut rng, k * n);
        let g = randv(&mut rng, m * n);
        let loss = |x_: &[f32]| -> f64 {
            matmul_fw(x_, &w, m, k, n)
                .iter()
                .zip(&g)
                .map(|(o, gi)| (*o as f64) * (*gi as f64))
                .sum()
        };
        let dx = matmul_bw_err(&g, &w, m, k, n);
        let eps = 1e-3f32;
        for i in 0..m * k {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let num = (loss(&xp) - loss(&xm)) / (2.0 * eps as f64);
            assert!(
                (num - dx[i] as f64).abs() < 1e-2,
                "dx[{i}]: fd {num} vs analytic {}",
                dx[i]
            );
        }
    }

    #[test]
    fn backward_grad_is_gradient() {
        let mut rng = Rng::new(4);
        let (m, k, n) = (4, 3, 2);
        let x = randv(&mut rng, m * k);
        let w = randv(&mut rng, k * n);
        let g = randv(&mut rng, m * n);
        let loss = |w_: &[f32]| -> f64 {
            matmul_fw(&x, w_, m, k, n)
                .iter()
                .zip(&g)
                .map(|(o, gi)| (*o as f64) * (*gi as f64))
                .sum()
        };
        let dw = matmul_bw_grad(&x, &g, m, k, n);
        let eps = 1e-3f32;
        for i in 0..k * n {
            let mut wp = w.clone();
            wp[i] += eps;
            let mut wm = w.clone();
            wm[i] -= eps;
            let num = (loss(&wp) - loss(&wm)) / (2.0 * eps as f64);
            assert!((num - dw[i] as f64).abs() < 1e-2, "dw[{i}]");
        }
    }

    #[test]
    fn im2col_times_weights_equals_depthwise_diag() {
        // a depthwise conv equals im2col @ block-diagonal weights; check
        // via a 1-channel case where they coincide exactly
        let mut rng = Rng::new(5);
        let (b, h, w) = (2, 5, 5);
        let x = randv(&mut rng, b * h * w);
        let kern = randv(&mut rng, 9);
        for stride in [1usize, 2] {
            let cols = im2col3x3(&x, b, h, w, 1, stride);
            let via_mm = matmul_fw(&cols, &kern, cols.len() / 9, 9, 1);
            let direct = depthwise_fw(&x, &kern, b, h, w, 1, stride);
            for (a, d) in via_mm.iter().zip(&direct) {
                assert!((a - d).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn depthwise_identity_kernel_is_identity() {
        // kernel with 1 at the center tap copies the input (stride 1)
        let mut rng = Rng::new(6);
        let (b, h, w, c) = (1, 4, 4, 3);
        let x = randv(&mut rng, b * h * w * c);
        let mut kern = vec![0.0f32; 9 * c];
        for ch in 0..c {
            kern[(1 * 3 + 1) * c + ch] = 1.0;
        }
        let out = depthwise_fw(&x, &kern, b, h, w, c, 1);
        assert_eq!(out, x);
    }

    #[test]
    fn pointwise_matches_matmul_semantics() {
        let mut rng = Rng::new(7);
        let (rows, cin, cout) = (6, 4, 3);
        let x = randv(&mut rng, rows * cin);
        let w = randv(&mut rng, cin * cout);
        assert_eq!(pointwise_fw(&x, &w, rows, cin, cout), matmul_fw(&x, &w, rows, cin, cout));
    }

    #[test]
    fn tiled_mac_accounting_matches_simulator() {
        // the simulator charges exactly the MACs the native tiled kernel
        // performs — per layer, pass and batch
        let net = mobilenet_v1_128();
        for l in [19usize, 22, 23, 27] {
            for pass in Pass::all() {
                for batch in [1usize, 21, 128] {
                    let sched = schedule_layer(net.layer(l), pass, batch, 128 * 1024);
                    assert_eq!(
                        sched.total_macs(),
                        tiled_macs(net.layer(l), pass, batch, 128 * 1024),
                        "layer {l} {pass:?} batch {batch}"
                    );
                }
            }
        }
    }
}
