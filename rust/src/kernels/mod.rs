//! Native rust implementations of the paper's CL compute primitives
//! (§IV-B, Fig. 3): FW / BW-ERR / BW-GRAD for pointwise, depthwise and
//! linear layers, via im2col + matmul — the same dataflow the paper's
//! RISC-V kernels use.
//!
//! Three roles in this repo:
//!  1. an executable *reference* for the simulator's work accounting (the
//!     blocked driver iterates exactly the solver's tile schedule, so MAC
//!     counts and block structure are validated on real data);
//!  2. a PJRT-free compute substrate for quick experiments and tests;
//!  3. the paper's "future work" portability claim made concrete — the
//!     primitives run anywhere rust runs.
//!
//! Layouts match the Python L1 kernels: NHWC activations, `[K, N]`
//! weights, HWC depthwise filters, pad=1 convolutions.
//!
//! ## The native kernel engine
//!
//! Since the perf rework, all three matmul passes and both conv paths run
//! on the cache-blocked, multi-threaded core in [`engine`]:
//!
//! - **L2 blocking** reuses the simulator's [`solve_tile`] schedule
//!   (M/N/K blocking, reduction kept resident as long as the budget
//!   allows) — the execution order the cycle model charges for;
//! - **panel packing** re-lays operands into contiguous `MR x k` /
//!   `k x NR` panels; the backward passes feed *strided views* through
//!   the same pack routine, so BW-ERR/BW-GRAD never materialize a
//!   transpose, and [`Engine::conv3x3_fw_into`] performs im2col directly
//!   into the A panel (no `[rows, 9*C]` intermediate buffer);
//! - an **`MR x NR` register micro-kernel** does one rank-1 update per
//!   packed `k` step — constant inner trip counts, so the compiler keeps
//!   the accumulator in registers and vectorizes the `NR` loop;
//! - **row-panel threading** splits output rows into chunks by the
//!   engine's logical thread count and fork-joins them on the persistent
//!   process-wide [`crate::exec::ExecPool`] (the paper's 8-core dataflow
//!   on an always-resident cluster — zero thread spawns at steady
//!   state); each chunk owns a disjoint output slice and the split never
//!   depends on the pool's physical width, making the parallel path
//!   sync-free and bit-deterministic across thread counts AND pool
//!   widths.
//!
//! The original naive triple loops survive as `*_naive` — they are the
//! oracle the engine's property tests and the `fig8_kernels` /
//! `hot_path` before/after benches compare against (EXPERIMENTS.md
//! §Perf records the measured speedups).
//!
//! ## The true-INT8 frozen path
//!
//! The frozen stage additionally runs on **integer** kernels
//! (`matmul_fw_i8_into`, `conv3x3_fw_i8_into`, `depthwise_fw_i8_into`,
//! plus the grouped cross-tenant variant): UINT-8 activation codes ×
//! true-`i8` weight codes with i32 accumulation, packed into
//! pair-interleaved i16 panels so the micro-kernel retires two MACs per
//! i32 lane (the `pmaddwd` / PULP-NN `sdotp` dataflow). Zero-point
//! corrections are folded in via per-row code sums, so every output is
//! the exact signed accumulation `Σ q_x·q_w` — integer arithmetic is
//! associative, hence the blocked/parallel kernels are **bit-identical**
//! to their `*_i8_naive` oracles at any thread count, tile budget and
//! batch width. `quant::requant` turns those accumulators back into
//! codes at each layer boundary.

pub mod engine;

pub use engine::{default_engine, Engine};

/// Integer FW on the default engine:
/// `out[M,N] = x[M,K] · (w[K,N] + w_off)` — see
/// [`Engine::matmul_fw_i8_into`].
pub fn matmul_fw_i8(x: &[u8], w: &[i8], w_off: i32, m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut out = vec![0i32; m * n];
    default_engine().matmul_fw_i8_into(x, w, w_off, m, k, n, &mut out);
    out
}

/// Fused integer 3x3 conv forward (pad=1) on the default engine — see
/// [`Engine::conv3x3_fw_i8_into`].
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_fw_i8(
    x: &[u8],
    wmat: &[i8],
    w_off: i32,
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    stride: usize,
    cout: usize,
) -> Vec<i32> {
    let ho = h.div_ceil(stride);
    let wo = w.div_ceil(stride);
    let mut out = vec![0i32; b * ho * wo * cout];
    default_engine().conv3x3_fw_i8_into(x, wmat, w_off, b, h, w, c, stride, cout, &mut out);
    out
}

/// Integer 3x3 depthwise conv forward (pad=1) on the default engine —
/// see [`Engine::depthwise_fw_i8_into`].
pub fn depthwise_fw_i8(
    x: &[u8],
    kern: &[i8],
    w_off: i32,
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    stride: usize,
) -> Vec<i32> {
    let ho = h.div_ceil(stride);
    let wo = w.div_ceil(stride);
    let mut out = vec![0i32; b * ho * wo * c];
    default_engine().depthwise_fw_i8_into(x, kern, w_off, b, h, w, c, stride, &mut out);
    out
}

use crate::models::LayerDesc;
use crate::simulator::kernels::Pass;
use crate::simulator::tiling::{matmul_geom, solve_tile};

/// `out[M,N] = x[M,K] @ w[K,N]` on the blocked parallel engine.
pub fn matmul_fw(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    default_engine().matmul_fw_into(x, w, m, k, n, &mut out);
    out
}

/// Cross-tenant grouped FW on the default engine: consecutive row groups
/// of `x`, each against its own `[K, N]` weight matrix (see
/// [`Engine::matmul_fw_grouped_into`] — the fleet's batched-inference
/// head kernel).
pub fn matmul_fw_grouped(x: &[f32], groups: &[(usize, &[f32])], k: usize, n: usize) -> Vec<f32> {
    let m: usize = groups.iter().map(|(rows, _)| rows).sum();
    let mut out = vec![0.0f32; m * n];
    default_engine().matmul_fw_grouped_into(x, groups, k, n, &mut out);
    out
}

/// BW-ERR: `dx[M,K] = g[M,N] @ w[K,N]^T` (packed transposed view — no
/// materialized transpose).
pub fn matmul_bw_err(g: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * k];
    default_engine().matmul_bw_err_into(g, w, m, k, n, &mut out);
    out
}

/// BW-GRAD: `dw[K,N] = x[M,K]^T @ g[M,N]` (packed transposed view).
pub fn matmul_bw_grad(x: &[f32], g: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; k * n];
    default_engine().matmul_bw_grad_into(x, g, m, k, n, &mut out);
    out
}

/// Tile-scheduled matmul forward: single-threaded engine blocking against
/// `l1_bytes` via the simulator's solver — the execution order the cycle
/// model charges for. Floating point reassociates across K-chunks, so
/// equality with [`matmul_fw_naive`] is to a tolerance, not bit-for-bit.
pub fn matmul_fw_tiled(
    x: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    l1_bytes: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    Engine::tiled(l1_bytes).matmul_fw_into(x, w, m, k, n, &mut out);
    out
}

/// 3x3 conv forward (pad=1) with im2col fused into panel packing:
/// `x [B,H,W,C]`, `wmat [9*C, Cout]` ((ky,kx,c) row order), output
/// `[B*Ho*Wo, Cout]`.
pub fn conv3x3_fw(
    x: &[f32],
    wmat: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    stride: usize,
    cout: usize,
) -> Vec<f32> {
    let ho = h.div_ceil(stride);
    let wo = w.div_ceil(stride);
    let mut out = vec![0.0f32; b * ho * wo * cout];
    default_engine().conv3x3_fw_into(x, wmat, b, h, w, c, stride, cout, &mut out);
    out
}

/// 3x3 depthwise conv forward (pad=1): `x [B,H,W,C]`, `kern [3,3,C]`,
/// rows split across the engine's workers (bit-exact at any count).
pub fn depthwise_fw(
    x: &[f32],
    kern: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    stride: usize,
) -> Vec<f32> {
    let ho = h.div_ceil(stride);
    let wo = w.div_ceil(stride);
    let mut out = vec![0.0f32; b * ho * wo * c];
    default_engine().depthwise_fw_into(x, kern, b, h, w, c, stride, &mut out);
    out
}

/// Pointwise (1x1) conv forward: matmul over `[B*H*W, Cin] x [Cin, Cout]`.
pub fn pointwise_fw(x: &[f32], w: &[f32], rows: usize, cin: usize, cout: usize) -> Vec<f32> {
    matmul_fw(x, w, rows, cin, cout)
}

/// Depthwise BW-ERR (pad=1): `dx[B,H,W,C]` of [`depthwise_fw`] given the
/// upstream gradient `g [B,Ho,Wo,C]`. The native backend's adaptive stage
/// backprops *through* its DW layers with this — the loops mirror the
/// forward's tap walk, scattering instead of gathering (depthwise is
/// < 2% of the stage's MACs, so the paper-style simple loop is the right
/// altitude; the matmul passes carry the compute and run on the engine).
pub fn depthwise_bw_err(
    g: &[f32],
    kern: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    stride: usize,
) -> Vec<f32> {
    let ho = h.div_ceil(stride);
    let wo = w.div_ceil(stride);
    assert_eq!(g.len(), b * ho * wo * c, "g size mismatch");
    assert_eq!(kern.len(), 9 * c, "kern size mismatch");
    let mut dx = vec![0.0f32; b * h * w * c];
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                let gsrc = ((bi * ho + oy) * wo + ox) * c;
                for ky in 0..3 {
                    let iy = (oy * stride + ky) as isize - 1;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..3 {
                        let ix = (ox * stride + kx) as isize - 1;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let dst = ((bi * h + iy as usize) * w + ix as usize) * c;
                        let kf = (ky * 3 + kx) * c;
                        for ch in 0..c {
                            dx[dst + ch] += g[gsrc + ch] * kern[kf + ch];
                        }
                    }
                }
            }
        }
    }
    dx
}

/// Depthwise BW-GRAD (pad=1): `dk [3,3,C]` (flattened `9*C`, same layout
/// as the forward's `kern`) of [`depthwise_fw`] given activations `x` and
/// upstream gradient `g`.
pub fn depthwise_bw_grad(
    x: &[f32],
    g: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    stride: usize,
) -> Vec<f32> {
    let ho = h.div_ceil(stride);
    let wo = w.div_ceil(stride);
    assert_eq!(x.len(), b * h * w * c, "x size mismatch");
    assert_eq!(g.len(), b * ho * wo * c, "g size mismatch");
    let mut dk = vec![0.0f32; 9 * c];
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                let gsrc = ((bi * ho + oy) * wo + ox) * c;
                for ky in 0..3 {
                    let iy = (oy * stride + ky) as isize - 1;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..3 {
                        let ix = (ox * stride + kx) as isize - 1;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((bi * h + iy as usize) * w + ix as usize) * c;
                        let kf = (ky * 3 + kx) * c;
                        for ch in 0..c {
                            dk[kf + ch] += x[src + ch] * g[gsrc + ch];
                        }
                    }
                }
            }
        }
    }
    dk
}

// ---- naive references ------------------------------------------------------

/// Naive integer FW oracle: `out[i,j] = Σ_k x[i,k] · (w[k,j] + w_off)`
/// with plain i32 loops — what every blocked/parallel integer kernel
/// must reproduce **bit-exactly** (integer accumulation is associative,
/// so there is no tolerance anywhere on the i8 path).
pub fn matmul_fw_i8_naive(
    x: &[u8],
    w: &[i8],
    w_off: i32,
    m: usize,
    k: usize,
    n: usize,
) -> Vec<i32> {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), k * n);
    let mut out = vec![0i32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i32;
            for p in 0..k {
                acc += x[i * k + p] as i32 * (w[p * n + j] as i32 + w_off);
            }
            out[i * n + j] = acc;
        }
    }
    out
}

/// Naive integer depthwise oracle (pad=1), mirroring
/// [`depthwise_fw`]'s tap walk over codes.
pub fn depthwise_fw_i8_naive(
    x: &[u8],
    kern: &[i8],
    w_off: i32,
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    stride: usize,
) -> Vec<i32> {
    assert_eq!(x.len(), b * h * w * c);
    assert_eq!(kern.len(), 9 * c);
    let ho = h.div_ceil(stride);
    let wo = w.div_ceil(stride);
    let mut out = vec![0i32; b * ho * wo * c];
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                let dst = ((bi * ho + oy) * wo + ox) * c;
                for ky in 0..3 {
                    let iy = (oy * stride + ky) as isize - 1;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..3 {
                        let ix = (ox * stride + kx) as isize - 1;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((bi * h + iy as usize) * w + ix as usize) * c;
                        let kf = (ky * 3 + kx) * c;
                        for ch in 0..c {
                            out[dst + ch] +=
                                x[src + ch] as i32 * (kern[kf + ch] as i32 + w_off);
                        }
                    }
                }
            }
        }
    }
    out
}

/// im2col over u8 codes for a pad=1 3x3 conv (`[B,H,W,C] ->
/// [B*Ho*Wo, 9*C]`, (ky,kx,c) column order, padding = code 0) — the
/// materializing oracle of the fused integer conv path.
pub fn im2col3x3_u8(x: &[u8], b: usize, h: usize, w: usize, c: usize, stride: usize) -> Vec<u8> {
    assert_eq!(x.len(), b * h * w * c);
    let ho = h.div_ceil(stride);
    let wo = w.div_ceil(stride);
    let cols = 9 * c;
    let mut out = vec![0u8; b * ho * wo * cols];
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = ((bi * ho + oy) * wo + ox) * cols;
                for ky in 0..3 {
                    for kx in 0..3 {
                        let iy = (oy * stride + ky) as isize - 1;
                        let ix = (ox * stride + kx) as isize - 1;
                        if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                            continue; // zero padding == code 0
                        }
                        let src = ((bi * h + iy as usize) * w + ix as usize) * c;
                        let dst = row + (ky * 3 + kx) * c;
                        out[dst..dst + c].copy_from_slice(&x[src..src + c]);
                    }
                }
            }
        }
    }
    out
}

/// Naive triple-loop FW (K innermost — the paper's inner-loop-over-K
/// structure). The engine's correctness oracle and the §Perf baseline.
pub fn matmul_fw_naive(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += x[i * k + p] * w[p * n + j];
            }
            out[i * n + j] = acc;
        }
    }
    out
}

/// Naive BW-ERR reference.
pub fn matmul_bw_err_naive(g: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; m * k];
    for i in 0..m {
        for p in 0..k {
            let mut acc = 0.0f32;
            for j in 0..n {
                acc += g[i * n + j] * w[p * n + j];
            }
            dx[i * k + p] = acc;
        }
    }
    dx
}

/// Naive BW-GRAD reference.
pub fn matmul_bw_grad_naive(x: &[f32], g: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut dw = vec![0.0f32; k * n];
    for p in 0..k {
        for j in 0..n {
            let mut acc = 0.0f32;
            for i in 0..m {
                acc += x[i * k + p] * g[i * n + j];
            }
            dw[p * n + j] = acc;
        }
    }
    dw
}

/// im2col for a pad=1 3x3 conv: `[B,H,W,C] -> [B*Ho*Wo, 9*C]`, (ky,kx,c)
/// column order — identical to the Python L1 kernel. The engine's conv
/// path fuses this into panel packing; the materializing version stays as
/// the reference (and the layout contract's executable documentation).
pub fn im2col3x3(x: &[f32], b: usize, h: usize, w: usize, c: usize, stride: usize) -> Vec<f32> {
    assert_eq!(x.len(), b * h * w * c);
    let ho = h.div_ceil(stride);
    let wo = w.div_ceil(stride);
    let cols = 9 * c;
    let mut out = vec![0.0f32; b * ho * wo * cols];
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = ((bi * ho + oy) * wo + ox) * cols;
                for ky in 0..3 {
                    for kx in 0..3 {
                        let iy = (oy * stride + ky) as isize - 1;
                        let ix = (ox * stride + kx) as isize - 1;
                        if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                            continue; // zero padding
                        }
                        let src = ((bi * h + iy as usize) * w + ix as usize) * c;
                        let dst = row + (ky * 3 + kx) * c;
                        out[dst..dst + c].copy_from_slice(&x[src..src + c]);
                    }
                }
            }
        }
    }
    out
}

/// Exact MAC count performed by the blocked engine for one (layer, pass,
/// batch) under a given L1 budget: the sum over the solver's tile grid,
/// mirroring the L2-block loops the engine executes — cross-checked
/// against the simulator's `TileSchedule::total_macs`.
///
/// NOTE: the grid sum factorizes, so the total always equals
/// `m * n * k` regardless of tile sizes — agreement on the *total* is a
/// consistency check, not a strong one. The non-trivial invariant (the
/// block grid itself matches the schedule's tile count, and the pass's
/// packed kernel matches its naive oracle) is asserted by
/// [`crate::simulator::executor::reference_check_layer`].
pub fn tiled_macs(layer: &LayerDesc, pass: Pass, batch: usize, l1_bytes: usize) -> u64 {
    let geom = matmul_geom(layer, pass, batch);
    let dims = solve_tile(&geom, l1_bytes);
    let div = |a: usize, b: usize| a.div_ceil(b);
    let mut total = 0u64;
    for im in 0..div(geom.m, dims.tm) {
        let rows = dims.tm.min(geom.m - im * dims.tm);
        for jn in 0..div(geom.n, dims.tn) {
            let cols = dims.tn.min(geom.n - jn * dims.tn);
            for ik in 0..div(geom.k, dims.tk) {
                let red = dims.tk.min(geom.k - ik * dims.tk);
                total += rows as u64 * cols as u64 * red as u64;
            }
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mobilenet_v1_128;
    use crate::simulator::tiling::schedule_layer;
    use crate::util::{prop, rng::Rng};

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn matmul_known_values() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let out = matmul_fw(&[1., 2., 3., 4.], &[1., 1., 1., 1.], 2, 2, 2);
        assert_eq!(out, vec![3., 3., 7., 7.]);
    }

    #[test]
    fn engine_matches_naive_reference() {
        prop::check("engine vs naive", 32, |rng| {
            let m = prop::int_in(rng, 1, 40);
            let k = prop::int_in(rng, 1, 40);
            let n = prop::int_in(rng, 1, 40);
            let x = randv(rng, m * k);
            let w = randv(rng, k * n);
            let naive = matmul_fw_naive(&x, &w, m, k, n);
            let blocked = matmul_fw(&x, &w, m, k, n);
            for (a, b) in naive.iter().zip(&blocked) {
                assert!((a - b).abs() < 1e-3 * k as f32);
            }
        });
    }

    #[test]
    fn tiled_matches_naive_for_many_l1_sizes() {
        prop::check("tiled matmul", 32, |rng| {
            let m = prop::int_in(rng, 1, 40);
            let k = prop::int_in(rng, 1, 40);
            let n = prop::int_in(rng, 1, 40);
            let x = randv(rng, m * k);
            let w = randv(rng, k * n);
            let naive = matmul_fw_naive(&x, &w, m, k, n);
            for l1 in [256usize, 1024, 64 * 1024] {
                let tiled = matmul_fw_tiled(&x, &w, m, k, n, l1);
                for (a, b) in naive.iter().zip(&tiled) {
                    assert!((a - b).abs() < 1e-3 * k as f32, "l1={l1}");
                }
            }
        });
    }

    #[test]
    fn backward_error_is_gradient() {
        // finite differences: d(sum(out * g))/dx[i] == bw_err[i]
        let mut rng = Rng::new(3);
        let (m, k, n) = (3, 4, 5);
        let x = randv(&mut rng, m * k);
        let w = randv(&mut rng, k * n);
        let g = randv(&mut rng, m * n);
        let loss = |x_: &[f32]| -> f64 {
            matmul_fw(x_, &w, m, k, n)
                .iter()
                .zip(&g)
                .map(|(o, gi)| (*o as f64) * (*gi as f64))
                .sum()
        };
        let dx = matmul_bw_err(&g, &w, m, k, n);
        let eps = 1e-3f32;
        for i in 0..m * k {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let num = (loss(&xp) - loss(&xm)) / (2.0 * eps as f64);
            assert!(
                (num - dx[i] as f64).abs() < 1e-2,
                "dx[{i}]: fd {num} vs analytic {}",
                dx[i]
            );
        }
    }

    #[test]
    fn backward_grad_is_gradient() {
        let mut rng = Rng::new(4);
        let (m, k, n) = (4, 3, 2);
        let x = randv(&mut rng, m * k);
        let w = randv(&mut rng, k * n);
        let g = randv(&mut rng, m * n);
        let loss = |w_: &[f32]| -> f64 {
            matmul_fw(&x, w_, m, k, n)
                .iter()
                .zip(&g)
                .map(|(o, gi)| (*o as f64) * (*gi as f64))
                .sum()
        };
        let dw = matmul_bw_grad(&x, &g, m, k, n);
        let eps = 1e-3f32;
        for i in 0..k * n {
            let mut wp = w.clone();
            wp[i] += eps;
            let mut wm = w.clone();
            wm[i] -= eps;
            let num = (loss(&wp) - loss(&wm)) / (2.0 * eps as f64);
            assert!((num - dw[i] as f64).abs() < 1e-2, "dw[{i}]");
        }
    }

    #[test]
    fn im2col_times_weights_equals_depthwise_diag() {
        // a depthwise conv equals im2col @ block-diagonal weights; check
        // via a 1-channel case where they coincide exactly
        let mut rng = Rng::new(5);
        let (b, h, w) = (2, 5, 5);
        let x = randv(&mut rng, b * h * w);
        let kern = randv(&mut rng, 9);
        for stride in [1usize, 2] {
            let cols = im2col3x3(&x, b, h, w, 1, stride);
            let via_mm = matmul_fw(&cols, &kern, cols.len() / 9, 9, 1);
            let direct = depthwise_fw(&x, &kern, b, h, w, 1, stride);
            for (a, d) in via_mm.iter().zip(&direct) {
                assert!((a - d).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn fused_conv_equals_materialized_im2col_path() {
        let mut rng = Rng::new(9);
        let (b, h, w, c, cout) = (2, 6, 5, 3, 4);
        let x = randv(&mut rng, b * h * w * c);
        let wmat = randv(&mut rng, 9 * c * cout);
        for stride in [1usize, 2] {
            let cols = im2col3x3(&x, b, h, w, c, stride);
            let rows = cols.len() / (9 * c);
            let via_mm = matmul_fw_naive(&cols, &wmat, rows, 9 * c, cout);
            let fused = conv3x3_fw(&x, &wmat, b, h, w, c, stride, cout);
            for (a, f) in via_mm.iter().zip(&fused) {
                assert!((a - f).abs() < 1e-3, "stride={stride}");
            }
        }
    }

    #[test]
    fn depthwise_bw_err_is_gradient() {
        // finite differences: d(sum(dw_fw(x) * g))/dx[i] == bw_err[i]
        let mut rng = Rng::new(21);
        let (b, h, w, c) = (2, 4, 5, 3);
        for stride in [1usize, 2] {
            let x = randv(&mut rng, b * h * w * c);
            let kern = randv(&mut rng, 9 * c);
            let ho = h.div_ceil(stride);
            let wo = w.div_ceil(stride);
            let g = randv(&mut rng, b * ho * wo * c);
            let loss = |x_: &[f32]| -> f64 {
                depthwise_fw(x_, &kern, b, h, w, c, stride)
                    .iter()
                    .zip(&g)
                    .map(|(o, gi)| (*o as f64) * (*gi as f64))
                    .sum()
            };
            let dx = depthwise_bw_err(&g, &kern, b, h, w, c, stride);
            let eps = 1e-3f32;
            for i in (0..b * h * w * c).step_by(7) {
                let mut xp = x.clone();
                xp[i] += eps;
                let mut xm = x.clone();
                xm[i] -= eps;
                let num = (loss(&xp) - loss(&xm)) / (2.0 * eps as f64);
                assert!(
                    (num - dx[i] as f64).abs() < 1e-2,
                    "stride={stride} dx[{i}]: fd {num} vs analytic {}",
                    dx[i]
                );
            }
        }
    }

    #[test]
    fn depthwise_bw_grad_is_gradient() {
        let mut rng = Rng::new(22);
        let (b, h, w, c) = (2, 5, 4, 2);
        for stride in [1usize, 2] {
            let x = randv(&mut rng, b * h * w * c);
            let kern = randv(&mut rng, 9 * c);
            let ho = h.div_ceil(stride);
            let wo = w.div_ceil(stride);
            let g = randv(&mut rng, b * ho * wo * c);
            let loss = |k_: &[f32]| -> f64 {
                depthwise_fw(&x, k_, b, h, w, c, stride)
                    .iter()
                    .zip(&g)
                    .map(|(o, gi)| (*o as f64) * (*gi as f64))
                    .sum()
            };
            let dk = depthwise_bw_grad(&x, &g, b, h, w, c, stride);
            let eps = 1e-3f32;
            for i in 0..9 * c {
                let mut kp = kern.clone();
                kp[i] += eps;
                let mut km = kern.clone();
                km[i] -= eps;
                let num = (loss(&kp) - loss(&km)) / (2.0 * eps as f64);
                assert!(
                    (num - dk[i] as f64).abs() < 1e-2,
                    "stride={stride} dk[{i}]: fd {num} vs analytic {}",
                    dk[i]
                );
            }
        }
    }

    #[test]
    fn depthwise_identity_kernel_is_identity() {
        // kernel with 1 at the center tap copies the input (stride 1)
        let mut rng = Rng::new(6);
        let (b, h, w, c) = (1, 4, 4, 3);
        let x = randv(&mut rng, b * h * w * c);
        let mut kern = vec![0.0f32; 9 * c];
        for ch in 0..c {
            kern[4 * c + ch] = 1.0; // (ky=1, kx=1): the center tap
        }
        let out = depthwise_fw(&x, &kern, b, h, w, c, 1);
        assert_eq!(out, x);
    }

    #[test]
    fn pointwise_matches_matmul_semantics() {
        let mut rng = Rng::new(7);
        let (rows, cin, cout) = (6, 4, 3);
        let x = randv(&mut rng, rows * cin);
        let w = randv(&mut rng, cin * cout);
        assert_eq!(pointwise_fw(&x, &w, rows, cin, cout), matmul_fw(&x, &w, rows, cin, cout));
    }

    #[test]
    fn tiled_mac_accounting_matches_simulator() {
        // the simulator charges exactly the MACs the native blocked kernel
        // performs — per layer, pass, batch and L1 budget
        let net = mobilenet_v1_128();
        for l in [19usize, 22, 23, 27] {
            for pass in Pass::all() {
                for batch in [1usize, 21, 128] {
                    for l1 in [4 * 1024usize, 128 * 1024] {
                        let sched = schedule_layer(net.layer(l), pass, batch, l1);
                        assert_eq!(
                            sched.total_macs(),
                            tiled_macs(net.layer(l), pass, batch, l1),
                            "layer {l} {pass:?} batch {batch} l1 {l1}"
                        );
                    }
                }
            }
        }
    }
}
