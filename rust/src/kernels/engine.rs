//! The native kernel engine: a multi-threaded, cache-blocked GEMM core
//! mirroring the paper's 8-core parallel FW/BW dataflow (§IV-B) on the
//! host.
//!
//! ## Blocking scheme
//!
//! Three levels, mapped onto the same quantities the simulator charges
//! cycles for:
//!
//! 1. **L2 blocks** — the outer `(tn, tk)` loops iterate the tile
//!    schedule produced by the simulator's [`solve_tile`] solver, so the
//!    execution order is the one the cycle model accounts (M/N/K blocking
//!    with K-accumulation, reduction kept as long as the budget allows);
//! 2. **packed panels** — inside a block, operands are re-laid-out into
//!    contiguous panels: A as `MR`-row panels (`[k][MR]`, column-major
//!    within the panel), B as `NR`-column panels (`[k][NR]`). Packing is
//!    where *strides die*: the backward passes feed transposed views
//!    through the same pack routine, so BW-ERR/BW-GRAD never materialize
//!    a transposed matrix, and the 3x3-conv path performs im2col directly
//!    into the A panel (no `[rows, 9*C]` intermediate);
//! 3. **register micro-tiles** — an `MR x NR` accumulator updated with a
//!    rank-1 step per packed `k`; both inner dimensions are compile-time
//!    constants so the compiler keeps the accumulator in registers and
//!    vectorizes the `NR` loop.
//!
//! ## Threading
//!
//! Row panels (the M dimension) are split into chunks by the engine's
//! LOGICAL thread count and dispatched onto the process-wide persistent
//! [`crate::exec::ExecPool`] — the same geometry the paper uses to split
//! output rows over the 8 PULP cores, minus the per-call spawn: a
//! steady-state frozen forward performs ZERO `thread::spawn` calls
//! (asserted in `rust/tests/exec.rs`). Each chunk owns a disjoint slice
//! of the output and the split is a pure function of
//! `(rows, Engine::threads)` — never of the pool's physical width — so
//! the parallel path needs no synchronization and is bit-deterministic:
//! results are identical for every thread count AND every pool width
//! (each output element is always reduced in the same order).

use std::sync::OnceLock;

use crate::simulator::tiling::{solve_tile, MatmulGeom, TileDims};
use crate::telemetry::{global_span, Counter, EventKind};

/// Register-block rows (output rows per micro-tile).
pub const MR: usize = 8;
/// Register-block columns (output columns per micro-tile).
pub const NR: usize = 8;

/// Integer-path register-block rows.
pub const MR_I8: usize = 8;
/// Integer-path register-block columns. Wider than the f32 tile: the
/// paired-`i16` micro-kernel retires two MACs per i32 accumulator lane
/// (the `pmaddwd` shape), so the sweet spot sits at 2x the f32 width
/// (measured ~3x the blocked-f32 GMAC/s in `tools/perf_mirror.c`).
pub const NR_I8: usize = 32;

/// Largest reduction length the integer kernels accept: worst-case
/// `|Σ q_x·q_w| <= K · 255 · 256` must stay inside the i32 accumulator.
pub const MAX_K_I8: usize = 32_000;

/// Default L2 block budget the tile solver blocks against. Chosen like
/// the simulator's default L1 sweep midpoint: big enough that whole
/// MicroNet layers are a single block, small enough to keep a packed
/// tile set cache-resident on typical hosts.
pub const DEFAULT_L2_BYTES: usize = 256 * 1024;

/// A configured kernel engine: thread count + L2 block budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Engine {
    pub threads: usize,
    pub l2_bytes: usize,
}

impl Engine {
    /// Host-sized engine: `TINYCL_THREADS` or the available parallelism.
    pub fn auto() -> Engine {
        Engine { threads: default_threads(), l2_bytes: DEFAULT_L2_BYTES }
    }

    /// Fixed thread count (property tests sweep {1, 2, 8}).
    pub fn with_threads(threads: usize) -> Engine {
        Engine { threads: threads.max(1), l2_bytes: DEFAULT_L2_BYTES }
    }

    /// Single-threaded engine blocking against an explicit budget — the
    /// configuration `matmul_fw_tiled` exposes for L1-sweep experiments.
    pub fn tiled(l2_bytes: usize) -> Engine {
        Engine { threads: 1, l2_bytes }
    }

    // ---- matmul passes --------------------------------------------------

    /// FW: `out[M,N] = x[M,K] @ w[K,N]`. Overwrites `out`.
    pub fn matmul_fw_into(
        &self,
        x: &[f32],
        w: &[f32],
        m: usize,
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        assert_eq!(x.len(), m * k, "x size mismatch");
        assert_eq!(w.len(), k * n, "w size mismatch");
        let _sp = global_span(EventKind::KernelMatmulF32)
            .payload(m as u64, n as u64)
            .counter(Counter::KernelCalls);
        let a = StridedMat { data: x, rs: k, cs: 1 };
        let b = StridedMat { data: w, rs: n, cs: 1 };
        out.fill(0.0);
        gemm_into(&a, &b, m, n, k, self.threads, self.l2_bytes, out);
    }

    /// BW-ERR: `out[M,K] = g[M,N] @ w[K,N]^T`. The transposed weight view
    /// is expressed as pack-time strides — nothing is materialized.
    pub fn matmul_bw_err_into(
        &self,
        g: &[f32],
        w: &[f32],
        m: usize,
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        assert_eq!(g.len(), m * n, "g size mismatch");
        assert_eq!(w.len(), k * n, "w size mismatch");
        let _sp = global_span(EventKind::KernelMatmulF32)
            .payload(m as u64, k as u64)
            .counter(Counter::KernelCalls);
        let a = StridedMat { data: g, rs: n, cs: 1 };
        // B = w^T as a [N, K] view: element (p, j) = w[j*n + p]
        let b = StridedMat { data: w, rs: 1, cs: n };
        out.fill(0.0);
        gemm_into(&a, &b, m, k, n, self.threads, self.l2_bytes, out);
    }

    /// BW-GRAD: `out[K,N] = x[M,K]^T @ g[M,N]`, transposed-x view packed
    /// on the fly.
    pub fn matmul_bw_grad_into(
        &self,
        x: &[f32],
        g: &[f32],
        m: usize,
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        assert_eq!(x.len(), m * k, "x size mismatch");
        assert_eq!(g.len(), m * n, "g size mismatch");
        let _sp = global_span(EventKind::KernelMatmulF32)
            .payload(k as u64, n as u64)
            .counter(Counter::KernelCalls);
        // A = x^T as a [K, M] view: element (i, p) = x[p*k + i]
        let a = StridedMat { data: x, rs: 1, cs: k };
        let b = StridedMat { data: g, rs: n, cs: 1 };
        out.fill(0.0);
        gemm_into(&a, &b, k, n, m, self.threads, self.l2_bytes, out);
    }

    /// Cross-tenant grouped FW: `x[M,K]` rows are partitioned into
    /// consecutive groups, each multiplied by **its own** `[K, N]` weight
    /// matrix — `out[r] = x[r] @ w[group(r)]`. This is the fleet server's
    /// batched-inference kernel: one engine call spans every tenant in a
    /// coalesced batch, so row-panel threading parallelizes across tenant
    /// boundaries instead of launching one tiny matmul per tenant.
    ///
    /// `groups` is `(rows, weights)` per consecutive row range. Bit-exact
    /// with per-group [`Engine::matmul_fw_into`] calls at any thread
    /// count: each output element reduces over `k` in ascending order
    /// inside exactly one worker, and the tile solve depends only on
    /// `(total_rows, n, k)` — never on the group split.
    pub fn matmul_fw_grouped_into(
        &self,
        x: &[f32],
        groups: &[(usize, &[f32])],
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        let m: usize = groups.iter().map(|(rows, _)| rows).sum();
        let _sp = global_span(EventKind::KernelMatmulF32)
            .payload(m as u64, n as u64)
            .counter(Counter::KernelCalls);
        assert_eq!(x.len(), m * k, "x size mismatch");
        assert_eq!(out.len(), m * n, "out size mismatch");
        for (gi, (_, w)) in groups.iter().enumerate() {
            assert_eq!(w.len(), k * n, "group {gi} weight size mismatch");
        }
        out.fill(0.0);
        if m == 0 || n == 0 || k == 0 {
            return;
        }
        let geom = MatmulGeom { m, n, k, scratch_per_row: 0 };
        let dims = solve_tile(&geom, self.l2_bytes);
        // group start rows (prefix sums)
        let mut starts = Vec::with_capacity(groups.len() + 1);
        let mut acc = 0;
        for (rows, _) in groups {
            starts.push(acc);
            acc += rows;
        }
        starts.push(acc);
        let work = |row0: usize, rows: usize, chunk: &mut [f32]| {
            for (gi, &(_, w)) in groups.iter().enumerate() {
                let lo = row0.max(starts[gi]);
                let hi = (row0 + rows).min(starts[gi + 1]);
                if lo >= hi {
                    continue;
                }
                let a = StridedMat { data: x, rs: k, cs: 1 };
                let b = StridedMat { data: w, rs: n, cs: 1 };
                gemm_rows(
                    &a,
                    &b,
                    lo,
                    hi - lo,
                    n,
                    k,
                    dims,
                    &mut chunk[(lo - row0) * n..(hi - row0) * n],
                );
            }
        };
        let panels = m.div_ceil(MR);
        let threads = self.threads.max(1).min(panels);
        if threads <= 1 {
            work(0, m, out);
            return;
        }
        let rows_per = panels.div_ceil(threads) * MR;
        crate::exec::global().parallel_rows_mut(out, n, m, rows_per, work);
    }

    // ---- convolution passes ---------------------------------------------

    /// Fused 3x3 conv forward (pad=1): im2col happens *inside* A-panel
    /// packing, skipping the `[rows, 9*C]` intermediate entirely.
    /// `wmat` is the `[9*C, Cout]` weight matrix ((ky,kx,c) row order,
    /// identical to [`super::im2col3x3`]'s column order); `out` is
    /// `[B*Ho*Wo, Cout]`.
    #[allow(clippy::too_many_arguments)]
    pub fn conv3x3_fw_into(
        &self,
        x: &[f32],
        wmat: &[f32],
        b: usize,
        h: usize,
        w: usize,
        c: usize,
        stride: usize,
        cout: usize,
        out: &mut [f32],
    ) {
        assert_eq!(x.len(), b * h * w * c, "x size mismatch");
        assert_eq!(wmat.len(), 9 * c * cout, "wmat size mismatch");
        let ho = h.div_ceil(stride);
        let wo = w.div_ceil(stride);
        let rows = b * ho * wo;
        assert_eq!(out.len(), rows * cout, "out size mismatch");
        let _sp = global_span(EventKind::KernelConv3x3)
            .payload(rows as u64, cout as u64)
            .counter(Counter::KernelCalls);
        let a = Im2colMat { x, h, w, c, stride, ho, wo };
        let bm = StridedMat { data: wmat, rs: cout, cs: 1 };
        out.fill(0.0);
        gemm_into(&a, &bm, rows, cout, 9 * c, self.threads, self.l2_bytes, out);
    }

    /// 3x3 depthwise conv forward (pad=1), output rows split across the
    /// engine's workers. Identical per-element accumulation order to the
    /// single-threaded reference, hence bit-exact at any thread count.
    #[allow(clippy::too_many_arguments)]
    pub fn depthwise_fw_into(
        &self,
        x: &[f32],
        kern: &[f32],
        b: usize,
        h: usize,
        w: usize,
        c: usize,
        stride: usize,
        out: &mut [f32],
    ) {
        assert_eq!(x.len(), b * h * w * c, "x size mismatch");
        assert_eq!(kern.len(), 9 * c, "kern size mismatch");
        let ho = h.div_ceil(stride);
        let wo = w.div_ceil(stride);
        assert_eq!(out.len(), b * ho * wo * c, "out size mismatch");
        let _sp = global_span(EventKind::KernelDepthwise)
            .payload((b * ho * wo) as u64, c as u64)
            .counter(Counter::KernelCalls);
        out.fill(0.0);
        let total_rows = b * ho;
        let threads = self.threads.max(1).min(total_rows.max(1));
        if threads <= 1 {
            dw_rows(x, kern, 0, total_rows, h, w, c, ho, wo, stride, out);
            return;
        }
        let rows_per = total_rows.div_ceil(threads);
        crate::exec::global().parallel_rows_mut(
            out,
            wo * c,
            total_rows,
            rows_per,
            |r0, rows, chunk| dw_rows(x, kern, r0, rows, h, w, c, ho, wo, stride, chunk),
        );
    }
    // ---- integer (i8×i8→i32) passes -------------------------------------
    //
    // The true-INT8 frozen-stage kernels: activations are UINT-8 codes,
    // weights are the i8 codes of `quant::requant::quantize_weights_i8`
    // (level `q = code + w_off`), and every output element is the EXACT
    // signed integer accumulation
    //
    //     out[i, j] = Σ_k  x[i, k] · (w[k, j] + w_off)
    //               = Σ_k  x[i, k] · w[k, j]  +  w_off · Σ_k x[i, k]
    //
    // — the dot product of the stored codes plus the per-row zero-point
    // correction, folded in via one cheap row-sum pass. Integer
    // accumulation is associative, so the blocked/parallel results are
    // bit-identical to the naive oracles at any thread count, tile
    // budget and batch width — no tolerance anywhere.

    /// Integer FW: `out[M,N] = x[M,K] · (w[K,N] + w_off)` over u8
    /// activation codes and i8 weight codes, i32 accumulation.
    /// Bit-exact vs [`super::matmul_fw_i8_naive`].
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_fw_i8_into(
        &self,
        x: &[u8],
        w: &[i8],
        w_off: i32,
        m: usize,
        k: usize,
        n: usize,
        out: &mut [i32],
    ) {
        assert_eq!(x.len(), m * k, "x size mismatch");
        assert_eq!(w.len(), k * n, "w size mismatch");
        assert!(k <= MAX_K_I8, "i8 reduction K={k} exceeds i32 headroom");
        let _sp = global_span(EventKind::KernelMatmulI8)
            .payload(m as u64, n as u64)
            .counter(Counter::KernelCalls);
        let a = StridedMatU8 { data: x, rs: k, cs: 1 };
        out.fill(0);
        gemm_i8_into(&a, w, w_off, m, n, k, self.threads, self.l2_bytes, out);
    }

    /// Cross-tenant grouped integer FW — the i8 sibling of
    /// [`Engine::matmul_fw_grouped_into`]: consecutive row groups of `x`,
    /// each against its own `[K, N]` i8 weight matrix and zero-point
    /// correction. Bit-exact vs per-group [`Engine::matmul_fw_i8_into`]
    /// calls at any thread count (integer accumulation, same split
    /// geometry as the f32 grouped kernel).
    ///
    /// Not yet dispatched on the serving path: the fleet's *frozen*
    /// coalescing is single-weight (one shared backbone) and reaches the
    /// integer kernels through `frozen_forward`, while the trained
    /// per-tenant heads stay f32. This is the kernel the ROADMAP's
    /// "INT8 adaptive-stage inference" step lands on (quantize trained
    /// heads post-hoc, serve the grouped fleet batch in integers).
    pub fn matmul_fw_i8_grouped_into(
        &self,
        x: &[u8],
        groups: &[(usize, &[i8], i32)],
        k: usize,
        n: usize,
        out: &mut [i32],
    ) {
        let m: usize = groups.iter().map(|(rows, _, _)| rows).sum();
        let _sp = global_span(EventKind::KernelMatmulI8)
            .payload(m as u64, n as u64)
            .counter(Counter::KernelCalls);
        assert_eq!(x.len(), m * k, "x size mismatch");
        assert_eq!(out.len(), m * n, "out size mismatch");
        assert!(k <= MAX_K_I8, "i8 reduction K={k} exceeds i32 headroom");
        for (gi, (_, w, _)) in groups.iter().enumerate() {
            assert_eq!(w.len(), k * n, "group {gi} weight size mismatch");
        }
        out.fill(0);
        if m == 0 || n == 0 || k == 0 {
            return;
        }
        let geom = MatmulGeom { m, n, k, scratch_per_row: 0 };
        let dims = solve_tile(&geom, self.l2_bytes);
        let mut starts = Vec::with_capacity(groups.len() + 1);
        let mut acc = 0;
        for (rows, _, _) in groups {
            starts.push(acc);
            acc += rows;
        }
        starts.push(acc);
        let work = |row0: usize, rows: usize, chunk: &mut [i32]| {
            for (gi, &(_, w, w_off)) in groups.iter().enumerate() {
                let lo = row0.max(starts[gi]);
                let hi = (row0 + rows).min(starts[gi + 1]);
                if lo >= hi {
                    continue;
                }
                let a = StridedMatU8 { data: x, rs: k, cs: 1 };
                gemm_i8_rows(
                    &a,
                    w,
                    w_off,
                    lo,
                    hi - lo,
                    n,
                    k,
                    dims,
                    &mut chunk[(lo - row0) * n..(hi - row0) * n],
                );
            }
        };
        let panels = m.div_ceil(MR_I8);
        let threads = self.threads.max(1).min(panels);
        if threads <= 1 {
            work(0, m, out);
            return;
        }
        let rows_per = panels.div_ceil(threads) * MR_I8;
        crate::exec::global().parallel_rows_mut(out, n, m, rows_per, work);
    }

    /// Fused integer 3x3 conv forward (pad=1): im2col over u8 codes
    /// happens inside A-panel packing (zero padding decodes to code 0 —
    /// exactly what the FP32 path's zero-valued padding quantizes to).
    /// `wmat` is the `[9*C, Cout]` i8 weight matrix in the same
    /// (ky,kx,c) row order as the f32 conv.
    #[allow(clippy::too_many_arguments)]
    pub fn conv3x3_fw_i8_into(
        &self,
        x: &[u8],
        wmat: &[i8],
        w_off: i32,
        b: usize,
        h: usize,
        w: usize,
        c: usize,
        stride: usize,
        cout: usize,
        out: &mut [i32],
    ) {
        assert_eq!(x.len(), b * h * w * c, "x size mismatch");
        assert_eq!(wmat.len(), 9 * c * cout, "wmat size mismatch");
        assert!(9 * c <= MAX_K_I8, "i8 reduction K={} exceeds i32 headroom", 9 * c);
        let ho = h.div_ceil(stride);
        let wo = w.div_ceil(stride);
        let rows = b * ho * wo;
        assert_eq!(out.len(), rows * cout, "out size mismatch");
        let _sp = global_span(EventKind::KernelConv3x3)
            .payload(rows as u64, cout as u64)
            .counter(Counter::KernelCalls);
        let a = Im2colMatU8 { x, h, w, c, stride, ho, wo };
        out.fill(0);
        gemm_i8_into(&a, wmat, w_off, rows, cout, 9 * c, self.threads, self.l2_bytes, out);
    }

    /// Integer 3x3 depthwise conv forward (pad=1): per-channel taps over
    /// u8 codes with the zero-point correction folded in per output
    /// element (`dot + w_off · tapsum`). Row-split across workers,
    /// bit-exact at any thread count.
    #[allow(clippy::too_many_arguments)]
    pub fn depthwise_fw_i8_into(
        &self,
        x: &[u8],
        kern: &[i8],
        w_off: i32,
        b: usize,
        h: usize,
        w: usize,
        c: usize,
        stride: usize,
        out: &mut [i32],
    ) {
        assert_eq!(x.len(), b * h * w * c, "x size mismatch");
        assert_eq!(kern.len(), 9 * c, "kern size mismatch");
        let ho = h.div_ceil(stride);
        let wo = w.div_ceil(stride);
        assert_eq!(out.len(), b * ho * wo * c, "out size mismatch");
        let _sp = global_span(EventKind::KernelDepthwise)
            .payload((b * ho * wo) as u64, c as u64)
            .counter(Counter::KernelCalls);
        out.fill(0);
        let total_rows = b * ho;
        let threads = self.threads.max(1).min(total_rows.max(1));
        if threads <= 1 {
            dw_rows_i8(x, kern, w_off, 0, total_rows, h, w, c, ho, wo, stride, out);
            return;
        }
        let rows_per = total_rows.div_ceil(threads);
        crate::exec::global().parallel_rows_mut(
            out,
            wo * c,
            total_rows,
            rows_per,
            |r0, rows, chunk| {
                dw_rows_i8(x, kern, w_off, r0, rows, h, w, c, ho, wo, stride, chunk)
            },
        );
    }
}

/// Thread count the auto engine uses — delegated to the unified
/// [`crate::exec::ExecConfig`] resolution (`TINYCL_THREADS` overrides
/// the host's available parallelism).
pub fn default_threads() -> usize {
    crate::exec::ExecConfig::from_env().threads
}

/// The process-wide default engine (env/host sized, resolved once).
pub fn default_engine() -> Engine {
    static ENGINE: OnceLock<Engine> = OnceLock::new();
    *ENGINE.get_or_init(Engine::auto)
}

// ---- operand views ---------------------------------------------------------

/// Source of A/B panel elements. Implementations must be cheap at `at`
/// (it runs once per packed element) and `Sync` (packing happens inside
/// worker threads).
pub trait PanelSource: Sync {
    /// Element `(i, p)` of the logical `[rows, K]` (A) or `(p, j)` of the
    /// logical `[K, cols]` (B) operand.
    fn at(&self, i: usize, j: usize) -> f32;
}

/// A dense matrix viewed through row/column strides — covers the plain
/// and the transposed operands of all three passes with one type.
#[derive(Clone, Copy)]
pub struct StridedMat<'a> {
    pub data: &'a [f32],
    /// stride between consecutive first-index steps
    pub rs: usize,
    /// stride between consecutive second-index steps
    pub cs: usize,
}

impl PanelSource for StridedMat<'_> {
    #[inline(always)]
    fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.rs + j * self.cs]
    }
}

/// The im2col view of an NHWC activation for a pad-1 3x3 conv: logical
/// `[B*Ho*Wo, 9*C]` with (ky,kx,c) column order, zero padding decoded on
/// the fly during A-panel packing.
#[derive(Clone, Copy)]
pub struct Im2colMat<'a> {
    pub x: &'a [f32],
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub stride: usize,
    pub ho: usize,
    pub wo: usize,
}

impl PanelSource for Im2colMat<'_> {
    #[inline(always)]
    fn at(&self, row: usize, kcol: usize) -> f32 {
        let ox = row % self.wo;
        let t = row / self.wo;
        let oy = t % self.ho;
        let bi = t / self.ho;
        let ch = kcol % self.c;
        let t2 = kcol / self.c;
        let kx = t2 % 3;
        let ky = t2 / 3;
        let iy = (oy * self.stride + ky) as isize - 1;
        let ix = (ox * self.stride + kx) as isize - 1;
        if iy < 0 || ix < 0 || iy >= self.h as isize || ix >= self.w as isize {
            return 0.0; // zero padding
        }
        self.x[((bi * self.h + iy as usize) * self.w + ix as usize) * self.c + ch]
    }
}

// ---- the packed, blocked, parallel core ------------------------------------

/// `out[M,N] += A[M,K] @ B[K,N]` over panel sources, L2-blocked by the
/// simulator's tile solver and row-parallel across `threads` workers.
/// `out` must be exactly `m * n` elements (pre-zeroed by the callers).
#[allow(clippy::too_many_arguments)]
pub fn gemm_into<A: PanelSource, B: PanelSource>(
    a: &A,
    b: &B,
    m: usize,
    n: usize,
    k: usize,
    threads: usize,
    l2_bytes: usize,
    out: &mut [f32],
) {
    assert_eq!(out.len(), m * n, "gemm out size mismatch");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let geom = MatmulGeom { m, n, k, scratch_per_row: 0 };
    let dims = solve_tile(&geom, l2_bytes);

    let panels = m.div_ceil(MR);
    let threads = threads.max(1).min(panels);
    if threads <= 1 {
        gemm_rows(a, b, 0, m, n, k, dims, out);
        return;
    }
    // whole MR panels per chunk, so panel boundaries never straddle two
    // output chunks
    let rows_per = panels.div_ceil(threads) * MR;
    crate::exec::global().parallel_rows_mut(out, n, m, rows_per, |r0, rows, chunk| {
        gemm_rows(a, b, r0, rows, n, k, dims, chunk)
    });
}

/// One worker's share: rows `[row0, row0 + rows)` of the output, written
/// into `out` (local indexing from 0).
#[allow(clippy::too_many_arguments)]
fn gemm_rows<A: PanelSource, B: PanelSource>(
    a: &A,
    b: &B,
    row0: usize,
    rows: usize,
    n: usize,
    k: usize,
    dims: TileDims,
    out: &mut [f32],
) {
    let tk = dims.tk.max(1);
    let tn = dims.tn.max(1);
    let mut apack = vec![0f32; MR * tk];
    let mut bpack = vec![0f32; tk * tn.div_ceil(NR) * NR];
    let mut acc = [[0f32; NR]; MR];

    let mut n0 = 0;
    while n0 < n {
        let nb = tn.min(n - n0);
        let nb_panels = nb.div_ceil(NR);
        let mut k0 = 0;
        while k0 < k {
            let kb = tk.min(k - k0);
            // pack the B block: NR-column panels, contiguous per k step.
            // Each worker re-packs its own copy — duplicated across
            // threads, but the cost is O(K*N) against O(M*K*N/threads)
            // of compute (< 1% for M >> threads), and sharing it would
            // need a per-block barrier.
            for jp in 0..nb_panels {
                let j0 = n0 + jp * NR;
                let jw = NR.min(n0 + nb - j0);
                let dst = &mut bpack[jp * kb * NR..(jp + 1) * kb * NR];
                for p in 0..kb {
                    let row = &mut dst[p * NR..p * NR + NR];
                    for (c, slot) in row.iter_mut().enumerate().take(jw) {
                        *slot = b.at(k0 + p, j0 + c);
                    }
                    for slot in row.iter_mut().take(NR).skip(jw) {
                        *slot = 0.0;
                    }
                }
            }
            // MR-row A panels over this worker's rows
            let mut i0 = 0;
            while i0 < rows {
                let iw = MR.min(rows - i0);
                for p in 0..kb {
                    let dst = &mut apack[p * MR..p * MR + MR];
                    for (r, slot) in dst.iter_mut().enumerate().take(iw) {
                        *slot = a.at(row0 + i0 + r, k0 + p);
                    }
                    for slot in dst.iter_mut().take(MR).skip(iw) {
                        *slot = 0.0;
                    }
                }
                for jp in 0..nb_panels {
                    let j0 = n0 + jp * NR;
                    let jw = NR.min(n0 + nb - j0);
                    for row in acc.iter_mut() {
                        *row = [0.0; NR];
                    }
                    microkernel(
                        kb,
                        &apack[..kb * MR],
                        &bpack[jp * kb * NR..(jp + 1) * kb * NR],
                        &mut acc,
                    );
                    for (r, acc_row) in acc.iter().enumerate().take(iw) {
                        let o = (i0 + r) * n + j0;
                        let orow = &mut out[o..o + jw];
                        for (slot, v) in orow.iter_mut().zip(acc_row.iter()) {
                            *slot += v;
                        }
                    }
                }
                i0 += MR;
            }
            k0 += kb;
        }
        n0 += nb;
    }
}

/// The register micro-kernel: one rank-1 update of the `MR x NR`
/// accumulator per packed `k` step. `a` is `[kc][MR]`, `b` is `[kc][NR]`.
#[inline]
fn microkernel(kc: usize, a: &[f32], b: &[f32], acc: &mut [[f32; NR]; MR]) {
    debug_assert!(a.len() >= kc * MR && b.len() >= kc * NR);
    for p in 0..kc {
        let ar: &[f32; MR] = a[p * MR..p * MR + MR].try_into().unwrap();
        let br: &[f32; NR] = b[p * NR..p * NR + NR].try_into().unwrap();
        for (r, acc_row) in acc.iter_mut().enumerate() {
            let av = ar[r];
            for (c, slot) in acc_row.iter_mut().enumerate() {
                *slot += av * br[c];
            }
        }
    }
}

/// One worker's share of a depthwise forward: output rows
/// `[row0, row0 + rows)` where a row is one `(batch, oy)` strip of
/// `wo * c` elements.
#[allow(clippy::too_many_arguments)]
fn dw_rows(
    x: &[f32],
    kern: &[f32],
    row0: usize,
    rows: usize,
    h: usize,
    w: usize,
    c: usize,
    ho: usize,
    wo: usize,
    stride: usize,
    out: &mut [f32],
) {
    for rr in 0..rows {
        let gr = row0 + rr;
        let bi = gr / ho;
        let oy = gr % ho;
        for ox in 0..wo {
            let dst = &mut out[(rr * wo + ox) * c..(rr * wo + ox + 1) * c];
            for ky in 0..3 {
                let iy = (oy * stride + ky) as isize - 1;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for kx in 0..3 {
                    let ix = (ox * stride + kx) as isize - 1;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let src = ((bi * h + iy as usize) * w + ix as usize) * c;
                    let kf = (ky * 3 + kx) * c;
                    for ch in 0..c {
                        dst[ch] += x[src + ch] * kern[kf + ch];
                    }
                }
            }
        }
    }
}

// ---- the integer packed core -----------------------------------------------

/// Source of u8 activation-code panel elements for the integer GEMM —
/// the u8 twin of [`PanelSource`].
pub trait PanelSourceU8: Sync {
    /// Element `(i, p)` of the logical `[rows, K]` operand.
    fn at(&self, i: usize, j: usize) -> u8;
}

/// Dense u8 code matrix viewed through strides.
#[derive(Clone, Copy)]
pub struct StridedMatU8<'a> {
    pub data: &'a [u8],
    pub rs: usize,
    pub cs: usize,
}

impl PanelSourceU8 for StridedMatU8<'_> {
    #[inline(always)]
    fn at(&self, i: usize, j: usize) -> u8 {
        self.data[i * self.rs + j * self.cs]
    }
}

/// The im2col view of an NHWC u8 code tensor for a pad-1 3x3 conv:
/// logical `[B*Ho*Wo, 9*C]`, (ky,kx,c) column order, zero padding
/// decoded as code 0 (the quantization of a zero activation).
#[derive(Clone, Copy)]
pub struct Im2colMatU8<'a> {
    pub x: &'a [u8],
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub stride: usize,
    pub ho: usize,
    pub wo: usize,
}

impl PanelSourceU8 for Im2colMatU8<'_> {
    #[inline(always)]
    fn at(&self, row: usize, kcol: usize) -> u8 {
        let ox = row % self.wo;
        let t = row / self.wo;
        let oy = t % self.ho;
        let bi = t / self.ho;
        let ch = kcol % self.c;
        let t2 = kcol / self.c;
        let kx = t2 % 3;
        let ky = t2 / 3;
        let iy = (oy * self.stride + ky) as isize - 1;
        let ix = (ox * self.stride + kx) as isize - 1;
        if iy < 0 || ix < 0 || iy >= self.h as isize || ix >= self.w as isize {
            return 0; // zero padding == code 0
        }
        self.x[((bi * self.h + iy as usize) * self.w + ix as usize) * self.c + ch]
    }
}

/// Integer `out[M,N] = A[M,K] · (B[K,N] + w_off)` over a u8 panel source
/// and a contiguous i8 weight matrix, L2-blocked by the same tile solver
/// as the f32 core and row-parallel across `threads` workers. `out` must
/// be pre-zeroed. Exact integer accumulation — bit-identical for every
/// thread count and tile budget.
#[allow(clippy::too_many_arguments)]
pub fn gemm_i8_into<A: PanelSourceU8>(
    a: &A,
    w: &[i8],
    w_off: i32,
    m: usize,
    n: usize,
    k: usize,
    threads: usize,
    l2_bytes: usize,
    out: &mut [i32],
) {
    assert_eq!(out.len(), m * n, "gemm_i8 out size mismatch");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let geom = MatmulGeom { m, n, k, scratch_per_row: 0 };
    let dims = solve_tile(&geom, l2_bytes);

    let panels = m.div_ceil(MR_I8);
    let threads = threads.max(1).min(panels);
    if threads <= 1 {
        gemm_i8_rows(a, w, w_off, 0, m, n, k, dims, out);
        return;
    }
    let rows_per = panels.div_ceil(threads) * MR_I8;
    crate::exec::global().parallel_rows_mut(out, n, m, rows_per, |r0, rows, chunk| {
        gemm_i8_rows(a, w, w_off, r0, rows, n, k, dims, chunk)
    });
}

/// One worker's share of the integer GEMM: rows `[row0, row0 + rows)`,
/// written into `out` (local indexing). Operands are re-laid-out into
/// **pair-interleaved i16 panels** — A as `[⌈k/2⌉][MR_I8][2]`, B as
/// `[⌈k/2⌉][NR_I8][2]` — so the micro-kernel's inner step is
/// `acc += a0·b0 + a1·b1` over adjacent k pairs: two MACs per i32 lane,
/// the `pmaddwd` dataflow PULP-NN's 8-bit SIMD MACs map to. The i16
/// widening is exact (u8 and i8 both embed in i16) and products stay
/// far inside i32.
#[allow(clippy::too_many_arguments)]
fn gemm_i8_rows<A: PanelSourceU8>(
    a: &A,
    w: &[i8],
    w_off: i32,
    row0: usize,
    rows: usize,
    n: usize,
    k: usize,
    dims: TileDims,
    out: &mut [i32],
) {
    let tk = dims.tk.max(1);
    let tn = dims.tn.max(1);
    let kp_max = tk.div_ceil(2);
    let mut apack = vec![0i16; kp_max * MR_I8 * 2];
    let mut bpack = vec![0i16; kp_max * tn.div_ceil(NR_I8) * NR_I8 * 2];
    let mut acc = [[0i32; NR_I8]; MR_I8];
    // zero-point row sums (`w_off · Σ_k a(r, k)` is added at the end),
    // accumulated DURING the first n-block's A-packing pass — each
    // (row, k) element is packed exactly once per n block, so the
    // n0 == 0 packs see every k and the A source is decoded only once
    // (this matters for the im2col stem, whose `at` is division-heavy)
    let mut rowsum = vec![0i32; rows];

    let mut n0 = 0;
    while n0 < n {
        let nb = tn.min(n - n0);
        let nb_panels = nb.div_ceil(NR_I8);
        let mut k0 = 0;
        while k0 < k {
            let kb = tk.min(k - k0);
            let kp = kb.div_ceil(2);
            // pack the B block: NR_I8-column panels, adjacent k steps
            // interleaved per column ([p/2][c][p%2]); ragged edges and
            // the odd-k tail pad with 0
            for jp in 0..nb_panels {
                let j0 = n0 + jp * NR_I8;
                let jw = NR_I8.min(n0 + nb - j0);
                let dst = &mut bpack[jp * kp * NR_I8 * 2..(jp + 1) * kp * NR_I8 * 2];
                dst.fill(0);
                for p in 0..kb {
                    let src = &w[(k0 + p) * n + j0..(k0 + p) * n + j0 + jw];
                    let half = p & 1;
                    let d = &mut dst[(p >> 1) * NR_I8 * 2..(p >> 1) * NR_I8 * 2 + NR_I8 * 2];
                    for (cidx, &v) in src.iter().enumerate() {
                        d[cidx * 2 + half] = v as i16;
                    }
                }
            }
            // MR_I8-row A panels over this worker's rows
            let mut i0 = 0;
            while i0 < rows {
                let iw = MR_I8.min(rows - i0);
                let adst = &mut apack[..kp * MR_I8 * 2];
                adst.fill(0);
                for p in 0..kb {
                    let half = p & 1;
                    let d = &mut adst[(p >> 1) * MR_I8 * 2..(p >> 1) * MR_I8 * 2 + MR_I8 * 2];
                    for r in 0..iw {
                        d[r * 2 + half] = a.at(row0 + i0 + r, k0 + p) as i16;
                    }
                }
                if n0 == 0 {
                    for p in 0..kb {
                        let base = (p >> 1) * MR_I8 * 2 + (p & 1);
                        for r in 0..iw {
                            rowsum[i0 + r] += adst[base + r * 2] as i32;
                        }
                    }
                }
                for jp in 0..nb_panels {
                    let j0 = n0 + jp * NR_I8;
                    let jw = NR_I8.min(n0 + nb - j0);
                    for row in acc.iter_mut() {
                        *row = [0; NR_I8];
                    }
                    let bp = &bpack[jp * kp * NR_I8 * 2..(jp + 1) * kp * NR_I8 * 2];
                    if jw <= NR_I8 / 2 {
                        microkernel_i8_half(kp, &apack[..kp * MR_I8 * 2], bp, &mut acc);
                    } else {
                        microkernel_i8(kp, &apack[..kp * MR_I8 * 2], bp, &mut acc);
                    }
                    for (r, acc_row) in acc.iter().enumerate().take(iw) {
                        let o = (i0 + r) * n + j0;
                        let orow = &mut out[o..o + jw];
                        for (slot, &v) in orow.iter_mut().zip(acc_row.iter()) {
                            *slot += v;
                        }
                    }
                }
                i0 += MR_I8;
            }
            k0 += kb;
        }
        n0 += nb;
    }
    if w_off != 0 {
        for (r, &sum) in rowsum.iter().enumerate() {
            let base = w_off * sum;
            for slot in out[r * n..(r + 1) * n].iter_mut() {
                *slot += base;
            }
        }
    }
}

/// The integer register micro-kernel: one paired rank-2 update of the
/// `MR_I8 x NR_I8` i32 accumulator per packed k-pair. `a` is
/// `[kp][MR_I8][2]`, `b` is `[kp][NR_I8][2]`; both inner trip counts are
/// compile-time constants so the compiler maps the
/// `a0·b0 + a1·b1` step onto packed 16-bit multiply-add lanes.
#[inline]
fn microkernel_i8(kp: usize, a: &[i16], b: &[i16], acc: &mut [[i32; NR_I8]; MR_I8]) {
    debug_assert!(a.len() >= kp * MR_I8 * 2 && b.len() >= kp * NR_I8 * 2);
    for p in 0..kp {
        let ap: &[i16; MR_I8 * 2] = a[p * MR_I8 * 2..(p + 1) * MR_I8 * 2].try_into().unwrap();
        let bp: &[i16; NR_I8 * 2] = b[p * NR_I8 * 2..(p + 1) * NR_I8 * 2].try_into().unwrap();
        for (r, acc_row) in acc.iter_mut().enumerate() {
            let a0 = ap[r * 2] as i32;
            let a1 = ap[r * 2 + 1] as i32;
            for (c, slot) in acc_row.iter_mut().enumerate() {
                *slot += a0 * bp[c * 2] as i32 + a1 * bp[c * 2 + 1] as i32;
            }
        }
    }
}

/// The narrow-N fallback micro-kernel: same packed layout, first
/// `NR_I8 / 2` lanes only — a panel whose live width is ≤ half the tile
/// (e.g. the stem conv's 16 output channels) would waste half its MACs
/// on zero columns in the full-width kernel.
#[inline]
fn microkernel_i8_half(kp: usize, a: &[i16], b: &[i16], acc: &mut [[i32; NR_I8]; MR_I8]) {
    debug_assert!(a.len() >= kp * MR_I8 * 2 && b.len() >= kp * NR_I8 * 2);
    for p in 0..kp {
        let ap: &[i16; MR_I8 * 2] = a[p * MR_I8 * 2..(p + 1) * MR_I8 * 2].try_into().unwrap();
        let bp = &b[p * NR_I8 * 2..(p + 1) * NR_I8 * 2];
        for (r, acc_row) in acc.iter_mut().enumerate() {
            let a0 = ap[r * 2] as i32;
            let a1 = ap[r * 2 + 1] as i32;
            for (c, slot) in acc_row.iter_mut().enumerate().take(NR_I8 / 2) {
                *slot += a0 * bp[c * 2] as i32 + a1 * bp[c * 2 + 1] as i32;
            }
        }
    }
}

/// One worker's share of the integer depthwise forward: output rows
/// `[row0, row0 + rows)` where a row is one `(batch, oy)` strip of
/// `wo * c` i32 accumulators (`dot + w_off · tapsum` per element).
#[allow(clippy::too_many_arguments)]
fn dw_rows_i8(
    x: &[u8],
    kern: &[i8],
    w_off: i32,
    row0: usize,
    rows: usize,
    h: usize,
    w: usize,
    c: usize,
    ho: usize,
    wo: usize,
    stride: usize,
    out: &mut [i32],
) {
    let mut tap = vec![0i32; c];
    for rr in 0..rows {
        let gr = row0 + rr;
        let bi = gr / ho;
        let oy = gr % ho;
        for ox in 0..wo {
            let dst = &mut out[(rr * wo + ox) * c..(rr * wo + ox + 1) * c];
            tap.fill(0);
            for ky in 0..3 {
                let iy = (oy * stride + ky) as isize - 1;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for kx in 0..3 {
                    let ix = (ox * stride + kx) as isize - 1;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let src = ((bi * h + iy as usize) * w + ix as usize) * c;
                    let kf = (ky * 3 + kx) * c;
                    for ch in 0..c {
                        let xv = x[src + ch] as i32;
                        dst[ch] += xv * kern[kf + ch] as i32;
                        tap[ch] += xv;
                    }
                }
            }
            for (d, &t) in dst.iter_mut().zip(tap.iter()) {
                *d += w_off * t;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn naive_fw(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        super::super::matmul_fw_naive(x, w, m, k, n)
    }

    #[test]
    fn fw_matches_naive_across_threads_and_ragged_shapes() {
        prop::check("engine fw", 48, |rng| {
            let m = prop::int_in(rng, 1, 70);
            let k = prop::int_in(rng, 1, 70);
            let n = prop::int_in(rng, 1, 70);
            let x = randv(rng, m * k);
            let w = randv(rng, k * n);
            let reference = naive_fw(&x, &w, m, k, n);
            for threads in [1usize, 2, 8] {
                let eng = Engine { threads, l2_bytes: 4096 };
                let mut out = vec![0f32; m * n];
                eng.matmul_fw_into(&x, &w, m, k, n, &mut out);
                for (i, (a, b)) in reference.iter().zip(&out).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-3 * k as f32,
                        "threads={threads} m={m} k={k} n={n} i={i}: {a} vs {b}"
                    );
                }
            }
        });
    }

    #[test]
    fn bw_err_matches_naive_across_threads() {
        prop::check("engine bw-err", 48, |rng| {
            let m = prop::int_in(rng, 1, 50);
            let k = prop::int_in(rng, 1, 50);
            let n = prop::int_in(rng, 1, 50);
            let g = randv(rng, m * n);
            let w = randv(rng, k * n);
            let reference = super::super::matmul_bw_err_naive(&g, &w, m, k, n);
            for threads in [1usize, 2, 8] {
                let eng = Engine { threads, l2_bytes: 4096 };
                let mut out = vec![0f32; m * k];
                eng.matmul_bw_err_into(&g, &w, m, k, n, &mut out);
                for (a, b) in reference.iter().zip(&out) {
                    assert!((a - b).abs() < 1e-3 * n as f32, "threads={threads}");
                }
            }
        });
    }

    #[test]
    fn bw_grad_matches_naive_across_threads() {
        prop::check("engine bw-grad", 48, |rng| {
            let m = prop::int_in(rng, 1, 50);
            let k = prop::int_in(rng, 1, 50);
            let n = prop::int_in(rng, 1, 50);
            let x = randv(rng, m * k);
            let g = randv(rng, m * n);
            let reference = super::super::matmul_bw_grad_naive(&x, &g, m, k, n);
            for threads in [1usize, 2, 8] {
                let eng = Engine { threads, l2_bytes: 4096 };
                let mut out = vec![0f32; k * n];
                eng.matmul_bw_grad_into(&x, &g, m, k, n, &mut out);
                for (a, b) in reference.iter().zip(&out) {
                    assert!((a - b).abs() < 1e-3 * m as f32, "threads={threads}");
                }
            }
        });
    }

    #[test]
    fn results_are_bit_deterministic_across_thread_counts() {
        // each output element reduces in the same order regardless of the
        // worker split, so results are identical — not just close
        let mut rng = Rng::new(11);
        let (m, k, n) = (37, 29, 23);
        let x = randv(&mut rng, m * k);
        let w = randv(&mut rng, k * n);
        let run = |threads: usize| {
            let mut out = vec![0f32; m * n];
            Engine { threads, l2_bytes: 4096 }.matmul_fw_into(&x, &w, m, k, n, &mut out);
            out
        };
        let one = run(1);
        assert_eq!(one, run(2));
        assert_eq!(one, run(8));
    }

    #[test]
    fn grouped_fw_matches_per_group_calls_bit_exact() {
        // the fleet's cross-tenant batched head: one grouped call must be
        // bit-identical to one matmul per tenant, at any thread count and
        // for ragged group sizes (including empty and 1-row groups)
        prop::check("engine grouped fw", 48, |rng| {
            let k = prop::int_in(rng, 1, 40);
            let n = prop::int_in(rng, 1, 24);
            let n_groups = prop::int_in(rng, 1, 6);
            let sizes: Vec<usize> = (0..n_groups).map(|_| rng.below(20)).collect();
            let m: usize = sizes.iter().sum();
            let x = randv(rng, m * k);
            let ws: Vec<Vec<f32>> = (0..n_groups).map(|_| randv(rng, k * n)).collect();
            // reference: one engine call per group
            let mut reference = vec![0f32; m * n];
            let eng1 = Engine { threads: 1, l2_bytes: 4096 };
            let mut r0 = 0;
            for (rows, w) in sizes.iter().zip(&ws) {
                if *rows > 0 {
                    eng1.matmul_fw_into(
                        &x[r0 * k..(r0 + rows) * k],
                        w,
                        *rows,
                        k,
                        n,
                        &mut reference[r0 * n..(r0 + rows) * n],
                    );
                }
                r0 += rows;
            }
            let groups: Vec<(usize, &[f32])> =
                sizes.iter().zip(&ws).map(|(&r, w)| (r, w.as_slice())).collect();
            for threads in [1usize, 2, 8] {
                let eng = Engine { threads, l2_bytes: 4096 };
                let mut out = vec![0f32; m * n];
                eng.matmul_fw_grouped_into(&x, &groups, k, n, &mut out);
                assert_eq!(reference, out, "threads={threads} sizes={sizes:?}");
            }
        });
    }

    #[test]
    fn row_results_do_not_depend_on_batch_width() {
        // the property cross-tenant frozen coalescing leans on: a row's
        // output is bit-identical whether it runs alone or inside a wider
        // batch (ascending-k reduction, tile dims independent of M)
        let mut rng = Rng::new(17);
        let (k, n) = (96, 40);
        let w = randv(&mut rng, k * n);
        let x = randv(&mut rng, 24 * k);
        let eng = Engine { threads: 2, l2_bytes: DEFAULT_L2_BYTES };
        let mut wide = vec![0f32; 24 * n];
        eng.matmul_fw_into(&x, &w, 24, k, n, &mut wide);
        for row in [0usize, 7, 23] {
            let mut solo = vec![0f32; n];
            eng.matmul_fw_into(&x[row * k..(row + 1) * k], &w, 1, k, n, &mut solo);
            assert_eq!(&wide[row * n..(row + 1) * n], &solo[..], "row {row}");
        }
    }

    #[test]
    fn fused_conv_matches_im2col_reference() {
        prop::check("engine conv3x3", 32, |rng| {
            let b = prop::int_in(rng, 1, 2);
            let h = prop::int_in(rng, 2, 9);
            let w = prop::int_in(rng, 2, 9);
            let c = prop::int_in(rng, 1, 5);
            let cout = prop::int_in(rng, 1, 6);
            let stride = 1 + rng.below(2);
            let x = randv(rng, b * h * w * c);
            let wmat = randv(rng, 9 * c * cout);
            let cols = super::super::im2col3x3(&x, b, h, w, c, stride);
            let rows = cols.len() / (9 * c);
            let reference = naive_fw(&cols, &wmat, rows, 9 * c, cout);
            for threads in [1usize, 2, 8] {
                let eng = Engine { threads, l2_bytes: 4096 };
                let mut out = vec![0f32; rows * cout];
                eng.conv3x3_fw_into(&x, &wmat, b, h, w, c, stride, cout, &mut out);
                for (a, o) in reference.iter().zip(&out) {
                    assert!((a - o).abs() < 1e-3 * (9 * c) as f32, "threads={threads}");
                }
            }
        });
    }

    #[test]
    fn parallel_depthwise_is_bit_exact() {
        prop::check("engine depthwise", 32, |rng| {
            let b = prop::int_in(rng, 1, 3);
            let h = prop::int_in(rng, 1, 9);
            let w = prop::int_in(rng, 1, 9);
            let c = prop::int_in(rng, 1, 6);
            let stride = 1 + rng.below(2);
            let x = randv(rng, b * h * w * c);
            let kern = randv(rng, 9 * c);
            let reference = {
                let eng = Engine { threads: 1, l2_bytes: 4096 };
                let ho = h.div_ceil(stride);
                let wo = w.div_ceil(stride);
                let mut out = vec![0f32; b * ho * wo * c];
                eng.depthwise_fw_into(&x, &kern, b, h, w, c, stride, &mut out);
                out
            };
            for threads in [2usize, 8] {
                let eng = Engine { threads, l2_bytes: 4096 };
                let mut out = vec![0f32; reference.len()];
                eng.depthwise_fw_into(&x, &kern, b, h, w, c, stride, &mut out);
                assert_eq!(reference, out, "threads={threads}");
            }
        });
    }

    // ---- integer (i8) kernels ------------------------------------------

    fn rand_codes(rng: &mut Rng, n: usize) -> Vec<u8> {
        (0..n).map(|_| rng.below(256) as u8).collect()
    }

    fn rand_weights_i8(rng: &mut Rng, n: usize) -> Vec<i8> {
        (0..n).map(|_| rng.below(256) as i8).collect()
    }

    #[test]
    fn i8_fw_is_bit_exact_vs_naive_across_threads_and_ragged_shapes() {
        prop::check("engine i8 fw", 48, |rng| {
            let m = prop::int_in(rng, 1, 70);
            let k = prop::int_in(rng, 1, 70);
            let n = prop::int_in(rng, 1, 70);
            let w_off = prop::int_in(rng, 0, 255) as i32 - 127;
            let x = rand_codes(rng, m * k);
            let w = rand_weights_i8(rng, k * n);
            let reference = super::super::matmul_fw_i8_naive(&x, &w, w_off, m, k, n);
            for threads in [1usize, 2, 8] {
                let eng = Engine { threads, l2_bytes: 4096 };
                let mut out = vec![0i32; m * n];
                eng.matmul_fw_i8_into(&x, &w, w_off, m, k, n, &mut out);
                assert_eq!(reference, out, "threads={threads} m={m} k={k} n={n} off={w_off}");
            }
        });
    }

    #[test]
    fn i8_grouped_fw_is_bit_exact_vs_per_group_calls() {
        // the i8 sibling of the fleet's grouped head kernel: one grouped
        // call must equal one integer matmul per group, at any thread
        // count and for ragged group sizes (empty and 1-row included)
        prop::check("engine i8 grouped", 48, |rng| {
            let k = prop::int_in(rng, 1, 40);
            let n = prop::int_in(rng, 1, 40);
            let n_groups = prop::int_in(rng, 1, 6);
            let sizes: Vec<usize> = (0..n_groups).map(|_| rng.below(20)).collect();
            let m: usize = sizes.iter().sum();
            let x = rand_codes(rng, m * k);
            let ws: Vec<Vec<i8>> = (0..n_groups).map(|_| rand_weights_i8(rng, k * n)).collect();
            let offs: Vec<i32> =
                (0..n_groups).map(|_| prop::int_in(rng, 0, 255) as i32 - 127).collect();
            let mut reference = vec![0i32; m * n];
            let eng1 = Engine { threads: 1, l2_bytes: 4096 };
            let mut r0 = 0;
            for ((rows, w), &off) in sizes.iter().zip(&ws).zip(&offs) {
                if *rows > 0 {
                    eng1.matmul_fw_i8_into(
                        &x[r0 * k..(r0 + rows) * k],
                        w,
                        off,
                        *rows,
                        k,
                        n,
                        &mut reference[r0 * n..(r0 + rows) * n],
                    );
                }
                r0 += rows;
            }
            let groups: Vec<(usize, &[i8], i32)> = sizes
                .iter()
                .zip(&ws)
                .zip(&offs)
                .map(|((&r, w), &off)| (r, w.as_slice(), off))
                .collect();
            for threads in [1usize, 2, 8] {
                let eng = Engine { threads, l2_bytes: 4096 };
                let mut out = vec![0i32; m * n];
                eng.matmul_fw_i8_grouped_into(&x, &groups, k, n, &mut out);
                assert_eq!(reference, out, "threads={threads} sizes={sizes:?}");
            }
        });
    }

    #[test]
    fn i8_row_results_do_not_depend_on_batch_width() {
        // the property the frozen coalescer leans on, integer edition —
        // trivially true for exact arithmetic, pinned anyway
        let mut rng = Rng::new(23);
        let (k, n) = (96, 40);
        let w = rand_weights_i8(&mut rng, k * n);
        let x = rand_codes(&mut rng, 24 * k);
        let eng = Engine { threads: 2, l2_bytes: DEFAULT_L2_BYTES };
        let mut wide = vec![0i32; 24 * n];
        eng.matmul_fw_i8_into(&x, &w, -3, 24, k, n, &mut wide);
        for row in [0usize, 7, 23] {
            let mut solo = vec![0i32; n];
            eng.matmul_fw_i8_into(&x[row * k..(row + 1) * k], &w, -3, 1, k, n, &mut solo);
            assert_eq!(&wide[row * n..(row + 1) * n], &solo[..], "row {row}");
        }
    }

    #[test]
    fn i8_fused_conv_matches_u8_im2col_oracle() {
        prop::check("engine i8 conv3x3", 32, |rng| {
            let b = prop::int_in(rng, 1, 2);
            let h = prop::int_in(rng, 2, 9);
            let w = prop::int_in(rng, 2, 9);
            let c = prop::int_in(rng, 1, 5);
            let cout = prop::int_in(rng, 1, 6);
            let stride = 1 + rng.below(2);
            let w_off = prop::int_in(rng, 0, 255) as i32 - 127;
            let x = rand_codes(rng, b * h * w * c);
            let wmat = rand_weights_i8(rng, 9 * c * cout);
            let cols = super::super::im2col3x3_u8(&x, b, h, w, c, stride);
            let rows = cols.len() / (9 * c);
            let reference =
                super::super::matmul_fw_i8_naive(&cols, &wmat, w_off, rows, 9 * c, cout);
            for threads in [1usize, 2, 8] {
                let eng = Engine { threads, l2_bytes: 4096 };
                let mut out = vec![0i32; rows * cout];
                eng.conv3x3_fw_i8_into(&x, &wmat, w_off, b, h, w, c, stride, cout, &mut out);
                assert_eq!(reference, out, "threads={threads} stride={stride}");
            }
        });
    }

    #[test]
    fn i8_depthwise_matches_naive_across_threads() {
        prop::check("engine i8 depthwise", 32, |rng| {
            let b = prop::int_in(rng, 1, 3);
            let h = prop::int_in(rng, 1, 9);
            let w = prop::int_in(rng, 1, 9);
            let c = prop::int_in(rng, 1, 6);
            let stride = 1 + rng.below(2);
            let w_off = prop::int_in(rng, 0, 255) as i32 - 127;
            let x = rand_codes(rng, b * h * w * c);
            let kern = rand_weights_i8(rng, 9 * c);
            let reference =
                super::super::depthwise_fw_i8_naive(&x, &kern, w_off, b, h, w, c, stride);
            for threads in [1usize, 2, 8] {
                let eng = Engine { threads, l2_bytes: 4096 };
                let mut out = vec![0i32; reference.len()];
                eng.depthwise_fw_i8_into(&x, &kern, w_off, b, h, w, c, stride, &mut out);
                assert_eq!(reference, out, "threads={threads} stride={stride}");
            }
        });
    }

    #[test]
    fn i8_saturating_codes_stay_exact() {
        // worst-case magnitudes: all-255 activations against extreme
        // weights and offsets — the accumulator bound MAX_K_I8 protects
        let (m, k, n) = (4, 512, 8);
        let x = vec![255u8; m * k];
        for (wv, off) in [(i8::MIN, 128), (i8::MAX, -127), (i8::MIN, -127)] {
            let w = vec![wv; k * n];
            let eng = Engine { threads: 2, l2_bytes: 4096 };
            let mut out = vec![0i32; m * n];
            eng.matmul_fw_i8_into(&x, &w, off, m, k, n, &mut out);
            let expect = 255 * k as i32 * (wv as i32 + off);
            assert!(out.iter().all(|&v| v == expect), "wv={wv} off={off}");
        }
    }

    #[test]
    fn default_engine_is_cached_and_sane() {
        let e1 = default_engine();
        let e2 = default_engine();
        assert_eq!(e1, e2);
        assert!(e1.threads >= 1);
        assert!(e1.l2_bytes >= 4 * 1024);
    }
}
