//! Process-wide, zero-overhead-when-disabled telemetry: structured
//! spans, latency histograms and fleet SLO metrics from the kernel
//! engine up through the governor.
//!
//! The paper's central evidence is an instrumentation result — the
//! per-layer cycle breakdown of the QLR-CL pipeline (Fig. 8/9) that
//! yields the 65x claim. This module is that measurement layer for the
//! host runtime, built on the same discipline as `fleet::faults`:
//!
//! - **one-branch disabled path**: [`Telemetry`] is an
//!   `Option<Arc<Inner>>`, exactly the `FaultPlan::none()` shape. Every
//!   recording call starts with that branch; disabled telemetry takes
//!   no clock readings, touches no atomics, allocates nothing.
//! - **recording never perturbs outcomes**: instrumentation only ever
//!   *observes* (clock reads, ring writes, atomic bumps). Fleet results
//!   are byte-identical with telemetry off and on, at any worker count
//!   (`rust/tests/telemetry.rs` pins this).
//! - **zero-alloc hot path**: events are fixed-size [`Event`] records
//!   copied into per-thread ring buffers preallocated at construction;
//!   histograms and counters are plain atomics. The counting-allocator
//!   test (`rust/tests/alloc_telemetry.rs`) asserts the record path
//!   performs ZERO heap allocations.
//! - **single-writer rings**: each recording thread claims its own ring
//!   once (thread-local cache), so pushes are lock-free stores. When a
//!   ring wraps, the oldest events are overwritten and counted in
//!   `events_dropped` — the drop counter is itself a metric. Rings are
//!   read only at export time, after the run has quiesced.
//!
//! Span keys: where the code already has a deterministic op index (the
//! spill `write_ops`/`read_ops` counters the fault injector keys off,
//! the dispatch event sequence), that index is the span key, so a trace
//! lines up with a fault-injection replay of the same seed. Spans
//! without a natural index draw from a per-instance sequence.
//!
//! Export surfaces: [`TelemetryReport`] (embedded in `FleetReport`,
//! JSON via `to_json`), Chrome `trace_event` JSON ([`Telemetry::
//! chrome_trace`], viewable in Perfetto), and the `tinycl fleet
//! --telemetry/--trace` flags / `TINYCL_TELEMETRY` env knob.

pub mod hist;
pub mod trace;

use std::cell::Cell;
use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::coordinator::metrics::RobustnessSummary;
use crate::util::json::{arr, num, obj, s, Json};
pub use hist::{HistSummary, Histogram};

// ---- event vocabulary ------------------------------------------------------

/// Typed span/event kinds. Stored in [`Event`] as a raw `u8` so torn
/// ring reads can never manufacture an invalid enum value.
#[repr(u8)]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// f32 3x3 conv kernel call (`a` = rows, `b` = cout)
    KernelConv3x3 = 0,
    /// depthwise kernel call (f32 or i8; `a` = rows, `b` = channels)
    KernelDepthwise = 1,
    /// f32 GEMM kernel call (`a` = rows, `b` = n)
    KernelMatmulF32 = 2,
    /// integer i8 GEMM / conv kernel call (`a` = rows, `b` = n)
    KernelMatmulI8 = 3,
    /// one whole frozen forward through the split (`a` = batch rows,
    /// `b` = split layer l)
    FrozenForward = 4,
    /// one frozen layer inside a forward (`a` = layer index, `b` = rows)
    FrozenLayer = 5,
    /// one adaptive-stage train step (`a` = batch, `b` = split l)
    TrainStep = 6,
    /// one async eval sweep (`a` = tenants swept)
    EvalSweep = 7,
    /// one fleet event dispatched end-to-end (`a` = frames)
    Dispatch = 8,
    /// one coalesced cross-tenant frozen batch (`a` = events coalesced)
    Coalesce = 9,
    /// spill snapshot write, retries included (`a` = bytes, `b` = attempts)
    SpillWrite = 10,
    /// spill snapshot read, retries included (`a` = bytes, `b` = attempts)
    SpillRead = 11,
    /// one committed governor action (`a` = action tag, `b` = bytes moved)
    Governor = 12,
    /// one shed ingress event (`a` = retry-after ms)
    Shed = 13,
    /// service-ladder degrade step (`a` = new level)
    Degrade = 14,
    /// one in-sequence event applied by a tenant (`a` = batch rows;
    /// wraps the replay-train steps it triggers — the serve path)
    TenantApply = 15,
    /// one wire-protocol frame served by a shard connection handler
    /// (`a` = request op code, `b` = reply payload bytes)
    Frame = 16,
}

pub const N_EVENT_KINDS: usize = 17;

impl EventKind {
    pub fn name(self) -> &'static str {
        match self {
            EventKind::KernelConv3x3 => "kernel.conv3x3",
            EventKind::KernelDepthwise => "kernel.depthwise",
            EventKind::KernelMatmulF32 => "kernel.matmul_f32",
            EventKind::KernelMatmulI8 => "kernel.matmul_i8",
            EventKind::FrozenForward => "frozen.forward",
            EventKind::FrozenLayer => "frozen.layer",
            EventKind::TrainStep => "train.step",
            EventKind::EvalSweep => "eval.sweep",
            EventKind::Dispatch => "fleet.dispatch",
            EventKind::Coalesce => "fleet.coalesce",
            EventKind::SpillWrite => "spill.write",
            EventKind::SpillRead => "spill.read",
            EventKind::Governor => "governor.action",
            EventKind::Shed => "fleet.shed",
            EventKind::Degrade => "fleet.degrade",
            EventKind::TenantApply => "tenant.apply",
            EventKind::Frame => "net.frame",
        }
    }

    pub fn from_u8(v: u8) -> Option<EventKind> {
        if (v as usize) < N_EVENT_KINDS {
            // SAFETY: repr(u8) enum with contiguous discriminants 0..N
            Some(unsafe { std::mem::transmute::<u8, EventKind>(v) })
        } else {
            None
        }
    }
}

/// Lane tag carried by events: 0 = high, 1 = low, [`LANE_NONE`] = n/a.
pub const LANE_HIGH: u8 = 0;
pub const LANE_LOW: u8 = 1;
pub const LANE_NONE: u8 = u8::MAX;

/// Tenant tag for events not tied to a tenant.
pub const TENANT_NONE: u32 = u32::MAX;

/// One fixed-size telemetry record. Plain integers only — safe to read
/// even if a wrapping writer races the (post-quiescence) exporter.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub kind: u8,
    pub lane: u8,
    pub tenant: u32,
    /// deterministic op index where one exists; else instance sequence
    pub key: u64,
    /// span start, ns since the telemetry epoch
    pub t0_ns: u64,
    pub dur_ns: u64,
    pub a: u64,
    pub b: u64,
}

const EMPTY_EVENT: Event =
    Event { kind: 0, lane: LANE_NONE, tenant: TENANT_NONE, key: 0, t0_ns: 0, dur_ns: 0, a: 0, b: 0 };

// ---- counters / gauges / histogram paths -----------------------------------

/// Monotonic counters. Indices are stable; names feed the report.
#[repr(usize)]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    KernelCalls = 0,
    FrozenForwards = 1,
    FrozenRows = 2,
    TrainSteps = 3,
    EvalSweeps = 4,
    SpillWrites = 5,
    SpillReads = 6,
    /// folded from `RobustnessSummary` at report time (authoritative)
    IoRetries = 7,
    Sheds = 8,
    Degrades = 9,
    GovActions = 10,
    LazyRestores = 11,
    CoalescedEvents = 12,
    Dispatches = 13,
    /// wire-protocol frames served by shard connection handlers
    FramesServed = 14,
    /// live tenant migrations (drain or restore leg) through this shard
    Migrations = 15,
    /// network-level request retries (reconnect + re-send of a frame)
    NetRetries = 16,
    /// shard failovers: a shard marked down and its routes re-resolved
    Failovers = 17,
    /// stamped requests acknowledged as duplicates by the dedup window
    Duplicates = 18,
}

pub const N_COUNTERS: usize = 19;

const COUNTER_NAMES: [&str; N_COUNTERS] = [
    "kernel_calls",
    "frozen_forwards",
    "frozen_rows",
    "train_steps",
    "eval_sweeps",
    "spill_writes",
    "spill_reads",
    "io_retries",
    "sheds",
    "degrades",
    "governor_actions",
    "lazy_restores",
    "coalesced_events",
    "dispatches",
    "frames_served",
    "migrations",
    "net_retries",
    "failovers",
    "duplicates",
];

/// Point-in-time gauges (peaks are monotonic maxima of the gauge).
#[repr(usize)]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gauge {
    /// deepest ingress queue observed
    QueueDepthPeak = 0,
    /// pool workers currently running a high-lane job
    PoolBusyHigh = 1,
    /// pool workers currently running a low-lane job
    PoolBusyLow = 2,
    PoolBusyHighPeak = 3,
    PoolBusyLowPeak = 4,
    /// governor RAM tier charge (hot + warm), bytes
    GovRamBytes = 5,
    /// governor cold-tier (disk) charge, bytes
    GovDiskBytes = 6,
    GovRamPeakBytes = 7,
    /// tenants currently mapped on this shard (global-id routing table)
    ShardTenants = 8,
}

pub const N_GAUGES: usize = 9;

const GAUGE_NAMES: [&str; N_GAUGES] = [
    "queue_depth_peak",
    "pool_busy_high",
    "pool_busy_low",
    "pool_busy_high_peak",
    "pool_busy_low_peak",
    "governor_ram_bytes",
    "governor_disk_bytes",
    "governor_ram_peak_bytes",
    "shard_tenants",
];

/// Latency histogram paths.
#[repr(usize)]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Path {
    /// fleet event dispatch: submit-stamp → applied (the SLO figure)
    Dispatch = 0,
    /// one serving-side train/apply step
    Serve = 1,
    /// eval sweeps
    Eval = 2,
    SpillRead = 3,
    SpillWrite = 4,
}

pub const N_PATHS: usize = 5;

const PATH_NAMES: [&str; N_PATHS] = ["dispatch", "serve", "eval", "spill_read", "spill_write"];

/// Per-layer frozen-forward accounting capacity (MicroNet-32 has 27
/// conv layers; generous headroom).
pub const MAX_LAYERS: usize = 64;

// ---- rings -----------------------------------------------------------------

/// One single-writer event ring. The writing thread is pinned by the
/// thread-local ring claim in [`Inner::push`]; `head` counts events
/// ever written (so `head - capacity` is the overwrite/drop count).
pub(crate) struct Ring {
    buf: UnsafeCell<Box<[Event]>>,
    head: AtomicU64,
}

// SAFETY: exactly one thread writes `buf` (the thread-local claim in
// `Inner::push` hands each ring to at most one thread); readers run at
// export time after the instrumented run has quiesced and only copy
// plain-integer records out.
unsafe impl Sync for Ring {}

impl Ring {
    fn new(capacity: usize) -> Ring {
        Ring {
            buf: UnsafeCell::new(vec![EMPTY_EVENT; capacity.max(8)].into_boxed_slice()),
            head: AtomicU64::new(0),
        }
    }

    #[inline]
    fn push(&self, ev: Event) {
        // SAFETY: single-writer discipline (see the Sync impl note)
        let buf = unsafe { &mut *self.buf.get() };
        let h = self.head.load(Relaxed);
        buf[(h % buf.len() as u64) as usize] = ev;
        self.head.store(h + 1, Relaxed);
    }

    /// `(events in chronological order, events overwritten)`. Export
    /// only — see the quiescence note on the Sync impl.
    pub(crate) fn snapshot(&self) -> (Vec<Event>, u64) {
        let h = self.head.load(Relaxed);
        // SAFETY: export-time read after quiescence
        let buf = unsafe { &*self.buf.get() };
        let cap = buf.len() as u64;
        if h <= cap {
            (buf[..h as usize].to_vec(), 0)
        } else {
            let split = (h % cap) as usize;
            let mut out = Vec::with_capacity(cap as usize);
            out.extend_from_slice(&buf[split..]);
            out.extend_from_slice(&buf[..split]);
            (out, h - cap)
        }
    }
}

thread_local! {
    /// `(telemetry instance id, claimed ring index)` — re-claimed when
    /// the thread first records into a different instance.
    static RING_CLAIM: Cell<(u64, usize)> = const { Cell::new((0, usize::MAX)) };
}

const RING_UNCLAIMED: usize = usize::MAX;
/// More recording threads than rings: this thread drops its events
/// (counted) instead of sharing a ring and breaking single-writer.
const RING_DROPPED: usize = usize::MAX - 1;

// ---- the shared state ------------------------------------------------------

pub struct Inner {
    id: u64,
    epoch: Instant,
    rings: Box<[Ring]>,
    next_ring: AtomicUsize,
    /// span-key allocator for spans without a natural op index
    seq: AtomicU64,
    /// events dropped because every ring was already claimed
    unringed_drops: AtomicU64,
    counters: [AtomicU64; N_COUNTERS],
    gauges: [AtomicU64; N_GAUGES],
    hists: [Histogram; N_PATHS],
    layer_calls: [AtomicU64; MAX_LAYERS],
    layer_rows: [AtomicU64; MAX_LAYERS],
    layer_ns: [AtomicU64; MAX_LAYERS],
    /// `LayerKind`-style tag + 1 (0 = layer never seen)
    layer_tag: [AtomicU64; MAX_LAYERS],
}

impl Inner {
    #[inline]
    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    #[inline]
    fn push(&self, ev: Event) {
        let (iid, mut idx) = RING_CLAIM.with(|r| r.get());
        if iid != self.id || idx == RING_UNCLAIMED {
            idx = self.next_ring.fetch_add(1, Relaxed);
            if idx >= self.rings.len() {
                idx = RING_DROPPED;
            }
            RING_CLAIM.with(|r| r.set((self.id, idx)));
        }
        if idx == RING_DROPPED {
            self.unringed_drops.fetch_add(1, Relaxed);
            return;
        }
        self.rings[idx].push(ev);
    }

    pub(crate) fn rings(&self) -> &[Ring] {
        &self.rings
    }

    pub(crate) fn epoch_stats(&self) -> (u64, u64, usize) {
        let mut recorded = 0u64;
        let mut dropped = self.unringed_drops.load(Relaxed);
        let mut threads = 0usize;
        for r in self.rings.iter() {
            let h = r.head.load(Relaxed);
            if h > 0 {
                threads += 1;
            }
            recorded += h;
            // SAFETY: export-time read
            let cap = unsafe { &*r.buf.get() }.len() as u64;
            dropped += h.saturating_sub(cap);
        }
        (recorded, dropped, threads)
    }
}

// ---- the handle ------------------------------------------------------------

/// The telemetry handle: `None` = disabled (one branch per call site,
/// the `FaultPlan::none()` discipline). Clone freely — clones share
/// the same recording state.
#[derive(Clone, Default)]
pub struct Telemetry {
    inner: Option<Arc<Inner>>,
}

impl std::fmt::Debug for Telemetry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Telemetry").field("enabled", &self.is_enabled()).finish()
    }
}

impl Telemetry {
    /// Disabled telemetry: every recording call is a single branch.
    pub fn none() -> Telemetry {
        Telemetry { inner: None }
    }

    /// Enabled with the default capacity (32 rings x 4096 events).
    pub fn enabled() -> Telemetry {
        Telemetry::with_capacity(32, 4096)
    }

    /// Enabled with explicit ring geometry. All recording memory is
    /// allocated here, up front — nothing allocates on the record path.
    pub fn with_capacity(rings: usize, events_per_ring: usize) -> Telemetry {
        static NEXT_ID: AtomicU64 = AtomicU64::new(1);
        let inner = Inner {
            id: NEXT_ID.fetch_add(1, Relaxed),
            epoch: Instant::now(),
            rings: (0..rings.max(1)).map(|_| Ring::new(events_per_ring)).collect(),
            next_ring: AtomicUsize::new(0),
            seq: AtomicU64::new(0),
            unringed_drops: AtomicU64::new(0),
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            gauges: std::array::from_fn(|_| AtomicU64::new(0)),
            hists: std::array::from_fn(|_| Histogram::new()),
            layer_calls: std::array::from_fn(|_| AtomicU64::new(0)),
            layer_rows: std::array::from_fn(|_| AtomicU64::new(0)),
            layer_ns: std::array::from_fn(|_| AtomicU64::new(0)),
            layer_tag: std::array::from_fn(|_| AtomicU64::new(0)),
        };
        Telemetry { inner: Some(Arc::new(inner)) }
    }

    /// `TINYCL_TELEMETRY` knob: unset/`0`/`off`/`false` → disabled,
    /// anything else → enabled at default capacity.
    pub fn from_env() -> Telemetry {
        match std::env::var("TINYCL_TELEMETRY") {
            Ok(v) if !matches!(v.as_str(), "" | "0" | "off" | "false") => Telemetry::enabled(),
            _ => Telemetry::none(),
        }
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Open a span of `kind` ending (and recorded) when the guard
    /// drops. Disabled: no clock read, nothing recorded.
    #[inline]
    pub fn span(&self, kind: EventKind) -> SpanGuard<'_> {
        let (inner, t0) = match &self.inner {
            Some(i) => (Some(&**i), i.now_ns()),
            None => (None, 0),
        };
        SpanGuard {
            inner,
            kind: kind as u8,
            lane: LANE_NONE,
            tenant: TENANT_NONE,
            key: u64::MAX,
            a: 0,
            b: 0,
            t0,
            hist: None,
        }
    }

    /// Record a complete event whose duration was measured externally
    /// (ends now; start back-dated by `dur_ns`).
    #[inline]
    pub fn event_ns(
        &self,
        kind: EventKind,
        key: u64,
        tenant: u32,
        lane: u8,
        dur_ns: u64,
        a: u64,
        b: u64,
    ) {
        if let Some(inner) = &self.inner {
            let now = inner.now_ns();
            inner.push(Event {
                kind: kind as u8,
                lane,
                tenant,
                key,
                t0_ns: now.saturating_sub(dur_ns),
                dur_ns,
                a,
                b,
            });
        }
    }

    #[inline]
    pub fn counter_add(&self, c: Counter, v: u64) {
        if let Some(inner) = &self.inner {
            inner.counters[c as usize].fetch_add(v, Relaxed);
        }
    }

    /// Overwrite a counter (used when folding authoritative totals in).
    #[inline]
    pub fn counter_set(&self, c: Counter, v: u64) {
        if let Some(inner) = &self.inner {
            inner.counters[c as usize].store(v, Relaxed);
        }
    }

    #[inline]
    pub fn gauge_set(&self, g: Gauge, v: u64) {
        if let Some(inner) = &self.inner {
            inner.gauges[g as usize].store(v, Relaxed);
        }
    }

    #[inline]
    pub fn gauge_max(&self, g: Gauge, v: u64) {
        if let Some(inner) = &self.inner {
            inner.gauges[g as usize].fetch_max(v, Relaxed);
        }
    }

    /// Increment gauge `g` and fold the new value into peak gauge `p`.
    #[inline]
    pub fn gauge_inc_peak(&self, g: Gauge, p: Gauge) {
        if let Some(inner) = &self.inner {
            let new = inner.gauges[g as usize].fetch_add(1, Relaxed) + 1;
            inner.gauges[p as usize].fetch_max(new, Relaxed);
        }
    }

    #[inline]
    pub fn gauge_dec(&self, g: Gauge) {
        if let Some(inner) = &self.inner {
            inner.gauges[g as usize].fetch_sub(1, Relaxed);
        }
    }

    #[inline]
    pub fn hist_ns(&self, p: Path, ns: u64) {
        if let Some(inner) = &self.inner {
            inner.hists[p as usize].record(ns);
        }
    }

    /// Per-layer frozen-forward accounting (the Fig. 8 table).
    #[inline]
    pub fn record_layer(&self, layer: usize, tag: u64, rows: u64, ns: u64) {
        if let Some(inner) = &self.inner {
            if layer < MAX_LAYERS {
                inner.layer_calls[layer].fetch_add(1, Relaxed);
                inner.layer_rows[layer].fetch_add(rows, Relaxed);
                inner.layer_ns[layer].fetch_add(ns, Relaxed);
                inner.layer_tag[layer].store(tag + 1, Relaxed);
            }
        }
    }

    /// Fold the authoritative robustness counters (the server's own
    /// atomics, reported as `RobustnessSummary`) over the live-recorded
    /// approximations.
    pub fn fold_robustness(&self, rs: &RobustnessSummary) {
        self.counter_set(Counter::Sheds, rs.shed);
        self.counter_set(Counter::IoRetries, rs.io_retries);
        self.counter_set(Counter::Degrades, rs.degrades);
    }

    /// Histogram summary of one path (None when disabled).
    pub fn path_summary(&self, p: Path) -> Option<HistSummary> {
        self.inner.as_ref().map(|i| i.hists[p as usize].summary())
    }

    /// Build the report. None when disabled. Call after the
    /// instrumented run has quiesced.
    pub fn report(&self) -> Option<TelemetryReport> {
        let inner = self.inner.as_ref()?;
        let (recorded, dropped, threads) = inner.epoch_stats();
        let hists = (0..N_PATHS)
            .filter(|&i| inner.hists[i].count() > 0)
            .map(|i| (PATH_NAMES[i], inner.hists[i].summary()))
            .collect();
        let counters = (0..N_COUNTERS)
            .map(|i| (COUNTER_NAMES[i], inner.counters[i].load(Relaxed)))
            .filter(|&(_, v)| v > 0)
            .collect();
        let gauges = (0..N_GAUGES)
            .map(|i| (GAUGE_NAMES[i], inner.gauges[i].load(Relaxed)))
            .filter(|&(_, v)| v > 0)
            .collect();
        let mut frozen_layers = Vec::new();
        for li in 0..MAX_LAYERS {
            let calls = inner.layer_calls[li].load(Relaxed);
            if calls == 0 {
                continue;
            }
            let rows = inner.layer_rows[li].load(Relaxed);
            let ns = inner.layer_ns[li].load(Relaxed);
            frozen_layers.push(LayerStat {
                layer: li,
                kind: match inner.layer_tag[li].load(Relaxed) {
                    1 => "conv3x3",
                    2 => "depthwise",
                    3 => "pointwise",
                    _ => "?",
                },
                calls,
                rows,
                total_ms: ns as f64 / 1e6,
                us_per_row: if rows == 0 { 0.0 } else { ns as f64 / 1e3 / rows as f64 },
            });
        }
        Some(TelemetryReport {
            events_recorded: recorded,
            events_dropped: dropped,
            threads_traced: threads,
            hists,
            counters,
            gauges,
            frozen_layers,
        })
    }

    /// Chrome `trace_event` JSON of every recorded span (None when
    /// disabled). Load in Perfetto / `chrome://tracing`.
    pub fn chrome_trace(&self) -> Option<Json> {
        self.inner.as_ref().map(|i| trace::chrome_trace(i))
    }
}

// ---- the span guard --------------------------------------------------------

/// RAII span: records one [`Event`] (and optionally one histogram
/// sample) when dropped. All setters are no-ops when disabled.
pub struct SpanGuard<'a> {
    inner: Option<&'a Inner>,
    kind: u8,
    lane: u8,
    tenant: u32,
    key: u64,
    a: u64,
    b: u64,
    t0: u64,
    hist: Option<Path>,
}

impl SpanGuard<'_> {
    /// Attach a deterministic op index (default: instance sequence).
    #[inline]
    pub fn key(mut self, k: u64) -> Self {
        self.key = k;
        self
    }

    #[inline]
    pub fn tenant(mut self, t: u32) -> Self {
        self.tenant = t;
        self
    }

    #[inline]
    pub fn lane(mut self, l: u8) -> Self {
        self.lane = l;
        self
    }

    #[inline]
    pub fn payload(mut self, a: u64, b: u64) -> Self {
        self.a = a;
        self.b = b;
        self
    }

    /// Also feed the span's duration into histogram path `p`.
    #[inline]
    pub fn hist(mut self, p: Path) -> Self {
        self.hist = Some(p);
        self
    }

    /// Set the payload after construction — for values only known at
    /// span end (bytes written, attempts used).
    #[inline]
    pub fn set_payload(&mut self, a: u64, b: u64) {
        self.a = a;
        self.b = b;
    }

    /// Duration so far in ns (0 when disabled) — for call sites that
    /// need the measurement as data, not only as a record.
    #[inline]
    pub fn elapsed_ns(&self) -> u64 {
        match self.inner {
            Some(inner) => inner.now_ns().saturating_sub(self.t0),
            None => 0,
        }
    }
}

impl Drop for SpanGuard<'_> {
    #[inline]
    fn drop(&mut self) {
        let Some(inner) = self.inner else { return };
        let dur = inner.now_ns().saturating_sub(self.t0);
        let key =
            if self.key == u64::MAX { inner.seq.fetch_add(1, Relaxed) } else { self.key };
        inner.push(Event {
            kind: self.kind,
            lane: self.lane,
            tenant: self.tenant,
            key,
            t0_ns: self.t0,
            dur_ns: dur,
            a: self.a,
            b: self.b,
        });
        if let Some(p) = self.hist {
            inner.hists[p as usize].record(dur);
        }
    }
}

/// Owning sibling of [`SpanGuard`] for call sites without a handle to
/// borrow from (the kernel engine spans the process-global slot). Same
/// cost profile: an `Arc` clone is refcount traffic, not allocation.
pub struct OwnedSpan {
    inner: Option<Arc<Inner>>,
    kind: u8,
    lane: u8,
    tenant: u32,
    key: u64,
    a: u64,
    b: u64,
    t0: u64,
    hist: Option<Path>,
    counter: Option<Counter>,
}

impl Telemetry {
    /// Open an owning span (see [`OwnedSpan`]). Consumes this handle's
    /// clone of the recording state.
    #[inline]
    pub fn owned_span(self, kind: EventKind) -> OwnedSpan {
        let t0 = match &self.inner {
            Some(i) => i.now_ns(),
            None => 0,
        };
        OwnedSpan {
            inner: self.inner,
            kind: kind as u8,
            lane: LANE_NONE,
            tenant: TENANT_NONE,
            key: u64::MAX,
            a: 0,
            b: 0,
            t0,
            hist: None,
            counter: None,
        }
    }
}

/// Span against the process-global telemetry slot — the one-liner the
/// kernel engine uses. One pointer load when no telemetry is installed.
#[inline]
pub fn global_span(kind: EventKind) -> OwnedSpan {
    global().owned_span(kind)
}

impl OwnedSpan {
    #[inline]
    pub fn key(mut self, k: u64) -> Self {
        self.key = k;
        self
    }

    #[inline]
    pub fn tenant(mut self, t: u32) -> Self {
        self.tenant = t;
        self
    }

    #[inline]
    pub fn lane(mut self, l: u8) -> Self {
        self.lane = l;
        self
    }

    #[inline]
    pub fn payload(mut self, a: u64, b: u64) -> Self {
        self.a = a;
        self.b = b;
        self
    }

    #[inline]
    pub fn hist(mut self, p: Path) -> Self {
        self.hist = Some(p);
        self
    }

    /// Also bump counter `c` by 1 when the span closes.
    #[inline]
    pub fn counter(mut self, c: Counter) -> Self {
        self.counter = Some(c);
        self
    }
}

impl Drop for OwnedSpan {
    #[inline]
    fn drop(&mut self) {
        let Some(inner) = &self.inner else { return };
        let dur = inner.now_ns().saturating_sub(self.t0);
        let key =
            if self.key == u64::MAX { inner.seq.fetch_add(1, Relaxed) } else { self.key };
        inner.push(Event {
            kind: self.kind,
            lane: self.lane,
            tenant: self.tenant,
            key,
            t0_ns: self.t0,
            dur_ns: dur,
            a: self.a,
            b: self.b,
        });
        if let Some(p) = self.hist {
            inner.hists[p as usize].record(dur);
        }
        if let Some(c) = self.counter {
            inner.counters[c as usize].fetch_add(1, Relaxed);
        }
    }
}

// ---- the report ------------------------------------------------------------

/// Per-layer frozen-forward latency accounting — the host-side
/// reproduction of the paper's Fig. 8 per-layer breakdown.
#[derive(Clone, Debug)]
pub struct LayerStat {
    pub layer: usize,
    pub kind: &'static str,
    pub calls: u64,
    pub rows: u64,
    pub total_ms: f64,
    pub us_per_row: f64,
}

/// The exported telemetry digest (embedded in `FleetReport`, emitted as
/// JSON by the CLI / example).
#[derive(Clone, Debug, Default)]
pub struct TelemetryReport {
    pub events_recorded: u64,
    pub events_dropped: u64,
    pub threads_traced: usize,
    pub hists: Vec<(&'static str, HistSummary)>,
    pub counters: Vec<(&'static str, u64)>,
    pub gauges: Vec<(&'static str, u64)>,
    pub frozen_layers: Vec<LayerStat>,
}

impl TelemetryReport {
    pub fn hist(&self, name: &str) -> Option<&HistSummary> {
        self.hists.iter().find(|(n, _)| *n == name).map(|(_, h)| h)
    }

    pub fn to_json(&self) -> Json {
        let hists =
            self.hists.iter().map(|(n, h)| (*n, h.to_json())).collect::<Vec<_>>();
        let counters =
            self.counters.iter().map(|(n, v)| (*n, num(*v as f64))).collect::<Vec<_>>();
        let gauges =
            self.gauges.iter().map(|(n, v)| (*n, num(*v as f64))).collect::<Vec<_>>();
        let layers = self
            .frozen_layers
            .iter()
            .map(|l| {
                obj(vec![
                    ("layer", num(l.layer as f64)),
                    ("kind", s(l.kind)),
                    ("calls", num(l.calls as f64)),
                    ("rows", num(l.rows as f64)),
                    ("total_ms", num((l.total_ms * 1e3).round() / 1e3)),
                    ("us_per_row", num((l.us_per_row * 1e3).round() / 1e3)),
                ])
            })
            .collect();
        obj(vec![
            ("events_recorded", num(self.events_recorded as f64)),
            ("events_dropped", num(self.events_dropped as f64)),
            ("threads_traced", num(self.threads_traced as f64)),
            ("hist", obj(hists)),
            ("counters", obj(counters)),
            ("gauges", obj(gauges)),
            ("frozen_layers", arr(layers)),
        ])
    }

    /// Human-readable rendering for the CLI.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "telemetry: {} events recorded ({} dropped) on {} threads",
            self.events_recorded, self.events_dropped, self.threads_traced
        );
        for (name, h) in &self.hists {
            let _ = writeln!(
                out,
                "  {:<12} n={:<7} p50={:.3}ms p95={:.3}ms p99={:.3}ms max={:.3}ms",
                name, h.n, h.p50_ms, h.p95_ms, h.p99_ms, h.max_ms
            );
        }
        for (name, v) in &self.counters {
            let _ = writeln!(out, "  counter {name} = {v}");
        }
        for (name, v) in &self.gauges {
            let _ = writeln!(out, "  gauge   {name} = {v}");
        }
        if !self.frozen_layers.is_empty() {
            let _ = writeln!(out, "  per-layer frozen forward (Fig. 8):");
            let _ =
                writeln!(out, "    {:<6} {:<10} {:>8} {:>10} {:>10} {:>10}", "layer", "kind", "calls", "rows", "total_ms", "us/row");
            for l in &self.frozen_layers {
                let _ = writeln!(
                    out,
                    "    {:<6} {:<10} {:>8} {:>10} {:>10.3} {:>10.3}",
                    l.layer, l.kind, l.calls, l.rows, l.total_ms, l.us_per_row
                );
            }
        }
        out
    }
}

/// `TINYCL_LOG` knob: human-readable action logging (governor commits,
/// degrade/shock notices) on stderr. Unset/`0`/`off`/`false` → quiet.
/// The telemetry event stream is the source of truth either way; this
/// only controls the rendering.
pub fn log_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        matches!(std::env::var("TINYCL_LOG"),
                 Ok(v) if !matches!(v.as_str(), "" | "0" | "off" | "false"))
    })
}

// ---- the process-global slot -----------------------------------------------

// The kernel engine and the exec pool have no config plumbing to a
// telemetry handle; they read this slot instead. Installed handles are
// kept alive forever (one Arc per install — bounded by install count),
// so the raw pointer read on the hot path is always valid.
static GLOBAL: AtomicPtr<Inner> = AtomicPtr::new(std::ptr::null_mut());

fn keep() -> &'static Mutex<Vec<Arc<Inner>>> {
    static KEEP: OnceLock<Mutex<Vec<Arc<Inner>>>> = OnceLock::new();
    KEEP.get_or_init(|| Mutex::new(Vec::new()))
}

/// Install `t` as the process-global telemetry for the guard's
/// lifetime (the previous global is restored on drop). The fleet
/// server installs its config's handle around each run so kernel- and
/// pool-level spans land in the same sink.
pub fn install(t: &Telemetry) -> InstallGuard {
    let ptr = match &t.inner {
        Some(arc) => {
            keep().lock().unwrap().push(arc.clone());
            Arc::as_ptr(arc) as *mut Inner
        }
        None => std::ptr::null_mut(),
    };
    InstallGuard { prev: GLOBAL.swap(ptr, Relaxed) }
}

pub struct InstallGuard {
    prev: *mut Inner,
}

impl Drop for InstallGuard {
    fn drop(&mut self) {
        GLOBAL.store(self.prev, Relaxed);
    }
}

// SAFETY: the guard only carries a pointer whose pointee is kept alive
// process-wide by `keep()`.
unsafe impl Send for InstallGuard {}

/// The process-global handle: disabled unless something installed an
/// enabled handle. One pointer load when disabled.
#[inline]
pub fn global() -> Telemetry {
    let p = GLOBAL.load(Relaxed);
    if p.is_null() {
        Telemetry { inner: None }
    } else {
        // SAFETY: installed pointers are kept alive forever by `keep()`
        unsafe {
            Arc::increment_strong_count(p);
            Telemetry { inner: Some(Arc::from_raw(p)) }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_handle_is_inert() {
        let t = Telemetry::none();
        assert!(!t.is_enabled());
        {
            let _s = t.span(EventKind::Dispatch).tenant(3).payload(1, 2).hist(Path::Dispatch);
        }
        t.counter_add(Counter::Sheds, 5);
        t.hist_ns(Path::Eval, 100);
        assert!(t.report().is_none());
        assert!(t.chrome_trace().is_none());
    }

    #[test]
    fn spans_land_in_the_report_and_trace() {
        let t = Telemetry::with_capacity(4, 64);
        {
            let _s = t
                .span(EventKind::SpillWrite)
                .key(7)
                .tenant(2)
                .payload(1024, 1)
                .hist(Path::SpillWrite);
        }
        t.counter_add(Counter::SpillWrites, 1);
        let rep = t.report().expect("enabled");
        assert_eq!(rep.events_recorded, 1);
        assert_eq!(rep.events_dropped, 0);
        assert_eq!(rep.hist("spill_write").unwrap().n, 1);
        assert_eq!(rep.counters, vec![("spill_writes", 1)]);
        let trace = t.chrome_trace().unwrap().to_string();
        assert!(trace.contains("\"spill.write\""), "trace: {trace}");
        assert!(trace.contains("traceEvents"));
    }

    #[test]
    fn ring_wrap_counts_dropped_events() {
        let t = Telemetry::with_capacity(1, 8);
        for i in 0..20u64 {
            t.event_ns(EventKind::Dispatch, i, TENANT_NONE, LANE_NONE, 10, 0, 0);
        }
        let rep = t.report().unwrap();
        assert_eq!(rep.events_recorded, 20);
        assert_eq!(rep.events_dropped, 12, "20 pushes into an 8-slot ring drop 12");
        // the survivors are the newest 8, in order
        let inner = t.inner.as_ref().unwrap();
        let (evs, dropped) = inner.rings()[0].snapshot();
        assert_eq!(dropped, 12);
        assert_eq!(evs.iter().map(|e| e.key).collect::<Vec<_>>(), (12..20).collect::<Vec<_>>());
    }

    #[test]
    fn global_install_restores_previous_on_drop() {
        assert!(!global().is_enabled());
        let t = Telemetry::with_capacity(2, 32);
        {
            let _g = install(&t);
            assert!(global().is_enabled());
            global().counter_add(Counter::KernelCalls, 2);
        }
        assert!(!global().is_enabled());
        let rep = t.report().unwrap();
        assert_eq!(rep.counters, vec![("kernel_calls", 2)]);
    }

    #[test]
    fn per_layer_table_accumulates() {
        let t = Telemetry::with_capacity(2, 32);
        t.record_layer(0, 0, 8, 4_000_000);
        t.record_layer(0, 0, 8, 2_000_000);
        t.record_layer(3, 2, 4, 1_000_000);
        let rep = t.report().unwrap();
        assert_eq!(rep.frozen_layers.len(), 2);
        let l0 = &rep.frozen_layers[0];
        assert_eq!((l0.layer, l0.kind, l0.calls, l0.rows), (0, "conv3x3", 2, 16));
        assert!((l0.total_ms - 6.0).abs() < 1e-9);
        let l3 = &rep.frozen_layers[1];
        assert_eq!((l3.layer, l3.kind), (3, "pointwise"));
    }

    #[test]
    fn from_env_defaults_off() {
        // can't mutate the process env safely under the test harness;
        // just pin the unset default
        if std::env::var("TINYCL_TELEMETRY").is_err() {
            assert!(!Telemetry::from_env().is_enabled());
        }
    }
}
