//! Chrome `trace_event` export: turn the per-thread event rings into a
//! JSON trace loadable in Perfetto (<https://ui.perfetto.dev>) or
//! `chrome://tracing`.
//!
//! Format: the object form `{"traceEvents": [...], "displayTimeUnit":
//! "ms"}`. Every span is a complete event (`"ph": "X"`) with
//! microsecond `ts`/`dur`, `pid` 0, and `tid` = the ring (recording
//! thread) index; each ring also contributes a thread-name metadata
//! record (`"ph": "M"`). Events are sorted by start time within each
//! tid, so per-thread timestamps are monotonic — a property
//! `tools/bench_check.py validate-telemetry` asserts on the committed
//! artifact. Span args carry the deterministic op key, tenant, lane and
//! the two kind-specific payload words, which is what lets a trace be
//! lined up against a fault-injection replay of the same seed.

use super::{EventKind, Inner, LANE_HIGH, LANE_LOW, TENANT_NONE};
use crate::util::json::{arr, num, obj, s, Json};

/// Build the full trace JSON. Call after the instrumented run has
/// quiesced (rings are single-writer; see `telemetry::Ring`).
pub fn chrome_trace(inner: &Inner) -> Json {
    let mut events: Vec<Json> = Vec::new();
    let mut total_dropped = 0u64;
    for (tid, ring) in inner.rings().iter().enumerate() {
        let (mut evs, dropped) = ring.snapshot();
        total_dropped += dropped;
        if evs.is_empty() {
            continue;
        }
        events.push(obj(vec![
            ("ph", s("M")),
            ("name", s("thread_name")),
            ("pid", num(0.0)),
            ("tid", num(tid as f64)),
            ("ts", num(0.0)),
            ("args", obj(vec![("name", s(&format!("worker-{tid}")))])),
        ]));
        // rings hold events in completion order; sort by start so the
        // per-tid timeline is monotonic
        evs.sort_by_key(|e| (e.t0_ns, e.key));
        for e in evs {
            let name = EventKind::from_u8(e.kind).map(|k| k.name()).unwrap_or("?");
            let mut args = vec![("key", num(e.key as f64))];
            if e.tenant != TENANT_NONE {
                args.push(("tenant", num(e.tenant as f64)));
            }
            match e.lane {
                LANE_HIGH => args.push(("lane", s("high"))),
                LANE_LOW => args.push(("lane", s("low"))),
                _ => {}
            }
            args.push(("a", num(e.a as f64)));
            args.push(("b", num(e.b as f64)));
            events.push(obj(vec![
                ("ph", s("X")),
                ("name", s(name)),
                ("cat", s("tinycl")),
                ("pid", num(0.0)),
                ("tid", num(tid as f64)),
                ("ts", num(e.t0_ns as f64 / 1e3)),
                ("dur", num(e.dur_ns as f64 / 1e3)),
                ("args", obj(args)),
            ]));
        }
    }
    obj(vec![
        ("traceEvents", arr(events)),
        ("displayTimeUnit", s("ms")),
        ("otherData", obj(vec![("events_dropped", num(total_dropped as f64))])),
    ])
}

#[cfg(test)]
mod tests {
    use crate::telemetry::{EventKind, Path, Telemetry};

    #[test]
    fn trace_is_sorted_and_well_formed_per_tid() {
        let t = Telemetry::with_capacity(2, 128);
        // out-of-order completion: open two spans, drop inner first
        let outer = t.span(EventKind::FrozenForward).key(1).payload(64, 15);
        {
            let _inner = t.span(EventKind::KernelMatmulI8).key(2).payload(64, 128);
        }
        drop(outer);
        t.span(EventKind::Dispatch).key(3).hist(Path::Dispatch);
        let trace = t.chrome_trace().unwrap();
        let evs = trace.at(&["traceEvents"]).as_arr();
        // one metadata record + three spans
        assert_eq!(evs.len(), 4);
        assert_eq!(evs[0].at(&["ph"]).as_str(), "M");
        let mut last_ts = -1.0;
        for e in &evs[1..] {
            assert_eq!(e.at(&["ph"]).as_str(), "X");
            let ts = e.at(&["ts"]).as_f64();
            assert!(ts >= last_ts, "per-tid ts must be monotonic");
            assert!(e.at(&["dur"]).as_f64() >= 0.0);
            assert_eq!(e.at(&["pid"]).as_f64(), 0.0);
            e.at(&["args", "key"]);
            last_ts = ts;
        }
        // the outer span started before the inner one
        assert_eq!(evs[1].at(&["name"]).as_str(), "frozen.forward");
        assert_eq!(evs[2].at(&["name"]).as_str(), "kernel.matmul_i8");
    }
}
