//! Fixed-bucket (log2) latency histograms with exact nearest-rank
//! percentile extraction.
//!
//! A [`Histogram`] is 64 power-of-two nanosecond buckets behind relaxed
//! atomics: bucket `b` covers `[2^b, 2^(b+1))` ns (bucket 0 also absorbs
//! 0). Recording is one `leading_zeros` + three `fetch_add`s — no locks,
//! no allocation, safe from any thread — which is what lets the fleet
//! feed one histogram per path (dispatch / serve / eval / spill) from
//! every worker at once.
//!
//! Percentiles use the SAME nearest-rank convention as
//! `coordinator::metrics::LatencySummary` (`rank = ceil(q*n)` clamped to
//! `[1, n]`) and return the upper bound of the bucket holding that rank,
//! clamped to the exact observed maximum (the top bucket's upper bound
//! would otherwise overshoot `max` for a sample set that doesn't reach
//! it, breaking the `p50 <= p95 <= p99 <= max` ordering every consumer
//! asserts). That makes extraction *exact with respect to the bucket
//! quantization*: for any sample set, `percentile_ns(q) ==
//! min(quantize_ns(oracle), max)` where `oracle` is the nearest-rank
//! percentile of the raw sorted samples — an equality the tests pin
//! against a sorted oracle, not an approximation bound.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// Number of power-of-two buckets: covers the full u64 ns range.
pub const N_BUCKETS: usize = 64;

/// Bucket index of a duration: `floor(log2(max(ns, 1)))`.
#[inline]
pub fn bucket_of(ns: u64) -> usize {
    (63 - (ns | 1).leading_zeros()) as usize
}

/// Inclusive upper bound of bucket `b` in ns.
#[inline]
pub fn bucket_upper_ns(b: usize) -> u64 {
    if b >= 63 {
        u64::MAX
    } else {
        (1u64 << (b + 1)) - 1
    }
}

/// The bucket-quantized representative of a raw duration — what any
/// percentile that lands on this sample will report.
#[inline]
pub fn quantize_ns(ns: u64) -> u64 {
    bucket_upper_ns(bucket_of(ns))
}

/// Lock-free log2 latency histogram. See the module docs for the
/// bucket/percentile semantics.
pub struct Histogram {
    counts: [AtomicU64; N_BUCKETS],
    n: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            n: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    /// Record one duration. Zero-alloc, lock-free.
    #[inline]
    pub fn record(&self, ns: u64) {
        self.counts[bucket_of(ns)].fetch_add(1, Relaxed);
        self.n.fetch_add(1, Relaxed);
        self.sum_ns.fetch_add(ns, Relaxed);
        self.max_ns.fetch_max(ns, Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.n.load(Relaxed)
    }

    /// Nearest-rank percentile (`rank = ceil(q*n)` clamped to `[1, n]`,
    /// the `LatencySummary` convention), reported as the upper bound of
    /// the bucket containing that rank, clamped to the exact observed
    /// max so `p99 <= max` always holds. 0 for an empty histogram.
    pub fn percentile_ns(&self, q: f64) -> u64 {
        let n = self.n.load(Relaxed);
        if n == 0 {
            return 0;
        }
        let max = self.max_ns.load(Relaxed);
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        for b in 0..N_BUCKETS {
            cum += self.counts[b].load(Relaxed);
            if cum >= rank {
                return bucket_upper_ns(b).min(max);
            }
        }
        bucket_upper_ns(N_BUCKETS - 1).min(max)
    }

    /// Exact (un-quantized) maximum recorded duration.
    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let n = self.n.load(Relaxed);
        if n == 0 {
            0.0
        } else {
            self.sum_ns.load(Relaxed) as f64 / n as f64
        }
    }

    pub fn summary(&self) -> HistSummary {
        HistSummary {
            n: self.count(),
            p50_ms: self.percentile_ns(0.50) as f64 / 1e6,
            p95_ms: self.percentile_ns(0.95) as f64 / 1e6,
            p99_ms: self.percentile_ns(0.99) as f64 / 1e6,
            max_ms: self.max_ns() as f64 / 1e6,
            mean_ms: self.mean_ns() / 1e6,
        }
    }
}

/// Percentile digest of one histogram, in milliseconds. `p*` values are
/// max-clamped bucket upper bounds (see module docs); `max`/`mean` are
/// exact.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HistSummary {
    pub n: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    pub mean_ms: f64,
}

impl HistSummary {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{num, obj};
        obj(vec![
            ("n", num(self.n as f64)),
            ("p50_ms", num(round6(self.p50_ms))),
            ("p95_ms", num(round6(self.p95_ms))),
            ("p99_ms", num(round6(self.p99_ms))),
            ("max_ms", num(round6(self.max_ms))),
            ("mean_ms", num(round6(self.mean_ms))),
        ])
    }
}

fn round6(v: f64) -> f64 {
    (v * 1e6).round() / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The raw nearest-rank oracle over sorted samples, mirroring
    /// `LatencySummary::from_ns`.
    fn oracle_ns(samples: &mut Vec<u64>, q: f64) -> u64 {
        samples.sort_unstable();
        let n = samples.len() as u64;
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        samples[(rank - 1) as usize]
    }

    #[test]
    fn bucket_edges() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(u64::MAX), 63);
        assert_eq!(bucket_upper_ns(0), 1);
        assert_eq!(bucket_upper_ns(1), 3);
        assert_eq!(bucket_upper_ns(63), u64::MAX);
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile_ns(0.5), 0);
        assert_eq!(h.summary(), HistSummary { n: 0, ..Default::default() });
    }

    #[test]
    fn percentiles_match_the_sorted_sample_oracle_exactly() {
        // deterministic pseudo-random samples spanning many octaves
        let mut state = 0x243F_6A88_85A3_08D3u64;
        let mut samples: Vec<u64> = (0..10_000)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (state >> 16) % 50_000_000 // 0 .. 50ms in ns
            })
            .collect();
        let h = Histogram::new();
        for &s in &samples {
            h.record(s);
        }
        let max = *samples.iter().max().unwrap();
        for &q in &[0.01, 0.25, 0.50, 0.90, 0.95, 0.99, 1.0] {
            let oracle = oracle_ns(&mut samples, q);
            assert_eq!(
                h.percentile_ns(q),
                quantize_ns(oracle).min(max),
                "q={q}: histogram percentile must equal the max-clamped bucket-quantized oracle"
            );
        }
        assert_eq!(h.count(), 10_000);
        assert_eq!(h.max_ns(), max);
        // the ordering every consumer (bench_check.py, the SLO report)
        // relies on: p100 never overshoots the true maximum
        assert!(h.percentile_ns(1.0) <= h.max_ns());
    }

    #[test]
    fn single_sample_every_percentile_is_its_bucket() {
        let h = Histogram::new();
        h.record(12_345);
        for &q in &[0.0, 0.5, 0.99, 1.0] {
            // one sample: every rank lands on it, and the max clamp
            // reports it exactly rather than its bucket's upper bound
            assert_eq!(h.percentile_ns(q), 12_345);
        }
    }
}
