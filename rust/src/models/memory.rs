//! Memory-requirement model (paper §III-B, regenerates Fig. 7).
//!
//! Splits the footprint of a QLR-CL deployment into the paper's four
//! components:
//!  - **LR memory**: `N_LR` latent vectors at `Q_LR` bits (non-volatile;
//!    the paper stores them in external Flash / on-chip MRAM),
//!  - **frozen parameters**: INT-8 (or FP32) weights of layers `[0, l)`.
//!    Since the true-INT8 frozen pipeline, the 1-byte-per-weight charge
//!    is **literal**: `NativeBackend` stores the executing frozen stage
//!    as `Vec<i8>` codes (`NativeBackend::frozen_arena_bytes`, asserted
//!    equal below) — previously the "INT-8" stage was a dequantized f32
//!    grid occupying 4x what this model charged,
//!  - **adaptive parameters + gradients**: FP32 weights of `[l, L)`, twice
//!    (the coefficient array and its gradient array),
//!  - **training activations**: feature maps of the adaptive stage that
//!    must persist from forward to backward, for one mini-batch.

use super::NetDesc;
use crate::coordinator::replay::ReplayBuffer;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MemoryBreakdown {
    pub lr_bytes: usize,
    pub frozen_param_bytes: usize,
    pub adaptive_param_bytes: usize,
    pub gradient_bytes: usize,
    pub activation_bytes: usize,
}

impl MemoryBreakdown {
    pub fn total(&self) -> usize {
        self.lr_bytes
            + self.frozen_param_bytes
            + self.adaptive_param_bytes
            + self.gradient_bytes
            + self.activation_bytes
    }

    pub fn total_mb(&self) -> f64 {
        self.total() as f64 / (1024.0 * 1024.0)
    }

    pub fn lr_mb(&self) -> f64 {
        self.lr_bytes as f64 / (1024.0 * 1024.0)
    }
}

/// Quantization arm of a deployment (frozen-stage datatype + LR datatype).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantSetting {
    /// frozen-stage weights: 8 (INT-8) or 32 (FP32 baseline)
    pub frozen_bits: u8,
    /// latent replays: 6..8 (UINT-Q) or 32 (FP32 baseline)
    pub lr_bits: u8,
}

impl QuantSetting {
    pub fn label(&self) -> String {
        let f = |b: u8| {
            if b == 32 {
                "FP32".to_string()
            } else {
                format!("UINT-{b}")
            }
        };
        format!("{}+{}", f(self.frozen_bits), f(self.lr_bits))
    }
}

/// Full footprint for a deployment choice, for **LR layer `l`** in the
/// paper's Table III labeling: latents are the output of layer `l` and the
/// retrained stage is `[l+1, L)` (just the classifier when `l` is the
/// linear row).
///
/// `batch` is the training mini-batch (paper: 128). Activation accounting
/// follows §III-B: the latent input batch plus every adaptive layer's
/// output feature map retained for back-prop, FP32.
pub fn breakdown(
    net: &NetDesc,
    l: usize,
    n_lr: usize,
    q: QuantSetting,
    batch: usize,
) -> MemoryBreakdown {
    // one source of truth with the live buffers: the LR component is the
    // very arena size `ReplayBuffer` allocates for (n_lr, lr_elems, Q) —
    // governor math and the Fig 5/7-style tables can never drift apart
    let lr_elems = net.lr_elems(l);
    let lr = ReplayBuffer::arena_bytes_for(n_lr, lr_elems, q.lr_bits);

    let first_adaptive = if net.layer(l).kind == super::LayerKind::Linear {
        l
    } else {
        l + 1
    };

    let frozen_w: usize = net.layers[..first_adaptive].iter().map(|x| x.n_weights()).sum();
    let frozen_bytes = frozen_w * if q.frozen_bits == 32 { 4 } else { 1 };

    let adaptive_w: usize = net.layers[first_adaptive..].iter().map(|x| x.n_weights()).sum();
    let adaptive_bytes = adaptive_w * 4;
    let grad_bytes = adaptive_w * 4;

    let mut act_elems = lr_elems; // latent input kept for the first BW-GRAD
    for layer in net.adaptive_layers(first_adaptive) {
        act_elems += layer.out_elems();
    }
    let act_bytes = act_elems * batch * 4;

    MemoryBreakdown {
        lr_bytes: lr,
        frozen_param_bytes: frozen_bytes,
        adaptive_param_bytes: adaptive_bytes,
        gradient_bytes: grad_bytes,
        activation_bytes: act_bytes,
    }
}

/// The *incremental* footprint one fleet tenant adds on top of the shared
/// frozen backbone: LR memory + adaptive params + gradients + one
/// mini-batch of training activations. This is the quantity the fleet's
/// `MemoryGovernor` charges per tenant against its global budget (the
/// frozen stage is loaded once per host and shared via `Arc`, so it is
/// excluded here and accounted once by [`shared_backbone_bytes`]).
pub fn tenant_bytes(net: &NetDesc, l: usize, n_lr: usize, q: QuantSetting, batch: usize) -> usize {
    let b = breakdown(net, l, n_lr, q, batch);
    b.total() - b.frozen_param_bytes
}

/// Bytes of the shared frozen backbone for split `l`: loaded once per
/// fleet host regardless of tenant count.
pub fn shared_backbone_bytes(net: &NetDesc, l: usize, frozen_bits: u8) -> usize {
    breakdown(net, l, 0, QuantSetting { frozen_bits, lr_bits: 8 }, 1).frozen_param_bytes
}

/// How many tenants of this configuration fit a global byte budget (the
/// EXPERIMENTS.md §Fleet "tenants per 64 MB" table): the shared backbone
/// is paid once, then tenants until the budget runs out.
pub fn tenants_within_budget(
    net: &NetDesc,
    l: usize,
    n_lr: usize,
    q: QuantSetting,
    batch: usize,
    budget_bytes: usize,
) -> usize {
    let shared = shared_backbone_bytes(net, l, q.frozen_bits);
    let per = tenant_bytes(net, l, n_lr, q, batch);
    budget_bytes.saturating_sub(shared) / per.max(1)
}

/// [`tenants_within_budget`] with the fleet's cold (disk-spill) tier
/// enabled: only `hot_num` of every `hot_den` tenants stay resident in
/// RAM at once (the working set), the rest wait as cold-tier snapshots
/// charged to disk, not to the budget. The hot fraction is a rational so
/// the capacity stays exact integer arithmetic — one source of truth
/// with the live governor, which charges residents the very same
/// `tenant_bytes` and spilled tenants zero RAM.
///
/// `hot_num = hot_den` degenerates to [`tenants_within_budget`];
/// `(1, 2)` — half the fleet hot — hosts ~2x the tenants per byte, which
/// is the capacity claim `examples/fleet_serving.rs` asserts live.
pub fn tenants_within_budget_tiered(
    net: &NetDesc,
    l: usize,
    n_lr: usize,
    q: QuantSetting,
    batch: usize,
    budget_bytes: usize,
    hot_num: usize,
    hot_den: usize,
) -> usize {
    assert!(
        hot_num >= 1 && hot_den >= hot_num,
        "hot fraction must satisfy 1 <= hot_num <= hot_den (got {hot_num}/{hot_den})"
    );
    let shared = shared_backbone_bytes(net, l, q.frozen_bits);
    let per = tenant_bytes(net, l, n_lr, q, batch);
    // residents = tenants * hot_num / hot_den must fit the budget:
    // tenants <= free * hot_den / (per * hot_num)
    budget_bytes.saturating_sub(shared) * hot_den / (per.max(1) * hot_num)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{micronet32, mobilenet_v1_128};

    const INT8_U8: QuantSetting = QuantSetting { frozen_bits: 8, lr_bits: 8 };
    const FP32_FP32: QuantSetting = QuantSetting { frozen_bits: 32, lr_bits: 32 };

    #[test]
    fn paper_lr_memory_scale() {
        // 3000 LRs at l=19 (32k elems) in UINT-8 ~ 96 MB -> wait: the paper's
        // Fig 6 x-axis tops out below 128 MB; 3000 * 32768 B = 93.75 MB. And
        // the same in FP32 is 375 MB (4x compression headline).
        let net = mobilenet_v1_128();
        let u8b = breakdown(&net, 19, 3000, INT8_U8, 128);
        let fp = breakdown(&net, 19, 3000, FP32_FP32, 128);
        assert_eq!(u8b.lr_bytes, 3000 * 32768);
        assert_eq!(fp.lr_bytes, 4 * u8b.lr_bytes);
    }

    #[test]
    fn headline_under_64mb() {
        // paper abstract: "continual learning ... using less than 64 MB";
        // the cluster-B point: l=23, 1500 LRs, UINT-8.
        let net = mobilenet_v1_128();
        let b = breakdown(&net, 23, 1500, INT8_U8, 128);
        assert!(
            b.total_mb() < 64.0,
            "cluster-B memory {} MB exceeds the paper bound",
            b.total_mb()
        );
    }

    #[test]
    fn deeper_split_means_less_lr_memory_more_frozen() {
        let net = mobilenet_v1_128();
        let a = breakdown(&net, 19, 1500, INT8_U8, 128);
        let b = breakdown(&net, 27, 1500, INT8_U8, 128);
        assert!(b.lr_bytes < a.lr_bytes);
        assert!(b.frozen_param_bytes > a.frozen_param_bytes);
        assert!(b.adaptive_param_bytes < a.adaptive_param_bytes);
    }

    #[test]
    fn lr_bits_ordering() {
        let net = mobilenet_v1_128();
        let mk = |bits| {
            breakdown(&net, 19, 1500, QuantSetting { frozen_bits: 8, lr_bits: bits }, 128).lr_bytes
        };
        assert!(mk(6) < mk(7));
        assert!(mk(7) < mk(8));
        assert!(mk(8) < mk(32));
        // 7-bit saves exactly 12.5% over 8-bit on whole-byte latents
        assert_eq!(mk(7) * 8, mk(8) * 7);
    }

    #[test]
    fn micronet_totals_are_small() {
        // MicroNet @ N_LR=512, l=13 should fit a small MCU budget (<1 MB)
        let net = micronet32();
        let b = breakdown(&net, 13, 512, INT8_U8, 64);
        assert!(b.total_mb() < 2.0, "{} MB", b.total_mb());
        assert!(b.lr_bytes == 512 * 1024);
    }

    #[test]
    fn components_all_positive_and_sum() {
        let net = mobilenet_v1_128();
        let b = breakdown(&net, 23, 750, INT8_U8, 128);
        assert!(b.lr_bytes > 0 && b.frozen_param_bytes > 0);
        assert!(b.adaptive_param_bytes > 0 && b.gradient_bytes > 0);
        assert!(b.activation_bytes > 0);
        assert_eq!(
            b.total(),
            b.lr_bytes + b.frozen_param_bytes + b.adaptive_param_bytes
                + b.gradient_bytes + b.activation_bytes
        );
        assert_eq!(b.adaptive_param_bytes, b.gradient_bytes);
    }

    #[test]
    fn lr_component_matches_live_replay_buffer() {
        // the model's LR bytes and a real buffer's arena must agree — the
        // "one source of truth" contract behind the governor tables
        let net = micronet32();
        for bits in [6u8, 7, 8, 32] {
            let q = QuantSetting { frozen_bits: 8, lr_bits: bits };
            let b = breakdown(&net, 13, 96, q, 64);
            let elems = net.lr_elems(13);
            let live = if bits == 32 {
                ReplayBuffer::new_f32(96, elems)
            } else {
                ReplayBuffer::new_packed(96, elems, bits, 1.0)
            };
            assert_eq!(b.lr_bytes, live.storage_bytes(), "Q={bits}");
        }
    }

    #[test]
    fn tenant_bytes_excludes_shared_backbone() {
        let net = micronet32();
        let q = INT8_U8;
        let full = breakdown(&net, 13, 128, q, 64);
        let t = tenant_bytes(&net, 13, 128, q, 64);
        assert_eq!(t + full.frozen_param_bytes, full.total());
        assert_eq!(shared_backbone_bytes(&net, 13, 8), full.frozen_param_bytes);
    }

    #[test]
    fn q7_admits_more_tenants_than_q8() {
        let net = micronet32();
        let budget = 64 * 1024 * 1024;
        let n8 = tenants_within_budget(
            &net, 15, 512, QuantSetting { frozen_bits: 8, lr_bits: 8 }, 64, budget,
        );
        let n7 = tenants_within_budget(
            &net, 15, 512, QuantSetting { frozen_bits: 8, lr_bits: 7 }, 64, budget,
        );
        assert!(n8 > 0);
        assert!(n7 >= n8, "narrower LR codes must never admit fewer tenants");
    }

    #[test]
    fn tiered_capacity_scales_with_the_inverse_hot_fraction() {
        let net = micronet32();
        let budget = 64 * 1024 * 1024;
        let q = INT8_U8;
        let plain = tenants_within_budget(&net, 15, 512, q, 64, budget);
        let full_hot = tenants_within_budget_tiered(&net, 15, 512, q, 64, budget, 1, 1);
        assert_eq!(full_hot, plain, "hot fraction 1/1 must degenerate to the flat model");
        let half_hot = tenants_within_budget_tiered(&net, 15, 512, q, 64, budget, 1, 2);
        let quarter_hot = tenants_within_budget_tiered(&net, 15, 512, q, 64, budget, 1, 4);
        // the spill tier's whole point: >= 2x / 4x tenants per byte of
        // RAM (exact up to the floor of the integer division)
        assert!(half_hot >= 2 * plain, "{half_hot} < 2 * {plain}");
        assert!(quarter_hot >= 4 * plain, "{quarter_hot} < 4 * {plain}");
        assert!(quarter_hot >= 2 * half_hot);
    }

    #[test]
    fn int8_backbone_charge_matches_the_live_backend_arena() {
        // the model's INT-8 frozen bytes are the LIVE i8 storage of the
        // executing backend, byte for byte — the "one source of truth"
        // contract, now extended to the backbone (the fleet's capacity
        // tables charge exactly what the process allocates)
        use crate::runtime::native::net_from_manifest;
        use crate::runtime::synthetic::{self, SyntheticSpec};
        use crate::runtime::NativeBackend;
        let (m, _ds) = synthetic::generate(&SyntheticSpec::tiny()).unwrap();
        let net = net_from_manifest(&m).unwrap();
        let be = NativeBackend::new(m).unwrap();
        let n_conv = net.layers.len() - 1;
        // full-frozen split: every conv layer is backbone
        assert_eq!(be.frozen_arena_bytes(), shared_backbone_bytes(&net, n_conv, 8));
        // and 4x below the FP32 arm's charge for the same stage
        assert_eq!(shared_backbone_bytes(&net, n_conv, 32), 4 * be.frozen_arena_bytes());
    }

    #[test]
    fn quant_setting_labels() {
        assert_eq!(FP32_FP32.label(), "FP32+FP32");
        assert_eq!(QuantSetting { frozen_bits: 8, lr_bits: 7 }.label(), "UINT-8+UINT-7");
    }
}
