//! Static network descriptions + per-layer work/size accounting.
//!
//! Two networks (DESIGN.md §1):
//! - [`micronet32`]: the trainable model behind all *learned* experiments
//!   (its runtime twin is defined in `python/compile/model.py`; the two are
//!   cross-checked by `integration_runtime` against the manifest);
//! - [`mobilenet_v1_128`]: the paper's exact MobileNet-V1 (width 1.0,
//!   128x128 input, 50 classes) used by the simulator and the memory model
//!   to regenerate Table III/IV and Figs 7-10 on the paper's own workload.

pub mod memory;

/// Layer vocabulary of both networks (the paper's §IV-B kernel set).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv3x3,
    DepthWise,
    PointWise,
    Linear,
}

impl LayerKind {
    pub fn short(&self) -> &'static str {
        match self {
            LayerKind::Conv3x3 => "C3",
            LayerKind::DepthWise => "DW",
            LayerKind::PointWise => "PW",
            LayerKind::Linear => "Lin",
        }
    }
}

/// One layer, with its *input* geometry attached.
#[derive(Clone, Copy, Debug)]
pub struct LayerDesc {
    pub idx: usize,
    pub kind: LayerKind,
    pub cin: usize,
    pub cout: usize,
    pub stride: usize,
    /// input spatial size (H = W); 1 for Linear.
    pub hw_in: usize,
}

impl LayerDesc {
    pub fn hw_out(&self) -> usize {
        match self.kind {
            LayerKind::Linear => 1,
            _ => (self.hw_in + self.stride - 1) / self.stride,
        }
    }

    /// Multiply-accumulate count for ONE sample's forward pass.
    pub fn macs(&self) -> u64 {
        let ho = self.hw_out() as u64;
        match self.kind {
            LayerKind::Conv3x3 => ho * ho * 9 * self.cin as u64 * self.cout as u64,
            LayerKind::DepthWise => ho * ho * 9 * self.cin as u64,
            LayerKind::PointWise => ho * ho * self.cin as u64 * self.cout as u64,
            LayerKind::Linear => self.cin as u64 * self.cout as u64,
        }
    }

    /// Weight parameter count (affine/bias excluded; they are negligible
    /// and the paper's accounting likewise tracks the conv weights).
    pub fn n_weights(&self) -> usize {
        match self.kind {
            LayerKind::Conv3x3 => 9 * self.cin * self.cout,
            LayerKind::DepthWise => 9 * self.cin,
            LayerKind::PointWise => self.cin * self.cout,
            LayerKind::Linear => self.cin * self.cout,
        }
    }

    pub fn in_elems(&self) -> usize {
        match self.kind {
            LayerKind::Linear => self.cin,
            _ => self.hw_in * self.hw_in * self.cin,
        }
    }

    pub fn out_elems(&self) -> usize {
        match self.kind {
            LayerKind::Linear => self.cout,
            _ => self.hw_out() * self.hw_out() * self.cout,
        }
    }
}

/// A whole network as an ordered layer list.
#[derive(Clone, Debug)]
pub struct NetDesc {
    pub name: &'static str,
    pub input_hw: usize,
    pub num_classes: usize,
    pub layers: Vec<LayerDesc>,
}

impl NetDesc {
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    pub fn total_weights(&self) -> usize {
        self.layers.iter().map(|l| l.n_weights()).sum()
    }

    /// Layers retrained when training starts at layer `l` (`[l, L)`) —
    /// Table IV's row labeling: "retraining from layer #20 comprises a
    /// total of eight layers". The latents feeding layer `l` are the
    /// output of layer `l-1`, i.e. LR layer `l-1` in Table III's labeling.
    pub fn adaptive_layers(&self, l: usize) -> &[LayerDesc] {
        &self.layers[l..]
    }

    /// Latent-replay vector size (elements) for **LR layer `l`** in the
    /// paper's Table II/III/Fig 5-7 labeling: the *output* feature map of
    /// layer `l` (the pooled vector when `l` is the classifier row). The
    /// retrained stage is then `[l+1, L)`.
    ///
    /// NOTE on conventions: the runtime (micronet) splits are labeled by
    /// the *first retrained layer* (Table IV style); `lr_elems(l-1)` gives
    /// the latent size of runtime split `l`.
    pub fn lr_elems(&self, l: usize) -> usize {
        let layer = &self.layers[l];
        if layer.kind == LayerKind::Linear {
            layer.cin // Table III row 27: the pooled 1x1x1024 input
        } else {
            layer.out_elems()
        }
    }

    pub fn layer(&self, idx: usize) -> &LayerDesc {
        &self.layers[idx]
    }
}

fn push(
    layers: &mut Vec<LayerDesc>,
    kind: LayerKind,
    cin: usize,
    cout: usize,
    stride: usize,
    hw: &mut usize,
) {
    layers.push(LayerDesc {
        idx: layers.len(),
        kind,
        cin,
        cout,
        stride,
        hw_in: *hw,
    });
    if kind != LayerKind::Linear {
        *hw = (*hw + stride - 1) / stride;
    }
}

/// The paper's MobileNet-V1 (width 1.0) at 128x128, 50 classes.
/// Layer numbering matches the paper: 0 = stem conv, 1..=26 = DW/PW pairs
/// of the 13 blocks, 27 = classifier. Table III dims fall out of this
/// geometry (asserted in tests).
pub fn mobilenet_v1_128() -> NetDesc {
    let mut layers = Vec::with_capacity(28);
    let mut hw = 128usize;
    push(&mut layers, LayerKind::Conv3x3, 3, 32, 2, &mut hw);
    // (cout, dw_stride) per block, standard MobileNet-V1:
    let blocks = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    let mut cin = 32;
    for &(cout, s) in &blocks {
        push(&mut layers, LayerKind::DepthWise, cin, cin, s, &mut hw);
        push(&mut layers, LayerKind::PointWise, cin, cout, 1, &mut hw);
        cin = cout;
    }
    push(&mut layers, LayerKind::Linear, 1024, 50, 1, &mut hw);
    NetDesc {
        name: "mobilenet_v1_128",
        input_hw: 128,
        num_classes: 50,
        layers,
    }
}

/// MicroNet-32: the repo's trainable model (mirror of python ARCH).
pub fn micronet32() -> NetDesc {
    let mut layers = Vec::with_capacity(16);
    let mut hw = 32usize;
    push(&mut layers, LayerKind::Conv3x3, 3, 16, 2, &mut hw);
    let blocks = [(32, 1), (64, 2), (64, 1), (128, 2), (128, 1), (256, 2), (256, 1)];
    let mut cin = 16;
    for &(cout, s) in &blocks {
        push(&mut layers, LayerKind::DepthWise, cin, cin, s, &mut hw);
        push(&mut layers, LayerKind::PointWise, cin, cout, 1, &mut hw);
        cin = cout;
    }
    push(&mut layers, LayerKind::Linear, 256, 10, 1, &mut hw);
    NetDesc {
        name: "micronet32",
        input_hw: 32,
        num_classes: 10,
        layers,
    }
}

/// The paper's Table III rows: (LR layer, kind, H, W, C) of the stored LR.
/// For rows 19..=26 the paper lists the *output* feature map of layer `l`;
/// row 27 stores the pooled 1024-vector.
pub fn table3_rows() -> Vec<(usize, LayerKind, usize, usize, usize)> {
    let net = mobilenet_v1_128();
    (19..=27)
        .map(|l| {
            let layer = net.layer(l);
            if layer.kind == LayerKind::Linear {
                (l, layer.kind, 1, 1, layer.cin)
            } else {
                let hw = layer.hw_out();
                (l, layer.kind, hw, hw, layer.cout)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mobilenet_matches_paper_table3() {
        // Table III of the paper, verbatim.
        let expected = [
            (19, LayerKind::DepthWise, 8, 8, 512),
            (20, LayerKind::PointWise, 8, 8, 512),
            (21, LayerKind::DepthWise, 8, 8, 512),
            (22, LayerKind::PointWise, 8, 8, 512),
            (23, LayerKind::DepthWise, 4, 4, 512),
            (24, LayerKind::PointWise, 4, 4, 1024),
            (25, LayerKind::DepthWise, 4, 4, 1024),
            (26, LayerKind::PointWise, 4, 4, 1024),
            (27, LayerKind::Linear, 1, 1, 1024),
        ];
        for (row, exp) in table3_rows().iter().zip(expected.iter()) {
            assert_eq!(row, exp, "Table III row mismatch");
        }
    }

    #[test]
    fn mobilenet_structure() {
        let net = mobilenet_v1_128();
        assert_eq!(net.layers.len(), 28);
        assert_eq!(net.layer(0).kind, LayerKind::Conv3x3);
        assert_eq!(net.layer(27).kind, LayerKind::Linear);
        assert_eq!(net.layer(27).cin, 1024);
        assert_eq!(net.layer(27).cout, 50);
        // ~4.2M weights for width-1.0 MobileNet-V1 (50-class head)
        let w = net.total_weights();
        assert!((3_100_000..3_400_000).contains(&w), "weights {w}");
        // ~186 MMAC/frame at 128x128 (0.25x of the 224x224 569 MMAC figure)
        let m = net.total_macs();
        assert!((150_000_000..220_000_000).contains(&m), "macs {m}");
    }

    #[test]
    fn micronet_structure_matches_python_arch() {
        let net = micronet32();
        assert_eq!(net.layers.len(), 16);
        assert_eq!(net.layer(15).kind, LayerKind::Linear);
        // Runtime split l stores the input of layer l = output of layer
        // l-1, i.e. lr_elems(l-1) in Table-III labeling; these mirror
        // python model.latent_shape for SPLITS = (9, 11, 13, 15).
        assert_eq!(net.lr_elems(8), 4 * 4 * 128); // split 9
        assert_eq!(net.lr_elems(10), 4 * 4 * 128); // split 11
        assert_eq!(net.lr_elems(12), 2 * 2 * 256); // split 13
        assert_eq!(net.lr_elems(15), 256); // split 15 (pooled)
        // ~139k weights+head (excl. affine params)
        let w = net.total_weights();
        assert!((130_000..145_000).contains(&w), "weights {w}");
    }

    #[test]
    fn macs_positive_and_spatial_consistent() {
        for net in [micronet32(), mobilenet_v1_128()] {
            let mut hw = net.input_hw;
            for l in &net.layers {
                if l.kind != LayerKind::Linear {
                    assert_eq!(l.hw_in, hw, "{}: layer {} hw", net.name, l.idx);
                    hw = l.hw_out();
                }
                assert!(l.macs() > 0);
            }
        }
    }

    #[test]
    fn adaptive_layer_counts_match_table4_semantics() {
        let net = mobilenet_v1_128();
        assert_eq!(net.adaptive_layers(27).len(), 1); // head only
        assert_eq!(net.adaptive_layers(20).len(), 8); // paper: "eight layers"
    }

    #[test]
    fn dw_macs_share_is_small() {
        // paper §IV-B: depthwise accounts for <1.5% of MobileNet compute
        let net = mobilenet_v1_128();
        let dw: u64 = net
            .layers
            .iter()
            .filter(|l| l.kind == LayerKind::DepthWise)
            .map(|l| l.macs())
            .sum();
        let share = dw as f64 / net.total_macs() as f64;
        assert!(share < 0.04, "dw share {share}");
    }
}
