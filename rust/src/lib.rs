//! # tinycl — TinyML On-Device Continual Learning with Quantized Latent Replays
//!
//! Rust + JAX + Pallas reproduction of Ravaglia et al., *"A TinyML Platform
//! for On-Device Continual Learning with Quantized Latent Replays"*
//! (IEEE JETCAS 2021). Three layers:
//!
//! - **L1/L2 (build time, Python)**: Pallas compute kernels + the JAX model,
//!   AOT-lowered to HLO text under `artifacts/` by `make artifacts`;
//! - **L3 (this crate)**: the continual-learning coordinator — replay
//!   buffer, batcher, NICv2 protocol driver, trainer — executing the AOT
//!   modules through PJRT with no Python on the request path, plus the
//!   VEGA/STM32L4 performance-model substrate that regenerates the paper's
//!   systems evaluation (Figs 7-10, Tables III-IV).
//!
//! On top of the single-learner stack, the [`fleet`] layer serves MANY
//! concurrent CL tenants per host: one `Arc`-shared frozen backbone,
//! per-tenant adaptive heads + quantized replay memories, a global
//! 64 MB memory governor running a three-tier replay hierarchy (hot
//! 8-bit / warm 7-bit in RAM, cold spilled to checksummed disk
//! snapshots with lazy restore and watermark-driven 7→8-bit
//! promotion), and cross-tenant batched frozen/inference compute.
//!
//! Entry points: the `tinycl` binary (`fig`, `run`, `fleet`, `info`
//! subcommands), the `examples/`, and the public API re-exported from
//! these modules.

pub mod coordinator;
pub mod exec;
pub mod fleet;
pub mod harness;
pub mod kernels;
pub mod models;
pub mod net;
pub mod quant;
pub mod runtime;
pub mod simulator;
pub mod telemetry;
pub mod util;
