//! # tinycl — TinyML On-Device Continual Learning with Quantized Latent Replays
//!
//! Rust + JAX + Pallas reproduction of Ravaglia et al., *"A TinyML Platform
//! for On-Device Continual Learning with Quantized Latent Replays"*
//! (IEEE JETCAS 2021). Three layers:
//!
//! - **L1/L2 (build time, Python)**: Pallas compute kernels + the JAX model,
//!   AOT-lowered to HLO text under `artifacts/` by `make artifacts`;
//! - **L3 (this crate)**: the continual-learning coordinator — replay
//!   buffer, batcher, NICv2 protocol driver, trainer — executing the AOT
//!   modules through PJRT with no Python on the request path, plus the
//!   VEGA/STM32L4 performance-model substrate that regenerates the paper's
//!   systems evaluation (Figs 7-10, Tables III-IV).
//!
//! Entry points: the `tinycl` binary (`fig`, `run`, `info` subcommands),
//! the `examples/`, and the public API re-exported from these modules.

pub mod coordinator;
pub mod harness;
pub mod kernels;
pub mod models;
pub mod quant;
pub mod runtime;
pub mod simulator;
pub mod util;
