//! `tinycl` — the leader binary of the QLR-CL platform.
//!
//! Subcommands:
//!   info                         manifest + platform summary
//!   run [--l N --n-lr N ...]     one full continual-learning protocol run
//!   fleet [--tenants N ...]      multi-tenant serving demo (shared
//!                                backbone + memory governor)
//!   shard --listen ADDR          one networked fleet shard (TCP ingress)
//!   shard-client --shards A,B    drive a sharded fleet over the wire
//!                                (admit, train, migrate, eval; stamped
//!                                exactly-once retries and failover)
//!   supervise --shards N         spawn + heartbeat + restart shard
//!                                processes (crash drills, MTTR)
//!   fig --id <id> | --all        regenerate a paper table/figure
//!   sim [--target vega|stm32l4]  simulated event latency/energy report
//!
//! See README.md for the full tour; `make figures` drives `fig --all`.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};
use tinycl::coordinator::{run_protocol, CLConfig, RunOptions};
use tinycl::fleet::{
    submit_with_backoff, traffic, FaultPlan, FleetApi, FleetClient, FleetConfig, FleetError,
    FleetServer, GovernorAction, RetryPolicy, ShardSupervisor, SupervisorConfig, TenantConfig,
};
use tinycl::harness::{self, Profile};
use tinycl::models::mobilenet_v1_128;
use tinycl::net::ShardServer;
use tinycl::runtime::{open_default_backend, open_shared_native};
use tinycl::simulator::executor::{event_seconds, EventSpec};
use tinycl::simulator::targets::{stm32l4, vega};
use tinycl::util::cli;
use tinycl::util::json::Json;

const USAGE: &str = "\
tinycl — TinyML on-device continual learning with quantized latent replays

USAGE:
  tinycl info
  tinycl run   [--l 13] [--n-lr 256] [--lr-bits 8|7|6|32] [--frozen int8|fp32]
               [--lr 0.1] [--epochs 2] [--seed 0] [--events N] [--eval-every 8]
  tinycl fleet [--tenants 8] [--workers 4 | 0 = auto (TINYCL_THREADS)]
               [--events 4] [--l 15] [--n-lr 128]
               [--budget-mb 64] [--coalesce 8] [--seed 1]
               [--spill-dir PATH] [--low-watermark 0.6] [--high-watermark 0.85]
               [--fault-plan SEED] [--shed-ms N]
               [--telemetry out.json] [--trace out.trace.json]
               (TINYCL_TELEMETRY=1 enables recording without the flags;
                TINYCL_LOG=1 renders governor actions on stderr)
  tinycl shard [--listen 127.0.0.1:0] [--shard-index 0] [--workers 2]
               [--l 15] [--budget-mb 64] [--max-tenants 64]
               [--spill-dir PATH] [--shed-ms N] [--crash-after-frames N]
               (prints \"shard I listening on ADDR\" once bound; serves
                framed requests until a Shutdown frame, then reports;
                --crash-after-frames scripts a process death for the
                supervisor drill)
  tinycl shard-client --shards 127.0.0.1:P1,127.0.0.1:P2 [--tenants 4]
               [--events 4] [--n-lr 128] [--seed 1000]
               [--min-migrations 0] [--shutdown] [--out BENCH_shard.json]
               [--client-id N] [--net-fault-plan SEED] [--addrs-file P]
               (admits tenants hashed across shards, trains two traffic
                legs with a pressure rebalance between them, evaluates
                every tenant, and optionally shuts the shards down;
                --client-id turns on exactly-once stamped retries,
                --net-fault-plan injects the bit-transparent seeded
                network chaos, --addrs-file follows supervisor restarts)
  tinycl supervise --shards 2 --addrs-file PATH [--spill-root DIR]
               [--workers 2] [--heartbeat-ms 100] [--ping-timeout-ms 500]
               [--max-misses 3] [--crash-shard I --crash-after-frames N]
               [--l 15] [--budget-mb 64] [--max-tenants 64] [--shed-ms N]
               (spawns the shards, publishes their addresses atomically,
                heartbeats them, restarts any that die, reports MTTR;
                returns once every shard shut down cleanly)
  tinycl fig   --id <tab1|tab2|tab3|tab4|fig5..fig10|fleet> [--profile fast|paper]
  tinycl fig   --all [--profile fast|paper]
  tinycl sim   [--l 23] [--target vega|stm32l4]
";

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&raw, &["all", "verbose", "help", "shutdown"]);
    if args.flag("help") || args.positional.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    match args.positional[0].as_str() {
        "info" => info(),
        "run" => run(&args),
        "fleet" => fleet(&args),
        "shard" => shard(&args),
        "shard-client" => shard_client(&args),
        "supervise" => supervise(&args),
        "fig" => fig(&args),
        "sim" => sim(&args),
        other => {
            eprintln!("unknown subcommand '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    }
}

fn info() -> Result<()> {
    let (be, ds) = open_default_backend()?;
    let m = be.manifest();
    println!("tinycl artifacts @ {:?}", m.dir);
    println!("  platform    : {}", be.platform());
    println!("  model       : MicroNet-32 ({} params, {} classes, input {}x{})",
        m.num_params, m.num_classes, m.input_hw, m.input_hw);
    println!("  splits      : {:?}", m.splits);
    println!("  quant       : W{} A{} (PTQ)", m.w_bits, m.a_bits);
    println!("  batches     : train {} ({} new + {} replay), eval {}",
        m.batch_train, m.batch_new, m.batch_train - m.batch_new, m.batch_eval);
    for (&l, lat) in &m.latent {
        println!("  latent l={:2}: shape {:?} ({} elems), a_max={:.3}",
            l, lat.shape, lat.elems(), lat.a_max_int8);
    }
    println!("  dataset     : {} train / {} test images", ds.n_train(), ds.n_test());
    Ok(())
}

fn run(args: &cli::Args) -> Result<()> {
    let (be, ds) = open_default_backend()?;
    let cfg = CLConfig {
        l: args.usize_or("l", 13),
        n_lr: args.usize_or("n-lr", 256),
        lr_bits: args.usize_or("lr-bits", 8) as u8,
        int8_frozen: args.get_or("frozen", "int8") == "int8",
        lr: args.f64_or("lr", 0.1) as f32,
        epochs: args.usize_or("epochs", 2),
        seed: args.u64_or("seed", 0),
    };
    let opts = RunOptions {
        eval_every: args.usize_or("eval-every", 8),
        max_events: args.usize_or("events", 0),
        verbose: true,
    };
    println!("running protocol: {} on {}", cfg.label(), be.platform());
    let result = run_protocol(&*be, &ds, cfg, opts)?;
    println!("\naccuracy curve:");
    for (ev, acc) in result.accuracy_curve() {
        println!("  event {ev:3}: {acc:.3}");
    }
    println!("final accuracy : {:.3} (initial {:.3})", result.final_acc, result.initial_acc);
    println!("LR storage     : {} bytes", result.lr_storage_bytes);
    println!("wall time      : {:?} total, {:?}/event",
        result.total_wall, result.mean_event_wall());
    Ok(())
}

/// Multi-tenant serving demo: admit N tenants over the shared native
/// backbone, drive a few NICv2 events each through the worker pool under
/// the governor's budget, report accuracy + throughput + governor log.
/// With `--spill-dir` the cold (disk) tier is enabled: coldest tenants
/// spill to snapshot files under pressure, restore lazily on traffic,
/// and a post-run `rebalance()` walks the ladder back up under the
/// watermark hysteresis.
fn fleet(args: &cli::Args) -> Result<()> {
    let n_tenants = args.usize_or("tenants", 8).max(1);
    let events_per_tenant = args.usize_or("events", 4);
    let seed0 = args.u64_or("seed", 1);
    let fault_seed = args.get("fault-plan").map(|s| s.parse::<u64>()).transpose()?;
    let shed_ms = args.get("shed-ms").map(|s| s.parse::<u64>()).transpose()?;
    // either export flag turns recording on; otherwise defer to the
    // TINYCL_TELEMETRY env knob (off by default — recording never
    // changes outcomes, but the zero-cost default is the contract)
    let telemetry_out = args.get("telemetry").map(std::path::PathBuf::from);
    let trace_out = args.get("trace").map(std::path::PathBuf::from);

    let mut b = FleetConfig::builder(args.usize_or("l", 15))
        .budget_mb(args.usize_or("budget-mb", 64))
        .low_watermark(args.f64_or("low-watermark", 0.60))
        .high_watermark(args.f64_or("high-watermark", 0.85))
        .coalesce(args.usize_or("coalesce", 8))
        .max_tenants(n_tenants.max(256))
        .telemetry(if telemetry_out.is_some() || trace_out.is_some() {
            tinycl::telemetry::Telemetry::enabled()
        } else {
            tinycl::telemetry::Telemetry::from_env()
        });
    if let Some(dir) = args.get("spill-dir") {
        b = b.spill_dir(dir);
    } else if let Some(seed) = fault_seed {
        // the chaos plan targets spill I/O; give it a cold tier
        let dir = std::env::temp_dir().join(format!("tinycl-fleet-chaos-{seed}"));
        std::fs::create_dir_all(&dir)?;
        b = b.spill_dir(dir);
    }
    if let Some(seed) = fault_seed {
        b = b.faults(FaultPlan::seeded(seed));
    }
    if let Some(max_wait_ms) = shed_ms {
        b = b.shed_after_ms(max_wait_ms);
    }
    let cfg = b.build()?;
    // --workers 0 = auto: size serving to the unified exec config (the
    // same TINYCL_THREADS resolution the kernel pool uses)
    let workers = match args.usize_or("workers", 4) {
        0 => cfg.exec.threads,
        w => w,
    };

    let (be, ds) = open_shared_native()?;
    println!("fleet on {} (shared backbone, governor budget {} MB)",
        be.platform(), cfg.governor.budget_bytes / (1024 * 1024));
    if let Some(seed) = fault_seed {
        println!("fault plan: seeded({seed}), spill dir {:?}", cfg.spill_dir.as_deref().unwrap());
    }
    let server = FleetServer::new(be, cfg)?;

    // admit: every tenant seeds from the same pre-deployment pool,
    // embedded once through the shared backbone
    let (init_images, init_labels) = traffic::init_pool(&ds);
    let init_latents = server.embed_images(&init_images)?;
    let mut ids = Vec::new();
    for t in 0..n_tenants {
        let tcfg = TenantConfig {
            n_lr: args.usize_or("n-lr", 128),
            seed: seed0 + t as u64,
            ..TenantConfig::default()
        };
        ids.push(server.admit_prepared(tcfg, &init_latents, &init_labels)?);
    }
    println!("admitted {} tenants, {} B in use", ids.len(), server.bytes_in_use());

    // the canonical interleaved per-tenant NICv2 stream
    let seeded: Vec<(usize, u64)> = ids.iter().map(|&id| (id, seed0 + id as u64)).collect();
    let events = traffic::interleaved_nicv2(
        &server.backend().manifest().protocol,
        &ds,
        &seeded,
        events_per_tenant,
    );

    let report = server.run(events, workers)?;
    println!(
        "\nprocessed {} events in {:.2} s  ({:.1} events/s, p50 {:.1} ms, p99 {:.1} ms)",
        report.events, report.wall_s, report.events_per_sec,
        report.latency.p50_ms, report.latency.p99_ms
    );
    println!(
        "frozen coalescing: {} engine calls for {} rows ({:.2} events/call)",
        report.frozen_calls, report.frozen_rows, report.mean_coalesce
    );
    if report.lazy_restores > 0 {
        println!("lazy restores during serving: {}", report.lazy_restores);
    }
    if let Some(tr) = &report.telemetry {
        print!("{}", tr.render());
    }
    if fault_seed.is_some() || shed_ms.is_some() {
        let r = &report.robustness;
        println!(
            "robustness: {} shed, {} I/O retries, {} degrades (service level {:?})",
            r.shed, r.io_retries, r.degrades, server.service_level()
        );
        let rejected = server.take_rejections();
        if let Some(worst) = rejected.iter().map(|j| j.retry_after_ms()).max() {
            println!(
                "admission: {} events rejected Overloaded (worst retry-after {worst} ms)",
                rejected.len()
            );
        }
    }
    // the whole-fleet sweep runs as low-priority pool tasks — off the
    // serving path (here the server is quiesced, so this is simply the
    // parallel form; accuracies are bit-identical to sequential calls)
    let accs = server.evaluate_tenants_async(&ds, &ids)?.wait()?;
    let mean_acc = accs.iter().sum::<f64>() / accs.len() as f64;
    println!("mean tenant accuracy: {mean_acc:.3} (min {:.3}, max {:.3})",
        accs.iter().cloned().fold(f64::INFINITY, f64::min),
        accs.iter().cloned().fold(f64::NEG_INFINITY, f64::max));
    let t = server.governor_tally();
    println!(
        "governor: {} admits, {} demotions, {} promotions, {} shrinks, {} spills, \
         {} unspills, {} evicts, {} rejects; {} B in use, {} B on disk",
        t.admits, t.demotes, t.promotes, t.shrinks, t.spills, t.unspills, t.evicts,
        t.rejects, server.bytes_in_use(), server.spilled_disk_bytes()
    );
    for a in server.governor_log() {
        match a {
            GovernorAction::Demote { tenant, from_bits, to_bits, freed } => {
                println!("  demoted tenant {tenant}: Q{from_bits} -> Q{to_bits} (freed {freed} B)");
            }
            GovernorAction::Spill { tenant, freed, disk_bytes } => {
                println!("  spilled tenant {tenant}: freed {freed} B RAM -> {disk_bytes} B disk");
            }
            GovernorAction::Promote { tenant, from_bits, to_bits, grew } => {
                println!("  promoted tenant {tenant}: Q{from_bits} -> Q{to_bits} (+{grew} B)");
            }
            GovernorAction::Degrade { tenant, bytes, disk_freed } => {
                println!(
                    "  degraded tenant {tenant}: rebuilt with empty replay \
                     ({bytes} B RAM, quarantined {disk_freed} B off-book)"
                );
            }
            _ => {}
        }
    }
    // with the cold tier enabled, walk the ladder back up once serving
    // has quiesced (a no-op unless usage sits below the low watermark)
    if server.config().spill_dir.is_some() {
        let out = server.rebalance()?;
        println!(
            "rebalance: {} unspilled, {} promoted ({} resident / {} cold, {} B in use)",
            out.unspilled, out.promoted, server.tenant_count(), server.spilled_count(),
            server.bytes_in_use()
        );
    }
    // exported from the live handle so post-run activity (the eval
    // sweep, rebalance spills) is included alongside the serving run
    let tm = &server.config().telemetry;
    if let Some(path) = &telemetry_out {
        let digest = tm.report().expect("--telemetry enables recording");
        std::fs::write(path, digest.to_json().to_string() + "\n")?;
        println!("wrote telemetry digest to {}", path.display());
    }
    if let Some(path) = &trace_out {
        let trace = tm.chrome_trace().expect("--trace enables recording");
        std::fs::write(path, trace.to_string() + "\n")?;
        println!("wrote Chrome trace to {} (open in chrome://tracing or Perfetto)", path.display());
    }
    Ok(())
}

/// One networked fleet shard: bind a TCP listener, print the bound
/// address (machine-readable — driving scripts wait for this line),
/// serve framed requests until a Shutdown frame, report.
fn shard(args: &cli::Args) -> Result<()> {
    let listen = args.get_or("listen", "127.0.0.1:0");
    let shard_index = args.usize_or("shard-index", 0) as u32;
    let workers = args.usize_or("workers", 2).max(1);
    let mut b = FleetConfig::builder(args.usize_or("l", 15))
        .budget_mb(args.usize_or("budget-mb", 64))
        .max_tenants(args.usize_or("max-tenants", 64))
        .telemetry(tinycl::telemetry::Telemetry::from_env());
    if let Some(dir) = args.get("spill-dir") {
        b = b.spill_dir(dir);
    }
    if let Some(ms) = args.get("shed-ms").map(|s| s.parse::<u64>()).transpose()? {
        b = b.shed_after_ms(ms);
    }
    if let Some(n) = args.get("crash-after-frames").map(|s| s.parse::<u64>()).transpose()? {
        // scripted process death for the supervisor drill: the shard
        // exits mid-operation once it has served n frames
        b = b.faults(FaultPlan::none().with_shard_crash(n));
    }
    let cfg = b.build()?;
    let (be, ds) = open_shared_native()?;
    let srv = ShardServer::bind(be, Arc::new(ds), cfg, shard_index, workers, listen)?;
    println!("shard {shard_index} listening on {}", srv.local_addr());
    let fleet = srv.fleet().clone();
    let report = srv.serve()?;
    println!(
        "shard {shard_index}: {} events in {:.2} s ({:.1} events/s), {} resident / {} cold",
        report.events,
        report.wall_s,
        report.events_per_sec,
        fleet.tenant_count(),
        fleet.spilled_count()
    );
    if let Some(tr) = &report.telemetry {
        print!("{}", tr.render());
    }
    Ok(())
}

/// Drive a sharded fleet over the wire: admit tenants hashed across the
/// shards, train a first traffic leg, rebalance (live-migrating under
/// governor pressure, or explicitly when --min-migrations demands it),
/// train a second leg, then evaluate every tenant. The `determinism`
/// block in --out carries accuracy BITS (hex), so `bench_check.py diff`
/// proves a 2-shard run byte-equal to the 1-shard control.
fn shard_client(args: &cli::Args) -> Result<()> {
    let addrs_file = args.get("addrs-file").map(String::from);
    let mut addrs: Vec<String> = match &addrs_file {
        Some(path) => read_addrs_file(path)?,
        None => args
            .get_or("shards", "127.0.0.1:7600")
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
    };
    let n_tenants = args.usize_or("tenants", 4).max(1);
    let events_per_tenant = args.usize_or("events", 4).max(2);
    let n_lr = args.usize_or("n-lr", 128);
    let seed0 = args.u64_or("seed", 1000);
    let min_migrations = args.usize_or("min-migrations", 0);
    let out_path = args.get("out");
    let client_id = args.u64_or("client-id", 0);
    // the bit-transparent network chaos preset: every injected fault
    // streak resolves inside the client's retry budget, so results stay
    // byte-identical to a fault-free run
    let plan = match args.get("net-fault-plan").map(|s| s.parse::<u64>()).transpose()? {
        Some(seed) => FaultPlan::net_recovering(seed),
        None => FaultPlan::none(),
    };
    // failover mode: only meaningful under a supervisor that rewrites
    // the addrs file when it restarts a dead shard
    let supervised = addrs_file.is_some();

    // generous connect retry: the shard processes may still be binding
    let retry = RetryPolicy { attempts: 40, base: Duration::from_millis(20) };
    let mut client = FleetClient::connect_with(&addrs, &retry, &plan, client_id)?;
    println!("connected to {} shard(s)", client.shard_count());

    // the same synthetic world the shards opened (deterministic from the
    // TINYCL_SYNTH_* env, which launcher scripts keep identical) — used
    // ONLY to generate traffic; all tenant state lives in the shards
    let (be, ds) = open_shared_native()?;
    let tenants: Vec<(usize, u64)> =
        (0..n_tenants).map(|g| (g, seed0 + g as u64)).collect();
    for &(g, seed) in &tenants {
        let tcfg = TenantConfig { n_lr, seed, ..TenantConfig::default() };
        let t = g as u64;
        with_failover(&mut client, supervised, addrs_file.as_deref(), &mut addrs,
            |c| c.router().route(t), |c| c.admit(t, tcfg.clone()))?;
        println!("tenant {g} -> shard {}", client.router().route(t));
    }

    let protocol = &be.manifest().protocol;
    let leg1 = events_per_tenant / 2;
    let leg2 = events_per_tenant - leg1;
    let t0 = Instant::now();
    let mut sheds = 0u32;
    for ev in traffic::nicv2_window(protocol, &ds, &tenants, 0, leg1) {
        let t = ev.tenant as u64;
        sheds += with_failover(&mut client, supervised, addrs_file.as_deref(), &mut addrs,
            |c| c.router().route(t),
            |c| submit_with_backoff(c, t, &ev.images, &ev.labels, 64))?
            .sheds;
    }

    // between the legs: pressure-driven rebalance; if the fleet is too
    // balanced to trigger one and the caller requires live migrations
    // (CI does), move the coldest tenant off the most-loaded shard
    for _ in 0..n_tenants {
        match client.rebalance()? {
            Some((t, from, to)) => println!("rebalanced tenant {t}: shard {from} -> {to}"),
            None => break,
        }
    }
    if client.shard_count() > 1 {
        let mut forced = 0;
        while client.migrations().len() < min_migrations && forced < n_tenants {
            let stats = client.stats()?;
            let busiest = stats
                .iter()
                .max_by_key(|s| s.tenants.len())
                .context("no shard stats")?;
            let Some(victim) = busiest.tenants.iter().min_by_key(|t| t.last_active) else {
                break;
            };
            let to = (busiest.shard as usize + 1) % client.shard_count();
            let t = victim.tenant;
            // the suspect on a failed migration is the DESTINATION (a
            // failed restore); the source keeps the tombstone meanwhile
            with_failover(&mut client, supervised, addrs_file.as_deref(), &mut addrs,
                |_| to, |c| c.migrate(t, to))?;
            println!("migrated tenant {t}: shard {} -> {to}", busiest.shard);
            forced += 1;
        }
    }

    for ev in traffic::nicv2_window(protocol, &ds, &tenants, leg1, leg2) {
        let t = ev.tenant as u64;
        sheds += with_failover(&mut client, supervised, addrs_file.as_deref(), &mut addrs,
            |c| c.router().route(t),
            |c| submit_with_backoff(c, t, &ev.images, &ev.labels, 64))?
            .sheds;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let total_events = n_tenants * events_per_tenant;

    // unresolved migration outcomes (a source shard that was down when
    // its commit/abort was due) are replayed before the final audit
    client.resolve_pending();
    let mut accs = Vec::new();
    let mut lost = 0usize;
    for &(g, _) in &tenants {
        let t = g as u64;
        match with_failover(&mut client, supervised, addrs_file.as_deref(), &mut addrs,
            |c| c.router().route(t), |c| c.evaluate(t))
        {
            Ok(acc) => accs.push((g, acc)),
            Err(e) => {
                eprintln!("tenant {g} LOST: {e}");
                lost += 1;
            }
        }
    }
    let n_migrations = client.migrations().len();
    let mean = accs.iter().map(|(_, a)| a).sum::<f64>() / accs.len().max(1) as f64;
    println!(
        "{total_events} events in {wall_s:.2} s ({:.1} events/s over the wire), \
         {sheds} shed, {n_migrations} live migration(s), {lost} tenant(s) lost, \
         mean accuracy {mean:.3}",
        total_events as f64 / wall_s
    );
    if plan.is_enabled() || supervised {
        println!(
            "recovery: {} net retries, {} failover(s), {} duplicate ack(s), {} unresolved",
            client.net_retries(),
            client.failovers(),
            client.duplicates(),
            client.pending().len()
        );
    }
    ensure!(lost == 0, "{lost} tenant(s) lost during sharded serving");
    ensure!(
        n_migrations >= min_migrations,
        "only {n_migrations} live migrations (need {min_migrations})"
    );

    if let Some(path) = out_path {
        let mut acc_bits: BTreeMap<String, Json> = BTreeMap::new();
        for &(g, acc) in &accs {
            acc_bits.insert(g.to_string(), Json::Str(format!("{:016x}", acc.to_bits())));
        }
        let mut det: BTreeMap<String, Json> = BTreeMap::new();
        det.insert("acc_bits".into(), Json::Obj(acc_bits));
        let mut root: BTreeMap<String, Json> = BTreeMap::new();
        root.insert("bench".into(), Json::Str("shard".into()));
        root.insert("shards".into(), Json::Num(client.shard_count() as f64));
        root.insert("tenants".into(), Json::Num(n_tenants as f64));
        root.insert("events_per_tenant".into(), Json::Num(events_per_tenant as f64));
        root.insert("events".into(), Json::Num(total_events as f64));
        root.insert("events_per_sec".into(), Json::Num(total_events as f64 / wall_s));
        root.insert("sheds".into(), Json::Num(sheds as f64));
        root.insert("migrations".into(), Json::Num(n_migrations as f64));
        root.insert("tenants_lost".into(), Json::Num(lost as f64));
        root.insert("determinism".into(), Json::Obj(det));
        let mut rec: BTreeMap<String, Json> = BTreeMap::new();
        rec.insert("net_retries".into(), Json::Num(client.net_retries() as f64));
        rec.insert("failovers".into(), Json::Num(client.failovers() as f64));
        rec.insert("duplicates".into(), Json::Num(client.duplicates() as f64));
        rec.insert("pending_unresolved".into(), Json::Num(client.pending().len() as f64));
        root.insert("recovery".into(), Json::Obj(rec));
        std::fs::write(path, Json::Obj(root).to_string() + "\n")?;
        println!("wrote {path}");
    }
    if args.flag("shutdown") {
        client.shutdown_all()?;
        println!("shards shut down");
    }
    Ok(())
}

fn read_addrs_file(path: &str) -> Result<Vec<String>> {
    let body =
        std::fs::read_to_string(path).with_context(|| format!("reading addrs file {path}"))?;
    let addrs: Vec<String> =
        body.lines().map(|l| l.trim().to_string()).filter(|l| !l.is_empty()).collect();
    ensure!(!addrs.is_empty(), "addrs file {path} is empty");
    Ok(addrs)
}

fn recoverable(e: &FleetError) -> bool {
    matches!(e, FleetError::Io(_) | FleetError::Protocol(_) | FleetError::ShardDown { .. })
}

/// Run one fleet op with supervisor-aware failover: on a transport-level
/// failure, mark the suspect shard down, re-read the addrs file (the
/// supervisor rewrites it after a restart), re-resolve routes + pending
/// migration outcomes, and retry. Without `supervised`, the op runs
/// once and its error stands.
fn with_failover<T>(
    client: &mut FleetClient,
    supervised: bool,
    addrs_file: Option<&str>,
    addrs: &mut Vec<String>,
    suspect: impl Fn(&FleetClient) -> usize,
    mut op: impl FnMut(&mut FleetClient) -> std::result::Result<T, FleetError>,
) -> std::result::Result<T, FleetError> {
    let rounds = if supervised { 60 } else { 1 };
    let mut last = None;
    for round in 0..rounds {
        match op(client) {
            Ok(v) => return Ok(v),
            Err(e) if supervised && recoverable(&e) && round + 1 < rounds => {
                let shard = suspect(client);
                client.mark_down(shard);
                // give the supervisor a beat to notice and restart
                std::thread::sleep(Duration::from_millis(100));
                if let Some(path) = addrs_file {
                    if let Ok(fresh) = read_addrs_file(path) {
                        *addrs = fresh;
                    }
                }
                // fails while the shard is still restarting; the next
                // round tries again
                let _ = client.re_resolve(addrs);
                last = Some(e);
            }
            Err(e) => return Err(e),
        }
    }
    Err(last.expect("at least one failover round ran"))
}

/// Spawn + heartbeat + restart a fleet of shard processes; exits once
/// every shard finished cleanly (clients deliver the Shutdown frames).
fn supervise(args: &cli::Args) -> Result<()> {
    let shards = args.usize_or("shards", 2).max(1);
    let addrs_file = std::path::PathBuf::from(args.get_or("addrs-file", "shard_addrs.txt"));
    let spill_root = std::path::PathBuf::from(
        args.get("spill-root")
            .map(String::from)
            .unwrap_or_else(|| {
                std::env::temp_dir().join("tinycl-supervise").to_string_lossy().into_owned()
            }),
    );
    let mut cfg = SupervisorConfig::new(
        std::env::current_exe().context("resolving own binary")?,
        shards,
        spill_root,
        addrs_file,
    );
    cfg.workers = args.usize_or("workers", 2).max(1);
    cfg.heartbeat = Duration::from_millis(args.u64_or("heartbeat-ms", 100).max(10));
    cfg.ping_timeout = Duration::from_millis(args.u64_or("ping-timeout-ms", 500).max(50));
    cfg.max_misses = args.usize_or("max-misses", 3).max(1) as u32;
    if let Some(n) = args.get("crash-after-frames").map(|s| s.parse::<u64>()).transpose()? {
        cfg.crash = Some((args.usize_or("crash-shard", shards - 1), n));
    }
    for key in ["l", "budget-mb", "max-tenants", "shed-ms"] {
        if let Some(v) = args.get(key) {
            cfg.shard_args.push(format!("--{key}"));
            cfg.shard_args.push(v.to_string());
        }
    }
    let sup = ShardSupervisor::start(cfg)?;
    println!("supervisor: {shards} shard(s) up: {}", sup.addresses().join(","));
    let report = sup.run()?;
    println!(
        "supervisor: {} restart(s), mttr_ms={:?}",
        report.restarts, report.mttr_ms
    );
    Ok(())
}

fn fig(args: &cli::Args) -> Result<()> {
    let profile = Profile::parse(args.get_or("profile", "fast"));
    if args.flag("all") {
        harness::run_all(profile)?;
        return Ok(());
    }
    match args.get("id") {
        Some(id) => {
            if !harness::run_one(id, profile)? {
                eprintln!("unknown figure id '{id}'; known: {:?}", harness::ALL_IDS);
                std::process::exit(2);
            }
            Ok(())
        }
        None => {
            eprintln!("fig requires --id <id> or --all; known ids: {:?}", harness::ALL_IDS);
            std::process::exit(2);
        }
    }
}

fn sim(args: &cli::Args) -> Result<()> {
    let l = args.usize_or("l", 23);
    let target = match args.get_or("target", "vega") {
        "stm32l4" | "stm32" => stm32l4(),
        _ => vega(),
    };
    let net = mobilenet_v1_128();
    let ev = EventSpec::paper();
    let secs = event_seconds(&target, &target.default_hw, &net, l, &ev);
    println!("{} @ {:.0} MHz, retraining from layer {l} of {}:",
        target.name, target.freq_hz / 1e6, net.name);
    println!("  learning event : {:.2} s", secs);
    println!("  energy         : {:.2} J", target.energy_j(secs));
    println!("  max event rate : {:.1}/hour", 3600.0 / secs);
    Ok(())
}
