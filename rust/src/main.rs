//! `tinycl` — the leader binary of the QLR-CL platform.
//!
//! Subcommands:
//!   info                         manifest + platform summary
//!   run [--l N --n-lr N ...]     one full continual-learning protocol run
//!   fleet [--tenants N ...]      multi-tenant serving demo (shared
//!                                backbone + memory governor)
//!   fig --id <id> | --all        regenerate a paper table/figure
//!   sim [--target vega|stm32l4]  simulated event latency/energy report
//!
//! See README.md for the full tour; `make figures` drives `fig --all`.

use anyhow::Result;
use tinycl::coordinator::{run_protocol, CLConfig, RunOptions};
use tinycl::fleet::{
    traffic, Admission, FaultPlan, FleetConfig, FleetServer, GovernorAction, TenantConfig,
};
use tinycl::harness::{self, Profile};
use tinycl::models::mobilenet_v1_128;
use tinycl::runtime::{open_default_backend, open_shared_native};
use tinycl::simulator::executor::{event_seconds, EventSpec};
use tinycl::simulator::targets::{stm32l4, vega};
use tinycl::util::cli;

const USAGE: &str = "\
tinycl — TinyML on-device continual learning with quantized latent replays

USAGE:
  tinycl info
  tinycl run   [--l 13] [--n-lr 256] [--lr-bits 8|7|6|32] [--frozen int8|fp32]
               [--lr 0.1] [--epochs 2] [--seed 0] [--events N] [--eval-every 8]
  tinycl fleet [--tenants 8] [--workers 4 | 0 = auto (TINYCL_THREADS)]
               [--events 4] [--l 15] [--n-lr 128]
               [--budget-mb 64] [--coalesce 8] [--seed 1]
               [--spill-dir PATH] [--low-watermark 0.6] [--high-watermark 0.85]
               [--fault-plan SEED] [--shed-ms N]
               [--telemetry out.json] [--trace out.trace.json]
               (TINYCL_TELEMETRY=1 enables recording without the flags;
                TINYCL_LOG=1 renders governor actions on stderr)
  tinycl fig   --id <tab1|tab2|tab3|tab4|fig5..fig10|fleet> [--profile fast|paper]
  tinycl fig   --all [--profile fast|paper]
  tinycl sim   [--l 23] [--target vega|stm32l4]
";

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&raw, &["all", "verbose", "help"]);
    if args.flag("help") || args.positional.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    match args.positional[0].as_str() {
        "info" => info(),
        "run" => run(&args),
        "fleet" => fleet(&args),
        "fig" => fig(&args),
        "sim" => sim(&args),
        other => {
            eprintln!("unknown subcommand '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    }
}

fn info() -> Result<()> {
    let (be, ds) = open_default_backend()?;
    let m = be.manifest();
    println!("tinycl artifacts @ {:?}", m.dir);
    println!("  platform    : {}", be.platform());
    println!("  model       : MicroNet-32 ({} params, {} classes, input {}x{})",
        m.num_params, m.num_classes, m.input_hw, m.input_hw);
    println!("  splits      : {:?}", m.splits);
    println!("  quant       : W{} A{} (PTQ)", m.w_bits, m.a_bits);
    println!("  batches     : train {} ({} new + {} replay), eval {}",
        m.batch_train, m.batch_new, m.batch_train - m.batch_new, m.batch_eval);
    for (&l, lat) in &m.latent {
        println!("  latent l={:2}: shape {:?} ({} elems), a_max={:.3}",
            l, lat.shape, lat.elems(), lat.a_max_int8);
    }
    println!("  dataset     : {} train / {} test images", ds.n_train(), ds.n_test());
    Ok(())
}

fn run(args: &cli::Args) -> Result<()> {
    let (be, ds) = open_default_backend()?;
    let cfg = CLConfig {
        l: args.usize_or("l", 13),
        n_lr: args.usize_or("n-lr", 256),
        lr_bits: args.usize_or("lr-bits", 8) as u8,
        int8_frozen: args.get_or("frozen", "int8") == "int8",
        lr: args.f64_or("lr", 0.1) as f32,
        epochs: args.usize_or("epochs", 2),
        seed: args.u64_or("seed", 0),
    };
    let opts = RunOptions {
        eval_every: args.usize_or("eval-every", 8),
        max_events: args.usize_or("events", 0),
        verbose: true,
    };
    println!("running protocol: {} on {}", cfg.label(), be.platform());
    let result = run_protocol(&*be, &ds, cfg, opts)?;
    println!("\naccuracy curve:");
    for (ev, acc) in result.accuracy_curve() {
        println!("  event {ev:3}: {acc:.3}");
    }
    println!("final accuracy : {:.3} (initial {:.3})", result.final_acc, result.initial_acc);
    println!("LR storage     : {} bytes", result.lr_storage_bytes);
    println!("wall time      : {:?} total, {:?}/event",
        result.total_wall, result.mean_event_wall());
    Ok(())
}

/// Multi-tenant serving demo: admit N tenants over the shared native
/// backbone, drive a few NICv2 events each through the worker pool under
/// the governor's budget, report accuracy + throughput + governor log.
/// With `--spill-dir` the cold (disk) tier is enabled: coldest tenants
/// spill to snapshot files under pressure, restore lazily on traffic,
/// and a post-run `rebalance()` walks the ladder back up under the
/// watermark hysteresis.
fn fleet(args: &cli::Args) -> Result<()> {
    let n_tenants = args.usize_or("tenants", 8).max(1);
    let events_per_tenant = args.usize_or("events", 4);
    let seed0 = args.u64_or("seed", 1);
    let mut cfg = FleetConfig::new(args.usize_or("l", 15));
    // --workers 0 = auto: size serving to the unified exec config (the
    // same TINYCL_THREADS resolution the kernel pool uses)
    let workers = match args.usize_or("workers", 4) {
        0 => cfg.exec.threads,
        w => w,
    };
    cfg.governor.budget_bytes = args.usize_or("budget-mb", 64) * 1024 * 1024;
    cfg.governor.low_watermark = args.f64_or("low-watermark", cfg.governor.low_watermark);
    cfg.governor.high_watermark = args.f64_or("high-watermark", cfg.governor.high_watermark);
    cfg.coalesce = args.usize_or("coalesce", 8);
    cfg.max_tenants = n_tenants.max(cfg.max_tenants);
    cfg.spill_dir = args.get("spill-dir").map(std::path::PathBuf::from);
    let fault_seed = args.get("fault-plan").map(|s| s.parse::<u64>()).transpose()?;
    if let Some(seed) = fault_seed {
        cfg.faults = FaultPlan::seeded(seed);
        if cfg.spill_dir.is_none() {
            // the chaos plan targets spill I/O; give it a cold tier
            let dir = std::env::temp_dir().join(format!("tinycl-fleet-chaos-{seed}"));
            std::fs::create_dir_all(&dir)?;
            cfg.spill_dir = Some(dir);
        }
    }
    let shed_ms = args.get("shed-ms").map(|s| s.parse::<u64>()).transpose()?;
    if let Some(max_wait_ms) = shed_ms {
        cfg.admission = Admission::Shed { max_wait_ms };
    }
    // either export flag turns recording on; otherwise defer to the
    // TINYCL_TELEMETRY env knob (off by default — recording never
    // changes outcomes, but the zero-cost default is the contract)
    let telemetry_out = args.get("telemetry").map(std::path::PathBuf::from);
    let trace_out = args.get("trace").map(std::path::PathBuf::from);
    cfg.telemetry = if telemetry_out.is_some() || trace_out.is_some() {
        tinycl::telemetry::Telemetry::enabled()
    } else {
        tinycl::telemetry::Telemetry::from_env()
    };

    let (be, ds) = open_shared_native()?;
    println!("fleet on {} (shared backbone, governor budget {} MB)",
        be.platform(), cfg.governor.budget_bytes / (1024 * 1024));
    if let Some(seed) = fault_seed {
        println!("fault plan: seeded({seed}), spill dir {:?}", cfg.spill_dir.as_deref().unwrap());
    }
    let server = FleetServer::new(be, cfg)?;

    // admit: every tenant seeds from the same pre-deployment pool,
    // embedded once through the shared backbone
    let (init_images, init_labels) = traffic::init_pool(&ds);
    let init_latents = server.embed_images(&init_images)?;
    let mut ids = Vec::new();
    for t in 0..n_tenants {
        let tcfg = TenantConfig {
            n_lr: args.usize_or("n-lr", 128),
            seed: seed0 + t as u64,
            ..TenantConfig::default()
        };
        ids.push(server.admit_prepared(tcfg, &init_latents, &init_labels)?);
    }
    println!("admitted {} tenants, {} B in use", ids.len(), server.bytes_in_use());

    // the canonical interleaved per-tenant NICv2 stream
    let seeded: Vec<(usize, u64)> = ids.iter().map(|&id| (id, seed0 + id as u64)).collect();
    let events = traffic::interleaved_nicv2(
        &server.backend().manifest().protocol,
        &ds,
        &seeded,
        events_per_tenant,
    );

    let report = server.run(events, workers)?;
    println!(
        "\nprocessed {} events in {:.2} s  ({:.1} events/s, p50 {:.1} ms, p99 {:.1} ms)",
        report.events, report.wall_s, report.events_per_sec,
        report.latency.p50_ms, report.latency.p99_ms
    );
    println!(
        "frozen coalescing: {} engine calls for {} rows ({:.2} events/call)",
        report.frozen_calls, report.frozen_rows, report.mean_coalesce
    );
    if report.lazy_restores > 0 {
        println!("lazy restores during serving: {}", report.lazy_restores);
    }
    if let Some(tr) = &report.telemetry {
        print!("{}", tr.render());
    }
    if fault_seed.is_some() || shed_ms.is_some() {
        let r = &report.robustness;
        println!(
            "robustness: {} shed, {} I/O retries, {} degrades (service level {:?})",
            r.shed, r.io_retries, r.degrades, server.service_level()
        );
        let rejected = server.take_rejections();
        if let Some(worst) = rejected.iter().map(|j| j.retry_after_ms()).max() {
            println!(
                "admission: {} events rejected Overloaded (worst retry-after {worst} ms)",
                rejected.len()
            );
        }
    }
    // the whole-fleet sweep runs as low-priority pool tasks — off the
    // serving path (here the server is quiesced, so this is simply the
    // parallel form; accuracies are bit-identical to sequential calls)
    let accs = server.evaluate_tenants_async(&ds, &ids)?.wait()?;
    let mean_acc = accs.iter().sum::<f64>() / accs.len() as f64;
    println!("mean tenant accuracy: {mean_acc:.3} (min {:.3}, max {:.3})",
        accs.iter().cloned().fold(f64::INFINITY, f64::min),
        accs.iter().cloned().fold(f64::NEG_INFINITY, f64::max));
    let t = server.governor_tally();
    println!(
        "governor: {} admits, {} demotions, {} promotions, {} shrinks, {} spills, \
         {} unspills, {} evicts, {} rejects; {} B in use, {} B on disk",
        t.admits, t.demotes, t.promotes, t.shrinks, t.spills, t.unspills, t.evicts,
        t.rejects, server.bytes_in_use(), server.spilled_disk_bytes()
    );
    for a in server.governor_log() {
        match a {
            GovernorAction::Demote { tenant, from_bits, to_bits, freed } => {
                println!("  demoted tenant {tenant}: Q{from_bits} -> Q{to_bits} (freed {freed} B)");
            }
            GovernorAction::Spill { tenant, freed, disk_bytes } => {
                println!("  spilled tenant {tenant}: freed {freed} B RAM -> {disk_bytes} B disk");
            }
            GovernorAction::Promote { tenant, from_bits, to_bits, grew } => {
                println!("  promoted tenant {tenant}: Q{from_bits} -> Q{to_bits} (+{grew} B)");
            }
            GovernorAction::Degrade { tenant, bytes, disk_freed } => {
                println!(
                    "  degraded tenant {tenant}: rebuilt with empty replay \
                     ({bytes} B RAM, quarantined {disk_freed} B off-book)"
                );
            }
            _ => {}
        }
    }
    // with the cold tier enabled, walk the ladder back up once serving
    // has quiesced (a no-op unless usage sits below the low watermark)
    if server.config().spill_dir.is_some() {
        let out = server.rebalance()?;
        println!(
            "rebalance: {} unspilled, {} promoted ({} resident / {} cold, {} B in use)",
            out.unspilled, out.promoted, server.tenant_count(), server.spilled_count(),
            server.bytes_in_use()
        );
    }
    // exported from the live handle so post-run activity (the eval
    // sweep, rebalance spills) is included alongside the serving run
    let tm = &server.config().telemetry;
    if let Some(path) = &telemetry_out {
        let digest = tm.report().expect("--telemetry enables recording");
        std::fs::write(path, digest.to_json().to_string() + "\n")?;
        println!("wrote telemetry digest to {}", path.display());
    }
    if let Some(path) = &trace_out {
        let trace = tm.chrome_trace().expect("--trace enables recording");
        std::fs::write(path, trace.to_string() + "\n")?;
        println!("wrote Chrome trace to {} (open in chrome://tracing or Perfetto)", path.display());
    }
    Ok(())
}

fn fig(args: &cli::Args) -> Result<()> {
    let profile = Profile::parse(args.get_or("profile", "fast"));
    if args.flag("all") {
        harness::run_all(profile)?;
        return Ok(());
    }
    match args.get("id") {
        Some(id) => {
            if !harness::run_one(id, profile)? {
                eprintln!("unknown figure id '{id}'; known: {:?}", harness::ALL_IDS);
                std::process::exit(2);
            }
            Ok(())
        }
        None => {
            eprintln!("fig requires --id <id> or --all; known ids: {:?}", harness::ALL_IDS);
            std::process::exit(2);
        }
    }
}

fn sim(args: &cli::Args) -> Result<()> {
    let l = args.usize_or("l", 23);
    let target = match args.get_or("target", "vega") {
        "stm32l4" | "stm32" => stm32l4(),
        _ => vega(),
    };
    let net = mobilenet_v1_128();
    let ev = EventSpec::paper();
    let secs = event_seconds(&target, &target.default_hw, &net, l, &ev);
    println!("{} @ {:.0} MHz, retraining from layer {l} of {}:",
        target.name, target.freq_hz / 1e6, net.name);
    println!("  learning event : {:.2} s", secs);
    println!("  energy         : {:.2} J", target.energy_j(secs));
    println!("  max event rate : {:.1}/hour", 3600.0 / secs);
    Ok(())
}
