//! Small statistics helpers shared by the bench harness and the figure
//! generators (mean±std rows of Table II, medians for §Perf).

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1), as used for the paper's ±std columns.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Median absolute deviation (robust spread for bench reporting).
pub fn mad(xs: &[f64]) -> f64 {
    let m = median(xs);
    let dev: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&dev)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_known_values() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 1.1, 0.9, 1.0, 100.0];
        assert!(mad(&xs) < 0.2);
    }

    #[test]
    fn empty_and_single() {
        assert!(mean(&[]).is_nan());
        assert_eq!(std(&[5.0]), 0.0);
        assert_eq!(min(&[2.0, 1.0]), 1.0);
        assert_eq!(max(&[2.0, 3.0]), 3.0);
    }
}
