//! xoshiro256** — the repo-wide deterministic PRNG.
//!
//! Every stochastic decision in the coordinator (event order, replay
//! sampling, buffer replacement, batch shuffling) flows through this
//! generator so a `(config, seed)` pair fully determines a run — the same
//! property the paper relies on for its mean±std tables (Table II).

/// xoshiro256** 1.0 (Blackman & Vigna), seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. per subsystem) from this one.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// The raw xoshiro256** state — the generator's exact stream
    /// position, for serialization (tenant snapshots persist this so a
    /// spill→restore cycle resumes the stream bit-for-bit).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at an exact stream position previously
    /// captured by [`Rng::state`]. The all-zero state is the one fixed
    /// point xoshiro cannot leave and is rejected (a zeroed snapshot
    /// field would otherwise produce a constant stream).
    pub fn from_state(s: [u64; 4]) -> Rng {
        assert!(s.iter().any(|&w| w != 0), "Rng::from_state: all-zero state");
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`. Uses rejection sampling (unbiased).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// `k` indices from `[0, n)` *with* replacement.
    pub fn sample_with_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.below(n)).collect()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 8];
        let n = 80_000;
        for _ in 0..n {
            counts[r.below(8)] += 1;
        }
        for &c in &counts {
            let expected = n / 8;
            assert!(
                (c as i64 - expected as i64).unsigned_abs() < (expected / 10) as u64,
                "bucket count {c} too far from {expected}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(100, 40);
        assert_eq!(s.len(), 40);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 40);
        assert!(t.iter().all(|&i| i < 100));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn state_round_trip_resumes_stream_exactly() {
        let mut a = Rng::new(77);
        for _ in 0..100 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    #[should_panic(expected = "all-zero state")]
    fn zero_state_rejected() {
        let _ = Rng::from_state([0; 4]);
    }

    #[test]
    fn fork_streams_diverge() {
        let mut base = Rng::new(1);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
