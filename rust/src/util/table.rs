//! Console table rendering for the figure/table harness — the paper-style
//! rows that `tinycl fig --id ...` prints (and writes as TSV to results/).

pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "table '{}': row width mismatch",
            self.title
        );
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:w$} | ", c, w = widths[i]));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &widths));
        let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }

    /// Tab-separated form written under `results/` for downstream plotting.
    pub fn to_tsv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join("\t"));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join("\t"));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Write TSV under `dir/<name>.tsv` (creating the directory).
    pub fn save_tsv(&self, dir: &str, name: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(format!("{dir}/{name}.tsv"), self.to_tsv())
    }
}

/// Format helper: fixed-point with given decimals.
pub fn fmt(v: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, v)
}

/// Format helper: engineering style for latencies ("2.49e3 s" ~ Table IV).
pub fn fmt_eng(v: f64) -> String {
    if !v.is_finite() {
        return format!("{v}");
    }
    let a = v.abs();
    if a == 0.0 {
        "0".to_string()
    } else if (0.01..10_000.0).contains(&a) {
        format!("{v:.3}")
    } else {
        format!("{v:.2e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "long-header"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["wide-cell".into(), "3".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.contains("long-header"));
        let lines: Vec<&str> = r.lines().filter(|l| l.starts_with('|')).collect();
        assert_eq!(lines.len(), 3);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_bad_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn tsv_shape() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_tsv(), "a\tb\n1\t2\n");
    }

    #[test]
    fn eng_format() {
        assert_eq!(fmt_eng(2490.0), "2490.000");
        assert_eq!(fmt_eng(24900.0), "2.49e4");
        assert_eq!(fmt_eng(0.0001), "1.00e-4");
    }
}
