//! Self-contained utility layer (DESIGN.md §8).
//!
//! The build environment mirrors only the `xla` crate's dependency closure,
//! so the usual ecosystem crates are replaced by small, tested, in-tree
//! equivalents: [`rng`] (xoshiro256**), [`json`] (manifest parsing),
//! [`cli`] (argument parsing), [`bench`] (criterion-style measurement for
//! `cargo bench` targets), [`prop`] (seeded property testing), [`stats`]
//! and [`table`] (harness output formatting).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
