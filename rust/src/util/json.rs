//! Minimal JSON: parse + emit, sufficient for `artifacts/manifest.json`
//! and the harness result files. (The environment has no `serde`.)
//!
//! Supports the full JSON grammar except `\u` surrogate pairs are passed
//! through unvalidated. Numbers parse as `f64`; the manifest only contains
//! magnitudes far below 2^53 so this is lossless in practice.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style traversal; panics with a useful path on miss.
    pub fn at(&self, path: &[&str]) -> &Json {
        let mut cur = self;
        for (i, k) in path.iter().enumerate() {
            cur = cur.get(k).unwrap_or_else(|| {
                panic!("json: missing key '{}' (path {:?})", k, &path[..=i])
            });
        }
        cur
    }

    pub fn as_f64(&self) -> f64 {
        match self {
            Json::Num(n) => *n,
            other => panic!("json: expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> usize {
        let f = self.as_f64();
        assert!(f >= 0.0 && f.fract() == 0.0, "json: not a usize: {f}");
        f as usize
    }

    pub fn as_str(&self) -> &str {
        match self {
            Json::Str(s) => s,
            other => panic!("json: expected string, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> &[Json] {
        match self {
            Json::Arr(a) => a,
            other => panic!("json: expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> &BTreeMap<String, Json> {
        match self {
            Json::Obj(m) => m,
            other => panic!("json: expected object, got {other:?}"),
        }
    }

    pub fn usize_vec(&self) -> Vec<usize> {
        self.as_arr().iter().map(|v| v.as_usize()).collect()
    }

    pub fn f64_vec(&self) -> Vec<f64> {
        self.as_arr().iter().map(|v| v.as_f64()).collect()
    }

    // ---- emit ------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

// ---- builders -------------------------------------------------------------

pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

// ---- parser ----------------------------------------------------------------

pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("json: trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "json: expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!(
                "json: unexpected {:?} at byte {}",
                other.map(|b| b as char),
                self.i
            )),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("json: bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        )
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("json: bad number '{txt}': {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("json: unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("json: truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "json: bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "json: bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("json: bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "json: invalid utf-8")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("json: expected , or ] (got {other:?})")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("json: expected , or }} (got {other:?})")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap().as_f64(), 42.0);
        assert_eq!(parse("-1.5e2").unwrap().as_f64(), -150.0);
        assert_eq!(parse("\"hi\\nthere\"").unwrap().as_str(), "hi\nthere");
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
    }

    #[test]
    fn parses_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}}"#).unwrap();
        assert_eq!(j.at(&["a"]).as_arr().len(), 3);
        assert_eq!(j.at(&["a"]).as_arr()[2].at(&["b"]).as_str(), "c");
        assert_eq!(j.at(&["d", "e"]), &Json::Bool(false));
    }

    #[test]
    fn round_trips() {
        let src = r#"{"arr":[1,2.5,"x"],"n":null,"s":"a\"b","t":true}"#;
        let j = parse(src).unwrap();
        let emitted = j.to_string();
        assert_eq!(parse(&emitted).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = parse(r#""café ☕""#).unwrap();
        assert_eq!(j.as_str(), "café ☕");
        let rt = parse(&j.to_string()).unwrap();
        assert_eq!(rt, j);
    }

    #[test]
    fn usize_vec_helper() {
        let j = parse("[9, 11, 13, 15]").unwrap();
        assert_eq!(j.usize_vec(), vec![9, 11, 13, 15]);
    }
}
