//! Seeded property-testing harness (proptest-lite).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` independent
//! seeded RNG streams; a failure reports the exact case seed so the case
//! reproduces with `check_one(seed, ...)`. No macro magic, no shrinking of
//! arbitrary types — generators are just closures over [`Rng`], which keeps
//! every invariant test explicit and greppable.

use super::rng::Rng;

pub const DEFAULT_CASES: usize = 128;

/// Run `body` over `cases` derived seeds; panic with the failing seed.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: usize, mut body: F) {
    let base = env_seed();
    for case in 0..cases {
        let seed = base
            .wrapping_mul(0x100000001B3)
            .wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut rng)));
        if let Err(err) = result {
            eprintln!(
                "[prop] '{name}' FAILED at case {case}/{cases} — reproduce with \
                 TINYCL_PROP_SEED={base} (case seed {seed})"
            );
            std::panic::resume_unwind(err);
        }
    }
}

/// Re-run a single failing case.
pub fn check_one<F: FnMut(&mut Rng)>(case_seed: u64, mut body: F) {
    let mut rng = Rng::new(case_seed);
    body(&mut rng);
}

fn env_seed() -> u64 {
    std::env::var("TINYCL_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

// ---- common generators -----------------------------------------------------

/// Vector of `n` f32 values in `[lo, hi)`.
pub fn vec_f32(rng: &mut Rng, n: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..n).map(|_| lo + rng.f32() * (hi - lo)).collect()
}

/// Vector of `n` normal f32 values.
pub fn vec_normal(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// Integer in `[lo, hi]` inclusive.
pub fn int_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        check("counter", 32, |_rng| {
            count += 1;
        });
        assert_eq!(count, 32);
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 64, |rng| {
            let v = vec_f32(rng, 100, -2.0, 3.0);
            assert!(v.iter().all(|&x| (-2.0..3.0).contains(&x)));
            let i = int_in(rng, 5, 9);
            assert!((5..=9).contains(&i));
        });
    }

    #[test]
    #[should_panic]
    fn propagates_failures() {
        check("fails", 8, |rng| {
            assert!(rng.f64() < 0.5, "intentional failure");
        });
    }
}
