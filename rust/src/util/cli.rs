//! Tiny argv parser: `--flag`, `--key value`, `--key=value`, positionals.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

/// Parse raw args (without the program name). `flag_names` lists options
/// that take no value; everything else starting with `--` consumes one.
pub fn parse(raw: &[String], flag_names: &[&str]) -> Args {
    let mut out = Args::default();
    let mut i = 0;
    while i < raw.len() {
        let a = &raw[i];
        if let Some(stripped) = a.strip_prefix("--") {
            if let Some((k, v)) = stripped.split_once('=') {
                out.options.insert(k.to_string(), v.to_string());
            } else if flag_names.contains(&stripped) {
                out.flags.push(stripped.to_string());
            } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                out.options.insert(stripped.to_string(), raw[i + 1].clone());
                i += 1;
            } else {
                out.flags.push(stripped.to_string());
            }
        } else {
            out.positional.push(a.clone());
        }
        i += 1;
    }
    out
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = parse(&sv(&["fig", "--id", "fig5", "--fast", "--n=3"]), &["fast"]);
        assert_eq!(a.positional, vec!["fig"]);
        assert_eq!(a.get("id"), Some("fig5"));
        assert!(a.flag("fast"));
        assert_eq!(a.usize_or("n", 0), 3);
    }

    #[test]
    fn flag_at_end_without_value() {
        let a = parse(&sv(&["--verbose"]), &[]);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn numeric_defaults() {
        let a = parse(&sv(&[]), &[]);
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.f64_or("lr", 0.05), 0.05);
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&sv(&["--lr=0.1", "--profile=paper"]), &[]);
        assert_eq!(a.f64_or("lr", 0.0), 0.1);
        assert_eq!(a.get("profile"), Some("paper"));
    }
}
