//! Criterion-lite: the measurement harness behind every `cargo bench`
//! target (`harness = false`). Warm-up, adaptive iteration scaling,
//! median ± MAD reporting, and optional baseline comparison via
//! `results/bench_baseline.tsv` (the §Perf before/after log).

use std::time::{Duration, Instant};

use super::stats;

pub struct Bench {
    name: String,
    warmup: Duration,
    measure: Duration,
    samples: usize,
    results: Vec<(String, f64, f64, f64)>, // (case, median_ns, mad_ns, iters/s)
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench {
            name: name.to_string(),
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            samples: 20,
            results: Vec::new(),
        }
    }

    pub fn quick(name: &str) -> Self {
        let mut b = Bench::new(name);
        b.warmup = Duration::from_millis(50);
        b.measure = Duration::from_millis(200);
        b.samples = 10;
        b
    }

    /// Measure `f`, which performs ONE logical operation per call.
    pub fn case<F: FnMut()>(&mut self, label: &str, mut f: F) -> f64 {
        // Warm-up and calibration: find iters per sample batch.
        let t0 = Instant::now();
        let mut calib_iters: u64 = 0;
        while t0.elapsed() < self.warmup {
            f();
            calib_iters += 1;
        }
        let per_call = self.warmup.as_secs_f64() / calib_iters.max(1) as f64;
        let batch = ((self.measure.as_secs_f64() / self.samples as f64) / per_call)
            .ceil()
            .max(1.0) as u64;

        let mut samples_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples_ns.push(t.elapsed().as_nanos() as f64 / batch as f64);
        }
        let med = stats::median(&samples_ns);
        let mad = stats::mad(&samples_ns);
        self.results
            .push((label.to_string(), med, mad, 1e9 / med));
        eprintln!(
            "  {:<44} {:>12}  ±{:>10}  ({:.1}/s)",
            label,
            fmt_ns(med),
            fmt_ns(mad),
            1e9 / med
        );
        med
    }

    /// Print summary and persist to `results/bench_<name>.tsv`.
    pub fn finish(&self) {
        let mut tsv = String::from("case\tmedian_ns\tmad_ns\tthroughput_per_s\n");
        for (label, med, mad, tput) in &self.results {
            tsv.push_str(&format!("{label}\t{med:.1}\t{mad:.1}\t{tput:.2}\n"));
        }
        let _ = std::fs::create_dir_all("results");
        let path = format!("results/bench_{}.tsv", self.name);
        let _ = std::fs::write(&path, tsv);
        eprintln!("[bench {}] {} cases -> {path}", self.name, self.results.len());
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let mut b = Bench::quick("selftest");
        let mut acc = 0u64;
        let med = b.case("wrapping_add", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(med > 0.0 && med < 1e7, "median {med} ns out of range");
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(12.0), "12 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }
}
