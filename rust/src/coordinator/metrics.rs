//! Run bookkeeping: per-event records, the accuracy curve, and run-level
//! summaries (what the figure harness and EXPERIMENTS.md consume).

use std::time::Duration;

#[derive(Clone, Debug)]
pub struct EventRecord {
    pub event_idx: usize,
    pub class: usize,
    pub session: usize,
    pub new_class: bool,
    pub steps: usize,
    pub mean_loss: f64,
    pub train_acc: f64,
    pub replaced: usize,
    /// test accuracy if an eval ran after this event
    pub test_acc: Option<f64>,
    pub wall: Duration,
}

#[derive(Clone, Debug, Default)]
pub struct RunResult {
    pub label: String,
    pub events: Vec<EventRecord>,
    pub final_acc: f64,
    pub initial_acc: f64,
    pub lr_storage_bytes: usize,
    pub total_wall: Duration,
}

impl RunResult {
    /// (event_idx, accuracy) curve of all measured evals, starting with
    /// the pre-CL accuracy at event 0.
    pub fn accuracy_curve(&self) -> Vec<(usize, f64)> {
        let mut curve = vec![(0, self.initial_acc)];
        for e in &self.events {
            if let Some(acc) = e.test_acc {
                curve.push((e.event_idx, acc));
            }
        }
        curve
    }

    pub fn mean_event_wall(&self) -> Duration {
        if self.events.is_empty() {
            return Duration::ZERO;
        }
        let total: Duration = self.events.iter().map(|e| e.wall).sum();
        total / self.events.len() as u32
    }

    /// Forgetting proxy: did accuracy ever drop more than `tol` below its
    /// running max? Returns the worst drop observed.
    pub fn worst_drop(&self) -> f64 {
        let mut run_max = self.initial_acc;
        let mut worst: f64 = 0.0;
        for (_, acc) in self.accuracy_curve() {
            worst = worst.max(run_max - acc);
            run_max = run_max.max(acc);
        }
        worst
    }
}

/// Latency percentiles over a sample set — what the fleet server and the
/// `fleet` bench report per event (BENCH_fleet.json's p50/p99 columns).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    pub n: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl LatencySummary {
    /// Summarize nanosecond samples (sorts `samples` in place).
    pub fn from_ns(samples: &mut [f64]) -> LatencySummary {
        if samples.is_empty() {
            return LatencySummary::default();
        }
        samples.sort_by(|a, b| a.partial_cmp(b).expect("NaN latency sample"));
        let pick = |q: f64| {
            // nearest-rank percentile: ceil(q * n) - 1, clamped
            let idx = ((q * samples.len() as f64).ceil() as usize).max(1) - 1;
            samples[idx.min(samples.len() - 1)] / 1e6
        };
        LatencySummary {
            n: samples.len(),
            p50_ms: pick(0.50),
            p99_ms: pick(0.99),
            max_ms: samples[samples.len() - 1] / 1e6,
        }
    }
}

/// Robustness counters for one fleet run: how often the server leaned on
/// its survival machinery instead of the happy path. All three are
/// exactly zero on a fault-free run with `Admission::Block` — the
/// regression gate for "zero overhead when chaos is disabled".
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RobustnessSummary {
    /// Events rejected at admission (`Rejected::Overloaded`) instead of
    /// blocking on a full ingress queue.
    pub shed: u64,
    /// Spill/restore I/O attempts that failed and were retried with
    /// backoff (counts retries, not operations).
    pub io_retries: u64,
    /// Tenants rebuilt with an empty replay buffer after unrecoverable
    /// restore corruption (quarantine + `GovernorAction::Degrade`).
    pub degrades: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(idx: usize, acc: Option<f64>) -> EventRecord {
        EventRecord {
            event_idx: idx,
            class: 0,
            session: 0,
            new_class: false,
            steps: 1,
            mean_loss: 0.1,
            train_acc: 0.9,
            replaced: 1,
            test_acc: acc,
            wall: Duration::from_millis(10),
        }
    }

    #[test]
    fn curve_includes_initial_and_evals() {
        let r = RunResult {
            initial_acc: 0.2,
            events: vec![rec(1, None), rec(2, Some(0.3)), rec(3, Some(0.5))],
            ..Default::default()
        };
        assert_eq!(r.accuracy_curve(), vec![(0, 0.2), (2, 0.3), (3, 0.5)]);
    }

    #[test]
    fn worst_drop_detects_forgetting() {
        let r = RunResult {
            initial_acc: 0.2,
            events: vec![rec(1, Some(0.5)), rec(2, Some(0.35)), rec(3, Some(0.6))],
            ..Default::default()
        };
        assert!((r.worst_drop() - 0.15).abs() < 1e-12);
    }

    #[test]
    fn latency_percentiles_nearest_rank() {
        let mut ns: Vec<f64> = (1..=100).map(|i| i as f64 * 1e6).collect();
        let s = LatencySummary::from_ns(&mut ns);
        assert_eq!(s.n, 100);
        assert_eq!(s.p50_ms, 50.0);
        assert_eq!(s.p99_ms, 99.0);
        assert_eq!(s.max_ms, 100.0);
        let mut one = vec![3e6];
        let s1 = LatencySummary::from_ns(&mut one);
        assert_eq!((s1.p50_ms, s1.p99_ms, s1.max_ms), (3.0, 3.0, 3.0));
        assert_eq!(LatencySummary::from_ns(&mut []), LatencySummary::default());
    }

    #[test]
    fn mean_wall() {
        let r = RunResult {
            events: vec![rec(1, None), rec(2, None)],
            ..Default::default()
        };
        assert_eq!(r.mean_event_wall(), Duration::from_millis(10));
    }
}
