//! Run bookkeeping: per-event records, the accuracy curve, and run-level
//! summaries (what the figure harness and EXPERIMENTS.md consume).

use std::time::Duration;

#[derive(Clone, Debug)]
pub struct EventRecord {
    pub event_idx: usize,
    pub class: usize,
    pub session: usize,
    pub new_class: bool,
    pub steps: usize,
    pub mean_loss: f64,
    pub train_acc: f64,
    pub replaced: usize,
    /// test accuracy if an eval ran after this event
    pub test_acc: Option<f64>,
    pub wall: Duration,
}

#[derive(Clone, Debug, Default)]
pub struct RunResult {
    pub label: String,
    pub events: Vec<EventRecord>,
    pub final_acc: f64,
    pub initial_acc: f64,
    pub lr_storage_bytes: usize,
    pub total_wall: Duration,
}

impl RunResult {
    /// (event_idx, accuracy) curve of all measured evals, starting with
    /// the pre-CL accuracy at event 0.
    pub fn accuracy_curve(&self) -> Vec<(usize, f64)> {
        let mut curve = vec![(0, self.initial_acc)];
        for e in &self.events {
            if let Some(acc) = e.test_acc {
                curve.push((e.event_idx, acc));
            }
        }
        curve
    }

    pub fn mean_event_wall(&self) -> Duration {
        if self.events.is_empty() {
            return Duration::ZERO;
        }
        let total: Duration = self.events.iter().map(|e| e.wall).sum();
        total / self.events.len() as u32
    }

    /// Forgetting proxy: did accuracy ever drop more than `tol` below its
    /// running max? Returns the worst drop observed.
    pub fn worst_drop(&self) -> f64 {
        let mut run_max = self.initial_acc;
        let mut worst: f64 = 0.0;
        for (_, acc) in self.accuracy_curve() {
            worst = worst.max(run_max - acc);
            run_max = run_max.max(acc);
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(idx: usize, acc: Option<f64>) -> EventRecord {
        EventRecord {
            event_idx: idx,
            class: 0,
            session: 0,
            new_class: false,
            steps: 1,
            mean_loss: 0.1,
            train_acc: 0.9,
            replaced: 1,
            test_acc: acc,
            wall: Duration::from_millis(10),
        }
    }

    #[test]
    fn curve_includes_initial_and_evals() {
        let r = RunResult {
            initial_acc: 0.2,
            events: vec![rec(1, None), rec(2, Some(0.3)), rec(3, Some(0.5))],
            ..Default::default()
        };
        assert_eq!(r.accuracy_curve(), vec![(0, 0.2), (2, 0.3), (3, 0.5)]);
    }

    #[test]
    fn worst_drop_detects_forgetting() {
        let r = RunResult {
            initial_acc: 0.2,
            events: vec![rec(1, Some(0.5)), rec(2, Some(0.35)), rec(3, Some(0.6))],
            ..Default::default()
        };
        assert!((r.worst_drop() - 0.15).abs() < 1e-12);
    }

    #[test]
    fn mean_wall() {
        let r = RunResult {
            events: vec![rec(1, None), rec(2, None)],
            ..Default::default()
        };
        assert_eq!(r.mean_event_wall(), Duration::from_millis(10));
    }
}
