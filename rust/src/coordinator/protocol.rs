//! NICv2-mini: the learning-event schedule (paper §V-A).
//!
//! Core50's NICv2-391 protocol makes 3000 images of 10 classes available
//! up front, then feeds the remaining data as 390 single-class, single-
//! session learning events (new instances AND new classes, non-IID). The
//! mini version mirrors the structure on Core50-mini: the initial classes'
//! initial sessions are consumed at build time (fine-tune + LR seeding);
//! every remaining `(class, session)` pair becomes one event, shuffled
//! deterministically per seed.

use crate::runtime::manifest::ProtocolCfg;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub class: usize,
    pub session: usize,
    /// true if this event introduces a class unseen since deployment
    pub new_class: bool,
}

/// Build the shuffled event schedule for one run.
pub fn build_schedule(cfg: &ProtocolCfg, rng: &mut Rng) -> Vec<Event> {
    let mut pairs: Vec<(usize, usize)> = Vec::new();
    for class in 0..cfg.n_classes {
        for session in 0..cfg.train_sessions {
            let initial = cfg.initial_classes.contains(&class)
                && cfg.initial_sessions.contains(&session);
            if !initial {
                pairs.push((class, session));
            }
        }
    }
    rng.shuffle(&mut pairs);
    let mut seen: Vec<bool> = (0..cfg.n_classes)
        .map(|c| cfg.initial_classes.contains(&c))
        .collect();
    pairs
        .into_iter()
        .map(|(class, session)| {
            let new_class = !seen[class];
            seen[class] = true;
            Event { class, session, new_class }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ProtocolCfg {
        ProtocolCfg {
            initial_classes: vec![0, 1, 2, 3],
            initial_sessions: vec![0, 1],
            n_classes: 10,
            train_sessions: 6,
            test_sessions: 2,
            frames_per_session: 60,
        }
    }

    #[test]
    fn schedule_covers_everything_once() {
        let mut rng = Rng::new(0);
        let ev = build_schedule(&cfg(), &mut rng);
        // 10*6 pairs minus 4*2 initial = 52 events
        assert_eq!(ev.len(), 52);
        let mut seen = std::collections::BTreeSet::new();
        for e in &ev {
            assert!(seen.insert((e.class, e.session)), "duplicate event");
            assert!(e.class < 10 && e.session < 6);
            // initial pairs never reappear
            assert!(!((0..4).contains(&e.class) && (0..2).contains(&e.session)));
        }
    }

    #[test]
    fn new_class_flag_set_exactly_once_per_new_class() {
        let mut rng = Rng::new(7);
        let ev = build_schedule(&cfg(), &mut rng);
        let flags: Vec<usize> = ev.iter().filter(|e| e.new_class).map(|e| e.class).collect();
        // classes 4..9 are new exactly once; initial classes never flagged
        let mut sorted = flags.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![4, 5, 6, 7, 8, 9]);
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = build_schedule(&cfg(), &mut Rng::new(3));
        let b = build_schedule(&cfg(), &mut Rng::new(3));
        let c = build_schedule(&cfg(), &mut Rng::new(4));
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn initial_class_later_sessions_are_events() {
        // NIC = new instances AND classes: known classes reappear with new
        // sessions (instances)
        let ev = build_schedule(&cfg(), &mut Rng::new(1));
        assert!(ev.iter().any(|e| e.class < 4 && !e.new_class));
    }
}
