//! Mini-batch composition (paper §III-A): every training step mixes
//! `B_new` fresh latents with `B - B_new` replays drawn from the LR memory
//! (paper ratio 21/128 ≈ 1/6; mini profile 8/64 = 1/8).
//!
//! The batcher owns the reusable scratch buffers of the hot loop — one
//! latent matrix `[B, latent_elems]` and one label vector — so steady-state
//! training performs no allocation (§Perf L3).
//!
//! [`FrozenCoalescer`] is the fleet-side sibling: it stacks image rows
//! from *many tenants'* events into one contiguous batch so the shared
//! frozen backbone runs once per coalesced batch instead of once per
//! tenant — the frozen stage is immutable and per-row deterministic, so
//! each tenant gets bit-identical latents to a solo run.

use anyhow::Result;

use super::replay::ReplayBuffer;
use crate::runtime::Backend;
use crate::util::rng::Rng;

pub struct Batcher {
    pub batch: usize,
    pub batch_new: usize,
    latent_elems: usize,
    latents: Vec<f32>,
    labels: Vec<i32>,
}

impl Batcher {
    pub fn new(batch: usize, batch_new: usize, latent_elems: usize) -> Self {
        assert!(batch_new <= batch, "batch_new {batch_new} > batch {batch}");
        Batcher {
            batch,
            batch_new,
            latent_elems,
            latents: vec![0.0; batch * latent_elems],
            labels: vec![0; batch],
        }
    }

    pub fn replay_count(&self) -> usize {
        self.batch - self.batch_new
    }

    /// Compose one training batch.
    ///
    /// `new_latents`: the event's latents (`n * latent_elems`), already on
    /// the storage grid; `pick` selects which `batch_new` rows go in this
    /// batch (indices into the event's rows); replays fill the rest.
    /// Returns `(latents, labels)` slices valid until the next call.
    /// Steady-state allocation-free: new rows are memcpy'd and replays are
    /// fused-dequantized straight into the owned scratch batch.
    pub fn compose(
        &mut self,
        new_latents: &[f32],
        new_labels: &[i32],
        pick: &[usize],
        replay: &ReplayBuffer,
        rng: &mut Rng,
    ) -> (&[f32], &[i32]) {
        assert_eq!(pick.len(), self.batch_new, "pick must have batch_new rows");
        let le = self.latent_elems;
        assert_eq!(replay.latent_elems(), le, "replay latent size mismatch");
        for (i, &src) in pick.iter().enumerate() {
            let dst = &mut self.latents[i * le..(i + 1) * le];
            dst.copy_from_slice(&new_latents[src * le..(src + 1) * le]);
            self.labels[i] = new_labels[src];
        }
        let k = self.replay_count();
        replay.sample_into(
            k,
            rng,
            &mut self.latents[self.batch_new * le..],
            &mut self.labels[self.batch_new..],
        );
        (&self.latents, &self.labels)
    }

    /// Compose an all-replay batch (used when an event has fewer images
    /// than `batch_new` left; keeps the module shape static).
    pub fn compose_replay_only(
        &mut self,
        replay: &ReplayBuffer,
        rng: &mut Rng,
    ) -> (&[f32], &[i32]) {
        replay.sample_into(self.batch, rng, &mut self.latents, &mut self.labels);
        (&self.latents, &self.labels)
    }
}

/// Cross-tenant frozen-forward coalescer: accumulate image rows from any
/// number of events (typically from *different* tenants), run the shared
/// frozen stage ONCE over the union, then hand each event its latent
/// slice. The buffers are owned and reused, so a fleet worker's stage-A
/// loop allocates nothing at steady state beyond backend internals.
///
/// Coalescing is exact, not approximate: the engine's per-row reduction
/// order is independent of batch width (`kernels::engine` tests pin
/// this), so `latents(i)` is bit-identical to running event `i`'s images
/// through `frozen_forward` alone.
pub struct FrozenCoalescer {
    image_elems: usize,
    latent_elems: usize,
    images: Vec<f32>,
    latents: Vec<f32>,
    /// per-event row ranges into the coalesced batch
    ranges: Vec<(usize, usize)>,
}

impl FrozenCoalescer {
    pub fn new(image_elems: usize, latent_elems: usize) -> Self {
        FrozenCoalescer {
            image_elems,
            latent_elems,
            images: Vec::new(),
            latents: Vec::new(),
            ranges: Vec::new(),
        }
    }

    /// Drop all staged events (buffers stay allocated for reuse).
    pub fn clear(&mut self) {
        self.images.clear();
        self.latents.clear();
        self.ranges.clear();
    }

    /// Stage one event's images (`n * image_elems`); returns its event
    /// index for [`FrozenCoalescer::latents`].
    pub fn push(&mut self, images: &[f32]) -> usize {
        assert!(
            !images.is_empty() && images.len() % self.image_elems == 0,
            "coalescer: ragged image batch ({} elems)",
            images.len()
        );
        let rows = images.len() / self.image_elems;
        let start = self.images.len() / self.image_elems;
        self.images.extend_from_slice(images);
        self.ranges.push((start, start + rows));
        self.ranges.len() - 1
    }

    /// Total staged rows across all pushed events.
    pub fn rows(&self) -> usize {
        self.images.len() / self.image_elems
    }

    /// Run the frozen stage once over every staged row.
    pub fn run(&mut self, be: &dyn Backend, l: usize, int8: bool) -> Result<()> {
        let rows = self.rows();
        self.latents.clear();
        self.latents.resize(rows * self.latent_elems, 0.0);
        if rows > 0 {
            be.frozen_forward(l, int8, false, &self.images, &mut self.latents)?;
        }
        Ok(())
    }

    /// Latents of pushed event `idx` (valid after [`FrozenCoalescer::run`]).
    pub fn latents(&self, idx: usize) -> &[f32] {
        let (lo, hi) = self.ranges[idx];
        &self.latents[lo * self.latent_elems..hi * self.latent_elems]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled_buffer(elems: usize) -> (ReplayBuffer, Rng) {
        let mut rng = Rng::new(1);
        let mut b = ReplayBuffer::new_f32(8, elems);
        let latents: Vec<f32> = (0..8 * elems).map(|i| 100.0 + i as f32).collect();
        let labels: Vec<i32> = (0..8).map(|i| 5 + (i % 2) as i32).collect();
        b.init_fill(&latents, &labels, &mut rng);
        (b, rng)
    }

    #[test]
    fn compose_layout_new_then_replay() {
        let elems = 4;
        let (mut buf, mut rng) = filled_buffer(elems);
        let mut batcher = Batcher::new(6, 2, elems);
        let new_lat: Vec<f32> = (0..3 * elems).map(|i| i as f32).collect();
        let new_lab = vec![0, 1, 2];
        let (lat, lab) = batcher.compose(&new_lat, &new_lab, &[2, 0], &mut buf, &mut rng);
        // first two rows are the picked new latents, in pick order
        assert_eq!(&lat[..elems], &new_lat[2 * elems..3 * elems]);
        assert_eq!(&lat[elems..2 * elems], &new_lat[..elems]);
        assert_eq!(&lab[..2], &[2, 0]);
        // remaining rows come from the replay buffer (values >= 100)
        assert!(lat[2 * elems..].iter().all(|&v| v >= 100.0));
        assert!(lab[2..].iter().all(|&l| l == 5 || l == 6));
    }

    #[test]
    fn ratio_matches_paper_shape() {
        // mini profile: 8 new / 64 total = 1/8 (paper: 21/128 ~ 1/6)
        let b = Batcher::new(64, 8, 16);
        assert_eq!(b.replay_count(), 56);
        let ratio = b.batch_new as f64 / b.batch as f64;
        assert!(ratio < 0.2, "new-data ratio should be small: {ratio}");
    }

    #[test]
    fn replay_only_batch() {
        let elems = 4;
        let (mut buf, mut rng) = filled_buffer(elems);
        let mut batcher = Batcher::new(5, 2, elems);
        let (lat, lab) = batcher.compose_replay_only(&mut buf, &mut rng);
        assert_eq!(lat.len(), 5 * elems);
        assert!(lab.iter().all(|&l| l == 5 || l == 6));
    }

    #[test]
    fn coalescer_bookkeeping() {
        let mut c = FrozenCoalescer::new(4, 2);
        let e0 = c.push(&[0.0; 8]); // 2 rows
        let e1 = c.push(&[1.0; 4]); // 1 row
        assert_eq!((e0, e1), (0, 1));
        assert_eq!(c.rows(), 3);
        c.clear();
        assert_eq!(c.rows(), 0);
        c.push(&[2.0; 4]);
        assert_eq!(c.rows(), 1, "clear() must reset event ranges");
    }

    #[test]
    #[should_panic(expected = "ragged image batch")]
    fn coalescer_rejects_ragged_rows() {
        let mut c = FrozenCoalescer::new(4, 2);
        c.push(&[0.0; 6]);
    }

    #[test]
    #[should_panic(expected = "pick must have batch_new rows")]
    fn pick_size_checked() {
        let elems = 4;
        let (mut buf, mut rng) = filled_buffer(elems);
        let mut batcher = Batcher::new(6, 2, elems);
        let new_lat = vec![0f32; 3 * elems];
        batcher.compose(&new_lat, &[0, 1, 2], &[0], &mut buf, &mut rng);
    }
}
