//! Mini-batch composition (paper §III-A): every training step mixes
//! `B_new` fresh latents with `B - B_new` replays drawn from the LR memory
//! (paper ratio 21/128 ≈ 1/6; mini profile 8/64 = 1/8).
//!
//! The batcher owns the reusable scratch buffers of the hot loop — one
//! latent matrix `[B, latent_elems]` and one label vector — so steady-state
//! training performs no allocation (§Perf L3).

use super::replay::ReplayBuffer;
use crate::util::rng::Rng;

pub struct Batcher {
    pub batch: usize,
    pub batch_new: usize,
    latent_elems: usize,
    latents: Vec<f32>,
    labels: Vec<i32>,
}

impl Batcher {
    pub fn new(batch: usize, batch_new: usize, latent_elems: usize) -> Self {
        assert!(batch_new <= batch, "batch_new {batch_new} > batch {batch}");
        Batcher {
            batch,
            batch_new,
            latent_elems,
            latents: vec![0.0; batch * latent_elems],
            labels: vec![0; batch],
        }
    }

    pub fn replay_count(&self) -> usize {
        self.batch - self.batch_new
    }

    /// Compose one training batch.
    ///
    /// `new_latents`: the event's latents (`n * latent_elems`), already on
    /// the storage grid; `pick` selects which `batch_new` rows go in this
    /// batch (indices into the event's rows); replays fill the rest.
    /// Returns `(latents, labels)` slices valid until the next call.
    /// Steady-state allocation-free: new rows are memcpy'd and replays are
    /// fused-dequantized straight into the owned scratch batch.
    pub fn compose(
        &mut self,
        new_latents: &[f32],
        new_labels: &[i32],
        pick: &[usize],
        replay: &ReplayBuffer,
        rng: &mut Rng,
    ) -> (&[f32], &[i32]) {
        assert_eq!(pick.len(), self.batch_new, "pick must have batch_new rows");
        let le = self.latent_elems;
        assert_eq!(replay.latent_elems(), le, "replay latent size mismatch");
        for (i, &src) in pick.iter().enumerate() {
            let dst = &mut self.latents[i * le..(i + 1) * le];
            dst.copy_from_slice(&new_latents[src * le..(src + 1) * le]);
            self.labels[i] = new_labels[src];
        }
        let k = self.replay_count();
        replay.sample_into(
            k,
            rng,
            &mut self.latents[self.batch_new * le..],
            &mut self.labels[self.batch_new..],
        );
        (&self.latents, &self.labels)
    }

    /// Compose an all-replay batch (used when an event has fewer images
    /// than `batch_new` left; keeps the module shape static).
    pub fn compose_replay_only(
        &mut self,
        replay: &ReplayBuffer,
        rng: &mut Rng,
    ) -> (&[f32], &[i32]) {
        replay.sample_into(self.batch, rng, &mut self.latents, &mut self.labels);
        (&self.latents, &self.labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled_buffer(elems: usize) -> (ReplayBuffer, Rng) {
        let mut rng = Rng::new(1);
        let mut b = ReplayBuffer::new_f32(8, elems);
        let latents: Vec<f32> = (0..8 * elems).map(|i| 100.0 + i as f32).collect();
        let labels: Vec<i32> = (0..8).map(|i| 5 + (i % 2) as i32).collect();
        b.init_fill(&latents, &labels, &mut rng);
        (b, rng)
    }

    #[test]
    fn compose_layout_new_then_replay() {
        let elems = 4;
        let (mut buf, mut rng) = filled_buffer(elems);
        let mut batcher = Batcher::new(6, 2, elems);
        let new_lat: Vec<f32> = (0..3 * elems).map(|i| i as f32).collect();
        let new_lab = vec![0, 1, 2];
        let (lat, lab) = batcher.compose(&new_lat, &new_lab, &[2, 0], &mut buf, &mut rng);
        // first two rows are the picked new latents, in pick order
        assert_eq!(&lat[..elems], &new_lat[2 * elems..3 * elems]);
        assert_eq!(&lat[elems..2 * elems], &new_lat[..elems]);
        assert_eq!(&lab[..2], &[2, 0]);
        // remaining rows come from the replay buffer (values >= 100)
        assert!(lat[2 * elems..].iter().all(|&v| v >= 100.0));
        assert!(lab[2..].iter().all(|&l| l == 5 || l == 6));
    }

    #[test]
    fn ratio_matches_paper_shape() {
        // mini profile: 8 new / 64 total = 1/8 (paper: 21/128 ~ 1/6)
        let b = Batcher::new(64, 8, 16);
        assert_eq!(b.replay_count(), 56);
        let ratio = b.batch_new as f64 / b.batch as f64;
        assert!(ratio < 0.2, "new-data ratio should be small: {ratio}");
    }

    #[test]
    fn replay_only_batch() {
        let elems = 4;
        let (mut buf, mut rng) = filled_buffer(elems);
        let mut batcher = Batcher::new(5, 2, elems);
        let (lat, lab) = batcher.compose_replay_only(&mut buf, &mut rng);
        assert_eq!(lat.len(), 5 * elems);
        assert!(lab.iter().all(|&l| l == 5 || l == 6));
    }

    #[test]
    #[should_panic(expected = "pick must have batch_new rows")]
    fn pick_size_checked() {
        let elems = 4;
        let (mut buf, mut rng) = filled_buffer(elems);
        let mut batcher = Batcher::new(6, 2, elems);
        let new_lat = vec![0f32; 3 * elems];
        batcher.compose(&new_lat, &[0, 1, 2], &[0], &mut buf, &mut rng);
    }
}
