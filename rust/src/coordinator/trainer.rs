//! The QLR-CL session: everything that happens on-device in the paper,
//! orchestrated per learning event (DESIGN.md §5).
//!
//! Per event: frozen-stage forward over the new images (INT-8 or FP32) →
//! mini-batches of new + replayed latents → fused train steps (fwd +
//! BW-ERR/BW-GRAD + SGD, parameters threaded through) → replay-memory
//! update. Evaluation runs the frozen stage + adaptive eval over the
//! held-out test sessions.
//!
//! All compute goes through the [`Backend`] trait, so the same session
//! drives the PJRT AOT modules and the native kernel engine unchanged.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::Result;

use super::batcher::Batcher;
use super::replay::ReplayBuffer;
use crate::runtime::{Backend, Dataset, ParamState};
use crate::util::rng::Rng;

/// One QLR-CL deployment configuration (a point in the Fig 5/6 sweeps).
#[derive(Clone, Copy, Debug)]
pub struct CLConfig {
    /// first adaptive layer (runtime split; one of the manifest splits)
    pub l: usize,
    /// replay-memory capacity N_LR
    pub n_lr: usize,
    /// LR storage bits: 6..8 packed, or 32 for the FP32 baseline arm
    pub lr_bits: u8,
    /// frozen stage: INT-8 (true) or FP32 baseline (false)
    pub int8_frozen: bool,
    /// SGD learning rate
    pub lr: f32,
    /// epochs over each event's images
    pub epochs: usize,
    /// RNG seed (schedule, sampling, replacement)
    pub seed: u64,
}

impl Default for CLConfig {
    fn default() -> Self {
        CLConfig {
            l: 13,
            n_lr: 256,
            lr_bits: 8,
            int8_frozen: true,
            // 0.1 conditions well on the standardized native stack and the
            // fine-tuned artifact models alike (tools/native_mirror.py
            // sweeps: 0.02 barely moves the loss, 0.3 oscillates at l=15)
            lr: 0.1,
            epochs: 2,
            seed: 0,
        }
    }
}

impl CLConfig {
    pub fn label(&self) -> String {
        let fr = if self.int8_frozen { "UINT-8" } else { "FP32" };
        let lrb = if self.lr_bits == 32 {
            "FP32".to_string()
        } else {
            format!("UINT-{}", self.lr_bits)
        };
        format!("l={} N_LR={} {fr}+{lrb}", self.l, self.n_lr)
    }
}

/// Per-event outcome.
#[derive(Clone, Copy, Debug)]
pub struct EventStats {
    pub steps: usize,
    pub mean_loss: f64,
    pub train_acc: f64,
    pub replaced: usize,
}

pub struct Session<'be> {
    be: &'be dyn Backend,
    pub cfg: CLConfig,
    pub params: ParamState,
    pub replay: ReplayBuffer,
    batcher: Batcher,
    pub rng: Rng,
    latent_elems: usize,
    batch_new: usize,
    batch_eval: usize,
    event_count: usize,
    img_scratch: Vec<f32>,
    /// reusable frozen-forward output buffer (one full batch of latents)
    lat_scratch: Vec<f32>,
    /// reusable eval-batch staging buffer (zero-alloc steady-state eval)
    eval_chunk: Vec<f32>,
    /// reusable eval logits buffer
    logits_chunk: Vec<f32>,
    /// test-split latents (computed once — the frozen stage is immutable,
    /// so they never change within or across runs of the same split/mode)
    eval_cache: Option<Rc<(Vec<f32>, Vec<i32>)>>,
}

/// Shared cache of test-split latents keyed by (split, int8) — sweeps over
/// N_LR / Q_LR / seeds reuse the same frozen stage, so the figure harness
/// shares one entry across dozens of runs.
#[derive(Default)]
pub struct EvalLatentCache {
    map: RefCell<HashMap<(usize, bool), Rc<(Vec<f32>, Vec<i32>)>>>,
}

impl EvalLatentCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get(&self, key: (usize, bool)) -> Option<Rc<(Vec<f32>, Vec<i32>)>> {
        self.map.borrow().get(&key).cloned()
    }

    pub fn put(&self, key: (usize, bool), v: Rc<(Vec<f32>, Vec<i32>)>) {
        self.map.borrow_mut().insert(key, v);
    }
}

impl<'be> Session<'be> {
    /// Build a session: load initial adaptive params and seed the replay
    /// memory from the pre-deployment images through the frozen stage.
    pub fn new(be: &'be dyn Backend, ds: &Dataset, cfg: CLConfig) -> Result<Session<'be>> {
        let m = be.manifest();
        let lat = m.latent_info(cfg.l)?;
        let latent_elems = lat.elems();
        let a_max = lat.a_max(cfg.int8_frozen);
        let params = be.load_params(cfg.l)?;

        let replay = if cfg.lr_bits == 32 {
            ReplayBuffer::new_f32(cfg.n_lr, latent_elems)
        } else {
            ReplayBuffer::new_packed(cfg.n_lr, latent_elems, cfg.lr_bits, a_max)
        };

        let b_max = m.batch_eval.max(m.batch_new);
        let mut session = Session {
            be,
            cfg,
            params,
            replay,
            batcher: Batcher::new(m.batch_train, m.batch_new, latent_elems),
            rng: Rng::new(cfg.seed ^ m.seed.wrapping_mul(0x9E37)),
            latent_elems,
            batch_new: m.batch_new,
            batch_eval: m.batch_eval,
            event_count: 0,
            img_scratch: vec![0.0; b_max * m.input_hw * m.input_hw * 3],
            lat_scratch: vec![0.0; b_max * latent_elems],
            eval_chunk: vec![0.0; m.batch_eval * latent_elems],
            logits_chunk: vec![0.0; m.batch_eval * m.num_classes],
            eval_cache: None,
        };

        // Seed the LR memory from the initial (pre-deployment) images —
        // the paper's "LRs sampled from the 3000 initial images".
        let init = ds.initial_indices();
        let (latents, labels) = session.latents_for(ds, &init, false)?;
        let mut seed_rng = session.rng.fork(0x1417);
        session.replay.init_fill(&latents, &labels, &mut seed_rng);
        Ok(session)
    }

    pub fn latent_elems(&self) -> usize {
        self.latent_elems
    }

    pub fn backend(&self) -> &dyn Backend {
        self.be
    }

    /// Frozen-stage forward for arbitrary train/test indices, batched at
    /// the backend batch size (padding the tail batch with repeats).
    fn latents_for(
        &mut self,
        ds: &Dataset,
        indices: &[usize],
        test_split: bool,
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        let b = if test_split { self.batch_eval } else { self.batch_new };
        let img = ds.image_elems();
        let le = self.latent_elems;
        let mut latents = vec![0f32; indices.len() * le];
        let mut labels = vec![0i32; indices.len()];
        let mut start = 0;
        while start < indices.len() {
            let count = (indices.len() - start).min(b);
            for slot in 0..b {
                // pad tail by repeating the last real image
                let idx = indices[start + slot.min(count - 1)];
                let dst = &mut self.img_scratch[slot * img..(slot + 1) * img];
                if test_split {
                    ds.test_image_into(idx, dst);
                } else {
                    ds.train_image_into(idx, dst);
                }
            }
            self.be.frozen_forward(
                self.cfg.l,
                self.cfg.int8_frozen,
                test_split,
                &self.img_scratch[..b * img],
                &mut self.lat_scratch[..b * le],
            )?;
            for slot in 0..count {
                let idx = indices[start + slot];
                let dst_off = (start + slot) * le;
                latents[dst_off..dst_off + le]
                    .copy_from_slice(&self.lat_scratch[slot * le..(slot + 1) * le]);
                labels[start + slot] = if test_split {
                    ds.test_labels[idx]
                } else {
                    ds.train_labels[idx]
                };
            }
            start += count;
        }
        Ok((latents, labels))
    }

    /// One learning event: new images of one (class, session).
    pub fn run_event(&mut self, ds: &Dataset, class: usize, session: usize) -> Result<EventStats> {
        let indices = ds.event_indices(class, session);
        anyhow::ensure!(!indices.is_empty(), "event ({class},{session}) has no images");
        let (latents, labels) = self.latents_for(ds, &indices, false)?;
        self.event_count += 1;
        train_event_on_latents(
            self.be,
            &self.cfg,
            &mut self.params,
            &mut self.replay,
            &mut self.batcher,
            &mut self.rng,
            self.event_count,
            &latents,
            &labels,
        )
    }

    /// Attach a shared eval-latent cache (see [`EvalLatentCache`]).
    pub fn use_eval_cache(&mut self, ds: &Dataset, cache: &EvalLatentCache) -> Result<()> {
        let key = (self.cfg.l, self.cfg.int8_frozen);
        if let Some(hit) = cache.get(key) {
            self.eval_cache = Some(hit);
            return Ok(());
        }
        let n = ds.n_test();
        let all: Vec<usize> = (0..n).collect();
        let entry = Rc::new(self.latents_for(ds, &all, true)?);
        cache.put(key, entry.clone());
        self.eval_cache = Some(entry);
        Ok(())
    }

    /// Test accuracy over the full held-out split.
    pub fn evaluate(&mut self, ds: &Dataset) -> Result<f64> {
        let n = ds.n_test();
        let cached = match &self.eval_cache {
            Some(c) => c.clone(),
            None => {
                let all: Vec<usize> = (0..n).collect();
                let entry = Rc::new(self.latents_for(ds, &all, true)?);
                self.eval_cache = Some(entry.clone());
                entry
            }
        };
        let (latents, labels) = (&cached.0, &cached.1);
        eval_on_latents(
            self.be,
            self.cfg.l,
            &self.params,
            latents,
            labels,
            self.batch_eval,
            &mut self.eval_chunk,
            &mut self.logits_chunk,
        )
    }

    pub fn events_run(&self) -> usize {
        self.event_count
    }
}

/// The per-event training loop over precomputed latents — shared verbatim
/// by [`Session::run_event`] and the fleet tenants
/// ([`crate::fleet::Tenant`]). Sharing the implementation is what makes
/// "fleet at N=1 reproduces the single-session path bit-for-bit" a
/// structural property instead of a hope: both callers consume the SAME
/// rng stream in the same order (per-epoch shuffle, per-step replay
/// draws, then one forked stream for the AR1* replacement).
///
/// `event_count` is 1-based and already incremented for this event.
#[allow(clippy::too_many_arguments)]
pub fn train_event_on_latents(
    be: &dyn Backend,
    cfg: &CLConfig,
    params: &mut ParamState,
    replay: &mut ReplayBuffer,
    batcher: &mut Batcher,
    rng: &mut Rng,
    event_count: usize,
    latents: &[f32],
    labels: &[i32],
) -> Result<EventStats> {
    let n = labels.len();
    let batch_new = batcher.batch_new;
    let mut order: Vec<usize> = (0..n).collect();
    let mut loss_sum = 0.0;
    let mut correct = 0u64;
    let mut seen = 0u64;
    let mut steps = 0usize;

    for _epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        let mut pos = 0;
        while pos + batch_new <= n {
            let pick = &order[pos..pos + batch_new];
            let (bl, bb) = batcher.compose(latents, labels, pick, replay, rng);
            let (loss, corr) = be.train_step(cfg.l, params, bl, bb, cfg.lr)?;
            loss_sum += loss;
            correct += corr;
            seen += batcher.batch as u64;
            steps += 1;
            pos += batch_new;
        }
    }

    // replay-memory update (AR1*-style random replacement)
    let mut upd_rng = rng.fork(0x5EED ^ event_count as u64);
    let replaced = replay.event_update(latents, labels, event_count, &mut upd_rng);

    Ok(EventStats {
        steps,
        mean_loss: if steps > 0 { loss_sum / steps as f64 } else { 0.0 },
        train_acc: if seen > 0 { correct as f64 / seen as f64 } else { 0.0 },
        replaced,
    })
}

/// Top-1 accuracy of the adaptive stage over precomputed latents, batched
/// at `batch_eval` with repeat-padding on the tail batch — the eval loop
/// [`Session::evaluate`] and the fleet tenants share. `eval_chunk` /
/// `logits_chunk` are caller-owned staging buffers
/// (`batch_eval * latent_elems` / `batch_eval * num_classes`), so
/// steady-state evaluation stays allocation-free.
#[allow(clippy::too_many_arguments)]
pub fn eval_on_latents(
    be: &dyn Backend,
    l: usize,
    params: &ParamState,
    latents: &[f32],
    labels: &[i32],
    batch_eval: usize,
    eval_chunk: &mut [f32],
    logits_chunk: &mut [f32],
) -> Result<f64> {
    let n = labels.len();
    anyhow::ensure!(n > 0, "eval_on_latents: empty test set");
    let le = latents.len() / n;
    anyhow::ensure!(latents.len() == n * le, "eval_on_latents: ragged latents");
    let ncls = be_num_classes(be);
    anyhow::ensure!(
        eval_chunk.len() == batch_eval * le && logits_chunk.len() == batch_eval * ncls,
        "eval_on_latents: staging buffer sizes"
    );
    let mut correct = 0usize;
    let mut start = 0;
    while start < n {
        let count = (n - start).min(batch_eval);
        // pad tail batch by repeating the last row (no per-batch alloc)
        for slot in 0..batch_eval {
            let src = (start + slot.min(count - 1)) * le;
            eval_chunk[slot * le..(slot + 1) * le].copy_from_slice(&latents[src..src + le]);
        }
        be.adaptive_eval(l, params, eval_chunk, logits_chunk)?;
        for slot in 0..count {
            let row = &logits_chunk[slot * ncls..(slot + 1) * ncls];
            if argmax(row) == labels[start + slot] as usize {
                correct += 1;
            }
        }
        start += count;
    }
    Ok(correct as f64 / n as f64)
}

fn be_num_classes(be: &dyn Backend) -> usize {
    be.manifest().num_classes
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[2.0, 2.0, 1.0]), 0);
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn config_labels() {
        let c = CLConfig { lr_bits: 7, int8_frozen: true, ..Default::default() };
        assert_eq!(c.label(), "l=13 N_LR=256 UINT-8+UINT-7");
        let c2 = CLConfig { lr_bits: 32, int8_frozen: false, ..Default::default() };
        assert!(c2.label().contains("FP32+FP32"));
    }
}
